//! Mixed-archetype workload planning + headroom analysis through the
//! unified workload subsystem: one spec string builds the workload (the
//! paper's motivating archetypes — always-on baselines, weekday bursts,
//! nightly batch windows, deadline jobs, duty-cycled sensors), a pipeline
//! rightsizes a cluster for it, and `sim::autoscale::stress` hits the
//! plan with surprise load drawn from another registered family.
//!
//! Run with: cargo run --release --example batch_windows

use tlrs::algo::pipeline::{CrossFill, LocalSearch, Lp, Pipeline};
use tlrs::algo::placement::FitPolicy;
use tlrs::io::workload::parse_workload;
use tlrs::lp::solver::NativePdhgSolver;
use tlrs::model::trim;
use tlrs::sim::autoscale;

fn main() -> anyhow::Result<()> {
    // 1. one spec names the whole workload — same grammar the CLI
    //    (--workload) and the planning service speak
    let spec = "mixed:services=120,m=4,dims=2,cap=0.35..1.0,dem=0.02..0.2";
    let source = parse_workload(spec)?;
    let inst = source.generate(7)?;
    println!("workload: {}", source.describe());
    println!(
        "  {} tasks on {} node-types over {} slots",
        inst.n_tasks(),
        inst.n_types(),
        inst.horizon
    );

    let tr = trim(&inst).instance;
    println!("timeline trimmed to {} slots", tr.horizon);

    // 2. rightsize with LP mapping + cross-fill + local search
    let solver = NativePdhgSolver::default();
    let rep = Pipeline::new()
        .map(Lp)
        .refine(CrossFill)
        .refine(LocalSearch::default())
        .label("lp+fill+ls")
        .run(&tr, &solver)?;
    let plan = &rep.solution;
    plan.verify(&tr).expect("feasible");
    println!(
        "\nplan: ${:.2} via {} ({} candidates; stages: {}); LB ${:.2}",
        rep.cost,
        rep.label,
        rep.candidates,
        rep.stage_summary(),
        rep.certified_lb.expect("LP pipelines certify a bound")
    );
    for (b, c) in plan.nodes_per_type(&tr).iter().enumerate() {
        if *c > 0 {
            println!("  {} x {}", c, tr.node_types[b].name);
        }
    }

    // 3. stress: replay the planned load, then add a heavy-tailed spiky
    //    surprise workload from another family in the same registry
    let surprise = parse_workload(&format!(
        "spiky:services=30,dims=2,horizon={},dem=0.02..0.15",
        tr.horizon
    ))?;
    let out = autoscale::stress(&tr, plan, surprise.as_ref(), 99, FitPolicy::FirstFit)?;
    println!("\nsurprise: {} ({} tasks)", out.surprise, out.surprise_tasks);
    println!(
        "planned load : {:.1}% admitted (expected 100%)",
        out.planned.admission_rate() * 100.0
    );
    println!(
        "fixed cluster: {:.1}% of planned+surprise arrivals admitted",
        out.fixed.admission_rate() * 100.0
    );
    println!(
        "hybrid mode  : {:.1}% admitted, ${:.2} overflow rent ({} nodes, {:.1}% of plan)",
        out.hybrid.admission_rate() * 100.0,
        out.hybrid.overflow_cost,
        out.hybrid.overflow_nodes,
        100.0 * out.hybrid.overflow_cost / out.hybrid.planned_cost
    );
    Ok(())
}
