//! Mixed-archetype workload planning + headroom analysis: builds a
//! workload from the paper's motivating patterns (always-on baselines,
//! weekday bursts, nightly batch windows, deadline jobs, duty-cycled
//! sensors), rightsizes a cluster for it, then stress-tests the plan with
//! the admission/auto-scaling simulator (the paper's future-work hook).
//!
//! Run with: cargo run --release --example batch_windows

use tlrs::algo::pipeline::{preset, CrossFill, LocalSearch, Lp, Pipeline};
use tlrs::algo::placement::FitPolicy;
use tlrs::io::patterns::{mixed_workload, WEEK_HOURS};
use tlrs::lp::solver::NativePdhgSolver;
use tlrs::model::{trim, Instance, NodeType, Task};
use tlrs::sim::autoscale;

fn main() -> anyhow::Result<()> {
    // 1. compose the workload from archetypes
    let tasks = mixed_workload(120, 7);
    println!(
        "workload: {} time-limited tasks from 120 services over a {}-hour week",
        tasks.len(),
        WEEK_HOURS
    );

    let catalog = vec![
        NodeType::new("edge-small", vec![0.35, 0.40], 3.0),
        NodeType::new("edge-med", vec![0.60, 0.60], 5.0),
        NodeType::new("dc-large", vec![1.0, 1.0], 8.5),
    ];
    let inst = Instance::new(tasks, catalog, WEEK_HOURS);
    let tr = trim(&inst).instance;
    println!("timeline trimmed to {} slots", tr.horizon);

    // 2. rightsize
    // One pipeline: LP mapping, cross-fill, then local search refining
    // every candidate — the combo no pre-pipeline preset could reach.
    let solver = NativePdhgSolver::default();
    let rep = Pipeline::new()
        .map(Lp)
        .refine(CrossFill)
        .refine(LocalSearch::default())
        .label("lp+fill+ls")
        .run(&tr, &solver)?;
    let plan = &rep.solution;
    plan.verify(&tr).expect("feasible");
    println!(
        "\nplan: ${:.2} via {} ({} candidates; stages: {}); LB ${:.2}",
        rep.cost,
        rep.label,
        rep.candidates,
        rep.stage_summary(),
        rep.certified_lb.expect("LP pipelines certify a bound")
    );
    for (b, c) in plan.nodes_per_type(&tr).iter().enumerate() {
        if *c > 0 {
            println!("  {} x {}", c, tr.node_types[b].name);
        }
    }

    // 3. stress: replay planned load, then +30% surprise bursts
    let planned = autoscale::simulate(&tr, &plan, &tr.tasks, FitPolicy::FirstFit, false);
    println!(
        "\nplanned load : {:.1}% admitted (expected 100%)",
        planned.admission_rate() * 100.0
    );

    let mut surprise = tr.tasks.clone();
    let extra = mixed_workload(36, 99);
    let base = surprise.len() as u64;
    // surprise tasks live on the original hourly timeline; retrim jointly
    let mut all = inst.tasks.clone();
    all.extend(extra.iter().map(|t| Task::new(base + t.id, t.demand.clone(), t.start, t.end)));
    let joint = trim(&Instance::new(all, inst.node_types.clone(), WEEK_HOURS)).instance;
    surprise = joint.tasks.clone();

    // re-plan cluster on the joint trimmed timeline for a fair replay
    let joint_rep = preset("lp-map-f").unwrap().run(&joint, &solver)?;
    let fixed = autoscale::simulate(&joint, &rep_plan_on(&joint, &joint_rep.solution), &surprise, FitPolicy::FirstFit, false);
    let hybrid = autoscale::simulate(&joint, &plan_shell(&joint, &plan), &surprise, FitPolicy::FirstFit, true);
    println!(
        "joint replan : ${:.2} for planned+surprise load",
        joint_rep.solution.cost(&joint)
    );
    println!(
        "fixed replan cluster admits {:.1}% of planned+surprise arrivals",
        fixed.admission_rate() * 100.0
    );
    println!(
        "original plan + rented overflow: {:.1}% admitted, ${:.2} overflow rent ({} nodes)",
        hybrid.admission_rate() * 100.0,
        hybrid.overflow_cost,
        hybrid.overflow_nodes
    );
    Ok(())
}

/// Use a solution's purchased nodes as an empty shell on another instance
/// with the same node-type catalog.
fn plan_shell(inst: &Instance, plan: &tlrs::model::Solution) -> tlrs::model::Solution {
    let mut shell = tlrs::model::Solution::new(inst.n_tasks());
    for (i, node) in plan.nodes.iter().enumerate() {
        shell.nodes.push(tlrs::model::PlacedNode {
            type_idx: node.type_idx,
            purchase_order: i,
            tasks: Vec::new(),
        });
    }
    shell
}

fn rep_plan_on(inst: &Instance, sol: &tlrs::model::Solution) -> tlrs::model::Solution {
    plan_shell(inst, sol)
}
