//! Stock-market week (the paper's Figure 2): a long-running service with a
//! low-demand baseline plus market-hours bursts, modeled as six
//! time-limited tasks, then rightsized together with a batch-analytics
//! workload that runs overnight.
//!
//! Shows the modeling workflow the paper motivates: windows of one
//! long-running task become independent time-limited tasks, letting night
//! batch jobs reuse the daytime burst capacity.
//!
//! Run with: cargo run --release --example stock_market_week

use tlrs::algo::pipeline::{preset, Portfolio};
use tlrs::harness::scenarios::figure2_tasks;
use tlrs::io::patterns::{Pattern, Timeline};
use tlrs::lp::solver::NativePdhgSolver;
use tlrs::model::{trim, Instance, NodeType};
use tlrs::sim::replay::replay;

fn main() -> anyhow::Result<()> {
    // Figure 2's six tasks: T1 baseline all week, T2-T6 market-hours bursts.
    let mut tasks = figure2_tasks();

    // Plus overnight batch analytics: three shards, 2:00-5:00 every
    // night, expressed with the pattern library on the hourly week.
    let week = Timeline::hourly_week();
    let mut next_id = 100u64;
    for shard in 0..3 {
        let batch = Pattern::NightlyBatch {
            demand: vec![0.20 + 0.05 * shard as f64, 0.15],
            start_hour: 2,
            duration: 3,
        };
        tasks.extend(batch.expand(week, &mut next_id)?);
    }

    // Node catalog: a big general-purpose shape and a small edge shape.
    let inst = Instance::new(
        tasks,
        vec![
            NodeType::new("c2-large", vec![1.0, 1.0], 10.0),
            NodeType::new("e2-small", vec![0.35, 0.40], 3.0),
        ],
        7 * 24,
    );
    println!(
        "workload: {} tasks over a {}-slot week; catalog: {} shapes",
        inst.n_tasks(),
        inst.horizon,
        inst.n_types()
    );

    let tr = trim(&inst).instance;
    println!("trimmed timeline: {} -> {} slots", inst.horizon, tr.horizon);

    // Race the two filling presets as a portfolio (one LP solve).
    let solver = NativePdhgSolver::default();
    let race = Portfolio::new()
        .add(preset("penalty-map-f").unwrap())
        .add(preset("lp-map-f").unwrap())
        .run(&tr, &solver)?;
    let pen = race.get("PenaltyMap-F").unwrap();
    let lp = race.get("LP-map-F").unwrap();
    let lb = lp.certified_lb.expect("LP pipelines certify a bound");
    println!("\nPenaltyMap-F cluster cost : ${:.2}", pen.cost);
    println!(
        "LP-map-F     cluster cost : ${:.2}   (lower bound ${:.2}, normalized {:.3})",
        lp.cost,
        lb,
        lp.cost / lb
    );
    let per_type = lp.solution.nodes_per_type(&tr);
    for (b, count) in per_type.iter().enumerate() {
        if *count > 0 {
            println!("  {} x {}", count, tr.node_types[b].name);
        }
    }

    // Replay the week against the plan: utilization + overload check.
    let rep = replay(&tr, &lp.solution);
    println!(
        "\nreplay: {} overloads, avg busy-node utilization {:.1}%, peak {} concurrent tasks",
        rep.overloads,
        rep.avg_utilization * 100.0,
        rep.peak_tasks
    );

    // Contrast with a plan that treats every task as always-on.
    let flat = inst.collapse_timeline();
    let flat_tr = trim(&flat).instance;
    let flat_lp = preset("lp-map-f").unwrap().run(&flat_tr, &solver)?;
    println!(
        "\nignoring the timeline, the same workload plans at ${:.2} ({:.2}x)",
        flat_lp.cost,
        flat_lp.cost / lp.cost
    );
    Ok(())
}
