//! Quickstart: rightsizing a tiny cluster — the paper's Figure 1 instance
//! — through the composable pipeline API.
//!
//! A solve is a pipeline: `.map(..)` picks the task -> node-type mapping
//! strategy, `.fit(..)` the within-type placement policy (omit it to race
//! both), `.refine(..)` appends post-passes (cross-fill, local search).
//! The four paper algorithms are named presets over the same builder, and
//! a `Portfolio` races pipelines in parallel on one shared LP solve.
//!
//! Run with: cargo run --release --example quickstart

use tlrs::algo::exact;
use tlrs::algo::pipeline::{preset, CrossFill, LocalSearch, Lp, Penalty, Pipeline, Portfolio};
use tlrs::harness::scenarios::figure1_instance;
use tlrs::lp::solver::NativePdhgSolver;
use tlrs::model::trim;

fn main() -> anyhow::Result<()> {
    // Three time-limited tasks, two node-types (Figure 1 of the paper).
    let inst = figure1_instance();
    println!(
        "instance: {} tasks, {} node-types, T={}",
        inst.n_tasks(),
        inst.n_types(),
        inst.horizon
    );
    for u in &inst.tasks {
        println!("  task {} demand {:?} active [{}, {}]", u.id, u.peak(), u.start, u.end);
    }
    for b in &inst.node_types {
        println!("  type {:8} capacity {:?} cost ${}", b.name, b.capacity, b.cost);
    }

    // Step 1: trim the timeline (only task start slots matter).
    let trimmed = trim(&inst);
    println!("\ntimeline trimmed: T={} -> T={}", inst.horizon, trimmed.instance.horizon);
    let tr = trimmed.instance;
    let solver = NativePdhgSolver::default();

    // Step 2: build pipelines. The baseline penalty mapping...
    let pen = Pipeline::new().map(Penalty::both()).run(&tr, &solver)?;
    println!("\nPenaltyMap  cost: ${:.2}  (stages: {})", pen.cost, pen.stage_summary());

    // ...and the LP mapping with cross-fill — the same pipeline the
    // "lp-map-f" preset names.
    let lp = Pipeline::new()
        .map(Lp)
        .refine(CrossFill)
        .label("LP-map-F")
        .run(&tr, &solver)?;
    println!(
        "LP-map-F    cost: ${:.2}  (LP lower bound ${:.2})",
        lp.cost,
        lp.certified_lb.expect("LP pipelines certify a bound")
    );

    // Step 3: race a portfolio — all four presets plus a combo no preset
    // reaches (LP + fill + local search) — sharing ONE LP solve.
    let mut portfolio = Portfolio::presets();
    portfolio = portfolio.add(
        Pipeline::new()
            .map(Lp)
            .refine(CrossFill)
            .refine(LocalSearch::default())
            .label("lp+fill+ls"),
    );
    let race = portfolio.run(&tr, &solver)?;
    println!("\nportfolio race (one LP solve, {} pipelines):", race.reports.len());
    for (i, r) in race.reports.iter().enumerate() {
        let marker = if i == race.winner { "  <- winner" } else { "" };
        println!("  {:<14} ${:.2}{marker}", r.label, r.cost);
    }

    // Step 4: check against the exact optimum (tiny instance).
    let opt = exact::optimal(&tr);
    println!("exact optimum   : ${:.2}", opt.cost(&tr));

    // Step 5: what ignoring the timeline would cost.
    let collapsed = inst.collapse_timeline();
    let opt_flat = exact::optimal(&collapsed);
    println!(
        "\nwithout time-sharing the same workload needs ${:.2} of nodes",
        opt_flat.cost(&collapsed)
    );

    // Every solution is independently verified; presets are also
    // reachable by name: preset("lp-map-f") == the pipeline above.
    race.best().solution.verify(&tr).expect("feasible");
    assert!(preset("lp-map-f").is_some());
    println!("\nsolution verified: every (node, timeslot, dimension) within capacity");

    // Step 6: workloads are spec strings too — the same grammar the CLI
    // --workload flag, the figures and the planning service parse.
    let source = tlrs::io::workload::parse_workload("mixed:services=40,m=3")?;
    let mixed = trim(&source.generate(1)?).instance;
    let rep = preset("lp-map-f").unwrap().run(&mixed, &solver)?;
    println!(
        "\nworkload '{}' ({}):\n  {} tasks planned at ${:.2}",
        source.label(),
        source.describe(),
        mixed.n_tasks(),
        rep.cost
    );
    Ok(())
}
