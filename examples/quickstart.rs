//! Quickstart: rightsizing a tiny cluster — the paper's Figure 1 instance.
//!
//! Run with: cargo run --release --example quickstart

use tlrs::algo::algorithms::{lp_map_best, penalty_map_best};
use tlrs::algo::exact;
use tlrs::harness::scenarios::figure1_instance;
use tlrs::lp::solver::NativePdhgSolver;
use tlrs::model::trim;

fn main() -> anyhow::Result<()> {
    // Three time-limited tasks, two node-types (Figure 1 of the paper).
    let inst = figure1_instance();
    println!(
        "instance: {} tasks, {} node-types, T={}",
        inst.n_tasks(),
        inst.n_types(),
        inst.horizon
    );
    for u in &inst.tasks {
        println!("  task {} demand {:?} active [{}, {}]", u.id, u.demand, u.start, u.end);
    }
    for b in &inst.node_types {
        println!("  type {:8} capacity {:?} cost ${}", b.name, b.capacity, b.cost);
    }

    // Step 1: trim the timeline (only task start slots matter).
    let trimmed = trim(&inst);
    println!("\ntimeline trimmed: T={} -> T={}", inst.horizon, trimmed.instance.horizon);
    let tr = trimmed.instance;

    // Step 2: the baseline PenaltyMap and the LP-based mapping.
    let solver = NativePdhgSolver::default();
    let pen = penalty_map_best(&tr, false);
    let lp = lp_map_best(&tr, &solver, true)?;
    println!("\nPenaltyMap  cost: ${:.2}", pen.cost(&tr));
    println!(
        "LP-map-F    cost: ${:.2}  (LP lower bound ${:.2})",
        lp.solution.cost(&tr),
        lp.certified_lb
    );

    // Step 3: check against the exact optimum (tiny instance).
    let opt = exact::optimal(&tr);
    println!("exact optimum   : ${:.2}", opt.cost(&tr));

    // Step 4: what ignoring the timeline would cost.
    let collapsed = inst.collapse_timeline();
    let opt_flat = exact::optimal(&collapsed);
    println!(
        "\nwithout time-sharing the same workload needs ${:.2} of nodes",
        opt_flat.cost(&collapsed)
    );

    // Every solution is independently verified.
    lp.solution.verify(&tr).expect("feasible");
    println!("\nsolution verified: every (node, timeslot, dimension) within capacity");
    Ok(())
}
