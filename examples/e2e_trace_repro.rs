//! End-to-end driver (the EXPERIMENTS.md validation run): exercises every
//! layer of the system on a real small workload —
//!
//!   1. generate the GCT-2019-like trace and round-trip it through the
//!      on-disk CSV format (the "processed trace" of paper section VI-A),
//!   2. sample paper-style scenarios (n tasks, m node-types),
//!   3. plan with all four algorithms through the coordinator, using the
//!      AOT JAX/Pallas LP artifact via PJRT when a shape bucket fits and
//!      the native sparse-operator PDHG otherwise,
//!   4. certify lower bounds, normalize costs, verify + replay solutions,
//!   5. print the paper's headline metric: LP-map-F within ~20% of the
//!      lower bound and significantly cheaper than PenaltyMap.
//!
//! Run with: cargo run --release --example e2e_trace_repro [-- quick]

use tlrs::coordinator::config::Backend;
use tlrs::coordinator::planner::Planner;
use tlrs::harness::runner::{instantiate, master_trace};
use tlrs::io::files;
use tlrs::io::workload::WorkloadSpec;
use tlrs::model::trim;
use tlrs::sim::replay::replay;
use tlrs::util::stats;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "quick");
    let t_start = std::time::Instant::now();

    // 1. trace generation + on-disk round-trip
    let trace = master_trace();
    let dir = std::env::temp_dir().join("tlrs_e2e");
    std::fs::create_dir_all(&dir)?;
    let csv = dir.join("gct_like_trace.csv");
    files::save_trace_csv(&trace.tasks, &csv)?;
    let loaded = files::load_trace_csv(&csv)?;
    anyhow::ensure!(loaded == trace.tasks, "trace CSV round-trip mismatch");
    println!(
        "trace: {} tasks, {} machine shapes; round-tripped through {}",
        trace.tasks.len(),
        trace.node_types.len(),
        csv.display()
    );

    // 2-4. scenarios through the full coordinator
    let planner = Planner::new(Backend::Auto)?;
    let scenarios: &[(usize, usize)] =
        if quick { &[(200, 8)] } else { &[(200, 8), (500, 10), (1000, 13)] };
    let seeds: &[u64] = if quick { &[1] } else { &[1, 2, 3] };

    let mut norm_pen = Vec::new();
    let mut norm_lpf = Vec::new();
    println!(
        "\n{:<16} {:>6} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "scenario", "seed", "PenaltyMap", "PenaltyMap-F", "LP-map", "LP-map-F", "backend"
    );
    for &(n, m) in scenarios {
        // scenarios are workload specs — the same strings the CLI
        // --workload flag and the service JSON API accept
        let spec = WorkloadSpec::parse(&format!("gct:n={n},m={m}"))?;
        for &seed in seeds {
            let inst = instantiate(&spec, seed)?;
            let row = planner.evaluate(&inst)?;
            println!(
                "n={n:<5} m={m:<5} {seed:>6} {:>12.3} {:>12.3} {:>12.3} {:>12.3} {:>10}",
                row.algos[0].normalized,
                row.algos[1].normalized,
                row.algos[2].normalized,
                row.algos[3].normalized,
                row.backend_used
            );
            norm_pen.push(row.get("PenaltyMap").unwrap().normalized);
            norm_lpf.push(row.get("LP-map-F").unwrap().normalized);

            // independent validation: verify + event replay of LP-map-F
            let tr = trim(&inst).instance;
            let (solver, _) = planner.solver_for(&tr);
            let rep = tlrs::algo::pipeline::preset("lp-map-f")
                .unwrap()
                .run(&tr, solver.as_ref())?;
            rep.solution.verify(&tr).expect("feasible");
            let sim = replay(&tr, &rep.solution);
            anyhow::ensure!(sim.overloads == 0, "replay found overloads");
        }
    }

    // 5. headline metrics
    let mean_pen = stats::mean(&norm_pen);
    let mean_lpf = stats::mean(&norm_lpf);
    let worst_lpf = stats::max(&norm_lpf);
    println!("\n=== headline (paper section VI) ===");
    println!("PenaltyMap mean normalized cost : {mean_pen:.3}");
    println!("LP-map-F   mean normalized cost : {mean_lpf:.3}");
    println!("LP-map-F   worst case           : {worst_lpf:.3}  (paper: within 20% of LB)");
    println!(
        "LP-map-F vs PenaltyMap          : {:.1}% cheaper on average",
        (mean_pen - mean_lpf) / mean_lpf * 100.0
    );
    println!("total wall time                 : {:.1?}", t_start.elapsed());

    anyhow::ensure!(worst_lpf < 1.35, "LP-map-F too far from the lower bound");
    anyhow::ensure!(mean_lpf <= mean_pen + 1e-9, "LP-map-F should beat PenaltyMap");
    println!("\nE2E VALIDATION PASSED");
    Ok(())
}
