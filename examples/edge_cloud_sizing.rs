//! Edge-cloud sizing with a heterogeneous cost model — the paper's
//! limited-resource motivation (Telco / 5G base-station clouds, section I):
//! cold-start rightsizing is the only knob, since there is no elastic pool
//! to autoscale into, and installation cost dominates.
//!
//! Uses the GCP pricing coefficients (paper section VI-C) and sweeps the
//! cost-model exponent `e` to show how rate curvature changes the chosen
//! machine mix.
//!
//! Run with: cargo run --release --example edge_cloud_sizing

use tlrs::algo::pipeline::{preset, Portfolio};
use tlrs::io::pricing;
use tlrs::io::workload::parse_workload;
use tlrs::lp::solver::NativePdhgSolver;
use tlrs::model::trim;

fn main() -> anyhow::Result<()> {
    let solver = NativePdhgSolver::default();

    println!("edge site: 400 duty-cycled sensor/NFV tasks, 8 machine shapes, 24h timeline");
    println!(
        "pricing coefficients (per normalized unit): cpu ${:.3}/h, mem ${:.3}/h\n",
        pricing::GCP_CPU_RATE,
        pricing::GCP_MEM_RATE
    );
    println!(
        "{:<6} {:>14} {:>14} {:>12} {:>10}  {}",
        "e", "PenaltyMap-F", "LP-map-F", "LB", "norm", "machine mix (LP-map-F)"
    );

    for e in [0.5, 1.0, 2.0] {
        // one workload spec per exponent — `cost=gcp` composes the GCE
        // rate card onto the synthetic family
        let source = parse_workload(&format!(
            "synth:n=400,m=8,dims=2,horizon=24,dem=0.02..0.15,cost=gcp,e={e}"
        ))?;
        let inst = source.generate(11)?;
        let tr = trim(&inst).instance;

        // race both filling presets in parallel on one shared LP solve
        let race = Portfolio::new()
            .add(preset("penalty-map-f").unwrap())
            .add(preset("lp-map-f").unwrap())
            .run(&tr, &solver)?;
        let pen = race.get("PenaltyMap-F").unwrap();
        let lp = race.get("LP-map-F").unwrap();
        let lb = lp.certified_lb.expect("LP pipelines certify a bound");
        lp.solution.verify(&tr).expect("feasible");

        let mix: Vec<String> = lp
            .solution
            .nodes_per_type(&tr)
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(b, &c)| format!("{}x{}", c, tr.node_types[b].name))
            .collect();
        println!(
            "{:<6} {:>13.2}$ {:>13.2}$ {:>11.2}$ {:>10.3}  {}",
            e,
            pen.cost,
            lp.cost,
            lb,
            lp.cost / lb,
            mix.join(" ")
        );
    }

    println!(
        "\nsub-linear rates (e<1) favor few large nodes; super-linear (e>1) favor many small ones."
    );
    println!("all plans verified feasible at every timeslot and dimension.");
    Ok(())
}
