//! Integration: the experiment harness end-to-end on shrunken sweeps —
//! the figure pipeline (scenario -> planner -> aggregation -> report JSON)
//! and the special runners.

use tlrs::coordinator::config::Backend;
use tlrs::coordinator::planner::Planner;
use tlrs::harness::{report, runner, scenarios, special};
use tlrs::util::json;

fn shrink(fig: &mut scenarios::Figure) {
    fig.seeds = vec![1];
    for p in fig.points.iter_mut() {
        // every point is a workload spec now: shrink by overriding keys
        match p.workload.family.as_str() {
            "synth" => {
                p.workload.set("n", "50");
                let m: usize =
                    p.workload.get("m").and_then(|v| v.parse().ok()).unwrap_or(10);
                p.workload.set("m", m.min(5).to_string());
            }
            "gct" => {
                let n: usize =
                    p.workload.get("n").and_then(|v| v.parse().ok()).unwrap_or(1000);
                p.workload.set("n", n.min(80).to_string());
            }
            other => panic!("unexpected figure family {other}"),
        }
    }
    fig.points.truncate(2);
}

#[test]
fn every_generic_figure_runs_shrunken() {
    let planner = Planner::new(Backend::Native).unwrap();
    for id in scenarios::all_ids() {
        let Some(mut fig) = scenarios::figure(id, true) else { continue };
        shrink(&mut fig);
        let res = runner::run_figure(&planner, &fig).unwrap();
        assert_eq!(res.rows.len(), fig.points.len(), "{id}");
        for row in &res.rows {
            for s in &row.normalized {
                assert!(s.mean >= 1.0 - 1e-6, "{id}: normalized below LB: {s:?}");
                assert!(s.mean.is_finite(), "{id}");
            }
            assert!(row.lower_bound.mean > 0.0, "{id}");
        }
        // table + JSON render
        let table = report::render_table(&res);
        assert!(table.contains(res.id.as_str()), "{id}");
        let parsed = json::parse(&report::to_json(&res).to_string()).unwrap();
        assert_eq!(parsed.get("id").as_str(), Some(id));
    }
}

#[test]
fn special_runners_produce_output() {
    let planner = Planner::new(Backend::Native).unwrap();

    let (text, json_out) = special::fig1(&planner).unwrap();
    assert!(text.contains("fig1"));
    assert_eq!(json_out.get("timeline_aware_cost").as_f64(), Some(10.0));
    assert_eq!(json_out.get("timeline_agnostic_cost").as_f64(), Some(16.0));

    let (text, _) = special::tab1();
    assert!(text.contains("tab1"));

    let (text, json_out) = special::running_time(&planner, true).unwrap();
    assert!(text.contains("rt"));
    assert_eq!(json_out.get("seconds").as_arr().unwrap().len(), 5);
}

#[test]
fn near_integrality_after_crossover() {
    // shrunken fig5: the crossover makes the LP mapping near-integral
    use tlrs::algo::lpmap::solve_lp_mapping;
    use tlrs::io::synth::{generate, SynthParams};
    use tlrs::lp::solver::NativePdhgSolver;
    use tlrs::model::trim;
    let inst = generate(&SynthParams { n: 200, ..Default::default() }, 1);
    let tr = trim(&inst).instance;
    let outcome = solve_lp_mapping(&tr, &NativePdhgSolver::default()).unwrap();
    let frac = outcome.x_max.iter().filter(|&&v| v > 0.9).count() as f64 / 200.0;
    assert!(frac > 0.75, "only {frac} near-integral after crossover");
}

#[test]
fn master_trace_is_cached_and_stable() {
    let a = runner::master_trace();
    let b = runner::master_trace();
    assert!(std::ptr::eq(a, b));
    assert_eq!(a.tasks.len(), 13_000);
    assert_eq!(a.node_types.len(), 13);
}
