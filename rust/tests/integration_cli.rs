//! Integration: the `tlrs` binary end-to-end through its CLI surface
//! (gen -> solve -> lb round-trips through real files and process exits).

use std::path::PathBuf;
use std::process::Command;

fn tlrs_bin() -> Option<PathBuf> {
    // cargo builds integration tests next to the binary
    let mut path = std::env::current_exe().ok()?;
    path.pop(); // deps/
    path.pop(); // debug|release/
    path.push("tlrs");
    path.exists().then_some(path)
}

fn run(args: &[&str]) -> (bool, String, String) {
    let bin = tlrs_bin().expect("tlrs binary built");
    let out = Command::new(bin).args(args).output().expect("spawn tlrs");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn gen_solve_lb_roundtrip() {
    if tlrs_bin().is_none() {
        eprintln!("tlrs binary not built; skipping");
        return;
    }
    let dir = std::env::temp_dir().join(format!("tlrs_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let inst = dir.join("inst.json");
    let sol = dir.join("sol.json");
    let csv = dir.join("trace.csv");

    let (ok, stdout, stderr) = run(&[
        "gen", "--kind", "synth", "--n", "60", "--m", "4", "--seed", "3",
        "--out", inst.to_str().unwrap(), "--csv", csv.to_str().unwrap(),
    ]);
    assert!(ok, "gen failed: {stderr}");
    assert!(stdout.contains("60 tasks"));
    assert!(inst.exists() && csv.exists());

    let (ok, stdout, stderr) = run(&[
        "solve", "--input", inst.to_str().unwrap(), "--algo", "lp-map-f",
        "--backend", "native", "--replay", "--out", sol.to_str().unwrap(),
    ]);
    assert!(ok, "solve failed: {stderr}");
    assert!(stdout.contains("cluster cost"), "{stdout}");
    assert!(stdout.contains("0 overloads"), "{stdout}");
    assert!(sol.exists());
    // solution file parses and has nodes
    let parsed = tlrs::util::json::parse(&std::fs::read_to_string(&sol).unwrap()).unwrap();
    assert!(parsed.get("n_nodes").as_f64().unwrap() >= 1.0);

    // pipeline-spec grammar: a combo no preset reaches runs end-to-end
    // (LP mapping + cross-fill + local search) and verifies feasible
    let (ok, stdout, stderr) = run(&[
        "solve", "--input", inst.to_str().unwrap(), "--algo", "lp+fill+ls",
        "--backend", "native", "--replay",
    ]);
    assert!(ok, "combo solve failed: {stderr}");
    assert!(stdout.contains("algorithm      : lp+fill+ls"), "{stdout}");
    assert!(stdout.contains("0 overloads"), "{stdout}");
    assert!(stdout.contains("stage times"), "{stdout}");

    // comma-separated specs race as a portfolio and report the winner
    let (ok, stdout, stderr) = run(&[
        "solve", "--input", inst.to_str().unwrap(),
        "--algo", "penalty-map-f,lp-map-f", "--backend", "native",
    ]);
    assert!(ok, "portfolio solve failed: {stderr}");
    assert!(stdout.contains("<- winner"), "{stdout}");

    // parse errors teach the valid presets and grammar
    let (ok, _, stderr) = run(&[
        "solve", "--input", inst.to_str().unwrap(), "--algo", "magic",
    ]);
    assert!(!ok);
    assert!(stderr.contains("unknown algorithm"), "{stderr}");
    assert!(stderr.contains("penalty-map-f"), "{stderr}");
    assert!(stderr.contains("lp-map-f"), "{stderr}");
    assert!(stderr.contains("fill | ls"), "{stderr}");

    let (ok, stdout, stderr) =
        run(&["lb", "--input", inst.to_str().unwrap(), "--backend", "native"]);
    assert!(ok, "lb failed: {stderr}");
    assert!(stdout.contains("best certified LB"), "{stdout}");

    let (ok, stdout, _) = run(&["info"]);
    assert!(ok);
    assert!(stdout.contains("tlrs"));

    // unknown flags/commands fail cleanly
    let (ok, _, stderr) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown subcommand"));
    let (ok, _, stderr) = run(&["solve", "--input", "/nonexistent.json"]);
    assert!(!ok);
    assert!(stderr.contains("error"));
}

#[test]
fn figures_tab1_runs() {
    if tlrs_bin().is_none() {
        return;
    }
    let dir = std::env::temp_dir().join(format!("tlrs_cli_fig_{}", std::process::id()));
    let (ok, stdout, stderr) = run(&[
        "figures", "tab1", "--backend", "native", "--out-dir", dir.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("Table I") || stdout.contains("tab1"));
    assert!(dir.join("tab1.json").exists());
}
