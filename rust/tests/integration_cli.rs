//! Integration: the `tlrs` binary end-to-end through its CLI surface
//! (gen -> solve -> lb round-trips through real files and process exits).

use std::path::PathBuf;
use std::process::Command;

fn tlrs_bin() -> Option<PathBuf> {
    // cargo builds integration tests next to the binary
    let mut path = std::env::current_exe().ok()?;
    path.pop(); // deps/
    path.pop(); // debug|release/
    path.push("tlrs");
    path.exists().then_some(path)
}

fn run(args: &[&str]) -> (bool, String, String) {
    let bin = tlrs_bin().expect("tlrs binary built");
    let out = Command::new(bin).args(args).output().expect("spawn tlrs");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn gen_solve_lb_roundtrip() {
    if tlrs_bin().is_none() {
        eprintln!("tlrs binary not built; skipping");
        return;
    }
    let dir = std::env::temp_dir().join(format!("tlrs_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let inst = dir.join("inst.json");
    let sol = dir.join("sol.json");
    let csv = dir.join("trace.csv");

    let (ok, stdout, stderr) = run(&[
        "gen", "--kind", "synth", "--n", "60", "--m", "4", "--seed", "3",
        "--out", inst.to_str().unwrap(), "--csv", csv.to_str().unwrap(),
    ]);
    assert!(ok, "gen failed: {stderr}");
    assert!(stdout.contains("60 tasks"));
    // the legacy flags compile down to a workload spec
    assert!(stdout.contains("synth:m=4,n=60"), "{stdout}");
    assert!(inst.exists() && csv.exists());

    // --workload generates the identical instance through the same parser
    let inst2 = dir.join("inst2.json");
    let (ok, stdout, stderr) = run(&[
        "gen", "--workload", "synth:n=60,m=4", "--seed", "3",
        "--out", inst2.to_str().unwrap(),
    ]);
    assert!(ok, "gen --workload failed: {stderr}");
    assert!(stdout.contains("60 tasks"));
    assert_eq!(
        std::fs::read_to_string(&inst).unwrap(),
        std::fs::read_to_string(&inst2).unwrap(),
        "legacy flags and --workload must generate byte-identical files"
    );

    // legacy flags that never applied to a kind stay ignored (old scripts
    // passed --dims to gct and it was dropped), not errors
    let inst3 = dir.join("inst3.json");
    let (ok, _, stderr) = run(&[
        "gen", "--kind", "gct", "--n", "40", "--m", "4", "--dims", "3",
        "--out", inst3.to_str().unwrap(),
    ]);
    assert!(ok, "legacy gct gen failed: {stderr}");
    // but mixing --workload with legacy flags is an explicit error
    let (ok, _, stderr) = run(&[
        "gen", "--workload", "synth", "--n", "500", "--out", inst3.to_str().unwrap(),
    ]);
    assert!(!ok);
    assert!(stderr.contains("legacy"), "{stderr}");

    let (ok, stdout, stderr) = run(&[
        "solve", "--input", inst.to_str().unwrap(), "--algo", "lp-map-f",
        "--backend", "native", "--replay", "--out", sol.to_str().unwrap(),
    ]);
    assert!(ok, "solve failed: {stderr}");
    assert!(stdout.contains("cluster cost"), "{stdout}");
    assert!(stdout.contains("0 overloads"), "{stdout}");
    assert!(sol.exists());
    // solution file parses and has nodes
    let parsed = tlrs::util::json::parse(&std::fs::read_to_string(&sol).unwrap()).unwrap();
    assert!(parsed.get("n_nodes").as_f64().unwrap() >= 1.0);

    // pipeline-spec grammar: a combo no preset reaches runs end-to-end
    // (LP mapping + cross-fill + local search) and verifies feasible
    let (ok, stdout, stderr) = run(&[
        "solve", "--input", inst.to_str().unwrap(), "--algo", "lp+fill+ls",
        "--backend", "native", "--replay",
    ]);
    assert!(ok, "combo solve failed: {stderr}");
    assert!(stdout.contains("algorithm      : lp+fill+ls"), "{stdout}");
    assert!(stdout.contains("0 overloads"), "{stdout}");
    assert!(stdout.contains("stage times"), "{stdout}");

    // comma-separated specs race as a portfolio and report the winner
    let (ok, stdout, stderr) = run(&[
        "solve", "--input", inst.to_str().unwrap(),
        "--algo", "penalty-map-f,lp-map-f", "--backend", "native",
    ]);
    assert!(ok, "portfolio solve failed: {stderr}");
    assert!(stdout.contains("<- winner"), "{stdout}");

    // parse errors teach the valid presets and grammar
    let (ok, _, stderr) = run(&[
        "solve", "--input", inst.to_str().unwrap(), "--algo", "magic",
    ]);
    assert!(!ok);
    assert!(stderr.contains("unknown algorithm"), "{stderr}");
    assert!(stderr.contains("penalty-map-f"), "{stderr}");
    assert!(stderr.contains("lp-map-f"), "{stderr}");
    assert!(stderr.contains("fill | ls"), "{stderr}");

    let (ok, stdout, stderr) =
        run(&["lb", "--input", inst.to_str().unwrap(), "--backend", "native"]);
    assert!(ok, "lb failed: {stderr}");
    assert!(stdout.contains("best certified LB"), "{stdout}");

    // solve straight from a workload spec, no file needed
    let (ok, stdout, stderr) = run(&[
        "solve", "--workload", "duty:services=20,m=3", "--seed", "2",
        "--algo", "penalty-map-f", "--backend", "native", "--replay",
    ]);
    assert!(ok, "solve --workload failed: {stderr}");
    assert!(stdout.contains("cluster cost"), "{stdout}");
    assert!(stdout.contains("0 overloads"), "{stdout}");

    // bad workload specs teach the grammar and the family catalog
    let (ok, _, stderr) = run(&["solve", "--workload", "warp:n=2"]);
    assert!(!ok);
    assert!(stderr.contains("invalid workload spec"), "{stderr}");
    assert!(stderr.contains("spec grammar"), "{stderr}");
    assert!(stderr.contains("spiky"), "{stderr}");
    assert!(stderr.contains("gct"), "{stderr}");
    // infeasible pattern parameters are parse-style errors, not aborts
    let (ok, _, stderr) = run(&["gen", "--workload", "mixed:day=0", "--out", "/dev/null"]);
    assert!(!ok);
    assert!(stderr.contains("invalid workload spec"), "{stderr}");

    // acceptance: a shaped workload solves end-to-end through the CLI
    // with verify-clean output (solve verifies and replays) and a valid
    // certified lower bound line
    let (ok, stdout, stderr) = run(&[
        "solve", "--workload", "mixed:services=20,m=3,shape=diurnal", "--seed", "2",
        "--algo", "lp+fill+ls", "--backend", "native", "--replay",
    ]);
    assert!(ok, "shaped solve failed: {stderr}");
    assert!(stdout.contains("cluster cost"), "{stdout}");
    assert!(stdout.contains("0 overloads"), "{stdout}");
    assert!(stdout.contains("lower bound"), "{stdout}");
    // shape=flat is accepted and identical in meaning to omitting it
    let (ok, _, stderr) = run(&[
        "solve", "--workload", "duty:services=10,m=3,shape=flat", "--seed", "2",
        "--algo", "penalty-map", "--backend", "native",
    ]);
    assert!(ok, "{stderr}");
    // bad shapes teach the grammar
    let (ok, _, stderr) = run(&["gen", "--workload", "synth:shape=wavy", "--out", "/dev/null"]);
    assert!(!ok);
    assert!(stderr.contains("not flat, ramp, diurnal or spike"), "{stderr}");

    // csv import family: gen a trace, re-import it as a workload, solve
    let csv2 = dir.join("import.csv");
    let (ok, _, stderr) = run(&[
        "gen", "--workload", "synth:n=30,m=3,dims=2", "--seed", "5",
        "--out", dir.join("csvsrc.json").to_str().unwrap(),
        "--csv", csv2.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    let spec = format!("csv:path={}", csv2.to_str().unwrap());
    let (ok, stdout, stderr) = run(&[
        "solve", "--workload", &spec, "--algo", "penalty-map-f",
        "--backend", "native", "--replay",
    ]);
    assert!(ok, "csv solve failed: {stderr}");
    assert!(stdout.contains("0 overloads"), "{stdout}");
    // a missing file fails like a parse-style error, not a panic
    let (ok, _, stderr) = run(&["solve", "--workload", "csv:path=/nonexistent.csv"]);
    assert!(!ok);
    assert!(stderr.contains("error"), "{stderr}");

    let (ok, stdout, _) = run(&["info"]);
    assert!(ok);
    assert!(stdout.contains("tlrs"));

    // unknown flags/commands fail cleanly
    let (ok, _, stderr) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown subcommand"));
    let (ok, _, stderr) = run(&["solve", "--input", "/nonexistent.json"]);
    assert!(!ok);
    assert!(stderr.contains("error"));
}

#[test]
fn workloads_catalog_and_stress() {
    if tlrs_bin().is_none() {
        return;
    }
    // catalog lists every family with keys and the grammar
    let (ok, stdout, _) = run(&["workloads"]);
    assert!(ok);
    for fam in
        ["synth", "gct", "mixed", "burst", "batch", "deadline", "duty", "spiky", "waves", "csv"]
    {
        assert!(stdout.contains(fam), "catalog missing {fam}: {stdout}");
    }
    assert!(stdout.contains("spec grammar"), "{stdout}");
    // the shape grammar is taught by the catalog and on every family
    assert!(stdout.contains("shape"), "{stdout}");
    assert!(stdout.contains("flat | ramp | diurnal | spike"), "{stdout}");

    // --names / --smoke are machine-readable (one entry per line)
    let (ok, names, _) = run(&["workloads", "--names"]);
    assert!(ok);
    let names: Vec<&str> = names.lines().collect();
    assert!(names.contains(&"waves"), "{names:?}");
    let (ok, smoke, _) = run(&["workloads", "--smoke"]);
    assert!(ok);
    for line in smoke.lines() {
        assert!(line.contains(':'), "smoke spec '{line}' has no parameters");
    }
    assert_eq!(smoke.lines().count(), names.len());

    // stress: plan a workload, hit it with surprise load
    let (ok, stdout, stderr) = run(&[
        "stress", "--workload", "burst:services=15,m=3", "--surprise",
        "spiky:services=10,dims=2", "--backend", "native", "--algo", "penalty-map-f",
    ]);
    assert!(ok, "stress failed: {stderr}");
    assert!(stdout.contains("planned load"), "{stdout}");
    assert!(stdout.contains("hybrid overflow"), "{stdout}");
}

#[test]
fn decomposed_solve_cli() {
    if tlrs_bin().is_none() {
        return;
    }
    let dir = std::env::temp_dir().join(format!("tlrs_cli_deco_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let sol = dir.join("deco-sol.json");
    for dspec in ["window:3", "dims", "size:2"] {
        let (ok, stdout, stderr) = run(&[
            "solve", "--workload", "synth:n=90,m=4,dims=3", "--seed", "4",
            "--algo", "penalty-map,penalty-map-f", "--decompose", dspec,
            "--backend", "native", "--replay", "--out", sol.to_str().unwrap(),
        ]);
        assert!(ok, "decomposed solve {dspec} failed: {stderr}");
        assert!(stdout.contains(&format!("decompose      : {dspec}")), "{stdout}");
        assert!(stdout.contains("partition    :"), "{stdout}");
        assert!(stdout.contains("stitch"), "{stdout}");
        assert!(stdout.contains("lower bound"), "{stdout}");
        assert!(stdout.contains("sum of parts"), "{stdout}");
        // --replay re-simulates the stitched solution: it must be clean
        assert!(stdout.contains("0 overloads"), "{stdout}");
        let parsed =
            tlrs::util::json::parse(&std::fs::read_to_string(&sol).unwrap()).unwrap();
        assert!(parsed.get("n_nodes").as_f64().unwrap() >= 1.0);
    }
    // degenerate and malformed specs are CLI errors that teach the grammar
    let (ok, _, stderr) =
        run(&["solve", "--workload", "synth:n=20,m=3", "--decompose", "window:0"]);
    assert!(!ok);
    assert!(stderr.contains("k must be"), "{stderr}");
    assert!(stderr.contains("spec grammar"), "{stderr}");
    let (ok, _, stderr) =
        run(&["solve", "--workload", "synth:n=20,m=3", "--decompose", "shard"]);
    assert!(!ok);
    assert!(stderr.contains("unknown partitioner"), "{stderr}");
}

#[test]
fn figures_tab1_runs() {
    if tlrs_bin().is_none() {
        return;
    }
    let dir = std::env::temp_dir().join(format!("tlrs_cli_fig_{}", std::process::id()));
    let (ok, stdout, stderr) = run(&[
        "figures", "tab1", "--backend", "native", "--out-dir", dir.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("Table I") || stdout.contains("tab1"));
    assert!(dir.join("tab1.json").exists());
}
