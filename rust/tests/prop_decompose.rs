//! Property tests for partition-decomposed solves: a k=1 decomposition
//! must be *bit-identical* to the plain sequential portfolio, every
//! decomposed solution must verify clean on the independent dense
//! backend, the combined certificate must lower-bound the reported cost
//! on the figure-style scenarios, and every built-in partitioner must
//! emit disjoint covering partitions on every workload family.

use tlrs::algo::decompose::{
    parse_decompose, solve_decomposed, validate_partition, MAX_PARTITIONS,
};
use tlrs::algo::pipeline::parse_portfolio;
use tlrs::io::synth::{generate, SynthParams};
use tlrs::io::workload::parse_workload;
use tlrs::lp::solver::{MappingSolver, NativePdhgSolver};
use tlrs::model::{trim, DenseProfile, Instance};

fn factory() -> Box<dyn MappingSolver> {
    Box::new(NativePdhgSolver::default())
}

fn figure_cases() -> Vec<(String, Instance)> {
    let mut cases = Vec::new();
    for seed in [2u64, 19] {
        let inst = generate(
            &SynthParams { n: 140, m: 5, dims: 3, ..Default::default() },
            seed,
        );
        cases.push((format!("synth seed {seed}"), trim(&inst).instance));
    }
    // piecewise-demand mix: the decomposition must survive shaped tasks
    let inst = parse_workload("mixed:services=40,shape=diurnal")
        .unwrap()
        .generate(7)
        .unwrap();
    cases.push(("mixed diurnal".into(), trim(&inst).instance));
    cases
}

#[test]
fn k1_decomposition_is_bit_identical_to_sequential_portfolio() {
    let portfolio = parse_portfolio("penalty-map,lp-map-f").unwrap();
    for (label, tr) in figure_cases() {
        for spec in ["window:1", "dims:1", "size:1"] {
            let spec = parse_decompose(spec).unwrap();
            let rep = solve_decomposed(&tr, &portfolio, &factory, &spec).unwrap();
            let direct = portfolio
                .run_sequential(&tr, &NativePdhgSolver::default())
                .unwrap();
            let best = direct.best();
            assert_eq!(
                rep.cost.to_bits(),
                best.cost.to_bits(),
                "{label} {spec}: cost not bit-identical"
            );
            assert_eq!(rep.solution.assignment, best.solution.assignment, "{label} {spec}");
            assert_eq!(rep.solution.nodes.len(), best.solution.nodes.len(), "{label} {spec}");
            for (a, b) in rep.solution.nodes.iter().zip(&best.solution.nodes) {
                assert_eq!(a.type_idx, b.type_idx, "{label} {spec}");
                assert_eq!(a.purchase_order, b.purchase_order, "{label} {spec}");
                assert_eq!(a.tasks, b.tasks, "{label} {spec}");
            }
            assert_eq!(rep.partitions.len(), 1, "{label} {spec}");
            assert_eq!(rep.stitch_seconds, 0.0, "{label} {spec}: no stitch pass at k=1");
        }
    }
}

#[test]
fn decomposed_solutions_verify_on_the_dense_backend() {
    let portfolio = parse_portfolio("penalty-map,penalty-map-f").unwrap();
    for (label, tr) in figure_cases() {
        for spec in ["window:4", "dims", "size:3"] {
            let spec = parse_decompose(spec).unwrap();
            let rep = solve_decomposed(&tr, &portfolio, &factory, &spec).unwrap();
            // segment-tree and dense backends must agree the plan is valid
            rep.solution
                .verify(&tr)
                .unwrap_or_else(|v| panic!("{label} {spec}: {v:?}"));
            rep.solution
                .verify_with::<DenseProfile>(&tr)
                .unwrap_or_else(|v| panic!("{label} {spec} (dense): {v:?}"));
            // every task of the original instance is placed exactly once
            assert_eq!(rep.solution.assignment.len(), tr.n_tasks(), "{label} {spec}");
            let placed: usize = rep.solution.nodes.iter().map(|n| n.tasks.len()).sum();
            assert_eq!(placed, tr.n_tasks(), "{label} {spec}");
        }
    }
}

#[test]
fn combined_certificate_bounds_cost_on_figure_seeds() {
    let portfolio = parse_portfolio("lp-map-f").unwrap();
    for (label, tr) in figure_cases() {
        for spec in ["window:3", "size:2"] {
            let spec = parse_decompose(spec).unwrap();
            let rep = solve_decomposed(&tr, &portfolio, &factory, &spec).unwrap();
            let tol = 1e-6 * (1.0 + rep.cost.abs());
            assert!(
                rep.certified_lb > 0.0 && rep.certified_lb <= rep.cost + tol,
                "{label} {spec}: certified lb {} vs cost {}",
                rep.certified_lb,
                rep.cost
            );
            // stitching never raises cost above the merged solution
            assert!(rep.cost <= rep.pre_stitch_cost + 1e-9, "{label} {spec}");
            // the node-disjoint certificate bounds the pre-stitch cost
            assert!(
                rep.pre_stitch_cost >= rep.sum_lb - tol,
                "{label} {spec}: merged {} below sum of partition bounds {}",
                rep.pre_stitch_cost,
                rep.sum_lb
            );
            // the global certificate is never the (invalid-globally) sum
            assert!(rep.certified_lb <= rep.sum_lb + tol, "{label} {spec}");
            assert!(rep.congestion_lb <= rep.certified_lb + tol, "{label} {spec}");
        }
    }
}

#[test]
fn partitioners_emit_disjoint_covering_parts_across_families() {
    for wspec in [
        "synth:n=75,m=4,dims=3",
        "gct:n=60,m=5",
        "burst:services=20,m=3,shape=spike",
        "deadline:services=40,m=3",
    ] {
        let inst = parse_workload(wspec).unwrap().generate(3).unwrap();
        let tr = trim(&inst).instance;
        for dspec in ["window:6", "window:1", "dims", "dims:2", "size", "size:4"] {
            let spec = parse_decompose(dspec).unwrap();
            let parts = spec.partitioner().partition(&tr).unwrap();
            validate_partition(tr.n_tasks(), &parts)
                .unwrap_or_else(|e| panic!("{wspec} {dspec}: {e:#}"));
            if let Some(k) = spec.requested_k() {
                assert!(parts.len() <= k, "{wspec} {dspec}: {} parts > k {k}", parts.len());
            }
        }
    }
}

#[test]
fn degenerate_specs_are_errors_not_degenerate_solves() {
    // parse-time rejections
    for bad in ["window:0", "dims:0", "size:0", "window:65", "size:9999", "shard", "window:k"] {
        assert!(parse_decompose(bad).is_err(), "{bad} must not parse");
    }
    assert!(parse_decompose(&format!("window:{MAX_PARTITIONS}")).is_ok());

    // partition-time rejection: k exceeding the task count
    let inst = generate(&SynthParams { n: 4, m: 2, ..Default::default() }, 1);
    let tr = trim(&inst).instance;
    let portfolio = parse_portfolio("penalty-map").unwrap();
    let spec = parse_decompose("window:10").unwrap();
    let err = solve_decomposed(&tr, &portfolio, &factory, &spec).unwrap_err();
    assert!(format!("{err:#}").contains("exceeds"), "{err:#}");

    // validate_partition catches malformed hand-built partitions
    assert!(validate_partition(4, &[vec![0, 1, 2, 3], vec![]]).is_err());
    assert!(validate_partition(4, &[vec![0, 1], vec![1, 2, 3]]).is_err());
    assert!(validate_partition(4, &[vec![0, 1], vec![2]]).is_err());
}
