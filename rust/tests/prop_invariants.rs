//! Property-based invariant tests: randomized instance generators drive
//! hundreds of cases through every algorithm, checking the invariants
//! DESIGN.md section 6 lists. (Hand-rolled driver — the vendored crate
//! universe has no proptest; shrinking is replaced by seed reporting.)

use tlrs::algo::algorithms::{penalty_map_best, Algorithm};
use tlrs::algo::lowerbound::lower_bound;
use tlrs::algo::penalty_map::{map_tasks, min_penalties, MappingPolicy};
use tlrs::algo::placement::FitPolicy;
use tlrs::algo::twophase::{solve_with_mapping, solve_with_mapping_ref};
use tlrs::io::synth::{generate, CostKind, SynthParams};
use tlrs::lp::solver::NativePdhgSolver;
use tlrs::lp::{dual, scaling, MappingLp};
use tlrs::model::{trim, DemandSeg, DenseProfile, Instance, LoadProfile, Profile, Task};
use tlrs::util::rng::Rng;

/// Random task over `[s, e]`: flat, or (when `shaped` and the span
/// allows) piecewise with 2-3 demand segments.
fn random_task(
    rng: &mut Rng,
    id: u64,
    s: u32,
    e: u32,
    dims: usize,
    dem: (f64, f64),
    shaped: bool,
) -> Task {
    let draw = |rng: &mut Rng| -> Vec<f64> {
        (0..dims).map(|_| rng.uniform(dem.0, dem.1)).collect()
    };
    let span = (e - s + 1) as u64;
    if !shaped || span < 2 || rng.below(10) < 4 {
        return Task::new(id, draw(rng), s, e);
    }
    let k = 2 + rng.below((span - 1).min(2)) as u32; // 2 or 3 segments
    let mut cuts: Vec<u32> = (1..k)
        .map(|_| s + 1 + rng.below(span - 1) as u32)
        .collect();
    cuts.sort_unstable();
    cuts.dedup();
    let mut segs = Vec::new();
    let mut lo = s;
    for &c in &cuts {
        segs.push(DemandSeg { start: lo, end: c - 1, demand: draw(rng) });
        lo = c;
    }
    segs.push(DemandSeg { start: lo, end: e, demand: draw(rng) });
    Task::piecewise(id, segs)
}

/// Random instance parameters spanning the interesting regimes.
fn random_params(rng: &mut Rng) -> SynthParams {
    let dims = 1 + rng.below(6) as usize;
    SynthParams {
        n: 10 + rng.below(120) as usize,
        m: 1 + rng.below(7) as usize,
        dims,
        horizon: 2 + rng.below(30) as u32,
        cap_range: (0.2, 1.0),
        dem_range: match rng.below(3) {
            0 => (0.01, 0.05),
            1 => (0.01, 0.2),
            _ => (0.05, 0.5),
        },
        cost_model: match rng.below(3) {
            0 => CostKind::HomogeneousLinear,
            1 => CostKind::HeterogeneousRandom { exponent: 0.5 },
            _ => CostKind::HeterogeneousRandom { exponent: 2.0 },
        },
    }
}

fn random_instance(seed: u64) -> Instance {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9));
    let params = random_params(&mut rng);
    generate(&params, seed)
}

const CASES: u64 = 60;

/// Seed-repeat determinism: regenerating the instance from the same seed
/// and re-running every algorithm must reproduce the solution bit for
/// bit (Debug formatting of f64 is shortest-roundtrip, so equal strings
/// mean equal bits). This pins the ordered-container invariant the
/// `unordered-iter` lint rule guards — a HashMap anywhere on the result
/// path shows up here as run-to-run drift.
#[test]
fn repeated_solves_are_bit_identical() {
    use tlrs::algo::algorithms::run;
    let solver = NativePdhgSolver::default();
    for seed in 0..6u64 {
        let first = trim(&random_instance(seed + 9000)).instance;
        let second = trim(&random_instance(seed + 9000)).instance;
        for algo in Algorithm::all() {
            let (a, _) = run(&first, algo, &solver).expect("first solve");
            let (b, _) = run(&second, algo, &solver).expect("second solve");
            assert_eq!(
                format!("{a:?}"),
                format!("{b:?}"),
                "seed {seed} {algo:?}: repeated solve diverged"
            );
        }
    }
}

#[test]
fn trimming_preserves_cost_and_feasibility() {
    for seed in 0..CASES {
        let inst = random_instance(seed);
        let tr = trim(&inst);
        // spans map back within the original horizon
        assert!(tr.instance.horizon as usize <= inst.n_tasks().max(1), "seed {seed}");
        // solving trimmed and verifying is consistent; costs agree with the
        // untrimmed instance solved with the same mapping
        let mapping = map_tasks(&tr.instance, MappingPolicy::HAvg);
        let sol_t = solve_with_mapping(&tr.instance, &mapping, FitPolicy::FirstFit, false);
        assert!(sol_t.verify(&tr.instance).is_ok(), "seed {seed}");
        let mapping_o = map_tasks(&inst, MappingPolicy::HAvg);
        assert_eq!(mapping, mapping_o, "seed {seed}: mapping is timeline-free");
        let sol_o = solve_with_mapping(&inst, &mapping_o, FitPolicy::FirstFit, false);
        assert!(sol_o.verify(&inst).is_ok(), "seed {seed}");
        assert!(
            (sol_t.cost(&tr.instance) - sol_o.cost(&inst)).abs() < 1e-9,
            "seed {seed}: trimmed {} vs original {}",
            sol_t.cost(&tr.instance),
            sol_o.cost(&inst)
        );
    }
}

#[test]
fn every_algorithm_is_feasible_and_above_congestion_bound() {
    for seed in 0..CASES {
        let inst = random_instance(seed + 1000);
        let tr = trim(&inst).instance;
        let mut lp = MappingLp::from_instance(&tr);
        scaling::equilibrate(&mut lp);
        let cong = dual::congestion_bound(&lp);
        for algo in [Algorithm::PenaltyMap, Algorithm::PenaltyMapF] {
            let sol = penalty_map_best(&tr, algo == Algorithm::PenaltyMapF);
            assert!(sol.verify(&tr).is_ok(), "seed {seed} {algo:?}");
            assert!(
                sol.cost(&tr) >= cong - 1e-9,
                "seed {seed} {algo:?}: cost {} below congestion bound {cong}",
                sol.cost(&tr)
            );
        }
    }
}

#[test]
fn mapping_respects_admissibility_and_penalties() {
    for seed in 0..CASES {
        let inst = random_instance(seed + 2000);
        for policy in [MappingPolicy::HAvg, MappingPolicy::HMax] {
            let mapping = map_tasks(&inst, policy);
            let pstar = min_penalties(&inst, policy);
            for (u, &b) in mapping.iter().enumerate() {
                assert!(
                    inst.node_types[b].admits(inst.tasks[u].peak()),
                    "seed {seed}: task {u} mapped to inadmissible type {b}"
                );
                assert!(pstar[u].is_finite(), "seed {seed}: task {u}");
            }
        }
    }
}

#[test]
fn lp_lower_bound_below_all_algorithms() {
    // heavier: fewer cases
    for seed in 0..15u64 {
        let inst = random_instance(seed + 3000);
        let tr = trim(&inst).instance;
        let solver = NativePdhgSolver::default();
        let lb = lower_bound(&tr, &solver).unwrap();
        for fill in [false, true] {
            let sol = penalty_map_best(&tr, fill);
            assert!(
                lb.best() <= sol.cost(&tr) + 1e-6,
                "seed {seed}: lb {} vs penalty cost {}",
                lb.best(),
                sol.cost(&tr)
            );
        }
        // congestion bound <= LP optimum holds exactly; lp_objective is the
        // *approximate* primal value, so allow first-order slack
        assert!(
            lb.congestion_bound <= lb.lp_objective * 1.005 + 1e-6,
            "seed {seed}: congestion {} vs approx LP {}",
            lb.congestion_bound,
            lb.lp_objective
        );
    }
}

#[test]
fn solution_accounting_is_exact() {
    for seed in 0..CASES {
        let inst = random_instance(seed + 4000);
        let tr = trim(&inst).instance;
        let mapping = map_tasks(&tr, MappingPolicy::HAvg);
        let sol = solve_with_mapping(&tr, &mapping, FitPolicy::SimilarityFit, true);
        // cost equals sum over nodes_per_type
        let per_type = sol.nodes_per_type(&tr);
        let recomputed: f64 = per_type
            .iter()
            .enumerate()
            .map(|(b, &c)| c as f64 * tr.node_types[b].cost)
            .sum();
        assert!((recomputed - sol.cost(&tr)).abs() < 1e-9, "seed {seed}");
        // every task appears in exactly one node task list
        let mut seen = vec![false; tr.n_tasks()];
        for node in &sol.nodes {
            for &u in &node.tasks {
                assert!(!seen[u], "seed {seed}: task {u} twice");
                seen[u] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "seed {seed}: unplaced task");
        // replay agrees with verify
        let rep = tlrs::sim::replay::replay(&tr, &sol);
        assert_eq!(rep.overloads, 0, "seed {seed}");
    }
}

#[test]
fn pdhg_certified_bound_valid_even_unconverged() {
    // failure injection: starve the solver of iterations; the certified
    // dual bound must remain a valid lower bound regardless.
    use tlrs::lp::pdhg::{self, PdhgOptions};
    use tlrs::lp::simplex;
    for seed in 0..10u64 {
        let inst = generate(
            &SynthParams {
                n: 12,
                m: 3,
                dims: 2,
                horizon: 6,
                dem_range: (0.05, 0.3),
                ..Default::default()
            },
            seed,
        );
        let mut lp = MappingLp::from_instance(&trim(&inst).instance);
        scaling::equilibrate(&mut lp);
        let exact = simplex::solve(&lp.to_dense());
        let starved = pdhg::solve(
            &lp,
            &PdhgOptions { max_iters: 50, chunk: 25, ..Default::default() },
        );
        assert!(!starved.converged);
        let (lb, _) = dual::certified_bound(&lp, &starved.y);
        assert!(
            lb <= exact.objective + 1e-7 * (1.0 + exact.objective),
            "seed {seed}: starved lb {lb} exceeds optimum {}",
            exact.objective
        );
    }
}

#[test]
fn indexed_profile_matches_dense_reference() {
    // randomized add/remove/probe workloads: the segment-tree profile and
    // the seed's dense array must agree on every query the solvers issue
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed.wrapping_mul(0xA24B_AED7).wrapping_add(11));
        let t_len = 1 + rng.below(120) as usize;
        let dims = 1 + rng.below(4) as usize;
        let cap: Vec<f64> = (0..dims).map(|_| rng.uniform(0.3, 1.0)).collect();
        let mut idx: LoadProfile = Profile::new(t_len, cap.clone());
        let mut dense: DenseProfile = Profile::new(t_len, cap.clone());
        let mut live: Vec<Task> = Vec::new();
        for step in 0..160u64 {
            let op = rng.below(4);
            if live.is_empty() || op == 0 {
                let s = rng.below(t_len as u64) as u32;
                let e = s + rng.below(t_len as u64 - s as u64) as u32;
                // shaped tasks exercise the per-segment range operations
                let task = random_task(&mut rng, step, s, e, dims, (0.01, 0.4), true);
                // mirror the solvers' invariant: profiles are fits-guarded,
                // so the clamped (dense/seed) and unclamped (indexed)
                // similarity computations stay comparable
                if dense.fits(&task) {
                    idx.add_task(&task);
                    dense.add_task(&task);
                    live.push(task);
                }
            } else if op == 1 {
                let k = rng.below(live.len() as u64) as usize;
                let task = live.swap_remove(k);
                idx.remove_task(&task);
                dense.remove_task(&task);
            } else {
                let s = rng.below(t_len as u64) as u32;
                let e = s + rng.below(t_len as u64 - s as u64) as u32;
                let probe = random_task(&mut rng, 1_000_000 + step, s, e, dims, (0.01, 0.6), true);
                assert_eq!(
                    idx.fits(&probe),
                    dense.fits(&probe),
                    "seed {seed} step {step}: fits diverges"
                );
                let (si, sd) = (idx.similarity(&probe), dense.similarity(&probe));
                assert!(
                    (si - sd).abs() <= 1e-9 * (1.0 + sd.abs()),
                    "seed {seed} step {step}: similarity {si} vs {sd}"
                );
                let (lo, hi) = (s as usize, e as usize);
                for d in 0..dims {
                    let (ma, mb) = (idx.window_max(d, lo, hi), dense.window_max(d, lo, hi));
                    assert!((ma - mb).abs() <= 1e-9, "seed {seed} step {step} dim {d}: max");
                    let (s1, q1) = idx.window_sums(d, lo, hi);
                    let (s2, q2) = dense.window_sums(d, lo, hi);
                    assert!(
                        (s1 - s2).abs() <= 1e-9 * (1.0 + s2.abs()),
                        "seed {seed} step {step} dim {d}: sum {s1} vs {s2}"
                    );
                    assert!(
                        (q1 - q2).abs() <= 1e-9 * (1.0 + q2.abs()),
                        "seed {seed} step {step} dim {d}: sumsq {q1} vs {q2}"
                    );
                    assert!(
                        (idx.peak(d) - dense.peak(d)).abs() <= 1e-9,
                        "seed {seed} step {step} dim {d}: peak"
                    );
                }
                // overload enumeration agrees slot-for-slot
                let thr = rng.uniform(0.0, 1.5);
                for d in 0..dims {
                    let (a, b) = (idx.overloads(d, thr), dense.overloads(d, thr));
                    assert_eq!(a.len(), b.len(), "seed {seed} step {step} dim {d}: overloads");
                    for (&(ta, va), &(tb, vb)) in a.iter().zip(&b) {
                        assert_eq!(ta, tb, "seed {seed} step {step} dim {d}");
                        assert!((va - vb).abs() <= 1e-9, "seed {seed} step {step} dim {d}");
                    }
                }
            }
        }
        assert!(
            (idx.peak_utilization() - dense.peak_utilization()).abs() <= 1e-9,
            "seed {seed}: peak_utilization"
        );
    }
}

#[test]
fn indexed_placement_matches_dense_reference_costs() {
    // the indexed core is an exact optimization: solver outputs must
    // coincide with the seed's dense path, not just stay feasible
    for seed in 0..20u64 {
        let inst = random_instance(seed + 6000);
        let tr = trim(&inst).instance;
        let mapping = map_tasks(&tr, MappingPolicy::HAvg);
        for policy in [FitPolicy::FirstFit, FitPolicy::SimilarityFit] {
            let indexed = solve_with_mapping(&tr, &mapping, policy, false);
            let dense = solve_with_mapping_ref(&tr, &mapping, policy);
            assert!(indexed.verify(&tr).is_ok(), "seed {seed} {policy:?}");
            // the dense verifier is independent of the segment-tree code
            // the solver ran on — both backends must pass
            assert!(
                indexed.verify_with::<DenseProfile>(&tr).is_ok(),
                "seed {seed} {policy:?}: dense verify"
            );
            assert!(dense.verify(&tr).is_ok(), "seed {seed} {policy:?}");
            assert_eq!(
                indexed.nodes.len(),
                dense.nodes.len(),
                "seed {seed} {policy:?}: node count"
            );
            assert!(
                (indexed.cost(&tr) - dense.cost(&tr)).abs() < 1e-12,
                "seed {seed} {policy:?}: cost {} vs {}",
                indexed.cost(&tr),
                dense.cost(&tr)
            );
            // first-fit decisions carry an EPS-wide margin, so the two
            // backends must agree placement-for-placement; similarity-fit
            // argmaxes can sit within an ulp on near-ties, so for it only
            // the node count and cost equality above are asserted
            if policy == FitPolicy::FirstFit {
                for (a, b) in indexed.nodes.iter().zip(&dense.nodes) {
                    assert_eq!(a.type_idx, b.type_idx, "seed {seed} {policy:?}");
                    assert_eq!(a.tasks, b.tasks, "seed {seed} {policy:?}");
                }
            }
        }
    }
}

#[test]
fn all_solvers_clean_on_synth_and_gct_scenarios() {
    fn check_all_solvers(tr: &Instance, label: &str) {
        let mapping = map_tasks(tr, MappingPolicy::HAvg);
        for policy in [FitPolicy::FirstFit, FitPolicy::SimilarityFit] {
            for fill in [false, true] {
                let sol = solve_with_mapping(tr, &mapping, policy, fill);
                assert!(sol.verify(tr).is_ok(), "{label} {policy:?} fill={fill}");
                // indexed and dense verifiers must agree
                assert!(
                    sol.verify_with::<DenseProfile>(tr).is_ok(),
                    "{label} {policy:?} fill={fill}: dense verify"
                );
            }
            let sol = tlrs::algo::online::solve_online(tr, policy).unwrap();
            assert!(sol.verify(tr).is_ok(), "{label} online {policy:?}");
            assert!(
                sol.verify_with::<DenseProfile>(tr).is_ok(),
                "{label} online {policy:?}: dense verify"
            );
        }
        let mut sol = solve_with_mapping(tr, &mapping, FitPolicy::FirstFit, false);
        let before = sol.cost(tr);
        tlrs::algo::local_search::improve(tr, &mut sol, 8);
        assert!(sol.verify(tr).is_ok(), "{label} local-search");
        assert!(
            sol.verify_with::<DenseProfile>(tr).is_ok(),
            "{label} local-search: dense verify"
        );
        assert!(sol.cost(tr) <= before + 1e-9, "{label} local-search cost");
    }

    for seed in 0..5u64 {
        let inst = generate(&SynthParams { n: 160, m: 6, ..Default::default() }, seed + 70);
        let tr = trim(&inst).instance;
        check_all_solvers(&tr, &format!("synth seed {seed}"));
    }
    let trace = tlrs::io::gct_like::generate_trace(1200, 5);
    for seed in 0..2u64 {
        let gct = trace.sample_scenario(300, 9, seed + 1);
        let tr = trim(&gct).instance;
        check_all_solvers(&tr, &format!("gct seed {seed}"));
    }
}

#[test]
fn segregation_matches_combined_feasibility() {
    use tlrs::algo::segregate;
    for seed in 0..30u64 {
        let inst = random_instance(seed + 5000);
        let tr = trim(&inst).instance;
        let sol = segregate::solve_segregated(&tr, |i| {
            let mapping = map_tasks(i, MappingPolicy::HAvg);
            solve_with_mapping(i, &mapping, FitPolicy::FirstFit, false)
        });
        assert!(sol.verify(&tr).is_ok(), "seed {seed}");
    }
}
