//! Property-based invariant tests: randomized instance generators drive
//! hundreds of cases through every algorithm, checking the invariants
//! DESIGN.md section 6 lists. (Hand-rolled driver — the vendored crate
//! universe has no proptest; shrinking is replaced by seed reporting.)

use tlrs::algo::algorithms::{penalty_map_best, Algorithm};
use tlrs::algo::lowerbound::lower_bound;
use tlrs::algo::penalty_map::{map_tasks, min_penalties, MappingPolicy};
use tlrs::algo::placement::FitPolicy;
use tlrs::algo::twophase::solve_with_mapping;
use tlrs::io::synth::{generate, CostKind, SynthParams};
use tlrs::lp::solver::NativePdhgSolver;
use tlrs::lp::{dual, scaling, MappingLp};
use tlrs::model::{trim, Instance};
use tlrs::util::rng::Rng;

/// Random instance parameters spanning the interesting regimes.
fn random_params(rng: &mut Rng) -> SynthParams {
    let dims = 1 + rng.below(6) as usize;
    SynthParams {
        n: 10 + rng.below(120) as usize,
        m: 1 + rng.below(7) as usize,
        dims,
        horizon: 2 + rng.below(30) as u32,
        cap_range: (0.2, 1.0),
        dem_range: match rng.below(3) {
            0 => (0.01, 0.05),
            1 => (0.01, 0.2),
            _ => (0.05, 0.5),
        },
        cost_model: match rng.below(3) {
            0 => CostKind::HomogeneousLinear,
            1 => CostKind::HeterogeneousRandom { exponent: 0.5 },
            _ => CostKind::HeterogeneousRandom { exponent: 2.0 },
        },
    }
}

fn random_instance(seed: u64) -> Instance {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9));
    let params = random_params(&mut rng);
    generate(&params, seed)
}

const CASES: u64 = 60;

#[test]
fn trimming_preserves_cost_and_feasibility() {
    for seed in 0..CASES {
        let inst = random_instance(seed);
        let tr = trim(&inst);
        // spans map back within the original horizon
        assert!(tr.instance.horizon as usize <= inst.n_tasks().max(1), "seed {seed}");
        // solving trimmed and verifying is consistent; costs agree with the
        // untrimmed instance solved with the same mapping
        let mapping = map_tasks(&tr.instance, MappingPolicy::HAvg);
        let sol_t = solve_with_mapping(&tr.instance, &mapping, FitPolicy::FirstFit, false);
        assert!(sol_t.verify(&tr.instance).is_ok(), "seed {seed}");
        let mapping_o = map_tasks(&inst, MappingPolicy::HAvg);
        assert_eq!(mapping, mapping_o, "seed {seed}: mapping is timeline-free");
        let sol_o = solve_with_mapping(&inst, &mapping_o, FitPolicy::FirstFit, false);
        assert!(sol_o.verify(&inst).is_ok(), "seed {seed}");
        assert!(
            (sol_t.cost(&tr.instance) - sol_o.cost(&inst)).abs() < 1e-9,
            "seed {seed}: trimmed {} vs original {}",
            sol_t.cost(&tr.instance),
            sol_o.cost(&inst)
        );
    }
}

#[test]
fn every_algorithm_is_feasible_and_above_congestion_bound() {
    for seed in 0..CASES {
        let inst = random_instance(seed + 1000);
        let tr = trim(&inst).instance;
        let mut lp = MappingLp::from_instance(&tr);
        scaling::equilibrate(&mut lp);
        let cong = dual::congestion_bound(&lp);
        for algo in [Algorithm::PenaltyMap, Algorithm::PenaltyMapF] {
            let sol = penalty_map_best(&tr, algo == Algorithm::PenaltyMapF);
            assert!(sol.verify(&tr).is_ok(), "seed {seed} {algo:?}");
            assert!(
                sol.cost(&tr) >= cong - 1e-9,
                "seed {seed} {algo:?}: cost {} below congestion bound {cong}",
                sol.cost(&tr)
            );
        }
    }
}

#[test]
fn mapping_respects_admissibility_and_penalties() {
    for seed in 0..CASES {
        let inst = random_instance(seed + 2000);
        for policy in [MappingPolicy::HAvg, MappingPolicy::HMax] {
            let mapping = map_tasks(&inst, policy);
            let pstar = min_penalties(&inst, policy);
            for (u, &b) in mapping.iter().enumerate() {
                assert!(
                    inst.node_types[b].admits(&inst.tasks[u].demand),
                    "seed {seed}: task {u} mapped to inadmissible type {b}"
                );
                assert!(pstar[u].is_finite(), "seed {seed}: task {u}");
            }
        }
    }
}

#[test]
fn lp_lower_bound_below_all_algorithms() {
    // heavier: fewer cases
    for seed in 0..15u64 {
        let inst = random_instance(seed + 3000);
        let tr = trim(&inst).instance;
        let solver = NativePdhgSolver::default();
        let lb = lower_bound(&tr, &solver).unwrap();
        for fill in [false, true] {
            let sol = penalty_map_best(&tr, fill);
            assert!(
                lb.best() <= sol.cost(&tr) + 1e-6,
                "seed {seed}: lb {} vs penalty cost {}",
                lb.best(),
                sol.cost(&tr)
            );
        }
        // congestion bound <= LP optimum holds exactly; lp_objective is the
        // *approximate* primal value, so allow first-order slack
        assert!(
            lb.congestion_bound <= lb.lp_objective * 1.005 + 1e-6,
            "seed {seed}: congestion {} vs approx LP {}",
            lb.congestion_bound,
            lb.lp_objective
        );
    }
}

#[test]
fn solution_accounting_is_exact() {
    for seed in 0..CASES {
        let inst = random_instance(seed + 4000);
        let tr = trim(&inst).instance;
        let mapping = map_tasks(&tr, MappingPolicy::HAvg);
        let sol = solve_with_mapping(&tr, &mapping, FitPolicy::SimilarityFit, true);
        // cost equals sum over nodes_per_type
        let per_type = sol.nodes_per_type(&tr);
        let recomputed: f64 = per_type
            .iter()
            .enumerate()
            .map(|(b, &c)| c as f64 * tr.node_types[b].cost)
            .sum();
        assert!((recomputed - sol.cost(&tr)).abs() < 1e-9, "seed {seed}");
        // every task appears in exactly one node task list
        let mut seen = vec![false; tr.n_tasks()];
        for node in &sol.nodes {
            for &u in &node.tasks {
                assert!(!seen[u], "seed {seed}: task {u} twice");
                seen[u] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "seed {seed}: unplaced task");
        // replay agrees with verify
        let rep = tlrs::sim::replay::replay(&tr, &sol);
        assert_eq!(rep.overloads, 0, "seed {seed}");
    }
}

#[test]
fn pdhg_certified_bound_valid_even_unconverged() {
    // failure injection: starve the solver of iterations; the certified
    // dual bound must remain a valid lower bound regardless.
    use tlrs::lp::pdhg::{self, PdhgOptions};
    use tlrs::lp::simplex;
    for seed in 0..10u64 {
        let inst = generate(
            &SynthParams {
                n: 12,
                m: 3,
                dims: 2,
                horizon: 6,
                dem_range: (0.05, 0.3),
                ..Default::default()
            },
            seed,
        );
        let mut lp = MappingLp::from_instance(&trim(&inst).instance);
        scaling::equilibrate(&mut lp);
        let exact = simplex::solve(&lp.to_dense());
        let starved = pdhg::solve(
            &lp,
            &PdhgOptions { max_iters: 50, chunk: 25, ..Default::default() },
        );
        assert!(!starved.converged);
        let (lb, _) = dual::certified_bound(&lp, &starved.y);
        assert!(
            lb <= exact.objective + 1e-7 * (1.0 + exact.objective),
            "seed {seed}: starved lb {lb} exceeds optimum {}",
            exact.objective
        );
    }
}

#[test]
fn segregation_matches_combined_feasibility() {
    use tlrs::algo::segregate;
    for seed in 0..30u64 {
        let inst = random_instance(seed + 5000);
        let tr = trim(&inst).instance;
        let sol = segregate::solve_segregated(&tr, |i| {
            let mapping = map_tasks(i, MappingPolicy::HAvg);
            solve_with_mapping(i, &mapping, FitPolicy::FirstFit, false)
        });
        assert!(sol.verify(&tr).is_ok(), "seed {seed}");
    }
}
