//! Property tests for piecewise demand profiles (the DemandProfile
//! tentpole): the flat embedding is bit-identical to the pre-profile
//! model, shaped instances solve end-to-end with certified bounds, and
//! per-slot verification sees exactly what the profiles say.

use tlrs::algo::pipeline::{self, Portfolio};
use tlrs::io::synth::{generate, SynthParams};
use tlrs::io::workload;
use tlrs::lp::solver::NativePdhgSolver;
use tlrs::model::{trim, DemandSeg, DenseProfile, Instance, Solution, Task};

fn assert_identical(a: &Solution, b: &Solution, label: &str) {
    assert_eq!(a.nodes.len(), b.nodes.len(), "{label}: node count");
    for (i, (x, y)) in a.nodes.iter().zip(&b.nodes).enumerate() {
        assert_eq!(x.type_idx, y.type_idx, "{label}: node {i} type");
        assert_eq!(x.purchase_order, y.purchase_order, "{label}: node {i} order");
        assert_eq!(x.tasks, y.tasks, "{label}: node {i} tasks");
    }
    assert_eq!(a.assignment, b.assignment, "{label}: assignment");
}

/// Rebuild every task as an explicit single-segment piecewise profile.
fn single_segment_embedding(inst: &Instance) -> Instance {
    let tasks = inst
        .tasks
        .iter()
        .map(|u| {
            Task::try_piecewise(
                u.id,
                vec![DemandSeg {
                    start: u.start,
                    end: u.end,
                    demand: u.peak().to_vec(),
                }],
            )
            .expect("valid single segment")
        })
        .collect();
    Instance::new(tasks, inst.node_types.clone(), inst.horizon)
}

/// Split every flat task into two equal-demand segments (mathematically
/// the same workload, exercising the multi-segment code paths).
fn equal_demand_split(inst: &Instance) -> Instance {
    let tasks = inst
        .tasks
        .iter()
        .map(|u| {
            if u.span_len() < 2 {
                return u.clone();
            }
            let mid = u.start + u.span_len() / 2;
            Task::piecewise(
                u.id,
                vec![
                    DemandSeg { start: u.start, end: mid - 1, demand: u.peak().to_vec() },
                    DemandSeg { start: mid, end: u.end, demand: u.peak().to_vec() },
                ],
            )
        })
        .collect();
    Instance::new(tasks, inst.node_types.clone(), inst.horizon)
}

#[test]
fn single_segment_embedding_is_bit_identical_across_presets() {
    let solver = NativePdhgSolver::default();
    // figure seeds 1..=5 on a shrunken Table-I configuration
    for seed in 1..=5u64 {
        let flat = generate(&SynthParams { n: 90, m: 5, ..Default::default() }, seed);
        let embedded = single_segment_embedding(&flat);
        // the embedding *is* the flat representation (canonical form)
        assert_eq!(flat.tasks, embedded.tasks, "seed {seed}");
        let (tf, te) = (trim(&flat).instance, trim(&embedded).instance);
        assert_eq!(tf.tasks, te.tasks, "seed {seed}: trim");
        for spec in ["penalty-map", "penalty-map-f", "lp-map", "lp-map-f", "lp+fill+ls"] {
            let a = pipeline::parse(spec).unwrap().run(&tf, &solver).unwrap();
            let b = pipeline::parse(spec).unwrap().run(&te, &solver).unwrap();
            assert!((a.cost - b.cost).abs() < 1e-12, "seed {seed} {spec}");
            assert_identical(&a.solution, &b.solution, &format!("seed {seed} {spec}"));
        }
    }
}

#[test]
fn equal_demand_split_solves_identically_under_first_fit() {
    use tlrs::algo::penalty_map::{map_tasks, MappingPolicy};
    use tlrs::algo::placement::FitPolicy;
    use tlrs::algo::twophase::solve_with_mapping;
    for seed in 1..=4u64 {
        let flat = generate(&SynthParams { n: 80, m: 4, ..Default::default() }, seed + 30);
        let split = equal_demand_split(&flat);
        let (tf, ts) = (trim(&flat).instance, trim(&split).instance);
        // peak and average demand are unchanged, so both penalty mappings
        // agree exactly
        for policy in [MappingPolicy::HAvg, MappingPolicy::HMax] {
            assert_eq!(
                map_tasks(&tf, policy),
                map_tasks(&ts, policy),
                "seed {seed} {policy:?}"
            );
        }
        let mapping = map_tasks(&tf, MappingPolicy::HAvg);
        let a = solve_with_mapping(&tf, &mapping, FitPolicy::FirstFit, false);
        let b = solve_with_mapping(&ts, &mapping, FitPolicy::FirstFit, false);
        assert!((a.cost(&tf) - b.cost(&ts)).abs() < 1e-12, "seed {seed}");
        assert_eq!(a.assignment, b.assignment, "seed {seed}");
        assert!(b.verify(&ts).is_ok(), "seed {seed}");
        assert!(b.verify_with::<DenseProfile>(&ts).is_ok(), "seed {seed}");
    }
}

#[test]
fn shaped_instances_solve_with_certified_bounds() {
    let solver = NativePdhgSolver::default();
    for spec in [
        "mixed:services=30,m=4,shape=diurnal",
        "synth:n=70,m=4,shape=ramp",
        "gct:n=90,m=5,pool=400,shape=spike",
    ] {
        let inst = workload::parse_workload(spec).unwrap().generate(2).unwrap();
        assert!(
            inst.tasks.iter().any(|t| !t.is_flat()),
            "{spec}: nothing shaped"
        );
        let tr = trim(&inst).instance;
        let race = Portfolio::presets()
            .add(pipeline::parse("lp+fill+ls").unwrap())
            .run(&tr, &solver)
            .unwrap();
        let lb = race.certified_lb().expect("LP members certify a bound");
        assert!(lb > 0.0, "{spec}");
        for rep in &race.reports {
            assert!(rep.solution.verify(&tr).is_ok(), "{spec} {}", rep.label);
            // independent dense verifier agrees slot-for-slot
            assert!(
                rep.solution.verify_with::<DenseProfile>(&tr).is_ok(),
                "{spec} {}",
                rep.label
            );
            assert!(
                lb <= rep.cost + 1e-6,
                "{spec} {}: lower bound {lb} above cost {}",
                rep.label,
                rep.cost
            );
        }
    }
}

#[test]
fn complementary_shapes_pack_tighter_than_their_peaks() {
    use tlrs::algo::placement::FitPolicy;
    use tlrs::algo::twophase::solve_with_mapping;
    use tlrs::model::NodeType;
    // n tasks alternate between "high early" and "high late" profiles.
    // Shaped: each pair shares one node (per-slot load 1.0). Peak-flat:
    // every task needs most of a node, so the flat relaxation of the same
    // workload buys ~2x the cluster — the capability the tentpole adds.
    let mk = |id: u64, hi_first: bool| {
        let (a, b) = if hi_first { (0.8, 0.2) } else { (0.2, 0.8) };
        Task::piecewise(
            id,
            vec![
                DemandSeg { start: 0, end: 3, demand: vec![a] },
                DemandSeg { start: 4, end: 7, demand: vec![b] },
            ],
        )
    };
    let n = 12u64;
    let shaped = Instance::new(
        (0..n).map(|i| mk(i, i % 2 == 0)).collect(),
        vec![NodeType::new("a", vec![1.0], 1.0)],
        8,
    );
    let peaks = shaped.collapse_timeline(); // every task at its 0.8 peak
    let mapping = vec![0usize; n as usize];
    let tr = trim(&shaped).instance;
    let shaped_sol = solve_with_mapping(&tr, &mapping, FitPolicy::FirstFit, false);
    assert!(shaped_sol.verify(&tr).is_ok());
    let flat_sol = solve_with_mapping(&peaks, &mapping, FitPolicy::FirstFit, false);
    assert!(flat_sol.verify(&peaks).is_ok());
    assert_eq!(shaped_sol.nodes.len(), (n / 2) as usize, "pairs share nodes");
    assert_eq!(flat_sol.nodes.len(), n as usize, "peaks cannot share");
    assert!(shaped_sol.cost(&tr) * 1.9 <= flat_sol.cost(&peaks));
}
