//! Session properties: delta replay vs cold solves, per-delta
//! feasibility on an independent verifier backend, and warm-start
//! consistency.

use tlrs::coordinator::session::{Decision, PlanSession, SessionConfig};
use tlrs::io::synth::{generate, SynthParams};
use tlrs::model::{Delta, DemandSeg, DenseProfile, Instance, Task};
use tlrs::util::rng::Rng;

fn base_instance(seed: u64, n: usize) -> Instance {
    generate(&SynthParams { n, m: 4, dims: 3, ..Default::default() }, seed)
}

/// A deterministic mixed delta stream: admits (flat and shaped), retires
/// of random live ids, reshapes of random live ids.
fn delta_stream(inst: &Instance, seed: u64, len: usize) -> Vec<Delta> {
    let mut rng = Rng::new(seed);
    let dims = inst.dims();
    let horizon = inst.horizon;
    let mut live: Vec<u64> = inst.tasks.iter().map(|t| t.id).collect();
    let mut next_id = live.iter().copied().max().unwrap_or(0) + 1;
    let mut out = Vec::with_capacity(len);
    for k in 0..len {
        let roll = rng.below(10);
        if roll < 5 || live.len() < 8 {
            // admit 1-2 fresh tasks; every third admit is piecewise
            let count = 1 + (rng.below(2) as usize);
            let mut tasks = Vec::new();
            for _ in 0..count {
                let a = rng.below(horizon as u64) as u32;
                let b = rng.below(horizon as u64) as u32;
                let (start, end) = (a.min(b), a.max(b));
                let demand: Vec<f64> =
                    (0..dims).map(|_| rng.uniform(0.01, 0.12)).collect();
                let task = if k % 3 == 0 && end > start {
                    let mid = start + (end - start) / 2;
                    let low: Vec<f64> = demand.iter().map(|d| d * 0.4).collect();
                    Task::piecewise(
                        next_id,
                        vec![
                            DemandSeg { start, end: mid, demand: low },
                            DemandSeg { start: mid + 1, end, demand },
                        ],
                    )
                } else {
                    Task::new(next_id, demand, start, end)
                };
                live.push(next_id);
                next_id += 1;
                tasks.push(task);
            }
            out.push(Delta::Admit { tasks });
        } else if roll < 8 {
            let i = rng.below(live.len() as u64) as usize;
            let id = live.swap_remove(i);
            out.push(Delta::Retire { ids: vec![id] });
        } else {
            let i = rng.below(live.len() as u64) as usize;
            let id = live[i];
            let a = rng.below(horizon as u64) as u32;
            let b = rng.below(horizon as u64) as u32;
            let demand: Vec<f64> = (0..dims).map(|_| rng.uniform(0.01, 0.15)).collect();
            out.push(Delta::Reshape {
                task: Task::new(id, demand, a.min(b), a.max(b)),
            });
        }
    }
    out
}

#[test]
fn forced_cold_resolve_is_bit_identical_to_a_cold_solve_of_the_final_instance() {
    // warm-starting off + a final capacity reprice (which forces a full
    // re-solve) => the session's last answer runs exactly the cold solve
    // path on the final instance; opening a fresh session on that
    // instance must reproduce it bit for bit.
    let inst = base_instance(31, 50);
    let cfg = SessionConfig { warm: false, escalate_ratio: None, ..Default::default() };
    let (mut s, _) = PlanSession::open(inst.clone(), cfg.clone()).unwrap();
    for d in delta_stream(&inst, 77, 24) {
        s.apply(&d).unwrap();
    }
    // final delta: nudge every capacity (catalog shape change => forced
    // full re-solve, cold because warm=false)
    let mut cat = s.instance().node_types.clone();
    for b in cat.iter_mut() {
        for c in b.capacity.iter_mut() {
            *c = (*c * 0.97).max(1e-3);
        }
    }
    let rep = s.apply(&Delta::Reprice { node_types: cat }).unwrap();
    assert_eq!(rep.decision, Decision::Resolve, "{rep:?}");

    let final_inst = s.instance().clone();
    let (cold, cold_open) = PlanSession::open(final_inst.clone(), cfg).unwrap();
    assert_eq!(s.cost().to_bits(), cold_open.cost.to_bits(), "cost must match bit for bit");
    let a = s.solution();
    let b = cold.solution();
    assert_eq!(a.assignment, b.assignment);
    assert_eq!(a.nodes.len(), b.nodes.len());
    for (x, y) in a.nodes.iter().zip(&b.nodes) {
        assert_eq!(x.type_idx, y.type_idx);
        assert_eq!(x.tasks, y.tasks);
    }
}

#[test]
fn every_intermediate_incremental_solution_is_verify_clean() {
    // pure incremental mode (no escalation): after every delta the
    // session state passes the independent dense-profile verifier and
    // never dips below the refreshed certified LB
    for seed in [1u64, 2, 3] {
        let inst = base_instance(seed, 40);
        let cfg = SessionConfig { escalate_ratio: None, ..Default::default() };
        let (mut s, open) = PlanSession::open(inst.clone(), cfg).unwrap();
        assert!(open.lower_bound <= open.cost + 1e-6);
        for (i, d) in delta_stream(&inst, seed * 13 + 5, 40).iter().enumerate() {
            let rep = s.apply(d).unwrap();
            assert_eq!(rep.decision, Decision::Repair, "escalation is off");
            let sol = s.solution();
            assert!(
                sol.verify_with::<DenseProfile>(s.instance()).is_ok(),
                "seed {seed} delta {i} ({}) fails dense verify",
                d.op()
            );
            assert!(
                rep.cost >= rep.lower_bound - 1e-6,
                "seed {seed} delta {i}: cost {} below certified LB {}",
                rep.cost,
                rep.lower_bound
            );
        }
        let (n, repairs, resolves) = s.delta_counts();
        assert_eq!(n, 40);
        assert_eq!(repairs, 40);
        assert_eq!(resolves, 0);
    }
}

#[test]
fn warm_started_escalation_stays_near_the_cold_answer() {
    // aggressive escalation with warm starts: the session must stay
    // verify-clean and land within a modest factor of a cold solve of
    // the final instance (warm-started PDHG may round to a slightly
    // different mapping — near-optimality, not bit-identity)
    let inst = base_instance(9, 45);
    let cfg = SessionConfig { escalate_ratio: Some(1.0), warm: true, ..Default::default() };
    let (mut s, _) = PlanSession::open(inst.clone(), cfg.clone()).unwrap();
    let mut resolves = 0usize;
    for d in delta_stream(&inst, 41, 20) {
        let rep = s.apply(&d).unwrap();
        if rep.decision == Decision::Resolve {
            resolves += 1;
        }
        assert!(rep.cost >= rep.lower_bound - 1e-6);
    }
    assert!(resolves > 0, "ratio 1.0 should escalate at least once in 20 deltas");
    let (cold, cold_open) = PlanSession::open(s.instance().clone(), cfg).unwrap();
    let _ = cold;
    assert!(
        s.cost() <= cold_open.cost * 1.25 + 1e-9,
        "warm final {} vs cold {}",
        s.cost(),
        cold_open.cost
    );
    assert!(s.solution().verify_with::<DenseProfile>(s.instance()).is_ok());
}

#[test]
fn replayed_delta_stream_matches_an_equivalent_cold_instance_when_escalated() {
    // escalation ratio 1.0 with warm=false: every delta that escalates
    // re-solves cold, so after a delta whose decision was Resolve the
    // session equals a cold open of its current instance
    let inst = base_instance(17, 35);
    let cfg = SessionConfig { warm: false, escalate_ratio: Some(1.0), ..Default::default() };
    let (mut s, _) = PlanSession::open(inst.clone(), cfg.clone()).unwrap();
    let mut checked = 0usize;
    for d in delta_stream(&inst, 23, 16) {
        let rep = s.apply(&d).unwrap();
        if rep.decision == Decision::Resolve && checked < 3 {
            let (cold, cold_open) = PlanSession::open(s.instance().clone(), cfg.clone()).unwrap();
            assert_eq!(s.cost().to_bits(), cold_open.cost.to_bits());
            assert_eq!(s.solution().assignment, cold.solution().assignment);
            checked += 1;
        }
    }
    assert!(checked > 0, "no escalation fired — widen the stream");
}
