//! Integration: the TCP planning service end-to-end over a real socket.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use tlrs::coordinator::config::Backend;
use tlrs::coordinator::planner::Planner;
use tlrs::coordinator::service;
use tlrs::io::files;
use tlrs::io::synth::{generate, SynthParams};
use tlrs::util::json::{self, Json};

/// Spin up a single-connection server on an ephemeral port.
fn serve_once() -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        let planner = Planner::new(Backend::Native).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let _ = service::serve_connection(&planner, stream);
    });
    (addr, handle)
}

#[test]
fn tcp_roundtrip_pipelined() {
    let (addr, handle) = serve_once();
    let mut stream = TcpStream::connect(addr).unwrap();

    let inst = generate(&SynthParams { n: 30, m: 3, ..Default::default() }, 8);
    let mk = |algo: &str| {
        Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str(algo.into())),
        ])
        .to_string()
            + "\n"
    };
    // pipeline three requests on one connection
    stream.write_all(mk("penalty-map").as_bytes()).unwrap();
    stream.write_all(mk("lp-map-f").as_bytes()).unwrap();
    stream.write_all(b"this is not json\n").unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();

    let reader = BufReader::new(stream);
    let responses: Vec<Json> = reader
        .lines()
        .map(|l| json::parse(&l.unwrap()).unwrap())
        .collect();
    assert_eq!(responses.len(), 3);
    assert_eq!(responses[0].get("ok").as_bool(), Some(true));
    assert_eq!(responses[0].get("algorithm").as_str(), Some("penalty-map"));
    assert_eq!(responses[1].get("ok").as_bool(), Some(true));
    let cost_pen = responses[0].get("cost").as_f64().unwrap();
    let cost_lpf = responses[1].get("cost").as_f64().unwrap();
    assert!(cost_lpf <= cost_pen + 1e-9, "lp-map-f {cost_lpf} vs penalty {cost_pen}");
    assert!(responses[1].get("normalized_cost").as_f64().unwrap() >= 1.0 - 1e-6);
    assert_eq!(responses[2].get("ok").as_bool(), Some(false));

    handle.join().unwrap();
}

#[test]
fn shaped_workload_served_with_valid_bound() {
    // acceptance: a shaped spec solves end-to-end through the service
    // with verify-clean output (the service verifies before answering)
    // and lower_bound <= cost
    let (addr, handle) = serve_once();
    let mut stream = TcpStream::connect(addr).unwrap();
    let req = Json::obj(vec![
        ("workload", Json::Str("mixed:services=15,m=3,shape=diurnal".into())),
        ("seed", Json::Num(4.0)),
        ("algorithm", Json::Str("lp-map-f".into())),
    ])
    .to_string()
        + "\n";
    stream.write_all(req.as_bytes()).unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    let v = json::parse(&line).unwrap();
    assert_eq!(v.get("ok").as_bool(), Some(true), "{line}");
    assert_eq!(
        v.get("workload").as_str(),
        Some("mixed:m=3,services=15,shape=diurnal")
    );
    let cost = v.get("cost").as_f64().unwrap();
    let lb = v.get("lower_bound").as_f64().unwrap();
    assert!(lb > 0.0 && lb <= cost + 1e-6, "{line}");
    assert!(v.get("normalized_cost").as_f64().unwrap() >= 1.0 - 1e-6);
    handle.join().unwrap();
}

#[test]
fn shaped_inline_instance_roundtrips_segments() {
    use tlrs::model::{DemandSeg, Instance, NodeType, Task};
    let (addr, handle) = serve_once();
    let mut stream = TcpStream::connect(addr).unwrap();
    // two complementary shaped tasks fit one node — something a
    // peak-demand model would price at two
    let mk = |id: u64, hi_first: bool| {
        let (a, b) = if hi_first { (0.8, 0.2) } else { (0.2, 0.8) };
        Task::piecewise(
            id,
            vec![
                DemandSeg { start: 0, end: 1, demand: vec![a] },
                DemandSeg { start: 2, end: 3, demand: vec![b] },
            ],
        )
    };
    let inst = Instance::new(
        vec![mk(0, true), mk(1, false)],
        vec![NodeType::new("a", vec![1.0], 1.0)],
        4,
    );
    let req = Json::obj(vec![
        ("instance", files::instance_to_json(&inst)),
        ("algorithm", Json::Str("penalty-map".into())),
    ])
    .to_string()
        + "\n";
    stream.write_all(req.as_bytes()).unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    let v = json::parse(&line).unwrap();
    assert_eq!(v.get("ok").as_bool(), Some(true), "{line}");
    assert_eq!(v.get("n_nodes").as_f64(), Some(1.0), "{line}");
    handle.join().unwrap();
}

#[test]
fn two_concurrent_sessions_over_one_connection_pool() {
    // one server, one shared planner (= one session registry), two
    // clients on separate connections each driving their own session
    // concurrently: sessions must stay isolated (task counts, costs) and
    // survive across the pooled connections
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let planner = Arc::new(Planner::new(Backend::Native).unwrap());
    let server = {
        let planner = planner.clone();
        std::thread::spawn(move || {
            for _ in 0..2 {
                let (stream, _) = listener.accept().unwrap();
                let planner = planner.clone();
                // pooled connections: each served on its own thread so
                // the two sessions genuinely interleave
                std::thread::spawn(move || {
                    let _ = service::serve_connection(&planner, stream);
                });
            }
        })
    };

    fn drive(addr: std::net::SocketAddr, n_tasks: usize, seed: u64, fresh_id: u64) -> usize {
        let mut stream = TcpStream::connect(addr).unwrap();
        let send = |stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: String| {
            stream.write_all(line.as_bytes()).unwrap();
            stream.write_all(b"\n").unwrap();
            stream.flush().unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            json::parse(&resp).unwrap()
        };
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let open = format!(
            r#"{{"op":"open","workload":"synth:n={n_tasks},m=3,dims=2","seed":{seed}}}"#
        );
        let v = send(&mut stream, &mut reader, open);
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        let sid = v.get("session").as_usize().unwrap();
        assert_eq!(v.get("n_tasks").as_usize(), Some(n_tasks));

        // admit a fresh task, then retire it again
        let admit = format!(
            r#"{{"op":"delta","session":{sid},"deltas":{{"op":"admit","tasks":[{{"id":{fresh_id},"demand":[0.05,0.05],"start":0,"end":2}}]}}}}"#
        );
        let v = send(&mut stream, &mut reader, admit);
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("n_tasks").as_usize(), Some(n_tasks + 1));

        let retire = format!(
            r#"{{"op":"delta","session":{sid},"deltas":{{"op":"retire","ids":[{fresh_id}]}}}}"#
        );
        let v = send(&mut stream, &mut reader, retire);
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("n_tasks").as_usize(), Some(n_tasks));

        let v = send(&mut stream, &mut reader, format!(r#"{{"op":"close","session":{sid}}}"#));
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("deltas").as_usize(), Some(2));
        stream.shutdown(std::net::Shutdown::Both).ok();
        sid
    }

    let a = std::thread::spawn(move || drive(addr, 20, 3, 700));
    let b = std::thread::spawn(move || drive(addr, 26, 4, 800));
    let sid_a = a.join().unwrap();
    let sid_b = b.join().unwrap();
    assert_ne!(sid_a, sid_b, "sessions must get distinct ids");
    assert_eq!(planner.sessions.count(), 0, "both sessions closed");
    server.join().unwrap();
}

#[test]
fn concurrent_clients_are_serialized_but_served() {
    // serve_connection is the single-connection primitive underneath the
    // runtime; a manual sequential accept loop over it must still answer
    // a client that queued behind another (kernel accept backlog).
    // Concurrent serving, shedding and shutdown are covered by
    // tests/stress_service.rs on the real runtime.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let planner = Planner::new(Backend::Native).unwrap();
        for _ in 0..2 {
            let (stream, _) = listener.accept().unwrap();
            let _ = service::serve_connection(&planner, stream);
        }
    });

    let inst = generate(&SynthParams { n: 20, m: 2, ..Default::default() }, 9);
    let req = Json::obj(vec![
        ("instance", files::instance_to_json(&inst)),
        ("algorithm", Json::Str("penalty-map-f".into())),
    ])
    .to_string()
        + "\n";

    let clients: Vec<_> = (0..2)
        .map(|_| {
            let req = req.clone();
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                stream.write_all(req.as_bytes()).unwrap();
                stream.shutdown(std::net::Shutdown::Write).unwrap();
                let mut line = String::new();
                BufReader::new(stream).read_line(&mut line).unwrap();
                json::parse(&line).unwrap()
            })
        })
        .collect();
    for c in clients {
        let resp = c.join().unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(true));
    }
    server.join().unwrap();
}
