//! Property tests over the unified workload subsystem: every registered
//! family, across seeds and dimension overrides, must be deterministic in
//! its seed, feasible, within-horizon and spec-round-trippable — and the
//! `synth`/`gct` families must reproduce the pre-refactor generators
//! byte-for-byte on the figure seeds (the figure scenarios regenerate
//! bit-identical instances through the new registry).

use tlrs::io::gct_like;
use tlrs::io::synth::{self, CostKind, SynthParams};
use tlrs::io::workload::{self, WorkloadSpec};
use tlrs::model::CostModel;

const SEEDS: [u64; 3] = [1, 2, 42];

/// Small test specs per family: the bare name, the registry's smoke spec,
/// and (where the family takes `dims`) a higher-dimensional override.
fn test_specs() -> Vec<String> {
    let mut specs = Vec::new();
    for fam in workload::families() {
        // bare names are valid for every family except csv (path required)
        if fam.name != "csv" {
            specs.push(fam.name.to_string());
        }
        specs.push(fam.smoke_spec.to_string());
        // smoke specs always carry parameters, so extend with ','
        assert!(fam.smoke_spec.contains(':'), "{}", fam.name);
        if fam.keys.iter().any(|(k, _)| *k == "dims") {
            specs.push(format!("{},dims=4", fam.smoke_spec));
        }
        if fam.keys.iter().any(|(k, _)| *k == "cost") {
            specs.push(format!("{},cost=het,e=2", fam.smoke_spec));
            specs.push(format!("{},cost=gcp", fam.smoke_spec));
        }
    }
    specs
}

#[test]
fn every_family_is_deterministic_feasible_and_in_horizon() {
    workload::csv_smoke_fixture();
    for spec_str in test_specs() {
        let source = workload::parse_workload(&spec_str)
            .unwrap_or_else(|e| panic!("'{spec_str}': {e:#}"));
        for &seed in &SEEDS {
            let a = source.generate(seed).unwrap_or_else(|e| panic!("'{spec_str}': {e:#}"));
            let b = source.generate(seed).unwrap();
            // deterministic in seed
            assert_eq!(a.tasks, b.tasks, "'{spec_str}' seed {seed}");
            assert_eq!(a.node_types, b.node_types, "'{spec_str}' seed {seed}");
            assert_eq!(a.horizon, b.horizon, "'{spec_str}' seed {seed}");
            // structurally valid
            assert!(a.n_tasks() > 0, "'{spec_str}' seed {seed}: no tasks");
            assert!(a.is_feasible(), "'{spec_str}' seed {seed}: infeasible");
            let dims = a.dims();
            for t in &a.tasks {
                assert!(t.end < a.horizon, "'{spec_str}' seed {seed}: task beyond horizon");
                assert_eq!(t.dims(), dims, "'{spec_str}' seed {seed}");
                assert!(
                    t.peak().iter().all(|&d| d > 0.0 && d <= 1.0),
                    "'{spec_str}' seed {seed}: peak demand out of (0, 1]"
                );
                // every segment's demand obeys the same bounds and never
                // exceeds the task's peak
                for seg in t.segments() {
                    for (x, p) in seg.demand.iter().zip(t.peak()) {
                        assert!(
                            *x > 0.0 && x <= p,
                            "'{spec_str}' seed {seed}: segment demand {x} vs peak {p}"
                        );
                    }
                }
            }
            for nt in &a.node_types {
                assert!(nt.cost > 0.0, "'{spec_str}' seed {seed}: free node-type");
            }
        }
        // distinct seeds give distinct instances (families are random; the
        // csv importer's tasks are fixed by the file, but its catalog is
        // still seed-drawn)
        let a = source.generate(SEEDS[0]).unwrap();
        let b = source.generate(SEEDS[1]).unwrap();
        assert!(
            a.tasks != b.tasks || a.node_types != b.node_types,
            "'{spec_str}': seed-independent generator"
        );
    }
}

#[test]
fn specs_round_trip_through_render() {
    workload::csv_smoke_fixture();
    for spec_str in test_specs() {
        let spec = WorkloadSpec::parse(&spec_str).unwrap();
        let rendered = spec.render();
        let back = WorkloadSpec::parse(&rendered).unwrap();
        assert_eq!(spec, back, "'{spec_str}' -> '{rendered}'");
        // rendering is a fixpoint
        assert_eq!(back.render(), rendered, "'{spec_str}'");
        // and the rendered spec names the same generator
        let a = spec.source().unwrap().generate(7).unwrap();
        let b = back.source().unwrap().generate(7).unwrap();
        assert_eq!(a.tasks, b.tasks, "'{spec_str}'");
        assert_eq!(a.node_types, b.node_types, "'{spec_str}'");
    }
}

#[test]
fn synth_specs_reproduce_pre_refactor_generator() {
    // the figure configurations: dims, m and demand sweeps plus the
    // heterogeneous cost exponents (fig7a/b/c, fig9, fig5/tab1 defaults)
    let het = |e: f64| SynthParams {
        cost_model: CostKind::HeterogeneousRandom { exponent: e },
        ..Default::default()
    };
    let cases: Vec<(String, SynthParams)> = vec![
        ("synth".into(), SynthParams::default()),
        ("synth:dims=2".into(), SynthParams { dims: 2, ..Default::default() }),
        ("synth:dims=7".into(), SynthParams { dims: 7, ..Default::default() }),
        ("synth:m=5".into(), SynthParams { m: 5, ..Default::default() }),
        ("synth:m=15".into(), SynthParams { m: 15, ..Default::default() }),
        (
            "synth:dem=0.01..0.05".into(),
            SynthParams { dem_range: (0.01, 0.05), ..Default::default() },
        ),
        (
            "synth:dem=0.01..0.2".into(),
            SynthParams { dem_range: (0.01, 0.2), ..Default::default() },
        ),
        ("synth:n=500".into(), SynthParams { n: 500, ..Default::default() }),
        ("synth:cost=het,e=0.33".into(), het(0.33)),
        ("synth:cost=het,e=3".into(), het(3.0)),
    ];
    for (spec, params) in cases {
        let source = workload::parse_workload(&spec).unwrap();
        for seed in 1..=5u64 {
            // the pre-refactor path: synth::generate on explicit params
            let want = synth::generate(&params, seed);
            let got = source.generate(seed).unwrap();
            assert_eq!(got.tasks, want.tasks, "'{spec}' seed {seed}");
            assert_eq!(got.node_types, want.node_types, "'{spec}' seed {seed}");
            assert_eq!(got.horizon, want.horizon, "'{spec}' seed {seed}");
        }
    }
}

#[test]
fn gct_specs_reproduce_pre_refactor_sampling() {
    // the pre-refactor path: a fresh 13K master trace (NOT the registry's
    // cached one) sampled exactly as harness::runner::instantiate did
    let trace = gct_like::generate_trace(13_000, 0x6c7_2019);
    let cases: [(usize, usize, bool); 5] =
        [(250, 10, false), (2000, 10, false), (1000, 4, false), (1000, 13, true), (500, 7, true)];
    for (n, m, priced) in cases {
        let spec = format!("gct:n={n},m={m}{}", if priced { ",priced" } else { "" });
        let source = workload::parse_workload(&spec).unwrap();
        for seed in 1..=5u64 {
            let mut want = trace.sample_scenario(n, m, seed);
            if !priced {
                CostModel::homogeneous(want.dims()).apply(&mut want.node_types);
            }
            let got = source.generate(seed).unwrap();
            assert_eq!(got.tasks, want.tasks, "'{spec}' seed {seed}");
            assert_eq!(got.node_types, want.node_types, "'{spec}' seed {seed}");
        }
    }
}

#[test]
fn figure_points_build_and_regenerate_identically() {
    // every generic figure's points materialize through the registry and
    // are reproducible: two instantiations agree byte-for-byte
    use tlrs::harness::{runner, scenarios};
    for id in scenarios::all_ids() {
        let Some(fig) = scenarios::figure(id, true) else { continue };
        for p in &fig.points {
            let a = runner::instantiate(&p.workload, fig.seeds[0]).unwrap();
            let b = runner::instantiate(&p.workload, fig.seeds[0]).unwrap();
            assert_eq!(a.tasks, b.tasks, "{id} {}", p.label);
            assert_eq!(a.node_types, b.node_types, "{id} {}", p.label);
        }
    }
}

#[test]
fn every_registered_family_reaches_a_solver() {
    // end-to-end: each family's smoke instance solves and verifies with
    // the penalty pipeline (no LP needed, keeps the test fast)
    use tlrs::algo::pipeline::{Penalty, Pipeline};
    use tlrs::algo::placement::FitPolicy;
    use tlrs::lp::solver::NativePdhgSolver;
    use tlrs::model::trim;
    workload::csv_smoke_fixture();
    for fam in workload::families() {
        // the flat smoke spec and one shaped variant both reach a solver
        for spec in [fam.smoke_spec.to_string(), format!("{},shape=diurnal", fam.smoke_spec)]
        {
            let inst = workload::parse_workload(&spec).unwrap().generate(3).unwrap();
            let tr = trim(&inst).instance;
            let rep = Pipeline::new()
                .map(Penalty::both())
                .fit(FitPolicy::FirstFit)
                .run(&tr, &NativePdhgSolver::default())
                .unwrap();
            assert!(rep.solution.verify(&tr).is_ok(), "'{spec}'");
            assert!(rep.cost > 0.0, "'{spec}'");
        }
    }
}
