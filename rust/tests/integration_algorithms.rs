//! Integration: the four algorithms against each other, the exact optimum
//! on tiny instances, and the paper's qualitative claims on mid-size
//! instances.

use tlrs::algo::algorithms::{penalty_map_best, run, Algorithm};
use tlrs::algo::exact;
use tlrs::io::gct_like;
use tlrs::io::synth::{generate, SynthParams};
use tlrs::lp::solver::NativePdhgSolver;
use tlrs::model::{trim, CostModel};

#[test]
fn approximation_quality_vs_exact_optimum() {
    // On tiny instances every heuristic stays within a small factor of the
    // true optimum, and LP-map-F is the best or tied-best in aggregate.
    let solver = NativePdhgSolver::default();
    let mut ratios = [0.0f64; 4];
    let mut count = 0;
    for seed in 0..8u64 {
        let inst = generate(
            &SynthParams {
                n: 8,
                m: 3,
                dims: 2,
                horizon: 6,
                dem_range: (0.1, 0.45),
                ..Default::default()
            },
            seed,
        );
        let tr = trim(&inst).instance;
        let opt = exact::optimal(&tr).cost(&tr);
        for (i, algo) in Algorithm::all().into_iter().enumerate() {
            let (sol, _) = run(&tr, algo, &solver).unwrap();
            let ratio = sol.cost(&tr) / opt;
            assert!(ratio >= 1.0 - 1e-9, "seed {seed} {algo:?} beat optimal");
            assert!(ratio <= 2.5 + 1e-9, "seed {seed} {algo:?} ratio {ratio}");
            ratios[i] += ratio;
        }
        count += 1;
    }
    let avg: Vec<f64> = ratios.iter().map(|r| r / count as f64).collect();
    println!("avg ratios vs optimal: {avg:?}");
    assert!(avg[3] <= avg[0] + 0.02, "LP-map-F {} vs PenaltyMap {}", avg[3], avg[0]);
}

#[test]
fn lp_map_beats_penalty_map_when_types_abound() {
    // paper: the PenaltyMap gap grows with m; LP-map stays stable
    let solver = NativePdhgSolver::default();
    let mut pen_costs = Vec::new();
    let mut lp_costs = Vec::new();
    for seed in 0..3u64 {
        let inst = generate(&SynthParams { n: 300, m: 12, ..Default::default() }, seed);
        let tr = trim(&inst).instance;
        let pen = penalty_map_best(&tr, false);
        let (lp, rep) = run(&tr, Algorithm::LpMapF, &solver).unwrap();
        pen_costs.push(pen.cost(&tr));
        lp_costs.push(lp.cost(&tr));
        let rep = rep.unwrap();
        assert!(rep.certified_lb > 0.0);
        // the paper's headline: LP-map-F within ~20-30% of the LB at m~12
        assert!(
            lp.cost(&tr) / rep.certified_lb < 1.40,
            "seed {seed}: normalized {}",
            lp.cost(&tr) / rep.certified_lb
        );
    }
    let pen_avg: f64 = pen_costs.iter().sum::<f64>() / pen_costs.len() as f64;
    let lp_avg: f64 = lp_costs.iter().sum::<f64>() / lp_costs.len() as f64;
    assert!(lp_avg < pen_avg, "LP-map-F {lp_avg} should beat PenaltyMap {pen_avg}");
}

#[test]
fn gct_scenarios_near_optimal() {
    // paper figure 8: on the trace, LP-map lands close to the lower bound
    let solver = NativePdhgSolver::default();
    let trace = gct_like::generate_trace(3000, 7);
    for seed in [1u64, 2] {
        let mut inst = trace.sample_scenario(400, 10, seed);
        CostModel::homogeneous(inst.dims()).apply(&mut inst.node_types);
        let tr = trim(&inst).instance;
        let (sol, rep) = run(&tr, Algorithm::LpMapF, &solver).unwrap();
        let rep = rep.unwrap();
        let norm = sol.cost(&tr) / rep.certified_lb;
        assert!(norm < 1.25, "seed {seed}: normalized {norm} (paper: ~1.1 or less)");
    }
}

#[test]
fn interval_coloring_special_case_consistency() {
    // with m=1, D=1 the general pipeline equals the dedicated baseline
    use tlrs::algo::interval_coloring;
    use tlrs::algo::penalty_map::{map_tasks, MappingPolicy};
    use tlrs::algo::placement::FitPolicy;
    use tlrs::algo::twophase::solve_with_mapping;
    let inst = generate(
        &SynthParams { n: 100, m: 1, dims: 1, horizon: 16, ..Default::default() },
        3,
    );
    let tr = trim(&inst).instance;
    let a = interval_coloring::color(&tr);
    let mapping = map_tasks(&tr, MappingPolicy::HAvg);
    let b = solve_with_mapping(&tr, &mapping, FitPolicy::FirstFit, false);
    assert_eq!(a.nodes.len(), b.nodes.len());
    assert!((a.cost(&tr) - b.cost(&tr)).abs() < 1e-12);
    assert!(a.nodes.len() >= interval_coloring::clique_bound(&tr));
}

#[test]
fn no_timeline_costs_more() {
    // section VI-F: collapsing the timeline roughly doubles cluster cost
    let solver = NativePdhgSolver::default();
    let trace = gct_like::generate_trace(3000, 8);
    let mut inst = trace.sample_scenario(400, 10, 5);
    CostModel::homogeneous(inst.dims()).apply(&mut inst.node_types);

    let tr = trim(&inst).instance;
    let (aware, _) = run(&tr, Algorithm::LpMapF, &solver).unwrap();

    let flat = trim(&inst.collapse_timeline()).instance;
    let (agnostic, _) = run(&flat, Algorithm::LpMapF, &solver).unwrap();

    let factor = agnostic.cost(&flat) / aware.cost(&tr);
    assert!(factor > 1.3, "no-timeline factor only {factor} (paper ~2x)");
}
