//! Property tests for the parallel PDHG engine: thread count is a pure
//! performance knob. Every kernel decomposes over fixed-boundary blocks
//! with fixed-order combines, so solves at 1/2/4/8 threads must agree
//! to the last bit — on flat and shaped instances alike — and the
//! certified dual bound computed from parallel-path iterates stays a
//! valid lower bound on the placed cost.

use tlrs::algo::lpmap::lp_map;
use tlrs::algo::placement::FitPolicy;
use tlrs::io::synth::{generate, SynthParams};
use tlrs::io::workload;
use tlrs::lp::solver::NativePdhgSolver;
use tlrs::lp::{dual, pdhg, scaling, MappingLp, PdhgOptions, PdhgResult};
use tlrs::model::{trim, Instance};

/// Instances big enough to clear the parallel gate (`n * m >=
/// `pdhg::PAR_MIN_NM`) while staying test-sized: a flat synthetic
/// catalog and a ramp-shaped variant of the same scale.
fn gated_instances(seed: u64) -> Vec<(String, Instance)> {
    let flat = generate(
        &SynthParams {
            n: 1500,
            m: 4,
            dims: 2,
            horizon: 12,
            dem_range: (0.02, 0.2),
            ..Default::default()
        },
        seed,
    );
    let shaped = workload::parse_workload("synth:n=1500,m=4,dims=2,horizon=12,shape=ramp")
        .unwrap()
        .generate(seed)
        .unwrap();
    vec![("flat".into(), flat), ("shaped".into(), shaped)]
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str, label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: {what} length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{label}: {what}[{i}] differs ({x} vs {y})"
        );
    }
}

fn assert_result_identical(a: &PdhgResult, b: &PdhgResult, label: &str) {
    assert_bits_eq(&a.x, &b.x, "x", label);
    assert_bits_eq(&a.y, &b.y, "y", label);
    assert_bits_eq(&a.w, &b.w, "w", label);
    assert_bits_eq(&a.alpha, &b.alpha, "alpha", label);
    assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{label}: objective");
    for k in 0..4 {
        assert_eq!(
            a.residuals[k].to_bits(),
            b.residuals[k].to_bits(),
            "{label}: residual {k}"
        );
    }
    assert_eq!(a.iterations, b.iterations, "{label}: iterations");
    assert_eq!(a.converged, b.converged, "{label}: converged");
}

#[test]
fn solves_bit_identical_across_thread_counts() {
    // Fixed iteration budget: bit-identity must hold at every chunk
    // boundary, converged or not, so a short run probes it as strictly
    // as a full solve while keeping the matrix over seeds affordable.
    for seed in [3u64, 17] {
        for (kind, inst) in gated_instances(seed) {
            let tr = trim(&inst).instance;
            assert!(
                tr.n_tasks() * tr.n_types() >= 4096,
                "instance too small to exercise the parallel path"
            );
            let mut lp = MappingLp::from_instance(&tr);
            scaling::equilibrate(&mut lp);
            let solve = |threads: usize| {
                let opts = PdhgOptions { max_iters: 1500, threads, ..Default::default() };
                pdhg::solve(&lp, &opts)
            };
            let reference = solve(1);
            for threads in [2usize, 4, 8] {
                let r = solve(threads);
                let label = format!("seed {seed} {kind} threads {threads}");
                assert_result_identical(&reference, &r, &label);
            }
        }
    }
}

#[test]
fn parallel_build_and_bound_match_serial_bitwise() {
    for (kind, inst) in gated_instances(5) {
        let tr = trim(&inst).instance;
        let serial = MappingLp::from_instance(&tr);
        for threads in [2usize, 4, 8] {
            let par = MappingLp::from_instance_par(&tr, threads);
            let label = format!("{kind} threads {threads}");
            assert_bits_eq(&par.seg_ratios, &serial.seg_ratios, "seg_ratios", &label);
            assert_eq!(par.seg_off, serial.seg_off, "{label}: seg_off");
            assert_eq!(par.seg_spans, serial.seg_spans, "{label}: seg_spans");
        }
        // certified bound repair: parallel == serial on real iterates
        let mut lp = serial;
        scaling::equilibrate(&mut lp);
        let r = pdhg::solve(&lp, &PdhgOptions { max_iters: 1000, ..Default::default() });
        let (b1, w1) = dual::certified_bound(&lp, &r.y);
        for threads in [2usize, 4, 8] {
            let (bt, wt) = dual::certified_bound_par(&lp, &r.y, threads);
            assert_eq!(b1.to_bits(), bt.to_bits(), "{kind}: bound at {threads} threads");
            assert_bits_eq(&w1, &wt, "repaired duals", &format!("{kind} t={threads}"));
        }
    }
}

#[test]
fn certified_bound_on_parallel_iterates_bounds_placed_cost() {
    // End-to-end through the parallel path: the dual bound the parallel
    // solve certifies must stay below every placed solution's cost.
    for (kind, inst) in gated_instances(9) {
        let tr = trim(&inst).instance;
        for threads in [2usize, 4] {
            let solver = NativePdhgSolver::with_threads(threads);
            let rep = lp_map(&tr, &solver, FitPolicy::FirstFit, true).unwrap();
            assert!(rep.solution.verify(&tr).is_ok(), "{kind} t={threads}");
            assert!(
                rep.certified_lb > 0.0 && rep.certified_lb <= rep.solution.cost(&tr) + 1e-6,
                "{kind} t={threads}: lb {} vs cost {}",
                rep.certified_lb,
                rep.solution.cost(&tr)
            );
        }
    }
}
