//! Property tests for the pipeline layer: every named preset must
//! reproduce the seed `Algorithm::run` code path *bit-identically*
//! (same cost, same assignment, same purchase numbering) on synthetic
//! and GCT-like scenarios, and the parallel [`Portfolio`] race must
//! equal the sequential fold member-for-member.

use tlrs::algo::algorithms::{lp_map_best, penalty_map_best, run, Algorithm};
use tlrs::algo::pipeline::{self, Portfolio};
use tlrs::io::gct_like;
use tlrs::io::synth::{generate, SynthParams};
use tlrs::lp::solver::NativePdhgSolver;
use tlrs::model::{trim, Instance, Solution};

fn assert_identical(a: &Solution, b: &Solution, label: &str) {
    assert_eq!(a.nodes.len(), b.nodes.len(), "{label}: node count");
    for (i, (x, y)) in a.nodes.iter().zip(&b.nodes).enumerate() {
        assert_eq!(x.type_idx, y.type_idx, "{label}: node {i} type");
        assert_eq!(x.purchase_order, y.purchase_order, "{label}: node {i} purchase order");
        assert_eq!(x.tasks, y.tasks, "{label}: node {i} tasks");
    }
    assert_eq!(a.assignment, b.assignment, "{label}: assignment");
}

fn synth_cases() -> Vec<(String, Instance)> {
    let mut cases = Vec::new();
    for seed in [3u64, 47] {
        let inst = generate(&SynthParams { n: 110, m: 6, ..Default::default() }, seed);
        cases.push((format!("synth seed {seed}"), trim(&inst).instance));
    }
    cases
}

fn gct_cases() -> Vec<(String, Instance)> {
    let trace = gct_like::generate_trace(1500, 11);
    let mut cases = Vec::new();
    for seed in [1u64, 4] {
        let gct = trace.sample_scenario(220, 9, seed);
        cases.push((format!("gct seed {seed}"), trim(&gct).instance));
    }
    cases
}

#[test]
fn penalty_presets_reproduce_seed_path_bit_identically() {
    for (label, tr) in synth_cases().into_iter().chain(gct_cases()) {
        for (preset, fill) in [("penalty-map", false), ("penalty-map-f", true)] {
            let seed_sol = penalty_map_best(&tr, fill);
            let rep = pipeline::preset(preset)
                .unwrap()
                .run(&tr, &NativePdhgSolver::default())
                .unwrap();
            assert!(
                (rep.cost - seed_sol.cost(&tr)).abs() < 1e-12,
                "{label} {preset}: cost {} vs seed {}",
                rep.cost,
                seed_sol.cost(&tr)
            );
            assert_identical(&rep.solution, &seed_sol, &format!("{label} {preset}"));
            assert!(rep.solution.verify(&tr).is_ok(), "{label} {preset}");
            assert!(rep.certified_lb.is_none(), "{label} {preset}: no LP, no bound");
        }
    }
}

#[test]
fn lp_presets_reproduce_seed_path_bit_identically() {
    let solver = NativePdhgSolver::default();
    for (label, tr) in synth_cases().into_iter().chain(gct_cases()) {
        for (preset, fill) in [("lp-map", false), ("lp-map-f", true)] {
            let seed_rep = lp_map_best(&tr, &solver, fill).unwrap();
            let rep = pipeline::preset(preset).unwrap().run(&tr, &solver).unwrap();
            assert!(
                (rep.cost - seed_rep.solution.cost(&tr)).abs() < 1e-12,
                "{label} {preset}: cost {} vs seed {}",
                rep.cost,
                seed_rep.solution.cost(&tr)
            );
            assert_identical(&rep.solution, &seed_rep.solution, &format!("{label} {preset}"));
            // LP diagnostics carry over unchanged
            let lb = rep.certified_lb.expect("LP preset certifies a bound");
            assert!((lb - seed_rep.certified_lb).abs() < 1e-12, "{label} {preset}");
            let stats = rep.lp.as_ref().expect("LP preset keeps stats");
            assert_eq!(stats.mapping, seed_rep.mapping, "{label} {preset}");
            assert_eq!(stats.x_max, seed_rep.x_max, "{label} {preset}");
            assert_eq!(stats.converged, seed_rep.solver_converged, "{label} {preset}");
        }
    }
}

#[test]
fn algorithm_enum_is_a_faithful_shim() {
    let solver = NativePdhgSolver::default();
    let inst = generate(&SynthParams { n: 90, m: 5, ..Default::default() }, 77);
    let tr = trim(&inst).instance;
    for algo in Algorithm::all() {
        let (sol, lp_rep) = run(&tr, algo, &solver).unwrap();
        let seed_sol = match algo {
            Algorithm::PenaltyMap => penalty_map_best(&tr, false),
            Algorithm::PenaltyMapF => penalty_map_best(&tr, true),
            Algorithm::LpMap => lp_map_best(&tr, &solver, false).unwrap().solution,
            Algorithm::LpMapF => lp_map_best(&tr, &solver, true).unwrap().solution,
        };
        assert_identical(&sol, &seed_sol, &format!("{algo:?}"));
        assert_eq!(lp_rep.is_some(), algo.uses_lp(), "{algo:?}");
        if let Some(rep) = lp_rep {
            assert!(rep.certified_lb > 0.0, "{algo:?}");
            assert!(rep.certified_lb <= sol.cost(&tr) + 1e-6, "{algo:?}");
        }
    }
}

#[test]
fn portfolio_race_equals_sequential_fold() {
    let solver = NativePdhgSolver::default();
    for (label, tr) in [synth_cases().remove(1), gct_cases().remove(0)] {
        let par = Portfolio::presets().run(&tr, &solver).unwrap();
        let seq = Portfolio::presets().run_sequential(&tr, &solver).unwrap();
        assert_eq!(par.winner, seq.winner, "{label}");
        assert_eq!(par.reports.len(), seq.reports.len(), "{label}");
        for (a, b) in par.reports.iter().zip(&seq.reports) {
            assert_eq!(a.label, b.label, "{label}");
            assert!((a.cost - b.cost).abs() < 1e-12, "{label} {}", a.label);
            assert_identical(&a.solution, &b.solution, &format!("{label} {}", a.label));
        }
        // the race winner is exactly the sequential best-of fold
        let fold = seq
            .reports
            .iter()
            .map(|r| r.cost)
            .fold(f64::INFINITY, f64::min);
        assert!((par.best().cost - fold).abs() < 1e-12, "{label}");
        assert!(par.best().solution.verify(&tr).is_ok(), "{label}");
    }
}

#[test]
fn early_abort_is_deterministic_and_cost_preserving() {
    use tlrs::lp::solver::SimplexSolver;
    use tlrs::model::NodeType;
    use tlrs::model::Task;

    // A bound-tight instance: four half-capacity tasks over one slot pack
    // into exactly two nodes, which is also the LP optimum — so the lp
    // member finishes *at* the certified bound and later members are
    // provably unable to beat it.
    let inst = Instance::new(
        (0..4).map(|i| Task::new(i, vec![0.5], 0, 1)).collect(),
        vec![NodeType::new("a", vec![1.0], 1.0)],
        2,
    );
    let tr = trim(&inst).instance;
    let specs = "lp:ff,penalty:ff,penalty:ff+ls";
    let portfolio = pipeline::parse_portfolio(specs).unwrap();
    assert!(portfolio.early_abort);

    // sequential reference: maximal deterministic skipping
    let seq = portfolio.run_sequential(&tr, &SimplexSolver).unwrap();
    assert_eq!(seq.reports.len(), 1, "skipped {:?}", seq.skipped);
    assert_eq!(seq.skipped, vec!["penalty:ff", "penalty:ff+ls"]);
    assert_eq!(seq.best().label, "lp:ff");
    assert!((seq.best().cost - 2.0).abs() < 1e-9);
    assert!(seq.best().solution.verify(&tr).is_ok());

    // the parallel race may let some members through, but the winner —
    // label and cost — must be identical run after run
    for _ in 0..4 {
        let par = portfolio.run(&tr, &SimplexSolver).unwrap();
        assert_eq!(par.best().label, seq.best().label);
        assert!((par.best().cost - seq.best().cost).abs() < 1e-12);
        // every skipped member provably could not have beaten the bound
        let lb = par.lp.as_ref().unwrap().certified_lb;
        assert!(par.best().cost <= lb + 1e-9 * lb.abs() + 1e-9);
        // completed + skipped account for every member, in order
        assert_eq!(par.reports.len() + par.skipped.len(), 3);
    }

    // disabling early abort runs everything and lands on the same cost
    let full = pipeline::parse_portfolio(specs)
        .unwrap()
        .with_early_abort(false)
        .run(&tr, &SimplexSolver)
        .unwrap();
    assert_eq!(full.reports.len(), 3);
    assert!(full.skipped.is_empty());
    assert!((full.best().cost - seq.best().cost).abs() < 1e-12);

    // on a non-tight instance nothing is ever skipped: heuristic costs sit
    // strictly above the LP bound, so the race degenerates to the plain
    // portfolio and matches its sequential fold member-for-member
    let loose = synth_cases().remove(0).1;
    let par = pipeline::parse_portfolio("portfolio")
        .unwrap()
        .run(&loose, &NativePdhgSolver::default())
        .unwrap();
    let seq = pipeline::parse_portfolio("portfolio")
        .unwrap()
        .run_sequential(&loose, &NativePdhgSolver::default())
        .unwrap();
    assert!(par.skipped.is_empty(), "{:?}", par.skipped);
    assert!(seq.skipped.is_empty(), "{:?}", seq.skipped);
    assert_eq!(par.winner, seq.winner);
    for (a, b) in par.reports.iter().zip(&seq.reports) {
        assert_eq!(a.label, b.label);
        assert!((a.cost - b.cost).abs() < 1e-12);
        assert_identical(&a.solution, &b.solution, &a.label);
    }
}

#[test]
fn previously_unreachable_combo_runs_and_never_hurts() {
    // lp+fill+ls: local search refines every fill candidate, so the
    // raced minimum can only improve on the plain LP-map-F preset
    let solver = NativePdhgSolver::default();
    let inst = generate(&SynthParams { n: 130, m: 6, ..Default::default() }, 5);
    let tr = trim(&inst).instance;
    let race = Portfolio::new()
        .add(pipeline::preset("lp-map-f").unwrap())
        .add(pipeline::parse("lp+fill+ls").unwrap())
        .run(&tr, &solver)
        .unwrap();
    let lpf = &race.reports[0];
    let combo = &race.reports[1];
    assert!(combo.solution.verify(&tr).is_ok());
    assert!(
        combo.cost <= lpf.cost + 1e-9,
        "ls made it worse: {} vs {}",
        combo.cost,
        lpf.cost
    );
    let lb = combo.certified_lb.expect("combo consumed the shared LP");
    assert!(lb <= combo.cost + 1e-6);
}
