//! Integration: the AOT JAX/Pallas artifacts executed through PJRT agree
//! with the native Rust solvers. Requires `make artifacts` (skips cleanly
//! when artifacts are absent, e.g. in a fresh checkout).

use tlrs::algo::penalty_map::{penalty_matrix, MappingPolicy};
use tlrs::io::synth::{generate, SynthParams};
use tlrs::lp::solver::{MappingSolver, NativePdhgSolver, SimplexSolver};
use tlrs::lp::{scaling, MappingLp};
use tlrs::model::trim;
use tlrs::runtime::{ArtifactSolver, Manifest};

fn artifact_solver() -> Option<ArtifactSolver> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("artifacts not built; skipping runtime integration test");
        return None;
    }
    Some(ArtifactSolver::from_default_dir().expect("loading artifacts"))
}

fn small_lp(seed: u64, n: usize, m: usize, dims: usize, horizon: u32) -> MappingLp {
    let inst = generate(
        &SynthParams { n, m, dims, horizon, dem_range: (0.05, 0.3), ..Default::default() },
        seed,
    );
    let mut lp = MappingLp::from_instance(&trim(&inst).instance);
    scaling::equilibrate(&mut lp);
    lp
}

#[test]
fn artifact_matches_simplex_small() {
    let Some(solver) = artifact_solver() else { return };
    for seed in [0u64, 1] {
        let lp = small_lp(seed, 12, 3, 2, 8);
        let exact = SimplexSolver.solve_mapping(&lp).unwrap();
        let got = solver.solve_mapping(&lp).unwrap();
        assert!(got.converged, "seed {seed}: artifact solve did not converge");
        let rel = (got.objective - exact.objective).abs() / (1.0 + exact.objective.abs());
        assert!(
            rel < 5e-3,
            "seed {seed}: artifact {} vs simplex {}",
            got.objective,
            exact.objective
        );
    }
}

#[test]
fn artifact_matches_native_pdhg_medium() {
    let Some(solver) = artifact_solver() else { return };
    let lp = small_lp(7, 100, 6, 4, 24);
    let native = NativePdhgSolver::default().solve_mapping(&lp).unwrap();
    let got = solver.solve_mapping(&lp).unwrap();
    assert!(got.converged);
    let rel = (got.objective - native.objective).abs() / (1.0 + native.objective.abs());
    assert!(rel < 5e-3, "artifact {} vs native {}", got.objective, native.objective);
    // roundings agree for decisively-assigned tasks
    let m = lp.m;
    let mut agree = 0;
    for u in 0..lp.n {
        let arg = |x: &[f64]| {
            (0..m).max_by(|&a, &b| x[u * m + a].partial_cmp(&x[u * m + b]).unwrap()).unwrap()
        };
        if arg(&got.x) == arg(&native.x) {
            agree += 1;
        }
    }
    assert!(agree as f64 >= 0.9 * lp.n as f64, "only {agree}/{} roundings agree", lp.n);
}

#[test]
fn penalty_artifact_matches_native() {
    let Some(solver) = artifact_solver() else { return };
    let inst = generate(&SynthParams { n: 50, m: 5, dims: 3, horizon: 12, ..Default::default() }, 3);
    let tr = trim(&inst).instance;
    let (p_avg, p_max) =
        tlrs::runtime::pdhg_exec::penalty_scores_artifact(&solver, &tr).unwrap();
    let native_avg = penalty_matrix(&tr, MappingPolicy::HAvg);
    let native_max = penalty_matrix(&tr, MappingPolicy::HMax);
    for i in 0..p_avg.len() {
        // native matrix has +inf on inadmissible pairs; kernel reports raw
        if native_avg[i].is_finite() {
            assert!((p_avg[i] - native_avg[i]).abs() < 1e-4 * (1.0 + native_avg[i]), "avg[{i}]");
        }
        if native_max[i].is_finite() {
            assert!((p_max[i] - native_max[i]).abs() < 1e-4 * (1.0 + native_max[i]), "max[{i}]");
        }
    }
}

#[test]
fn dual_bound_from_artifact_is_valid() {
    let Some(solver) = artifact_solver() else { return };
    let lp = small_lp(11, 14, 3, 2, 8);
    let exact = SimplexSolver.solve_mapping(&lp).unwrap();
    let got = solver.solve_mapping(&lp).unwrap();
    let (lb, _) = tlrs::lp::dual::certified_bound(&lp, &got.y);
    assert!(lb <= exact.objective + 1e-6 * (1.0 + exact.objective));
    assert!(lb >= 0.9 * exact.objective, "lb {lb} too loose vs {}", exact.objective);
}
