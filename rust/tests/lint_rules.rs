//! Fixture-driven tests for `tlrs-lint` (util::lint), plus the
//! repo-clean gate: the crate's own sources must scan violation-free.
//!
//! Each fixture under `tests/lint_fixtures/` declares its pretend path
//! and expected verdicts in its first two lines:
//!
//! ```text
//! //! path: algo/example.rs
//! //! expect: unordered-iter@4 float-ord@9     (or: clean)
//! ```
//!
//! `python/tests/test_lint_mirror.py` runs the *same* corpus through
//! the Python mirror — the two implementations must agree fixture for
//! fixture, and byte for byte on the unsafe inventory.

use std::fs;
use std::path::{Path, PathBuf};

use tlrs::util::lint::{scan_source, scan_tree, unsafe_json};

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures")
}

/// Parse the two-line fixture header: (pretend path, expected (line, rule)s).
fn parse_header(src: &str, file: &str) -> (String, Vec<(usize, String)>) {
    let mut lines = src.lines();
    let path_line = lines.next().unwrap_or_default();
    let expect_line = lines.next().unwrap_or_default();
    let path = path_line
        .strip_prefix("//! path: ")
        .unwrap_or_else(|| panic!("{file}: first line must be `//! path: ..`"))
        .trim()
        .to_string();
    let spec = expect_line
        .strip_prefix("//! expect: ")
        .unwrap_or_else(|| panic!("{file}: second line must be `//! expect: ..`"))
        .trim();
    let mut want = Vec::new();
    if spec != "clean" {
        for entry in spec.split_whitespace() {
            let (rule, line) = entry
                .split_once('@')
                .unwrap_or_else(|| panic!("{file}: bad expect entry `{entry}`"));
            let line: usize = line
                .parse()
                .unwrap_or_else(|_| panic!("{file}: bad line in `{entry}`"));
            want.push((line, rule.to_string()));
        }
    }
    want.sort();
    (path, want)
}

#[test]
fn fixtures_match_expected_verdicts() {
    let dir = fixture_dir();
    let mut names: Vec<PathBuf> = fs::read_dir(&dir)
        .expect("fixture dir exists")
        .map(|e| e.expect("readable entry").path())
        .filter(|p| p.extension().map_or(false, |x| x == "rs"))
        .collect();
    names.sort();
    assert!(names.len() >= 15, "fixture corpus shrank: {}", names.len());
    for file in names {
        let name = file.file_name().unwrap().to_string_lossy().to_string();
        let src = fs::read_to_string(&file).expect("readable fixture");
        let (path, want) = parse_header(&src, &name);
        let out = scan_source(&path, &src);
        let mut got: Vec<(usize, String)> =
            out.findings.iter().map(|(ln, rule, _)| (*ln, rule.clone())).collect();
        got.sort();
        assert_eq!(got, want, "{name}: verdicts diverge from header");
    }
}

#[test]
fn fixture_allows_are_honored_where_declared() {
    // the allow fixtures must actually exercise the suppression path
    for (name, min_allows) in [("r1_allow.rs", 3), ("r2_float_allow.rs", 1), ("r6_unsafe_allow.rs", 1)] {
        let src = fs::read_to_string(fixture_dir().join(name)).expect("fixture");
        let (path, _) = parse_header(&src, name);
        let out = scan_source(&path, &src);
        assert!(
            out.allows_used.len() >= min_allows,
            "{name}: expected >= {min_allows} honored allows, got {}",
            out.allows_used.len()
        );
    }
}

#[test]
fn repo_sources_are_lint_clean() {
    let src_root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = scan_tree(&src_root).expect("scan src tree");
    assert!(report.n_files > 50, "src tree went missing?");
    let rendered: Vec<String> = report
        .findings
        .iter()
        .map(|(f, ln, rule, msg)| format!("{f}:{ln}: [{rule}] {msg}"))
        .collect();
    assert!(
        rendered.is_empty(),
        "the crate's own sources violate the lint:\n{}",
        rendered.join("\n")
    );
}

#[test]
fn unsafe_inventory_is_complete_and_committed() {
    let src_root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = scan_tree(&src_root).expect("scan src tree");
    assert!(!report.blocks.is_empty(), "the pool/pdhg unsafe blocks vanished?");
    for (f, ln, safety, allow) in &report.blocks {
        assert!(
            safety.is_some() || allow.is_some(),
            "{f}:{ln}: unsafe block with neither SAFETY comment nor allow"
        );
    }
    // the committed inventory is the regenerated one, byte for byte
    let committed = Path::new(env!("CARGO_MANIFEST_DIR")).join("../LINT_unsafe.json");
    let committed =
        fs::read_to_string(committed).expect("LINT_unsafe.json is committed at the repo root");
    assert_eq!(
        unsafe_json(&report.blocks),
        committed,
        "LINT_unsafe.json is stale — regenerate with scripts/lint.sh"
    );
}
