//! Differential fuzz for the streaming wire layer (`util::wire` and the
//! typed decoders built on it): the pull parser agrees with the DOM
//! parser byte-for-byte — same values on valid input, same error
//! message *and* byte position on truncated/malformed input — the
//! direct-write serializer reproduces `Json::to_string` exactly, the
//! typed instance/delta decoders only ever succeed where the DOM
//! succeeds with the identical result, and both service entry points
//! answer identically with canonical (sorted-key, re-serializable)
//! responses.

use tlrs::coordinator::config::Backend;
use tlrs::coordinator::planner::Planner;
use tlrs::coordinator::service;
use tlrs::io::delta::{delta_from_json, delta_from_slice, delta_to_json};
use tlrs::io::files;
use tlrs::io::synth::{generate, SynthParams};
use tlrs::model::{DemandSeg, Instance, Task};
use tlrs::util::json::{self, Json};
use tlrs::util::rng::Rng;
use tlrs::util::wire::{parse_dom, JsonWrite};

// ---------- generators ----------------------------------------------------

fn gen_string(rng: &mut Rng) -> String {
    const POOL: &[&str] = &[
        "a", "b", "Z", "0", " ", "\"", "\\", "\n", "\t", "\r", "\u{8}", "\u{c}", "/",
        "é", "日", "🦀", "\u{fffd}", "\u{1}", "\u{1f}",
    ];
    let n = rng.below(8);
    (0..n).map(|_| POOL[rng.below(POOL.len() as u64) as usize]).collect()
}

fn gen_num(rng: &mut Rng) -> f64 {
    match rng.below(6) {
        0 => rng.below(1000) as f64,
        1 => -(rng.below(1000) as f64),
        2 => rng.uniform(-1e6, 1e6),
        3 => rng.uniform(0.0, 1.0),
        // beyond 2^53: exercises the integer-formatting boundary and
        // the as_usize safety cutoff
        4 => rng.below(1 << 60) as f64,
        _ => rng.uniform(-1.0, 1.0) * 1e-9,
    }
}

fn gen_value(rng: &mut Rng, depth: usize) -> Json {
    let pick = if depth == 0 { rng.below(5) } else { rng.below(7) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num(gen_num(rng)),
        3 | 4 => Json::Str(gen_string(rng)),
        5 => Json::Arr((0..rng.below(4)).map(|_| gen_value(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(4))
                .map(|_| (gen_string(rng), gen_value(rng, depth - 1)))
                .collect(),
        ),
    }
}

/// A random char-boundary byte index into `s` (0..=len).
fn boundary(s: &str, rng: &mut Rng) -> usize {
    let mut i = rng.below(s.len() as u64 + 1) as usize;
    while !s.is_char_boundary(i) {
        i += 1;
    }
    i
}

/// Mutate a text while staying valid UTF-8: truncate at a boundary,
/// splice a random printable ASCII byte, or overwrite one.
fn mutate(text: &str, rng: &mut Rng) -> String {
    let mut s = text.to_string();
    match rng.below(3) {
        0 => {
            s.truncate(boundary(&s, rng));
        }
        1 => {
            let pos = boundary(&s, rng);
            s.insert(pos, (rng.below(95) + 32) as u8 as char);
        }
        _ => {
            let pos = boundary(&s, rng);
            if pos < s.len() {
                let end = pos + s[pos..].chars().next().unwrap().len_utf8();
                s.replace_range(pos..end, &((rng.below(95) + 32) as u8 as char).to_string());
            }
        }
    }
    s
}

// ---------- parser vs DOM -------------------------------------------------

fn assert_parsers_agree(text: &str) {
    match (parse_dom(text), json::parse(text)) {
        (Ok(a), Ok(b)) => assert_eq!(a, b, "value mismatch on {text:?}"),
        (Err(a), Err(b)) => assert_eq!(
            format!("{a}"),
            format!("{b}"),
            "error mismatch on {text:?}"
        ),
        (a, b) => panic!("pull/DOM disagreement on {text:?}: {a:?} vs {b:?}"),
    }
}

#[test]
fn pull_parser_matches_dom_on_random_documents_and_mutations() {
    for seed in 0..150u64 {
        let mut rng = Rng::new(seed + 1);
        let v = gen_value(&mut rng, 3);
        let text = v.to_string();
        assert_parsers_agree(&text);
        assert_parsers_agree(&format!("  {text} \t"));
        for _ in 0..6 {
            assert_parsers_agree(&mutate(&text, &mut rng));
        }
    }
}

#[test]
fn pull_parser_matches_dom_on_handwritten_edge_cases() {
    // the canonical serializer never emits these spellings, so cover
    // them explicitly: every escape form, number grammar edges, nesting
    // and truncation errors
    const CASES: &[&str] = &[
        r#""Aé\ud83e""#, // \u escapes incl. a lone surrogate (-> U+FFFD)
        r#""\b\f\/\n\r\t\"\\""#,
        r#""\q""#,   // bad escape
        r#""\u00""#, // truncated \u
        r#""\u00zz""#,
        "\"unterminated",
        "\"\\\"",
        "1e5", "1E+5", "1e-5", "-0.5", "-0", "0.0", "01", "1.", "1e", "-", "+1",
        "9007199254740993", "1e999", "-1e999", // overflow -> inf is a parse_f64 artifact both share
        "[1,2,]", "[,1]", "[1 2]", "[", "]", "[]", "[ ]",
        "{", "}", "{}", "{ }", r#"{"a"}"#, r#"{"a":}"#, r#"{"a":1,}"#, r#"{"a":1"#,
        r#"{"a":1 "b":2}"#, r#"{1:2}"#, r#"{"a":1,"a":2}"#,
        "tru", "truex", "true false", "null", "nul", "false",
        "  ", "", "\t\n\r ", "{]", "[}",
        r#"{"a":[{"b":[[]]}]}"#,
        r#"{"é":"日🦀"}"#,
        "3 ", " 3x",
    ];
    for text in CASES {
        assert_parsers_agree(text);
    }
    // deep nesting: the pull parser must not recurse
    let deep = format!("{}1{}", "[".repeat(3000), "]".repeat(3000));
    assert_parsers_agree(&deep);
}

// ---------- writer vs DOM -------------------------------------------------

#[test]
fn direct_writer_matches_dom_serialization_on_random_values() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed + 7);
        let v = gen_value(&mut rng, 3);
        assert_eq!(v.to_wire_string(), v.to_string(), "seed {seed}");
    }
}

// ---------- typed instance decoder ----------------------------------------

fn shaped(inst: &Instance) -> Instance {
    let tasks = inst
        .tasks
        .iter()
        .enumerate()
        .map(|(i, u)| {
            if i % 2 == 0 || u.span_len() < 2 {
                return u.clone();
            }
            let mid = u.start + u.span_len() / 2;
            Task::piecewise(
                u.id,
                vec![
                    DemandSeg { start: u.start, end: mid - 1, demand: u.peak().to_vec() },
                    DemandSeg { start: mid, end: u.end, demand: u.peak().to_vec() },
                ],
            )
        })
        .collect();
    Instance::new(tasks, inst.node_types.clone(), inst.horizon)
}

#[test]
fn instance_decoder_matches_dom_on_canonical_and_mutated_texts() {
    for seed in 1..=8u64 {
        let flat = generate(&SynthParams { n: 12, m: 3, ..Default::default() }, seed);
        for inst in [flat.clone(), shaped(&flat)] {
            let text = files::instance_to_wire_string(&inst);
            // serializer differential
            assert_eq!(text, files::instance_to_json(&inst).to_string(), "seed {seed}");
            // the hot path must take its own canonical output
            let back = files::instance_from_slice(text.as_bytes())
                .expect("canonical instance text must stream-decode");
            assert_eq!(
                files::instance_to_json(&back),
                files::instance_to_json(&inst),
                "seed {seed}"
            );
            // typed success on a mutation implies the DOM agrees exactly
            let mut rng = Rng::new(seed ^ 0xA5A5);
            for _ in 0..60 {
                let m = mutate(&text, &mut rng);
                if let Some(fast) = files::instance_from_slice(m.as_bytes()) {
                    let dom = json::parse(&m)
                        .ok()
                        .and_then(|v| files::instance_from_json(&v).ok())
                        .expect("typed decode succeeded where the DOM fails");
                    assert_eq!(
                        files::instance_to_json(&fast),
                        files::instance_to_json(&dom),
                        "on {m:?}"
                    );
                }
            }
        }
    }
}

// ---------- typed delta decoder -------------------------------------------

#[test]
fn delta_decoder_matches_dom_on_canonical_and_mutated_texts() {
    const VALID: &[&str] = &[
        r#"{"op":"admit","tasks":[{"id":9,"start":0,"end":3,"demand":[1.0,2.0]}]}"#,
        r#"{"op":"admit","tasks":[{"id":1,"start":2,"end":2,"demand":[0.5]},{"id":2,"start":0,"end":1,"demand":[3]}]}"#,
        r#"{"op":"admit","tasks":[{"id":4,"segments":[{"start":1,"end":2,"demand":[1]},{"start":3,"end":5,"demand":[2]}]}]}"#,
        r#"{"op":"retire","ids":[1,2,3]}"#,
        r#"{"op":"reshape","id":7,"demand":[2.5],"start":1,"end":4}"#,
        r#"{"op":"reshape","id":7,"segments":[{"start":0,"end":1,"demand":[1]},{"start":2,"end":3,"demand":[4]}]}"#,
        r#"{"op":"reshape","id":7,"segments":null,"demand":[1],"start":0,"end":2}"#,
        r#"{"op":"reprice","node_types":[{"name":"m1","capacity":[8.0,16.0],"cost":3.5}]}"#,
    ];
    for text in VALID {
        let fast = delta_from_slice(text.as_bytes())
            .unwrap_or_else(|| panic!("hot path must decode {text}"));
        let dom = delta_from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(delta_to_json(&fast), delta_to_json(&dom), "on {text}");

        let mut rng = Rng::new(text.len() as u64);
        for _ in 0..80 {
            let m = mutate(text, &mut rng);
            if let Some(fast) = delta_from_slice(m.as_bytes()) {
                let dom = json::parse(&m)
                    .ok()
                    .and_then(|v| delta_from_json(&v).ok())
                    .expect("typed delta decode succeeded where the DOM fails");
                assert_eq!(delta_to_json(&fast), delta_to_json(&dom), "on {m:?}");
            }
        }
    }
    // shapes the typed path must hand back to the DOM (which errors)
    const INVALID: &[&str] = &[
        r#"{"op":"admit","tasks":[{"id":-1,"start":0,"end":1,"demand":[1]}]}"#,
        r#"{"op":"admit","tasks":[{"id":9007199254740994,"start":0,"end":1,"demand":[1]}]}"#,
        r#"{"op":"admit","tasks":[]}"#,
        r#"{"op":"retire","ids":[]}"#,
        r#"{"op":"reshape","id":1,"segments":null}"#,
        r#"{"op":"reshape","id":1,"demand":[1],"start":0}"#,
        r#"{"op":"nope"}"#,
        r#"{"tasks":[]}"#,
    ];
    for text in INVALID {
        assert!(delta_from_slice(text.as_bytes()).is_none(), "{text}");
        assert!(
            delta_from_json(&json::parse(text).unwrap()).is_err(),
            "{text} should be a DOM grammar error"
        );
    }
}

// ---------- the service envelope ------------------------------------------

/// Drop every `seconds` field (the only nondeterministic response
/// content) so two runs of the same request compare equal.
fn strip_seconds(resp: &str) -> Json {
    fn strip(v: &mut Json) {
        match v {
            Json::Obj(m) => {
                m.remove("seconds");
                for x in m.values_mut() {
                    strip(x);
                }
            }
            Json::Arr(a) => {
                for x in a.iter_mut() {
                    strip(x);
                }
            }
            _ => {}
        }
    }
    let mut v = json::parse(resp).unwrap_or_else(|e| panic!("unparsable response {resp}: {e}"));
    strip(&mut v);
    v
}

#[test]
fn service_entry_points_agree_and_responses_are_canonical() {
    let planner = Planner::new(Backend::Native).unwrap();
    let inst = generate(&SynthParams { n: 8, m: 2, ..Default::default() }, 7);
    let inst_text = files::instance_to_wire_string(&inst);
    let corpus: Vec<(String, &str)> = vec![
        (format!("{{\"instance\":{inst_text},\"algorithm\":\"penalty-map-f\"}}"), "solve"),
        (format!(" {{\"instance\": {inst_text} ,\"algorithm\":\"penalty-map-f\"}} "), "solve"),
        // empty deltas array: streaming bails, the DOM path answers
        (
            format!("{{\"deltas\":[],\"instance\":{inst_text},\"algorithm\":\"penalty-map-f\"}}"),
            "solve",
        ),
        ("{\"workload\":\"warp:n=6\",\"seed\":2,\"algorithm\":\"penalty-map-f\"}".into(), "solve"),
        ("{\"op\":\"stats\"}".into(), "stats"),
        ("{\"op\":\"shutdown\"}".into(), "shutdown"), // error: no runtime ctl
        ("{\"op\":\"bogus\"}".into(), "invalid"),
        ("{\"op\":3}".into(), "invalid"),
        ("{}".into(), "solve"),                       // needs instance/workload
        (format!("{{\"instance\":{inst_text},\"workload\":\"warp:n=6\"}}"), "solve"),
        ("{\"instance\":3}".into(), "solve"),
        ("{\"instance\":null}".into(), "solve"),
        ("not json".into(), "invalid"),
        ("[1,2]".into(), "solve"),                    // non-object request
        ("{\"op\":\"close\",\"session\":99}".into(), "close"),
        ("{\"op\":\"delta\",\"session\":99}".into(), "delta"),
        ("{\"op\":\"query\",\"session\":99,\"delta\":{\"op\":\"retire\",\"ids\":[1]}}".into(), "query"),
    ];
    for (line, want_verb) in &corpus {
        let (a, va) = service::handle_request_with(&planner, line, None);
        let (b, vb) = service::handle_request_bytes(&planner, line.as_bytes(), None).unwrap();
        assert_eq!(va, want_verb, "verb for {line}");
        assert_eq!(va, vb, "verb split for {line}");
        // canonical: the direct-written response re-serializes to
        // itself through the DOM (sorted keys, same number/escape form)
        assert_eq!(json::parse(&a).unwrap().to_string(), a, "non-canonical: {a}");
        if a.contains("\"ok\":false") {
            // deterministic error paths: byte-identical across entries
            assert_eq!(a, b, "for {line}");
        } else {
            assert_eq!(strip_seconds(&a), strip_seconds(&b), "for {line}");
        }
    }

    // typed fast path vs forced DOM fallback: same solve, same answer
    let (fast, _) = service::handle_request_with(
        &planner,
        &format!("{{\"instance\":{inst_text},\"algorithm\":\"penalty-map-f\"}}"),
        None,
    );
    let (dom, _) = service::handle_request_with(
        &planner,
        &format!("{{\"deltas\":[],\"instance\":{inst_text},\"algorithm\":\"penalty-map-f\"}}"),
        None,
    );
    assert!(fast.contains("\"ok\":true"), "{fast}");
    assert_eq!(strip_seconds(&fast), strip_seconds(&dom));

    // invalid UTF-8 only errors on the bytes entry (the &str entry
    // cannot receive it); message matches the legacy runtime's
    let err = service::handle_request_bytes(&planner, b"{\"op\":\"stats\"\xff}", None)
        .expect_err("invalid UTF-8 must be a connection error");
    assert!(
        err.to_string().starts_with("request line is not valid UTF-8"),
        "{err}"
    );
}

#[test]
fn session_roundtrip_over_the_bytes_entry() {
    let planner = Planner::new(Backend::Native).unwrap();
    let inst = generate(&SynthParams { n: 8, m: 2, ..Default::default() }, 3);
    let inst_text = files::instance_to_wire_string(&inst);

    let open = format!("{{\"op\":\"open\",\"instance\":{inst_text},\"algorithm\":\"penalty-map-f\"}}");
    let (resp, verb) = service::handle_request_bytes(&planner, open.as_bytes(), None).unwrap();
    assert_eq!(verb, "open");
    let v = json::parse(&resp).unwrap();
    assert_eq!(v.get("ok"), &Json::Bool(true), "{resp}");
    assert_eq!(v.to_string(), resp, "non-canonical open response");
    let sid = v.get("session").as_f64().unwrap() as u64;

    // one typed delta batch: array form, mixed ops
    let batch = format!(
        "{{\"op\":\"delta\",\"session\":{sid},\"deltas\":[\
         {{\"op\":\"admit\",\"tasks\":[{{\"id\":9001,\"start\":0,\"end\":2,\"demand\":[0.5,0.5]}}]}},\
         {{\"op\":\"retire\",\"ids\":[9001]}}]}}"
    );
    let (resp, verb) = service::handle_request_bytes(&planner, batch.as_bytes(), None).unwrap();
    assert_eq!(verb, "delta");
    let v = json::parse(&resp).unwrap();
    assert_eq!(v.get("ok"), &Json::Bool(true), "{resp}");
    assert_eq!(v.to_string(), resp, "non-canonical delta response");
    assert_eq!(v.get("applied").as_arr().map(|a| a.len()), Some(2), "{resp}");

    let query = format!(
        "{{\"op\":\"query\",\"session\":{sid},\"delta\":{{\"op\":\"retire\",\"ids\":[{}]}}}}",
        inst.tasks[0].id
    );
    let (resp, verb) = service::handle_request_bytes(&planner, query.as_bytes(), None).unwrap();
    assert_eq!(verb, "query");
    let v = json::parse(&resp).unwrap();
    assert_eq!(v.get("ok"), &Json::Bool(true), "{resp}");
    assert_eq!(v.to_string(), resp, "non-canonical query response");

    let close = format!("{{\"op\":\"close\",\"session\":{sid}}}");
    let (resp, verb) = service::handle_request_bytes(&planner, close.as_bytes(), None).unwrap();
    assert_eq!(verb, "close");
    let v = json::parse(&resp).unwrap();
    assert_eq!(v.get("ok"), &Json::Bool(true), "{resp}");
    assert_eq!(v.to_string(), resp, "non-canonical close response");
}
