//! Integration: the concurrent service runtime under multi-client load —
//! byte-identity at the minimal configuration, concurrent pipelined
//! clients, the shed path, per-request budgets, and graceful shutdown.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tlrs::coordinator::config::Backend;
use tlrs::coordinator::planner::Planner;
use tlrs::coordinator::runtime::{RuntimeConfig, RuntimeHandle, ServiceRuntime};
use tlrs::coordinator::service;
use tlrs::io::files;
use tlrs::io::synth::{generate, SynthParams};
use tlrs::util::json::{self, Json};

fn cfg(workers: usize, queue: usize) -> RuntimeConfig {
    RuntimeConfig { workers, queue, ..RuntimeConfig::default() }
}

fn start(cfg: RuntimeConfig) -> (Arc<Planner>, RuntimeHandle) {
    let planner = Arc::new(Planner::new(Backend::Native).unwrap());
    let rt = ServiceRuntime::bind(planner.clone(), "127.0.0.1:0", cfg).unwrap();
    (planner, rt.spawn())
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "timed out waiting for {what}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// One client connection with a line-oriented request/response API.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn send(&mut self, line: &str) {
        self.stream.write_all(line.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        self.stream.flush().unwrap();
    }

    fn recv_raw(&mut self) -> String {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).unwrap();
        assert!(n > 0, "unexpected EOF from server");
        line.trim_end_matches('\n').to_string()
    }

    fn recv(&mut self) -> Json {
        let raw = self.recv_raw();
        json::parse(&raw).unwrap()
    }

    fn request(&mut self, line: &str) -> Json {
        self.send(line);
        self.recv()
    }

    fn expect_eof(&mut self) {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).unwrap();
        assert_eq!(n, 0, "expected EOF, got {line:?}");
    }

    fn finish_writes(&mut self) {
        self.stream.shutdown(std::net::Shutdown::Write).unwrap();
    }
}

fn solve_req(n: usize, seed: u64, algo: &str) -> String {
    let inst = generate(&SynthParams { n, m: 3, ..Default::default() }, seed);
    Json::obj(vec![
        ("instance", files::instance_to_json(&inst)),
        ("algorithm", Json::Str(algo.into())),
    ])
    .to_string()
}

/// Deep-copy with every "seconds" field zeroed: wall times are the one
/// legitimately nondeterministic part of a response.
fn normalize(v: &Json) -> Json {
    match v {
        Json::Obj(map) => Json::Obj(
            map.iter()
                .map(|(k, val)| {
                    let nv =
                        if k == "seconds" { Json::Num(0.0) } else { normalize(val) };
                    (k.clone(), nv)
                })
                .collect(),
        ),
        Json::Arr(items) => Json::Arr(items.iter().map(normalize).collect()),
        other => other.clone(),
    }
}

#[test]
fn minimal_runtime_responses_match_direct_handling() {
    // acceptance gate: at --workers 1 --queue 0 a single connection's
    // responses are byte-identical to calling handle_request directly
    // (modulo measured wall times, zeroed on both sides before the
    // solve comparison; error lines compare as exact bytes)
    let direct = Planner::new(Backend::Native).unwrap();
    let (_planner, handle) = start(cfg(1, 0));
    let mut c = Client::connect(handle.addr);

    let solve = solve_req(20, 5, "lp-map-f");
    let errors = [
        "this is not json".to_string(),
        solve_req(10, 1, "magic"),
        r#"{"op":"frobnicate"}"#.to_string(),
        r#"{"op":3}"#.to_string(),
    ];

    // pipeline everything (plus blank lines, skipped by both paths)
    c.send(&solve);
    c.send("");
    for e in &errors {
        c.send(e);
    }
    let got_solve = c.recv_raw();
    let direct_solve = service::handle_request(&direct, &solve);
    assert_eq!(
        normalize(&json::parse(&got_solve).unwrap()),
        normalize(&json::parse(&direct_solve).unwrap()),
        "solve responses diverge:\n  runtime: {got_solve}\n  direct:  {direct_solve}"
    );
    for e in &errors {
        assert_eq!(c.recv_raw(), service::handle_request(&direct, e), "request {e}");
    }
    c.finish_writes();
    c.expect_eof();
    handle.shutdown_and_join().unwrap();
}

#[test]
fn concurrent_clients_all_served_within_bounds() {
    // 3 pipelined one-shot clients + 2 session clients on a 4-worker
    // runtime: everything completes, nothing is shed, concurrency stays
    // within the worker bound, and stats surfaces the runtime telemetry
    let (planner, handle) = start(cfg(4, 8));
    let addr = handle.addr;

    let solver_clients: Vec<_> = (0..3)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                // write all requests first, then read all: exercises
                // pipelining through the worker, not just lock-step RPC
                let algo = if i == 0 { "lp-map-f" } else { "penalty-map-f" };
                let reqs: Vec<String> =
                    (0..3).map(|j| solve_req(16 + 2 * i, 10 + j, algo)).collect();
                for r in &reqs {
                    c.send(r);
                }
                for r in &reqs {
                    let v = c.recv();
                    assert_eq!(v.get("ok").as_bool(), Some(true), "{r}: {v:?}");
                    if i == 0 {
                        assert!(
                            v.get("normalized_cost").as_f64().unwrap() >= 1.0 - 1e-6,
                            "{v:?}"
                        );
                    }
                }
                c.finish_writes();
                c.expect_eof();
            })
        })
        .collect();

    let session_clients: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let v = c.request(&format!(
                    r#"{{"op":"open","workload":"synth:n={},m=3,dims=2","seed":{}}}"#,
                    14 + 4 * i,
                    i + 1
                ));
                assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
                let sid = v.get("session").as_usize().unwrap();
                let fresh = 900 + i;
                let v = c.request(&format!(
                    r#"{{"op":"delta","session":{sid},"deltas":{{"op":"admit","tasks":[{{"id":{fresh},"demand":[0.05,0.05],"start":0,"end":2}}]}}}}"#
                ));
                assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
                let v = c.request(&format!(
                    r#"{{"op":"query","session":{sid},"delta":{{"op":"retire","ids":[{fresh}]}}}}"#
                ));
                assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
                let v = c.request(&format!(r#"{{"op":"close","session":{sid}}}"#));
                assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
                c.finish_writes();
                c.expect_eof();
            })
        })
        .collect();

    for h in solver_clients.into_iter().chain(session_clients) {
        h.join().unwrap();
    }

    // one more sequential client inspects the runtime's own telemetry
    let mut c = Client::connect(addr);
    let v = c.request(r#"{"op":"stats"}"#);
    assert_eq!(v.get("ok").as_bool(), Some(true));
    let timers = v.get("timers");
    assert!(
        timers.get("request.solve").get("count").as_usize().unwrap() >= 9,
        "{v:?}"
    );
    assert!(timers.get("request.open").get("count").as_usize().unwrap() >= 2);
    let live = v.get("gauges").get("service_connections_live");
    assert!(live.get("peak").as_usize().unwrap() >= 1, "{v:?}");
    drop(c);

    let m = &planner.metrics;
    wait_until("stats connection to finish", || {
        m.gauge("service_connections_live") == 0
    });
    assert_eq!(planner.sessions.count(), 0, "both sessions closed by clients");
    assert_eq!(m.counter("connections_accepted"), 6);
    assert_eq!(m.counter("connections_shed"), 0);
    // 9 solves + 2 x (open, delta, query, close) + 1 stats
    assert_eq!(m.counter("requests_handled"), 18);
    let peak = m.gauge_peak("service_connections_live");
    assert!(
        peak >= 2 && peak <= 4,
        "expected concurrent-but-bounded service, peak {peak}"
    );
    handle.shutdown_and_join().unwrap();
}

#[test]
fn overload_sheds_with_retry_after() {
    // workers=1 queue=1: one active + one queued connection is the
    // admission bound; the third connection gets the typed shed line
    let (planner, handle) = start(cfg(1, 1));
    let addr = handle.addr;
    let m = planner.metrics.clone();

    // A occupies the single worker for as long as it stays connected
    let mut a = Client::connect(addr);
    let v = a.request(&solve_req(14, 1, "penalty-map-f"));
    assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");

    // B is admitted into the queue slot (its request bytes buffer up)
    let mut b = Client::connect(addr);
    b.send(&solve_req(14, 2, "penalty-map-f"));
    wait_until("B to be admitted", || m.counter("connections_accepted") == 2);

    // C exceeds workers + queue: shed with a typed line, then closed
    let mut c = Client::connect(addr);
    let v = c.recv();
    assert_eq!(v.get("ok").as_bool(), Some(false), "{v:?}");
    assert_eq!(v.get("error").as_str(), Some("overloaded"), "{v:?}");
    assert!(v.get("retry_after_ms").as_f64().unwrap() >= 50.0, "{v:?}");
    c.expect_eof();
    assert_eq!(m.counter("connections_shed"), 1);

    // A departs; the worker drains B's buffered request
    drop(a);
    let v = b.recv();
    assert_eq!(v.get("ok").as_bool(), Some(true), "queued client served: {v:?}");
    drop(b);

    assert_eq!(m.counter("connections_accepted"), 2);
    handle.shutdown_and_join().unwrap();
}

#[test]
fn graceful_shutdown_drains_pending_requests() {
    // 2 workers: A (holding an open session) and B occupy them; C and D
    // are queued with their request bytes already in socket buffers.
    // Shutdown must answer C and D (data-first drain), close every
    // connection, and close A's session.
    let (planner, handle) = start(cfg(2, 8));
    let addr = handle.addr;
    let m = planner.metrics.clone();

    let mut a = Client::connect(addr);
    let v = a.request(r#"{"op":"open","workload":"synth:n=12,m=2,dims=2","seed":3}"#);
    assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
    assert_eq!(planner.sessions.count(), 1);

    let mut b = Client::connect(addr);
    let v = b.request(&solve_req(12, 4, "penalty-map-f"));
    assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");

    // A and B hold both workers (responses read, connections open)
    let mut c = Client::connect(addr);
    c.send(&solve_req(12, 5, "penalty-map-f"));
    c.finish_writes();
    let mut d = Client::connect(addr);
    d.send(&solve_req(12, 6, "penalty-map-f"));
    d.finish_writes();
    wait_until("C and D to be admitted", || m.counter("connections_accepted") == 4);
    assert_eq!(m.counter("connections_shed"), 0);

    handle.ctl().begin_shutdown();

    // queued connections still get their answers during the drain
    for (label, q) in [("C", &mut c), ("D", &mut d)] {
        let v = q.recv();
        assert_eq!(v.get("ok").as_bool(), Some(true), "client {label}: {v:?}");
        q.expect_eof();
    }
    // idle-open connections are closed by the drain
    a.expect_eof();
    b.expect_eof();
    handle.join().unwrap();

    assert_eq!(planner.sessions.count(), 0, "drain closes abandoned sessions");
    assert_eq!(m.counter("sessions_closed_on_shutdown"), 1);
    assert_eq!(m.counter("requests_handled"), 4);
    assert_eq!(m.gauge("service_queue_depth"), 0);
    assert_eq!(m.gauge("service_connections_live"), 0);
}

#[test]
fn shutdown_verb_gated_and_draining() {
    // without --allow-shutdown the verb is refused and the server keeps
    // serving
    let (_planner, handle) = start(cfg(2, 4));
    let mut c = Client::connect(handle.addr);
    let v = c.request(r#"{"op":"shutdown"}"#);
    assert_eq!(v.get("ok").as_bool(), Some(false), "{v:?}");
    assert!(v.get("error").as_str().unwrap().contains("--allow-shutdown"), "{v:?}");
    let v = c.request(&solve_req(12, 7, "penalty-map-f"));
    assert_eq!(v.get("ok").as_bool(), Some(true), "server kept serving: {v:?}");
    drop(c);
    handle.shutdown_and_join().unwrap();

    // with it, the verb answers, drains, and the runtime exits cleanly
    let (planner, handle) =
        start(RuntimeConfig { allow_shutdown: true, ..cfg(2, 4) });
    let mut c = Client::connect(handle.addr);
    let v = c.request(&solve_req(12, 8, "penalty-map-f"));
    assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
    let v = c.request(r#"{"op":"shutdown"}"#);
    assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
    assert_eq!(v.get("op").as_str(), Some("shutdown"));
    assert_eq!(v.get("draining").as_bool(), Some(true));
    c.expect_eof();
    handle.join().unwrap();
    assert_eq!(planner.metrics.counter("shutdown_requests"), 1);
}

#[test]
fn oversize_request_gets_typed_error_and_close() {
    let (planner, handle) =
        start(RuntimeConfig { max_request_bytes: 2048, ..cfg(1, 2) });
    let mut c = Client::connect(handle.addr);
    let huge = format!(r#"{{"pad":"{}"}}"#, "x".repeat(5000));
    let v = c.request(&huge);
    assert_eq!(v.get("ok").as_bool(), Some(false), "{v:?}");
    assert_eq!(v.get("error").as_str(), Some("request too large"), "{v:?}");
    assert_eq!(v.get("max_request_bytes").as_usize(), Some(2048), "{v:?}");
    // mid-line there is no resync point: the connection closes
    c.expect_eof();
    assert_eq!(planner.metrics.counter("requests_too_large"), 1);

    // the server itself is unaffected: a fresh connection solves
    let mut c2 = Client::connect(handle.addr);
    let v = c2.request(&solve_req(12, 9, "penalty-map-f"));
    assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
    drop(c2);
    handle.shutdown_and_join().unwrap();
}

#[test]
fn request_timeout_answers_typed_error_but_keeps_connection() {
    // an unmeetable 1ns budget: every request times out, but the budget
    // bounds the answer, not the connection — the next request still
    // gets served (and also answers with the typed error)
    let (planner, handle) =
        start(RuntimeConfig { request_timeout: Duration::from_nanos(1), ..cfg(1, 2) });
    let mut c = Client::connect(handle.addr);
    for seed in [11, 12] {
        let v = c.request(&solve_req(12, seed, "penalty-map-f"));
        assert_eq!(v.get("ok").as_bool(), Some(false), "{v:?}");
        assert_eq!(v.get("error").as_str(), Some("timeout"), "{v:?}");
        assert!(v.get("elapsed_ms").as_f64().unwrap() >= 0.0);
    }
    drop(c);
    assert_eq!(planner.metrics.counter("requests_timed_out"), 2);
    assert_eq!(planner.metrics.counter("requests_handled"), 2);
    handle.shutdown_and_join().unwrap();
}
