//! path: coordinator/service.rs
//! expect: panic-path@5

pub fn handle(req: &[u8]) -> u8 {
    req.first().copied().unwrap()
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn free_to_panic() {
        let t0 = std::time::Instant::now();
        let mut m = HashMap::new();
        m.insert(1u32, t0);
        let v = [1u32, 2];
        assert_eq!(v[0], m.keys().copied().next().unwrap());
    }
}
