//! path: coordinator/runtime.rs
//! expect: clean

pub fn timed() -> std::time::Instant {
    std::time::Instant::now()
}
