//! path: lp/example.rs
//! expect: wallclock@5 wallclock@6

pub fn timed() -> u64 {
    let t0 = std::time::Instant::now();
    let _epoch = std::time::SystemTime::now();
    t0.elapsed().as_nanos() as u64
}
