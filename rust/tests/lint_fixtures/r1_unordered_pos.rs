//! path: algo/example.rs
//! expect: unordered-iter@4 unordered-iter@7 unordered-iter@8 unordered-iter@8

use std::collections::HashMap;

pub fn tally(xs: &[u32]) -> Vec<(u32, usize)> {
    let mut seen = std::collections::HashSet::new();
    let mut counts: HashMap<u32, usize> = HashMap::new();
    for &x in xs {
        seen.insert(x);
        *counts.entry(x).or_insert(0) += 1;
    }
    counts.into_iter().collect()
}
