//! path: util/pool.rs
//! expect: clean

pub fn helper() {
    let _b = std::thread::Builder::new().name("tlrs-pool-0".into());
    let _h = std::thread::spawn(|| ());
}
