//! path: harness/example.rs
//! expect: float-ord@5 float-ord@11

pub fn sort_scores(v: &mut Vec<f64>) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn max_score(v: &[f64]) -> Option<f64> {
    let mut best: Option<f64> = None;
    for &x in v {
        if best.map(|b| x.partial_cmp(&b) == Some(std::cmp::Ordering::Greater)).unwrap_or(true) {
            best = Some(x);
        }
    }
    best
}
