//! path: model/example.rs
//! expect: clean

pub fn skip_zero(w: f64) -> bool {
    // lint:allow(float-ord): exact-zero sparsity sentinel, never computed
    w != 0.0
}
