//! path: algo/example.rs
//! expect: unordered-iter@12 unordered-iter@12 float-ord@13 float-ord@14

pub fn edge_cases(x: f64, n: usize) -> usize {
    let _doc = "HashMap == 1.0 unsafe inside a string";
    let _raw = r#"thread::spawn and "quotes" stay inert"#;
    let _bytes = b"Instant::now() \" still a string";
    /* block comment: SystemTime partial_cmp
       spans lines and stays inert */
    let _cont = "line one \
        line two with HashMap inside";
    let flagged: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
    let trailing_dot = x == 1.;
    let exponent = 2e3 != x;
    let range_not_float = n > 1 && (1..n).len() > 0;
    let _ = (flagged, trailing_dot, exponent, range_not_float);
    n
}
