//! path: lp/example.rs
//! expect: unsafe-audit@5

pub fn read_both(p: *const f64) -> f64 {
    let a = unsafe { p.read() };
    // SAFETY: caller guarantees `p` points one past a valid pair.
    let b = unsafe { p.add(1).read() };
    a + b
}
