//! path: lp/example.rs
//! expect: clean

pub fn read(p: *const f64) -> f64 {
    // lint:allow(unsafe-audit): justification tracked in the module doc
    unsafe { p.read() }
}
