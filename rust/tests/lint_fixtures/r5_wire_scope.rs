//! path: util/wire.rs
//! expect: panic-path@5

pub fn peek(buf: &[u8]) -> u8 {
    let first = buf.first().unwrap();
    let second = buf[1];
    first + second
}
