//! path: coordinator/metrics.rs
//! expect: clean

use std::collections::HashMap;

pub struct Counters {
    by_op: HashMap<String, u64>,
}
