//! path: coordinator/service.rs
//! expect: clean

pub fn shapes(i: usize) -> u32 {
    let a = [1u32, 2, 3];
    let v = vec![7u32];
    let mut total = 0;
    for x in [10u32, 20] {
        total += x;
    }
    total + a.get(i).copied().unwrap_or(0) + v.first().copied().unwrap_or(0)
}
