//! path: runtime/example.rs
//! expect: raw-spawn@5 raw-spawn@6 raw-spawn@7

pub fn run() {
    let h = std::thread::spawn(|| 1 + 1);
    let b = std::thread::Builder::new().name("x".into());
    std::thread::scope(|s| {
        let _ = s;
    });
    let _ = (h, b);
}
