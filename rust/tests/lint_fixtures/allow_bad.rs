//! path: algo/example.rs
//! expect: bad-allow@5 float-ord@6 bad-allow@7 float-ord@8 bad-allow@9 float-ord@10

pub fn f(x: f64) -> bool {
    // lint:allow(float-ord) missing the colon-reason tail
    let a = x == 1.0;
    // lint:allow(bogus-rule): rule name does not exist
    let b = x != 2.0;
    // lint:allow(float-ord):
    let c = x == 3.0;
    a && b && c
}
