//! path: algo/example.rs
//! expect: stale-allow@5

pub fn add(a: u64, b: u64) -> u64 {
    // lint:allow(float-ord): nothing on the next line actually trips it
    a + b
}
