//! path: lp/example.rs
//! expect: clean

use std::collections::HashMap; // lint:allow(unordered-iter): alias only — all iteration below drains through a sort

// lint:allow(unordered-iter): probe-only scratch set, never iterated
use std::collections::HashSet;

pub fn dedup_sorted(xs: &[u32]) -> Vec<u32> {
    let mut seen: HashSet<u32> = HashSet::new(); // lint:allow(unordered-iter): membership probes only
    let mut out: Vec<u32> = Vec::new();
    for &x in xs {
        if seen.insert(x) {
            out.push(x);
        }
    }
    out.sort_unstable();
    out
}
