//! path: model/example.rs
//! expect: float-ord@5 float-ord@6

pub fn checks(x: f64, y: f64, i: u32) -> bool {
    let a = x == 1.0;
    let b = 0.5 != y;
    let c = x == y;
    let d = i == 1;
    a && b && c && d
}
