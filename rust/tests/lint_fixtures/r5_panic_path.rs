//! path: coordinator/service.rs
//! expect: panic-path@5 panic-path@6 panic-path@7 panic-path@8

pub fn handle(req: &[u8], items: &[u32], i: usize) -> u32 {
    let head = req.first().unwrap();
    let tail = req.last().expect("nonempty");
    let a = items[0];
    let b = items[i];
    u32::from(*head) + u32::from(*tail) + a + b
}
