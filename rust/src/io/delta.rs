//! Wire grammar for session deltas: one JSON object per delta, shared by
//! the service's `delta`/`query` verbs and the CLI's `tlrs session`
//! JSON-lines files.
//!
//! ```text
//!   {"op": "admit",   "tasks": [<task>, ...]}       task = instance format:
//!                                                   {"id", "start", "end",
//!                                                    "demand": [...]} or a
//!                                                   "segments" array
//!   {"op": "retire",  "ids": [3, 17, ...]}
//!   {"op": "reshape", "id": 3, "demand": [...], "start": s, "end": e}
//!   {"op": "reshape", "id": 3, "segments": [{"start","end","demand"}, ...]}
//!   {"op": "reprice", "node_types": [{"name","capacity","cost"}, ...]}
//! ```
//!
//! Everything is validated before model construction (spans, finiteness,
//! dimensionality against the session happens later in the session
//! layer) — malformed wire data is an error, never a panic.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::{Delta, DemandSeg, NodeType, Task};
use crate::util::json::{self, num_is_usize, Json};
use crate::util::wire::{Event, JsonPull};

use super::files;

/// Grammar summary printed by CLI/service errors.
pub const DELTA_GRAMMAR: &str = "\
  delta := {\"op\": \"admit\",   \"tasks\": [<task>...]}
         | {\"op\": \"retire\",  \"ids\": [<id>...]}
         | {\"op\": \"reshape\", \"id\": <id>, \"demand\": [...], \"start\": s, \"end\": e}
         | {\"op\": \"reshape\", \"id\": <id>, \"segments\": [{start,end,demand}...]}
         | {\"op\": \"reprice\", \"node_types\": [{name,capacity,cost}...]}
  task  := the instance-file task format (flat \"demand\" or \"segments\")";

fn grammar_err(why: impl std::fmt::Display) -> anyhow::Error {
    anyhow::anyhow!("invalid delta: {why}\nvalid deltas:\n{DELTA_GRAMMAR}")
}

/// Parse one delta object.
pub fn delta_from_json(v: &Json) -> Result<Delta> {
    let op = v
        .get("op")
        .as_str()
        .ok_or_else(|| grammar_err("missing 'op' field"))?;
    match op {
        "admit" => {
            let arr = v
                .get("tasks")
                .as_arr()
                .ok_or_else(|| grammar_err("admit needs a 'tasks' array"))?;
            if arr.is_empty() {
                return Err(grammar_err("admit with an empty 'tasks' array"));
            }
            for t in arr {
                // ids address tasks across the session's lifetime:
                // reject negative/fractional ids instead of letting the
                // (legacy-lenient) task parser coerce them
                if t.get("id").as_usize().is_none() {
                    return Err(grammar_err(
                        "admit task ids must be non-negative integers",
                    ));
                }
            }
            let tasks: Vec<Task> = arr
                .iter()
                .map(files::task_from_json)
                .collect::<Result<_>>()
                .context("admit")?;
            Ok(Delta::Admit { tasks })
        }
        "retire" => {
            let arr = v
                .get("ids")
                .as_arr()
                .ok_or_else(|| grammar_err("retire needs an 'ids' array"))?;
            if arr.is_empty() {
                return Err(grammar_err("retire with an empty 'ids' array"));
            }
            let ids: Vec<u64> = arr
                .iter()
                .map(|x| {
                    x.as_usize()
                        .map(|v| v as u64)
                        .ok_or_else(|| grammar_err("retire ids must be non-negative integers"))
                })
                .collect::<Result<_>>()?;
            Ok(Delta::Retire { ids })
        }
        "reshape" => {
            // the replacement task reuses the task grammar; the delta's
            // 'id' doubles as the task id
            if v.get("id").as_usize().is_none() {
                return Err(grammar_err("reshape needs an integer 'id'"));
            }
            let mut obj = v.as_obj().expect("op implies object").clone();
            // flat reshape may omit start/end only if segments given
            if obj.get("segments").is_none()
                && (obj.get("start").is_none() || obj.get("end").is_none())
            {
                return Err(grammar_err(
                    "flat reshape needs 'demand', 'start' and 'end'",
                ));
            }
            // derive the declared span from the segments so the task
            // grammar's span cross-check passes
            let derived = match obj.get("segments") {
                Some(segs) if !obj.contains_key("start") && !obj.contains_key("end") => {
                    let arr = segs
                        .as_arr()
                        .ok_or_else(|| grammar_err("'segments' must be an array"))?;
                    let first = arr.first().ok_or_else(|| grammar_err("empty 'segments'"))?;
                    let last = arr.last().expect("non-empty");
                    Some((first.get("start").clone(), last.get("end").clone()))
                }
                _ => None,
            };
            if let Some((s, e)) = derived {
                obj.insert("start".into(), s);
                obj.insert("end".into(), e);
            }
            let task = files::task_from_json(&Json::Obj(obj)).context("reshape")?;
            Ok(Delta::Reshape { task })
        }
        "reprice" => {
            let arr = v
                .get("node_types")
                .as_arr()
                .ok_or_else(|| grammar_err("reprice needs a 'node_types' array"))?;
            if arr.is_empty() {
                return Err(grammar_err("reprice with an empty 'node_types' array"));
            }
            let node_types = arr
                .iter()
                .map(files::node_type_from_json)
                .collect::<Result<_>>()
                .context("reprice")?;
            Ok(Delta::Reprice { node_types })
        }
        other => Err(grammar_err(format!("unknown op '{other}'"))),
    }
}

/// Serialize a delta back to its wire object (round-trip tests, echo).
pub fn delta_to_json(d: &Delta) -> Json {
    match d {
        Delta::Admit { tasks } => Json::obj(vec![
            ("op", Json::Str("admit".into())),
            ("tasks", Json::Arr(tasks.iter().map(files::task_to_json).collect())),
        ]),
        Delta::Retire { ids } => Json::obj(vec![
            ("op", Json::Str("retire".into())),
            ("ids", Json::Arr(ids.iter().map(|&i| Json::Num(i as f64)).collect())),
        ]),
        Delta::Reshape { task } => {
            let mut obj = match files::task_to_json(task) {
                Json::Obj(m) => m,
                _ => unreachable!(),
            };
            obj.insert("op".into(), Json::Str("reshape".into()));
            Json::Obj(obj)
        }
        Delta::Reprice { node_types } => Json::obj(vec![
            ("op", Json::Str("reprice".into())),
            (
                "node_types",
                Json::Arr(node_types.iter().map(files::node_type_to_json).collect()),
            ),
        ]),
    }
}

/// Parse a `"deltas"` field: a single delta object or an array of them.
pub fn deltas_from_json(v: &Json) -> Result<Vec<Delta>> {
    match v {
        Json::Arr(items) => {
            if items.is_empty() {
                return Err(grammar_err("'deltas' array is empty"));
            }
            items.iter().map(delta_from_json).collect()
        }
        Json::Obj(_) => Ok(vec![delta_from_json(v)?]),
        _ => Err(grammar_err("'deltas' must be a delta object or an array of them")),
    }
}

// ---------- streaming hot path (typed pull decoders) ----------------------
//
// Same contract as the instance decoders in `io::files`: fast paths for
// valid input only. Any surprise returns `None`; the caller re-runs
// `delta_from_json` on the DOM, which produces the canonical grammar
// error. Typed success must imply an identical DOM result
// (`tests/prop_wire.rs` pins this differentially).

/// Decode a delta object body (after its `ObjStart` was consumed).
pub(crate) fn delta_body_from_pull(p: &mut JsonPull) -> Option<Delta> {
    let mut op: Option<String> = None;
    // admit / retire / reprice payloads
    let mut tasks: Option<Vec<(Task, bool)>> = None;
    let mut ids: Option<Vec<u64>> = None;
    let mut node_types: Option<Vec<NodeType>> = None;
    // reshape payload (inline task fields)
    let mut id: Option<f64> = None;
    let mut start: Option<u32> = None;
    let mut end: Option<u32> = None;
    let mut demand: Option<Vec<f64>> = None;
    let mut segments: Option<Option<Vec<DemandSeg>>> = None;
    loop {
        match p.next().ok()? {
            // last occurrence wins, like the DOM's BTreeMap insert
            Some(Event::Key(k)) => match k.as_ref() {
                "op" => match p.next().ok()? {
                    Some(Event::Str(s)) => op = Some(s.into_owned()),
                    _ => return None,
                },
                "tasks" => {
                    match p.next().ok()? {
                        Some(Event::ArrStart) => {}
                        _ => return None,
                    }
                    let mut out = Vec::new();
                    loop {
                        match p.next().ok()? {
                            Some(Event::ObjStart) => {
                                out.push(files::task_body_from_pull(p)?)
                            }
                            Some(Event::ArrEnd) => break,
                            _ => return None,
                        }
                    }
                    tasks = Some(out);
                }
                "ids" => {
                    match p.next().ok()? {
                        Some(Event::ArrStart) => {}
                        _ => return None,
                    }
                    let mut out = Vec::new();
                    loop {
                        match p.next().ok()? {
                            // the DOM's as_usize() as u64 idiom
                            Some(Event::Num(x)) if num_is_usize(x) => {
                                out.push((x as usize) as u64)
                            }
                            Some(Event::ArrEnd) => break,
                            _ => return None,
                        }
                    }
                    ids = Some(out);
                }
                "node_types" => {
                    match p.next().ok()? {
                        Some(Event::ArrStart) => {}
                        _ => return None,
                    }
                    let mut out = Vec::new();
                    loop {
                        match p.next().ok()? {
                            Some(Event::ObjStart) => {
                                out.push(files::node_type_body_from_pull(p)?)
                            }
                            Some(Event::ArrEnd) => break,
                            _ => return None,
                        }
                    }
                    node_types = Some(out);
                }
                "id" => id = Some(files::pull_num(p)?),
                "start" => start = Some(files::num_u32(files::pull_num(p)?)?),
                "end" => end = Some(files::num_u32(files::pull_num(p)?)?),
                "demand" => demand = Some(files::pull_f64_vec(p)?),
                "segments" => segments = Some(files::segs_value_from_pull(p)?),
                _ => p.skip_value().ok()?,
            },
            Some(Event::ObjEnd) => break,
            _ => return None,
        }
    }
    match op?.as_str() {
        "admit" => {
            let tasks = tasks?;
            // session ids are addressing keys: every id must have been a
            // strict non-negative integer (the DOM pre-check)
            if tasks.is_empty() || tasks.iter().any(|(_, strict)| !strict) {
                return None;
            }
            Some(Delta::Admit { tasks: tasks.into_iter().map(|(t, _)| t).collect() })
        }
        "retire" => {
            let ids = ids?;
            if ids.is_empty() {
                return None;
            }
            Some(Delta::Retire { ids })
        }
        "reshape" => {
            let id_raw = id?;
            if !num_is_usize(id_raw) {
                return None;
            }
            // the DOM's flat-check is on key *presence*: a literal
            // `"segments": null` counts as present there
            if segments.is_none() && (start.is_none() || end.is_none()) {
                return None;
            }
            let (start, end) = match (&segments, start, end) {
                // derive the declared span from the segments
                (Some(Some(segs)), None, None) => {
                    let first = segs.first()?;
                    (first.start, segs.last().expect("non-empty").end)
                }
                (_, Some(s), Some(e)) => (s, e),
                // half-declared span without a derivable one: DOM errors
                _ => return None,
            };
            let (task, _) = files::build_task(id_raw, start, end, demand, segments)?;
            Some(Delta::Reshape { task })
        }
        "reprice" => {
            let node_types = node_types?;
            if node_types.is_empty() {
                return None;
            }
            Some(Delta::Reprice { node_types })
        }
        _ => None,
    }
}

/// Decode one full delta value (the upcoming value must be an object).
pub(crate) fn delta_value_from_pull(p: &mut JsonPull) -> Option<Delta> {
    match p.next().ok()? {
        Some(Event::ObjStart) => delta_body_from_pull(p),
        _ => None,
    }
}

/// Decode a `"deltas"` array value. `None` for an empty array too — the
/// DOM path owns that grammar error.
pub(crate) fn deltas_array_from_pull(p: &mut JsonPull) -> Option<Vec<Delta>> {
    match p.next().ok()? {
        Some(Event::ArrStart) => {}
        _ => return None,
    }
    let mut out = Vec::new();
    loop {
        match p.next().ok()? {
            Some(Event::ObjStart) => out.push(delta_body_from_pull(p)?),
            Some(Event::ArrEnd) => break,
            _ => return None,
        }
    }
    if out.is_empty() {
        return None;
    }
    Some(out)
}

/// Streaming-decode one complete delta document from raw bytes; `None`
/// means "fall back to the DOM path".
pub fn delta_from_slice(bytes: &[u8]) -> Option<Delta> {
    let mut p = JsonPull::new(bytes);
    let d = delta_value_from_pull(&mut p)?;
    matches!(p.next(), Ok(None)).then_some(d)
}

/// Load a JSON-lines delta stream (one delta per line; blank lines and
/// `#` comment lines are skipped) — the `tlrs session --deltas` format.
/// Each line takes the streaming hot path first and only rebuilds a DOM
/// when that bails (then purely to produce the canonical error or
/// handle a cold shape).
pub fn load_delta_stream(path: &Path) -> Result<Vec<Delta>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(d) = delta_from_slice(line.as_bytes()) {
            out.push(d);
            continue;
        }
        let v = json::parse(line)
            .map_err(|e| anyhow::anyhow!("{}:{}: {e}", path.display(), i + 1))?;
        out.push(
            delta_from_json(&v)
                .with_context(|| format!("{}:{}", path.display(), i + 1))?,
        );
    }
    if out.is_empty() {
        bail!("{}: no deltas found", path.display());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{DemandSeg, NodeType};

    #[test]
    fn parse_all_ops() {
        let admit = json::parse(
            r#"{"op":"admit","tasks":[{"id":7,"demand":[0.2,0.1],"start":0,"end":3}]}"#,
        )
        .unwrap();
        match delta_from_json(&admit).unwrap() {
            Delta::Admit { tasks } => {
                assert_eq!(tasks.len(), 1);
                assert_eq!(tasks[0].id, 7);
                assert!(tasks[0].is_flat());
            }
            other => panic!("{other:?}"),
        }

        let retire = json::parse(r#"{"op":"retire","ids":[3,5]}"#).unwrap();
        match delta_from_json(&retire).unwrap() {
            Delta::Retire { ids } => assert_eq!(ids, vec![3, 5]),
            other => panic!("{other:?}"),
        }

        let reshape_flat = json::parse(
            r#"{"op":"reshape","id":3,"demand":[0.4],"start":1,"end":4}"#,
        )
        .unwrap();
        match delta_from_json(&reshape_flat).unwrap() {
            Delta::Reshape { task } => {
                assert_eq!(task.id, 3);
                assert_eq!((task.start, task.end), (1, 4));
            }
            other => panic!("{other:?}"),
        }

        // piecewise reshape may omit the declared span (derived)
        let reshape_segs = json::parse(
            r#"{"op":"reshape","id":9,"segments":[
                {"start":0,"end":1,"demand":[0.1]},
                {"start":2,"end":5,"demand":[0.6]}]}"#,
        )
        .unwrap();
        match delta_from_json(&reshape_segs).unwrap() {
            Delta::Reshape { task } => {
                assert!(!task.is_flat());
                assert_eq!((task.start, task.end), (0, 5));
            }
            other => panic!("{other:?}"),
        }

        let reprice = json::parse(
            r#"{"op":"reprice","node_types":[{"name":"a","capacity":[1.0],"cost":2.5}]}"#,
        )
        .unwrap();
        match delta_from_json(&reprice).unwrap() {
            Delta::Reprice { node_types } => {
                assert_eq!(node_types.len(), 1);
                assert_eq!(node_types[0].cost, 2.5);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_deltas_error_with_grammar() {
        for bad in [
            r#"{"tasks":[]}"#,
            r#"{"op":"warp"}"#,
            r#"{"op":"admit","tasks":[]}"#,
            r#"{"op":"admit"}"#,
            r#"{"op":"retire","ids":[]}"#,
            r#"{"op":"retire","ids":[-1]}"#,
            r#"{"op":"retire","ids":["x"]}"#,
            r#"{"op":"reshape","id":1}"#,
            r#"{"op":"reshape","id":1,"demand":[0.1]}"#,
            r#"{"op":"reprice","node_types":[]}"#,
            r#"{"op":"reprice","node_types":[{"name":"a","capacity":[],"cost":1}]}"#,
        ] {
            let v = json::parse(bad).unwrap();
            let err = format!("{:#}", delta_from_json(&v).unwrap_err());
            assert!(
                err.contains("invalid delta")
                    || err.contains("capacity")
                    || err.contains("task"),
                "{bad}: {err}"
            );
        }
        // inverted spans / non-finite demand surface the task validators
        let v = json::parse(
            r#"{"op":"admit","tasks":[{"id":1,"demand":[0.1],"start":5,"end":2}]}"#,
        )
        .unwrap();
        assert!(delta_from_json(&v).is_err());
        // ids are addressing keys: negative/fractional ids are rejected
        // here even though the legacy-lenient task parser would coerce
        for bad_id in ["-7", "1.5"] {
            let v = json::parse(&format!(
                r#"{{"op":"admit","tasks":[{{"id":{bad_id},"demand":[0.1],"start":0,"end":1}}]}}"#
            ))
            .unwrap();
            let err = format!("{:#}", delta_from_json(&v).unwrap_err());
            assert!(err.contains("non-negative integers"), "{bad_id}: {err}");
        }
    }

    #[test]
    fn round_trip() {
        let deltas = vec![
            Delta::Admit {
                tasks: vec![
                    Task::new(11, vec![0.3, 0.2], 2, 6),
                    Task::piecewise(
                        12,
                        vec![
                            DemandSeg { start: 0, end: 2, demand: vec![0.1, 0.1] },
                            DemandSeg { start: 3, end: 4, demand: vec![0.5, 0.2] },
                        ],
                    ),
                ],
            },
            Delta::Retire { ids: vec![4, 9] },
            Delta::Reshape { task: Task::new(11, vec![0.6, 0.1], 1, 3) },
            Delta::Reprice {
                node_types: vec![NodeType::new("a", vec![1.0, 1.0], 3.0)],
            },
        ];
        for d in &deltas {
            let j = delta_to_json(d);
            let back = delta_from_json(&j).unwrap();
            assert_eq!(delta_to_json(&back).to_string(), j.to_string(), "{d:?}");
            assert_eq!(back.op(), d.op());
        }
    }

    #[test]
    fn jsonl_stream_loads_and_reports_line_numbers() {
        let dir = std::env::temp_dir().join(format!("tlrs-delta-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.jsonl");
        std::fs::write(
            &path,
            "# a comment\n\
             {\"op\":\"admit\",\"tasks\":[{\"id\":1,\"demand\":[0.1],\"start\":0,\"end\":1}]}\n\
             \n\
             {\"op\":\"retire\",\"ids\":[1]}\n",
        )
        .unwrap();
        let ds = load_delta_stream(&path).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds[0].op(), "admit");
        assert_eq!(ds[1].op(), "retire");

        std::fs::write(&path, "{\"op\":\"retire\",\"ids\":[]}\n").unwrap();
        let err = format!("{:#}", load_delta_stream(&path).unwrap_err());
        assert!(err.contains(":1"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streaming_delta_decoder_matches_dom() {
        // every shape the DOM accepts must pull-decode to the same delta
        for text in [
            r#"{"op":"admit","tasks":[{"id":7,"demand":[0.2,0.1],"start":0,"end":3}]}"#,
            r#"{"op":"admit","tasks":[{"id":7,"start":0,"end":3,"segments":[
                {"start":0,"end":1,"demand":[0.1]},{"start":2,"end":3,"demand":[0.6]}]}]}"#,
            r#"{"op":"retire","ids":[3,5]}"#,
            r#"{"op":"reshape","id":3,"demand":[0.4],"start":1,"end":4}"#,
            r#"{"op":"reshape","id":9,"segments":[
                {"start":0,"end":1,"demand":[0.1]},
                {"start":2,"end":5,"demand":[0.6]}]}"#,
            r#"{"op":"reshape","id":3,"demand":[0.4],"start":1,"end":4,"segments":null}"#,
            r#"{"op":"reprice","node_types":[{"name":"a","capacity":[1.0],"cost":2.5}]}"#,
            // unknown fields are skipped, duplicate keys last-wins
            r#"{"op":"retire","note":{"x":[1,2]},"ids":[9],"ids":[3,5]}"#,
        ] {
            let fast = delta_from_slice(text.as_bytes())
                .unwrap_or_else(|| panic!("hot path bailed on valid delta: {text}"));
            let dom = delta_from_json(&json::parse(text).unwrap()).unwrap();
            assert_eq!(
                delta_to_json(&fast).to_string(),
                delta_to_json(&dom).to_string(),
                "{text}"
            );
        }
        // everything the DOM rejects must come back None
        for text in [
            r#"{"tasks":[]}"#,
            r#"{"op":"warp"}"#,
            r#"{"op":"admit","tasks":[]}"#,
            r#"{"op":"admit","tasks":[{"id":-7,"demand":[0.1],"start":0,"end":1}]}"#,
            r#"{"op":"admit","tasks":[{"id":1.5,"demand":[0.1],"start":0,"end":1}]}"#,
            r#"{"op":"admit","tasks":[{"id":9007199254740994,"demand":[0.1],"start":0,"end":1}]}"#,
            r#"{"op":"retire","ids":[]}"#,
            r#"{"op":"retire","ids":[-1]}"#,
            r#"{"op":"reshape","id":1,"demand":[0.1]}"#,
            r#"{"op":"reshape","id":1,"segments":null}"#,
            r#"{"op":"reshape","id":1,"start":0,"segments":[
                {"start":0,"end":1,"demand":[0.1]}]}"#,
            r#"{"op":"reshape","id":1,"segments":[]}"#,
            r#"{"op":"reprice","node_types":[]}"#,
            r#"{"op":"retire","ids":[1]} trailing"#,
        ] {
            assert!(delta_from_slice(text.as_bytes()).is_none(), "{text}");
            assert!(
                json::parse(text).is_err()
                    || delta_from_json(&json::parse(text).unwrap()).is_err(),
                "DOM must also reject: {text}"
            );
        }
    }

    #[test]
    fn deltas_field_accepts_object_or_array() {
        let single = json::parse(r#"{"op":"retire","ids":[1]}"#).unwrap();
        assert_eq!(deltas_from_json(&single).unwrap().len(), 1);
        let arr = json::parse(
            r#"[{"op":"retire","ids":[1]},{"op":"retire","ids":[2]}]"#,
        )
        .unwrap();
        assert_eq!(deltas_from_json(&arr).unwrap().len(), 2);
        assert!(deltas_from_json(&Json::Num(3.0)).is_err());
        assert!(deltas_from_json(&Json::Arr(vec![])).is_err());
    }
}
