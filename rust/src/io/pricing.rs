//! Public-cloud pricing coefficients (paper section VI-C, reference [32]).
//!
//! The paper prices GCT-2019 node-types with coefficients from the public
//! Google Compute Engine pricing model at exponent e=1. We use the public
//! on-demand Iowa rates (n1 custom machine pricing): $0.031611 per vCPU-hour
//! and $0.004237 per GB-hour. GCT capacities are normalized, so we anchor
//! the normalization at a 64-vCPU / 256-GB machine = capacity 1.0 on each
//! axis, giving per-normalized-unit coefficients:
//!
//! ```text
//! c_cpu = 64  * 0.031611 = 2.0231 $/h
//! c_mem = 256 * 0.004237 = 1.0847 $/h
//! ```
//!
//! Only the *ratio* of the coefficients matters for solution structure
//! (all reported costs are normalized by the LP lower bound).

/// Per-normalized-unit hourly rates `[cpu, mem]`.
pub const GCP_CPU_RATE: f64 = 64.0 * 0.031611;
pub const GCP_MEM_RATE: f64 = 256.0 * 0.004237;

/// Pricing coefficients for a D-dimensional instance. The first two
/// dimensions are priced as CPU and memory; any further dimensions fall
/// back to the geometric mean of the two rates (e.g. disk/accelerators,
/// not present in GCT-like traces).
pub fn gcp_coefficients(dims: usize) -> Vec<f64> {
    assert!(dims >= 1);
    let fallback = (GCP_CPU_RATE * GCP_MEM_RATE).sqrt();
    (0..dims)
        .map(|d| match d {
            0 => GCP_CPU_RATE,
            1 => GCP_MEM_RATE,
            _ => fallback,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_dim_rates() {
        let c = gcp_coefficients(2);
        assert!((c[0] - 2.023104).abs() < 1e-6);
        assert!((c[1] - 1.084672).abs() < 1e-6);
        // cpu capacity is the pricier resource, as in the real rate card
        assert!(c[0] > c[1]);
    }

    #[test]
    fn extra_dims_get_fallback() {
        let c = gcp_coefficients(4);
        assert_eq!(c.len(), 4);
        assert!(c[2] > c[1] && c[2] < c[0]);
        assert_eq!(c[2], c[3]);
    }
}
