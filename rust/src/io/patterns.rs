//! Workload pattern library — the task archetypes the paper's introduction
//! motivates: load bursts during peak hours, nightly batch windows,
//! deadline jobs, duty-cycled sensors and always-on baselines. Patterns
//! compose into mixed workloads for the examples and ablation studies.

use crate::model::Task;
use crate::util::rng::Rng;

/// Hourly slots over one week.
pub const WEEK_HOURS: u32 = 7 * 24;

/// A parametric workload pattern on an hourly one-week timeline.
#[derive(Clone, Debug)]
pub enum Pattern {
    /// Always-on service baseline.
    Baseline { demand: Vec<f64> },
    /// Extra demand during daily peak hours [start_hour, end_hour).
    DailyBurst { demand: Vec<f64>, start_hour: u32, end_hour: u32, weekdays_only: bool },
    /// Nightly batch window: fixed start hour and duration, every day.
    NightlyBatch { demand: Vec<f64>, start_hour: u32, duration: u32 },
    /// One-shot deadline job: release and deadline hours; runs for
    /// `duration` hours placed as late as possible (paper: scheduled
    /// tasks with deadlines in edge settings).
    DeadlineJob { demand: Vec<f64>, release: u32, deadline: u32, duration: u32 },
    /// Duty-cycled sensor: `on` hours every `period` hours.
    DutyCycle { demand: Vec<f64>, period: u32, on: u32 },
}

impl Pattern {
    /// Expand the pattern into time-limited tasks over the week,
    /// allocating ids starting at `next_id` (updated in place).
    pub fn expand(&self, next_id: &mut u64) -> Vec<Task> {
        let mut out = Vec::new();
        let mut push = |id: &mut u64, demand: &Vec<f64>, s: u32, e: u32| {
            out.push(Task::new(*id, demand.clone(), s, e.min(WEEK_HOURS - 1)));
            *id += 1;
        };
        match self {
            Pattern::Baseline { demand } => push(next_id, demand, 0, WEEK_HOURS - 1),
            Pattern::DailyBurst { demand, start_hour, end_hour, weekdays_only } => {
                let days = if *weekdays_only { 0..5 } else { 0..7 };
                for day in days {
                    let s = day * 24 + start_hour;
                    let e = day * 24 + end_hour - 1;
                    push(next_id, demand, s, e);
                }
            }
            Pattern::NightlyBatch { demand, start_hour, duration } => {
                for day in 0..7 {
                    let s = day * 24 + start_hour;
                    push(next_id, demand, s, s + duration - 1);
                }
            }
            Pattern::DeadlineJob { demand, release, deadline, duration } => {
                assert!(release + duration <= *deadline, "infeasible deadline job");
                let s = deadline - duration; // as late as possible
                push(next_id, demand, s, deadline - 1);
            }
            Pattern::DutyCycle { demand, period, on } => {
                assert!(on <= period && *period > 0);
                let mut s = 0;
                while s < WEEK_HOURS {
                    push(next_id, demand, s, s + on - 1);
                    s += period;
                }
            }
        }
        out
    }
}

/// A randomized mixed workload of the paper's motivating archetypes.
pub fn mixed_workload(n_services: usize, seed: u64) -> Vec<Task> {
    let mut rng = Rng::new(seed);
    let mut next_id = 0u64;
    let mut tasks = Vec::new();
    for _ in 0..n_services {
        let d2 = |rng: &mut Rng, lo: f64, hi: f64| vec![rng.uniform(lo, hi), rng.uniform(lo, hi)];
        let pattern = match rng.below(5) {
            0 => Pattern::Baseline { demand: d2(&mut rng, 0.01, 0.06) },
            1 => Pattern::DailyBurst {
                demand: d2(&mut rng, 0.05, 0.2),
                start_hour: 8 + rng.below(3) as u32,
                end_hour: 16 + rng.below(4) as u32,
                weekdays_only: rng.f64() < 0.6,
            },
            2 => Pattern::NightlyBatch {
                demand: d2(&mut rng, 0.1, 0.3),
                start_hour: 0 + rng.below(4) as u32,
                duration: 2 + rng.below(4) as u32,
            },
            3 => {
                let release = rng.below(100) as u32;
                let duration = 2 + rng.below(20) as u32;
                let deadline = (release + duration + rng.below(40) as u32).min(WEEK_HOURS);
                Pattern::DeadlineJob {
                    demand: d2(&mut rng, 0.05, 0.25),
                    release,
                    deadline,
                    duration,
                }
            }
            _ => Pattern::DutyCycle {
                demand: d2(&mut rng, 0.02, 0.1),
                period: 4 + rng.below(8) as u32,
                on: 1 + rng.below(3) as u32,
            },
        };
        tasks.extend(pattern.expand(&mut next_id));
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_spans_week() {
        let mut id = 0;
        let t = Pattern::Baseline { demand: vec![0.1] }.expand(&mut id);
        assert_eq!(t.len(), 1);
        assert_eq!((t[0].start, t[0].end), (0, WEEK_HOURS - 1));
    }

    #[test]
    fn burst_weekdays() {
        let mut id = 0;
        let t = Pattern::DailyBurst {
            demand: vec![0.2],
            start_hour: 9,
            end_hour: 17,
            weekdays_only: true,
        }
        .expand(&mut id);
        assert_eq!(t.len(), 5);
        assert_eq!(t[0].start, 9);
        assert_eq!(t[0].end, 16);
        assert_eq!(t[4].start, 4 * 24 + 9);
    }

    #[test]
    fn nightly_batch_and_duty_cycle() {
        let mut id = 0;
        let t = Pattern::NightlyBatch { demand: vec![0.3], start_hour: 2, duration: 3 }
            .expand(&mut id);
        assert_eq!(t.len(), 7);
        assert_eq!((t[0].start, t[0].end), (2, 4));
        let t = Pattern::DutyCycle { demand: vec![0.1], period: 6, on: 2 }.expand(&mut id);
        assert_eq!(t.len(), (WEEK_HOURS as usize).div_ceil(6));
        assert_eq!((t[0].start, t[0].end), (0, 1));
    }

    #[test]
    fn deadline_placed_late() {
        let mut id = 0;
        let t = Pattern::DeadlineJob { demand: vec![0.2], release: 10, deadline: 30, duration: 5 }
            .expand(&mut id);
        assert_eq!((t[0].start, t[0].end), (25, 29));
    }

    #[test]
    #[should_panic]
    fn infeasible_deadline_rejected() {
        let mut id = 0;
        Pattern::DeadlineJob { demand: vec![0.2], release: 10, deadline: 12, duration: 5 }
            .expand(&mut id);
    }

    #[test]
    fn mixed_workload_valid() {
        let tasks = mixed_workload(50, 3);
        assert!(tasks.len() >= 50);
        for t in &tasks {
            assert!(t.end < WEEK_HOURS);
            assert_eq!(t.dims(), 2);
        }
        // deterministic
        assert_eq!(tasks, mixed_workload(50, 3));
    }
}
