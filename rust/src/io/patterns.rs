//! Workload pattern library — the task archetypes the paper's introduction
//! motivates: load bursts during peak hours, nightly batch windows,
//! deadline jobs, duty-cycled sensors and always-on baselines. Patterns
//! expand on any [`Timeline`] (arbitrary horizon, arbitrary day length)
//! and any demand dimensionality, and compose into the first-class
//! workload families `io::workload` registers.
//!
//! Infeasible parameters (a deadline job that cannot fit its window, a
//! duty cycle longer than its period) are *data* errors, not programmer
//! errors: expansion returns `Result` so bad CLI/service input surfaces
//! as a parse-style error instead of aborting the process.

use anyhow::{bail, ensure, Result};

use crate::model::Task;
use crate::util::rng::Rng;

/// Hourly slots over one week (the classic pattern timeline).
pub const WEEK_HOURS: u32 = 7 * 24;

/// The discrete timeline patterns expand on: `horizon` timeslots total,
/// `slots_per_day` slots to one diurnal period (24 for hourly slots,
/// 288 for 5-minute slots, ...).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Timeline {
    pub horizon: u32,
    pub slots_per_day: u32,
}

impl Timeline {
    pub fn new(horizon: u32, slots_per_day: u32) -> Result<Timeline> {
        ensure!(horizon > 0, "timeline needs a positive horizon");
        ensure!(slots_per_day > 0, "timeline needs a positive day length");
        Ok(Timeline { horizon, slots_per_day })
    }

    /// One week of hourly slots — the timeline the original examples used.
    pub fn hourly_week() -> Timeline {
        Timeline { horizon: WEEK_HOURS, slots_per_day: 24 }
    }

    /// Number of (possibly partial) days on the timeline.
    pub fn days(&self) -> u32 {
        self.horizon.div_ceil(self.slots_per_day)
    }
}

/// A parametric workload pattern. Hours are slots within a day
/// (`0..slots_per_day`); expansion clips every task to the horizon.
#[derive(Clone, Debug)]
pub enum Pattern {
    /// Always-on service baseline.
    Baseline { demand: Vec<f64> },
    /// Extra demand during daily peak hours [start_hour, end_hour).
    DailyBurst { demand: Vec<f64>, start_hour: u32, end_hour: u32, weekdays_only: bool },
    /// Nightly batch window: fixed start hour and duration, every day.
    NightlyBatch { demand: Vec<f64>, start_hour: u32, duration: u32 },
    /// One-shot deadline job: release and deadline slots; runs for
    /// `duration` slots placed as late as possible (paper: scheduled
    /// tasks with deadlines in edge settings).
    DeadlineJob { demand: Vec<f64>, release: u32, deadline: u32, duration: u32 },
    /// Duty-cycled sensor: `on` slots every `period` slots.
    DutyCycle { demand: Vec<f64>, period: u32, on: u32 },
}

impl Pattern {
    /// Validate the pattern against a timeline. Expansion calls this, so
    /// callers only need it to fail early with a better context.
    pub fn validate(&self, tl: Timeline) -> Result<()> {
        let spd = tl.slots_per_day;
        match self {
            Pattern::Baseline { .. } => {}
            Pattern::DailyBurst { start_hour, end_hour, .. } => {
                ensure!(
                    start_hour < end_hour,
                    "daily burst: start hour {start_hour} must precede end hour {end_hour}"
                );
                ensure!(
                    *end_hour <= spd,
                    "daily burst: end hour {end_hour} exceeds the {spd}-slot day"
                );
            }
            Pattern::NightlyBatch { start_hour, duration, .. } => {
                ensure!(*duration > 0, "nightly batch: zero duration");
                ensure!(
                    *start_hour < spd,
                    "nightly batch: start hour {start_hour} exceeds the {spd}-slot day"
                );
            }
            Pattern::DeadlineJob { release, deadline, duration, .. } => {
                ensure!(*duration > 0, "deadline job: zero duration");
                ensure!(
                    release + duration <= *deadline,
                    "deadline job: release {release} + duration {duration} overruns \
                     deadline {deadline}"
                );
                ensure!(
                    *deadline <= tl.horizon,
                    "deadline job: deadline {deadline} beyond horizon {}",
                    tl.horizon
                );
            }
            Pattern::DutyCycle { period, on, .. } => {
                ensure!(*period > 0, "duty cycle: zero period");
                ensure!(
                    *on >= 1 && on <= period,
                    "duty cycle: on-time {on} must lie in [1, period {period}]"
                );
            }
        }
        let demand = match self {
            Pattern::Baseline { demand }
            | Pattern::DailyBurst { demand, .. }
            | Pattern::NightlyBatch { demand, .. }
            | Pattern::DeadlineJob { demand, .. }
            | Pattern::DutyCycle { demand, .. } => demand,
        };
        if demand.is_empty() {
            bail!("pattern has an empty demand vector");
        }
        Ok(())
    }

    /// Expand the pattern into time-limited tasks on `tl`, allocating ids
    /// starting at `next_id` (updated in place). Errors on infeasible
    /// parameters instead of panicking.
    pub fn expand(&self, tl: Timeline, next_id: &mut u64) -> Result<Vec<Task>> {
        self.validate(tl)?;
        let horizon = tl.horizon;
        let spd = tl.slots_per_day;
        let mut out = Vec::new();
        let mut push = |id: &mut u64, demand: &Vec<f64>, s: u32, e: u32| {
            if s < horizon {
                out.push(Task::new(*id, demand.clone(), s, e.min(horizon - 1)));
                *id += 1;
            }
        };
        match self {
            Pattern::Baseline { demand } => push(next_id, demand, 0, horizon - 1),
            Pattern::DailyBurst { demand, start_hour, end_hour, weekdays_only } => {
                for day in 0..tl.days() {
                    if *weekdays_only && day % 7 >= 5 {
                        continue;
                    }
                    let s = day * spd + start_hour;
                    let e = day * spd + end_hour - 1;
                    push(next_id, demand, s, e);
                }
            }
            Pattern::NightlyBatch { demand, start_hour, duration } => {
                for day in 0..tl.days() {
                    let s = day * spd + start_hour;
                    push(next_id, demand, s, s + duration - 1);
                }
            }
            Pattern::DeadlineJob { demand, deadline, duration, .. } => {
                let s = deadline - duration; // as late as possible
                push(next_id, demand, s, deadline - 1);
            }
            Pattern::DutyCycle { demand, period, on } => {
                let mut s = 0;
                while s < horizon {
                    push(next_id, demand, s, s + on - 1);
                    s += period;
                }
            }
        }
        Ok(out)
    }
}

/// Demand vector drawn from a sub-range `[lo + a*w, lo + b*w]` of a
/// demand interval, `w = hi - lo` — keeps baselines light and batch
/// windows heavy while respecting the configured bounds.
pub fn sub_range_demand(
    rng: &mut Rng,
    dims: usize,
    (lo, hi): (f64, f64),
    a: f64,
    b: f64,
) -> Vec<f64> {
    let w = hi - lo;
    (0..dims).map(|_| rng.uniform(lo + a * w, lo + b * w)).collect()
}

/// Draw a daily peak-hours burst shape on `tl` (demand supplied by the
/// caller). The parameters are always feasible: the start stays below
/// the day, so `start + 1` is a valid end.
pub fn draw_burst(rng: &mut Rng, demand: Vec<f64>, tl: Timeline) -> Pattern {
    let spd = tl.slots_per_day as u64;
    let start = spd / 3 + rng.below((spd / 8).max(1));
    let end = (2 * spd / 3 + rng.below((spd / 6).max(1))).clamp(start + 1, spd);
    Pattern::DailyBurst {
        demand,
        start_hour: start as u32,
        end_hour: end as u32,
        weekdays_only: rng.f64() < 0.6,
    }
}

/// Draw a nightly batch-window shape on `tl`.
pub fn draw_batch(rng: &mut Rng, demand: Vec<f64>, tl: Timeline) -> Pattern {
    let spd = tl.slots_per_day as u64;
    Pattern::NightlyBatch {
        demand,
        start_hour: rng.below((spd / 6).max(1)) as u32,
        duration: (1 + rng.below((spd / 6).max(2))) as u32,
    }
}

/// Draw a one-shot deadline-job shape within `tl`'s horizon.
pub fn draw_deadline(rng: &mut Rng, demand: Vec<f64>, tl: Timeline) -> Pattern {
    let horizon = tl.horizon as u64;
    let duration = 1 + rng.below((horizon / 8).max(1));
    let release = rng.below((horizon + 1 - duration).max(1));
    let deadline = (release + duration + rng.below((horizon / 4).max(1))).min(horizon);
    Pattern::DeadlineJob {
        demand,
        release: release as u32,
        deadline: deadline as u32,
        duration: duration as u32,
    }
}

/// Draw a duty-cycle shape with a period scaled to `tl`'s day length.
pub fn draw_duty(rng: &mut Rng, demand: Vec<f64>, tl: Timeline) -> Pattern {
    let spd = tl.slots_per_day as u64;
    let period = 2 + rng.below((spd / 3).max(2));
    Pattern::DutyCycle {
        demand,
        period: period as u32,
        on: (1 + rng.below((period - 1).max(1))) as u32,
    }
}

/// A randomized mixed workload of the paper's motivating archetypes on an
/// arbitrary timeline and dimensionality. Deterministic in `seed`; demand
/// components are drawn from `dem_range` (pattern-specific sub-ranges
/// keep baselines light and batch windows heavy, as the originals did).
pub fn mixed_tasks(
    n_services: usize,
    dims: usize,
    tl: Timeline,
    dem_range: (f64, f64),
    rng: &mut Rng,
) -> Result<Vec<Task>> {
    ensure!(dims > 0, "mixed workload needs at least one dimension");
    let (lo, hi) = dem_range;
    ensure!(lo > 0.0 && hi >= lo, "mixed workload: bad demand range [{lo}, {hi}]");
    let mut next_id = 0u64;
    let mut tasks = Vec::new();
    for _ in 0..n_services {
        let pattern = match rng.below(5) {
            0 => Pattern::Baseline {
                demand: sub_range_demand(rng, dims, dem_range, 0.0, 0.25),
            },
            1 => draw_burst(rng, sub_range_demand(rng, dims, dem_range, 0.2, 1.0), tl),
            2 => draw_batch(rng, sub_range_demand(rng, dims, dem_range, 0.5, 1.0), tl),
            3 => draw_deadline(rng, sub_range_demand(rng, dims, dem_range, 0.2, 1.0), tl),
            _ => draw_duty(rng, sub_range_demand(rng, dims, dem_range, 0.0, 0.5), tl),
        };
        tasks.extend(pattern.expand(tl, &mut next_id)?);
    }
    Ok(tasks)
}

/// The original examples-facing helper: a 2-dimensional mixed workload on
/// the hourly one-week timeline. Thin shim over [`mixed_tasks`].
pub fn mixed_workload(n_services: usize, seed: u64) -> Vec<Task> {
    let mut rng = Rng::new(seed);
    mixed_tasks(n_services, 2, Timeline::hourly_week(), (0.01, 0.3), &mut rng)
        .expect("hourly-week mixed workload parameters are always feasible")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn week() -> Timeline {
        Timeline::hourly_week()
    }

    #[test]
    fn baseline_spans_week() {
        let mut id = 0;
        let t = Pattern::Baseline { demand: vec![0.1] }.expand(week(), &mut id).unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!((t[0].start, t[0].end), (0, WEEK_HOURS - 1));
    }

    #[test]
    fn burst_weekdays() {
        let mut id = 0;
        let t = Pattern::DailyBurst {
            demand: vec![0.2],
            start_hour: 9,
            end_hour: 17,
            weekdays_only: true,
        }
        .expand(week(), &mut id)
        .unwrap();
        assert_eq!(t.len(), 5);
        assert_eq!(t[0].start, 9);
        assert_eq!(t[0].end, 16);
        assert_eq!(t[4].start, 4 * 24 + 9);
    }

    #[test]
    fn nightly_batch_and_duty_cycle() {
        let mut id = 0;
        let t = Pattern::NightlyBatch { demand: vec![0.3], start_hour: 2, duration: 3 }
            .expand(week(), &mut id)
            .unwrap();
        assert_eq!(t.len(), 7);
        assert_eq!((t[0].start, t[0].end), (2, 4));
        let t = Pattern::DutyCycle { demand: vec![0.1], period: 6, on: 2 }
            .expand(week(), &mut id)
            .unwrap();
        assert_eq!(t.len(), (WEEK_HOURS as usize).div_ceil(6));
        assert_eq!((t[0].start, t[0].end), (0, 1));
    }

    #[test]
    fn deadline_placed_late() {
        let mut id = 0;
        let t = Pattern::DeadlineJob { demand: vec![0.2], release: 10, deadline: 30, duration: 5 }
            .expand(week(), &mut id)
            .unwrap();
        assert_eq!((t[0].start, t[0].end), (25, 29));
    }

    #[test]
    fn infeasible_parameters_are_errors_not_panics() {
        let mut id = 0;
        let err = Pattern::DeadlineJob { demand: vec![0.2], release: 10, deadline: 12, duration: 5 }
            .expand(week(), &mut id)
            .unwrap_err()
            .to_string();
        assert!(err.contains("overruns deadline"), "{err}");
        let err = Pattern::DutyCycle { demand: vec![0.1], period: 4, on: 9 }
            .expand(week(), &mut id)
            .unwrap_err()
            .to_string();
        assert!(err.contains("period"), "{err}");
        let err = Pattern::DailyBurst {
            demand: vec![0.1],
            start_hour: 9,
            end_hour: 40,
            weekdays_only: false,
        }
        .expand(week(), &mut id)
        .unwrap_err()
        .to_string();
        assert!(err.contains("exceeds"), "{err}");
        assert!(Pattern::Baseline { demand: vec![] }.expand(week(), &mut id).is_err());
        assert_eq!(id, 0, "failed expansions must not consume ids");
    }

    #[test]
    fn generalized_timelines() {
        // two 12-slot days
        let tl = Timeline::new(24, 12).unwrap();
        let mut id = 0;
        let t = Pattern::NightlyBatch { demand: vec![0.2, 0.1], start_hour: 10, duration: 4 }
            .expand(tl, &mut id)
            .unwrap();
        // both windows clip to the horizon
        assert_eq!(t.len(), 2);
        assert_eq!((t[0].start, t[0].end), (10, 13));
        assert_eq!((t[1].start, t[1].end), (22, 23));
        assert!(Timeline::new(0, 24).is_err());
        assert!(Timeline::new(24, 0).is_err());
    }

    #[test]
    fn mixed_workload_valid() {
        let tasks = mixed_workload(50, 3);
        assert!(tasks.len() >= 50);
        for t in &tasks {
            assert!(t.end < WEEK_HOURS);
            assert_eq!(t.dims(), 2);
            assert!(t.peak().iter().all(|&d| d > 0.0));
        }
        // deterministic
        assert_eq!(tasks, mixed_workload(50, 3));
    }

    #[test]
    fn mixed_tasks_respects_dims_and_horizon() {
        let tl = Timeline::new(48, 24).unwrap();
        let mut rng = Rng::new(5);
        let tasks = mixed_tasks(30, 4, tl, (0.01, 0.2), &mut rng).unwrap();
        assert!(!tasks.is_empty());
        for t in &tasks {
            assert_eq!(t.dims(), 4);
            assert!(t.end < 48);
            assert!(t.peak().iter().all(|&d| (0.01..=0.2 + 1e-12).contains(&d)));
        }
    }
}
