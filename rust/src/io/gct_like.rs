//! GCT-2019-like trace generator.
//!
//! The paper samples ~13K tasks and 13 machine-types from the Google Cloud
//! Trace 2019 (cluster "a") via BigQuery. That dataset needs credentialed
//! BigQuery access, so we synthesize a trace with the same *statistics the
//! paper actually uses* (see DESIGN.md section 3):
//!
//!   - D = 2 (CPU, memory), both demands and capacities normalized;
//!   - 13 machine shapes mirroring the public GCT-2019 machine-type table
//!     (dominant 0.5/0.25-normalized shapes plus low/high-mem variants);
//!   - task demands small relative to capacities (medians ~1e-2);
//!   - heavy-tailed durations (lognormal) and a diurnal start-time mix
//!     over a one-week timeline at 5-minute granularity;
//!   - scenario sampling (n tasks, m types) exactly as the paper does.

use crate::model::{Instance, NodeType, Task};
use crate::util::rng::Rng;

use super::pricing;

/// One week at 5-minute slots.
pub const WEEK_SLOTS: u32 = 7 * 24 * 12;

/// The 13 machine shapes (normalized CPU, normalized memory). Mirrors the
/// shape table of GCT-2019: capacities are fractions of the largest
/// machine; 0.5-CPU shapes dominate the fleet.
pub const MACHINE_SHAPES: [(f64, f64); 13] = [
    (0.25, 0.125),
    (0.25, 0.25),
    (0.375, 0.25),
    (0.5, 0.125),
    (0.5, 0.25),
    (0.5, 0.375),
    (0.5, 0.5),
    (0.5, 0.75),
    (0.75, 0.5),
    (0.75, 0.75),
    (1.0, 0.5),
    (1.0, 0.75),
    (1.0, 1.0),
];

/// A full generated trace: the pool scenarios are sampled from.
#[derive(Clone, Debug)]
pub struct Trace {
    pub tasks: Vec<Task>,
    pub node_types: Vec<NodeType>,
    pub horizon: u32,
}

/// Generate the full ~13K-task trace. Deterministic in `seed`.
pub fn generate_trace(n_tasks: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let coeff = pricing::gcp_coefficients(2);

    let node_types: Vec<NodeType> = MACHINE_SHAPES
        .iter()
        .enumerate()
        .map(|(i, &(cpu, mem))| {
            let cost = coeff[0] * cpu + coeff[1] * mem;
            NodeType::new(format!("gct-shape-{i:02}"), vec![cpu, mem], cost)
        })
        .collect();

    let tasks: Vec<Task> = (0..n_tasks)
        .map(|i| {
            // Demands: lognormal around ~5% of a full machine, clipped to
            // [0.5%, 25%]; memory correlated with CPU (rho ~ 0.7) as in
            // real traces. Calibrated so a 1000-task sample needs a
            // multi-node cluster (as in the paper's Figure 8 scenarios),
            // not a single machine.
            let z_cpu = rng.normal();
            let z_shared = 0.7 * z_cpu + 0.3 * rng.normal();
            let cpu = (0.02 * (0.8 * z_cpu).exp()).clamp(2e-3, 0.25);
            let mem = (0.016 * (0.8 * z_shared).exp()).clamp(2e-3, 0.25);

            // Durations: lognormal, median ~25h (300 slots), heavy tail
            // capped at the week.
            let dur_slots = rng.lognormal((300.0f64).ln(), 1.0).clamp(1.0, 2016.0) as u32;

            // Starts: diurnal mixture — 70% drawn from daily peak hours
            // (9:00-17:00), 30% uniform over the week.
            let start = if rng.f64() < 0.7 {
                let day = rng.below(7) as u32;
                let slot_in_day = 9 * 12 + rng.below(8 * 12) as u32;
                day * 24 * 12 + slot_in_day
            } else {
                rng.below(WEEK_SLOTS as u64) as u32
            };
            let end = (start + dur_slots - 1).min(WEEK_SLOTS - 1);
            Task::new(i as u64, vec![cpu, mem], start, end)
        })
        .collect();

    Trace { tasks, node_types, horizon: WEEK_SLOTS }
}

impl Trace {
    /// Sample an experimental scenario: n tasks and m node-types drawn
    /// uniformly without replacement (paper section VI-A).
    pub fn sample_scenario(&self, n: usize, m: usize, seed: u64) -> Instance {
        assert!(n <= self.tasks.len(), "scenario n exceeds trace size");
        assert!(m <= self.node_types.len(), "scenario m exceeds shape count");
        let mut rng = Rng::new(seed ^ 0x5ca1_ab1e);
        let ti = rng.sample_indices(self.tasks.len(), n);
        let bi = rng.sample_indices(self.node_types.len(), m);
        let mut types: Vec<NodeType> =
            bi.iter().map(|&i| self.node_types[i].clone()).collect();
        // Keep catalog order deterministic (sampling order is random).
        types.sort_by(|a, b| a.name.cmp(&b.name));
        let tasks: Vec<Task> = ti
            .iter()
            .enumerate()
            .map(|(new_id, &i)| self.tasks[i].with_id(new_id as u64))
            .collect();
        // Guarantee feasibility: the largest machine admits any clipped task.
        Instance::new(tasks, types, self.horizon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shape() {
        let tr = generate_trace(500, 1);
        assert_eq!(tr.tasks.len(), 500);
        assert_eq!(tr.node_types.len(), 13);
        for u in &tr.tasks {
            assert_eq!(u.dims(), 2);
            assert!(u.end < WEEK_SLOTS);
            assert!(u.peak().iter().all(|&d| (1e-3..=0.25).contains(&d)));
        }
    }

    #[test]
    fn deterministic() {
        let a = generate_trace(100, 7);
        let b = generate_trace(100, 7);
        assert_eq!(a.tasks, b.tasks);
    }

    #[test]
    fn demands_small_vs_capacity() {
        // paper: "task demands are fixed and small compared to capacities"
        let tr = generate_trace(2000, 2);
        let med_cpu = {
            let mut v: Vec<f64> = tr.tasks.iter().map(|t| t.peak()[0]).collect();
            v.sort_by(f64::total_cmp);
            v[v.len() / 2]
        };
        assert!(med_cpu < 0.05, "median cpu demand {med_cpu}");
    }

    #[test]
    fn scenario_sampling() {
        let tr = generate_trace(1000, 3);
        let inst = tr.sample_scenario(200, 10, 42);
        assert_eq!(inst.n_tasks(), 200);
        assert_eq!(inst.n_types(), 10);
        assert!(inst.is_feasible());
        // distinct seeds give distinct samples
        let inst2 = tr.sample_scenario(200, 10, 43);
        assert!(inst.tasks != inst2.tasks);
    }

    #[test]
    fn pricing_applied() {
        let tr = generate_trace(10, 1);
        for b in &tr.node_types {
            let want = pricing::GCP_CPU_RATE * b.capacity[0]
                + pricing::GCP_MEM_RATE * b.capacity[1];
            assert!((b.cost - want).abs() < 1e-12);
        }
    }
}
