//! Unified workload subsystem: every scenario generator in the system
//! behind one [`WorkloadSource`] trait, one registry of named families,
//! and one spec grammar (`<family>[:k=v,...]`) shared verbatim by the
//! CLI (`gen`/`solve --workload`), the planning service's JSON API and
//! the figure definitions — the workload-side mirror of the
//! `algo::pipeline` spec grammar.
//!
//! Registered families:
//!
//! | family     | shape                                                    |
//! |------------|----------------------------------------------------------|
//! | `synth`    | paper Table I uniform generator (section VI-A)           |
//! | `gct`      | GCT-2019-like trace scenario sampling                    |
//! | `mixed`    | random mix of the paper's motivating archetypes          |
//! | `burst`    | always-on baselines + daily peak-hour bursts             |
//! | `batch`    | nightly batch windows                                    |
//! | `deadline` | one-shot deadline jobs placed as late as possible        |
//! | `duty`     | edge fleet of duty-cycled sensors                        |
//! | `spiky`    | heavy-tailed spiky load (lognormal demand multipliers)   |
//! | `waves`    | arrival waves with lognormal durations (DVBP-like, cf.   |
//! |            | arXiv 2304.08648's arrival/departure-shaped traces)      |
//!
//! Every source is deterministic in its seed; `CostKind` pricing
//! (`cost=hom|het|gcp|fixed`, `e=<exponent>`, `coef=...`) composes onto
//! every generated family (all but `gct`, whose catalog prices via its
//! `priced` flag). Bad specs fail with an error that lists the grammar
//! and the registered families, exactly like the `--algo` parse errors.

use std::fmt::Write as _;
use std::sync::OnceLock;

use anyhow::{bail, ensure, Context, Result};

use crate::model::{CostModel, DemandSeg, Instance, Task};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::gct_like::{self, Trace, MACHINE_SHAPES};
use super::patterns::{
    draw_batch, draw_burst, draw_deadline, draw_duty, mixed_tasks, sub_range_demand,
    Pattern, Timeline, WEEK_HOURS,
};
use super::pricing;
use super::synth::{self, CostKind, SynthParams};

// ---------- the trait ----------------------------------------------------

/// A named, parameterized scenario generator. Implementations must be
/// deterministic in `seed`: two calls with the same seed yield identical
/// instances (the property tests pin this for every registered family).
pub trait WorkloadSource: Send + Sync {
    /// Canonical, re-parseable spec string for this source.
    fn label(&self) -> String;

    /// One human-readable sentence describing the generated workload.
    fn describe(&self) -> String;

    /// Generate the instance for `seed`.
    fn generate(&self, seed: u64) -> Result<Instance>;
}

// ---------- the master GCT-like trace ------------------------------------

/// Size and seed of the master GCT-2019-like trace pool (paper: ~13K
/// tasks sampled from cluster "a").
pub const MASTER_TRACE_TASKS: usize = 13_000;
pub const MASTER_TRACE_SEED: u64 = 0x6c7_2019;

/// Upper bounds on generator size parameters. Workload specs reach the
/// planning service from untrusted clients (like `--algo` specs, cf.
/// `pipeline::MAX_PORTFOLIO_SPECS`), so a few bytes of spec must never
/// demand unbounded server memory/CPU. The caps are far above any real
/// experiment (the paper's largest scenario is n=2000 over a 2016-slot
/// week).
pub const MAX_SPEC_TASKS: usize = 5_000_000;
pub const MAX_SPEC_HORIZON: u32 = 2_000_000;
pub const MAX_SPEC_DIMS: usize = 64;
pub const MAX_SPEC_TYPES: usize = 4096;

/// Master GCT-like trace, generated once per process. Every `gct` spec
/// with the default pool samples scenarios from this cached trace.
pub fn master_trace() -> &'static Trace {
    static TRACE: OnceLock<Trace> = OnceLock::new();
    TRACE.get_or_init(|| gct_like::generate_trace(MASTER_TRACE_TASKS, MASTER_TRACE_SEED))
}

// ---------- spec grammar --------------------------------------------------

/// The `--workload` / service / figure spec grammar (printed by errors).
pub const WORKLOAD_GRAMMAR: &str = "\
  workload := <family>[:<key>=<value>[,<key>=<value>|<flag>]...]
  range    := <lo>..<hi>            (e.g. dem=0.01..0.2, cap=0.3..1.0)
  cost     := hom | het | gcp | fixed   with e=<exponent>: 'hom' is the
              unit rate card, 'het' draws random coefficients, 'gcp'
              prices with the public GCE rates (io::pricing), 'fixed'
              takes explicit coef=<c0;c1;...>
  shape    := flat | ramp | diurnal | spike   (every family): reshape each
              task's demand into a piecewise-constant profile over its
              span — 'ramp' climbs to the drawn demand, 'diurnal'
              oscillates with the day period, 'spike' concentrates it in
              a short burst. The drawn demand becomes the task's *peak*;
              'flat' (the default) keeps the constant-demand model.
  csv      := csv:path=<trace.csv> imports an on-disk trace through
              io::files ('+'-prefixed rows carry extra demand segments)
              and draws a priced node-type catalog around it
  examples : synth:n=2000,dims=7    gct:n=1000,priced    spiky
             mixed:services=200,shape=diurnal    burst:day=48,services=50
             csv:path=trace.csv,m=6,cost=gcp
             synth:dims=2,cost=fixed,coef=2;1,e=0.5";

/// A parsed workload spec: family name plus key=value parameters
/// (flags carry an empty value). Canonical rendering sorts the keys, so
/// `parse(render(s)) == parse(s)` for every valid spec.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkloadSpec {
    pub family: String,
    pub params: std::collections::BTreeMap<String, String>,
}

fn workload_error(spec: &str, why: impl std::fmt::Display) -> anyhow::Error {
    let mut catalog = String::new();
    for f in families() {
        let _ = writeln!(catalog, "  {:<9} {}", f.name, f.summary);
    }
    anyhow::anyhow!(
        "invalid workload spec '{spec}': {why}\nspec grammar:\n{WORKLOAD_GRAMMAR}\n\
         registered families:\n{catalog}"
    )
}

impl WorkloadSpec {
    /// Parse `<family>[:k=v,...]`, validating the family name and its
    /// keys against the registry. Errors teach the grammar and catalog.
    pub fn parse(spec: &str) -> Result<WorkloadSpec> {
        let trimmed = spec.trim();
        if trimmed.is_empty() {
            return Err(workload_error(spec, "empty spec"));
        }
        let (family, rest) = trimmed.split_once(':').unwrap_or((trimmed, ""));
        let mut out = WorkloadSpec {
            family: family.to_string(),
            params: std::collections::BTreeMap::new(),
        };
        for tok in rest.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (k, v) = tok.split_once('=').unwrap_or((tok, ""));
            let (k, v) = (k.trim(), v.trim());
            if out.params.insert(k.to_string(), v.to_string()).is_some() {
                return Err(workload_error(spec, format!("duplicate key '{k}'")));
            }
        }
        out.validate_keys().map_err(|e| workload_error(spec, e))?;
        Ok(out)
    }

    /// Canonical spec string: family, then sorted `k=v` pairs (flags bare).
    pub fn render(&self) -> String {
        let mut out = self.family.clone();
        for (i, (k, v)) in self.params.iter().enumerate() {
            out.push(if i == 0 { ':' } else { ',' });
            out.push_str(k);
            if !v.is_empty() {
                out.push('=');
                out.push_str(v);
            }
        }
        out
    }

    /// Family metadata from the registry (errors on unknown families).
    pub fn family_info(&self) -> Result<&'static Family> {
        families()
            .iter()
            .find(|f| f.name == self.family)
            .ok_or_else(|| anyhow::anyhow!("unknown workload family '{}'", self.family))
    }

    /// Check the family exists and every key is one it accepts.
    pub fn validate_keys(&self) -> Result<()> {
        let fam = self.family_info()?;
        for k in self.params.keys() {
            if !fam.keys.iter().any(|(name, _)| name == k) {
                bail!(
                    "unknown key '{k}' for family '{}' (valid keys: {})",
                    self.family,
                    fam.keys.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
                );
            }
        }
        Ok(())
    }

    /// Build the generator this spec names (re-validates keys + values).
    /// A non-flat `shape` key wraps the family's generator in the demand
    /// reshaper ([`Shape`]): the family draws its tasks as usual, then
    /// each flat task becomes a piecewise profile whose peak is the drawn
    /// demand — so admissibility and clamping guarantees carry over.
    pub fn source(&self) -> Result<Box<dyn WorkloadSource>> {
        let rendered = self.render();
        self.validate_keys().map_err(|e| workload_error(&rendered, e))?;
        let fam = self.family_info().expect("validated above");
        let shape = Shape::parse(self.get("shape"))
            .map_err(|e| workload_error(&rendered, e))?;
        let inner = (fam.build)(self).map_err(|e| workload_error(&rendered, e))?;
        if shape == Shape::Flat {
            // bit-identical to omitting the key (no wrapper at all)
            return Ok(inner);
        }
        let day = if fam.keys.iter().any(|(k, _)| *k == "day") {
            self.u32_of("day", 24).map_err(|e| workload_error(&rendered, e))?
        } else if self.family == "gct" {
            288 // the GCT-like trace runs at 5-minute slots
        } else {
            24
        };
        Ok(Box::new(ShapedSource { inner, shape, day }))
    }

    /// Set or override one parameter (used by harness shrink hooks and
    /// the JSON form). Key/value validity is checked at `source()` time.
    pub fn set(&mut self, key: &str, value: impl Into<String>) {
        self.params.insert(key.to_string(), value.into());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.params.get(key).map(String::as_str)
    }

    // -- typed accessors (parse errors name the key and value) -----------

    /// Bare flag lookup. A flag with an explicit value is rejected:
    /// `priced=false` would otherwise silently mean `priced`.
    pub fn flag(&self, key: &str) -> Result<bool> {
        match self.get(key) {
            None => Ok(false),
            Some("") => Ok(true),
            Some(v) => bail!("key '{key}' is a flag, not a value key; drop '={v}'"),
        }
    }

    fn value_of(&self, key: &str) -> Result<Option<&str>> {
        match self.get(key) {
            None => Ok(None),
            Some("") => bail!("key '{key}' needs a value"),
            Some(v) => Ok(Some(v)),
        }
    }

    pub fn usize_of(&self, key: &str, default: usize) -> Result<usize> {
        match self.value_of(key)? {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("key '{key}': '{v}' is not a count")),
        }
    }

    pub fn u32_of(&self, key: &str, default: u32) -> Result<u32> {
        match self.value_of(key)? {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("key '{key}': '{v}' is not a count")),
        }
    }

    pub fn f64_of(&self, key: &str, default: f64) -> Result<f64> {
        match self.value_of(key)? {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("key '{key}': '{v}' is not a number")),
        }
    }

    /// `lo..hi` range values (e.g. `dem=0.01..0.2`).
    pub fn range_of(&self, key: &str, default: (f64, f64)) -> Result<(f64, f64)> {
        let Some(v) = self.value_of(key)? else { return Ok(default) };
        let parsed = v.split_once("..").and_then(|(a, b)| {
            Some((a.trim().parse::<f64>().ok()?, b.trim().parse::<f64>().ok()?))
        });
        let (lo, hi) =
            parsed.with_context(|| format!("key '{key}': '{v}' is not a <lo>..<hi> range"))?;
        ensure!(
            lo > 0.0 && hi >= lo && hi.is_finite(),
            "key '{key}': range [{lo}, {hi}] must satisfy 0 < lo <= hi"
        );
        Ok((lo, hi))
    }
}

/// Parse a workload spec and build its generator — the single entry point
/// the CLI, the service and the figure definitions share.
pub fn parse_workload(spec: &str) -> Result<Box<dyn WorkloadSource>> {
    WorkloadSpec::parse(spec)?.source()
}

// ---------- the registry --------------------------------------------------

/// One registered workload family.
pub struct Family {
    pub name: &'static str,
    /// One-line summary for the catalog listing.
    pub summary: &'static str,
    /// Accepted spec keys with one-line help each.
    pub keys: &'static [(&'static str, &'static str)],
    /// A small spec used by the tier-1 generator smoke loop.
    pub smoke_spec: &'static str,
    build: fn(&WorkloadSpec) -> Result<Box<dyn WorkloadSource>>,
}

const SIZE_KEYS: &[(&str, &str)] = &[
    ("services", "number of services expanded into tasks (default 200)"),
    ("m", "node-types in the catalog (default 6)"),
    ("dims", "resource dimensions D (default 2)"),
    ("horizon", "timeslots T (default 168)"),
    ("cap", "capacity range lo..hi (default 0.3..1.0)"),
    ("dem", "demand range lo..hi (default 0.01..0.2)"),
    ("cost", "cost model: hom | het | gcp | fixed (default hom)"),
    ("e", "cost exponent (default 1)"),
    ("coef", "fixed cost coefficients c0;c1;... (with cost=fixed)"),
];

const DAY_KEY: (&str, &str) = ("day", "slots per diurnal period (default 24)");

/// Every family accepts `shape=` — the tentpole lever: time-varying
/// demand *within* a task, as a piecewise-constant profile.
const SHAPE_KEY: (&str, &str) =
    ("shape", "demand shape: flat | ramp | diurnal | spike (default flat)");

macro_rules! pattern_keys {
    () => {
        &[
            SIZE_KEYS[0], SIZE_KEYS[1], SIZE_KEYS[2], SIZE_KEYS[3], DAY_KEY,
            SIZE_KEYS[4], SIZE_KEYS[5], SIZE_KEYS[6], SIZE_KEYS[7], SIZE_KEYS[8],
            SHAPE_KEY,
        ]
    };
}

static FAMILIES: &[Family] = &[
    Family {
        name: "synth",
        summary: "uniform synthetic benchmark (paper Table I)",
        keys: &[
            ("n", "tasks (default 1000)"),
            ("m", "node-types (default 10)"),
            ("dims", "resource dimensions D (default 5)"),
            ("horizon", "timeslots T (default 24)"),
            ("cap", "capacity range lo..hi (default 0.2..1.0)"),
            ("dem", "demand range lo..hi (default 0.01..0.1)"),
            ("cost", "cost model: hom | het | gcp | fixed (default hom)"),
            ("e", "cost exponent (default 1)"),
            ("coef", "fixed cost coefficients c0;c1;... (with cost=fixed)"),
            SHAPE_KEY,
        ],
        smoke_spec: "synth:n=80,m=4",
        build: build_synth,
    },
    Family {
        name: "gct",
        summary: "GCT-2019-like trace scenario (n tasks, m machine shapes)",
        keys: &[
            ("n", "tasks sampled from the trace pool (default 1000)"),
            ("m", "machine shapes sampled, <= 13 (default 10)"),
            ("pool", "trace pool size (default 13000, the cached master trace)"),
            ("priced", "flag: keep GCE rate-card costs instead of homogeneous"),
            SHAPE_KEY,
        ],
        smoke_spec: "gct:n=80,m=5,pool=400",
        build: build_gct,
    },
    Family {
        name: "mixed",
        summary: "random mix of the paper's five motivating archetypes",
        keys: pattern_keys!(),
        smoke_spec: "mixed:services=25,m=3",
        build: |s| build_pattern(s, PatternFamily::Mixed),
    },
    Family {
        name: "burst",
        summary: "always-on baselines plus daily peak-hour bursts",
        keys: pattern_keys!(),
        smoke_spec: "burst:services=20,m=3",
        build: |s| build_pattern(s, PatternFamily::Burst),
    },
    Family {
        name: "batch",
        summary: "nightly batch windows",
        keys: pattern_keys!(),
        smoke_spec: "batch:services=30,m=3",
        build: |s| build_pattern(s, PatternFamily::Batch),
    },
    Family {
        name: "deadline",
        summary: "one-shot deadline jobs placed as late as possible",
        keys: pattern_keys!(),
        smoke_spec: "deadline:services=40,m=3",
        build: |s| build_pattern(s, PatternFamily::Deadline),
    },
    Family {
        name: "duty",
        summary: "edge fleet of duty-cycled sensors",
        keys: pattern_keys!(),
        smoke_spec: "duty:services=25,m=3",
        build: |s| build_pattern(s, PatternFamily::Duty),
    },
    Family {
        name: "spiky",
        summary: "heavy-tailed spiky load (lognormal demand multipliers)",
        keys: &[
            SIZE_KEYS[0], SIZE_KEYS[1], SIZE_KEYS[2], SIZE_KEYS[3],
            SIZE_KEYS[4], SIZE_KEYS[5], SIZE_KEYS[6], SIZE_KEYS[7], SIZE_KEYS[8],
            SHAPE_KEY,
        ],
        smoke_spec: "spiky:services=60,m=4",
        build: |s| build_pattern(s, PatternFamily::Spiky),
    },
    Family {
        name: "waves",
        summary: "arrival waves with lognormal durations (DVBP-like)",
        keys: &[
            SIZE_KEYS[0], SIZE_KEYS[1], SIZE_KEYS[2], SIZE_KEYS[3],
            ("waves", "number of arrival waves (default 8)"),
            SIZE_KEYS[4], SIZE_KEYS[5], SIZE_KEYS[6], SIZE_KEYS[7], SIZE_KEYS[8],
            SHAPE_KEY,
        ],
        smoke_spec: "waves:services=60,m=4",
        build: |s| build_pattern(s, PatternFamily::Waves),
    },
    Family {
        name: "csv",
        summary: "import an on-disk CSV trace (io::files format) as a workload",
        keys: &[
            ("path", "path to the trace CSV (required; io::files format)"),
            ("m", "node-types drawn around the trace (default 6)"),
            ("cap", "capacity range lo..hi (default 0.3..1.0)"),
            ("horizon", "timeline override (default: last task end + 1)"),
            ("cost", "cost model: hom | het | gcp | fixed (default hom)"),
            ("e", "cost exponent (default 1)"),
            ("coef", "fixed cost coefficients c0;c1;... (with cost=fixed)"),
            SHAPE_KEY,
        ],
        smoke_spec: "csv:path=target/tlrs-smoke-trace.csv",
        build: build_csv,
    },
];

/// All registered workload families, in catalog order.
pub fn families() -> &'static [Family] {
    FAMILIES
}

// ---------- cost composition ---------------------------------------------

/// Parse the `cost`/`e`/`coef` keys shared by every family into a
/// [`CostKind`].
fn cost_kind(spec: &WorkloadSpec, dims: usize) -> Result<CostKind> {
    let e = spec.f64_of("e", 1.0)?;
    ensure!(e > 0.0 && e.is_finite(), "key 'e': exponent must be positive");
    let cost = spec.get("cost").unwrap_or("hom");
    ensure!(
        cost == "fixed" || spec.get("coef").is_none(),
        "key 'coef' needs cost=fixed"
    );
    Ok(match cost {
        // lint:allow(float-ord): e == 1.0 detects the literal default exponent
        // written by the spec author; 1.0 is exactly representable.
        "hom" if e == 1.0 => CostKind::HomogeneousLinear,
        // unit coefficients with a non-unit exponent: still "homogeneous",
        // but needs the general fixed form
        "hom" => CostKind::Fixed { coefficients: vec![1.0; dims], exponent: e },
        "het" => CostKind::HeterogeneousRandom { exponent: e },
        "gcp" => CostKind::Fixed { coefficients: pricing::gcp_coefficients(dims), exponent: e },
        "fixed" => {
            let raw = match spec.value_of("coef")? {
                Some(v) => v,
                None => bail!("cost=fixed needs coef=<c0;c1;...> (one per dimension)"),
            };
            let coefficients: Vec<f64> = raw
                .split(';')
                .map(|t| t.trim().parse::<f64>())
                .collect::<Result<_, _>>()
                .map_err(|_| {
                    anyhow::anyhow!("key 'coef': '{raw}' is not a ';'-separated number list")
                })?;
            ensure!(
                coefficients.len() == dims,
                "key 'coef': {} coefficients for dims={dims}",
                coefficients.len()
            );
            ensure!(
                coefficients.iter().all(|&c| c > 0.0 && c.is_finite()),
                "key 'coef': coefficients must be positive"
            );
            CostKind::Fixed { coefficients, exponent: e }
        }
        other => bail!("key 'cost': '{other}' is not hom, het, gcp or fixed"),
    })
}

// ---------- synth family --------------------------------------------------

struct SynthSource {
    spec: WorkloadSpec,
    params: SynthParams,
}

impl WorkloadSource for SynthSource {
    fn label(&self) -> String {
        self.spec.render()
    }

    fn describe(&self) -> String {
        let p = &self.params;
        format!(
            "uniform synthetic benchmark: {} tasks over {} slots, {} node-types, D={}",
            p.n, p.horizon, p.m, p.dims
        )
    }

    fn generate(&self, seed: u64) -> Result<Instance> {
        Ok(synth::generate(&self.params, seed))
    }
}

fn build_synth(spec: &WorkloadSpec) -> Result<Box<dyn WorkloadSource>> {
    let mut p = SynthParams::default();
    p.n = spec.usize_of("n", p.n)?;
    p.m = spec.usize_of("m", p.m)?;
    p.dims = spec.usize_of("dims", p.dims)?;
    p.horizon = spec.u32_of("horizon", p.horizon)?;
    p.cap_range = spec.range_of("cap", p.cap_range)?;
    p.dem_range = spec.range_of("dem", p.dem_range)?;
    p.cost_model = cost_kind(spec, p.dims)?;
    validate_synth_params(&p)?;
    Ok(Box::new(SynthSource { spec: spec.clone(), params: p }))
}

/// Shared validation for [`SynthParams`] regardless of entry form (spec
/// string, JSON object, `TraceKind` shim) — untrusted parameters must
/// hit the same caps and sanity checks on every path.
pub fn validate_synth_params(p: &SynthParams) -> Result<()> {
    ensure!(
        (1..=MAX_SPEC_TASKS).contains(&p.n),
        "n must be in 1..={MAX_SPEC_TASKS}"
    );
    ensure!(
        (1..=MAX_SPEC_TYPES).contains(&p.m),
        "m must be in 1..={MAX_SPEC_TYPES}"
    );
    ensure!(
        (1..=MAX_SPEC_DIMS).contains(&p.dims),
        "dims must be in 1..={MAX_SPEC_DIMS}"
    );
    ensure!(
        (1..=MAX_SPEC_HORIZON).contains(&p.horizon),
        "horizon must be in 1..={MAX_SPEC_HORIZON}"
    );
    let (clo, chi) = p.cap_range;
    ensure!(
        clo > 0.0 && chi >= clo && chi <= 1.0,
        "cap range [{clo}, {chi}] must satisfy 0 < lo <= hi <= 1"
    );
    let (dlo, dhi) = p.dem_range;
    ensure!(
        dlo > 0.0 && dhi >= dlo && dhi.is_finite(),
        "demand range [{dlo}, {dhi}] must satisfy 0 < lo <= hi"
    );
    match &p.cost_model {
        CostKind::HomogeneousLinear => {}
        CostKind::HeterogeneousRandom { exponent } => {
            ensure!(
                *exponent > 0.0 && exponent.is_finite(),
                "cost exponent must be positive"
            );
        }
        CostKind::Fixed { coefficients, exponent } => {
            ensure!(
                *exponent > 0.0 && exponent.is_finite(),
                "cost exponent must be positive"
            );
            ensure!(
                coefficients.len() == p.dims,
                "{} cost coefficients for dims={}",
                coefficients.len(),
                p.dims
            );
            ensure!(
                coefficients.iter().all(|&c| c > 0.0 && c.is_finite()),
                "cost coefficients must be positive"
            );
        }
    }
    Ok(())
}

/// Canonical spec for explicit [`SynthParams`] (the `TraceKind` shim and
/// the JSON form use this for labels). Only non-default keys render.
pub fn spec_of_synth(p: &SynthParams) -> WorkloadSpec {
    let d = SynthParams::default();
    let mut spec = WorkloadSpec {
        family: "synth".into(),
        params: std::collections::BTreeMap::new(),
    };
    if p.n != d.n {
        spec.set("n", p.n.to_string());
    }
    if p.m != d.m {
        spec.set("m", p.m.to_string());
    }
    if p.dims != d.dims {
        spec.set("dims", p.dims.to_string());
    }
    if p.horizon != d.horizon {
        spec.set("horizon", p.horizon.to_string());
    }
    if p.cap_range != d.cap_range {
        spec.set("cap", format!("{}..{}", p.cap_range.0, p.cap_range.1));
    }
    if p.dem_range != d.dem_range {
        spec.set("dem", format!("{}..{}", p.dem_range.0, p.dem_range.1));
    }
    match &p.cost_model {
        CostKind::HomogeneousLinear => {}
        CostKind::HeterogeneousRandom { exponent } => {
            spec.set("cost", "het");
            // lint:allow(float-ord): round-trip spec printing — only the exact
            // default 1.0 may be omitted; any other value must be serialized.
            if *exponent != 1.0 {
                spec.set("e", exponent.to_string());
            }
        }
        CostKind::Fixed { coefficients, exponent } => {
            if coefficients == &pricing::gcp_coefficients(p.dims) {
                spec.set("cost", "gcp");
            // lint:allow(float-ord): all-ones coefficient detection for the
            // compact spec form; 1.0 is exactly representable.
            } else if coefficients.iter().all(|&c| c == 1.0) {
                spec.set("cost", "hom");
            } else {
                spec.set("cost", "fixed");
                spec.set(
                    "coef",
                    coefficients
                        .iter()
                        .map(f64::to_string)
                        .collect::<Vec<_>>()
                        .join(";"),
                );
            }
            // lint:allow(float-ord): round-trip spec printing — only the exact
            // default 1.0 may be omitted; any other value must be serialized.
            if *exponent != 1.0 {
                spec.set("e", exponent.to_string());
            }
        }
    }
    spec
}

/// Parse the JSON-object form of a synth workload (the service's
/// `"workload": {...}` and the config-layer scenario overrides). Starts
/// from Table I defaults; unknown keys are errors, and `"cost_model":
/// "fixed"` takes an explicit `"coefficients"` array.
pub fn synth_params_from_json(v: &Json) -> Result<SynthParams> {
    let obj = v.as_obj().context("synth workload JSON must be an object")?;
    const KNOWN: &[&str] = &[
        "family", "n", "m", "dims", "horizon", "dem_range", "cap_range",
        "cost_model", "exponent", "coefficients",
    ];
    for k in obj.keys() {
        if !KNOWN.contains(&k.as_str()) {
            bail!(
                "unknown key '{k}' in synth workload JSON (known keys: {})",
                KNOWN.join(", ")
            );
        }
    }
    if let Some(fam) = v.get("family").as_str() {
        ensure!(fam == "synth", "synth workload JSON with family '{fam}'");
    }
    let mut p = SynthParams::default();
    if let Some(n) = v.get("n").as_usize() {
        p.n = n;
    }
    if let Some(m) = v.get("m").as_usize() {
        p.m = m;
    }
    if let Some(d) = v.get("dims").as_usize() {
        p.dims = d;
    }
    if let Some(t) = v.get("horizon").as_usize() {
        p.horizon = t as u32;
    }
    if let Some(r) = v.get("dem_range").to_f64_vec() {
        ensure!(r.len() == 2, "dem_range needs two entries");
        p.dem_range = (r[0], r[1]);
    }
    if let Some(r) = v.get("cap_range").to_f64_vec() {
        ensure!(r.len() == 2, "cap_range needs two entries");
        p.cap_range = (r[0], r[1]);
    }
    let exponent = v.get("exponent").as_f64();
    match v.get("cost_model").as_str() {
        None | Some("homogeneous") => {
            ensure!(
                exponent.is_none() || exponent == Some(1.0),
                "'exponent' needs cost_model 'heterogeneous' or 'fixed'"
            );
            ensure!(
                matches!(v.get("coefficients"), Json::Null),
                "'coefficients' needs cost_model 'fixed'"
            );
        }
        Some("heterogeneous") => {
            p.cost_model =
                CostKind::HeterogeneousRandom { exponent: exponent.unwrap_or(1.0) };
        }
        Some("fixed") => {
            let coefficients = v
                .get("coefficients")
                .to_f64_vec()
                .context("cost_model 'fixed' needs a 'coefficients' array")?;
            ensure!(
                coefficients.len() == p.dims,
                "coefficients has {} entries for dims={}",
                coefficients.len(),
                p.dims
            );
            ensure!(
                coefficients.iter().all(|&c| c > 0.0 && c.is_finite()),
                "coefficients must be positive"
            );
            p.cost_model =
                CostKind::Fixed { coefficients, exponent: exponent.unwrap_or(1.0) };
        }
        Some(other) => bail!("unknown cost_model '{other}'"),
    }
    validate_synth_params(&p)?;
    Ok(p)
}

// ---------- gct family ----------------------------------------------------

struct GctSource {
    spec: WorkloadSpec,
    n: usize,
    m: usize,
    pool: usize,
    priced: bool,
    /// Lazily generated non-default pool (the trace depends only on the
    /// pool size, so multi-seed scenario sampling reuses it).
    pool_trace: OnceLock<Trace>,
}

impl GctSource {
    fn trace(&self) -> &Trace {
        if self.pool == MASTER_TRACE_TASKS {
            master_trace()
        } else {
            self.pool_trace
                .get_or_init(|| gct_like::generate_trace(self.pool, MASTER_TRACE_SEED))
        }
    }
}

impl WorkloadSource for GctSource {
    fn label(&self) -> String {
        self.spec.render()
    }

    fn describe(&self) -> String {
        format!(
            "GCT-2019-like scenario: {} tasks and {} machine shapes sampled from a \
             {}-task trace pool ({} pricing)",
            self.n,
            self.m,
            self.pool,
            if self.priced { "GCE rate-card" } else { "homogeneous" }
        )
    }

    fn generate(&self, seed: u64) -> Result<Instance> {
        let mut inst = self.trace().sample_scenario(self.n, self.m, seed);
        if !self.priced {
            // homogeneous-linear experiments re-price cap-sum = cost
            CostModel::homogeneous(inst.dims()).apply(&mut inst.node_types);
        }
        Ok(inst)
    }
}

fn build_gct(spec: &WorkloadSpec) -> Result<Box<dyn WorkloadSource>> {
    let n = spec.usize_of("n", 1000)?;
    let m = spec.usize_of("m", 10)?;
    let pool = spec.usize_of("pool", MASTER_TRACE_TASKS)?;
    ensure!(
        (1..=MAX_SPEC_TASKS).contains(&pool),
        "key 'pool': need 1..={MAX_SPEC_TASKS} trace tasks"
    );
    ensure!(n >= 1, "key 'n': need at least one task");
    ensure!(
        n <= pool,
        "key 'n': scenario n={n} exceeds the {pool}-task trace pool"
    );
    ensure!(
        (1..=MACHINE_SHAPES.len()).contains(&m),
        "key 'm': the GCT-like trace has {} machine shapes",
        MACHINE_SHAPES.len()
    );
    Ok(Box::new(GctSource {
        spec: spec.clone(),
        n,
        m,
        pool,
        priced: spec.flag("priced")?,
        pool_trace: OnceLock::new(),
    }))
}

// ---------- pattern families ----------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum PatternFamily {
    Mixed,
    Burst,
    Batch,
    Deadline,
    Duty,
    Spiky,
    Waves,
}

/// Shared parameters of the pattern-backed families.
#[derive(Clone, Debug)]
struct PatternParams {
    services: usize,
    m: usize,
    dims: usize,
    horizon: u32,
    day: u32,
    waves: usize,
    cap_range: (f64, f64),
    dem_range: (f64, f64),
    cost: CostKind,
}

struct PatternSource {
    spec: WorkloadSpec,
    family: PatternFamily,
    name: &'static str,
    params: PatternParams,
}

fn build_pattern(spec: &WorkloadSpec, family: PatternFamily) -> Result<Box<dyn WorkloadSource>> {
    let dims = spec.usize_of("dims", 2)?;
    ensure!(
        (1..=MAX_SPEC_DIMS).contains(&dims),
        "key 'dims': need 1..={MAX_SPEC_DIMS} dimensions"
    );
    let p = PatternParams {
        services: spec.usize_of("services", 200)?,
        m: spec.usize_of("m", 6)?,
        dims,
        horizon: spec.u32_of("horizon", WEEK_HOURS)?,
        day: spec.u32_of("day", 24)?,
        waves: spec.usize_of("waves", 8)?,
        cap_range: spec.range_of("cap", (0.3, 1.0))?,
        dem_range: spec.range_of("dem", (0.01, 0.2))?,
        cost: cost_kind(spec, dims)?,
    };
    ensure!(p.services >= 1, "key 'services': need at least one service");
    ensure!(
        (1..=MAX_SPEC_TYPES).contains(&p.m),
        "key 'm': need 1..={MAX_SPEC_TYPES} node-types"
    );
    ensure!(
        (1..=MAX_SPEC_HORIZON).contains(&p.horizon),
        "key 'horizon': need 1..={MAX_SPEC_HORIZON} timeslots"
    );
    ensure!(p.cap_range.1 <= 1.0, "key 'cap': capacities are normalized to (0, 1]");
    ensure!(p.waves >= 1, "key 'waves': need at least one wave");
    // surfaces bad horizon/day combinations at parse time
    Timeline::new(p.horizon, p.day)?;
    // worst-case expansion bound: an untrusted few-byte spec must not
    // demand unbounded generation work (duty/mixed expand each service
    // into up to horizon/2 tasks, daily patterns into one per day)
    let days = (p.horizon / p.day.max(1)) as usize + 2;
    let est_tasks = match family {
        PatternFamily::Spiky | PatternFamily::Waves | PatternFamily::Deadline => p.services,
        PatternFamily::Batch | PatternFamily::Burst => p.services.saturating_mul(days),
        PatternFamily::Mixed | PatternFamily::Duty => {
            p.services.saturating_mul(((p.horizon as usize) / 2).max(days))
        }
    };
    ensure!(
        est_tasks <= MAX_SPEC_TASKS,
        "spec would expand to ~{est_tasks} tasks (cap {MAX_SPEC_TASKS}); \
         lower services/horizon"
    );
    let name = spec.family_info().expect("registered family").name;
    Ok(Box::new(PatternSource { spec: spec.clone(), family, name, params: p }))
}

impl WorkloadSource for PatternSource {
    fn label(&self) -> String {
        self.spec.render()
    }

    fn describe(&self) -> String {
        let p = &self.params;
        let shape = match self.family {
            PatternFamily::Mixed => "a random mix of the five archetypes",
            PatternFamily::Burst => "baseline + daily peak-hour burst services",
            PatternFamily::Batch => "nightly batch windows",
            PatternFamily::Deadline => "one-shot deadline jobs",
            PatternFamily::Duty => "duty-cycled sensors",
            PatternFamily::Spiky => "heavy-tailed spiky tasks",
            PatternFamily::Waves => "tasks arriving in waves",
        };
        format!(
            "{} services of {shape} over {} slots ({} per day), {} node-types, D={}",
            p.services, p.horizon, p.day, p.m, p.dims
        )
    }

    fn generate(&self, seed: u64) -> Result<Instance> {
        let p = &self.params;
        let mut rng = Rng::new(seed);
        let d = p.dims;

        // catalog drawn exactly like synth's (shared helpers: capacities
        // first, then the heterogeneous coefficients from the same
        // stream); clamping against the anchor keeps every task
        // admissible somewhere
        let mut node_types =
            synth::draw_capacities(&mut rng, p.m, d, p.cap_range, self.name);
        synth::price_catalog(&mut rng, &mut node_types, d, &p.cost);
        let anchor_cap = node_types[synth::anchor_index(&node_types)].capacity.clone();

        let tl = Timeline::new(p.horizon, p.day)?;
        let mut tasks = match self.family {
            PatternFamily::Mixed => {
                mixed_tasks(p.services, d, tl, p.dem_range, &mut rng)?
            }
            PatternFamily::Burst
            | PatternFamily::Batch
            | PatternFamily::Deadline
            | PatternFamily::Duty => archetype_tasks(self.family, p, tl, &mut rng)?,
            PatternFamily::Spiky => spiky_tasks(p, &mut rng),
            PatternFamily::Waves => wave_tasks(p, &mut rng),
        };
        ensure!(
            !tasks.is_empty(),
            "workload '{}' expanded to zero tasks on this timeline/seed — \
             the horizon ({} slots, {}-slot days) is too short for its \
             patterns; raise horizon or lower day",
            self.spec.render(),
            p.horizon,
            p.day
        );
        for t in &mut tasks {
            t.clamp_demand(&anchor_cap);
        }
        Ok(Instance::new(tasks, node_types, p.horizon))
    }
}

/// Single-archetype families: every service expands one pattern (plus a
/// light baseline for `burst`, which models a peak over an always-on
/// service rather than a bare burst). Shape draws and demand sub-ranges
/// are the shared `io::patterns` helpers, so these families and the
/// `mixed` family can never disagree about what an archetype looks like.
fn archetype_tasks(
    family: PatternFamily,
    p: &PatternParams,
    tl: Timeline,
    rng: &mut Rng,
) -> Result<Vec<Task>> {
    let mut next_id = 0u64;
    let mut tasks = Vec::new();
    for _ in 0..p.services {
        let pattern = match family {
            PatternFamily::Burst => {
                let base = Pattern::Baseline {
                    demand: sub_range_demand(rng, p.dims, p.dem_range, 0.0, 0.25),
                };
                tasks.extend(base.expand(tl, &mut next_id)?);
                draw_burst(rng, sub_range_demand(rng, p.dims, p.dem_range, 0.2, 1.0), tl)
            }
            PatternFamily::Batch => {
                draw_batch(rng, sub_range_demand(rng, p.dims, p.dem_range, 0.5, 1.0), tl)
            }
            PatternFamily::Deadline => {
                draw_deadline(rng, sub_range_demand(rng, p.dims, p.dem_range, 0.2, 1.0), tl)
            }
            PatternFamily::Duty => {
                draw_duty(rng, sub_range_demand(rng, p.dims, p.dem_range, 0.0, 0.5), tl)
            }
            _ => unreachable!("archetype_tasks only handles single-pattern families"),
        };
        tasks.extend(pattern.expand(tl, &mut next_id)?);
    }
    Ok(tasks)
}

/// Heavy-tailed spiky load: short tasks whose demand is a lognormal
/// multiple of the configured range, so a few tasks dominate — the load
/// shape flash crowds and tail-heavy batch queues produce.
fn spiky_tasks(p: &PatternParams, rng: &mut Rng) -> Vec<Task> {
    let horizon = p.horizon as u64;
    (0..p.services as u64)
        .map(|id| {
            let base = sub_range_demand(rng, p.dims, p.dem_range, 0.0, 1.0);
            // multiplier median 1, sigma 1 => ~8x spikes in the tail
            let mult = rng.lognormal(0.0, 1.0).clamp(0.25, 8.0);
            let dem: Vec<f64> = base.iter().map(|&x| (x * mult).min(0.95)).collect();
            let dur = rng
                .lognormal(((horizon as f64 / 16.0).max(1.0)).ln(), 1.0)
                .clamp(1.0, horizon as f64) as u64;
            let start = rng.below((horizon + 1 - dur).max(1));
            Task::new(id, dem, start as u32, (start + dur - 1) as u32)
        })
        .collect()
}

/// DVBP-like arrival waves: task starts cluster around wave centers with
/// lognormal durations, producing the arrival/departure-shaped traces
/// dynamic vector bin packing evaluates on (arXiv 2304.08648).
fn wave_tasks(p: &PatternParams, rng: &mut Rng) -> Vec<Task> {
    let horizon = p.horizon as f64;
    let k = p.waves as f64;
    (0..p.services as u64)
        .map(|id| {
            let dem = sub_range_demand(rng, p.dims, p.dem_range, 0.0, 1.0);
            let wave = rng.below(p.waves as u64) as f64;
            let center = (wave + 0.5) * horizon / k;
            let jitter = rng.normal() * horizon / (4.0 * k);
            let start = (center + jitter).clamp(0.0, horizon - 1.0) as u64;
            let dur = rng
                .lognormal((horizon / 10.0).max(1.0).ln(), 0.8)
                .clamp(1.0, horizon) as u64;
            let end = (start + dur - 1).min(p.horizon as u64 - 1);
            Task::new(id, dem, start as u32, end as u32)
        })
        .collect()
}

// ---------- demand shapes (tentpole: time-varying demand per task) --------

/// How a family's drawn (flat) demand is reshaped into a piecewise
/// profile over each task's span. The drawn demand always becomes the
/// task's *peak* (some window keeps the exact vector), so the families'
/// admissibility/clamping guarantees hold unchanged for shaped tasks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shape {
    /// Constant demand — the pre-profile model; applying it is a no-op.
    Flat,
    /// Demand climbs in up to four steps from a fraction of the drawn
    /// vector to the full vector (fan-out ramps, warming caches).
    Ramp,
    /// Full demand during each day's peak-hour window, a drawn off-peak
    /// fraction otherwise (the paper's business-hours motivation).
    Diurnal,
    /// Full demand over one short burst window, a drawn low fraction
    /// elsewhere (flash crowds over an always-on baseline).
    Spike,
}

impl Shape {
    /// Parse the `shape=` spec value (`None` means flat).
    pub fn parse(value: Option<&str>) -> Result<Shape> {
        Ok(match value {
            None | Some("flat") => Shape::Flat,
            Some("ramp") => Shape::Ramp,
            Some("diurnal") => Shape::Diurnal,
            Some("spike") => Shape::Spike,
            Some("") => bail!("key 'shape' needs a value"),
            Some(other) => {
                bail!("key 'shape': '{other}' is not flat, ramp, diurnal or spike")
            }
        })
    }

    fn name(&self) -> &'static str {
        match self {
            Shape::Flat => "flat",
            Shape::Ramp => "ramp",
            Shape::Diurnal => "diurnal",
            Shape::Spike => "spike",
        }
    }
}

/// Salt separating the shape RNG stream from the family's draw stream.
const SHAPE_SALT: u64 = 0x5a4d_e11e_5eed;

/// Wraps any family's generator and reshapes its tasks' demand. The
/// underlying family is untouched (same catalog, same spans, same drawn
/// peaks) — only the within-task load profile changes.
struct ShapedSource {
    inner: Box<dyn WorkloadSource>,
    shape: Shape,
    day: u32,
}

impl WorkloadSource for ShapedSource {
    fn label(&self) -> String {
        // the inner source's spec already carries the shape key
        self.inner.label()
    }

    fn describe(&self) -> String {
        format!("{} — {} demand shape", self.inner.describe(), self.shape.name())
    }

    fn generate(&self, seed: u64) -> Result<Instance> {
        let inst = self.inner.generate(seed)?;
        let tasks: Vec<Task> = inst
            .tasks
            .into_iter()
            .map(|t| shape_task(t, self.shape, self.day, seed))
            .collect();
        Ok(Instance::new(tasks, inst.node_types, inst.horizon))
    }
}

/// Reshape one flat task. Deterministic in (seed, task id) — independent
/// of task order — and the identity on single-slot or already-shaped
/// tasks. Every multiplier lies in (0, 1] and at least one window uses
/// exactly 1.0, so the reshaped peak *is* the drawn demand vector.
fn shape_task(t: Task, shape: Shape, day: u32, seed: u64) -> Task {
    let span = t.span_len() as u64;
    if span < 2 || !t.is_flat() || shape == Shape::Flat {
        return t;
    }
    let base = t.peak().to_vec();
    let mut rng = Rng::new(seed ^ SHAPE_SALT ^ t.id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    // (inclusive window, multiplier) list covering [t.start, t.end]
    let windows: Vec<(u32, u32, f64)> = match shape {
        Shape::Flat => unreachable!("handled above"),
        Shape::Ramp => {
            let k = span.min(4);
            let low = rng.uniform(0.3, 0.7);
            (0..k)
                .map(|i| {
                    let s = t.start + (span * i / k) as u32;
                    let e = t.start + (span * (i + 1) / k) as u32 - 1;
                    let mult = if i + 1 == k {
                        1.0 // the final step is exactly the drawn demand
                    } else {
                        low + (1.0 - low) * i as f64 / (k - 1) as f64
                    };
                    (s, e, mult)
                })
                .collect()
        }
        Shape::Diurnal => {
            // peak window [day/3, 2*day/3) within each day; days shorter
            // than 3 slots cannot express a within-day shape
            if day < 3 {
                return t;
            }
            let (ps, pe) = (day / 3, 2 * day / 3);
            let in_peak = |slot: u32| {
                let h = slot % day;
                h >= ps && h < pe
            };
            if !(t.start..=t.end).any(in_peak) {
                return t; // span misses every peak window: stays flat
            }
            let off = rng.uniform(0.3, 0.6);
            let mut out: Vec<(u32, u32, f64)> = Vec::new();
            for slot in t.start..=t.end {
                let mult = if in_peak(slot) { 1.0 } else { off };
                match out.last_mut() {
                    Some((_, e, m)) if *m == mult && *e + 1 == slot => *e = slot,
                    _ => out.push((slot, slot, mult)),
                }
            }
            out
        }
        Shape::Spike => {
            let burst = (span / 8).max(1);
            let start = t.start + rng.below(span - burst + 1) as u32;
            let end = start + burst as u32 - 1;
            let low = rng.uniform(0.2, 0.5);
            let mut out = Vec::new();
            if start > t.start {
                out.push((t.start, start - 1, low));
            }
            out.push((start, end, 1.0));
            if end < t.end {
                out.push((end + 1, t.end, low));
            }
            out
        }
    };
    let segs: Vec<DemandSeg> = windows
        .into_iter()
        .map(|(s, e, mult)| DemandSeg {
            start: s,
            end: e,
            // mult == 1.0 reproduces the drawn vector bit-exactly
            // lint:allow(float-ord): multiplier 1.0 marks an untouched window in
            // the generator — an exact sentinel, never a computed value.
            demand: if mult == 1.0 {
                base.clone()
            } else {
                base.iter().map(|&x| x * mult).collect()
            },
        })
        .collect();
    Task::piecewise(t.id, segs)
}

// ---------- csv import family ---------------------------------------------

/// Trace import (ROADMAP Scenarios lever): an on-disk CSV trace becomes a
/// first-class workload. The tasks come verbatim from the file (including
/// piecewise `+` continuation rows); the node-type catalog is drawn like
/// synth's from `cap`/`cost` (deterministic in the seed), with the anchor
/// type's capacity raised to the trace's per-dimension peak so every
/// imported task is admissible.
struct CsvSource {
    spec: WorkloadSpec,
    path: String,
    m: usize,
    cap_range: (f64, f64),
    horizon_override: Option<u32>,
}

impl WorkloadSource for CsvSource {
    fn label(&self) -> String {
        self.spec.render()
    }

    fn describe(&self) -> String {
        format!(
            "CSV trace import from '{}' with {} drawn node-types",
            self.path, self.m
        )
    }

    fn generate(&self, seed: u64) -> Result<Instance> {
        let tasks = crate::io::files::load_trace_csv(std::path::Path::new(&self.path))
            .with_context(|| format!("key 'path': loading trace '{}'", self.path))?;
        ensure!(!tasks.is_empty(), "trace '{}' has no tasks", self.path);
        ensure!(
            tasks.len() <= MAX_SPEC_TASKS,
            "trace '{}' has {} tasks (cap {MAX_SPEC_TASKS})",
            self.path,
            tasks.len()
        );
        let dims = tasks[0].dims();
        ensure!(
            (1..=MAX_SPEC_DIMS).contains(&dims),
            "trace '{}': need 1..={MAX_SPEC_DIMS} dimensions",
            self.path
        );
        for u in &tasks {
            ensure!(
                u.dims() == dims,
                "trace '{}': task {} has {} dims, expected {dims}",
                self.path,
                u.id,
                u.dims()
            );
        }
        let last_end = tasks.iter().map(|u| u.end).max().expect("non-empty");
        let horizon = match self.horizon_override {
            Some(h) => {
                ensure!(
                    h > last_end,
                    "key 'horizon': {h} does not cover the trace (last end {last_end})"
                );
                h
            }
            // the loader guarantees end < u32::MAX, so this cannot wrap
            None => last_end
                .checked_add(1)
                .context("trace end out of range")?,
        };
        ensure!(
            horizon <= MAX_SPEC_HORIZON,
            "trace horizon {horizon} exceeds the {MAX_SPEC_HORIZON}-slot cap"
        );
        // per-dimension peak over the trace: the anchor type must admit it
        let mut need = vec![0.0f64; dims];
        for u in &tasks {
            for (nd, &p) in need.iter_mut().zip(u.peak()) {
                *nd = nd.max(p);
            }
        }
        ensure!(
            need.iter().all(|&x| x > 0.0 && x <= 1.0),
            "trace demands must lie in (0, 1] (capacities are normalized); \
             per-dimension peaks {need:?}"
        );

        // catalog drawn with the shared synth helpers; the anchor
        // (largest weakest-dimension type) is raised to the trace peak
        // *before* pricing, so costs reflect the real capacity and the
        // import is always feasible
        let mut rng = Rng::new(seed);
        let mut node_types =
            synth::draw_capacities(&mut rng, self.m, dims, self.cap_range, "csv");
        let anchor = synth::anchor_index(&node_types);
        for (c, &nd) in node_types[anchor].capacity.iter_mut().zip(&need) {
            *c = c.max(nd);
        }
        let cost = cost_kind(&self.spec, dims)?;
        synth::price_catalog(&mut rng, &mut node_types, dims, &cost);
        Ok(Instance::new(tasks, node_types, horizon))
    }
}

fn build_csv(spec: &WorkloadSpec) -> Result<Box<dyn WorkloadSource>> {
    let path = match spec.get("path") {
        Some(p) if !p.is_empty() => p.to_string(),
        _ => bail!("the csv family needs path=<trace.csv>"),
    };
    let m = spec.usize_of("m", 6)?;
    ensure!(
        (1..=MAX_SPEC_TYPES).contains(&m),
        "key 'm': need 1..={MAX_SPEC_TYPES} node-types"
    );
    let cap_range = spec.range_of("cap", (0.3, 1.0))?;
    ensure!(cap_range.1 <= 1.0, "key 'cap': capacities are normalized to (0, 1]");
    let horizon_override = match spec.get("horizon") {
        None => None,
        Some(_) => {
            let h = spec.u32_of("horizon", 0)?;
            ensure!(
                (1..=MAX_SPEC_HORIZON).contains(&h),
                "key 'horizon': need 1..={MAX_SPEC_HORIZON} timeslots"
            );
            Some(h)
        }
    };
    // cost/e/coef syntax is validated here (arity against the file's
    // dimensionality only at generate time, when the file is read)
    if let Some(c) = spec.get("cost") {
        ensure!(
            matches!(c, "hom" | "het" | "gcp" | "fixed"),
            "key 'cost': '{c}' is not hom, het, gcp or fixed"
        );
    }
    Ok(Box::new(CsvSource {
        spec: spec.clone(),
        path,
        m,
        cap_range,
        horizon_override,
    }))
}

/// Write the deterministic fixture trace the `csv` family's smoke spec
/// points at (`target/tlrs-smoke-trace.csv`, relative to the crate root
/// both `cargo test` and `scripts/tier1.sh` run from). Tests call this
/// before exercising the smoke spec; returns the path.
pub fn csv_smoke_fixture() -> &'static str {
    const PATH: &str = "target/tlrs-smoke-trace.csv";
    static WRITTEN: OnceLock<()> = OnceLock::new();
    WRITTEN.get_or_init(|| {
        let inst = synth::generate(
            &SynthParams { n: 40, m: 3, dims: 2, horizon: 24, ..Default::default() },
            1,
        );
        std::fs::create_dir_all("target").ok();
        crate::io::files::save_trace_csv(&inst.tasks, std::path::Path::new(PATH))
            .expect("writing the csv smoke fixture");
    });
    PATH
}

// ---------- JSON form -----------------------------------------------------

/// Build a source from the service's JSON `workload` field: either a
/// spec string (the shared grammar) or an object `{"family": ..., ...}`.
/// Object keys follow the spec keys for every family; `synth` objects
/// using any config-layer name (`dem_range`, `cap_range`, `cost_model`,
/// `exponent`, explicit fixed `coefficients`) take the
/// [`synth_params_from_json`] route instead. Unknown keys are errors,
/// never silently ignored, and both routes hit the same size caps.
pub fn source_from_json(v: &Json) -> Result<Box<dyn WorkloadSource>> {
    // The csv family reads server-local files: reachable from the
    // service's untrusted `workload` field it would hand remote clients
    // arbitrary-path reads (and file-existence probing through error
    // text). It stays CLI-only; the service takes inline instances.
    fn reject_csv(family: &str) -> Result<()> {
        ensure!(
            family != "csv",
            "the csv family reads server-local files and is not accepted \
             over the service API; submit the tasks as an inline 'instance'"
        );
        Ok(())
    }
    match v {
        Json::Str(s) => {
            let spec = WorkloadSpec::parse(s)?;
            reject_csv(&spec.family).map_err(|e| workload_error(s, e))?;
            spec.source()
        }
        Json::Obj(obj) => {
            // a present-but-non-string family must not silently fall back
            let family = match v.get("family") {
                Json::Null => "synth".to_string(),
                f => f
                    .as_str()
                    .context("workload 'family' must be a string")?
                    .to_string(),
            };
            const CONFIG_KEYS: &[&str] =
                &["dem_range", "cap_range", "cost_model", "exponent", "coefficients"];
            if family == "synth" && obj.keys().any(|k| CONFIG_KEYS.contains(&k.as_str())) {
                let params =
                    synth_params_from_json(v).map_err(|e| workload_error("synth", e))?;
                let spec = spec_of_synth(&params);
                return Ok(Box::new(SynthSource { spec, params }));
            }
            reject_csv(&family).map_err(|e| workload_error(&family, e))?;
            let mut spec = WorkloadSpec {
                family: family.clone(),
                params: std::collections::BTreeMap::new(),
            };
            // validate the family before converting values
            let fam = spec.family_info().map_err(|e| workload_error(&family, e))?;
            for (k, val) in obj {
                if k == "family" {
                    continue;
                }
                // key membership first, so even false-valued flags cannot
                // smuggle an unknown key past validation
                if !fam.keys.iter().any(|(name, _)| name == k) {
                    return Err(workload_error(
                        &family,
                        format!(
                            "unknown key '{k}' for family '{family}' (valid keys: {})",
                            fam.keys.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
                        ),
                    ));
                }
                let rendered = match val {
                    Json::Num(_) => val.to_string(),
                    Json::Str(s) => s.clone(),
                    Json::Bool(true) => String::new(), // flag
                    Json::Bool(false) => continue,
                    Json::Arr(xs) if xs.len() == 2 => {
                        let r = val
                            .to_f64_vec()
                            .with_context(|| format!("key '{k}': bad range array"))?;
                        format!("{}..{}", r[0], r[1])
                    }
                    _ => bail!("key '{k}': unsupported JSON value {val:?}"),
                };
                spec.set(k, rendered);
            }
            spec.source()
        }
        _ => bail!("workload must be a spec string or an object"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_family_has_a_valid_smoke_spec() {
        csv_smoke_fixture();
        for fam in families() {
            let src = parse_workload(fam.smoke_spec).unwrap_or_else(|e| {
                panic!("{}: smoke spec '{}' invalid: {e:#}", fam.name, fam.smoke_spec)
            });
            let inst = src.generate(1).unwrap();
            assert!(inst.n_tasks() > 0, "{}", fam.name);
            assert!(inst.is_feasible(), "{}", fam.name);
            assert!(!src.describe().is_empty());
            if fam.name == "csv" {
                // csv requires path=, so the bare name is an error
                assert!(parse_workload(fam.name).is_err());
            } else {
                // bare family names are valid specs too
                parse_workload(fam.name).unwrap();
            }
        }
    }

    #[test]
    fn shapes_compose_onto_every_family() {
        csv_smoke_fixture();
        for fam in families() {
            for shape in ["ramp", "diurnal", "spike"] {
                let spec = format!("{},shape={shape}", fam.smoke_spec);
                let src = parse_workload(&spec)
                    .unwrap_or_else(|e| panic!("'{spec}': {e:#}"));
                let a = src.generate(5).unwrap_or_else(|e| panic!("'{spec}': {e:#}"));
                let b = src.generate(5).unwrap();
                assert_eq!(a.tasks, b.tasks, "'{spec}' not deterministic");
                assert!(a.is_feasible(), "'{spec}'");
                // nightly batch windows never intersect the diurnal peak
                // hours, so that one combination legitimately stays flat
                if !(fam.name == "batch" && shape == "diurnal") {
                    assert!(
                        a.tasks.iter().any(|t| !t.is_flat()),
                        "'{spec}' produced no shaped task"
                    );
                }
                // the flat instance is the same workload at its peaks:
                // shaping never moves spans or raises demand
                let flat = parse_workload(fam.smoke_spec).unwrap().generate(5).unwrap();
                assert_eq!(flat.n_tasks(), a.n_tasks(), "'{spec}'");
                for (s, f) in a.tasks.iter().zip(&flat.tasks) {
                    assert_eq!((s.start, s.end, s.id), (f.start, f.end, f.id), "'{spec}'");
                    assert_eq!(s.peak(), f.peak(), "'{spec}' task {}", s.id);
                }
                assert_eq!(a.node_types, flat.node_types, "'{spec}'");
            }
            // shape=flat is bit-identical to omitting the key
            let spec = format!("{},shape=flat", fam.smoke_spec);
            let shaped = parse_workload(&spec).unwrap().generate(3).unwrap();
            let plain = parse_workload(fam.smoke_spec).unwrap().generate(3).unwrap();
            assert_eq!(shaped.tasks, plain.tasks, "'{spec}'");
            assert_eq!(shaped.node_types, plain.node_types, "'{spec}'");
        }
        // bad shape values teach the grammar
        let err = parse_workload("synth:shape=wavy").unwrap_err().to_string();
        assert!(err.contains("not flat, ramp, diurnal or spike"), "{err}");
    }

    #[test]
    fn csv_family_imports_and_rejects() {
        use crate::io::files;
        let path = csv_smoke_fixture();
        // round-trip: the imported tasks are the file's tasks verbatim
        let spec = format!("csv:path={path},m=4");
        let src = parse_workload(&spec).unwrap();
        let inst = src.generate(2).unwrap();
        let direct = files::load_trace_csv(std::path::Path::new(path)).unwrap();
        assert_eq!(inst.tasks, direct);
        assert_eq!(inst.n_types(), 4);
        assert!(inst.is_feasible());
        assert_eq!(
            inst.horizon,
            direct.iter().map(|t| t.end).max().unwrap() + 1
        );
        // deterministic in seed; different seeds redraw the catalog only
        let again = src.generate(2).unwrap();
        assert_eq!(inst.tasks, again.tasks);
        assert_eq!(inst.node_types, again.node_types);
        let other = src.generate(3).unwrap();
        assert_eq!(inst.tasks, other.tasks);
        assert_ne!(inst.node_types, other.node_types);
        // spec round-trips through render
        let parsed = WorkloadSpec::parse(&spec).unwrap();
        assert_eq!(WorkloadSpec::parse(&parsed.render()).unwrap(), parsed);
        // cost composes like on every family
        let priced = parse_workload(&format!("csv:path={path},m=3,cost=gcp"))
            .unwrap()
            .generate(1)
            .unwrap();
        let coeff = pricing::gcp_coefficients(2);
        for b in &priced.node_types {
            let want: f64 =
                b.capacity.iter().zip(&coeff).map(|(&c, &k)| k * c).sum();
            assert!((b.cost - want).abs() < 1e-12);
        }
        // rejections: missing path, missing file, bad horizon override
        assert!(parse_workload("csv").is_err());
        assert!(parse_workload("csv:path=").is_err());
        let missing = parse_workload("csv:path=/nonexistent/trace.csv").unwrap();
        assert!(missing.generate(1).is_err());
        let short = parse_workload(&format!("csv:path={path},horizon=2")).unwrap();
        let err = short.generate(1).unwrap_err().to_string();
        assert!(err.contains("does not cover"), "{err}");
        // unknown keys are rejected like every family's
        assert!(parse_workload(&format!("csv:path={path},frobs=3")).is_err());
        // the service's JSON entry point rejects csv in both forms: a
        // remote client must not get server-local file reads
        let err = source_from_json(&Json::Str(format!("csv:path={path}")))
            .unwrap_err()
            .to_string();
        assert!(err.contains("not accepted over the service"), "{err}");
        let v = crate::util::json::parse(&format!(
            r#"{{"family": "csv", "path": "{path}"}}"#
        ))
        .unwrap();
        let err = source_from_json(&v).unwrap_err().to_string();
        assert!(err.contains("not accepted over the service"), "{err}");
    }

    #[test]
    fn spec_parse_render_roundtrip() {
        for s in [
            "synth",
            "synth:n=2000,dims=7",
            "gct:n=1000,priced",
            "mixed:horizon=336,services=200",
            "burst:day=48",
            "spiky:dem=0.01..0.3",
            "waves:waves=4",
        ] {
            let spec = WorkloadSpec::parse(s).unwrap();
            let back = WorkloadSpec::parse(&spec.render()).unwrap();
            assert_eq!(spec, back, "{s}");
        }
        // rendering canonicalizes key order
        assert_eq!(
            WorkloadSpec::parse("gct:priced,n=5").unwrap().render(),
            "gct:n=5,priced"
        );
    }

    #[test]
    fn errors_teach_grammar_and_catalog() {
        for bad in [
            "",
            "warp",
            "synth:frobs=3",
            "synth:n=x",
            "synth:dem=0.1",
            "synth:n=0",
            "gct:m=99",
            "gct:n=900,pool=100",
            "mixed:day=0",
            "synth:cost=quadratic",
            "gct:n=5,priced=false",                // flags must be bare
            "synth:cost=fixed",                    // coef required
            "synth:dims=2,cost=fixed,coef=1;2;3",  // coef arity != dims
            "synth:coef=1;2",                      // coef needs cost=fixed
            "deadline:services=1,horizon=0",
            // untrusted size parameters are capped
            "synth:n=4000000000",
            "gct:pool=2000000000",
            "duty:services=400000,horizon=100000",
        ] {
            let err = match parse_workload(bad) {
                Err(e) => e.to_string(),
                Ok(_) => panic!("'{bad}' should not parse"),
            };
            assert!(err.contains("invalid workload spec"), "{bad}: {err}");
            assert!(err.contains("spec grammar"), "{bad}: {err}");
            // the catalog names every family
            for fam in families() {
                assert!(err.contains(fam.name), "{bad}: {err}");
            }
        }
    }

    #[test]
    fn synth_spec_matches_direct_generator() {
        let via_spec = parse_workload("synth:n=120,m=5,dims=3").unwrap().generate(7).unwrap();
        let direct = synth::generate(
            &SynthParams { n: 120, m: 5, dims: 3, ..Default::default() },
            7,
        );
        assert_eq!(via_spec.tasks, direct.tasks);
        assert_eq!(via_spec.node_types, direct.node_types);
    }

    #[test]
    fn gct_spec_matches_master_trace_sampling() {
        let via_spec = parse_workload("gct:n=150,m=7").unwrap().generate(3).unwrap();
        let mut direct = master_trace().sample_scenario(150, 7, 3);
        CostModel::homogeneous(direct.dims()).apply(&mut direct.node_types);
        assert_eq!(via_spec.tasks, direct.tasks);
        assert_eq!(via_spec.node_types, direct.node_types);
        // priced keeps the rate-card costs
        let priced = parse_workload("gct:n=150,m=7,priced").unwrap().generate(3).unwrap();
        assert_eq!(priced.tasks, via_spec.tasks);
        assert!(priced.node_types.iter().zip(&via_spec.node_types).any(|(a, b)| a.cost != b.cost));
    }

    #[test]
    fn pricing_composes_onto_any_family() {
        let inst = parse_workload("duty:services=10,m=3,cost=gcp,e=2")
            .unwrap()
            .generate(5)
            .unwrap();
        let coeff = pricing::gcp_coefficients(2);
        for b in &inst.node_types {
            let want: f64 = b
                .capacity
                .iter()
                .zip(&coeff)
                .map(|(&c, &k)| k * c.powf(2.0))
                .sum();
            assert!((b.cost - want).abs() < 1e-12);
        }
        // hom with an exponent prices with unit coefficients
        let inst = parse_workload("batch:services=5,m=2,e=0.5").unwrap().generate(1).unwrap();
        for b in &inst.node_types {
            let want: f64 = b.capacity.iter().map(|&c| c.sqrt()).sum();
            assert!((b.cost - want).abs() < 1e-12);
        }
        // explicit fixed coefficients via coef=
        let inst = parse_workload("synth:n=10,m=2,dims=2,cost=fixed,coef=2;0.5,e=2")
            .unwrap()
            .generate(1)
            .unwrap();
        for b in &inst.node_types {
            let want = 2.0 * b.capacity[0].powi(2) + 0.5 * b.capacity[1].powi(2);
            assert!((b.cost - want).abs() < 1e-12);
        }
        // and the synth-params renderer round-trips them through the parser
        let p = SynthParams {
            dims: 2,
            cost_model: CostKind::Fixed { coefficients: vec![2.0, 0.5], exponent: 2.0 },
            ..Default::default()
        };
        let spec = spec_of_synth(&p);
        assert_eq!(spec.get("coef"), Some("2;0.5"));
        assert!(spec.source().is_ok());
    }

    #[test]
    fn synth_json_fixed_cost_and_unknown_keys() {
        let v = crate::util::json::parse(
            r#"{"n": 20, "dims": 2, "cost_model": "fixed",
                "coefficients": [2.0, 1.0], "exponent": 2.0}"#,
        )
        .unwrap();
        let p = synth_params_from_json(&v).unwrap();
        match &p.cost_model {
            CostKind::Fixed { coefficients, exponent } => {
                assert_eq!(coefficients, &vec![2.0, 1.0]);
                assert_eq!(*exponent, 2.0);
            }
            other => panic!("{other:?}"),
        }
        // unknown keys are errors, not silently ignored
        let v = crate::util::json::parse(r#"{"n": 20, "tasks": 5}"#).unwrap();
        let err = synth_params_from_json(&v).unwrap_err().to_string();
        assert!(err.contains("unknown key 'tasks'"), "{err}");
        // coefficient arity must match dims
        let v = crate::util::json::parse(
            r#"{"dims": 3, "cost_model": "fixed", "coefficients": [1.0]}"#,
        )
        .unwrap();
        assert!(synth_params_from_json(&v).is_err());
    }

    #[test]
    fn json_object_form_builds_any_family() {
        let v = crate::util::json::parse(
            r#"{"family": "waves", "services": 30, "m": 3, "waves": 4,
                "dem": [0.02, 0.1], "priced_flag_unused": false}"#,
        )
        .unwrap();
        // unknown key is rejected through the same validation
        assert!(source_from_json(&v).is_err());
        let v = crate::util::json::parse(
            r#"{"family": "waves", "services": 30, "m": 3, "waves": 4,
                "dem": [0.02, 0.1]}"#,
        )
        .unwrap();
        let src = source_from_json(&v).unwrap();
        let inst = src.generate(2).unwrap();
        assert_eq!(
            inst.tasks,
            parse_workload("waves:services=30,m=3,waves=4,dem=0.02..0.1")
                .unwrap()
                .generate(2)
                .unwrap()
                .tasks
        );
        // string form goes through the shared parser
        let v = Json::Str("gct:n=50,m=4,pool=200".into());
        assert!(source_from_json(&v).unwrap().generate(1).unwrap().n_tasks() == 50);
        // a present-but-non-string family is an error, not a synth fallback
        let v = crate::util::json::parse(r#"{"family": 42, "n": 10}"#).unwrap();
        let err = source_from_json(&v).unwrap_err().to_string();
        assert!(err.contains("family"), "{err}");
        // synth objects accept the spec-key vocabulary like every family
        let v = crate::util::json::parse(
            r#"{"family": "synth", "n": 25, "m": 3, "dem": [0.02, 0.1]}"#,
        )
        .unwrap();
        let inst = source_from_json(&v).unwrap().generate(3).unwrap();
        assert_eq!(
            inst.tasks,
            parse_workload("synth:n=25,m=3,dem=0.02..0.1")
                .unwrap()
                .generate(3)
                .unwrap()
                .tasks
        );
        // size caps hold on both object routes (spec-key and config-key)
        let v = crate::util::json::parse(r#"{"family": "synth", "horizon": 0}"#).unwrap();
        assert!(source_from_json(&v).is_err());
        let v = crate::util::json::parse(
            r#"{"n": 4000000000, "cost_model": "heterogeneous"}"#,
        )
        .unwrap();
        assert!(source_from_json(&v).is_err());
    }
}
