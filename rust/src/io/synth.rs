//! Synthetic benchmark generator (paper section VI-A).
//!
//! Each of the D demand/capacity components is drawn uniformly and
//! independently from its interval; task spans are uniform over `[0, T)`;
//! node-type costs follow the configured cost model (Equation 8).

use crate::model::{CostModel, Instance, NodeType, Task};
use crate::util::rng::Rng;

/// Generator parameters with the paper's Table I defaults.
#[derive(Clone, Debug)]
pub struct SynthParams {
    pub n: usize,
    pub m: usize,
    pub dims: usize,
    pub horizon: u32,
    /// Capacity component interval [a, b] ⊆ (0, 1].
    pub cap_range: (f64, f64),
    /// Demand component interval [a, b] ⊆ (0, 1).
    pub dem_range: (f64, f64),
    pub cost_model: CostKind,
}

/// Which cost model to price node-types with (paper sections VI-B/VI-C).
#[derive(Clone, Debug)]
pub enum CostKind {
    /// c_d = 1, e = 1.
    HomogeneousLinear,
    /// Coefficients drawn uniformly from [0.3, 1.0]; exponent `e`.
    HeterogeneousRandom { exponent: f64 },
    /// Fixed coefficients (e.g. pricing-table based) with exponent `e`.
    Fixed { coefficients: Vec<f64>, exponent: f64 },
}

impl Default for SynthParams {
    /// Table I defaults: n=1000, m=10, D=5, T=24, cap [0.2,1.0],
    /// demand [0.01,0.1], homogeneous linear cost.
    fn default() -> Self {
        SynthParams {
            n: 1000,
            m: 10,
            dims: 5,
            horizon: 24,
            cap_range: (0.2, 1.0),
            dem_range: (0.01, 0.1),
            cost_model: CostKind::HomogeneousLinear,
        }
    }
}

/// Draw an m-type catalog skeleton: capacities uniform per dimension
/// from `cap_range`, cost 1.0 until [`price_catalog`] runs. One shared
/// implementation for every catalog-drawing family (synth, the pattern
/// families, csv import) — the draw order is a generator contract:
/// changing it changes every pinned instance.
pub fn draw_capacities(
    rng: &mut Rng,
    m: usize,
    dims: usize,
    cap_range: (f64, f64),
    prefix: &str,
) -> Vec<NodeType> {
    (0..m)
        .map(|i| {
            let cap: Vec<f64> = (0..dims)
                .map(|_| rng.uniform(cap_range.0, cap_range.1))
                .collect();
            NodeType::new(format!("{prefix}-{i}"), cap, 1.0)
        })
        .collect()
}

/// Price a drawn catalog. The heterogeneous model draws its coefficients
/// from the same stream — after the capacities, the seed's order.
pub fn price_catalog(
    rng: &mut Rng,
    node_types: &mut [NodeType],
    dims: usize,
    cost_model: &CostKind,
) {
    let model = match cost_model {
        CostKind::HomogeneousLinear => CostModel::homogeneous(dims),
        CostKind::HeterogeneousRandom { exponent } => {
            let coeff: Vec<f64> = (0..dims).map(|_| rng.uniform(0.3, 1.0)).collect();
            CostModel::new(coeff, *exponent)
        }
        CostKind::Fixed { coefficients, exponent } => {
            CostModel::new(coefficients.clone(), *exponent)
        }
    };
    model.apply(node_types);
}

/// Index of the catalog's *anchor*: the type whose weakest dimension is
/// largest (NaN-safe, last max wins — the seed's tie direction). Tasks
/// clamped to the anchor's capacity are admissible on it by
/// construction; clamping against the per-dimension max over *all*
/// types would not be enough (the maxima may come from different types).
pub fn anchor_index(node_types: &[NodeType]) -> usize {
    (0..node_types.len())
        .max_by(|&a, &b| {
            let min_a =
                node_types[a].capacity.iter().copied().fold(f64::INFINITY, f64::min);
            let min_b =
                node_types[b].capacity.iter().copied().fold(f64::INFINITY, f64::min);
            min_a.total_cmp(&min_b).then(a.cmp(&b))
        })
        .expect("at least one node-type")
}

/// Generate a synthetic instance. Fully deterministic in `seed`.
pub fn generate(params: &SynthParams, seed: u64) -> Instance {
    let mut rng = Rng::new(seed);
    let d = params.dims;

    let mut node_types = draw_capacities(&mut rng, params.m, d, params.cap_range, "synth");
    price_catalog(&mut rng, &mut node_types, d, &params.cost_model);
    let anchor_cap = node_types[anchor_index(&node_types)].capacity.clone();

    let tasks: Vec<Task> = (0..params.n)
        .map(|i| {
            let dem: Vec<f64> = (0..d)
                .map(|k| {
                    rng.uniform(params.dem_range.0, params.dem_range.1).min(anchor_cap[k])
                })
                .collect();
            let a = rng.below(params.horizon as u64) as u32;
            let b = rng.below(params.horizon as u64) as u32;
            let (s, e) = if a <= b { (a, b) } else { (b, a) };
            Task::new(i as u64, dem, s, e)
        })
        .collect();

    Instance::new(tasks, node_types, params.horizon)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let p = SynthParams { n: 50, m: 4, ..Default::default() };
        let a = generate(&p, 3);
        let b = generate(&p, 3);
        assert_eq!(a.tasks, b.tasks);
        assert_eq!(a.node_types, b.node_types);
    }

    #[test]
    fn respects_ranges() {
        let p = SynthParams { n: 200, m: 8, ..Default::default() };
        let inst = generate(&p, 1);
        assert_eq!(inst.n_tasks(), 200);
        assert_eq!(inst.n_types(), 8);
        assert_eq!(inst.dims(), 5);
        for b in &inst.node_types {
            for &c in &b.capacity {
                assert!((0.2..=1.0).contains(&c));
            }
        }
        for u in &inst.tasks {
            assert!(u.end < 24);
            for &x in u.peak() {
                assert!(x >= 0.01 - 1e-12 && x <= 0.1 + 1e-12);
            }
        }
        assert!(inst.is_feasible());
    }

    #[test]
    fn homogeneous_cost_is_capacity_sum() {
        let p = SynthParams { n: 5, m: 3, ..Default::default() };
        let inst = generate(&p, 9);
        for b in &inst.node_types {
            let sum: f64 = b.capacity.iter().sum();
            assert!((b.cost - sum).abs() < 1e-12);
        }
    }

    #[test]
    fn heterogeneous_cost_nonlinear() {
        let p = SynthParams {
            n: 5,
            m: 6,
            cost_model: CostKind::HeterogeneousRandom { exponent: 2.0 },
            ..Default::default()
        };
        let inst = generate(&p, 4);
        // super-linear pricing: cost below the linear-coefficient bound
        for b in &inst.node_types {
            assert!(b.cost > 0.0);
            let linear_ub: f64 = b.capacity.iter().sum();
            assert!(b.cost <= linear_ub + 1e-9, "coefficients <=1, caps <=1");
        }
        assert!(inst.is_feasible());
    }
}
