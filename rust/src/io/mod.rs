//! Loaders and generators: the unified workload subsystem (spec grammar +
//! family registry), synthetic benchmark, GCT-like trace, the pattern
//! library, pricing, and on-disk formats.

pub mod delta;
pub mod files;
pub mod gct_like;
pub mod patterns;
pub mod pricing;
pub mod synth;
pub mod workload;
