//! Loaders and generators: synthetic benchmark, GCT-like trace, pricing,
//! and on-disk formats.

pub mod files;
pub mod gct_like;
pub mod patterns;
pub mod pricing;
pub mod synth;
