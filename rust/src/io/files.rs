//! On-disk formats: instances as JSON, task traces as CSV.
//!
//! The CSV trace format mirrors the processed GCT-2019 table the paper
//! builds from BigQuery: one task per line, `id,start,end,dem0,dem1,...`.
//! Tasks with piecewise-constant demand profiles write one row per
//! segment: the first segment as a normal task row, each further segment
//! as a continuation row `+,start,end,dem0,...` immediately after it.
//! Node-type catalogs live in the JSON instance format; shaped tasks
//! there carry a `"segments"` array instead of a flat `"demand"`.
//!
//! External data is *validated before construction*: a malformed row
//! (inverted span, non-finite demand, a continuation with a gap) returns
//! the loader's `Result` error instead of tripping `Task::new`'s
//! programmer-error panic.

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::{DemandSeg, Instance, NodeType, Solution, Task};
use crate::util::json::{self, num_is_usize, Json};
use crate::util::wire::{Event, JsonPull, JsonWriter};

// ---------- JSON instance format ----------------------------------------

/// Serialize one node-type (the instance-format object shape).
pub fn node_type_to_json(b: &NodeType) -> Json {
    Json::obj(vec![
        ("name", Json::Str(b.name.clone())),
        ("capacity", Json::arr_f64(&b.capacity)),
        ("cost", Json::Num(b.cost)),
    ])
}

/// Serialize one task (flat `"demand"` or `"segments"` — the shared
/// grammar of instance files, service requests and session deltas).
pub fn task_to_json(u: &Task) -> Json {
    let mut fields = vec![
        ("id", Json::Num(u.id as f64)),
    ];
    if u.is_flat() {
        // flat tasks keep the seed's exact format
        fields.push(("demand", Json::arr_f64(u.peak())));
    } else {
        fields.push((
            "segments",
            Json::Arr(
                u.segments()
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("start", Json::Num(s.start as f64)),
                            ("end", Json::Num(s.end as f64)),
                            ("demand", Json::arr_f64(&s.demand)),
                        ])
                    })
                    .collect(),
            ),
        ));
    }
    fields.push(("start", Json::Num(u.start as f64)));
    fields.push(("end", Json::Num(u.end as f64)));
    Json::obj(fields)
}

pub fn instance_to_json(inst: &Instance) -> Json {
    Json::obj(vec![
        ("horizon", Json::Num(inst.horizon as f64)),
        (
            "node_types",
            Json::Arr(inst.node_types.iter().map(node_type_to_json).collect()),
        ),
        (
            "tasks",
            Json::Arr(inst.tasks.iter().map(task_to_json).collect()),
        ),
    ])
}

/// Parse one node-type object (`{"name", "capacity", "cost"}`),
/// validating before construction so malformed external data errors
/// instead of tripping `NodeType::new`'s programmer-error asserts.
pub fn node_type_from_json(b: &Json) -> Result<NodeType> {
    let name = b.get("name").as_str().unwrap_or("unnamed");
    let capacity = b.get("capacity").to_f64_vec().context("node_type capacity")?;
    let cost = b.get("cost").as_f64().context("node_type cost")?;
    if capacity.is_empty() || capacity.iter().any(|c| !c.is_finite() || *c <= 0.0) {
        bail!("node-type {name}: capacity must be non-empty, finite and positive");
    }
    if !cost.is_finite() || cost < 0.0 {
        bail!("node-type {name}: cost must be finite and non-negative");
    }
    Ok(NodeType::new(name, capacity, cost))
}

/// Parse one task object — a flat `"demand"` or a `"segments"` array
/// (the same grammar instance files, service requests and session
/// deltas all share).
pub fn task_from_json(t: &Json) -> Result<Task> {
    // NOTE: the id cast is deliberately lenient (the seed's behavior —
    // legacy one-shot responses are pinned byte-identical). Surfaces
    // where ids are an addressing key (session deltas) enforce strict
    // non-negative-integer ids before calling this (see io::delta).
    let id = t.get("id").as_f64().context("task id")? as u64;
    let start = t.get("start").as_usize().context("task start")? as u32;
    let end = t.get("end").as_usize().context("task end")? as u32;
    match t.get("segments") {
        Json::Null => {
            let demand = t.get("demand").to_f64_vec().context("task demand")?;
            if end < start || demand.is_empty() {
                bail!("task {id} with invalid span [{start},{end}] or empty demand");
            }
            validate_demand(id, &demand)?;
            Ok(Task::new(id, demand, start, end))
        }
        segs_json => {
            let mut segs = Vec::new();
            for s in segs_json.as_arr().context("task segments")? {
                let demand = s.get("demand").to_f64_vec().context("segment demand")?;
                validate_demand(id, &demand)?;
                segs.push(DemandSeg {
                    start: s.get("start").as_usize().context("segment start")? as u32,
                    end: s.get("end").as_usize().context("segment end")? as u32,
                    demand,
                });
            }
            let task = Task::try_piecewise(id, segs)
                .map_err(|e| anyhow::anyhow!("invalid segments: {e}"))?;
            if (task.start, task.end) != (start, end) {
                bail!(
                    "task {id}: declared span [{start},{end}] does not match its \
                     segments [{},{}]",
                    task.start,
                    task.end
                );
            }
            Ok(task)
        }
    }
}

pub fn instance_from_json(v: &Json) -> Result<Instance> {
    let horizon = v
        .get("horizon")
        .as_usize()
        .context("instance: missing horizon")? as u32;
    let mut node_types = Vec::new();
    for b in v.get("node_types").as_arr().context("instance: node_types")? {
        node_types.push(node_type_from_json(b)?);
    }
    let mut tasks = Vec::new();
    for t in v.get("tasks").as_arr().context("instance: tasks")? {
        tasks.push(task_from_json(t)?);
    }
    // Validate before Instance::new, which treats violations as programmer
    // errors (panics) — external input must fail gracefully instead.
    if node_types.is_empty() {
        bail!("instance has no node-types");
    }
    if horizon == 0 {
        bail!("instance has zero horizon");
    }
    let dims = node_types[0].dims();
    for b in &node_types {
        if b.dims() != dims {
            bail!("node-type {} has {} dims, expected {dims}", b.name, b.dims());
        }
    }
    for u in &tasks {
        if u.dims() != dims {
            bail!("task {} has {} dims, expected {dims}", u.id, u.dims());
        }
        if u.end >= horizon {
            bail!("task {} extends beyond horizon {horizon}", u.id);
        }
    }
    Ok(Instance::new(tasks, node_types, horizon))
}

/// Demand values from external sources must be finite and non-negative —
/// a NaN would silently disable the verifier's comparisons downstream.
fn validate_demand(id: u64, demand: &[f64]) -> Result<()> {
    if demand.iter().any(|d| !d.is_finite() || *d < 0.0) {
        bail!("task {id}: demand components must be finite and non-negative");
    }
    Ok(())
}

// ---------- streaming hot path (typed pull decoders) ----------------------
//
// Fast decoders over `util::wire::JsonPull` for the instance grammar,
// building `Task`/`NodeType`/`Instance` without a DOM. They are fast
// paths for *valid* input only: any surprise — wrong type, missing
// field, failed validation — returns `None` and the caller falls back
// to the `*_from_json` DOM path above, which produces the canonical
// error. The only obligation is: typed success implies the DOM path
// would succeed with an identical value (pinned by `tests/prop_wire.rs`).

pub(crate) fn pull_num(p: &mut JsonPull) -> Option<f64> {
    match p.next().ok()? {
        Some(Event::Num(x)) => Some(x),
        _ => None,
    }
}

pub(crate) fn pull_f64_vec(p: &mut JsonPull) -> Option<Vec<f64>> {
    match p.next().ok()? {
        Some(Event::ArrStart) => {}
        _ => return None,
    }
    let mut out = Vec::new();
    loop {
        match p.next().ok()? {
            Some(Event::Num(x)) => out.push(x),
            Some(Event::ArrEnd) => return Some(out),
            _ => return None,
        }
    }
}

/// The `as_usize() as u32` idiom of the DOM path, as one cast chain.
pub(crate) fn num_u32(x: f64) -> Option<u32> {
    num_is_usize(x).then(|| (x as usize) as u32)
}

fn demand_ok(demand: &[f64]) -> bool {
    demand.iter().all(|d| d.is_finite() && *d >= 0.0)
}

/// Decode a task object body (after its `ObjStart` was consumed).
/// Returns the task plus whether its id was a strict non-negative
/// integer — surfaces that address tasks by id (session deltas)
/// enforce that; instance files keep the seed's lenient cast.
pub(crate) fn task_body_from_pull(p: &mut JsonPull) -> Option<(Task, bool)> {
    let mut id: Option<f64> = None;
    let mut start: Option<u32> = None;
    let mut end: Option<u32> = None;
    let mut demand: Option<Vec<f64>> = None;
    let mut segments: Option<Option<Vec<DemandSeg>>> = None;
    loop {
        match p.next().ok()? {
            // last occurrence wins, like the DOM's BTreeMap insert
            Some(Event::Key(k)) => match k.as_ref() {
                "id" => id = Some(pull_num(p)?),
                "start" => start = Some(num_u32(pull_num(p)?)?),
                "end" => end = Some(num_u32(pull_num(p)?)?),
                "demand" => demand = Some(pull_f64_vec(p)?),
                "segments" => segments = Some(segs_value_from_pull(p)?),
                _ => p.skip_value().ok()?,
            },
            Some(Event::ObjEnd) => break,
            _ => return None,
        }
    }
    build_task(id?, start?, end?, demand, segments)
}

pub(crate) fn build_task(
    id_raw: f64,
    start: u32,
    end: u32,
    demand: Option<Vec<f64>>,
    segments: Option<Option<Vec<DemandSeg>>>,
) -> Option<(Task, bool)> {
    let strict = num_is_usize(id_raw);
    let id = id_raw as u64;
    // a literal `"segments": null` is absent for the DOM's get(): flat
    match segments.flatten() {
        None => {
            let demand = demand?;
            if end < start || demand.is_empty() || !demand_ok(&demand) {
                return None;
            }
            Some((Task::new(id, demand, start, end), strict))
        }
        Some(segs) => {
            let task = Task::try_piecewise(id, segs).ok()?;
            if (task.start, task.end) != (start, end) {
                return None;
            }
            Some((task, strict))
        }
    }
}

/// Decode a `"segments"` *value*: `Some(None)` for a literal `null`
/// (≡ absent under the DOM's `get`), `Some(Some(segs))` for an array.
pub(crate) fn segs_value_from_pull(p: &mut JsonPull) -> Option<Option<Vec<DemandSeg>>> {
    match p.next().ok()? {
        Some(Event::Null) => Some(None),
        Some(Event::ArrStart) => {
            let mut segs = Vec::new();
            loop {
                match p.next().ok()? {
                    Some(Event::ObjStart) => segs.push(seg_body_from_pull(p)?),
                    Some(Event::ArrEnd) => return Some(Some(segs)),
                    _ => return None,
                }
            }
        }
        _ => None,
    }
}

fn seg_body_from_pull(p: &mut JsonPull) -> Option<DemandSeg> {
    let mut start: Option<u32> = None;
    let mut end: Option<u32> = None;
    let mut demand: Option<Vec<f64>> = None;
    loop {
        match p.next().ok()? {
            Some(Event::Key(k)) => match k.as_ref() {
                "start" => start = Some(num_u32(pull_num(p)?)?),
                "end" => end = Some(num_u32(pull_num(p)?)?),
                "demand" => demand = Some(pull_f64_vec(p)?),
                _ => p.skip_value().ok()?,
            },
            Some(Event::ObjEnd) => break,
            _ => return None,
        }
    }
    let demand = demand?;
    if !demand_ok(&demand) {
        return None;
    }
    Some(DemandSeg { start: start?, end: end?, demand })
}

pub(crate) fn node_type_body_from_pull(p: &mut JsonPull) -> Option<NodeType> {
    let mut name: Option<Option<String>> = None;
    let mut capacity: Option<Vec<f64>> = None;
    let mut cost: Option<f64> = None;
    loop {
        match p.next().ok()? {
            Some(Event::Key(k)) => match k.as_ref() {
                // the DOM treats any non-string name as "unnamed" and
                // keeps going, so a container here is parsed, not a bail
                "name" => name = Some(p.parse_value().ok()?.as_str().map(String::from)),
                "capacity" => capacity = Some(pull_f64_vec(p)?),
                "cost" => cost = Some(pull_num(p)?),
                _ => p.skip_value().ok()?,
            },
            Some(Event::ObjEnd) => break,
            _ => return None,
        }
    }
    let name = name.flatten();
    let name = name.as_deref().unwrap_or("unnamed");
    let capacity = capacity?;
    let cost = cost?;
    if capacity.is_empty() || capacity.iter().any(|c| !c.is_finite() || *c <= 0.0) {
        return None;
    }
    if !cost.is_finite() || cost < 0.0 {
        return None;
    }
    Some(NodeType::new(name, capacity, cost))
}

/// Decode one full instance value (the upcoming value must be an
/// object). Applies the same post-validations as `instance_from_json`.
pub(crate) fn instance_value_from_pull(p: &mut JsonPull) -> Option<Instance> {
    match p.next().ok()? {
        Some(Event::ObjStart) => {}
        _ => return None,
    }
    let mut horizon: Option<u32> = None;
    let mut node_types: Option<Vec<NodeType>> = None;
    let mut tasks: Option<Vec<Task>> = None;
    loop {
        match p.next().ok()? {
            Some(Event::Key(k)) => match k.as_ref() {
                "horizon" => horizon = Some(num_u32(pull_num(p)?)?),
                "node_types" => {
                    match p.next().ok()? {
                        Some(Event::ArrStart) => {}
                        _ => return None,
                    }
                    let mut out = Vec::new();
                    loop {
                        match p.next().ok()? {
                            Some(Event::ObjStart) => out.push(node_type_body_from_pull(p)?),
                            Some(Event::ArrEnd) => break,
                            _ => return None,
                        }
                    }
                    node_types = Some(out);
                }
                "tasks" => {
                    match p.next().ok()? {
                        Some(Event::ArrStart) => {}
                        _ => return None,
                    }
                    let mut out = Vec::new();
                    loop {
                        match p.next().ok()? {
                            Some(Event::ObjStart) => out.push(task_body_from_pull(p)?.0),
                            Some(Event::ArrEnd) => break,
                            _ => return None,
                        }
                    }
                    tasks = Some(out);
                }
                _ => p.skip_value().ok()?,
            },
            Some(Event::ObjEnd) => break,
            _ => return None,
        }
    }
    let (horizon, node_types, tasks) = (horizon?, node_types?, tasks?);
    if node_types.is_empty() || horizon == 0 {
        return None;
    }
    let dims = node_types[0].dims();
    if node_types.iter().any(|b| b.dims() != dims) {
        return None;
    }
    if tasks.iter().any(|u| u.dims() != dims || u.end >= horizon) {
        return None;
    }
    Some(Instance::new(tasks, node_types, horizon))
}

/// Streaming-decode a complete instance document from raw bytes.
/// `None` means "not decodable on the hot path" — re-run the DOM path
/// for the canonical result or error.
pub fn instance_from_slice(bytes: &[u8]) -> Option<Instance> {
    let mut p = JsonPull::new(bytes);
    let inst = instance_value_from_pull(&mut p)?;
    matches!(p.next(), Ok(None)).then_some(inst)
}

// ---------- streaming hot path (direct-write serializer) -------------------
//
// Byte-identical to `instance_to_json(..).to_string()`: same key orders
// (the DOM's BTreeMap sorts them), same number formatting.

pub(crate) fn write_f64_arr<W: std::io::Write>(w: &mut JsonWriter<W>, xs: &[f64]) {
    w.begin_arr();
    for &x in xs {
        w.num(x);
    }
    w.end_arr();
}

pub fn write_task_json<W: std::io::Write>(w: &mut JsonWriter<W>, u: &Task) {
    w.begin_obj();
    if u.is_flat() {
        w.key("demand");
        write_f64_arr(w, u.peak());
        w.key("end").num(u.end as f64);
        w.key("id").num(u.id as f64);
        w.key("start").num(u.start as f64);
    } else {
        w.key("end").num(u.end as f64);
        w.key("id").num(u.id as f64);
        w.key("segments").begin_arr();
        for s in u.segments() {
            w.begin_obj();
            w.key("demand");
            write_f64_arr(w, &s.demand);
            w.key("end").num(s.end as f64);
            w.key("start").num(s.start as f64);
            w.end_obj();
        }
        w.end_arr();
        w.key("start").num(u.start as f64);
    }
    w.end_obj();
}

pub fn write_node_type_json<W: std::io::Write>(w: &mut JsonWriter<W>, b: &NodeType) {
    w.begin_obj();
    w.key("capacity");
    write_f64_arr(w, &b.capacity);
    w.key("cost").num(b.cost);
    w.key("name").str(&b.name);
    w.end_obj();
}

pub fn write_instance_json<W: std::io::Write>(w: &mut JsonWriter<W>, inst: &Instance) {
    w.begin_obj();
    w.key("horizon").num(inst.horizon as f64);
    w.key("node_types").begin_arr();
    for b in &inst.node_types {
        write_node_type_json(w, b);
    }
    w.end_arr();
    w.key("tasks").begin_arr();
    for u in &inst.tasks {
        write_task_json(w, u);
    }
    w.end_arr();
    w.end_obj();
}

pub fn instance_to_wire_string(inst: &Instance) -> String {
    // rough per-row reservation so large instances don't regrow the buffer
    let cap = 64 * (inst.tasks.len() + inst.node_types.len()) + 64;
    let mut w = JsonWriter::new(Vec::with_capacity(cap));
    write_instance_json(&mut w, inst);
    w.into_string()
}

pub fn save_instance(inst: &Instance, path: &Path) -> Result<()> {
    fs::write(path, instance_to_wire_string(inst))
        .with_context(|| format!("writing {}", path.display()))
}

pub fn load_instance(path: &Path) -> Result<Instance> {
    let bytes =
        fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if let Some(inst) = instance_from_slice(&bytes) {
        return Ok(inst);
    }
    // cold path: re-read as text so the legacy UTF-8/parse/validation
    // error surfaces exactly as before
    drop(bytes);
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let v = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    instance_from_json(&v)
}

// ---------- CSV trace format ---------------------------------------------

/// Write tasks as `id,start,end,dem0,dem1,...` with a header line. A
/// shaped task writes its first segment as the task row and each further
/// segment as a `+,start,end,dem...` continuation row.
pub fn save_trace_csv(tasks: &[Task], path: &Path) -> Result<()> {
    let dims = tasks.first().map(|t| t.dims()).unwrap_or(0);
    let mut out = String::from("id,start,end");
    for d in 0..dims {
        out.push_str(&format!(",dem{d}"));
    }
    out.push('\n');
    for t in tasks {
        for (i, seg) in t.segments().iter().enumerate() {
            if i == 0 {
                out.push_str(&format!("{},{},{}", t.id, seg.start, seg.end));
            } else {
                out.push_str(&format!("+,{},{}", seg.start, seg.end));
            }
            for &x in &seg.demand {
                out.push_str(&format!(",{x}"));
            }
            out.push('\n');
        }
    }
    fs::write(path, out).with_context(|| format!("writing {}", path.display()))
}

/// Load tasks from the CSV trace format. Rows with missing fields are
/// rejected (the paper purges them from the sampled trace), and so are
/// semantically malformed rows — `end < start`, non-finite demand, or a
/// `+` continuation row that does not extend the previous task
/// contiguously. External data never reaches `Task::new`'s panics.
pub fn load_trace_csv(path: &Path) -> Result<Vec<Task>> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut lines = text.lines();
    let header = lines.next().context("empty trace file")?;
    let dims = header.split(',').count().saturating_sub(3);
    if dims == 0 {
        // deliberately does not echo the line: loader errors can end up
        // in logs/responses, and the "file" may not be a trace at all
        bail!(
            "trace header has {} column(s), need at least 4 (id,start,end,dem0,...)",
            header.split(',').count()
        );
    }
    // (id, accumulated segments) of the task being assembled
    let mut pending: Option<(u64, Vec<DemandSeg>)> = None;
    let mut tasks: Vec<Task> = Vec::new();
    let flush = |pending: &mut Option<(u64, Vec<DemandSeg>)>,
                 tasks: &mut Vec<Task>|
     -> Result<()> {
        if let Some((id, segs)) = pending.take() {
            let task = Task::try_piecewise(id, segs)
                .map_err(|e| anyhow::anyhow!("invalid trace rows: {e}"))?;
            tasks.push(task);
        }
        Ok(())
    };
    for (lineno, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let row = lineno + 2;
        let fields: Vec<&str> = line.split(',').collect();
        if fields.len() != dims + 3 {
            bail!("line {row}: expected {} fields, got {}", dims + 3, fields.len());
        }
        let start: u32 = fields[1]
            .parse()
            .with_context(|| format!("line {row}: start"))?;
        let end: u32 = fields[2].parse().with_context(|| format!("line {row}: end"))?;
        let demand: Vec<f64> = fields[3..]
            .iter()
            .map(|f| f.parse::<f64>())
            .collect::<Result<_, _>>()
            .with_context(|| format!("line {row}: demand"))?;
        // validate *before* any Task construction: loader errors, not panics
        if end < start {
            bail!("line {row}: end {end} < start {start}");
        }
        // keep end + 1 representable: the contiguity check below and every
        // horizon derivation downstream compute it
        if end == u32::MAX {
            bail!("line {row}: end {end} out of range");
        }
        if demand.iter().any(|d| !d.is_finite() || *d < 0.0) {
            bail!("line {row}: demand components must be finite and non-negative");
        }
        let seg = DemandSeg { start, end, demand };
        if fields[0] == "+" {
            let Some((_, segs)) = pending.as_mut() else {
                bail!("line {row}: '+' continuation row without a preceding task row");
            };
            let prev_end = segs.last().expect("pending has a segment").end;
            if start != prev_end + 1 {
                bail!(
                    "line {row}: continuation starts at {start} but the previous \
                     segment ends at {prev_end} (segments must be contiguous)"
                );
            }
            segs.push(seg);
        } else {
            flush(&mut pending, &mut tasks)?;
            let id: u64 = fields[0]
                .parse()
                .with_context(|| format!("line {row}: id"))?;
            pending = Some((id, vec![seg]));
        }
    }
    flush(&mut pending, &mut tasks)?;
    Ok(tasks)
}

// ---------- Solution summary (report artifact) ----------------------------

pub fn solution_to_json(sol: &Solution, inst: &Instance) -> Json {
    Json::obj(vec![
        ("cost", Json::Num(sol.cost(inst))),
        ("n_nodes", Json::Num(sol.nodes.len() as f64)),
        (
            "nodes_per_type",
            Json::Arr(
                sol.nodes_per_type(inst)
                    .iter()
                    .map(|&c| Json::Num(c as f64))
                    .collect(),
            ),
        ),
        (
            "nodes",
            Json::Arr(
                sol.nodes
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("type", Json::Str(inst.node_types[b.type_idx].name.clone())),
                            (
                                "tasks",
                                Json::Arr(
                                    b.tasks.iter().map(|&u| Json::Num(u as f64)).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};

    fn shaped_tasks() -> Vec<Task> {
        vec![
            Task::new(0, vec![0.2, 0.1], 0, 4),
            Task::piecewise(
                1,
                vec![
                    DemandSeg { start: 1, end: 2, demand: vec![0.1, 0.3] },
                    DemandSeg { start: 3, end: 5, demand: vec![0.4, 0.05] },
                    DemandSeg { start: 6, end: 6, demand: vec![0.05, 0.05] },
                ],
            ),
            Task::new(2, vec![0.3, 0.3], 5, 6),
        ]
    }

    #[test]
    fn instance_json_roundtrip() {
        let inst = generate(&SynthParams { n: 20, m: 3, ..Default::default() }, 5);
        let v = instance_to_json(&inst);
        let back = instance_from_json(&json::parse(&v.to_string()).unwrap()).unwrap();
        assert_eq!(inst.tasks, back.tasks);
        assert_eq!(inst.node_types, back.node_types);
        assert_eq!(inst.horizon, back.horizon);
    }

    #[test]
    fn shaped_instance_json_roundtrip() {
        let inst = Instance::new(
            shaped_tasks(),
            vec![NodeType::new("a", vec![1.0, 1.0], 1.0)],
            7,
        );
        let v = instance_to_json(&inst);
        let back = instance_from_json(&json::parse(&v.to_string()).unwrap()).unwrap();
        assert_eq!(inst.tasks, back.tasks);
        assert!(!back.tasks[1].is_flat());
        assert_eq!(back.tasks[1].segments().len(), 3);
    }

    #[test]
    fn wire_serializer_matches_dom() {
        let inst = generate(&SynthParams { n: 40, m: 3, ..Default::default() }, 9);
        assert_eq!(instance_to_wire_string(&inst), instance_to_json(&inst).to_string());
        let shaped = Instance::new(
            shaped_tasks(),
            vec![NodeType::new("a\"b\n", vec![1.0, 1.0], 1.5)],
            7,
        );
        assert_eq!(
            instance_to_wire_string(&shaped),
            instance_to_json(&shaped).to_string()
        );
    }

    #[test]
    fn streaming_decoder_matches_dom() {
        for (inst, label) in [
            (generate(&SynthParams { n: 40, m: 3, ..Default::default() }, 9), "flat"),
            (
                Instance::new(
                    shaped_tasks(),
                    vec![NodeType::new("a", vec![1.0, 1.0], 1.0)],
                    7,
                ),
                "shaped",
            ),
        ] {
            let text = instance_to_json(&inst).to_string();
            let fast = instance_from_slice(text.as_bytes()).expect(label);
            assert_eq!(fast.tasks, inst.tasks, "{label}");
            assert_eq!(fast.node_types, inst.node_types, "{label}");
            assert_eq!(fast.horizon, inst.horizon, "{label}");
        }
        // unknown fields skipped, duplicate keys last-wins, null segments
        // means flat — exactly like the DOM
        let text = r#"{"horizon":4,"extra":{"deep":[1,{"x":2}]},
            "node_types":[{"name":"a","capacity":[1.0],"cost":1.0,"note":7}],
            "tasks":[{"id":1,"id":2,"demand":[0.5],"start":0,"end":2,
                      "segments":null}]}"#;
        let fast = instance_from_slice(text.as_bytes()).unwrap();
        let dom = instance_from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(fast.tasks, dom.tasks);
        assert_eq!(fast.tasks[0].id, 2);
        assert!(fast.tasks[0].is_flat());
    }

    #[test]
    fn streaming_decoder_bails_where_dom_errors() {
        // everything the DOM rejects must come back None (the caller
        // falls back and reports the DOM's canonical error)
        for text in [
            // invalid flat span
            r#"{"horizon":4,"node_types":[{"name":"a","capacity":[1.0],"cost":1.0}],
                "tasks":[{"id":0,"demand":[0.1],"start":3,"end":1}]}"#,
            // beyond-horizon task
            r#"{"horizon":2,"node_types":[{"name":"a","capacity":[1.0],"cost":1.0}],
                "tasks":[{"id":0,"demand":[0.1],"start":0,"end":2}]}"#,
            // declared span disagreeing with segments
            r#"{"horizon":8,"node_types":[{"name":"a","capacity":[1.0],"cost":1.0}],
                "tasks":[{"id":0,"start":0,"end":5,"segments":[
                    {"start":0,"end":1,"demand":[0.1]},
                    {"start":2,"end":4,"demand":[0.2]}]}]}"#,
            // dims mismatch, empty node_types, zero horizon
            r#"{"horizon":4,"node_types":[{"name":"a","capacity":[1.0],"cost":1.0}],
                "tasks":[{"id":0,"demand":[0.1,0.2],"start":0,"end":1}]}"#,
            r#"{"horizon":4,"node_types":[],"tasks":[]}"#,
            r#"{"horizon":0,"node_types":[{"name":"a","capacity":[1.0],"cost":1.0}],
                "tasks":[]}"#,
            // malformed JSON and trailing garbage
            r#"{"horizon":4"#,
            r#"{"horizon":4,"node_types":[{"name":"a","capacity":[1.0],"cost":1.0}],
                "tasks":[]} extra"#,
        ] {
            assert!(instance_from_slice(text.as_bytes()).is_none(), "{text}");
            assert!(
                json::parse(text).is_err()
                    || instance_from_json(&json::parse(text).unwrap()).is_err(),
                "DOM must also reject: {text}"
            );
        }
    }

    #[test]
    fn csv_roundtrip() {
        let inst = generate(&SynthParams { n: 15, m: 2, ..Default::default() }, 6);
        let dir = std::env::temp_dir().join("tlrs_test_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        save_trace_csv(&inst.tasks, &path).unwrap();
        let back = load_trace_csv(&path).unwrap();
        assert_eq!(inst.tasks, back);
    }

    #[test]
    fn shaped_csv_roundtrip() {
        let tasks = shaped_tasks();
        let dir = std::env::temp_dir().join("tlrs_test_csv_shaped");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        save_trace_csv(&tasks, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // one continuation row per extra segment
        assert_eq!(text.lines().filter(|l| l.starts_with('+')).count(), 2, "{text}");
        let back = load_trace_csv(&path).unwrap();
        assert_eq!(tasks, back);
    }

    #[test]
    fn csv_rejects_malformed() {
        let dir = std::env::temp_dir().join("tlrs_test_csv2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "id,start,end,dem0\n1,2\n").unwrap();
        assert!(load_trace_csv(&path).is_err());
    }

    #[test]
    fn csv_malformed_rows_error_not_panic() {
        let dir = std::env::temp_dir().join("tlrs_test_csv3");
        std::fs::create_dir_all(&dir).unwrap();
        let cases: &[(&str, &str)] = &[
            // the seed panicked on this one inside Task::new
            ("id,start,end,dem0\n1,5,4,0.1\n", "end 4 < start 5"),
            ("id,start,end,dem0\n1,0,2,NaN\n", "finite"),
            // end + 1 must stay representable (horizon = last end + 1)
            ("id,start,end,dem0\n1,0,4294967295,0.1\n", "out of range"),
            ("id,start,end,dem0\n1,0,2,-0.5\n", "finite"),
            // continuation without a task row
            ("id,start,end,dem0\n+,0,2,0.1\n", "without a preceding"),
            // continuation with a gap
            ("id,start,end,dem0\n1,0,2,0.1\n+,4,5,0.2\n", "contiguous"),
            // continuation overlapping its predecessor
            ("id,start,end,dem0\n1,0,2,0.1\n+,2,5,0.2\n", "contiguous"),
        ];
        for (i, (content, needle)) in cases.iter().enumerate() {
            let path = dir.join(format!("bad{i}.csv"));
            std::fs::write(&path, content).unwrap();
            let err = match load_trace_csv(&path) {
                Err(e) => format!("{e:#}"),
                Ok(t) => panic!("case {i} parsed: {t:?}"),
            };
            assert!(err.contains(needle), "case {i}: {err}");
        }
    }

    #[test]
    fn json_rejects_malformed_tasks() {
        // invalid flat span
        let v = json::parse(
            r#"{"horizon": 4, "node_types": [{"name":"a","capacity":[1.0],"cost":1.0}],
                "tasks": [{"id":0,"demand":[0.1],"start":3,"end":1}]}"#,
        )
        .unwrap();
        assert!(instance_from_json(&v).is_err());
        // gap between segments
        let v = json::parse(
            r#"{"horizon": 8, "node_types": [{"name":"a","capacity":[1.0],"cost":1.0}],
                "tasks": [{"id":0,"start":0,"end":5,"segments":[
                    {"start":0,"end":1,"demand":[0.1]},
                    {"start":3,"end":5,"demand":[0.2]}]}]}"#,
        )
        .unwrap();
        let err = instance_from_json(&v).unwrap_err().to_string();
        assert!(err.contains("contiguous"), "{err}");
        // declared span disagreeing with segments
        let v = json::parse(
            r#"{"horizon": 8, "node_types": [{"name":"a","capacity":[1.0],"cost":1.0}],
                "tasks": [{"id":0,"start":0,"end":5,"segments":[
                    {"start":0,"end":1,"demand":[0.1]},
                    {"start":2,"end":4,"demand":[0.2]}]}]}"#,
        )
        .unwrap();
        let err = instance_from_json(&v).unwrap_err().to_string();
        assert!(err.contains("does not match"), "{err}");
    }

    #[test]
    fn files_io_errors_surface() {
        assert!(load_instance(Path::new("/nonexistent/inst.json")).is_err());
        assert!(load_trace_csv(Path::new("/nonexistent/trace.csv")).is_err());
    }
}
