//! On-disk formats: instances as JSON, task traces as CSV.
//!
//! The CSV trace format mirrors the processed GCT-2019 table the paper
//! builds from BigQuery: one task per line, `id,start,end,dem0,dem1,...`.
//! Node-type catalogs live in the JSON instance format.

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::{Instance, NodeType, Solution, Task};
use crate::util::json::{self, Json};

// ---------- JSON instance format ----------------------------------------

pub fn instance_to_json(inst: &Instance) -> Json {
    Json::obj(vec![
        ("horizon", Json::Num(inst.horizon as f64)),
        (
            "node_types",
            Json::Arr(
                inst.node_types
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("name", Json::Str(b.name.clone())),
                            ("capacity", Json::arr_f64(&b.capacity)),
                            ("cost", Json::Num(b.cost)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "tasks",
            Json::Arr(
                inst.tasks
                    .iter()
                    .map(|u| {
                        Json::obj(vec![
                            ("id", Json::Num(u.id as f64)),
                            ("demand", Json::arr_f64(&u.demand)),
                            ("start", Json::Num(u.start as f64)),
                            ("end", Json::Num(u.end as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

pub fn instance_from_json(v: &Json) -> Result<Instance> {
    let horizon = v
        .get("horizon")
        .as_usize()
        .context("instance: missing horizon")? as u32;
    let mut node_types = Vec::new();
    for b in v.get("node_types").as_arr().context("instance: node_types")? {
        node_types.push(NodeType::new(
            b.get("name").as_str().unwrap_or("unnamed"),
            b.get("capacity").to_f64_vec().context("node_type capacity")?,
            b.get("cost").as_f64().context("node_type cost")?,
        ));
    }
    let mut tasks = Vec::new();
    for t in v.get("tasks").as_arr().context("instance: tasks")? {
        let start = t.get("start").as_usize().context("task start")? as u32;
        let end = t.get("end").as_usize().context("task end")? as u32;
        let demand = t.get("demand").to_f64_vec().context("task demand")?;
        if end < start || demand.is_empty() {
            bail!("task with invalid span [{start},{end}] or empty demand");
        }
        tasks.push(Task::new(
            t.get("id").as_f64().context("task id")? as u64,
            demand,
            start,
            end,
        ));
    }
    // Validate before Instance::new, which treats violations as programmer
    // errors (panics) — external input must fail gracefully instead.
    if node_types.is_empty() {
        bail!("instance has no node-types");
    }
    if horizon == 0 {
        bail!("instance has zero horizon");
    }
    let dims = node_types[0].dims();
    for b in &node_types {
        if b.dims() != dims {
            bail!("node-type {} has {} dims, expected {dims}", b.name, b.dims());
        }
    }
    for u in &tasks {
        if u.dims() != dims {
            bail!("task {} has {} dims, expected {dims}", u.id, u.dims());
        }
        if u.end >= horizon {
            bail!("task {} extends beyond horizon {horizon}", u.id);
        }
    }
    Ok(Instance::new(tasks, node_types, horizon))
}

pub fn save_instance(inst: &Instance, path: &Path) -> Result<()> {
    fs::write(path, instance_to_json(inst).to_string())
        .with_context(|| format!("writing {}", path.display()))
}

pub fn load_instance(path: &Path) -> Result<Instance> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let v = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    instance_from_json(&v)
}

// ---------- CSV trace format ---------------------------------------------

/// Write tasks as `id,start,end,dem0,dem1,...` with a header line.
pub fn save_trace_csv(tasks: &[Task], path: &Path) -> Result<()> {
    let dims = tasks.first().map(|t| t.dims()).unwrap_or(0);
    let mut out = String::from("id,start,end");
    for d in 0..dims {
        out.push_str(&format!(",dem{d}"));
    }
    out.push('\n');
    for t in tasks {
        out.push_str(&format!("{},{},{}", t.id, t.start, t.end));
        for &x in &t.demand {
            out.push_str(&format!(",{x}"));
        }
        out.push('\n');
    }
    fs::write(path, out).with_context(|| format!("writing {}", path.display()))
}

/// Load tasks from the CSV trace format. Rows with missing fields are
/// rejected (the paper purges them from the sampled trace).
pub fn load_trace_csv(path: &Path) -> Result<Vec<Task>> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut lines = text.lines();
    let header = lines.next().context("empty trace file")?;
    let dims = header.split(',').count().saturating_sub(3);
    if dims == 0 {
        bail!("trace header has no demand columns: {header}");
    }
    let mut tasks = Vec::new();
    for (lineno, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        if fields.len() != dims + 3 {
            bail!("line {}: expected {} fields, got {}", lineno + 2, dims + 3, fields.len());
        }
        let id: u64 = fields[0].parse().with_context(|| format!("line {}: id", lineno + 2))?;
        let start: u32 = fields[1].parse().context("start")?;
        let end: u32 = fields[2].parse().context("end")?;
        let demand: Vec<f64> = fields[3..]
            .iter()
            .map(|f| f.parse::<f64>())
            .collect::<Result<_, _>>()
            .with_context(|| format!("line {}: demand", lineno + 2))?;
        tasks.push(Task::new(id, demand, start, end));
    }
    Ok(tasks)
}

// ---------- Solution summary (report artifact) ----------------------------

pub fn solution_to_json(sol: &Solution, inst: &Instance) -> Json {
    Json::obj(vec![
        ("cost", Json::Num(sol.cost(inst))),
        ("n_nodes", Json::Num(sol.nodes.len() as f64)),
        (
            "nodes_per_type",
            Json::Arr(
                sol.nodes_per_type(inst)
                    .iter()
                    .map(|&c| Json::Num(c as f64))
                    .collect(),
            ),
        ),
        (
            "nodes",
            Json::Arr(
                sol.nodes
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("type", Json::Str(inst.node_types[b.type_idx].name.clone())),
                            (
                                "tasks",
                                Json::Arr(
                                    b.tasks.iter().map(|&u| Json::Num(u as f64)).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};

    #[test]
    fn instance_json_roundtrip() {
        let inst = generate(&SynthParams { n: 20, m: 3, ..Default::default() }, 5);
        let v = instance_to_json(&inst);
        let back = instance_from_json(&json::parse(&v.to_string()).unwrap()).unwrap();
        assert_eq!(inst.tasks, back.tasks);
        assert_eq!(inst.node_types, back.node_types);
        assert_eq!(inst.horizon, back.horizon);
    }

    #[test]
    fn csv_roundtrip() {
        let inst = generate(&SynthParams { n: 15, m: 2, ..Default::default() }, 6);
        let dir = std::env::temp_dir().join("tlrs_test_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        save_trace_csv(&inst.tasks, &path).unwrap();
        let back = load_trace_csv(&path).unwrap();
        assert_eq!(inst.tasks, back);
    }

    #[test]
    fn csv_rejects_malformed() {
        let dir = std::env::temp_dir().join("tlrs_test_csv2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "id,start,end,dem0\n1,2\n").unwrap();
        assert!(load_trace_csv(&path).is_err());
    }

    #[test]
    fn files_io_errors_surface() {
        assert!(load_instance(Path::new("/nonexistent/inst.json")).is_err());
        assert!(load_trace_csv(Path::new("/nonexistent/trace.csv")).is_err());
    }
}
