//! On-disk formats: instances as JSON, task traces as CSV.
//!
//! The CSV trace format mirrors the processed GCT-2019 table the paper
//! builds from BigQuery: one task per line, `id,start,end,dem0,dem1,...`.
//! Tasks with piecewise-constant demand profiles write one row per
//! segment: the first segment as a normal task row, each further segment
//! as a continuation row `+,start,end,dem0,...` immediately after it.
//! Node-type catalogs live in the JSON instance format; shaped tasks
//! there carry a `"segments"` array instead of a flat `"demand"`.
//!
//! External data is *validated before construction*: a malformed row
//! (inverted span, non-finite demand, a continuation with a gap) returns
//! the loader's `Result` error instead of tripping `Task::new`'s
//! programmer-error panic.

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::{DemandSeg, Instance, NodeType, Solution, Task};
use crate::util::json::{self, Json};

// ---------- JSON instance format ----------------------------------------

/// Serialize one node-type (the instance-format object shape).
pub fn node_type_to_json(b: &NodeType) -> Json {
    Json::obj(vec![
        ("name", Json::Str(b.name.clone())),
        ("capacity", Json::arr_f64(&b.capacity)),
        ("cost", Json::Num(b.cost)),
    ])
}

/// Serialize one task (flat `"demand"` or `"segments"` — the shared
/// grammar of instance files, service requests and session deltas).
pub fn task_to_json(u: &Task) -> Json {
    let mut fields = vec![
        ("id", Json::Num(u.id as f64)),
    ];
    if u.is_flat() {
        // flat tasks keep the seed's exact format
        fields.push(("demand", Json::arr_f64(u.peak())));
    } else {
        fields.push((
            "segments",
            Json::Arr(
                u.segments()
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("start", Json::Num(s.start as f64)),
                            ("end", Json::Num(s.end as f64)),
                            ("demand", Json::arr_f64(&s.demand)),
                        ])
                    })
                    .collect(),
            ),
        ));
    }
    fields.push(("start", Json::Num(u.start as f64)));
    fields.push(("end", Json::Num(u.end as f64)));
    Json::obj(fields)
}

pub fn instance_to_json(inst: &Instance) -> Json {
    Json::obj(vec![
        ("horizon", Json::Num(inst.horizon as f64)),
        (
            "node_types",
            Json::Arr(inst.node_types.iter().map(node_type_to_json).collect()),
        ),
        (
            "tasks",
            Json::Arr(inst.tasks.iter().map(task_to_json).collect()),
        ),
    ])
}

/// Parse one node-type object (`{"name", "capacity", "cost"}`),
/// validating before construction so malformed external data errors
/// instead of tripping `NodeType::new`'s programmer-error asserts.
pub fn node_type_from_json(b: &Json) -> Result<NodeType> {
    let name = b.get("name").as_str().unwrap_or("unnamed");
    let capacity = b.get("capacity").to_f64_vec().context("node_type capacity")?;
    let cost = b.get("cost").as_f64().context("node_type cost")?;
    if capacity.is_empty() || capacity.iter().any(|c| !c.is_finite() || *c <= 0.0) {
        bail!("node-type {name}: capacity must be non-empty, finite and positive");
    }
    if !cost.is_finite() || cost < 0.0 {
        bail!("node-type {name}: cost must be finite and non-negative");
    }
    Ok(NodeType::new(name, capacity, cost))
}

/// Parse one task object — a flat `"demand"` or a `"segments"` array
/// (the same grammar instance files, service requests and session
/// deltas all share).
pub fn task_from_json(t: &Json) -> Result<Task> {
    // NOTE: the id cast is deliberately lenient (the seed's behavior —
    // legacy one-shot responses are pinned byte-identical). Surfaces
    // where ids are an addressing key (session deltas) enforce strict
    // non-negative-integer ids before calling this (see io::delta).
    let id = t.get("id").as_f64().context("task id")? as u64;
    let start = t.get("start").as_usize().context("task start")? as u32;
    let end = t.get("end").as_usize().context("task end")? as u32;
    match t.get("segments") {
        Json::Null => {
            let demand = t.get("demand").to_f64_vec().context("task demand")?;
            if end < start || demand.is_empty() {
                bail!("task {id} with invalid span [{start},{end}] or empty demand");
            }
            validate_demand(id, &demand)?;
            Ok(Task::new(id, demand, start, end))
        }
        segs_json => {
            let mut segs = Vec::new();
            for s in segs_json.as_arr().context("task segments")? {
                let demand = s.get("demand").to_f64_vec().context("segment demand")?;
                validate_demand(id, &demand)?;
                segs.push(DemandSeg {
                    start: s.get("start").as_usize().context("segment start")? as u32,
                    end: s.get("end").as_usize().context("segment end")? as u32,
                    demand,
                });
            }
            let task = Task::try_piecewise(id, segs)
                .map_err(|e| anyhow::anyhow!("invalid segments: {e}"))?;
            if (task.start, task.end) != (start, end) {
                bail!(
                    "task {id}: declared span [{start},{end}] does not match its \
                     segments [{},{}]",
                    task.start,
                    task.end
                );
            }
            Ok(task)
        }
    }
}

pub fn instance_from_json(v: &Json) -> Result<Instance> {
    let horizon = v
        .get("horizon")
        .as_usize()
        .context("instance: missing horizon")? as u32;
    let mut node_types = Vec::new();
    for b in v.get("node_types").as_arr().context("instance: node_types")? {
        node_types.push(node_type_from_json(b)?);
    }
    let mut tasks = Vec::new();
    for t in v.get("tasks").as_arr().context("instance: tasks")? {
        tasks.push(task_from_json(t)?);
    }
    // Validate before Instance::new, which treats violations as programmer
    // errors (panics) — external input must fail gracefully instead.
    if node_types.is_empty() {
        bail!("instance has no node-types");
    }
    if horizon == 0 {
        bail!("instance has zero horizon");
    }
    let dims = node_types[0].dims();
    for b in &node_types {
        if b.dims() != dims {
            bail!("node-type {} has {} dims, expected {dims}", b.name, b.dims());
        }
    }
    for u in &tasks {
        if u.dims() != dims {
            bail!("task {} has {} dims, expected {dims}", u.id, u.dims());
        }
        if u.end >= horizon {
            bail!("task {} extends beyond horizon {horizon}", u.id);
        }
    }
    Ok(Instance::new(tasks, node_types, horizon))
}

/// Demand values from external sources must be finite and non-negative —
/// a NaN would silently disable the verifier's comparisons downstream.
fn validate_demand(id: u64, demand: &[f64]) -> Result<()> {
    if demand.iter().any(|d| !d.is_finite() || *d < 0.0) {
        bail!("task {id}: demand components must be finite and non-negative");
    }
    Ok(())
}

pub fn save_instance(inst: &Instance, path: &Path) -> Result<()> {
    fs::write(path, instance_to_json(inst).to_string())
        .with_context(|| format!("writing {}", path.display()))
}

pub fn load_instance(path: &Path) -> Result<Instance> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let v = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    instance_from_json(&v)
}

// ---------- CSV trace format ---------------------------------------------

/// Write tasks as `id,start,end,dem0,dem1,...` with a header line. A
/// shaped task writes its first segment as the task row and each further
/// segment as a `+,start,end,dem...` continuation row.
pub fn save_trace_csv(tasks: &[Task], path: &Path) -> Result<()> {
    let dims = tasks.first().map(|t| t.dims()).unwrap_or(0);
    let mut out = String::from("id,start,end");
    for d in 0..dims {
        out.push_str(&format!(",dem{d}"));
    }
    out.push('\n');
    for t in tasks {
        for (i, seg) in t.segments().iter().enumerate() {
            if i == 0 {
                out.push_str(&format!("{},{},{}", t.id, seg.start, seg.end));
            } else {
                out.push_str(&format!("+,{},{}", seg.start, seg.end));
            }
            for &x in &seg.demand {
                out.push_str(&format!(",{x}"));
            }
            out.push('\n');
        }
    }
    fs::write(path, out).with_context(|| format!("writing {}", path.display()))
}

/// Load tasks from the CSV trace format. Rows with missing fields are
/// rejected (the paper purges them from the sampled trace), and so are
/// semantically malformed rows — `end < start`, non-finite demand, or a
/// `+` continuation row that does not extend the previous task
/// contiguously. External data never reaches `Task::new`'s panics.
pub fn load_trace_csv(path: &Path) -> Result<Vec<Task>> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut lines = text.lines();
    let header = lines.next().context("empty trace file")?;
    let dims = header.split(',').count().saturating_sub(3);
    if dims == 0 {
        // deliberately does not echo the line: loader errors can end up
        // in logs/responses, and the "file" may not be a trace at all
        bail!(
            "trace header has {} column(s), need at least 4 (id,start,end,dem0,...)",
            header.split(',').count()
        );
    }
    // (id, accumulated segments) of the task being assembled
    let mut pending: Option<(u64, Vec<DemandSeg>)> = None;
    let mut tasks: Vec<Task> = Vec::new();
    let flush = |pending: &mut Option<(u64, Vec<DemandSeg>)>,
                 tasks: &mut Vec<Task>|
     -> Result<()> {
        if let Some((id, segs)) = pending.take() {
            let task = Task::try_piecewise(id, segs)
                .map_err(|e| anyhow::anyhow!("invalid trace rows: {e}"))?;
            tasks.push(task);
        }
        Ok(())
    };
    for (lineno, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let row = lineno + 2;
        let fields: Vec<&str> = line.split(',').collect();
        if fields.len() != dims + 3 {
            bail!("line {row}: expected {} fields, got {}", dims + 3, fields.len());
        }
        let start: u32 = fields[1]
            .parse()
            .with_context(|| format!("line {row}: start"))?;
        let end: u32 = fields[2].parse().with_context(|| format!("line {row}: end"))?;
        let demand: Vec<f64> = fields[3..]
            .iter()
            .map(|f| f.parse::<f64>())
            .collect::<Result<_, _>>()
            .with_context(|| format!("line {row}: demand"))?;
        // validate *before* any Task construction: loader errors, not panics
        if end < start {
            bail!("line {row}: end {end} < start {start}");
        }
        // keep end + 1 representable: the contiguity check below and every
        // horizon derivation downstream compute it
        if end == u32::MAX {
            bail!("line {row}: end {end} out of range");
        }
        if demand.iter().any(|d| !d.is_finite() || *d < 0.0) {
            bail!("line {row}: demand components must be finite and non-negative");
        }
        let seg = DemandSeg { start, end, demand };
        if fields[0] == "+" {
            let Some((_, segs)) = pending.as_mut() else {
                bail!("line {row}: '+' continuation row without a preceding task row");
            };
            let prev_end = segs.last().expect("pending has a segment").end;
            if start != prev_end + 1 {
                bail!(
                    "line {row}: continuation starts at {start} but the previous \
                     segment ends at {prev_end} (segments must be contiguous)"
                );
            }
            segs.push(seg);
        } else {
            flush(&mut pending, &mut tasks)?;
            let id: u64 = fields[0]
                .parse()
                .with_context(|| format!("line {row}: id"))?;
            pending = Some((id, vec![seg]));
        }
    }
    flush(&mut pending, &mut tasks)?;
    Ok(tasks)
}

// ---------- Solution summary (report artifact) ----------------------------

pub fn solution_to_json(sol: &Solution, inst: &Instance) -> Json {
    Json::obj(vec![
        ("cost", Json::Num(sol.cost(inst))),
        ("n_nodes", Json::Num(sol.nodes.len() as f64)),
        (
            "nodes_per_type",
            Json::Arr(
                sol.nodes_per_type(inst)
                    .iter()
                    .map(|&c| Json::Num(c as f64))
                    .collect(),
            ),
        ),
        (
            "nodes",
            Json::Arr(
                sol.nodes
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("type", Json::Str(inst.node_types[b.type_idx].name.clone())),
                            (
                                "tasks",
                                Json::Arr(
                                    b.tasks.iter().map(|&u| Json::Num(u as f64)).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};

    fn shaped_tasks() -> Vec<Task> {
        vec![
            Task::new(0, vec![0.2, 0.1], 0, 4),
            Task::piecewise(
                1,
                vec![
                    DemandSeg { start: 1, end: 2, demand: vec![0.1, 0.3] },
                    DemandSeg { start: 3, end: 5, demand: vec![0.4, 0.05] },
                    DemandSeg { start: 6, end: 6, demand: vec![0.05, 0.05] },
                ],
            ),
            Task::new(2, vec![0.3, 0.3], 5, 6),
        ]
    }

    #[test]
    fn instance_json_roundtrip() {
        let inst = generate(&SynthParams { n: 20, m: 3, ..Default::default() }, 5);
        let v = instance_to_json(&inst);
        let back = instance_from_json(&json::parse(&v.to_string()).unwrap()).unwrap();
        assert_eq!(inst.tasks, back.tasks);
        assert_eq!(inst.node_types, back.node_types);
        assert_eq!(inst.horizon, back.horizon);
    }

    #[test]
    fn shaped_instance_json_roundtrip() {
        let inst = Instance::new(
            shaped_tasks(),
            vec![NodeType::new("a", vec![1.0, 1.0], 1.0)],
            7,
        );
        let v = instance_to_json(&inst);
        let back = instance_from_json(&json::parse(&v.to_string()).unwrap()).unwrap();
        assert_eq!(inst.tasks, back.tasks);
        assert!(!back.tasks[1].is_flat());
        assert_eq!(back.tasks[1].segments().len(), 3);
    }

    #[test]
    fn csv_roundtrip() {
        let inst = generate(&SynthParams { n: 15, m: 2, ..Default::default() }, 6);
        let dir = std::env::temp_dir().join("tlrs_test_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        save_trace_csv(&inst.tasks, &path).unwrap();
        let back = load_trace_csv(&path).unwrap();
        assert_eq!(inst.tasks, back);
    }

    #[test]
    fn shaped_csv_roundtrip() {
        let tasks = shaped_tasks();
        let dir = std::env::temp_dir().join("tlrs_test_csv_shaped");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        save_trace_csv(&tasks, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // one continuation row per extra segment
        assert_eq!(text.lines().filter(|l| l.starts_with('+')).count(), 2, "{text}");
        let back = load_trace_csv(&path).unwrap();
        assert_eq!(tasks, back);
    }

    #[test]
    fn csv_rejects_malformed() {
        let dir = std::env::temp_dir().join("tlrs_test_csv2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "id,start,end,dem0\n1,2\n").unwrap();
        assert!(load_trace_csv(&path).is_err());
    }

    #[test]
    fn csv_malformed_rows_error_not_panic() {
        let dir = std::env::temp_dir().join("tlrs_test_csv3");
        std::fs::create_dir_all(&dir).unwrap();
        let cases: &[(&str, &str)] = &[
            // the seed panicked on this one inside Task::new
            ("id,start,end,dem0\n1,5,4,0.1\n", "end 4 < start 5"),
            ("id,start,end,dem0\n1,0,2,NaN\n", "finite"),
            // end + 1 must stay representable (horizon = last end + 1)
            ("id,start,end,dem0\n1,0,4294967295,0.1\n", "out of range"),
            ("id,start,end,dem0\n1,0,2,-0.5\n", "finite"),
            // continuation without a task row
            ("id,start,end,dem0\n+,0,2,0.1\n", "without a preceding"),
            // continuation with a gap
            ("id,start,end,dem0\n1,0,2,0.1\n+,4,5,0.2\n", "contiguous"),
            // continuation overlapping its predecessor
            ("id,start,end,dem0\n1,0,2,0.1\n+,2,5,0.2\n", "contiguous"),
        ];
        for (i, (content, needle)) in cases.iter().enumerate() {
            let path = dir.join(format!("bad{i}.csv"));
            std::fs::write(&path, content).unwrap();
            let err = match load_trace_csv(&path) {
                Err(e) => format!("{e:#}"),
                Ok(t) => panic!("case {i} parsed: {t:?}"),
            };
            assert!(err.contains(needle), "case {i}: {err}");
        }
    }

    #[test]
    fn json_rejects_malformed_tasks() {
        // invalid flat span
        let v = json::parse(
            r#"{"horizon": 4, "node_types": [{"name":"a","capacity":[1.0],"cost":1.0}],
                "tasks": [{"id":0,"demand":[0.1],"start":3,"end":1}]}"#,
        )
        .unwrap();
        assert!(instance_from_json(&v).is_err());
        // gap between segments
        let v = json::parse(
            r#"{"horizon": 8, "node_types": [{"name":"a","capacity":[1.0],"cost":1.0}],
                "tasks": [{"id":0,"start":0,"end":5,"segments":[
                    {"start":0,"end":1,"demand":[0.1]},
                    {"start":3,"end":5,"demand":[0.2]}]}]}"#,
        )
        .unwrap();
        let err = instance_from_json(&v).unwrap_err().to_string();
        assert!(err.contains("contiguous"), "{err}");
        // declared span disagreeing with segments
        let v = json::parse(
            r#"{"horizon": 8, "node_types": [{"name":"a","capacity":[1.0],"cost":1.0}],
                "tasks": [{"id":0,"start":0,"end":5,"segments":[
                    {"start":0,"end":1,"demand":[0.1]},
                    {"start":2,"end":4,"demand":[0.2]}]}]}"#,
        )
        .unwrap();
        let err = instance_from_json(&v).unwrap_err().to_string();
        assert!(err.contains("does not match"), "{err}");
    }

    #[test]
    fn files_io_errors_surface() {
        assert!(load_instance(Path::new("/nonexistent/inst.json")).is_err());
        assert!(load_trace_csv(Path::new("/nonexistent/trace.csv")).is_err());
    }
}
