//! TL-Rightsizing: cold-start cluster rightsizing for time-limited tasks.
pub mod model;
pub mod io;
pub mod algo;
pub mod lp;
pub mod runtime;
pub mod coordinator;
pub mod harness;
pub mod sim;
pub mod util;
