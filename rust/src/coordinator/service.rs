//! The planning service: a line-delimited JSON-over-TCP request loop.
//!
//! Request (one line):
//!   {"instance": {<io::files instance format>}, "algorithm": "lp-map-f"}
//! or, generating the workload server-side through the shared registry:
//!   {"workload": "gct:n=500,m=10,priced", "seed": 3, "algorithm": ...}
//! `workload` accepts the same spec language as the CLI `--workload`
//! flag (any registered family; see `io::workload::WORKLOAD_GRAMMAR`) or
//! a JSON object form `{"family": ..., <keys>...}`. `algorithm` accepts
//! the same language as the CLI `--algo` flag (both call
//! `algo::pipeline::parse_portfolio`): preset names, compositions like
//! "lp+fill+ls", the token "portfolio", and comma-separated lists that
//! race in parallel on one LP solve — see `algo::pipeline::SPEC_GRAMMAR`.
//! For a multi-pipeline race the response describes the winner, plus a
//! "raced" array of member costs and (when the certified LP bound let
//! the race abort members early) a "skipped" array of member labels.
//! Workload specs accept the `shape=flat|ramp|diurnal|spike` key on
//! every family (time-varying demand within a task), and inline
//! instances may give any task a piecewise profile via a "segments"
//! array (see `io::files`). The `csv` family is CLI-only: accepting it
//! here would hand untrusted clients server-local file reads, so
//! `source_from_json` rejects it — submit the tasks inline instead.
//! Response (one line):
//!   {"ok": true, "cost": ..., "normalized_cost": ..., "n_nodes": ...,
//!    "nodes_per_type": [...], "backend": "...", "seconds": ...,
//!    "stages": [{"stage": "...", "seconds": ...}, ...]}
//! or {"ok": false, "error": "..."}.
//!
//! Python never serves requests; this loop is the deployable L3 artifact.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::io::files;
use crate::model::trim;
use crate::util::json::{self, Json};

use super::planner::Planner;

/// Handle one request line; always returns a JSON response line.
pub fn handle_request(planner: &Planner, line: &str) -> String {
    match handle_inner(planner, line) {
        Ok(v) => v.to_string(),
        Err(e) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str(format!("{e:#}"))),
        ])
        .to_string(),
    }
}

fn handle_inner(planner: &Planner, line: &str) -> Result<Json> {
    let req = json::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
    // either an inline instance or a server-side generated workload
    let mut workload_used: Option<(String, u64)> = None;
    let inst = match (req.get("instance"), req.get("workload")) {
        (Json::Null, Json::Null) => {
            anyhow::bail!("request needs an 'instance' or a 'workload'")
        }
        (inst_json, Json::Null) => {
            files::instance_from_json(inst_json).context("instance")?
        }
        (Json::Null, w) => {
            let source = crate::io::workload::source_from_json(w)?;
            let seed = match req.get("seed") {
                Json::Null => 1,
                s => s
                    .as_usize()
                    .context("'seed' must be a non-negative integer")?
                    as u64,
            };
            workload_used = Some((source.label(), seed));
            source.generate(seed)?
        }
        _ => anyhow::bail!("request has both 'instance' and 'workload'"),
    };
    anyhow::ensure!(inst.n_tasks() > 0, "empty instance");
    let algo = req.get("algorithm").as_str().unwrap_or("lp-map-f");
    let t0 = std::time::Instant::now();

    let tr = trim(&inst).instance;
    let (solver, backend) = planner.solver_for(&tr);
    let portfolio = crate::algo::pipeline::parse_portfolio(algo)?;
    let race = portfolio.run(&tr, solver.as_ref())?;
    let rep = race.best();
    let lb = race.certified_lb();
    let solution = &rep.solution;
    solution
        .verify(&tr)
        .map_err(|v| anyhow::anyhow!("internal: infeasible solution: {v:?}"))?;
    let cost = solution.cost(&tr);
    let seconds = t0.elapsed().as_secs_f64();
    planner.metrics.inc("service_requests", 1);

    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("algorithm", Json::Str(algo.to_string())),
        ("cost", Json::Num(cost)),
        ("n_nodes", Json::Num(solution.nodes.len() as f64)),
        (
            "nodes_per_type",
            Json::Arr(
                solution
                    .nodes_per_type(&tr)
                    .iter()
                    .map(|&c| Json::Num(c as f64))
                    .collect(),
            ),
        ),
        ("backend", Json::Str(backend.to_string())),
        ("seconds", Json::Num(seconds)),
        (
            // array, not an object: a spec may repeat a stage (ls:2+ls:8)
            "stages",
            Json::Arr(
                rep.stages
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("stage", Json::Str(s.stage.clone())),
                            ("seconds", Json::Num(s.seconds)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    if let Some((label, seed)) = workload_used {
        fields.push(("workload", Json::Str(label)));
        fields.push(("seed", Json::Num(seed as f64)));
    }
    if let Some(lb) = lb {
        fields.push(("lower_bound", Json::Num(lb)));
        fields.push(("normalized_cost", Json::Num(cost / lb.max(1e-12))));
    }
    if race.reports.len() + race.skipped.len() > 1 {
        fields.push(("winner", Json::Str(rep.label.clone())));
        fields.push((
            "raced",
            Json::Arr(
                race.reports
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("algorithm", Json::Str(r.label.clone())),
                            ("cost", Json::Num(r.cost)),
                        ])
                    })
                    .collect(),
            ),
        ));
        if !race.skipped.is_empty() {
            // members the certified LP bound proved could not beat a
            // finished incumbent (early abort) — no cost to report
            fields.push((
                "skipped",
                Json::Arr(race.skipped.iter().map(|l| Json::Str(l.clone())).collect()),
            ));
        }
    }
    Ok(Json::obj(fields))
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7077"). Connections are
/// handled sequentially on the accept thread: the PJRT client underneath
/// the artifact backend is deliberately not shared across threads (the
/// xla handle is not Sync), and on this single-solver deployment a solve
/// saturates the machine anyway. Each connection may pipeline many
/// request lines.
pub fn serve(planner: Arc<Planner>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("tlrs planning service on {addr}");
    for stream in listener.incoming() {
        let stream = stream?;
        if let Err(e) = serve_connection(&planner, stream) {
            eprintln!("connection error: {e:#}");
        }
    }
    Ok(())
}

/// Handle one client connection (used directly by tests).
pub fn serve_connection(planner: &Planner, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_request(planner, &line);
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    let _ = peer;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::Backend;
    use crate::io::synth::{generate, SynthParams};

    fn planner() -> Planner {
        Planner::new(Backend::Native).unwrap()
    }

    #[test]
    fn request_roundtrip() {
        let p = planner();
        let inst = generate(&SynthParams { n: 40, m: 3, ..Default::default() }, 4);
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("lp-map-f".into())),
        ]);
        let resp = handle_request(&p, &req.to_string());
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{resp}");
        assert!(v.get("cost").as_f64().unwrap() > 0.0);
        assert!(v.get("normalized_cost").as_f64().unwrap() >= 1.0 - 1e-6);
    }

    #[test]
    fn malformed_requests_dont_crash() {
        let p = planner();
        for bad in ["not json", "{}", r#"{"instance": 3}"#,
                    r#"{"instance": {"horizon": 1, "node_types": [], "tasks": []}}"#] {
            let resp = handle_request(&p, bad);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").as_bool(), Some(false), "input {bad}: {resp}");
        }
    }

    #[test]
    fn comma_list_races_and_reports_the_winner() {
        let p = planner();
        let inst = generate(&SynthParams { n: 30, m: 3, ..Default::default() }, 6);
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("penalty-map-f,lp-map-f".into())),
        ]);
        let resp = handle_request(&p, &req.to_string());
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{resp}");
        let raced = v.get("raced").as_arr().unwrap();
        assert_eq!(raced.len(), 2);
        assert!(v.get("winner").as_str().is_some());
        // the penalty winner case still certifies the shared-LP bound
        assert!(v.get("lower_bound").as_f64().unwrap() > 0.0);
        let cost = v.get("cost").as_f64().unwrap();
        for r in raced {
            assert!(cost <= r.get("cost").as_f64().unwrap() + 1e-9);
        }
    }

    #[test]
    fn workload_spec_requests() {
        let p = planner();
        // spec-string form, any registered family
        let req = Json::obj(vec![
            ("workload", Json::Str("mixed:services=15,m=3".into())),
            ("seed", Json::Num(4.0)),
            ("algorithm", Json::Str("lp-map-f".into())),
        ]);
        let resp = handle_request(&p, &req.to_string());
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{resp}");
        assert_eq!(v.get("workload").as_str(), Some("mixed:m=3,services=15"));
        assert_eq!(v.get("seed").as_usize(), Some(4));
        // the generated instance matches a client-side generation
        let inst = crate::io::workload::parse_workload("mixed:services=15,m=3")
            .unwrap()
            .generate(4)
            .unwrap();
        let req2 = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("lp-map-f".into())),
        ]);
        let v2 = json::parse(&handle_request(&p, &req2.to_string())).unwrap();
        assert_eq!(v.get("cost").as_f64(), v2.get("cost").as_f64(), "{resp}");

        // JSON object form with the fixed cost model
        let req = Json::obj(vec![
            (
                "workload",
                json::parse(
                    r#"{"family": "synth", "n": 30, "m": 3, "dims": 2,
                        "cost_model": "fixed", "coefficients": [2.0, 1.0]}"#,
                )
                .unwrap(),
            ),
            ("algorithm", Json::Str("penalty-map-f".into())),
        ]);
        let v = json::parse(&handle_request(&p, &req.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true));

        // non-integer seeds are rejected, not silently defaulted
        let req = Json::obj(vec![
            ("workload", Json::Str("synth:n=10,m=2".into())),
            ("seed", Json::Str("7".into())),
        ]);
        let v = json::parse(&handle_request(&p, &req.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(v.get("error").as_str().unwrap().contains("seed"), "{v:?}");

        // bad specs fail with the family catalog, not a crash
        let req = Json::obj(vec![("workload", Json::Str("warp:n=3".into()))]);
        let resp = handle_request(&p, &req.to_string());
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(v.get("error").as_str().unwrap().contains("invalid workload spec"));
        // both instance and workload is ambiguous
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("workload", Json::Str("synth".into())),
        ]);
        let v = json::parse(&handle_request(&p, &req.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
    }

    #[test]
    fn unknown_algorithm_rejected() {
        let p = planner();
        let inst = generate(&SynthParams { n: 10, m: 2, ..Default::default() }, 1);
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("magic".into())),
        ]);
        let resp = handle_request(&p, &req.to_string());
        assert!(resp.contains("unknown algorithm"));
    }
}
