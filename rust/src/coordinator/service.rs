//! The planning service: a line-delimited JSON-over-TCP request loop.
//!
//! ## One-shot solves (the legacy request shape, unchanged)
//!
//! A request without an `"op"` field is a one-shot solve:
//!   {"instance": {<io::files instance format>}, "algorithm": "lp-map-f"}
//! or, generating the workload server-side through the shared registry:
//!   {"workload": "gct:n=500,m=10,priced", "seed": 3, "algorithm": ...}
//! `workload` accepts the same spec language as the CLI `--workload`
//! flag (any registered family; see `io::workload::WORKLOAD_GRAMMAR`) or
//! a JSON object form `{"family": ..., <keys>...}`. `algorithm` accepts
//! the same language as the CLI `--algo` flag (both call
//! `algo::pipeline::parse_portfolio`): preset names, compositions like
//! "lp+fill+ls", the token "portfolio", and comma-separated lists that
//! race in parallel on one LP solve — see `algo::pipeline::SPEC_GRAMMAR`.
//! For a multi-pipeline race the response describes the winner, plus a
//! "raced" array of member costs and (when the certified LP bound let
//! the race abort members early) a "skipped" array of member labels.
//! Workload specs accept the `shape=flat|ramp|diurnal|spike` key on
//! every family (time-varying demand within a task), and inline
//! instances may give any task a piecewise profile via a "segments"
//! array (see `io::files`). The `csv` family is CLI-only: accepting it
//! here would hand untrusted clients server-local file reads, so
//! `source_from_json` rejects it — submit the tasks inline instead.
//! Response (one line):
//!   {"ok": true, "cost": ..., "normalized_cost": ..., "n_nodes": ...,
//!    "nodes_per_type": [...], "backend": "...", "seconds": ...,
//!    "stages": [{"stage": "...", "seconds": ...}, ...]}
//! or {"ok": false, "error": "..."}.
//!
//! ## Plan sessions (the `"op"` verb layer)
//!
//! A request with an `"op"` field speaks to the stateful session layer
//! (`coordinator::session`): open a plan once, then answer workload
//! *deltas* incrementally instead of re-solving from scratch.
//!
//!   {"op": "open", "instance"|"workload": ..., ["seed": S,]
//!    ["algorithm": <spec>,] ["escalate": 1.5 | "off",] ["fit": "ff"|"sim"]}
//!       -> {"ok": true, "op": "open", "session": <id>, "cost": ...,
//!           "lower_bound": ..., "n_tasks": ..., "n_nodes": ...}
//!   {"op": "delta", "session": <id>, "deltas": <delta> | [<delta>...]}
//!       applies each delta in order; see `io::delta::DELTA_GRAMMAR` for
//!       the delta objects (admit / retire / reshape / reprice). Each is
//!       answered incrementally — untouched placements kept, affected
//!       nodes repaired — escalating to a full warm-started re-solve
//!       when the incremental cost drifts past `escalate` × the
//!       refreshed certified LB (the knob set at open; default 1.5,
//!       "off" disables). Every delta's answer is per-slot verified.
//!       -> {"ok": true, "op": "delta", "applied": [{"op", "decision":
//!           "repair"|"resolve", "cost", "lower_bound", ...}...], ...}
//!       On a mid-batch error the response is {"ok": false, ...} and
//!       names how many deltas of the batch were already applied (they
//!       stay applied — deltas are not transactional across a batch).
//!   {"op": "query", "session": <id>, "delta": <delta>}
//!       what-if: prices the delta on a copy of the session without
//!       committing it.
//!   {"op": "close", "session": <id>}   -> final summary, frees the id.
//!   {"op": "stats"}                    -> `Metrics::report()` counters,
//!       gauges (live/peak connections, queue depth) and latency
//!       histograms (p50/p95/max, including per-verb `request.<verb>`
//!       series) plus open-session count — the deployed server's
//!       introspection endpoint.
//!   {"op": "shutdown"}                 -> begin a graceful drain (only
//!       under `tlrs serve --allow-shutdown`; refused otherwise).
//!
//! Sessions are shared across connections (per-session locking) and
//! capped at `session::MAX_SESSIONS`.
//!
//! ## The runtime underneath
//!
//! `serve` runs on `coordinator::runtime`: an accept thread feeding a
//! bounded worker pool, admission control that sheds excess connections
//! with a typed `{"ok":false,"error":"overloaded","retry_after_ms":...}`
//! line, per-request time/size budgets, and graceful shutdown that
//! drains every in-flight request before closing sessions. At
//! `--workers 1 --queue 0` the runtime degenerates to the seed's
//! strictly sequential behavior (same `handle_request` path, byte-
//! identical responses). See the `runtime` module doc for the contract.
//!
//! ## The wire layer
//!
//! Requests enter through the two-tier wire layer (`util::wire`): the
//! hot shapes — an inline `instance` object, `delta`/`deltas` payloads —
//! pull-parse straight into typed structs with zero intermediate DOM,
//! and every response is direct-written by `util::wire::JsonWriter`
//! instead of being built as a `Json` tree and serialized. Anything the
//! typed decoders do not recognize falls back to the DOM path
//! (`util::json`), which owns all error reporting — so responses,
//! including every error string, stay byte-identical to the DOM-only
//! service (pinned by `tests/prop_wire.rs`).
//!
//! Python never serves requests; this loop is the deployable L3 artifact.

use std::collections::BTreeMap;
use std::net::TcpStream;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::io::delta as iodelta;
use crate::io::files;
use crate::lp::pdhg;
use crate::model::{trim, Delta, Instance};
use crate::util::json::{self, Json};
use crate::util::wire::{self, Event, JsonPull, JsonWriter};

use super::planner::Planner;
use super::runtime;
use super::session::{self, DeltaReport, PlanSession, SessionConfig};

/// Handle one request line; always returns a JSON response line.
pub fn handle_request(planner: &Planner, line: &str) -> String {
    handle_request_with(planner, line, None).0
}

/// A hot request field: absent (or JSON `null`, which every consumer
/// treats the same), already pull-parsed into its typed form, or left
/// for the DOM path (the value sits in `Envelope::rest` under its key).
enum Hot<T> {
    Absent,
    Typed(T),
    Dom,
}

/// The `deltas`/`delta` payload: one delta object or an array of them.
enum DeltasField {
    One(Delta),
    Many(Vec<Delta>),
}

/// A parsed request envelope. The hot fields (`instance`, `deltas`,
/// `delta`) are pull-parsed straight into typed structs when they have
/// the expected shape; everything else — including hot fields with a
/// surprising shape — lands in `rest` as a DOM value so the legacy
/// code paths (and their exact error strings) still apply.
struct Envelope {
    instance: Hot<Instance>,
    deltas: Hot<DeltasField>,
    delta: Hot<DeltasField>,
    rest: Json,
}

impl Envelope {
    /// Streaming fast path: pull-parse the request bytes. Returns `None`
    /// on *any* surprise — malformed JSON, a hot field that fails its
    /// typed decoder, trailing bytes — and the caller re-runs the DOM
    /// path, which owns the canonical error. Duplicate keys keep the
    /// last occurrence, like the DOM's `BTreeMap` insert.
    fn from_bytes(bytes: &[u8]) -> Option<Envelope> {
        let mut p = JsonPull::new(bytes);
        match p.next().ok()? {
            Some(Event::ObjStart) => {}
            _ => return None,
        }
        let mut instance = Hot::Absent;
        let mut deltas = Hot::Absent;
        let mut delta = Hot::Absent;
        let mut rest: BTreeMap<String, Json> = BTreeMap::new();
        loop {
            match p.next().ok()? {
                Some(Event::Key(k)) => match k.as_ref() {
                    "instance" => {
                        rest.remove("instance");
                        if p.peek_value_byte() == Some(b'{') {
                            instance = Hot::Typed(files::instance_value_from_pull(&mut p)?);
                        } else {
                            match p.parse_value().ok()? {
                                Json::Null => instance = Hot::Absent,
                                v => {
                                    rest.insert("instance".to_string(), v);
                                    instance = Hot::Dom;
                                }
                            }
                        }
                    }
                    key @ ("deltas" | "delta") => {
                        let key = key.to_string();
                        rest.remove(&key);
                        let slot = match p.peek_value_byte() {
                            Some(b'{') => Hot::Typed(DeltasField::One(
                                iodelta::delta_value_from_pull(&mut p)?,
                            )),
                            Some(b'[') => Hot::Typed(DeltasField::Many(
                                iodelta::deltas_array_from_pull(&mut p)?,
                            )),
                            _ => match p.parse_value().ok()? {
                                Json::Null => Hot::Absent,
                                v => {
                                    rest.insert(key.clone(), v);
                                    Hot::Dom
                                }
                            },
                        };
                        if key == "deltas" {
                            deltas = slot;
                        } else {
                            delta = slot;
                        }
                    }
                    key => {
                        let key = key.to_string();
                        let v = p.parse_value().ok()?;
                        rest.insert(key, v);
                    }
                },
                Some(Event::ObjEnd) => break,
                _ => return None,
            }
        }
        matches!(p.next(), Ok(None)).then(|| Envelope {
            instance,
            deltas,
            delta,
            rest: Json::Obj(rest),
        })
    }

    /// DOM fallback: every field stays in `rest`; hot slots just record
    /// presence so the shared dispatch reads them through the DOM.
    fn from_dom(req: Json) -> Envelope {
        fn slot<T>(req: &Json, key: &str) -> Hot<T> {
            if matches!(req.get(key), Json::Null) { Hot::Absent } else { Hot::Dom }
        }
        Envelope {
            instance: slot(&req, "instance"),
            deltas: slot(&req, "deltas"),
            delta: slot(&req, "delta"),
            rest: req,
        }
    }
}

/// `handle_request` plus the runtime's needs: an optional control handle
/// (enables the `shutdown` verb) and the request's verb label for
/// per-verb latency metrics. This is the single dispatch path — the
/// concurrent runtime and the legacy entry points produce byte-identical
/// responses because they both run through here.
pub fn handle_request_with(
    planner: &Planner,
    line: &str,
    ctl: Option<&runtime::RuntimeCtl>,
) -> (String, &'static str) {
    if let Some(mut env) = Envelope::from_bytes(line.as_bytes()) {
        return finish_request(planner, &mut env, ctl);
    }
    match json::parse(line) {
        Ok(req) => finish_request(planner, &mut Envelope::from_dom(req), ctl),
        Err(e) => (error_response(&anyhow::anyhow!("{e}")), "invalid"),
    }
}

/// Byte-slice entry point for the runtime: lets the pull parser consume
/// the request buffer without an up-front UTF-8 validation pass. Only
/// when the streaming decode bails do we validate UTF-8 for the DOM
/// fallback; invalid bytes propagate as a connection error, exactly like
/// the legacy `from_utf8`-first loop.
pub fn handle_request_bytes(
    planner: &Planner,
    bytes: &[u8],
    ctl: Option<&runtime::RuntimeCtl>,
) -> Result<(String, &'static str)> {
    if let Some(mut env) = Envelope::from_bytes(bytes) {
        return Ok(finish_request(planner, &mut env, ctl));
    }
    let line = std::str::from_utf8(bytes)
        .map_err(|e| anyhow!("request line is not valid UTF-8: {e}"))?;
    Ok(match json::parse(line) {
        Ok(req) => finish_request(planner, &mut Envelope::from_dom(req), ctl),
        Err(e) => (error_response(&anyhow::anyhow!("{e}")), "invalid"),
    })
}

fn finish_request(
    planner: &Planner,
    env: &mut Envelope,
    ctl: Option<&runtime::RuntimeCtl>,
) -> (String, &'static str) {
    let verb = verb_of(&env.rest);
    match handle_parsed(planner, env, ctl) {
        Ok(resp) => (resp, verb),
        Err(e) => (error_response(&e), verb),
    }
}

fn error_response(e: &anyhow::Error) -> String {
    let mut w = wire::obj_writer(64);
    w.key("error").str(&format!("{e:#}"));
    w.key("ok").bool(false);
    w.finish_obj()
}

/// Metrics label for a request (the `request.<verb>` histogram key).
fn verb_of(req: &Json) -> &'static str {
    match req.get("op") {
        Json::Null => "solve",
        op => match op.as_str() {
            Some("open") => "open",
            Some("delta") => "delta",
            Some("query") => "query",
            Some("close") => "close",
            Some("stats") => "stats",
            Some("shutdown") => "shutdown",
            _ => "invalid",
        },
    }
}

fn handle_parsed(
    planner: &Planner,
    env: &mut Envelope,
    ctl: Option<&runtime::RuntimeCtl>,
) -> Result<String> {
    let op = match env.rest.get("op") {
        // no 'op': the legacy one-shot solve, byte-identical to pre-
        // session behavior
        Json::Null => None,
        op => Some(
            op.as_str()
                .context("'op' must be a string (open|delta|query|close|stats|shutdown)")?
                .to_string(),
        ),
    };
    match op.as_deref() {
        None => handle_solve(planner, env),
        Some("open") => op_open(planner, env),
        Some("delta") => op_delta(planner, env),
        Some("query") => op_query(planner, env),
        Some("close") => op_close(planner, &env.rest),
        Some("stats") => op_stats(planner),
        Some("shutdown") => op_shutdown(planner, ctl),
        Some(other) => anyhow::bail!(
            "unknown op '{other}' (session verbs: open, delta, query, close, \
             stats, shutdown; omit 'op' for a one-shot solve)"
        ),
    }
}

/// Resolve the instance a request operates on: inline `instance` or a
/// server-side generated `workload` (+ `seed`). Returns the workload
/// label/seed for response echo when generated. The typed slot hands
/// over a ready `Instance` with no DOM in between; the Dom slot re-reads
/// `rest` so malformed inline instances keep their legacy error text.
fn resolve_instance(env: &mut Envelope) -> Result<(Instance, Option<(String, u64)>)> {
    let has_workload = !matches!(env.rest.get("workload"), Json::Null);
    let slot = std::mem::replace(&mut env.instance, Hot::Absent);
    match (slot, has_workload) {
        (Hot::Absent, false) => {
            anyhow::bail!("request needs an 'instance' or a 'workload'")
        }
        (Hot::Typed(_) | Hot::Dom, true) => {
            anyhow::bail!("request has both 'instance' and 'workload'")
        }
        (Hot::Typed(inst), false) => Ok((inst, None)),
        (Hot::Dom, false) => Ok((
            files::instance_from_json(env.rest.get("instance")).context("instance")?,
            None,
        )),
        (Hot::Absent, true) => {
            let source = crate::io::workload::source_from_json(env.rest.get("workload"))?;
            let seed = match env.rest.get("seed") {
                Json::Null => 1,
                s => s
                    .as_usize()
                    .context("'seed' must be a non-negative integer")?
                    as u64,
            };
            let label = source.label();
            let inst = source.generate(seed)?;
            Ok((inst, Some((label, seed))))
        }
    }
}

/// Optional `lp_threads` request field: worker threads for the LP
/// kernels (0 = auto). Requests come from untrusted clients, so the
/// count is validated against the hard cap rather than silently
/// clamped — like the portfolio-spec cap, an out-of-range value is a
/// request error, not a server choice.
fn lp_threads_override(req: &Json) -> Result<Option<usize>> {
    match req.get("lp_threads") {
        Json::Null => Ok(None),
        v => {
            let t = v
                .as_usize()
                .context("'lp_threads' must be a non-negative integer (0 = auto)")?;
            anyhow::ensure!(
                t <= pdhg::MAX_LP_THREADS,
                "lp_threads {t} exceeds the cap of {}",
                pdhg::MAX_LP_THREADS
            );
            Ok(Some(t))
        }
    }
}

/// The legacy one-shot solve path (requests without an 'op' field).
/// With a `decompose` field the solve routes through the partition-
/// decomposed pipeline; the response keeps every legacy field and adds
/// the decomposition telemetry (additive only — requests without
/// `decompose` answer with the exact legacy key set).
fn handle_solve(planner: &Planner, env: &mut Envelope) -> Result<String> {
    let (inst, workload_used) = resolve_instance(env)?;
    anyhow::ensure!(inst.n_tasks() > 0, "empty instance");
    let req = &env.rest;
    let algo = req.get("algorithm").as_str().unwrap_or("lp-map-f");
    let lp_threads = lp_threads_override(req)?;
    // lint:allow(wallclock): request-latency observation for the metrics
    // envelope only — the measured duration never feeds plan math.
    let t0 = std::time::Instant::now();

    match req.get("decompose") {
        Json::Null => {}
        Json::Str(spec) => {
            let spec = crate::algo::decompose::parse_decompose(spec)?;
            return handle_solve_decomposed(
                planner,
                &inst,
                algo,
                &spec,
                lp_threads,
                workload_used,
                t0,
            );
        }
        _ => anyhow::bail!(
            "'decompose' must be a spec string\n{}",
            crate::algo::decompose::DECOMPOSE_GRAMMAR
        ),
    }

    let tr = trim(&inst).instance;
    let (solver, backend) = planner.solver_for_threads(&tr, lp_threads);
    let portfolio = crate::algo::pipeline::parse_portfolio(algo)?;
    let race = portfolio.run(&tr, solver.as_ref())?;
    let rep = race.best();
    let lb = race.certified_lb();
    let solution = &rep.solution;
    solution
        .verify(&tr)
        .map_err(|v| anyhow::anyhow!("internal: infeasible solution: {v:?}"))?;
    let cost = solution.cost(&tr);
    let seconds = t0.elapsed().as_secs_f64();
    planner.metrics.inc("service_requests", 1);

    // direct-write, keys in the DOM's sorted order
    let racing = race.reports.len() + race.skipped.len() > 1;
    let mut w = wire::obj_writer(512);
    w.key("algorithm").str(algo);
    w.key("backend").str(backend);
    w.key("cost").num(cost);
    if let Some(lb) = lb {
        w.key("lower_bound").num(lb);
    }
    if lp_threads.is_some() {
        // echo the resolved count only when the request asked for the
        // knob — legacy requests keep the exact legacy key set
        w.key("lp_threads").num(solver.lp_threads() as f64);
    }
    w.key("n_nodes").num(solution.nodes.len() as f64);
    w.key("nodes_per_type").begin_arr();
    for &c in solution.nodes_per_type(&tr).iter() {
        w.num(c as f64);
    }
    w.end_arr();
    if let Some(lb) = lb {
        w.key("normalized_cost").num(cost / lb.max(1e-12));
    }
    w.key("ok").bool(true);
    if racing {
        w.key("raced").begin_arr();
        for r in &race.reports {
            w.begin_obj();
            w.key("algorithm").str(&r.label);
            w.key("cost").num(r.cost);
            w.end_obj();
        }
        w.end_arr();
    }
    w.key("seconds").num(seconds);
    if let Some((_, seed)) = &workload_used {
        w.key("seed").num(*seed as f64);
    }
    if racing && !race.skipped.is_empty() {
        // members the certified LP bound proved could not beat a
        // finished incumbent (early abort) — no cost to report
        w.key("skipped").begin_arr();
        for l in &race.skipped {
            w.str(l);
        }
        w.end_arr();
    }
    // array, not an object: a spec may repeat a stage (ls:2+ls:8)
    w.key("stages").begin_arr();
    for s in &rep.stages {
        w.begin_obj();
        w.key("seconds").num(s.seconds);
        w.key("stage").str(&s.stage);
        w.end_obj();
    }
    w.end_arr();
    if racing {
        w.key("winner").str(&rep.label);
    }
    if let Some((label, _)) = &workload_used {
        w.key("workload").str(label);
    }
    Ok(w.finish_obj())
}

/// Decomposed variant of the one-shot solve. Response fields are the
/// legacy set plus `decompose`, `sum_partition_bounds`,
/// `congestion_bound`, `pre_stitch_cost` and a `partitions` array —
/// additive only, and only when the request opted in.
fn handle_solve_decomposed(
    planner: &Planner,
    inst: &Instance,
    algo: &str,
    spec: &crate::algo::decompose::DecomposeSpec,
    lp_threads: Option<usize>,
    workload_used: Option<(String, u64)>,
    t0: std::time::Instant,
) -> Result<String> {
    let portfolio = crate::algo::pipeline::parse_portfolio(algo)?;
    let (rep, backend) = planner.solve_decomposed_threads(inst, &portfolio, spec, lp_threads)?;
    let tr = trim(inst).instance;
    rep.solution
        .verify(&tr)
        .map_err(|v| anyhow::anyhow!("internal: infeasible decomposed solution: {v:?}"))?;
    let seconds = t0.elapsed().as_secs_f64();
    planner.metrics.inc("service_requests", 1);

    let lb = rep.certified_lb;
    let mut w = wire::obj_writer(1024);
    w.key("algorithm").str(algo);
    w.key("backend").str(backend);
    w.key("congestion_bound").num(rep.congestion_lb);
    w.key("cost").num(rep.cost);
    w.key("decompose").str(&spec.to_string());
    w.key("lower_bound").num(lb);
    if let Some(t) = lp_threads {
        // resolved total budget (the planner splits it per partition)
        w.key("lp_threads").num(pdhg::resolve_threads(t) as f64);
    }
    w.key("n_nodes").num(rep.solution.nodes.len() as f64);
    w.key("nodes_per_type").begin_arr();
    for &c in rep.solution.nodes_per_type(&tr).iter() {
        w.num(c as f64);
    }
    w.end_arr();
    w.key("normalized_cost").num(rep.cost / lb.max(1e-12));
    w.key("ok").bool(true);
    w.key("partitions").begin_arr();
    for p in &rep.partitions {
        w.begin_obj();
        w.key("cost").num(p.cost);
        w.key("lower_bound").num(p.lb);
        w.key("n_tasks").num(p.n_tasks as f64);
        w.key("partition").str(&p.label);
        w.key("seconds").num(p.seconds);
        w.key("winner").str(&p.winner);
        w.end_obj();
    }
    w.end_arr();
    w.key("pre_stitch_cost").num(rep.pre_stitch_cost);
    w.key("seconds").num(seconds);
    if let Some((_, seed)) = &workload_used {
        w.key("seed").num(*seed as f64);
    }
    w.key("stages").begin_arr();
    for s in &rep.stages {
        w.begin_obj();
        w.key("seconds").num(s.seconds);
        w.key("stage").str(&s.stage);
        w.end_obj();
    }
    w.end_arr();
    w.key("sum_partition_bounds").num(rep.sum_lb);
    if let Some((label, _)) = &workload_used {
        w.key("workload").str(label);
    }
    Ok(w.finish_obj())
}

// ----- session verbs ------------------------------------------------------

/// One per-delta report, direct-written (keys in the DOM's sorted order).
fn write_delta_report(w: &mut JsonWriter<Vec<u8>>, rep: &DeltaReport) {
    w.begin_obj();
    w.key("cost").num(rep.cost);
    w.key("decision").str(rep.decision.as_str());
    w.key("lower_bound").num(rep.lower_bound);
    w.key("n_nodes").num(rep.n_nodes as f64);
    w.key("n_tasks").num(rep.n_tasks as f64);
    w.key("op").str(rep.op);
    if let Some(reason) = &rep.reason {
        w.key("reason").str(reason);
    }
    w.key("seconds").num(rep.seconds);
    w.end_obj();
}

/// Session config from request knobs (`algorithm`, `escalate`, `fit`,
/// `lp_threads`). `default_lp_threads` is the planner-wide knob, used
/// when the request does not carry its own.
fn session_config(req: &Json, default_lp_threads: usize) -> Result<SessionConfig> {
    let mut cfg = SessionConfig::default();
    cfg.lp_threads = lp_threads_override(req)?.unwrap_or(default_lp_threads);
    if let Some(algo) = req.get("algorithm").as_str() {
        cfg.algo = algo.to_string();
    }
    match req.get("escalate") {
        Json::Null => {}
        Json::Num(r) => {
            anyhow::ensure!(
                r.is_finite() && *r >= 1.0,
                "escalate ratio must be >= 1, got {r}"
            );
            cfg.escalate_ratio = Some(*r);
        }
        Json::Str(s) => cfg.escalate_ratio = session::parse_escalate(s)?,
        _ => anyhow::bail!("'escalate' must be a ratio >= 1 or \"off\""),
    }
    match req.get("fit") {
        Json::Null => {}
        Json::Str(s) => cfg.fit = session::parse_fit(s)?,
        _ => anyhow::bail!("'fit' must be \"ff\" or \"sim\""),
    }
    Ok(cfg)
}

fn session_id(req: &Json) -> Result<u64> {
    Ok(req
        .get("session")
        .as_usize()
        .context("'session' must be the id returned by open")? as u64)
}

fn session_handle(
    planner: &Planner,
    req: &Json,
) -> Result<(u64, Arc<std::sync::Mutex<PlanSession>>)> {
    let id = session_id(req)?;
    let handle = planner
        .sessions
        .get(id)
        .ok_or_else(|| anyhow!("no open session {id}"))?;
    Ok((id, handle))
}

/// Lock a session's mutex, turning lock poisoning (a prior request
/// panicked mid-update, so the plan state may be inconsistent) into a
/// typed `{"ok":false,...}` response instead of propagating the panic
/// into this worker. The session stays addressable so the client can
/// still `close` it — close recovers the guard and drops the state.
fn lock_session(
    id: u64,
    handle: &std::sync::Mutex<PlanSession>,
) -> Result<std::sync::MutexGuard<'_, PlanSession>> {
    handle.lock().map_err(|_| {
        anyhow!(
            "session {id} is poisoned: a prior request panicked mid-update; \
             close it and open a new plan"
        )
    })
}

fn op_open(planner: &Planner, env: &mut Envelope) -> Result<String> {
    // cheap early reject: the cap must bound *compute*, not just memory —
    // the authoritative re-check happens inside sessions.insert()
    anyhow::ensure!(
        planner.sessions.count() < session::MAX_SESSIONS,
        "too many open sessions ({}); close one first",
        session::MAX_SESSIONS
    );
    let (inst, workload_used) = resolve_instance(env)?;
    let cfg = session_config(&env.rest, planner.lp_threads())?;
    let algo = cfg.algo.clone();
    let (session, open) =
        planner.metrics.time("session_open", || PlanSession::open(inst, cfg))?;
    let id = planner.sessions.insert(session)?;
    planner.metrics.inc("sessions_opened", 1);
    let mut w = wire::obj_writer(256);
    w.key("algorithm").str(&algo);
    w.key("cost").num(open.cost);
    w.key("lower_bound").num(open.lower_bound);
    w.key("n_nodes").num(open.n_nodes as f64);
    w.key("n_tasks").num(open.n_tasks as f64);
    w.key("ok").bool(true);
    w.key("op").str("open");
    w.key("seconds").num(open.seconds);
    if let Some((_, seed)) = &workload_used {
        w.key("seed").num(*seed as f64);
    }
    w.key("session").num(id as f64);
    w.key("winner").str(&open.label);
    if let Some((label, _)) = &workload_used {
        w.key("workload").str(label);
    }
    Ok(w.finish_obj())
}

/// Pull the delta payload out of the envelope for the `delta` op:
/// `deltas` wins over `delta` when both are present (the DOM rule), the
/// typed slot hands over ready structs, and the Dom slot re-runs the
/// grammar parser on `rest` so every legacy error string survives.
fn take_deltas_field(env: &mut Envelope) -> Result<Vec<Delta>> {
    let (slot, key) = if !matches!(env.deltas, Hot::Absent) {
        (std::mem::replace(&mut env.deltas, Hot::Absent), "deltas")
    } else if !matches!(env.delta, Hot::Absent) {
        (std::mem::replace(&mut env.delta, Hot::Absent), "delta")
    } else {
        anyhow::bail!("the delta op needs a 'deltas' field (one delta object or an array)")
    };
    match slot {
        Hot::Typed(DeltasField::One(d)) => Ok(vec![d]),
        Hot::Typed(DeltasField::Many(ds)) => Ok(ds),
        Hot::Dom => iodelta::deltas_from_json(env.rest.get(key)),
        Hot::Absent => unreachable!("absent slots are rejected above"),
    }
}

fn op_delta(planner: &Planner, env: &mut Envelope) -> Result<String> {
    let (id, handle) = session_handle(planner, &env.rest)?;
    let deltas = take_deltas_field(env)?;
    let mut session = lock_session(id, &handle)?;
    let mut applied = Vec::with_capacity(deltas.len());
    for (i, d) in deltas.iter().enumerate() {
        let rep = session.apply(d).map_err(|e| {
            anyhow!(
                "delta {i} ({}): {e:#} — the {} earlier delta(s) of this batch \
                 stay applied",
                d.op(),
                i
            )
        })?;
        planner.metrics.inc("session_deltas", 1);
        planner.metrics.inc(
            match rep.decision {
                session::Decision::Repair => "session_deltas_incremental",
                session::Decision::Resolve => "session_deltas_resolved",
            },
            1,
        );
        planner.metrics.observe("session_delta", rep.seconds);
        planner.metrics.observe(&format!("session_delta.{}", rep.op), rep.seconds);
        applied.push(rep);
    }
    let mut w = wire::obj_writer(128 + 128 * applied.len());
    w.key("applied").begin_arr();
    for rep in &applied {
        write_delta_report(&mut w, rep);
    }
    w.end_arr();
    w.key("cost").num(session.cost());
    w.key("lower_bound").num(session.lower_bound());
    w.key("n_nodes").num(session.n_nodes() as f64);
    w.key("n_tasks").num(session.n_tasks() as f64);
    w.key("ok").bool(true);
    w.key("op").str("delta");
    w.key("session").num(id as f64);
    Ok(w.finish_obj())
}

fn op_query(planner: &Planner, env: &mut Envelope) -> Result<String> {
    let (id, handle) = session_handle(planner, &env.rest)?;
    let delta = match std::mem::replace(&mut env.delta, Hot::Absent) {
        Hot::Absent => {
            anyhow::bail!("the query op needs a 'delta' field (one delta object)")
        }
        Hot::Typed(DeltasField::One(d)) => d,
        Hot::Typed(DeltasField::Many(_)) => {
            // an array is not a delta object: reproduce the DOM grammar
            // error an array input hits (`get("op")` on a non-object)
            iodelta::delta_from_json(&Json::Arr(Vec::new()))?;
            unreachable!("an array delta always fails the grammar")
        }
        Hot::Dom => iodelta::delta_from_json(env.rest.get("delta"))?,
    };
    let session = lock_session(id, &handle)?;
    let current = session.cost();
    let rep = session.quote(&delta)?;
    planner.metrics.inc("session_queries", 1);
    let mut w = wire::obj_writer(256);
    w.key("cost").num(current);
    w.key("cost_if").num(rep.cost);
    w.key("delta_cost").num(rep.cost - current);
    w.key("ok").bool(true);
    w.key("op").str("query");
    w.key("session").num(id as f64);
    w.key("would");
    write_delta_report(&mut w, &rep);
    Ok(w.finish_obj())
}

fn op_close(planner: &Planner, req: &Json) -> Result<String> {
    let id = session_id(req)?;
    let handle = planner
        .sessions
        .close(id)
        .ok_or_else(|| anyhow!("no open session {id}"))?;
    // a poisoned session is still closable: recover the guard (the state
    // is only read for the summary and dropped right after)
    let session = handle.lock().unwrap_or_else(|e| e.into_inner());
    let (n_deltas, repairs, resolves) = session.delta_counts();
    planner.metrics.inc("sessions_closed", 1);
    let mut w = wire::obj_writer(160);
    w.key("cost").num(session.cost());
    w.key("deltas").num(n_deltas as f64);
    w.key("lower_bound").num(session.lower_bound());
    w.key("n_tasks").num(session.n_tasks() as f64);
    w.key("ok").bool(true);
    w.key("op").str("close");
    w.key("repairs").num(repairs as f64);
    w.key("resolves").num(resolves as f64);
    w.key("session").num(id as f64);
    Ok(w.finish_obj())
}

/// `{"op": "stats"}` — the deployed server's introspection endpoint:
/// every counter, every gauge (current value + all-time peak), every
/// latency histogram (p50/p95/max over the recent window), open-session
/// count, and the human-readable report text.
fn op_stats(planner: &Planner) -> Result<String> {
    // the snapshots come off `BTreeMap`s, so iteration is already in the
    // sorted order the writer requires
    let mut w = wire::obj_writer(2048);
    w.key("counters").begin_obj();
    for (k, v) in planner.metrics.counters_snapshot() {
        w.key(&k).num(v as f64);
    }
    w.end_obj();
    w.key("gauges").begin_obj();
    for (k, g) in planner.metrics.gauges_snapshot() {
        w.key(&k).begin_obj();
        w.key("peak").num(g.peak as f64);
        w.key("value").num(g.value as f64);
        w.end_obj();
    }
    w.end_obj();
    w.key("ok").bool(true);
    w.key("op").str("stats");
    w.key("report").str(&planner.metrics.report());
    w.key("sessions_open").num(planner.sessions.count() as f64);
    w.key("timers").begin_obj();
    for (k, t) in planner.metrics.timers_snapshot() {
        w.key(&k).begin_obj();
        w.key("count").num(t.count as f64);
        w.key("max").num(t.max);
        w.key("mean").num(t.mean());
        w.key("p50").num(t.pct(50.0));
        w.key("p95").num(t.pct(95.0));
        w.key("total").num(t.total);
        w.end_obj();
    }
    w.end_obj();
    Ok(w.finish_obj())
}

/// `{"op": "shutdown"}` — begin a graceful drain: stop accepting, let
/// every in-flight and queued request finish, close all sessions, exit.
/// Only meaningful over the runtime (`tlrs serve`), and only when it was
/// started with `--allow-shutdown`.
fn op_shutdown(planner: &Planner, ctl: Option<&runtime::RuntimeCtl>) -> Result<String> {
    let ctl =
        ctl.context("shutdown is only available over the service runtime (tlrs serve)")?;
    ctl.request_shutdown()?;
    planner.metrics.inc("shutdown_requests", 1);
    let mut w = wire::obj_writer(80);
    w.key("draining").bool(true);
    w.key("ok").bool(true);
    w.key("op").str("shutdown");
    w.key("sessions_open").num(planner.sessions.count() as f64);
    Ok(w.finish_obj())
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7077") with default runtime
/// knobs. See [`serve_with`].
pub fn serve(planner: Arc<Planner>, addr: &str) -> Result<()> {
    serve_with(planner, addr, runtime::RuntimeConfig::default())
}

/// Serve on `addr` over the concurrent runtime (`coordinator::runtime`):
/// an accept thread feeding `cfg.workers` connection workers with a
/// bounded queue, shedding excess connections with a typed "overloaded"
/// line, enforcing per-request time/size budgets, and draining
/// gracefully on shutdown. Each connection may pipeline many request
/// lines. Blocks until the runtime shuts down (fatal accept error, or
/// `{"op":"shutdown"}` under `cfg.allow_shutdown`).
pub fn serve_with(
    planner: Arc<Planner>,
    addr: &str,
    cfg: runtime::RuntimeConfig,
) -> Result<()> {
    let rt = runtime::ServiceRuntime::bind(planner, addr, cfg)?;
    let c = rt.config();
    eprintln!(
        "tlrs planning service on {} ({} workers, queue {}, request timeout {:.0}s, \
         max request {} bytes{})",
        rt.local_addr(),
        c.workers,
        c.queue,
        c.request_timeout.as_secs_f64(),
        c.max_request_bytes,
        if c.allow_shutdown { ", shutdown enabled" } else { "" },
    );
    rt.run()
}

/// Handle one client connection on the calling thread (used directly by
/// tests): the single-connection primitive the runtime's workers run,
/// with default budgets and no shutdown/control surface.
pub fn serve_connection(planner: &Planner, stream: TcpStream) -> Result<()> {
    runtime::handle_connection(planner, stream, &runtime::ConnBudget::default(), None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::Backend;
    use crate::io::synth::{generate, SynthParams};

    fn planner() -> Planner {
        Planner::new(Backend::Native).unwrap()
    }

    #[test]
    fn request_roundtrip() {
        let p = planner();
        let inst = generate(&SynthParams { n: 40, m: 3, ..Default::default() }, 4);
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("lp-map-f".into())),
        ]);
        let resp = handle_request(&p, &req.to_string());
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{resp}");
        assert!(v.get("cost").as_f64().unwrap() > 0.0);
        assert!(v.get("normalized_cost").as_f64().unwrap() >= 1.0 - 1e-6);
    }

    #[test]
    fn malformed_requests_dont_crash() {
        let p = planner();
        for bad in ["not json", "{}", r#"{"instance": 3}"#,
                    r#"{"instance": {"horizon": 1, "node_types": [], "tasks": []}}"#] {
            let resp = handle_request(&p, bad);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").as_bool(), Some(false), "input {bad}: {resp}");
        }
    }

    #[test]
    fn comma_list_races_and_reports_the_winner() {
        let p = planner();
        let inst = generate(&SynthParams { n: 30, m: 3, ..Default::default() }, 6);
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("penalty-map-f,lp-map-f".into())),
        ]);
        let resp = handle_request(&p, &req.to_string());
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{resp}");
        let raced = v.get("raced").as_arr().unwrap();
        assert_eq!(raced.len(), 2);
        assert!(v.get("winner").as_str().is_some());
        // the penalty winner case still certifies the shared-LP bound
        assert!(v.get("lower_bound").as_f64().unwrap() > 0.0);
        let cost = v.get("cost").as_f64().unwrap();
        for r in raced {
            assert!(cost <= r.get("cost").as_f64().unwrap() + 1e-9);
        }
    }

    #[test]
    fn workload_spec_requests() {
        let p = planner();
        // spec-string form, any registered family
        let req = Json::obj(vec![
            ("workload", Json::Str("mixed:services=15,m=3".into())),
            ("seed", Json::Num(4.0)),
            ("algorithm", Json::Str("lp-map-f".into())),
        ]);
        let resp = handle_request(&p, &req.to_string());
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{resp}");
        assert_eq!(v.get("workload").as_str(), Some("mixed:m=3,services=15"));
        assert_eq!(v.get("seed").as_usize(), Some(4));
        // the generated instance matches a client-side generation
        let inst = crate::io::workload::parse_workload("mixed:services=15,m=3")
            .unwrap()
            .generate(4)
            .unwrap();
        let req2 = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("lp-map-f".into())),
        ]);
        let v2 = json::parse(&handle_request(&p, &req2.to_string())).unwrap();
        assert_eq!(v.get("cost").as_f64(), v2.get("cost").as_f64(), "{resp}");

        // JSON object form with the fixed cost model
        let req = Json::obj(vec![
            (
                "workload",
                json::parse(
                    r#"{"family": "synth", "n": 30, "m": 3, "dims": 2,
                        "cost_model": "fixed", "coefficients": [2.0, 1.0]}"#,
                )
                .unwrap(),
            ),
            ("algorithm", Json::Str("penalty-map-f".into())),
        ]);
        let v = json::parse(&handle_request(&p, &req.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true));

        // non-integer seeds are rejected, not silently defaulted
        let req = Json::obj(vec![
            ("workload", Json::Str("synth:n=10,m=2".into())),
            ("seed", Json::Str("7".into())),
        ]);
        let v = json::parse(&handle_request(&p, &req.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(v.get("error").as_str().unwrap().contains("seed"), "{v:?}");

        // bad specs fail with the family catalog, not a crash
        let req = Json::obj(vec![("workload", Json::Str("warp:n=3".into()))]);
        let resp = handle_request(&p, &req.to_string());
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(v.get("error").as_str().unwrap().contains("invalid workload spec"));
        // both instance and workload is ambiguous
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("workload", Json::Str("synth".into())),
        ]);
        let v = json::parse(&handle_request(&p, &req.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
    }

    #[test]
    fn decomposed_solve_request_roundtrip() {
        let p = planner();
        let inst = generate(&SynthParams { n: 60, m: 3, ..Default::default() }, 7);
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("penalty-map,penalty-map-f".into())),
            ("decompose", Json::Str("window:3".into())),
        ]);
        let v = json::parse(&handle_request(&p, &req.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("decompose").as_str(), Some("window:3"));
        let cost = v.get("cost").as_f64().unwrap();
        let lb = v.get("lower_bound").as_f64().unwrap();
        assert!(lb > 0.0 && lb <= cost + 1e-6, "{v:?}");
        assert!(v.get("pre_stitch_cost").as_f64().unwrap() >= cost - 1e-9);
        let parts = v.get("partitions").as_arr().unwrap();
        assert_eq!(parts.len(), 3);
        let total: usize = parts.iter().map(|p| p.get("n_tasks").as_usize().unwrap()).sum();
        assert_eq!(total, 60);
        assert!(parts[0].get("winner").as_str().is_some());
        // stage telemetry includes the stitch pass
        let stages = v.get("stages").as_arr().unwrap();
        assert!(stages.iter().any(|s| s.get("stage").as_str() == Some("stitch")));
        // the stats endpoint surfaces the decompose counters/timers
        let s = json::parse(&handle_request(&p, r#"{"op":"stats"}"#)).unwrap();
        assert_eq!(s.get("counters").get("decomposed_solves").as_usize(), Some(1));
        assert_eq!(s.get("counters").get("decompose_partitions").as_usize(), Some(3));
        assert!(s.get("timers").get("decompose_solve").get("count").as_usize() == Some(1));

        // degenerate partition counts are request errors, not solves
        let bad = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("decompose", Json::Str("window:0".into())),
        ]);
        let v = json::parse(&handle_request(&p, &bad.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        let bad = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("decompose", Json::Str("size:64".into())),
        ]);
        let v = json::parse(&handle_request(&p, &bad.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false), "k > n must be rejected");
        assert!(v.get("error").as_str().unwrap().contains("exceeds"), "{v:?}");
    }

    #[test]
    fn legacy_solve_response_shape_is_unchanged() {
        // pre-session responses must stay byte-compatible: exactly this
        // key set, nothing session-related leaking in
        let p = planner();
        let inst = generate(&SynthParams { n: 20, m: 3, ..Default::default() }, 5);
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("lp-map-f".into())),
        ]);
        let v = json::parse(&handle_request(&p, &req.to_string())).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().keys().map(String::as_str).collect();
        assert_eq!(
            keys,
            vec![
                "algorithm",
                "backend",
                "cost",
                "lower_bound",
                "n_nodes",
                "nodes_per_type",
                "normalized_cost",
                "ok",
                "seconds",
                "stages"
            ],
            "{v:?}"
        );
    }

    #[test]
    fn lp_threads_knob_roundtrip() {
        let p = planner();
        let inst = generate(&SynthParams { n: 20, m: 3, ..Default::default() }, 5);
        // explicit count: echoed back, surfaced in the stats gauge
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("lp-map-f".into())),
            ("lp_threads", Json::Num(2.0)),
        ]);
        let v = json::parse(&handle_request(&p, &req.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("lp_threads").as_usize(), Some(2));
        let s = json::parse(&handle_request(&p, r#"{"op":"stats"}"#)).unwrap();
        assert_eq!(
            s.get("gauges").get("lp_threads_used").get("value").as_usize(),
            Some(2),
            "{s:?}"
        );
        // identical solve: a parallel run is bit-identical to serial
        let serial = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("lp-map-f".into())),
            ("lp_threads", Json::Num(1.0)),
        ]);
        let v1 = json::parse(&handle_request(&p, &serial.to_string())).unwrap();
        assert_eq!(v1.get("cost").as_f64(), v.get("cost").as_f64());
        assert_eq!(v1.get("lower_bound").as_f64(), v.get("lower_bound").as_f64());
        // over-cap counts are request errors, not silent clamps
        let big = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("lp_threads", Json::Num(1000.0)),
        ]);
        let v = json::parse(&handle_request(&p, &big.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(v.get("error").as_str().unwrap().contains("exceeds"), "{v:?}");
        // non-integer is a typed request error
        let bad = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("lp_threads", Json::Str("many".into())),
        ]);
        let v = json::parse(&handle_request(&p, &bad.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        // decomposed solves accept the knob and echo the resolved budget
        let dec = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("decompose", Json::Str("window:2".into())),
            ("lp_threads", Json::Num(4.0)),
        ]);
        let v = json::parse(&handle_request(&p, &dec.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("lp_threads").as_usize(), Some(4));
    }

    #[test]
    fn session_verbs_roundtrip() {
        let p = planner();
        // open on a server-side generated workload
        let open = Json::obj(vec![
            ("op", Json::Str("open".into())),
            ("workload", Json::Str("synth:n=30,m=3,dims=2".into())),
            ("seed", Json::Num(2.0)),
            ("algorithm", Json::Str("lp-map-f".into())),
            ("escalate", Json::Num(1.5)),
        ]);
        let v = json::parse(&handle_request(&p, &open.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("op").as_str(), Some("open"));
        let sid = v.get("session").as_usize().unwrap();
        let open_cost = v.get("cost").as_f64().unwrap();
        assert!(v.get("lower_bound").as_f64().unwrap() <= open_cost + 1e-6);
        assert_eq!(v.get("n_tasks").as_usize(), Some(30));

        // query a retire without committing
        let query = format!(
            r#"{{"op":"query","session":{sid},"delta":{{"op":"retire","ids":[0,1]}}}}"#
        );
        let v = json::parse(&handle_request(&p, &query)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        assert!(v.get("cost_if").as_f64().unwrap() <= open_cost + 1e-9);
        assert!(v.get("delta_cost").as_f64().unwrap() <= 1e-9);

        // the query did not commit: a delta batch still sees 30 tasks
        let batch = format!(
            r#"{{"op":"delta","session":{sid},"deltas":[
                {{"op":"admit","tasks":[{{"id":900,"demand":[0.1,0.1],"start":0,"end":3}}]}},
                {{"op":"reshape","id":900,"demand":[0.2,0.05],"start":0,"end":2}},
                {{"op":"retire","ids":[900]}}]}}"#
        );
        let v = json::parse(&handle_request(&p, &batch)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        let applied = v.get("applied").as_arr().unwrap();
        assert_eq!(applied.len(), 3);
        assert_eq!(applied[0].get("op").as_str(), Some("admit"));
        assert_eq!(applied[0].get("n_tasks").as_usize(), Some(31));
        assert_eq!(applied[2].get("n_tasks").as_usize(), Some(30));
        for a in applied {
            let cost = a.get("cost").as_f64().unwrap();
            let lb = a.get("lower_bound").as_f64().unwrap();
            assert!(lb <= cost + 1e-6, "{a:?}");
            assert!(a.get("decision").as_str().is_some());
        }

        // a bad delta mid-batch reports partial application; earlier
        // deltas stay applied
        let bad = format!(
            r#"{{"op":"delta","session":{sid},"deltas":[
                {{"op":"admit","tasks":[{{"id":901,"demand":[0.1,0.1],"start":0,"end":3}}]}},
                {{"op":"retire","ids":[424242]}}]}}"#
        );
        let v = json::parse(&handle_request(&p, &bad)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        let err = v.get("error").as_str().unwrap();
        assert!(err.contains("delta 1") && err.contains("stay applied"), "{err}");

        // close reports the summary and frees the id
        let close = format!(r#"{{"op":"close","session":{sid}}}"#);
        let v = json::parse(&handle_request(&p, &close)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("n_tasks").as_usize(), Some(31)); // 901 stayed
        assert_eq!(v.get("deltas").as_usize(), Some(4));
        let v = json::parse(&handle_request(&p, &close)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(v.get("error").as_str().unwrap().contains("no open session"));
    }

    #[test]
    fn stats_op_exposes_counters_and_histograms() {
        let p = planner();
        // one legacy solve + one open/close to move the counters
        let inst = generate(&SynthParams { n: 15, m: 2, ..Default::default() }, 3);
        let req = Json::obj(vec![("instance", files::instance_to_json(&inst))]);
        assert!(handle_request(&p, &req.to_string()).contains("\"ok\":true"));
        let open = Json::obj(vec![
            ("op", Json::Str("open".into())),
            ("instance", files::instance_to_json(&inst)),
        ]);
        let v = json::parse(&handle_request(&p, &open.to_string())).unwrap();
        let sid = v.get("session").as_usize().unwrap();

        let v = json::parse(&handle_request(&p, r#"{"op":"stats"}"#)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        let counters = v.get("counters");
        assert_eq!(counters.get("service_requests").as_usize(), Some(1));
        assert_eq!(counters.get("sessions_opened").as_usize(), Some(1));
        assert_eq!(v.get("sessions_open").as_usize(), Some(1));
        let timers = v.get("timers");
        let open_t = timers.get("session_open");
        assert_eq!(open_t.get("count").as_usize(), Some(1));
        assert!(open_t.get("p95").as_f64().unwrap() >= 0.0);
        assert!(open_t.get("max").as_f64().unwrap() > 0.0);
        assert!(v.get("report").as_str().unwrap().contains("sessions_opened"));

        let _ = handle_request(&p, &format!(r#"{{"op":"close","session":{sid}}}"#));
    }

    #[test]
    fn unknown_ops_and_bad_session_ids_error() {
        let p = planner();
        let v = json::parse(&handle_request(&p, r#"{"op":"frobnicate"}"#)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(v.get("error").as_str().unwrap().contains("unknown op"));
        let v = json::parse(&handle_request(
            &p,
            r#"{"op":"delta","session":99,"deltas":{"op":"retire","ids":[1]}}"#,
        ))
        .unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(v.get("error").as_str().unwrap().contains("no open session"));
        let v = json::parse(&handle_request(&p, r#"{"op":3}"#)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
    }

    #[test]
    fn shutdown_op_requires_the_runtime() {
        // without a runtime control handle (direct handle_request, as in
        // tests and one-off embedding) the verb is a typed refusal, not
        // a crash or an exit
        let p = planner();
        let v = json::parse(&handle_request(&p, r#"{"op":"shutdown"}"#)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(
            v.get("error").as_str().unwrap().contains("service runtime"),
            "{v:?}"
        );
        assert_eq!(p.metrics.counter("shutdown_requests"), 0);
    }

    #[test]
    fn stats_op_exposes_gauges_and_verb_labels() {
        let p = planner();
        p.metrics.gauge_add("service_connections_live", 1);
        p.metrics.gauge_add("service_connections_live", -1);
        let (resp, verb) = handle_request_with(&p, r#"{"op":"stats"}"#, None);
        assert_eq!(verb, "stats");
        let v = json::parse(&resp).unwrap();
        let g = v.get("gauges").get("service_connections_live");
        assert_eq!(g.get("value").as_usize(), Some(0), "{v:?}");
        assert_eq!(g.get("peak").as_usize(), Some(1), "{v:?}");
        // verb labels cover every request shape, including unparseable
        assert_eq!(handle_request_with(&p, "not json", None).1, "invalid");
        assert_eq!(handle_request_with(&p, r#"{"op":3}"#, None).1, "invalid");
        assert_eq!(handle_request_with(&p, r#"{"op":"close"}"#, None).1, "close");
        assert_eq!(handle_request_with(&p, r#"{"x":1}"#, None).1, "solve");
    }

    #[test]
    fn unknown_algorithm_rejected() {
        let p = planner();
        let inst = generate(&SynthParams { n: 10, m: 2, ..Default::default() }, 1);
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("magic".into())),
        ]);
        let resp = handle_request(&p, &req.to_string());
        assert!(resp.contains("unknown algorithm"));
    }
}
