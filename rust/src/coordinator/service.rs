//! The planning service: a line-delimited JSON-over-TCP request loop.
//!
//! ## One-shot solves (the legacy request shape, unchanged)
//!
//! A request without an `"op"` field is a one-shot solve:
//!   {"instance": {<io::files instance format>}, "algorithm": "lp-map-f"}
//! or, generating the workload server-side through the shared registry:
//!   {"workload": "gct:n=500,m=10,priced", "seed": 3, "algorithm": ...}
//! `workload` accepts the same spec language as the CLI `--workload`
//! flag (any registered family; see `io::workload::WORKLOAD_GRAMMAR`) or
//! a JSON object form `{"family": ..., <keys>...}`. `algorithm` accepts
//! the same language as the CLI `--algo` flag (both call
//! `algo::pipeline::parse_portfolio`): preset names, compositions like
//! "lp+fill+ls", the token "portfolio", and comma-separated lists that
//! race in parallel on one LP solve — see `algo::pipeline::SPEC_GRAMMAR`.
//! For a multi-pipeline race the response describes the winner, plus a
//! "raced" array of member costs and (when the certified LP bound let
//! the race abort members early) a "skipped" array of member labels.
//! Workload specs accept the `shape=flat|ramp|diurnal|spike` key on
//! every family (time-varying demand within a task), and inline
//! instances may give any task a piecewise profile via a "segments"
//! array (see `io::files`). The `csv` family is CLI-only: accepting it
//! here would hand untrusted clients server-local file reads, so
//! `source_from_json` rejects it — submit the tasks inline instead.
//! Response (one line):
//!   {"ok": true, "cost": ..., "normalized_cost": ..., "n_nodes": ...,
//!    "nodes_per_type": [...], "backend": "...", "seconds": ...,
//!    "stages": [{"stage": "...", "seconds": ...}, ...]}
//! or {"ok": false, "error": "..."}.
//!
//! ## Plan sessions (the `"op"` verb layer)
//!
//! A request with an `"op"` field speaks to the stateful session layer
//! (`coordinator::session`): open a plan once, then answer workload
//! *deltas* incrementally instead of re-solving from scratch.
//!
//!   {"op": "open", "instance"|"workload": ..., ["seed": S,]
//!    ["algorithm": <spec>,] ["escalate": 1.5 | "off",] ["fit": "ff"|"sim"]}
//!       -> {"ok": true, "op": "open", "session": <id>, "cost": ...,
//!           "lower_bound": ..., "n_tasks": ..., "n_nodes": ...}
//!   {"op": "delta", "session": <id>, "deltas": <delta> | [<delta>...]}
//!       applies each delta in order; see `io::delta::DELTA_GRAMMAR` for
//!       the delta objects (admit / retire / reshape / reprice). Each is
//!       answered incrementally — untouched placements kept, affected
//!       nodes repaired — escalating to a full warm-started re-solve
//!       when the incremental cost drifts past `escalate` × the
//!       refreshed certified LB (the knob set at open; default 1.5,
//!       "off" disables). Every delta's answer is per-slot verified.
//!       -> {"ok": true, "op": "delta", "applied": [{"op", "decision":
//!           "repair"|"resolve", "cost", "lower_bound", ...}...], ...}
//!       On a mid-batch error the response is {"ok": false, ...} and
//!       names how many deltas of the batch were already applied (they
//!       stay applied — deltas are not transactional across a batch).
//!   {"op": "query", "session": <id>, "delta": <delta>}
//!       what-if: prices the delta on a copy of the session without
//!       committing it.
//!   {"op": "close", "session": <id>}   -> final summary, frees the id.
//!   {"op": "stats"}                    -> `Metrics::report()` counters,
//!       gauges (live/peak connections, queue depth) and latency
//!       histograms (p50/p95/max, including per-verb `request.<verb>`
//!       series) plus open-session count — the deployed server's
//!       introspection endpoint.
//!   {"op": "shutdown"}                 -> begin a graceful drain (only
//!       under `tlrs serve --allow-shutdown`; refused otherwise).
//!
//! Sessions are shared across connections (per-session locking) and
//! capped at `session::MAX_SESSIONS`.
//!
//! ## The runtime underneath
//!
//! `serve` runs on `coordinator::runtime`: an accept thread feeding a
//! bounded worker pool, admission control that sheds excess connections
//! with a typed `{"ok":false,"error":"overloaded","retry_after_ms":...}`
//! line, per-request time/size budgets, and graceful shutdown that
//! drains every in-flight request before closing sessions. At
//! `--workers 1 --queue 0` the runtime degenerates to the seed's
//! strictly sequential behavior (same `handle_request` path, byte-
//! identical responses). See the `runtime` module doc for the contract.
//!
//! Python never serves requests; this loop is the deployable L3 artifact.

use std::net::TcpStream;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::io::delta as iodelta;
use crate::io::files;
use crate::model::{trim, Instance};
use crate::util::json::{self, Json};

use super::planner::Planner;
use super::runtime;
use super::session::{self, DeltaReport, PlanSession, SessionConfig};

/// Handle one request line; always returns a JSON response line.
pub fn handle_request(planner: &Planner, line: &str) -> String {
    handle_request_with(planner, line, None).0
}

/// `handle_request` plus the runtime's needs: an optional control handle
/// (enables the `shutdown` verb) and the request's verb label for
/// per-verb latency metrics. This is the single dispatch path — the
/// concurrent runtime and the legacy entry points produce byte-identical
/// responses because they both run through here.
pub fn handle_request_with(
    planner: &Planner,
    line: &str,
    ctl: Option<&runtime::RuntimeCtl>,
) -> (String, &'static str) {
    let parsed = json::parse(line);
    let verb = match &parsed {
        Ok(req) => verb_of(req),
        Err(_) => "invalid",
    };
    let result = match parsed {
        Ok(req) => handle_parsed(planner, &req, ctl),
        Err(e) => Err(anyhow::anyhow!("{e}")),
    };
    let resp = match result {
        Ok(v) => v.to_string(),
        Err(e) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str(format!("{e:#}"))),
        ])
        .to_string(),
    };
    (resp, verb)
}

/// Metrics label for a request (the `request.<verb>` histogram key).
fn verb_of(req: &Json) -> &'static str {
    match req.get("op") {
        Json::Null => "solve",
        op => match op.as_str() {
            Some("open") => "open",
            Some("delta") => "delta",
            Some("query") => "query",
            Some("close") => "close",
            Some("stats") => "stats",
            Some("shutdown") => "shutdown",
            _ => "invalid",
        },
    }
}

fn handle_parsed(
    planner: &Planner,
    req: &Json,
    ctl: Option<&runtime::RuntimeCtl>,
) -> Result<Json> {
    match req.get("op") {
        // no 'op': the legacy one-shot solve, byte-identical to pre-
        // session behavior
        Json::Null => handle_solve(planner, req),
        op => {
            let op = op
                .as_str()
                .context("'op' must be a string (open|delta|query|close|stats|shutdown)")?;
            match op {
                "open" => op_open(planner, req),
                "delta" => op_delta(planner, req),
                "query" => op_query(planner, req),
                "close" => op_close(planner, req),
                "stats" => op_stats(planner),
                "shutdown" => op_shutdown(planner, ctl),
                other => anyhow::bail!(
                    "unknown op '{other}' (session verbs: open, delta, query, close, \
                     stats, shutdown; omit 'op' for a one-shot solve)"
                ),
            }
        }
    }
}

/// Resolve the instance a request operates on: inline `instance` or a
/// server-side generated `workload` (+ `seed`). Returns the workload
/// label/seed for response echo when generated.
fn resolve_instance(req: &Json) -> Result<(Instance, Option<(String, u64)>)> {
    let mut workload_used: Option<(String, u64)> = None;
    let inst = match (req.get("instance"), req.get("workload")) {
        (Json::Null, Json::Null) => {
            anyhow::bail!("request needs an 'instance' or a 'workload'")
        }
        (inst_json, Json::Null) => {
            files::instance_from_json(inst_json).context("instance")?
        }
        (Json::Null, w) => {
            let source = crate::io::workload::source_from_json(w)?;
            let seed = match req.get("seed") {
                Json::Null => 1,
                s => s
                    .as_usize()
                    .context("'seed' must be a non-negative integer")?
                    as u64,
            };
            workload_used = Some((source.label(), seed));
            source.generate(seed)?
        }
        _ => anyhow::bail!("request has both 'instance' and 'workload'"),
    };
    Ok((inst, workload_used))
}

/// The legacy one-shot solve path (requests without an 'op' field).
/// With a `decompose` field the solve routes through the partition-
/// decomposed pipeline; the response keeps every legacy field and adds
/// the decomposition telemetry (additive only — requests without
/// `decompose` answer with the exact legacy key set).
fn handle_solve(planner: &Planner, req: &Json) -> Result<Json> {
    let (inst, workload_used) = resolve_instance(req)?;
    anyhow::ensure!(inst.n_tasks() > 0, "empty instance");
    let algo = req.get("algorithm").as_str().unwrap_or("lp-map-f");
    let t0 = std::time::Instant::now();

    match req.get("decompose") {
        Json::Null => {}
        Json::Str(spec) => {
            let spec = crate::algo::decompose::parse_decompose(spec)?;
            return handle_solve_decomposed(planner, &inst, algo, &spec, workload_used, t0);
        }
        _ => anyhow::bail!(
            "'decompose' must be a spec string\n{}",
            crate::algo::decompose::DECOMPOSE_GRAMMAR
        ),
    }

    let tr = trim(&inst).instance;
    let (solver, backend) = planner.solver_for(&tr);
    let portfolio = crate::algo::pipeline::parse_portfolio(algo)?;
    let race = portfolio.run(&tr, solver.as_ref())?;
    let rep = race.best();
    let lb = race.certified_lb();
    let solution = &rep.solution;
    solution
        .verify(&tr)
        .map_err(|v| anyhow::anyhow!("internal: infeasible solution: {v:?}"))?;
    let cost = solution.cost(&tr);
    let seconds = t0.elapsed().as_secs_f64();
    planner.metrics.inc("service_requests", 1);

    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("algorithm", Json::Str(algo.to_string())),
        ("cost", Json::Num(cost)),
        ("n_nodes", Json::Num(solution.nodes.len() as f64)),
        (
            "nodes_per_type",
            Json::Arr(
                solution
                    .nodes_per_type(&tr)
                    .iter()
                    .map(|&c| Json::Num(c as f64))
                    .collect(),
            ),
        ),
        ("backend", Json::Str(backend.to_string())),
        ("seconds", Json::Num(seconds)),
        (
            // array, not an object: a spec may repeat a stage (ls:2+ls:8)
            "stages",
            Json::Arr(
                rep.stages
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("stage", Json::Str(s.stage.clone())),
                            ("seconds", Json::Num(s.seconds)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    if let Some((label, seed)) = workload_used {
        fields.push(("workload", Json::Str(label)));
        fields.push(("seed", Json::Num(seed as f64)));
    }
    if let Some(lb) = lb {
        fields.push(("lower_bound", Json::Num(lb)));
        fields.push(("normalized_cost", Json::Num(cost / lb.max(1e-12))));
    }
    if race.reports.len() + race.skipped.len() > 1 {
        fields.push(("winner", Json::Str(rep.label.clone())));
        fields.push((
            "raced",
            Json::Arr(
                race.reports
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("algorithm", Json::Str(r.label.clone())),
                            ("cost", Json::Num(r.cost)),
                        ])
                    })
                    .collect(),
            ),
        ));
        if !race.skipped.is_empty() {
            // members the certified LP bound proved could not beat a
            // finished incumbent (early abort) — no cost to report
            fields.push((
                "skipped",
                Json::Arr(race.skipped.iter().map(|l| Json::Str(l.clone())).collect()),
            ));
        }
    }
    Ok(Json::obj(fields))
}

/// Decomposed variant of the one-shot solve. Response fields are the
/// legacy set plus `decompose`, `sum_partition_bounds`,
/// `congestion_bound`, `pre_stitch_cost` and a `partitions` array —
/// additive only, and only when the request opted in.
fn handle_solve_decomposed(
    planner: &Planner,
    inst: &Instance,
    algo: &str,
    spec: &crate::algo::decompose::DecomposeSpec,
    workload_used: Option<(String, u64)>,
    t0: std::time::Instant,
) -> Result<Json> {
    let portfolio = crate::algo::pipeline::parse_portfolio(algo)?;
    let (rep, backend) = planner.solve_decomposed(inst, &portfolio, spec)?;
    let tr = trim(inst).instance;
    rep.solution
        .verify(&tr)
        .map_err(|v| anyhow::anyhow!("internal: infeasible decomposed solution: {v:?}"))?;
    let seconds = t0.elapsed().as_secs_f64();
    planner.metrics.inc("service_requests", 1);

    let lb = rep.certified_lb;
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("algorithm", Json::Str(algo.to_string())),
        ("decompose", Json::Str(spec.to_string())),
        ("cost", Json::Num(rep.cost)),
        ("n_nodes", Json::Num(rep.solution.nodes.len() as f64)),
        (
            "nodes_per_type",
            Json::Arr(
                rep.solution
                    .nodes_per_type(&tr)
                    .iter()
                    .map(|&c| Json::Num(c as f64))
                    .collect(),
            ),
        ),
        ("backend", Json::Str(backend.to_string())),
        ("seconds", Json::Num(seconds)),
        (
            "stages",
            Json::Arr(
                rep.stages
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("stage", Json::Str(s.stage.clone())),
                            ("seconds", Json::Num(s.seconds)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    if let Some((label, seed)) = workload_used {
        fields.push(("workload", Json::Str(label)));
        fields.push(("seed", Json::Num(seed as f64)));
    }
    fields.push(("lower_bound", Json::Num(lb)));
    fields.push(("normalized_cost", Json::Num(rep.cost / lb.max(1e-12))));
    fields.push(("sum_partition_bounds", Json::Num(rep.sum_lb)));
    fields.push(("congestion_bound", Json::Num(rep.congestion_lb)));
    fields.push(("pre_stitch_cost", Json::Num(rep.pre_stitch_cost)));
    fields.push((
        "partitions",
        Json::Arr(
            rep.partitions
                .iter()
                .map(|p| {
                    Json::obj(vec![
                        ("partition", Json::Str(p.label.clone())),
                        ("n_tasks", Json::Num(p.n_tasks as f64)),
                        ("cost", Json::Num(p.cost)),
                        ("lower_bound", Json::Num(p.lb)),
                        ("seconds", Json::Num(p.seconds)),
                        ("winner", Json::Str(p.winner.clone())),
                    ])
                })
                .collect(),
        ),
    ));
    Ok(Json::obj(fields))
}

// ----- session verbs ------------------------------------------------------

/// One per-delta report as a wire object.
fn delta_report_json(rep: &DeltaReport) -> Json {
    let mut fields = vec![
        ("op", Json::Str(rep.op.to_string())),
        ("decision", Json::Str(rep.decision.as_str().to_string())),
        ("cost", Json::Num(rep.cost)),
        ("lower_bound", Json::Num(rep.lower_bound)),
        ("n_tasks", Json::Num(rep.n_tasks as f64)),
        ("n_nodes", Json::Num(rep.n_nodes as f64)),
        ("seconds", Json::Num(rep.seconds)),
    ];
    if let Some(reason) = &rep.reason {
        fields.push(("reason", Json::Str(reason.clone())));
    }
    Json::obj(fields)
}

/// Session config from request knobs (`algorithm`, `escalate`, `fit`).
fn session_config(req: &Json) -> Result<SessionConfig> {
    let mut cfg = SessionConfig::default();
    if let Some(algo) = req.get("algorithm").as_str() {
        cfg.algo = algo.to_string();
    }
    match req.get("escalate") {
        Json::Null => {}
        Json::Num(r) => {
            anyhow::ensure!(
                r.is_finite() && *r >= 1.0,
                "escalate ratio must be >= 1, got {r}"
            );
            cfg.escalate_ratio = Some(*r);
        }
        Json::Str(s) => cfg.escalate_ratio = session::parse_escalate(s)?,
        _ => anyhow::bail!("'escalate' must be a ratio >= 1 or \"off\""),
    }
    match req.get("fit") {
        Json::Null => {}
        Json::Str(s) => cfg.fit = session::parse_fit(s)?,
        _ => anyhow::bail!("'fit' must be \"ff\" or \"sim\""),
    }
    Ok(cfg)
}

fn session_id(req: &Json) -> Result<u64> {
    Ok(req
        .get("session")
        .as_usize()
        .context("'session' must be the id returned by open")? as u64)
}

fn session_handle(
    planner: &Planner,
    req: &Json,
) -> Result<(u64, Arc<std::sync::Mutex<PlanSession>>)> {
    let id = session_id(req)?;
    let handle = planner
        .sessions
        .get(id)
        .ok_or_else(|| anyhow!("no open session {id}"))?;
    Ok((id, handle))
}

fn op_open(planner: &Planner, req: &Json) -> Result<Json> {
    // cheap early reject: the cap must bound *compute*, not just memory —
    // the authoritative re-check happens inside sessions.insert()
    anyhow::ensure!(
        planner.sessions.count() < session::MAX_SESSIONS,
        "too many open sessions ({}); close one first",
        session::MAX_SESSIONS
    );
    let (inst, workload_used) = resolve_instance(req)?;
    let cfg = session_config(req)?;
    let algo = cfg.algo.clone();
    let (session, open) =
        planner.metrics.time("session_open", || PlanSession::open(inst, cfg))?;
    let id = planner.sessions.insert(session)?;
    planner.metrics.inc("sessions_opened", 1);
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("op", Json::Str("open".into())),
        ("session", Json::Num(id as f64)),
        ("algorithm", Json::Str(algo)),
        ("winner", Json::Str(open.label.clone())),
        ("cost", Json::Num(open.cost)),
        ("lower_bound", Json::Num(open.lower_bound)),
        ("n_tasks", Json::Num(open.n_tasks as f64)),
        ("n_nodes", Json::Num(open.n_nodes as f64)),
        ("seconds", Json::Num(open.seconds)),
    ];
    if let Some((label, seed)) = workload_used {
        fields.push(("workload", Json::Str(label)));
        fields.push(("seed", Json::Num(seed as f64)));
    }
    Ok(Json::obj(fields))
}

fn op_delta(planner: &Planner, req: &Json) -> Result<Json> {
    let (id, handle) = session_handle(planner, req)?;
    let deltas_json = match (req.get("deltas"), req.get("delta")) {
        (Json::Null, Json::Null) => anyhow::bail!(
            "the delta op needs a 'deltas' field (one delta object or an array)"
        ),
        (Json::Null, d) => d,
        (d, _) => d,
    };
    let deltas = iodelta::deltas_from_json(deltas_json)?;
    let mut session = handle.lock().unwrap();
    let mut applied = Vec::with_capacity(deltas.len());
    for (i, d) in deltas.iter().enumerate() {
        let rep = session.apply(d).map_err(|e| {
            anyhow!(
                "delta {i} ({}): {e:#} — the {} earlier delta(s) of this batch \
                 stay applied",
                d.op(),
                i
            )
        })?;
        planner.metrics.inc("session_deltas", 1);
        planner.metrics.inc(
            match rep.decision {
                session::Decision::Repair => "session_deltas_incremental",
                session::Decision::Resolve => "session_deltas_resolved",
            },
            1,
        );
        planner.metrics.observe("session_delta", rep.seconds);
        planner.metrics.observe(&format!("session_delta.{}", rep.op), rep.seconds);
        applied.push(delta_report_json(&rep));
    }
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("op", Json::Str("delta".into())),
        ("session", Json::Num(id as f64)),
        ("applied", Json::Arr(applied)),
        ("cost", Json::Num(session.cost())),
        ("lower_bound", Json::Num(session.lower_bound())),
        ("n_tasks", Json::Num(session.n_tasks() as f64)),
        ("n_nodes", Json::Num(session.n_nodes() as f64)),
    ]))
}

fn op_query(planner: &Planner, req: &Json) -> Result<Json> {
    let (id, handle) = session_handle(planner, req)?;
    let delta_json = match req.get("delta") {
        Json::Null => anyhow::bail!("the query op needs a 'delta' field (one delta object)"),
        d => d,
    };
    let delta = iodelta::delta_from_json(delta_json)?;
    let session = handle.lock().unwrap();
    let current = session.cost();
    let rep = session.quote(&delta)?;
    planner.metrics.inc("session_queries", 1);
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("op", Json::Str("query".into())),
        ("session", Json::Num(id as f64)),
        ("cost", Json::Num(current)),
        ("cost_if", Json::Num(rep.cost)),
        ("delta_cost", Json::Num(rep.cost - current)),
        ("would", delta_report_json(&rep)),
    ]))
}

fn op_close(planner: &Planner, req: &Json) -> Result<Json> {
    let id = session_id(req)?;
    let handle = planner
        .sessions
        .close(id)
        .ok_or_else(|| anyhow!("no open session {id}"))?;
    let session = handle.lock().unwrap();
    let (n_deltas, repairs, resolves) = session.delta_counts();
    planner.metrics.inc("sessions_closed", 1);
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("op", Json::Str("close".into())),
        ("session", Json::Num(id as f64)),
        ("cost", Json::Num(session.cost())),
        ("lower_bound", Json::Num(session.lower_bound())),
        ("n_tasks", Json::Num(session.n_tasks() as f64)),
        ("deltas", Json::Num(n_deltas as f64)),
        ("repairs", Json::Num(repairs as f64)),
        ("resolves", Json::Num(resolves as f64)),
    ]))
}

/// `{"op": "stats"}` — the deployed server's introspection endpoint:
/// every counter, every gauge (current value + all-time peak), every
/// latency histogram (p50/p95/max over the recent window), open-session
/// count, and the human-readable report text.
fn op_stats(planner: &Planner) -> Result<Json> {
    let counters = Json::Obj(
        planner
            .metrics
            .counters_snapshot()
            .into_iter()
            .map(|(k, v)| (k, Json::Num(v as f64)))
            .collect(),
    );
    let gauges = Json::Obj(
        planner
            .metrics
            .gauges_snapshot()
            .into_iter()
            .map(|(k, g)| {
                (
                    k,
                    Json::obj(vec![
                        ("value", Json::Num(g.value as f64)),
                        ("peak", Json::Num(g.peak as f64)),
                    ]),
                )
            })
            .collect(),
    );
    let timers = Json::Obj(
        planner
            .metrics
            .timers_snapshot()
            .into_iter()
            .map(|(k, t)| {
                (
                    k,
                    Json::obj(vec![
                        ("count", Json::Num(t.count as f64)),
                        ("total", Json::Num(t.total)),
                        ("mean", Json::Num(t.mean())),
                        ("p50", Json::Num(t.pct(50.0))),
                        ("p95", Json::Num(t.pct(95.0))),
                        ("max", Json::Num(t.max)),
                    ]),
                )
            })
            .collect(),
    );
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("op", Json::Str("stats".into())),
        ("counters", counters),
        ("gauges", gauges),
        ("timers", timers),
        ("sessions_open", Json::Num(planner.sessions.count() as f64)),
        ("report", Json::Str(planner.metrics.report())),
    ]))
}

/// `{"op": "shutdown"}` — begin a graceful drain: stop accepting, let
/// every in-flight and queued request finish, close all sessions, exit.
/// Only meaningful over the runtime (`tlrs serve`), and only when it was
/// started with `--allow-shutdown`.
fn op_shutdown(planner: &Planner, ctl: Option<&runtime::RuntimeCtl>) -> Result<Json> {
    let ctl =
        ctl.context("shutdown is only available over the service runtime (tlrs serve)")?;
    ctl.request_shutdown()?;
    planner.metrics.inc("shutdown_requests", 1);
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("op", Json::Str("shutdown".into())),
        ("draining", Json::Bool(true)),
        ("sessions_open", Json::Num(planner.sessions.count() as f64)),
    ]))
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7077") with default runtime
/// knobs. See [`serve_with`].
pub fn serve(planner: Arc<Planner>, addr: &str) -> Result<()> {
    serve_with(planner, addr, runtime::RuntimeConfig::default())
}

/// Serve on `addr` over the concurrent runtime (`coordinator::runtime`):
/// an accept thread feeding `cfg.workers` connection workers with a
/// bounded queue, shedding excess connections with a typed "overloaded"
/// line, enforcing per-request time/size budgets, and draining
/// gracefully on shutdown. Each connection may pipeline many request
/// lines. Blocks until the runtime shuts down (fatal accept error, or
/// `{"op":"shutdown"}` under `cfg.allow_shutdown`).
pub fn serve_with(
    planner: Arc<Planner>,
    addr: &str,
    cfg: runtime::RuntimeConfig,
) -> Result<()> {
    let rt = runtime::ServiceRuntime::bind(planner, addr, cfg)?;
    let c = rt.config();
    eprintln!(
        "tlrs planning service on {} ({} workers, queue {}, request timeout {:.0}s, \
         max request {} bytes{})",
        rt.local_addr(),
        c.workers,
        c.queue,
        c.request_timeout.as_secs_f64(),
        c.max_request_bytes,
        if c.allow_shutdown { ", shutdown enabled" } else { "" },
    );
    rt.run()
}

/// Handle one client connection on the calling thread (used directly by
/// tests): the single-connection primitive the runtime's workers run,
/// with default budgets and no shutdown/control surface.
pub fn serve_connection(planner: &Planner, stream: TcpStream) -> Result<()> {
    runtime::handle_connection(planner, stream, &runtime::ConnBudget::default(), None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::Backend;
    use crate::io::synth::{generate, SynthParams};

    fn planner() -> Planner {
        Planner::new(Backend::Native).unwrap()
    }

    #[test]
    fn request_roundtrip() {
        let p = planner();
        let inst = generate(&SynthParams { n: 40, m: 3, ..Default::default() }, 4);
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("lp-map-f".into())),
        ]);
        let resp = handle_request(&p, &req.to_string());
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{resp}");
        assert!(v.get("cost").as_f64().unwrap() > 0.0);
        assert!(v.get("normalized_cost").as_f64().unwrap() >= 1.0 - 1e-6);
    }

    #[test]
    fn malformed_requests_dont_crash() {
        let p = planner();
        for bad in ["not json", "{}", r#"{"instance": 3}"#,
                    r#"{"instance": {"horizon": 1, "node_types": [], "tasks": []}}"#] {
            let resp = handle_request(&p, bad);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").as_bool(), Some(false), "input {bad}: {resp}");
        }
    }

    #[test]
    fn comma_list_races_and_reports_the_winner() {
        let p = planner();
        let inst = generate(&SynthParams { n: 30, m: 3, ..Default::default() }, 6);
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("penalty-map-f,lp-map-f".into())),
        ]);
        let resp = handle_request(&p, &req.to_string());
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{resp}");
        let raced = v.get("raced").as_arr().unwrap();
        assert_eq!(raced.len(), 2);
        assert!(v.get("winner").as_str().is_some());
        // the penalty winner case still certifies the shared-LP bound
        assert!(v.get("lower_bound").as_f64().unwrap() > 0.0);
        let cost = v.get("cost").as_f64().unwrap();
        for r in raced {
            assert!(cost <= r.get("cost").as_f64().unwrap() + 1e-9);
        }
    }

    #[test]
    fn workload_spec_requests() {
        let p = planner();
        // spec-string form, any registered family
        let req = Json::obj(vec![
            ("workload", Json::Str("mixed:services=15,m=3".into())),
            ("seed", Json::Num(4.0)),
            ("algorithm", Json::Str("lp-map-f".into())),
        ]);
        let resp = handle_request(&p, &req.to_string());
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{resp}");
        assert_eq!(v.get("workload").as_str(), Some("mixed:m=3,services=15"));
        assert_eq!(v.get("seed").as_usize(), Some(4));
        // the generated instance matches a client-side generation
        let inst = crate::io::workload::parse_workload("mixed:services=15,m=3")
            .unwrap()
            .generate(4)
            .unwrap();
        let req2 = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("lp-map-f".into())),
        ]);
        let v2 = json::parse(&handle_request(&p, &req2.to_string())).unwrap();
        assert_eq!(v.get("cost").as_f64(), v2.get("cost").as_f64(), "{resp}");

        // JSON object form with the fixed cost model
        let req = Json::obj(vec![
            (
                "workload",
                json::parse(
                    r#"{"family": "synth", "n": 30, "m": 3, "dims": 2,
                        "cost_model": "fixed", "coefficients": [2.0, 1.0]}"#,
                )
                .unwrap(),
            ),
            ("algorithm", Json::Str("penalty-map-f".into())),
        ]);
        let v = json::parse(&handle_request(&p, &req.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true));

        // non-integer seeds are rejected, not silently defaulted
        let req = Json::obj(vec![
            ("workload", Json::Str("synth:n=10,m=2".into())),
            ("seed", Json::Str("7".into())),
        ]);
        let v = json::parse(&handle_request(&p, &req.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(v.get("error").as_str().unwrap().contains("seed"), "{v:?}");

        // bad specs fail with the family catalog, not a crash
        let req = Json::obj(vec![("workload", Json::Str("warp:n=3".into()))]);
        let resp = handle_request(&p, &req.to_string());
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(v.get("error").as_str().unwrap().contains("invalid workload spec"));
        // both instance and workload is ambiguous
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("workload", Json::Str("synth".into())),
        ]);
        let v = json::parse(&handle_request(&p, &req.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
    }

    #[test]
    fn decomposed_solve_request_roundtrip() {
        let p = planner();
        let inst = generate(&SynthParams { n: 60, m: 3, ..Default::default() }, 7);
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("penalty-map,penalty-map-f".into())),
            ("decompose", Json::Str("window:3".into())),
        ]);
        let v = json::parse(&handle_request(&p, &req.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("decompose").as_str(), Some("window:3"));
        let cost = v.get("cost").as_f64().unwrap();
        let lb = v.get("lower_bound").as_f64().unwrap();
        assert!(lb > 0.0 && lb <= cost + 1e-6, "{v:?}");
        assert!(v.get("pre_stitch_cost").as_f64().unwrap() >= cost - 1e-9);
        let parts = v.get("partitions").as_arr().unwrap();
        assert_eq!(parts.len(), 3);
        let total: usize = parts.iter().map(|p| p.get("n_tasks").as_usize().unwrap()).sum();
        assert_eq!(total, 60);
        assert!(parts[0].get("winner").as_str().is_some());
        // stage telemetry includes the stitch pass
        let stages = v.get("stages").as_arr().unwrap();
        assert!(stages.iter().any(|s| s.get("stage").as_str() == Some("stitch")));
        // the stats endpoint surfaces the decompose counters/timers
        let s = json::parse(&handle_request(&p, r#"{"op":"stats"}"#)).unwrap();
        assert_eq!(s.get("counters").get("decomposed_solves").as_usize(), Some(1));
        assert_eq!(s.get("counters").get("decompose_partitions").as_usize(), Some(3));
        assert!(s.get("timers").get("decompose_solve").get("count").as_usize() == Some(1));

        // degenerate partition counts are request errors, not solves
        let bad = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("decompose", Json::Str("window:0".into())),
        ]);
        let v = json::parse(&handle_request(&p, &bad.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        let bad = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("decompose", Json::Str("size:64".into())),
        ]);
        let v = json::parse(&handle_request(&p, &bad.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false), "k > n must be rejected");
        assert!(v.get("error").as_str().unwrap().contains("exceeds"), "{v:?}");
    }

    #[test]
    fn legacy_solve_response_shape_is_unchanged() {
        // pre-session responses must stay byte-compatible: exactly this
        // key set, nothing session-related leaking in
        let p = planner();
        let inst = generate(&SynthParams { n: 20, m: 3, ..Default::default() }, 5);
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("lp-map-f".into())),
        ]);
        let v = json::parse(&handle_request(&p, &req.to_string())).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().keys().map(String::as_str).collect();
        assert_eq!(
            keys,
            vec![
                "algorithm",
                "backend",
                "cost",
                "lower_bound",
                "n_nodes",
                "nodes_per_type",
                "normalized_cost",
                "ok",
                "seconds",
                "stages"
            ],
            "{v:?}"
        );
    }

    #[test]
    fn session_verbs_roundtrip() {
        let p = planner();
        // open on a server-side generated workload
        let open = Json::obj(vec![
            ("op", Json::Str("open".into())),
            ("workload", Json::Str("synth:n=30,m=3,dims=2".into())),
            ("seed", Json::Num(2.0)),
            ("algorithm", Json::Str("lp-map-f".into())),
            ("escalate", Json::Num(1.5)),
        ]);
        let v = json::parse(&handle_request(&p, &open.to_string())).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("op").as_str(), Some("open"));
        let sid = v.get("session").as_usize().unwrap();
        let open_cost = v.get("cost").as_f64().unwrap();
        assert!(v.get("lower_bound").as_f64().unwrap() <= open_cost + 1e-6);
        assert_eq!(v.get("n_tasks").as_usize(), Some(30));

        // query a retire without committing
        let query = format!(
            r#"{{"op":"query","session":{sid},"delta":{{"op":"retire","ids":[0,1]}}}}"#
        );
        let v = json::parse(&handle_request(&p, &query)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        assert!(v.get("cost_if").as_f64().unwrap() <= open_cost + 1e-9);
        assert!(v.get("delta_cost").as_f64().unwrap() <= 1e-9);

        // the query did not commit: a delta batch still sees 30 tasks
        let batch = format!(
            r#"{{"op":"delta","session":{sid},"deltas":[
                {{"op":"admit","tasks":[{{"id":900,"demand":[0.1,0.1],"start":0,"end":3}}]}},
                {{"op":"reshape","id":900,"demand":[0.2,0.05],"start":0,"end":2}},
                {{"op":"retire","ids":[900]}}]}}"#
        );
        let v = json::parse(&handle_request(&p, &batch)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        let applied = v.get("applied").as_arr().unwrap();
        assert_eq!(applied.len(), 3);
        assert_eq!(applied[0].get("op").as_str(), Some("admit"));
        assert_eq!(applied[0].get("n_tasks").as_usize(), Some(31));
        assert_eq!(applied[2].get("n_tasks").as_usize(), Some(30));
        for a in applied {
            let cost = a.get("cost").as_f64().unwrap();
            let lb = a.get("lower_bound").as_f64().unwrap();
            assert!(lb <= cost + 1e-6, "{a:?}");
            assert!(a.get("decision").as_str().is_some());
        }

        // a bad delta mid-batch reports partial application; earlier
        // deltas stay applied
        let bad = format!(
            r#"{{"op":"delta","session":{sid},"deltas":[
                {{"op":"admit","tasks":[{{"id":901,"demand":[0.1,0.1],"start":0,"end":3}}]}},
                {{"op":"retire","ids":[424242]}}]}}"#
        );
        let v = json::parse(&handle_request(&p, &bad)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        let err = v.get("error").as_str().unwrap();
        assert!(err.contains("delta 1") && err.contains("stay applied"), "{err}");

        // close reports the summary and frees the id
        let close = format!(r#"{{"op":"close","session":{sid}}}"#);
        let v = json::parse(&handle_request(&p, &close)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("n_tasks").as_usize(), Some(31)); // 901 stayed
        assert_eq!(v.get("deltas").as_usize(), Some(4));
        let v = json::parse(&handle_request(&p, &close)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(v.get("error").as_str().unwrap().contains("no open session"));
    }

    #[test]
    fn stats_op_exposes_counters_and_histograms() {
        let p = planner();
        // one legacy solve + one open/close to move the counters
        let inst = generate(&SynthParams { n: 15, m: 2, ..Default::default() }, 3);
        let req = Json::obj(vec![("instance", files::instance_to_json(&inst))]);
        assert!(handle_request(&p, &req.to_string()).contains("\"ok\":true"));
        let open = Json::obj(vec![
            ("op", Json::Str("open".into())),
            ("instance", files::instance_to_json(&inst)),
        ]);
        let v = json::parse(&handle_request(&p, &open.to_string())).unwrap();
        let sid = v.get("session").as_usize().unwrap();

        let v = json::parse(&handle_request(&p, r#"{"op":"stats"}"#)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true), "{v:?}");
        let counters = v.get("counters");
        assert_eq!(counters.get("service_requests").as_usize(), Some(1));
        assert_eq!(counters.get("sessions_opened").as_usize(), Some(1));
        assert_eq!(v.get("sessions_open").as_usize(), Some(1));
        let timers = v.get("timers");
        let open_t = timers.get("session_open");
        assert_eq!(open_t.get("count").as_usize(), Some(1));
        assert!(open_t.get("p95").as_f64().unwrap() >= 0.0);
        assert!(open_t.get("max").as_f64().unwrap() > 0.0);
        assert!(v.get("report").as_str().unwrap().contains("sessions_opened"));

        let _ = handle_request(&p, &format!(r#"{{"op":"close","session":{sid}}}"#));
    }

    #[test]
    fn unknown_ops_and_bad_session_ids_error() {
        let p = planner();
        let v = json::parse(&handle_request(&p, r#"{"op":"frobnicate"}"#)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(v.get("error").as_str().unwrap().contains("unknown op"));
        let v = json::parse(&handle_request(
            &p,
            r#"{"op":"delta","session":99,"deltas":{"op":"retire","ids":[1]}}"#,
        ))
        .unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(v.get("error").as_str().unwrap().contains("no open session"));
        let v = json::parse(&handle_request(&p, r#"{"op":3}"#)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
    }

    #[test]
    fn shutdown_op_requires_the_runtime() {
        // without a runtime control handle (direct handle_request, as in
        // tests and one-off embedding) the verb is a typed refusal, not
        // a crash or an exit
        let p = planner();
        let v = json::parse(&handle_request(&p, r#"{"op":"shutdown"}"#)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert!(
            v.get("error").as_str().unwrap().contains("service runtime"),
            "{v:?}"
        );
        assert_eq!(p.metrics.counter("shutdown_requests"), 0);
    }

    #[test]
    fn stats_op_exposes_gauges_and_verb_labels() {
        let p = planner();
        p.metrics.gauge_add("service_connections_live", 1);
        p.metrics.gauge_add("service_connections_live", -1);
        let (resp, verb) = handle_request_with(&p, r#"{"op":"stats"}"#, None);
        assert_eq!(verb, "stats");
        let v = json::parse(&resp).unwrap();
        let g = v.get("gauges").get("service_connections_live");
        assert_eq!(g.get("value").as_usize(), Some(0), "{v:?}");
        assert_eq!(g.get("peak").as_usize(), Some(1), "{v:?}");
        // verb labels cover every request shape, including unparseable
        assert_eq!(handle_request_with(&p, "not json", None).1, "invalid");
        assert_eq!(handle_request_with(&p, r#"{"op":3}"#, None).1, "invalid");
        assert_eq!(handle_request_with(&p, r#"{"op":"close"}"#, None).1, "close");
        assert_eq!(handle_request_with(&p, r#"{"x":1}"#, None).1, "solve");
    }

    #[test]
    fn unknown_algorithm_rejected() {
        let p = planner();
        let inst = generate(&SynthParams { n: 10, m: 2, ..Default::default() }, 1);
        let req = Json::obj(vec![
            ("instance", files::instance_to_json(&inst)),
            ("algorithm", Json::Str("magic".into())),
        ]);
        let resp = handle_request(&p, &req.to_string());
        assert!(resp.contains("unknown algorithm"));
    }
}
