//! L3 coordination: configuration, planning, metrics, stateful plan
//! sessions, and the TCP planning service.

pub mod config;
pub mod metrics;
pub mod planner;
pub mod service;
pub mod session;
