//! L3 coordination: configuration, planning, metrics, and the TCP
//! planning service.

pub mod config;
pub mod metrics;
pub mod planner;
pub mod service;
