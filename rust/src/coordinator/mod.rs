//! L3 coordination: configuration, planning, metrics, stateful plan
//! sessions, the TCP planning service and its concurrent runtime.

pub mod config;
pub mod metrics;
pub mod planner;
pub mod runtime;
pub mod service;
pub mod session;
