//! Experiment/scenario configuration (Table I defaults + JSON overrides).

use anyhow::{bail, Result};

use crate::io::synth::{CostKind, SynthParams};
use crate::util::json::Json;

/// Which LP backend the coordinator should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Artifact when a bucket fits, native PDHG otherwise.
    Auto,
    Native,
    Artifact,
    Simplex,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        Ok(match s {
            "auto" => Backend::Auto,
            "native" => Backend::Native,
            "artifact" => Backend::Artifact,
            "simplex" => Backend::Simplex,
            other => bail!("unknown backend '{other}'"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Backend::Auto => "auto",
            Backend::Native => "native",
            Backend::Artifact => "artifact",
            Backend::Simplex => "simplex",
        }
    }
}

/// Source of the workload.
#[derive(Clone, Debug)]
pub enum TraceKind {
    Synthetic(SynthParams),
    /// GCT-like trace scenario: (n, m); pricing-based cost when `priced`.
    GctLike { n: usize, m: usize, priced: bool },
}

/// One experiment scenario (a figure data point before seeding).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub label: String,
    pub trace: TraceKind,
    pub seeds: Vec<u64>,
}

/// Table I defaults (paper section VI-A).
pub fn table1_defaults() -> SynthParams {
    SynthParams::default()
}

/// Parse a synthetic-scenario override from JSON, starting at defaults.
pub fn synth_from_json(v: &Json) -> Result<SynthParams> {
    let mut p = table1_defaults();
    if let Some(n) = v.get("n").as_usize() {
        p.n = n;
    }
    if let Some(m) = v.get("m").as_usize() {
        p.m = m;
    }
    if let Some(d) = v.get("dims").as_usize() {
        p.dims = d;
    }
    if let Some(t) = v.get("horizon").as_usize() {
        p.horizon = t as u32;
    }
    if let Some(r) = v.get("dem_range").to_f64_vec() {
        if r.len() != 2 {
            bail!("dem_range needs two entries");
        }
        p.dem_range = (r[0], r[1]);
    }
    if let Some(r) = v.get("cap_range").to_f64_vec() {
        if r.len() != 2 {
            bail!("cap_range needs two entries");
        }
        p.cap_range = (r[0], r[1]);
    }
    match v.get("cost_model").as_str() {
        None | Some("homogeneous") => {}
        Some("heterogeneous") => {
            let e = v.get("exponent").as_f64().unwrap_or(1.0);
            p.cost_model = CostKind::HeterogeneousRandom { exponent: e };
        }
        Some(other) => bail!("unknown cost_model '{other}'"),
    }
    Ok(p)
}

/// Default seed list: 5 random inputs per scenario (paper section VI-A).
pub fn default_seeds(quick: bool) -> Vec<u64> {
    if quick {
        vec![1, 2]
    } else {
        vec![1, 2, 3, 4, 5]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn defaults_match_table1() {
        let p = table1_defaults();
        assert_eq!(p.n, 1000);
        assert_eq!(p.m, 10);
        assert_eq!(p.dims, 5);
        assert_eq!(p.horizon, 24);
        assert_eq!(p.cap_range, (0.2, 1.0));
        assert_eq!(p.dem_range, (0.01, 0.1));
    }

    #[test]
    fn json_overrides() {
        let v = json::parse(
            r#"{"n": 200, "dims": 3, "dem_range": [0.05, 0.2],
                "cost_model": "heterogeneous", "exponent": 2.0}"#,
        )
        .unwrap();
        let p = synth_from_json(&v).unwrap();
        assert_eq!(p.n, 200);
        assert_eq!(p.dims, 3);
        assert_eq!(p.dem_range, (0.05, 0.2));
        assert!(matches!(p.cost_model, CostKind::HeterogeneousRandom { exponent } if exponent == 2.0));
    }

    #[test]
    fn bad_configs_rejected() {
        assert!(Backend::parse("quantum").is_err());
        let v = json::parse(r#"{"dem_range": [0.1]}"#).unwrap();
        assert!(synth_from_json(&v).is_err());
        let v = json::parse(r#"{"cost_model": "mystery"}"#).unwrap();
        assert!(synth_from_json(&v).is_err());
    }

    #[test]
    fn seeds() {
        assert_eq!(default_seeds(false).len(), 5);
        assert_eq!(default_seeds(true).len(), 2);
    }
}
