//! Experiment/scenario configuration (Table I defaults + JSON overrides).
//!
//! Workloads are named by `io::workload` spec strings everywhere; the old
//! closed [`TraceKind`] enum survives only as a shim that renders itself
//! into a spec for the shared parser.

use anyhow::{bail, Result};

use crate::io::synth::SynthParams;
use crate::io::workload::{self, WorkloadSpec};
use crate::util::json::Json;

/// Which LP backend the coordinator should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Artifact when a bucket fits, native PDHG otherwise.
    Auto,
    Native,
    Artifact,
    Simplex,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        Ok(match s {
            "auto" => Backend::Auto,
            "native" => Backend::Native,
            "artifact" => Backend::Artifact,
            "simplex" => Backend::Simplex,
            other => bail!("unknown backend '{other}'"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Backend::Auto => "auto",
            Backend::Native => "native",
            Backend::Artifact => "artifact",
            Backend::Simplex => "simplex",
        }
    }
}

/// Source of the workload — SHIM ONLY. The two historic variants render
/// into `io::workload` specs; new code should hold a [`WorkloadSpec`]
/// (any registered family) instead of this closed enum.
#[derive(Clone, Debug)]
pub enum TraceKind {
    Synthetic(SynthParams),
    /// GCT-like trace scenario: (n, m); pricing-based cost when `priced`.
    GctLike { n: usize, m: usize, priced: bool },
}

impl TraceKind {
    /// Render into the spec grammar the rest of the system speaks.
    pub fn to_spec(&self) -> WorkloadSpec {
        match self {
            TraceKind::Synthetic(p) => workload::spec_of_synth(p),
            TraceKind::GctLike { n, m, priced } => {
                let mut spec = WorkloadSpec::parse("gct").expect("gct is registered");
                spec.set("n", n.to_string());
                spec.set("m", m.to_string());
                if *priced {
                    spec.set("priced", "");
                }
                spec
            }
        }
    }
}

/// One experiment scenario (a figure data point before seeding).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub label: String,
    pub workload: WorkloadSpec,
    pub seeds: Vec<u64>,
}

/// Table I defaults (paper section VI-A).
pub fn table1_defaults() -> SynthParams {
    SynthParams::default()
}

/// Parse a synthetic-scenario override from JSON, starting at defaults.
/// Thin shim over [`workload::synth_params_from_json`]: accepts the
/// `"cost_model": "fixed"` + `"coefficients"` form and rejects unknown
/// keys instead of silently ignoring them.
pub fn synth_from_json(v: &Json) -> Result<SynthParams> {
    workload::synth_params_from_json(v)
}

/// Default seed list: 5 random inputs per scenario (paper section VI-A).
pub fn default_seeds(quick: bool) -> Vec<u64> {
    if quick {
        vec![1, 2]
    } else {
        vec![1, 2, 3, 4, 5]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::CostKind;
    use crate::util::json;

    #[test]
    fn defaults_match_table1() {
        let p = table1_defaults();
        assert_eq!(p.n, 1000);
        assert_eq!(p.m, 10);
        assert_eq!(p.dims, 5);
        assert_eq!(p.horizon, 24);
        assert_eq!(p.cap_range, (0.2, 1.0));
        assert_eq!(p.dem_range, (0.01, 0.1));
    }

    #[test]
    fn json_overrides() {
        let v = json::parse(
            r#"{"n": 200, "dims": 3, "dem_range": [0.05, 0.2],
                "cost_model": "heterogeneous", "exponent": 2.0}"#,
        )
        .unwrap();
        let p = synth_from_json(&v).unwrap();
        assert_eq!(p.n, 200);
        assert_eq!(p.dims, 3);
        assert_eq!(p.dem_range, (0.05, 0.2));
        assert!(matches!(p.cost_model, CostKind::HeterogeneousRandom { exponent } if exponent == 2.0));
    }

    #[test]
    fn json_fixed_cost_model() {
        let v = json::parse(
            r#"{"n": 40, "dims": 2, "cost_model": "fixed",
                "coefficients": [0.7, 0.3], "exponent": 0.5}"#,
        )
        .unwrap();
        let p = synth_from_json(&v).unwrap();
        assert!(matches!(
            &p.cost_model,
            CostKind::Fixed { coefficients, exponent }
                if coefficients == &vec![0.7, 0.3] && *exponent == 0.5
        ));
    }

    #[test]
    fn bad_configs_rejected() {
        assert!(Backend::parse("quantum").is_err());
        let v = json::parse(r#"{"dem_range": [0.1]}"#).unwrap();
        assert!(synth_from_json(&v).is_err());
        let v = json::parse(r#"{"cost_model": "mystery"}"#).unwrap();
        assert!(synth_from_json(&v).is_err());
        // unknown keys no longer silently ignored
        let v = json::parse(r#"{"n": 10, "horizons": 5}"#).unwrap();
        let err = synth_from_json(&v).unwrap_err().to_string();
        assert!(err.contains("unknown key 'horizons'"), "{err}");
        // fixed without coefficients
        let v = json::parse(r#"{"cost_model": "fixed"}"#).unwrap();
        assert!(synth_from_json(&v).is_err());
    }

    #[test]
    fn trace_kind_shim_renders_specs() {
        let spec = TraceKind::GctLike { n: 500, m: 7, priced: true }.to_spec();
        assert_eq!(spec.render(), "gct:m=7,n=500,priced");
        let mut p = SynthParams::default();
        p.dims = 7;
        let spec = TraceKind::Synthetic(p).to_spec();
        assert_eq!(spec.render(), "synth:dims=7");
        // the rendered shim spec round-trips through the shared parser
        assert!(spec.source().is_ok());
        // fixed-coefficient cost models render to cost=fixed,coef=... and
        // still parse (the grammar is complete over SynthParams)
        let mut p = SynthParams::default();
        p.dims = 2;
        p.cost_model =
            CostKind::Fixed { coefficients: vec![0.7, 0.3], exponent: 2.0 };
        let spec = TraceKind::Synthetic(p).to_spec();
        assert_eq!(spec.render(), "synth:coef=0.7;0.3,cost=fixed,dims=2,e=2");
        assert!(spec.source().is_ok());
    }

    #[test]
    fn seeds() {
        assert_eq!(default_seeds(false).len(), 5);
        assert_eq!(default_seeds(true).len(), 2);
    }
}
