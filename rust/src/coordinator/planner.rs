//! The planning coordinator: backend selection, full-instance evaluation
//! (a pipeline portfolio + lower bound), and a worker pool for scenario
//! sweeps. This is the L3 entry point both the CLI and the service use.

use std::sync::Arc;

use anyhow::Result;

use crate::algo::decompose::{self, DecomposeReport, DecomposeSpec};
use crate::algo::pipeline::{Portfolio, StageTime};
use crate::lp::dual;
use crate::lp::scaling;
use crate::lp::solver::{MappingSolver, NativePdhgSolver, SimplexSolver};
use crate::lp::MappingLp;
use crate::model::{trim, Instance};
use crate::runtime::ArtifactSolver;

use super::config::Backend;
use super::metrics::Metrics;
use super::session::SessionRegistry;

/// One algorithm's evaluation on one instance.
#[derive(Clone, Debug)]
pub struct AlgoEval {
    /// Pipeline display label (figure legend name for the presets).
    pub label: String,
    pub cost: f64,
    /// Cost normalized by the certified lower bound.
    pub normalized: f64,
    /// Total wall seconds attributed to this algorithm; pipelines that
    /// consumed the shared LP solve include its time (the old
    /// `t_solve + t_place` convention). Under [`Planner::evaluate`] the
    /// pipelines race concurrently, so these are contended wall times;
    /// use [`Planner::evaluate_sequential`] for isolated measurements.
    pub seconds: f64,
    /// Per-stage wall times from the pipeline run.
    pub stages: Vec<StageTime>,
}

/// Evaluation of one instance: LB-normalized costs for a portfolio of
/// pipelines (by default the four paper presets), plus diagnostics.
#[derive(Clone, Debug)]
pub struct EvalRow {
    /// One entry per portfolio member, in portfolio order.
    pub algos: Vec<AlgoEval>,
    pub lower_bound: f64,
    /// Wall seconds spent on the lower-bound extras (congestion bound).
    pub lb_seconds: f64,
    /// Figure-5 series from the shared LP solve.
    pub x_max: Vec<f64>,
    pub backend_used: &'static str,
    pub lp_converged: bool,
}

impl EvalRow {
    /// Look up one algorithm's evaluation by display label.
    pub fn get(&self, label: &str) -> Option<&AlgoEval> {
        self.algos.iter().find(|a| a.label == label)
    }

    /// The cheapest algorithm (shared first-wins selection rule).
    pub fn best(&self) -> &AlgoEval {
        let i = crate::util::stats::argmin_f64(self.algos.iter().map(|a| a.cost))
            .expect("non-empty evaluation");
        &self.algos[i]
    }
}

/// Planner: owns the (optional) artifact engine and dispatches solves.
/// Also hosts the plan-session registry, shared by every service
/// connection (sessions outlive the connection that opened them).
pub struct Planner {
    backend: Backend,
    artifact: Option<Arc<ArtifactSolver>>,
    pub metrics: Arc<Metrics>,
    pub sessions: SessionRegistry,
}

impl Planner {
    /// Build a planner. `Auto`/`Artifact` try to load artifacts;
    /// `Auto` silently degrades to native when they are absent.
    pub fn new(backend: Backend) -> Result<Planner> {
        let artifact = match backend {
            Backend::Artifact => Some(Arc::new(ArtifactSolver::from_default_dir()?)),
            Backend::Auto => match ArtifactSolver::from_default_dir() {
                Ok(s) => Some(Arc::new(s)),
                Err(e) => {
                    eprintln!("note: artifacts unavailable ({e}); using native backend");
                    None
                }
            },
            _ => None,
        };
        Ok(Planner {
            backend,
            artifact,
            metrics: Arc::new(Metrics::new()),
            sessions: SessionRegistry::new(),
        })
    }

    /// Pick the solver for a (trimmed) instance shape and report its name.
    pub fn solver_for(&self, inst: &Instance) -> (Box<dyn MappingSolver + '_>, &'static str) {
        let (n, m, t, d) =
            (inst.n_tasks(), inst.n_types(), inst.horizon as usize, inst.dims());
        match self.backend {
            Backend::Simplex => (Box::new(SimplexSolver), "simplex"),
            Backend::Native => (Box::new(NativePdhgSolver::default()), "pdhg-native"),
            Backend::Artifact => {
                let s = self.artifact.as_ref().expect("artifact backend loaded").clone();
                (Box::new(ArcSolver(s)), "pdhg-artifact")
            }
            Backend::Auto => {
                // the compiled artifact factors the constraint matrix as
                // (activity x per-task ratios): it cannot express per-slot
                // (shaped) coefficients, so shaped instances route native
                let flat = inst.tasks.iter().all(|u| u.is_flat());
                if let (Some(a), true) = (&self.artifact, flat) {
                    // probe bucket fit using the logical LP shape
                    let probe = MappingLp {
                        n,
                        m,
                        dims: d,
                        t,
                        spans: vec![],
                        seg_off: vec![],
                        seg_spans: vec![],
                        seg_ratios: vec![],
                        costs: vec![],
                        rho: vec![],
                    };
                    if let Some(bucket) = a.bucket_for(&probe) {
                        // The artifact computes over the padded dense shape;
                        // if padding inflates the work too far past the
                        // actual problem volume, the native sparse-operator
                        // backend wins. Factor 8 ~ measured crossover.
                        let actual = (n * m * t * d).max(1);
                        if bucket.volume() <= 8 * actual {
                            return (Box::new(ArcSolver(a.clone())), "pdhg-artifact");
                        }
                    }
                }
                (Box::new(NativePdhgSolver::default()), "pdhg-native")
            }
        }
    }

    /// Evaluate the four preset pipelines + lower bound on a raw instance
    /// (timeline trimming applied here). The presets race on scoped
    /// threads sharing one LP solve, so per-algorithm `seconds` are
    /// contended wall times — see [`Planner::evaluate_sequential`].
    pub fn evaluate(&self, inst: &Instance) -> Result<EvalRow> {
        self.eval_inner(inst, Portfolio::presets(), true)
    }

    /// [`Planner::evaluate`] with a sequential fold instead of the race:
    /// identical results, uncontended per-algorithm timings (the variant
    /// the section VI-E running-time report uses).
    pub fn evaluate_sequential(&self, inst: &Instance) -> Result<EvalRow> {
        self.eval_inner(inst, Portfolio::presets(), false)
    }

    /// Evaluate an arbitrary pipeline portfolio + lower bound. The
    /// members race on scoped threads and share one LP solve; the LB
    /// comes from the shared LP's certified dual bound floored by the
    /// congestion bound (both certified in f64), so the portfolio must
    /// contain at least one LP-based pipeline.
    pub fn evaluate_portfolio(
        &self,
        inst: &Instance,
        portfolio: Portfolio,
    ) -> Result<EvalRow> {
        self.eval_inner(inst, portfolio, true)
    }

    fn eval_inner(
        &self,
        inst: &Instance,
        portfolio: Portfolio,
        parallel: bool,
    ) -> Result<EvalRow> {
        let tr = trim(inst).instance;
        let (solver, backend_used) = self.solver_for(&tr);
        let m = &self.metrics;

        anyhow::ensure!(
            portfolio.pipelines.iter().any(|p| p.needs_lp()),
            "portfolio needs an LP-based pipeline to certify the lower bound"
        );
        let race = m.time("portfolio_race", || {
            if parallel {
                portfolio.run(&tr, solver.as_ref())
            } else {
                portfolio.run_sequential(&tr, solver.as_ref())
            }
        })?;
        let outcome = race.lp.as_ref().expect("portfolio solved the shared LP");
        m.observe("lp_solve", race.lp_seconds);

        // Lower bound: certified dual bound from the shared LP solve,
        // floored by the congestion bound.
        let t0 = std::time::Instant::now();
        let cong = {
            let mut lp = MappingLp::from_instance(&tr);
            scaling::equilibrate(&mut lp);
            dual::congestion_bound(&lp)
        };
        let lb = outcome.certified_lb.max(cong);
        let lb_seconds = t0.elapsed().as_secs_f64();
        anyhow::ensure!(lb > 0.0, "degenerate lower bound {lb}");

        let algos: Vec<AlgoEval> = race
            .reports
            .iter()
            .map(|r| {
                let lp_share = if r.lp.is_some() { race.lp_seconds } else { 0.0 };
                let seconds = r.total_seconds() + lp_share;
                m.observe(&format!("pipeline.{}", r.label), seconds);
                AlgoEval {
                    label: r.label.clone(),
                    cost: r.cost,
                    normalized: r.cost / lb,
                    seconds,
                    stages: r.stages.clone(),
                }
            })
            .collect();
        m.inc("instances_evaluated", 1);
        Ok(EvalRow {
            algos,
            lower_bound: lb,
            lb_seconds,
            x_max: outcome.x_max.clone(),
            backend_used,
            lp_converged: outcome.solver_converged,
        })
    }

    /// Decomposed solve (timeline trimming applied here): partition the
    /// instance per `spec`, race the portfolio inside each partition
    /// concurrently, merge and stitch. Partition workers each need
    /// their own solver, so this path always uses the stateless
    /// native/simplex solvers — the artifact engine's buckets are sized
    /// for full instances, and sub-instance shapes would mostly miss
    /// them anyway. Returns the report and the backend label used.
    ///
    /// Telemetry: `decomposed_solves` / `decompose_partitions` counters
    /// and `decompose_solve` / `decompose_partition` /
    /// `decompose_stitch` timers, surfaced by the service `stats` op
    /// like every other stage.
    pub fn solve_decomposed(
        &self,
        inst: &Instance,
        portfolio: &Portfolio,
        spec: &DecomposeSpec,
    ) -> Result<(DecomposeReport, &'static str)> {
        let tr = trim(inst).instance;
        let simplex = matches!(self.backend, Backend::Simplex);
        let factory = move || -> Box<dyn MappingSolver> {
            if simplex {
                Box::new(SimplexSolver)
            } else {
                Box::new(NativePdhgSolver::default())
            }
        };
        let backend_used = if simplex { "simplex" } else { "pdhg-native" };
        let m = &self.metrics;
        let rep = m.time("decompose_solve", || {
            decompose::solve_decomposed(&tr, portfolio, &factory, spec)
        })?;
        m.inc("decomposed_solves", 1);
        m.inc("decompose_partitions", rep.partitions.len() as u64);
        m.observe("decompose_partition_wall", rep.partition_seconds);
        m.observe("decompose_stitch", rep.stitch_seconds);
        for p in &rep.partitions {
            m.observe("decompose_partition", p.seconds);
        }
        anyhow::ensure!(
            rep.certified_lb <= rep.cost + 1e-6 * (1.0 + rep.cost.abs()),
            "certified bound {} exceeds decomposed cost {}",
            rep.certified_lb,
            rep.cost
        );
        Ok((rep, backend_used))
    }

    /// Run jobs across a worker pool (scoped threads, shared queue).
    /// Results are returned in job order.
    pub fn run_jobs<T, R>(
        &self,
        jobs: Vec<T>,
        workers: usize,
        f: impl Fn(&T) -> R + Sync,
    ) -> Vec<R>
    where
        T: Sync,
        R: Send,
    {
        crate::util::pool::run_indexed(jobs.len(), workers, |i| f(&jobs[i]))
    }
}

/// Adapter: Arc<ArtifactSolver> as a MappingSolver.
struct ArcSolver(Arc<ArtifactSolver>);

impl MappingSolver for ArcSolver {
    fn solve_mapping(&self, lp: &MappingLp) -> Result<crate::lp::solver::MappingSolution> {
        self.0.solve_mapping(lp)
    }

    fn name(&self) -> &'static str {
        "pdhg-artifact"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};

    #[test]
    fn native_planner_evaluates() {
        let planner = Planner::new(Backend::Native).unwrap();
        let inst = generate(&SynthParams { n: 80, m: 4, ..Default::default() }, 2);
        let row = planner.evaluate(&inst).unwrap();
        assert!(row.lower_bound > 0.0);
        assert_eq!(row.algos.len(), 4);
        for a in &row.algos {
            assert!(a.normalized >= 1.0 - 1e-6, "{} beat the lower bound: {}", a.label, a.normalized);
            assert!(a.normalized < 5.0, "{} way off: {}", a.label, a.normalized);
            assert!(!a.stages.is_empty(), "{} has no stage telemetry", a.label);
        }
        // LP-map should not lose to PenaltyMap by much on defaults
        let lp = row.get("LP-map").unwrap();
        let pen = row.get("PenaltyMap").unwrap();
        assert!(lp.normalized <= pen.normalized + 0.25);
        // LP pipelines carry the shared solve time; the best() helper
        // picks a member at least as cheap as every other
        assert!(lp.seconds > 0.0);
        assert!(row.algos.iter().all(|a| row.best().cost <= a.cost + 1e-12));
        assert_eq!(row.backend_used, "pdhg-native");
    }

    #[test]
    fn decomposed_solve_reports_telemetry() {
        let planner = Planner::new(Backend::Native).unwrap();
        let inst = generate(&SynthParams { n: 90, m: 4, ..Default::default() }, 5);
        let portfolio =
            crate::algo::pipeline::parse_portfolio("penalty-map,penalty-map-f").unwrap();
        let spec = decompose::parse_decompose("window:3").unwrap();
        let (rep, backend) = planner.solve_decomposed(&inst, &portfolio, &spec).unwrap();
        assert_eq!(backend, "pdhg-native");
        let tr = trim(&inst).instance;
        assert!(rep.solution.verify(&tr).is_ok());
        assert!(rep.certified_lb > 0.0);
        assert_eq!(rep.partitions.len(), 3);
        assert_eq!(planner.metrics.counter("decomposed_solves"), 1);
        assert_eq!(planner.metrics.counter("decompose_partitions"), 3);
        assert!(planner.metrics.timer_count("decompose_partition") == 3);
    }

    #[test]
    fn worker_pool_ordering() {
        let planner = Planner::new(Backend::Native).unwrap();
        let jobs: Vec<usize> = (0..17).collect();
        let out = planner.run_jobs(jobs, 4, |&i| i * i);
        assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
    }
}
