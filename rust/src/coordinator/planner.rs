//! The planning coordinator: backend selection, full-instance evaluation
//! (all four algorithms + lower bound), and a worker pool for scenario
//! sweeps. This is the L3 entry point both the CLI and the service use.

use std::sync::Arc;

use anyhow::Result;

use crate::algo::algorithms::{lp_place_best, penalty_map_best};
use crate::algo::lpmap::solve_lp_mapping;
use crate::lp::dual;
use crate::lp::scaling;
use crate::lp::solver::{MappingSolver, NativePdhgSolver, SimplexSolver};
use crate::lp::MappingLp;
use crate::model::{trim, Instance};
use crate::runtime::ArtifactSolver;

use super::config::Backend;
use super::metrics::Metrics;

/// Evaluation of one instance: absolute and LB-normalized costs for the
/// four algorithms, plus diagnostics.
#[derive(Clone, Debug)]
pub struct EvalRow {
    /// [PenaltyMap, PenaltyMap-F, LP-map, LP-map-F]
    pub costs: [f64; 4],
    pub lower_bound: f64,
    pub normalized: [f64; 4],
    /// Figure-5 series from the LP-map solve.
    pub x_max: Vec<f64>,
    /// Wall seconds: [penalty, penalty_f, lp, lp_f, lb]
    pub seconds: [f64; 5],
    pub backend_used: &'static str,
    pub lp_converged: bool,
}

/// Planner: owns the (optional) artifact engine and dispatches solves.
pub struct Planner {
    backend: Backend,
    artifact: Option<Arc<ArtifactSolver>>,
    pub metrics: Arc<Metrics>,
}

impl Planner {
    /// Build a planner. `Auto`/`Artifact` try to load artifacts;
    /// `Auto` silently degrades to native when they are absent.
    pub fn new(backend: Backend) -> Result<Planner> {
        let artifact = match backend {
            Backend::Artifact => Some(Arc::new(ArtifactSolver::from_default_dir()?)),
            Backend::Auto => match ArtifactSolver::from_default_dir() {
                Ok(s) => Some(Arc::new(s)),
                Err(e) => {
                    eprintln!("note: artifacts unavailable ({e}); using native backend");
                    None
                }
            },
            _ => None,
        };
        Ok(Planner { backend, artifact, metrics: Arc::new(Metrics::new()) })
    }

    /// Pick the solver for a (trimmed) instance shape and report its name.
    pub fn solver_for(&self, inst: &Instance) -> (Box<dyn MappingSolver + '_>, &'static str) {
        let (n, m, t, d) =
            (inst.n_tasks(), inst.n_types(), inst.horizon as usize, inst.dims());
        match self.backend {
            Backend::Simplex => (Box::new(SimplexSolver), "simplex"),
            Backend::Native => (Box::new(NativePdhgSolver::default()), "pdhg-native"),
            Backend::Artifact => {
                let s = self.artifact.as_ref().expect("artifact backend loaded").clone();
                (Box::new(ArcSolver(s)), "pdhg-artifact")
            }
            Backend::Auto => {
                if let Some(a) = &self.artifact {
                    // probe bucket fit using the logical LP shape
                    let probe = MappingLp {
                        n,
                        m,
                        dims: d,
                        t,
                        spans: vec![],
                        ratios: vec![],
                        costs: vec![],
                        rho: vec![],
                    };
                    if let Some(bucket) = a.bucket_for(&probe) {
                        // The artifact computes over the padded dense shape;
                        // if padding inflates the work too far past the
                        // actual problem volume, the native sparse-operator
                        // backend wins. Factor 8 ~ measured crossover.
                        let actual = (n * m * t * d).max(1);
                        if bucket.volume() <= 8 * actual {
                            return (Box::new(ArcSolver(a.clone())), "pdhg-artifact");
                        }
                    }
                }
                (Box::new(NativePdhgSolver::default()), "pdhg-native")
            }
        }
    }

    /// Evaluate all four algorithms + lower bound on a raw instance
    /// (timeline trimming applied here).
    pub fn evaluate(&self, inst: &Instance) -> Result<EvalRow> {
        let tr = trim(inst).instance;
        let (solver, backend_used) = self.solver_for(&tr);
        let m = &self.metrics;

        let t0 = std::time::Instant::now();
        let pen = m.time("penalty_map", || penalty_map_best(&tr, false));
        let t_pen = t0.elapsed().as_secs_f64();

        let t0 = std::time::Instant::now();
        let pen_f = m.time("penalty_map_f", || penalty_map_best(&tr, true));
        let t_pen_f = t0.elapsed().as_secs_f64();

        // One LP solve feeds LP-map, LP-map-F and the lower bound.
        let t0 = std::time::Instant::now();
        let outcome = m.time("lp_solve", || solve_lp_mapping(&tr, solver.as_ref()))?;
        let t_solve = t0.elapsed().as_secs_f64();

        let t0 = std::time::Instant::now();
        let lp_sol = m.time("lp_map_place", || lp_place_best(&tr, &outcome, false));
        let t_lp = t_solve + t0.elapsed().as_secs_f64();

        let t0 = std::time::Instant::now();
        let lp_f_sol = m.time("lp_map_f_place", || lp_place_best(&tr, &outcome, true));
        let t_lp_f = t_solve + t0.elapsed().as_secs_f64();

        // Lower bound: certified dual bound from the LP solve, floored by
        // the congestion bound; both certified in f64.
        let t0 = std::time::Instant::now();
        let cong = {
            let mut lp = MappingLp::from_instance(&tr);
            scaling::equilibrate(&mut lp);
            dual::congestion_bound(&lp)
        };
        let lb = outcome.certified_lb.max(cong);
        let t_lb = t0.elapsed().as_secs_f64();
        anyhow::ensure!(lb > 0.0, "degenerate lower bound {lb}");

        let costs = [
            pen.cost(&tr),
            pen_f.cost(&tr),
            lp_sol.cost(&tr),
            lp_f_sol.cost(&tr),
        ];
        m.inc("instances_evaluated", 1);
        Ok(EvalRow {
            costs,
            lower_bound: lb,
            normalized: [costs[0] / lb, costs[1] / lb, costs[2] / lb, costs[3] / lb],
            x_max: outcome.x_max,
            seconds: [t_pen, t_pen_f, t_lp, t_lp_f, t_lb],
            backend_used,
            lp_converged: outcome.solver_converged,
        })
    }

    /// Run jobs across a worker pool (scoped threads, shared queue).
    /// Results are returned in job order.
    pub fn run_jobs<T, R>(
        &self,
        jobs: Vec<T>,
        workers: usize,
        f: impl Fn(&T) -> R + Sync,
    ) -> Vec<R>
    where
        T: Sync,
        R: Send,
    {
        let n = jobs.len();
        let next = std::sync::atomic::AtomicUsize::new(0);
        let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let slots = std::sync::Mutex::new(&mut results);
        let workers = workers.max(1).min(n.max(1));
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    if i >= n {
                        break;
                    }
                    let r = f(&jobs[i]);
                    slots.lock().unwrap()[i] = Some(r);
                });
            }
        });
        results.into_iter().map(|r| r.expect("job completed")).collect()
    }
}

/// Adapter: Arc<ArtifactSolver> as a MappingSolver.
struct ArcSolver(Arc<ArtifactSolver>);

impl MappingSolver for ArcSolver {
    fn solve_mapping(&self, lp: &MappingLp) -> Result<crate::lp::solver::MappingSolution> {
        self.0.solve_mapping(lp)
    }

    fn name(&self) -> &'static str {
        "pdhg-artifact"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};

    #[test]
    fn native_planner_evaluates() {
        let planner = Planner::new(Backend::Native).unwrap();
        let inst = generate(&SynthParams { n: 80, m: 4, ..Default::default() }, 2);
        let row = planner.evaluate(&inst).unwrap();
        assert!(row.lower_bound > 0.0);
        for (i, &nc) in row.normalized.iter().enumerate() {
            assert!(nc >= 1.0 - 1e-6, "algo {i} beat the lower bound: {nc}");
            assert!(nc < 5.0, "algo {i} way off: {nc}");
        }
        // LP-map should not lose to PenaltyMap by much on defaults
        assert!(row.normalized[2] <= row.normalized[0] + 0.25);
        assert_eq!(row.backend_used, "pdhg-native");
    }

    #[test]
    fn worker_pool_ordering() {
        let planner = Planner::new(Backend::Native).unwrap();
        let jobs: Vec<usize> = (0..17).collect();
        let out = planner.run_jobs(jobs, 4, |&i| i * i);
        assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
    }
}
