//! The planning coordinator: backend selection, full-instance evaluation
//! (a pipeline portfolio + lower bound), and a worker pool for scenario
//! sweeps. This is the L3 entry point both the CLI and the service use.

use std::sync::{mpsc, Arc, Mutex};
use std::thread;

use anyhow::{anyhow, Result};

use crate::algo::decompose::{self, DecomposeReport, DecomposeSpec};
use crate::algo::pipeline::{Portfolio, StageTime};
use crate::lp::dual;
use crate::lp::scaling;
use crate::lp::solver::{MappingSolution, MappingSolver, NativePdhgSolver, SimplexSolver};
use crate::lp::MappingLp;
use crate::model::{trim, Instance};
use crate::runtime::{ArtifactSolver, Manifest};

use super::config::Backend;
use super::metrics::Metrics;
use super::session::SessionRegistry;

/// One algorithm's evaluation on one instance.
#[derive(Clone, Debug)]
pub struct AlgoEval {
    /// Pipeline display label (figure legend name for the presets).
    pub label: String,
    pub cost: f64,
    /// Cost normalized by the certified lower bound.
    pub normalized: f64,
    /// Total wall seconds attributed to this algorithm; pipelines that
    /// consumed the shared LP solve include its time (the old
    /// `t_solve + t_place` convention). Under [`Planner::evaluate`] the
    /// pipelines race concurrently, so these are contended wall times;
    /// use [`Planner::evaluate_sequential`] for isolated measurements.
    pub seconds: f64,
    /// Per-stage wall times from the pipeline run.
    pub stages: Vec<StageTime>,
}

/// Evaluation of one instance: LB-normalized costs for a portfolio of
/// pipelines (by default the four paper presets), plus diagnostics.
#[derive(Clone, Debug)]
pub struct EvalRow {
    /// One entry per portfolio member, in portfolio order.
    pub algos: Vec<AlgoEval>,
    pub lower_bound: f64,
    /// Wall seconds spent on the lower-bound extras (congestion bound).
    pub lb_seconds: f64,
    /// Figure-5 series from the shared LP solve.
    pub x_max: Vec<f64>,
    pub backend_used: &'static str,
    pub lp_converged: bool,
}

impl EvalRow {
    /// Look up one algorithm's evaluation by display label.
    pub fn get(&self, label: &str) -> Option<&AlgoEval> {
        self.algos.iter().find(|a| a.label == label)
    }

    /// The cheapest algorithm (shared first-wins selection rule).
    pub fn best(&self) -> &AlgoEval {
        let i = crate::util::stats::argmin_f64(self.algos.iter().map(|a| a.cost))
            .expect("non-empty evaluation");
        &self.algos[i]
    }
}

/// Planner: owns the (optional) artifact engine and dispatches solves.
/// Also hosts the plan-session registry, shared by every service
/// connection (sessions outlive the connection that opened them).
pub struct Planner {
    backend: Backend,
    artifact: Option<ArtifactRoute>,
    /// LP worker-thread knob (0 = auto): the default for every solve
    /// this planner dispatches; per-request `lp_threads` overrides it.
    lp_threads: usize,
    pub metrics: Arc<Metrics>,
    pub sessions: SessionRegistry,
}

impl Planner {
    /// Build a planner. `Auto`/`Artifact` try to load artifacts;
    /// `Auto` silently degrades to native when they are absent.
    pub fn new(backend: Backend) -> Result<Planner> {
        let artifact = match backend {
            Backend::Artifact => {
                Some(ArtifactRoute::Direct(Arc::new(ArtifactSolver::from_default_dir()?)))
            }
            Backend::Auto => match ArtifactSolver::from_default_dir() {
                Ok(s) => Some(ArtifactRoute::Direct(Arc::new(s))),
                Err(e) => {
                    eprintln!("note: artifacts unavailable ({e}); using native backend");
                    None
                }
            },
            _ => None,
        };
        Ok(Planner {
            backend,
            artifact,
            lp_threads: 0,
            metrics: Arc::new(Metrics::new()),
            sessions: SessionRegistry::new(),
        })
    }

    /// Set the planner-wide LP thread knob (CLI `--lp-threads`; 0 = auto).
    /// LP results are bit-identical for every value (see `lp::pdhg`).
    pub fn set_lp_threads(&mut self, threads: usize) {
        self.lp_threads = threads.min(crate::lp::pdhg::MAX_LP_THREADS);
    }

    /// The planner-wide LP thread knob (0 = auto).
    pub fn lp_threads(&self) -> usize {
        self.lp_threads
    }

    /// Move the artifact solver (if loaded) onto a dedicated solver
    /// thread behind a channel, so concurrent connection workers can
    /// share it without sharing the PJRT client across threads: workers
    /// hold a cheap channel handle, solves serialize on the one thread.
    /// Returns whether a solver was rerouted. Idempotent; `tlrs serve`
    /// calls this before starting the concurrent runtime.
    pub fn route_artifact_serial(&mut self) -> bool {
        match self.artifact.take() {
            Some(ArtifactRoute::Direct(a)) => {
                let manifest = a.manifest().clone();
                let serial = Arc::new(SerialSolver::spawn(ArcSolver(a), "pdhg-artifact"));
                self.artifact = Some(ArtifactRoute::Serial { solver: serial, manifest });
                true
            }
            other => {
                self.artifact = other;
                false
            }
        }
    }

    /// Whether this planner still holds a direct (thread-confined)
    /// artifact handle that a concurrent runtime must not share.
    pub fn artifact_needs_serial_routing(&self) -> bool {
        matches!(self.artifact, Some(ArtifactRoute::Direct(_)))
    }

    /// Pick the solver for a (trimmed) instance shape and report its name.
    pub fn solver_for(&self, inst: &Instance) -> (Box<dyn MappingSolver + '_>, &'static str) {
        self.solver_for_threads(inst, None)
    }

    /// [`Planner::solver_for`] with a per-request LP-thread override
    /// (service `lp_threads` field); `None` uses the planner-wide knob.
    /// Native solves record the resolved count in the `lp_threads_used`
    /// gauge surfaced by `{"op":"stats"}`.
    pub fn solver_for_threads(
        &self,
        inst: &Instance,
        threads: Option<usize>,
    ) -> (Box<dyn MappingSolver + '_>, &'static str) {
        let (n, m, t, d) =
            (inst.n_tasks(), inst.n_types(), inst.horizon as usize, inst.dims());
        let eff = threads.unwrap_or(self.lp_threads);
        let native = || -> Box<dyn MappingSolver> {
            let resolved = crate::lp::pdhg::resolve_threads(eff);
            self.metrics.gauge_set("lp_threads_used", resolved as i64);
            Box::new(NativePdhgSolver::with_threads(eff))
        };
        match self.backend {
            Backend::Simplex => (Box::new(SimplexSolver), "simplex"),
            Backend::Native => (native(), "pdhg-native"),
            Backend::Artifact => {
                let route = self.artifact.as_ref().expect("artifact backend loaded");
                (route.solver(), "pdhg-artifact")
            }
            Backend::Auto => {
                // the compiled artifact factors the constraint matrix as
                // (activity x per-task ratios): it cannot express per-slot
                // (shaped) coefficients, so shaped instances route native
                let flat = inst.tasks.iter().all(|u| u.is_flat());
                if let (Some(route), true) = (&self.artifact, flat) {
                    // probe bucket fit using the logical LP shape
                    let probe = MappingLp {
                        n,
                        m,
                        dims: d,
                        t,
                        spans: vec![],
                        seg_off: vec![],
                        seg_spans: vec![],
                        seg_ratios: vec![],
                        costs: vec![],
                        rho: vec![],
                    };
                    if let Some(volume) = route.bucket_volume(&probe) {
                        // The artifact computes over the padded dense shape;
                        // if padding inflates the work too far past the
                        // actual problem volume, the native sparse-operator
                        // backend wins. Factor 8 ~ measured crossover.
                        let actual = (n * m * t * d).max(1);
                        if volume <= 8 * actual {
                            return (route.solver(), "pdhg-artifact");
                        }
                    }
                }
                (native(), "pdhg-native")
            }
        }
    }

    /// Evaluate the four preset pipelines + lower bound on a raw instance
    /// (timeline trimming applied here). The presets race on scoped
    /// threads sharing one LP solve, so per-algorithm `seconds` are
    /// contended wall times — see [`Planner::evaluate_sequential`].
    pub fn evaluate(&self, inst: &Instance) -> Result<EvalRow> {
        self.eval_inner(inst, Portfolio::presets(), true)
    }

    /// [`Planner::evaluate`] with a sequential fold instead of the race:
    /// identical results, uncontended per-algorithm timings (the variant
    /// the section VI-E running-time report uses).
    pub fn evaluate_sequential(&self, inst: &Instance) -> Result<EvalRow> {
        self.eval_inner(inst, Portfolio::presets(), false)
    }

    /// Evaluate an arbitrary pipeline portfolio + lower bound. The
    /// members race on scoped threads and share one LP solve; the LB
    /// comes from the shared LP's certified dual bound floored by the
    /// congestion bound (both certified in f64), so the portfolio must
    /// contain at least one LP-based pipeline.
    pub fn evaluate_portfolio(
        &self,
        inst: &Instance,
        portfolio: Portfolio,
    ) -> Result<EvalRow> {
        self.eval_inner(inst, portfolio, true)
    }

    fn eval_inner(
        &self,
        inst: &Instance,
        portfolio: Portfolio,
        parallel: bool,
    ) -> Result<EvalRow> {
        let tr = trim(inst).instance;
        let (solver, backend_used) = self.solver_for(&tr);
        let m = &self.metrics;

        anyhow::ensure!(
            portfolio.pipelines.iter().any(|p| p.needs_lp()),
            "portfolio needs an LP-based pipeline to certify the lower bound"
        );
        let race = m.time("portfolio_race", || {
            if parallel {
                portfolio.run(&tr, solver.as_ref())
            } else {
                portfolio.run_sequential(&tr, solver.as_ref())
            }
        })?;
        let outcome = race.lp.as_ref().expect("portfolio solved the shared LP");
        m.observe("lp_solve", race.lp_seconds);

        // Lower bound: certified dual bound from the shared LP solve,
        // floored by the congestion bound.
        let t0 = std::time::Instant::now();
        let cong = {
            let mut lp = MappingLp::from_instance_par(&tr, solver.lp_threads());
            scaling::equilibrate(&mut lp);
            dual::congestion_bound(&lp)
        };
        let lb = outcome.certified_lb.max(cong);
        let lb_seconds = t0.elapsed().as_secs_f64();
        anyhow::ensure!(lb > 0.0, "degenerate lower bound {lb}");

        let algos: Vec<AlgoEval> = race
            .reports
            .iter()
            .map(|r| {
                let lp_share = if r.lp.is_some() { race.lp_seconds } else { 0.0 };
                let seconds = r.total_seconds() + lp_share;
                m.observe(&format!("pipeline.{}", r.label), seconds);
                AlgoEval {
                    label: r.label.clone(),
                    cost: r.cost,
                    normalized: r.cost / lb,
                    seconds,
                    stages: r.stages.clone(),
                }
            })
            .collect();
        m.inc("instances_evaluated", 1);
        Ok(EvalRow {
            algos,
            lower_bound: lb,
            lb_seconds,
            x_max: outcome.x_max.clone(),
            backend_used,
            lp_converged: outcome.solver_converged,
        })
    }

    /// Decomposed solve (timeline trimming applied here): partition the
    /// instance per `spec`, race the portfolio inside each partition
    /// concurrently, merge and stitch. Partition workers each need
    /// their own solver, so this path always uses the stateless
    /// native/simplex solvers — the artifact engine's buckets are sized
    /// for full instances, and sub-instance shapes would mostly miss
    /// them anyway. Returns the report and the backend label used.
    ///
    /// Telemetry: `decomposed_solves` / `decompose_partitions` counters
    /// and `decompose_solve` / `decompose_partition` /
    /// `decompose_stitch` timers, surfaced by the service `stats` op
    /// like every other stage.
    pub fn solve_decomposed(
        &self,
        inst: &Instance,
        portfolio: &Portfolio,
        spec: &DecomposeSpec,
    ) -> Result<(DecomposeReport, &'static str)> {
        self.solve_decomposed_threads(inst, portfolio, spec, None)
    }

    /// [`Planner::solve_decomposed`] with a per-request LP-thread
    /// override. Partitions solve concurrently, so the resolved LP
    /// budget is split across the partition workers (`requested_k`);
    /// partitioners of unknown width keep their solvers single-threaded.
    pub fn solve_decomposed_threads(
        &self,
        inst: &Instance,
        portfolio: &Portfolio,
        spec: &DecomposeSpec,
        threads: Option<usize>,
    ) -> Result<(DecomposeReport, &'static str)> {
        let tr = trim(inst).instance;
        let simplex = matches!(self.backend, Backend::Simplex);
        let eff = threads.unwrap_or(self.lp_threads);
        let per_partition = match spec.requested_k() {
            Some(k) => (crate::lp::pdhg::resolve_threads(eff) / k.max(1)).max(1),
            None => 1,
        };
        if !simplex {
            self.metrics.gauge_set("lp_threads_used", per_partition as i64);
        }
        let factory = move || -> Box<dyn MappingSolver> {
            if simplex {
                Box::new(SimplexSolver)
            } else {
                Box::new(NativePdhgSolver::with_threads(per_partition))
            }
        };
        let backend_used = if simplex { "simplex" } else { "pdhg-native" };
        let m = &self.metrics;
        let rep = m.time("decompose_solve", || {
            decompose::solve_decomposed(&tr, portfolio, &factory, spec)
        })?;
        m.inc("decomposed_solves", 1);
        m.inc("decompose_partitions", rep.partitions.len() as u64);
        m.observe("decompose_partition_wall", rep.partition_seconds);
        m.observe("decompose_stitch", rep.stitch_seconds);
        for p in &rep.partitions {
            m.observe("decompose_partition", p.seconds);
        }
        anyhow::ensure!(
            rep.certified_lb <= rep.cost + 1e-6 * (1.0 + rep.cost.abs()),
            "certified bound {} exceeds decomposed cost {}",
            rep.certified_lb,
            rep.cost
        );
        Ok((rep, backend_used))
    }

    /// Run jobs across a worker pool (scoped threads, shared queue).
    /// Results are returned in job order.
    pub fn run_jobs<T, R>(
        &self,
        jobs: Vec<T>,
        workers: usize,
        f: impl Fn(&T) -> R + Sync,
    ) -> Vec<R>
    where
        T: Sync,
        R: Send,
    {
        crate::util::pool::run_indexed(jobs.len(), workers, |i| f(&jobs[i]))
    }
}

/// How the planner reaches the artifact backend: a direct handle (the
/// seed behavior — fine while one thread does all the solving), or a
/// channel to a dedicated solver thread once
/// [`Planner::route_artifact_serial`] ran (required before the
/// concurrent service runtime may serve with more than one worker). The
/// serial route keeps a copy of the bucket manifest so Auto-mode routing
/// decisions stay local instead of round-tripping through the channel.
enum ArtifactRoute {
    Direct(Arc<ArtifactSolver>),
    Serial { solver: Arc<SerialSolver>, manifest: Manifest },
}

impl ArtifactRoute {
    fn solver(&self) -> Box<dyn MappingSolver> {
        match self {
            ArtifactRoute::Direct(a) => Box::new(ArcSolver(a.clone())),
            ArtifactRoute::Serial { solver, .. } => Box::new(SerialHandle(solver.clone())),
        }
    }

    fn bucket_volume(&self, probe: &MappingLp) -> Option<usize> {
        match self {
            ArtifactRoute::Direct(a) => a.bucket_for(probe).map(|b| b.volume()),
            ArtifactRoute::Serial { manifest, .. } => manifest
                .select(probe.n, probe.m, probe.t, probe.dims)
                .map(|b| b.volume()),
        }
    }
}

/// Adapter: Arc<ArtifactSolver> as a MappingSolver.
struct ArcSolver(Arc<ArtifactSolver>);

impl MappingSolver for ArcSolver {
    fn solve_mapping(&self, lp: &MappingLp) -> Result<crate::lp::solver::MappingSolution> {
        self.0.solve_mapping(lp)
    }

    fn name(&self) -> &'static str {
        "pdhg-artifact"
    }
}

// ----- serial solver thread ------------------------------------------------

/// One queued solve: the LP, and where to send the answer.
struct SerialJob {
    lp: MappingLp,
    reply: mpsc::SyncSender<Result<MappingSolution>>,
}

/// Hoist any solver onto a dedicated thread behind a channel: callers on
/// any thread submit an LP and block for the answer, solves execute
/// strictly one at a time on the owning thread. This is how the
/// thread-confined PJRT artifact client serves a multi-worker runtime —
/// the handles are `Send + Sync` even when the inner solver is not
/// shareable. Dropping the `SerialSolver` closes the channel and joins
/// the thread.
pub struct SerialSolver {
    tx: Mutex<Option<mpsc::Sender<SerialJob>>>,
    worker: Mutex<Option<thread::JoinHandle<()>>>,
    name: &'static str,
}

impl SerialSolver {
    pub fn spawn<S: MappingSolver + Send + 'static>(inner: S, name: &'static str) -> Self {
        let (tx, rx) = mpsc::channel::<SerialJob>();
        // lint:allow(raw-spawn): the serial solver owns a dedicated, named,
        // long-lived thread (not a data-parallel fan-out) — the pool's
        // run-to-completion helpers do not fit a command-loop lifetime.
        let worker = thread::Builder::new()
            .name("tlrs-serial-solver".into())
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    // a caller that gave up (dropped its receiver) is fine
                    let _ = job.reply.send(inner.solve_mapping(&job.lp));
                }
            })
            .expect("spawn serial solver thread");
        SerialSolver {
            tx: Mutex::new(Some(tx)),
            worker: Mutex::new(Some(worker)),
            name,
        }
    }

    /// Solve on the dedicated thread; blocks until this job's turn comes
    /// and completes. Queue order is the channel's FIFO order.
    pub fn solve(&self, lp: &MappingLp) -> Result<MappingSolution> {
        let (reply, answer) = mpsc::sync_channel(1);
        {
            let tx = self.tx.lock().unwrap();
            let tx = tx.as_ref().ok_or_else(|| anyhow!("serial solver already shut down"))?;
            tx.send(SerialJob { lp: lp.clone(), reply })
                .map_err(|_| anyhow!("serial solver thread stopped"))?;
        }
        answer
            .recv()
            .map_err(|_| anyhow!("serial solver thread dropped the reply"))?
    }
}

impl Drop for SerialSolver {
    fn drop(&mut self) {
        // closing the channel ends the worker's recv loop
        self.tx.lock().unwrap().take();
        if let Some(h) = self.worker.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// Adapter: a shared SerialSolver as a MappingSolver.
struct SerialHandle(Arc<SerialSolver>);

impl MappingSolver for SerialHandle {
    fn solve_mapping(&self, lp: &MappingLp) -> Result<MappingSolution> {
        self.0.solve(lp)
    }

    fn name(&self) -> &'static str {
        self.0.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};

    #[test]
    fn native_planner_evaluates() {
        let planner = Planner::new(Backend::Native).unwrap();
        let inst = generate(&SynthParams { n: 80, m: 4, ..Default::default() }, 2);
        let row = planner.evaluate(&inst).unwrap();
        assert!(row.lower_bound > 0.0);
        assert_eq!(row.algos.len(), 4);
        for a in &row.algos {
            assert!(a.normalized >= 1.0 - 1e-6, "{} beat the lower bound: {}", a.label, a.normalized);
            assert!(a.normalized < 5.0, "{} way off: {}", a.label, a.normalized);
            assert!(!a.stages.is_empty(), "{} has no stage telemetry", a.label);
        }
        // LP-map should not lose to PenaltyMap by much on defaults
        let lp = row.get("LP-map").unwrap();
        let pen = row.get("PenaltyMap").unwrap();
        assert!(lp.normalized <= pen.normalized + 0.25);
        // LP pipelines carry the shared solve time; the best() helper
        // picks a member at least as cheap as every other
        assert!(lp.seconds > 0.0);
        assert!(row.algos.iter().all(|a| row.best().cost <= a.cost + 1e-12));
        assert_eq!(row.backend_used, "pdhg-native");
    }

    #[test]
    fn decomposed_solve_reports_telemetry() {
        let planner = Planner::new(Backend::Native).unwrap();
        let inst = generate(&SynthParams { n: 90, m: 4, ..Default::default() }, 5);
        let portfolio =
            crate::algo::pipeline::parse_portfolio("penalty-map,penalty-map-f").unwrap();
        let spec = decompose::parse_decompose("window:3").unwrap();
        let (rep, backend) = planner.solve_decomposed(&inst, &portfolio, &spec).unwrap();
        assert_eq!(backend, "pdhg-native");
        let tr = trim(&inst).instance;
        assert!(rep.solution.verify(&tr).is_ok());
        assert!(rep.certified_lb > 0.0);
        assert_eq!(rep.partitions.len(), 3);
        assert_eq!(planner.metrics.counter("decomposed_solves"), 1);
        assert_eq!(planner.metrics.counter("decompose_partitions"), 3);
        assert!(planner.metrics.timer_count("decompose_partition") == 3);
    }

    #[test]
    fn worker_pool_ordering() {
        let planner = Planner::new(Backend::Native).unwrap();
        let jobs: Vec<usize> = (0..17).collect();
        let out = planner.run_jobs(jobs, 4, |&i| i * i);
        assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn serial_solver_serializes_but_answers_every_caller() {
        // deterministic inner solver: three concurrent callers through
        // the one solver thread must each get the bitwise-identical
        // answer a direct solve produces
        let inst = generate(&SynthParams { n: 30, m: 3, ..Default::default() }, 9);
        let tr = trim(&inst).instance;
        let mut lp = MappingLp::from_instance(&tr);
        scaling::equilibrate(&mut lp);
        let direct = NativePdhgSolver::default().solve_mapping(&lp).unwrap();

        let serial = Arc::new(SerialSolver::spawn(NativePdhgSolver::default(), "pdhg-native"));
        let outs: Vec<MappingSolution> = thread::scope(|s| {
            let handles: Vec<_> = (0..3)
                .map(|_| {
                    let serial = serial.clone();
                    let lp = &lp;
                    s.spawn(move || serial.solve(lp).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for o in &outs {
            assert_eq!(o.x, direct.x, "serialized solve must be bit-identical");
            assert!((o.objective - direct.objective).abs() <= 1e-12);
            assert_eq!(o.converged, direct.converged);
        }
        // the adapter reports the inner solver's routing label
        let handle = SerialHandle(serial.clone());
        assert_eq!(handle.name(), "pdhg-native");
        assert_eq!(handle.solve_mapping(&lp).unwrap().x, direct.x);
    }

    #[test]
    fn serial_routing_is_a_noop_without_artifacts() {
        let mut planner = Planner::new(Backend::Native).unwrap();
        assert!(!planner.artifact_needs_serial_routing());
        assert!(!planner.route_artifact_serial(), "nothing to reroute");
        assert!(!planner.route_artifact_serial(), "idempotent");
        // the native path still solves after the (no-op) reroute
        let inst = generate(&SynthParams { n: 20, m: 3, ..Default::default() }, 2);
        let row = planner.evaluate(&inst).unwrap();
        assert_eq!(row.backend_used, "pdhg-native");
    }
}
