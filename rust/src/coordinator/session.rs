//! Stateful plan sessions: incremental re-solve over a typed delta
//! stream.
//!
//! The paper's cold-start rightsizing answers one frozen workload with
//! one solve; a deployed planner watches that workload drift — tasks
//! arrive, retire and reshape (the dynamic arrival/departure setting of
//! DVBP, arXiv 2304.08648, and Eva's continuous reconfiguration loop,
//! arXiv 2503.07437). A [`PlanSession`] keeps everything a cheap
//! incremental answer needs alive between requests:
//!
//!   * the live instance (untrimmed — the timeline is fixed at open so
//!     retained LP iterates stay shape-compatible across deltas),
//!   * the live node pool ([`crate::algo::repair::Pool`]: load profiles
//!     that survive deltas, so an admit is one first-fit scan and a
//!     retirement one profile subtraction),
//!   * the last PDHG primal/dual iterates keyed by task id, which (a)
//!     refresh a certified lower bound per delta without an LP solve
//!     (`dual::certified_bound` repairs any dual point) and (b) warm-
//!     start the full re-solve when escalation fires.
//!
//! Each [`Delta`] is answered by incremental repair — untouched
//! placements are kept; only affected nodes change — and the session
//! escalates to a full warm-started re-solve (through the same
//! pipeline/portfolio API as one-shot solves) only when the incremental
//! cost drifts past `escalate_ratio` × the refreshed certified LB, or a
//! catalog change invalidates the placement outright. Every delta ends
//! with a full per-slot `Solution::verify`: the session never holds an
//! infeasible plan.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, ensure, Context, Result};

use crate::algo::penalty_map::{best_type, MappingPolicy};
use crate::algo::pipeline::parse_portfolio;
use crate::algo::placement::FitPolicy;
use crate::algo::repair::Pool;
use crate::lp::dual;
use crate::lp::pdhg::{self, PdhgOptions, PdhgResult, WarmIterates};
use crate::lp::scaling;
use crate::lp::solver::{MappingSolution, MappingSolver};
use crate::lp::MappingLp;
use crate::model::{Delta, Instance, Solution, Task};

/// Sessions keep per-slot structures on the *untrimmed* timeline (fixed
/// horizon = stable LP dual shape); a pathological horizon would make
/// every per-delta LB refresh scan millions of slots.
pub const MAX_SESSION_HORIZON: u32 = 100_000;

/// Most live tasks one session may hold (at open or grown via admit
/// deltas). Untrusted clients drive the delta surface, and every delta
/// pays O(n·m·D) for the LB refresh — unbounded growth would wedge the
/// service (cf. `MAX_SPEC_TASKS` on the workload-spec surface).
pub const MAX_SESSION_TASKS: usize = 1_000_000;

/// How a session answered one delta.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Incremental repair: untouched placements kept, affected nodes
    /// patched.
    Repair,
    /// Full warm-started re-solve (escalation fired or the catalog
    /// changed shape).
    Resolve,
}

impl Decision {
    pub fn as_str(&self) -> &'static str {
        match self {
            Decision::Repair => "repair",
            Decision::Resolve => "resolve",
        }
    }
}

/// Per-delta answer: what happened, what the plan costs now, and the
/// refreshed certified lower bound it is measured against.
#[derive(Clone, Debug)]
pub struct DeltaReport {
    pub op: &'static str,
    pub decision: Decision,
    /// Why a full re-solve fired (None for repairs).
    pub reason: Option<String>,
    pub cost: f64,
    /// Refreshed certified LB (congestion bound ⊔ re-certified retained
    /// duals; tight dual bound after a re-solve). 0 for an empty session.
    pub lower_bound: f64,
    pub n_tasks: usize,
    pub n_nodes: usize,
    pub seconds: f64,
}

/// Result of opening a session (the initial full solve).
#[derive(Clone, Debug)]
pub struct OpenReport {
    /// Winning pipeline's display label.
    pub label: String,
    pub cost: f64,
    pub lower_bound: f64,
    pub n_tasks: usize,
    pub n_nodes: usize,
    pub seconds: f64,
}

/// Session tuning knobs.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Pipeline/portfolio spec for full solves (the `--algo` language).
    pub algo: String,
    /// Fit policy the incremental repair path scans with.
    pub fit: FitPolicy,
    /// Escalate to a full re-solve when `cost > ratio * refreshed LB`;
    /// `None` never escalates (pure incremental mode).
    pub escalate_ratio: Option<f64>,
    /// Warm-start escalated re-solves from the retained PDHG iterates
    /// (disable to force bit-identical cold re-solves, e.g. in tests).
    pub warm: bool,
    /// LP worker threads for the session's solves (0 = auto; results
    /// are bit-identical for every value).
    pub lp_threads: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            algo: "lp-map-f".into(),
            fit: FitPolicy::FirstFit,
            escalate_ratio: Some(1.5),
            warm: true,
            lp_threads: 0,
        }
    }
}

/// Parse an `escalate` knob value: a ratio >= 1, or "off".
pub fn parse_escalate(s: &str) -> Result<Option<f64>> {
    if s == "off" {
        return Ok(None);
    }
    let r: f64 = s
        .parse()
        .map_err(|_| anyhow!("escalate must be a ratio >= 1 or 'off', got '{s}'"))?;
    ensure!(r.is_finite() && r >= 1.0, "escalate ratio must be >= 1, got {r}");
    Ok(Some(r))
}

/// Parse a repair fit-policy token (the `--algo` fit names).
pub fn parse_fit(s: &str) -> Result<FitPolicy> {
    match s {
        "ff" => Ok(FitPolicy::FirstFit),
        "sim" => Ok(FitPolicy::SimilarityFit),
        other => Err(anyhow!("fit must be 'ff' or 'sim', got '{other}'")),
    }
}

/// Retained PDHG state, keyed by task id so rows survive index
/// compaction across retirements.
#[derive(Clone, Debug)]
struct WarmState {
    /// Task ids aligned with the x rows / w entries of `iterates`.
    ids: Vec<u64>,
    iterates: WarmIterates,
    m: usize,
    t: usize,
    dims: usize,
}

/// Native-PDHG mapping solver that (a) resumes from retained iterates
/// when they fit the LP shape and (b) captures the full result so the
/// session can retain the new iterates. The portfolio solves its shared
/// LP on the calling thread, so the capture slot sees no contention.
struct WarmSolver {
    opts: PdhgOptions,
    warm: Option<WarmIterates>,
    captured: Mutex<Option<PdhgResult>>,
}

impl WarmSolver {
    fn new(warm: Option<WarmIterates>, threads: usize) -> Self {
        WarmSolver {
            opts: PdhgOptions { threads, ..Default::default() },
            warm,
            captured: Mutex::new(None),
        }
    }

    fn take_captured(&self) -> Option<PdhgResult> {
        self.captured.lock().unwrap().take()
    }
}

impl MappingSolver for WarmSolver {
    fn solve_mapping(&self, lp: &MappingLp) -> Result<MappingSolution> {
        let r = match &self.warm {
            Some(w) if w.fits_shape(lp) => pdhg::solve_resume(lp, &self.opts, w),
            _ => pdhg::solve(lp, &self.opts),
        };
        let sol = MappingSolution {
            x: r.x.clone(),
            y: r.y.clone(),
            objective: r.objective,
            converged: r.converged,
            iterations: r.iterations,
        };
        *self.captured.lock().unwrap() = Some(r);
        Ok(sol)
    }

    fn name(&self) -> &'static str {
        "pdhg-native"
    }

    fn lp_threads(&self) -> usize {
        pdhg::resolve_threads(self.opts.threads)
    }
}

/// A live plan under a delta stream. See the module doc.
#[derive(Clone)]
pub struct PlanSession {
    inst: Instance,
    pool: Pool,
    cfg: SessionConfig,
    warm: Option<WarmState>,
    /// Latest refreshed certified lower bound.
    lb: f64,
    id_index: BTreeMap<u64, usize>,
    n_deltas: usize,
    n_repairs: usize,
    n_resolves: usize,
}

impl PlanSession {
    /// Open a session: full initial solve of `inst` through the existing
    /// pipeline/portfolio API (`cfg.algo` spec, native PDHG backend so
    /// iterates can be retained), on the session's fixed untrimmed
    /// timeline.
    pub fn open(inst: Instance, cfg: SessionConfig) -> Result<(PlanSession, OpenReport)> {
        let t0 = Instant::now();
        ensure!(inst.n_tasks() > 0, "cannot open a session on an empty instance");
        ensure!(
            inst.n_tasks() <= MAX_SESSION_TASKS,
            "session would hold {} tasks, over the {MAX_SESSION_TASKS}-task cap",
            inst.n_tasks()
        );
        ensure!(
            inst.horizon <= MAX_SESSION_HORIZON,
            "session horizon {} exceeds the {MAX_SESSION_HORIZON}-slot cap",
            inst.horizon
        );
        ensure!(
            inst.is_feasible(),
            "some task fits no node-type alone — the instance is unplannable"
        );
        {
            let mut seen = BTreeMap::new();
            for (u, t) in inst.tasks.iter().enumerate() {
                if let Some(prev) = seen.insert(t.id, u) {
                    anyhow::bail!(
                        "tasks {prev} and {u} share id {} — session deltas address \
                         tasks by id, which must be unique",
                        t.id
                    );
                }
            }
        }
        let portfolio = parse_portfolio(&cfg.algo)?;
        let solver = WarmSolver::new(None, cfg.lp_threads);
        let race = portfolio.run(&inst, &solver)?;
        let rep = race.best();
        rep.solution
            .verify(&inst)
            .map_err(|v| anyhow!("internal: initial solve infeasible: {v:?}"))?;
        let pool = Pool::from_solution(&inst, &rep.solution);
        let id_index = inst.tasks.iter().enumerate().map(|(u, t)| (t.id, u)).collect();
        let mut session = PlanSession {
            inst,
            pool,
            cfg,
            warm: None,
            lb: 0.0,
            id_index,
            n_deltas: 0,
            n_repairs: 0,
            n_resolves: 0,
        };
        session.retain_iterates(solver.take_captured());
        session.lb = {
            let lp = MappingLp::from_instance_par(
                &session.inst,
                pdhg::resolve_threads(session.cfg.lp_threads),
            );
            let mut lb = dual::congestion_bound(&lp);
            if let Some(clb) = race.certified_lb() {
                lb = lb.max(clb);
            }
            lb
        };
        let report = OpenReport {
            label: rep.label.clone(),
            cost: session.cost(),
            lower_bound: session.lb,
            n_tasks: session.inst.n_tasks(),
            n_nodes: session.pool.len(),
            seconds: t0.elapsed().as_secs_f64(),
        };
        Ok((session, report))
    }

    // ----- accessors -------------------------------------------------------

    pub fn instance(&self) -> &Instance {
        &self.inst
    }

    pub fn config(&self) -> &SessionConfig {
        &self.cfg
    }

    /// Current plan cost.
    pub fn cost(&self) -> f64 {
        self.pool.cost(&self.inst)
    }

    /// Latest refreshed certified lower bound.
    pub fn lower_bound(&self) -> f64 {
        self.lb
    }

    pub fn n_nodes(&self) -> usize {
        self.pool.len()
    }

    pub fn n_tasks(&self) -> usize {
        self.inst.n_tasks()
    }

    /// (deltas applied, answered by repair, answered by full re-solve).
    pub fn delta_counts(&self) -> (usize, usize, usize) {
        (self.n_deltas, self.n_repairs, self.n_resolves)
    }

    /// Snapshot the current placement as a [`Solution`].
    pub fn solution(&self) -> Solution {
        self.pool.to_solution(&self.inst)
    }

    // ----- the delta entry point ------------------------------------------

    /// Apply one delta: incremental repair, LB refresh, optional
    /// escalation to a full warm-started re-solve, per-slot verification.
    /// On `Err` the delta was rejected *before* any state change (input
    /// validation happens first), except for internal-invariant errors
    /// which are labeled as such.
    pub fn apply(&mut self, delta: &Delta) -> Result<DeltaReport> {
        let t0 = Instant::now();
        let force = match delta {
            Delta::Admit { tasks } => {
                self.apply_admit(tasks)?;
                false
            }
            Delta::Retire { ids } => {
                self.apply_retire(ids)?;
                false
            }
            Delta::Reshape { task } => {
                self.apply_reshape(task)?;
                false
            }
            Delta::Reprice { node_types } => self.apply_reprice(node_types)?,
        };
        self.n_deltas += 1;
        self.refresh_lb();

        let mut decision = Decision::Repair;
        let mut reason = None;
        // NOTE: when `force` is set the catalog changed shape, so the
        // stale pool's type indices may be out of range — do not cost it
        let drifted = if force {
            false
        } else {
            let cost = self.cost();
            match self.cfg.escalate_ratio {
                Some(r) if cost > r * self.lb + 1e-9 => {
                    reason = Some(format!(
                        "incremental cost {cost:.4} > {r:.2} x refreshed LB {:.4}",
                        self.lb
                    ));
                    true
                }
                _ => false,
            }
        };
        if (force || drifted) && self.inst.n_tasks() > 0 {
            if force {
                reason = Some(
                    "catalog shape changed — incremental placement invalidated".to_string(),
                );
            }
            self.full_resolve()?;
            decision = Decision::Resolve;
            self.n_resolves += 1;
        } else {
            self.n_repairs += 1;
        }

        // per-slot verification after every delta: the session never
        // holds (or answers from) an infeasible plan
        self.solution().verify(&self.inst).map_err(|v| {
            anyhow!(
                "internal: session state infeasible after {} ({} violations, first: {:?})",
                delta.op(),
                v.len(),
                v.first()
            )
        })?;

        Ok(DeltaReport {
            op: delta.op(),
            decision,
            reason,
            cost: self.cost(),
            lower_bound: self.lb,
            n_tasks: self.inst.n_tasks(),
            n_nodes: self.pool.len(),
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// What-if: price a delta without committing it. The session state is
    /// untouched; the returned report describes the hypothetical plan.
    pub fn quote(&self, delta: &Delta) -> Result<DeltaReport> {
        let mut probe = self.clone();
        probe.apply(delta)
    }

    // ----- per-kind incremental repair ------------------------------------

    fn validate_new_task(&self, t: &Task, verb: &str) -> Result<()> {
        let dims = self.inst.dims();
        ensure!(
            t.dims() == dims,
            "{verb}: task {} has {} dims, the session has {dims}",
            t.id,
            t.dims()
        );
        ensure!(
            t.end < self.inst.horizon,
            "{verb}: task {} ends at {} but the session timeline is fixed at {} slots",
            t.id,
            t.end,
            self.inst.horizon
        );
        for seg in t.segments() {
            ensure!(
                seg.demand.iter().all(|d| d.is_finite() && *d >= 0.0),
                "{verb}: task {}: demand must be finite and non-negative",
                t.id
            );
        }
        ensure!(
            self.inst.node_types.iter().any(|b| b.admits(t.peak())),
            "{verb}: task {} fits no node-type alone",
            t.id
        );
        Ok(())
    }

    fn apply_admit(&mut self, tasks: &[Task]) -> Result<()> {
        ensure!(!tasks.is_empty(), "admit: no tasks given");
        ensure!(
            self.inst.n_tasks() + tasks.len() <= MAX_SESSION_TASKS,
            "admit: session would grow to {} tasks, over the {MAX_SESSION_TASKS}-task cap",
            self.inst.n_tasks() + tasks.len()
        );
        // validate the whole batch before touching any state
        let mut fresh = BTreeMap::new();
        for t in tasks {
            self.validate_new_task(t, "admit")?;
            ensure!(
                !self.id_index.contains_key(&t.id),
                "admit: task id {} is already live",
                t.id
            );
            ensure!(
                fresh.insert(t.id, ()).is_none(),
                "admit: duplicate task id {} within the batch",
                t.id
            );
        }
        for t in tasks {
            let u = self.inst.tasks.len();
            self.inst.tasks.push(t.clone());
            self.id_index.insert(t.id, u);
            if self.pool.try_admit(&self.inst, u, self.cfg.fit, None).is_none() {
                let b = best_type(&self.inst, u, MappingPolicy::HAvg)
                    .expect("validated admissible above");
                self.pool.buy_and_place(&self.inst, u, b)?;
            }
        }
        Ok(())
    }

    fn apply_retire(&mut self, ids: &[u64]) -> Result<()> {
        ensure!(!ids.is_empty(), "retire: no ids given");
        let mut batch = BTreeMap::new();
        for &id in ids {
            ensure!(self.id_index.contains_key(&id), "retire: no live task with id {id}");
            ensure!(
                batch.insert(id, ()).is_none(),
                "retire: duplicate id {id} within the batch"
            );
        }
        let n = self.inst.n_tasks();
        let assignment = self.pool.assignment(n);
        let mut removed = vec![false; n];
        for &id in ids {
            let u = self.id_index[&id];
            removed[u] = true;
            if let Some(bi) = assignment[u] {
                self.pool.evict(&self.inst, u, bi);
            }
        }
        // compact the task vector; node task lists follow
        let mut new_idx = vec![usize::MAX; n];
        let mut kept = Vec::with_capacity(n - ids.len());
        for (u, task) in std::mem::take(&mut self.inst.tasks).into_iter().enumerate() {
            if !removed[u] {
                new_idx[u] = kept.len();
                kept.push(task);
            }
        }
        self.inst.tasks = kept;
        self.pool.remap_tasks(&new_idx);
        self.pool.drop_empty();
        self.id_index = self.inst.tasks.iter().enumerate().map(|(u, t)| (t.id, u)).collect();
        Ok(())
    }

    fn apply_reshape(&mut self, task: &Task) -> Result<()> {
        ensure!(
            self.id_index.contains_key(&task.id),
            "reshape: no live task with id {}",
            task.id
        );
        self.validate_new_task(task, "reshape")?;
        let u = self.id_index[&task.id];
        // eviction-and-refill: subtract the OLD profile, swap the task,
        // then re-admit preferring the node it lived in
        let old_node = self.pool.assignment(self.inst.n_tasks())[u];
        if let Some(bi) = old_node {
            self.pool.evict(&self.inst, u, bi);
        }
        self.inst.tasks[u] = task.clone();
        if self.pool.try_admit(&self.inst, u, self.cfg.fit, old_node).is_none() {
            let b = best_type(&self.inst, u, MappingPolicy::HAvg)
                .expect("validated admissible above");
            self.pool.buy_and_place(&self.inst, u, b)?;
        }
        self.pool.drop_empty();
        Ok(())
    }

    /// Returns true when the catalog changed *shape* (count or
    /// capacities) and the placement must be rebuilt by a full re-solve;
    /// a pure price change keeps the placement valid.
    fn apply_reprice(&mut self, node_types: &[crate::model::NodeType]) -> Result<bool> {
        ensure!(!node_types.is_empty(), "reprice: empty node-type catalog");
        let dims = self.inst.dims();
        for b in node_types {
            ensure!(
                b.dims() == dims,
                "reprice: node-type '{}' has {} dims, the session has {dims}",
                b.name,
                b.dims()
            );
        }
        for t in &self.inst.tasks {
            ensure!(
                node_types.iter().any(|b| b.admits(t.peak())),
                "reprice: live task {} fits no node-type in the new catalog",
                t.id
            );
        }
        let same_shape = node_types.len() == self.inst.node_types.len()
            && node_types
                .iter()
                .zip(&self.inst.node_types)
                .all(|(a, b)| a.capacity == b.capacity);
        self.inst.node_types = node_types.to_vec();
        Ok(!same_shape)
    }

    // ----- LB refresh and escalation --------------------------------------

    /// Refresh the certified lower bound without an LP solve: the
    /// combinatorial congestion bound (Lemma 1) floored-up by
    /// re-certifying the retained dual iterates against the *current*
    /// LP (`dual::certified_bound` repairs any dual point into
    /// feasibility, so the result is a true bound for the new instance).
    fn refresh_lb(&mut self) {
        if self.inst.n_tasks() == 0 {
            self.lb = 0.0;
            return;
        }
        let threads = pdhg::resolve_threads(self.cfg.lp_threads);
        let mut lp = MappingLp::from_instance_par(&self.inst, threads);
        let mut lb = dual::congestion_bound(&lp);
        if let Some(w) = &self.warm {
            if w.m == lp.m && w.t == lp.t && w.dims == lp.dims {
                scaling::equilibrate(&mut lp);
                lb = lb.max(dual::certified_bound_par(&lp, &w.iterates.y, threads).0);
            }
        }
        self.lb = lb;
    }

    /// Map the retained iterates onto the current task order (rows
    /// follow ids; fresh tasks start at zero and are pulled in by the
    /// PDHG projections). None when the dual shape no longer matches.
    fn warm_for_current(&self) -> Option<WarmIterates> {
        let w = self.warm.as_ref()?;
        let (n, m) = (self.inst.n_tasks(), self.inst.n_types());
        if w.m != m || w.t != self.inst.horizon as usize || w.dims != self.inst.dims() {
            return None;
        }
        let old_pos: BTreeMap<u64, usize> =
            w.ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        let mut x = vec![0.0; n * m];
        let mut ww = vec![0.0; n];
        for (u, task) in self.inst.tasks.iter().enumerate() {
            if let Some(&j) = old_pos.get(&task.id) {
                x[u * m..(u + 1) * m].copy_from_slice(&w.iterates.x[j * m..(j + 1) * m]);
                ww[u] = w.iterates.w[j];
            }
        }
        Some(WarmIterates {
            x,
            alpha: w.iterates.alpha.clone(),
            y: w.iterates.y.clone(),
            w: ww,
        })
    }

    fn retain_iterates(&mut self, captured: Option<PdhgResult>) {
        if let Some(r) = captured {
            self.warm = Some(WarmState {
                ids: self.inst.tasks.iter().map(|t| t.id).collect(),
                iterates: WarmIterates::from(&r),
                m: self.inst.n_types(),
                t: self.inst.horizon as usize,
                dims: self.inst.dims(),
            });
        }
    }

    /// Full re-solve of the current instance through the portfolio API,
    /// warm-started from the retained iterates (unless `cfg.warm` is
    /// off). Retains the new iterates and the tight refreshed LB.
    fn full_resolve(&mut self) -> Result<()> {
        let portfolio = parse_portfolio(&self.cfg.algo)?;
        let warm = if self.cfg.warm { self.warm_for_current() } else { None };
        let solver = WarmSolver::new(warm, self.cfg.lp_threads);
        let race = portfolio
            .run(&self.inst, &solver)
            .context("escalated full re-solve")?;
        let rep = race.best();
        self.pool = Pool::from_solution(&self.inst, &rep.solution);
        self.retain_iterates(solver.take_captured());
        let lp = MappingLp::from_instance_par(
            &self.inst,
            pdhg::resolve_threads(self.cfg.lp_threads),
        );
        let mut lb = dual::congestion_bound(&lp);
        if let Some(clb) = race.certified_lb() {
            lb = lb.max(clb);
        }
        self.lb = lb;
        Ok(())
    }
}

// ----- registry -----------------------------------------------------------

/// Most concurrently open sessions one service process accepts — each
/// holds live profiles over its whole timeline, and session ops arrive
/// from untrusted clients.
pub const MAX_SESSIONS: usize = 64;

/// Sessions idle longer than this are evicted when a full registry
/// receives a new open: clients crash and disconnect without closing,
/// and sessions deliberately outlive connections, so without an idle
/// bound 64 leaked opens would deny the session layer to everyone until
/// a process restart. Active sessions are never evicted.
pub const SESSION_IDLE_TIMEOUT: std::time::Duration =
    std::time::Duration::from_secs(30 * 60);

/// Shared session table with per-session locking: ops on different
/// sessions never contend on each other's solves, only on the brief map
/// lookup. Each entry tracks its last-touched instant for idle eviction.
#[derive(Default)]
pub struct SessionRegistry {
    inner: Mutex<BTreeMap<u64, (Arc<Mutex<PlanSession>>, Instant)>>,
    next: AtomicU64,
}

impl SessionRegistry {
    pub fn new() -> Self {
        SessionRegistry::default()
    }

    /// Register a session, returning its id. A full registry first
    /// evicts sessions idle past [`SESSION_IDLE_TIMEOUT`] (abandoned by
    /// crashed/disconnected clients); live ones are never evicted.
    pub fn insert(&self, session: PlanSession) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        if inner.len() >= MAX_SESSIONS {
            let now = Instant::now();
            inner.retain(|_, (_, touched)| now.duration_since(*touched) < SESSION_IDLE_TIMEOUT);
        }
        ensure!(
            inner.len() < MAX_SESSIONS,
            "too many open sessions ({MAX_SESSIONS}); close one first"
        );
        let id = self.next.fetch_add(1, Ordering::SeqCst) + 1;
        inner.insert(id, (Arc::new(Mutex::new(session)), Instant::now()));
        Ok(id)
    }

    /// Handle to a live session (lock it to operate). Touches the entry,
    /// keeping actively-used sessions clear of idle eviction.
    pub fn get(&self, id: u64) -> Option<Arc<Mutex<PlanSession>>> {
        let mut inner = self.inner.lock().unwrap();
        inner.get_mut(&id).map(|(s, touched)| {
            *touched = Instant::now();
            s.clone()
        })
    }

    /// Remove and return a session.
    pub fn close(&self, id: u64) -> Option<Arc<Mutex<PlanSession>>> {
        self.inner.lock().unwrap().remove(&id).map(|(s, _)| s)
    }

    /// Evict sessions idle at least `ttl`; returns how many were
    /// dropped. `insert` calls this implicitly with
    /// [`SESSION_IDLE_TIMEOUT`] when the registry is full.
    pub fn sweep_idle(&self, ttl: std::time::Duration) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let before = inner.len();
        let now = Instant::now();
        inner.retain(|_, (_, touched)| now.duration_since(*touched) < ttl);
        before - inner.len()
    }

    /// Close every open session (the graceful-shutdown path); returns
    /// how many were open. Handles already obtained via `get` stay
    /// usable until dropped — the runtime only calls this after its
    /// connection workers have drained, so no op is in flight.
    pub fn drain_all(&self) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let n = inner.len();
        inner.clear();
        n
    }

    pub fn count(&self) -> usize {
        self.inner.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};
    use crate::model::{DemandSeg, NodeType};

    fn small(seed: u64) -> Instance {
        generate(&SynthParams { n: 40, m: 3, ..Default::default() }, seed)
    }

    fn extra_tasks(inst: &Instance, seed: u64, k: usize) -> Vec<Task> {
        // fresh tasks drawn from another seed, re-id'd above the live ids
        let base = inst.tasks.iter().map(|t| t.id).max().unwrap_or(0) + 1;
        let donor = generate(
            &SynthParams { n: k, m: 3, horizon: inst.horizon, ..Default::default() },
            seed,
        );
        donor.tasks.iter().enumerate().map(|(i, t)| t.with_id(base + i as u64)).collect()
    }

    #[test]
    fn open_admit_reshape_retire_close_flow() {
        let inst = small(11);
        let (mut s, open) = PlanSession::open(inst, SessionConfig::default()).unwrap();
        assert!(open.cost > 0.0);
        assert!(open.lower_bound > 0.0 && open.lower_bound <= open.cost + 1e-6);
        assert_eq!(open.n_tasks, 40);

        // admit two fresh tasks
        let fresh = extra_tasks(s.instance(), 99, 2);
        let ids: Vec<u64> = fresh.iter().map(|t| t.id).collect();
        let r = s.apply(&Delta::Admit { tasks: fresh }).unwrap();
        assert_eq!(r.op, "admit");
        assert_eq!(r.n_tasks, 42);
        assert!(r.cost >= open.cost - 1e-9, "admits never shrink the plan");
        assert!(r.lower_bound <= r.cost + 1e-6);

        // reshape the first admitted task to a two-segment profile
        let dims = s.instance().dims();
        let reshaped = Task::piecewise(
            ids[0],
            vec![
                DemandSeg { start: 0, end: 1, demand: vec![0.05; dims] },
                DemandSeg { start: 2, end: 3, demand: vec![0.1; dims] },
            ],
        );
        let r = s.apply(&Delta::Reshape { task: reshaped }).unwrap();
        assert_eq!(r.op, "reshape");
        assert_eq!(r.n_tasks, 42);

        // retire both admitted tasks: cost returns to (at most) the
        // opening plan's cost
        let r = s.apply(&Delta::Retire { ids }).unwrap();
        assert_eq!(r.n_tasks, 40);
        assert!(r.cost <= open.cost + 1e-9, "retire must not inflate the plan");
        let (n, rep, res) = s.delta_counts();
        assert_eq!(n, 3);
        assert_eq!(rep + res, 3);
        assert!(s.solution().verify(s.instance()).is_ok());
    }

    #[test]
    fn bad_deltas_are_rejected_without_state_change() {
        let inst = small(12);
        let (mut s, open) = PlanSession::open(inst, SessionConfig::default()).unwrap();
        let cost0 = s.cost();
        let dims = s.instance().dims();

        // duplicate id
        let live_id = s.instance().tasks[0].id;
        let dup = Task::new(live_id, vec![0.1; dims], 0, 1);
        assert!(s.apply(&Delta::Admit { tasks: vec![dup] }).is_err());
        // unknown retire id
        assert!(s.apply(&Delta::Retire { ids: vec![9_999_999] }).is_err());
        // reshape of an unknown id
        let ghost = Task::new(9_999_999, vec![0.1; dims], 0, 1);
        assert!(s.apply(&Delta::Reshape { task: ghost }).is_err());
        // admit past the fixed horizon
        let late = Task::new(7_777_777, vec![0.1; dims], 0, s.instance().horizon + 5);
        assert!(s.apply(&Delta::Admit { tasks: vec![late] }).is_err());
        // admit that fits no node-type
        let huge = Task::new(8_888_888, vec![50.0; dims], 0, 1);
        assert!(s.apply(&Delta::Admit { tasks: vec![huge] }).is_err());
        // reprice that strands a live task
        let tiny_cat = vec![NodeType::new("nano", vec![1e-6; dims], 0.1)];
        assert!(s.apply(&Delta::Reprice { node_types: tiny_cat }).is_err());

        assert_eq!(s.cost(), cost0);
        assert_eq!(s.n_tasks(), open.n_tasks);
        assert_eq!(s.delta_counts().0, 0);
    }

    #[test]
    fn escalation_fires_on_tight_ratio_and_quote_does_not_commit() {
        let inst = small(13);
        let cfg = SessionConfig { escalate_ratio: Some(1.0), ..Default::default() };
        let (mut s, _) = PlanSession::open(inst, cfg).unwrap();
        let fresh = extra_tasks(s.instance(), 5, 4);

        // a quote prices the delta without committing
        let before = (s.cost(), s.n_tasks(), s.delta_counts());
        let q = s.quote(&Delta::Admit { tasks: fresh.clone() }).unwrap();
        assert_eq!(q.n_tasks, before.1 + 4);
        assert_eq!((s.cost(), s.n_tasks(), s.delta_counts()), before);

        // ratio 1.0: any strictly-above-LB incremental cost escalates
        let r = s.apply(&Delta::Admit { tasks: fresh }).unwrap();
        if r.decision == Decision::Resolve {
            assert!(r.reason.is_some());
        }
        assert!(r.cost >= r.lower_bound - 1e-6);
        assert!(s.solution().verify(s.instance()).is_ok());
    }

    #[test]
    fn reprice_cost_change_repairs_capacity_change_resolves() {
        let inst = small(14);
        let (mut s, _) = PlanSession::open(inst, SessionConfig::default()).unwrap();
        // pure price change: placement is kept, decision is repair
        let mut repriced = s.instance().node_types.clone();
        for b in repriced.iter_mut() {
            b.cost *= 2.0;
        }
        let c0 = s.cost();
        let r = s.apply(&Delta::Reprice { node_types: repriced }).unwrap();
        assert_eq!(r.decision, Decision::Repair);
        assert!((r.cost - 2.0 * c0).abs() < 1e-6, "{} vs {}", r.cost, 2.0 * c0);

        // capacity change: forced full re-solve
        let mut reshaped_cat = s.instance().node_types.clone();
        for b in reshaped_cat.iter_mut() {
            for c in b.capacity.iter_mut() {
                *c = (*c * 1.1).min(1.0);
            }
        }
        let r = s.apply(&Delta::Reprice { node_types: reshaped_cat }).unwrap();
        assert_eq!(r.decision, Decision::Resolve);
        assert!(s.solution().verify(s.instance()).is_ok());
    }

    #[test]
    fn retire_everything_and_repopulate() {
        let inst = small(15);
        let cfg = SessionConfig { escalate_ratio: None, ..Default::default() };
        let (mut s, _) = PlanSession::open(inst, cfg).unwrap();
        let ids: Vec<u64> = s.instance().tasks.iter().map(|t| t.id).collect();
        let r = s.apply(&Delta::Retire { ids }).unwrap();
        assert_eq!(r.n_tasks, 0);
        assert_eq!(r.n_nodes, 0);
        assert_eq!(r.cost, 0.0);
        assert_eq!(r.lower_bound, 0.0);
        // an empty session still accepts admits
        let fresh = extra_tasks(s.instance(), 21, 3);
        let r = s.apply(&Delta::Admit { tasks: fresh }).unwrap();
        assert_eq!(r.n_tasks, 3);
        assert!(r.cost > 0.0);
        assert!(s.solution().verify(s.instance()).is_ok());
    }

    #[test]
    fn registry_caps_and_isolates() {
        let reg = SessionRegistry::new();
        let (a, _) = PlanSession::open(small(1), SessionConfig::default()).unwrap();
        let (b, _) = PlanSession::open(small(2), SessionConfig::default()).unwrap();
        let ia = reg.insert(a).unwrap();
        let ib = reg.insert(b).unwrap();
        assert_ne!(ia, ib);
        assert_eq!(reg.count(), 2);
        let ha = reg.get(ia).unwrap();
        let cost_a = ha.lock().unwrap().cost();
        assert!(cost_a > 0.0);
        assert!(reg.get(777).is_none());
        assert!(reg.close(ia).is_some());
        assert!(reg.get(ia).is_none());
        assert_eq!(reg.count(), 1);
    }

    #[test]
    fn registry_sweeps_idle_sessions() {
        let reg = SessionRegistry::new();
        let (a, _) = PlanSession::open(small(3), SessionConfig::default()).unwrap();
        let (b, _) = PlanSession::open(small(4), SessionConfig::default()).unwrap();
        let ia = reg.insert(a).unwrap();
        let _ib = reg.insert(b).unwrap();
        // nothing is older than a generous ttl
        assert_eq!(reg.sweep_idle(std::time::Duration::from_secs(3600)), 0);
        assert_eq!(reg.count(), 2);
        // touch session a, then sweep with a zero ttl: everything idle
        // "at least 0" goes — including just-touched entries — proving
        // the ttl comparison is exercised; a real deployment uses
        // SESSION_IDLE_TIMEOUT via insert's full-registry path
        assert!(reg.get(ia).is_some());
        assert_eq!(reg.sweep_idle(std::time::Duration::ZERO), 2);
        assert_eq!(reg.count(), 0);
    }

    #[test]
    fn registry_drain_all_closes_everything() {
        let reg = SessionRegistry::new();
        let (a, _) = PlanSession::open(small(5), SessionConfig::default()).unwrap();
        let (b, _) = PlanSession::open(small(6), SessionConfig::default()).unwrap();
        let ia = reg.insert(a).unwrap();
        let _ib = reg.insert(b).unwrap();
        assert_eq!(reg.drain_all(), 2);
        assert_eq!(reg.count(), 0);
        assert!(reg.get(ia).is_none());
        assert_eq!(reg.drain_all(), 0, "draining an empty registry is a no-op");
    }

    #[test]
    fn knob_parsers() {
        assert_eq!(parse_escalate("off").unwrap(), None);
        assert_eq!(parse_escalate("1.5").unwrap(), Some(1.5));
        assert!(parse_escalate("0.5").is_err());
        assert!(parse_escalate("nan").is_err());
        assert!(matches!(parse_fit("ff").unwrap(), FitPolicy::FirstFit));
        assert!(matches!(parse_fit("sim").unwrap(), FitPolicy::SimilarityFit));
        assert!(parse_fit("bogus").is_err());
    }
}
