//! Lightweight metrics registry: counters, gauges and latency
//! histograms, shared across the planner's worker threads and the
//! service's session verbs and connection workers.
//!
//! Timers used to fold every observation into a bare (total, count)
//! pair, which erased the distribution — a per-delta latency series with
//! one slow escalation looked identical to a uniformly slow one. Each
//! timer now keeps count/total/max plus a bounded ring of recent samples
//! from which `report()` and the service `stats` verb surface p50/p95.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::percentile;

/// How many recent observations a timer retains for percentile
/// estimation. Bounded so a long-lived service cannot grow without
/// limit; p50/p95 are over this sliding window, max is over all time.
const TIMER_WINDOW: usize = 512;

/// One timer's accumulated state.
#[derive(Clone, Debug, Default)]
pub struct TimerStat {
    pub total: f64,
    pub count: u64,
    /// Largest observation ever recorded.
    pub max: f64,
    /// Ring buffer of the most recent observations (cap TIMER_WINDOW).
    window: Vec<f64>,
    /// Next ring slot to overwrite once the window is full.
    pos: usize,
}

impl TimerStat {
    fn observe(&mut self, seconds: f64) {
        self.total += seconds;
        self.count += 1;
        self.max = self.max.max(seconds);
        if self.window.len() < TIMER_WINDOW {
            self.window.push(seconds);
        } else {
            self.window[self.pos] = seconds;
            self.pos = (self.pos + 1) % TIMER_WINDOW;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count > 0 {
            self.total / self.count as f64
        } else {
            0.0
        }
    }

    /// Percentile over the retained window (p in [0, 100]).
    pub fn pct(&self, p: f64) -> f64 {
        if self.window.is_empty() {
            return 0.0;
        }
        percentile(&self.window, p)
    }
}

/// One gauge's state: the current value plus the high-water mark (the
/// service runtime reads peaks for "most concurrent connections ever").
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GaugeStat {
    pub value: i64,
    pub peak: i64,
}

#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, GaugeStat>>,
    timers: Mutex<BTreeMap<String, TimerStat>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Add `delta` (may be negative) to a gauge, tracking its peak.
    /// Returns the new value.
    pub fn gauge_add(&self, name: &str, delta: i64) -> i64 {
        let mut gauges = self.gauges.lock().unwrap();
        let g = gauges.entry(name.to_string()).or_default();
        g.value += delta;
        g.peak = g.peak.max(g.value);
        g.value
    }

    /// Set a gauge to an absolute value, tracking its peak.
    pub fn gauge_set(&self, name: &str, value: i64) {
        let mut gauges = self.gauges.lock().unwrap();
        let g = gauges.entry(name.to_string()).or_default();
        g.value = value;
        g.peak = g.peak.max(value);
    }

    /// Current gauge value (0 for a gauge never touched).
    pub fn gauge(&self, name: &str) -> i64 {
        self.gauges.lock().unwrap().get(name).map(|g| g.value).unwrap_or(0)
    }

    /// All-time high-water mark of a gauge.
    pub fn gauge_peak(&self, name: &str) -> i64 {
        self.gauges.lock().unwrap().get(name).map(|g| g.peak).unwrap_or(0)
    }

    /// Snapshot every gauge (sorted by name).
    pub fn gauges_snapshot(&self) -> Vec<(String, GaugeStat)> {
        self.gauges.lock().unwrap().iter().map(|(k, g)| (k.clone(), *g)).collect()
    }

    /// Time a closure and accumulate under `name`. Returns its result.
    pub fn time<R>(&self, name: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.observe(name, t0.elapsed().as_secs_f64());
        r
    }

    /// Record an externally measured duration (e.g. a stage time reported
    /// by a pipeline run on another thread).
    pub fn observe(&self, name: &str, seconds: f64) {
        self.timers
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .observe(seconds);
    }

    pub fn timer_total(&self, name: &str) -> f64 {
        self.timers.lock().unwrap().get(name).map(|e| e.total).unwrap_or(0.0)
    }

    pub fn timer_count(&self, name: &str) -> u64 {
        self.timers.lock().unwrap().get(name).map(|e| e.count).unwrap_or(0)
    }

    /// Full distribution snapshot for one timer.
    pub fn timer_stats(&self, name: &str) -> Option<TimerStat> {
        self.timers.lock().unwrap().get(name).cloned()
    }

    /// Snapshot every counter (sorted by name).
    pub fn counters_snapshot(&self) -> Vec<(String, u64)> {
        self.counters.lock().unwrap().iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    /// Snapshot every timer (sorted by name).
    pub fn timers_snapshot(&self) -> Vec<(String, TimerStat)> {
        self.timers.lock().unwrap().iter().map(|(k, v)| (k.clone(), v.clone())).collect()
    }

    /// Human-readable dump, sorted by key.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k:<40} {v}\n"));
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("gauge   {k:<40} {} (peak {})\n", g.value, g.peak));
        }
        for (k, t) in self.timers.lock().unwrap().iter() {
            out.push_str(&format!(
                "timer   {k:<40} total {:>9.3}s  n={:<6} avg {:.2}ms  p50 {:.2}ms  \
                 p95 {:.2}ms  max {:.2}ms\n",
                t.total,
                t.count,
                t.mean() * 1e3,
                t.pct(50.0) * 1e3,
                t.pct(95.0) * 1e3,
                t.max * 1e3
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_timers() {
        let m = Metrics::new();
        m.inc("solves", 2);
        m.inc("solves", 1);
        assert_eq!(m.counter("solves"), 3);
        let v = m.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(m.timer_count("work"), 1);
        assert!(m.timer_total("work") >= 0.0);
        let rep = m.report();
        assert!(rep.contains("solves") && rep.contains("work"));
        assert!(rep.contains("p50") && rep.contains("p95") && rep.contains("max"));
    }

    #[test]
    fn gauges_track_value_and_peak() {
        let m = Metrics::new();
        assert_eq!(m.gauge("live"), 0);
        assert_eq!(m.gauge_add("live", 1), 1);
        assert_eq!(m.gauge_add("live", 1), 2);
        assert_eq!(m.gauge_add("live", -1), 1);
        assert_eq!(m.gauge("live"), 1);
        assert_eq!(m.gauge_peak("live"), 2, "peak survives the drop");
        m.gauge_set("depth", 5);
        m.gauge_set("depth", 2);
        assert_eq!(m.gauge("depth"), 2);
        assert_eq!(m.gauge_peak("depth"), 5);
        let snap = m.gauges_snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0, "depth");
        assert_eq!(snap[1].1, GaugeStat { value: 1, peak: 2 });
        assert!(m.report().contains("gauge   "));
        assert!(m.report().contains("(peak 2)"));
    }

    #[test]
    fn timers_keep_distribution_shape() {
        let m = Metrics::new();
        // 99 fast observations and one slow one: the old (total, count)
        // fold reported avg ~0.03s and nothing else; the histogram keeps
        // the tail visible
        for _ in 0..99 {
            m.observe("delta", 0.01);
        }
        m.observe("delta", 2.0);
        let t = m.timer_stats("delta").unwrap();
        assert_eq!(t.count, 100);
        assert!((t.max - 2.0).abs() < 1e-12);
        assert!((t.pct(50.0) - 0.01).abs() < 1e-9, "p50 {}", t.pct(50.0));
        assert!(t.pct(95.0) <= 2.0 + 1e-12);
        assert!(t.mean() > 0.01 && t.mean() < 0.05);
    }

    #[test]
    fn window_is_bounded() {
        let m = Metrics::new();
        for i in 0..(TIMER_WINDOW * 3) {
            m.observe("w", i as f64);
        }
        let t = m.timer_stats("w").unwrap();
        assert_eq!(t.count as usize, TIMER_WINDOW * 3);
        assert_eq!(t.window.len(), TIMER_WINDOW);
        // the retained window is the most recent observations, so p50
        // reflects the tail of the stream, max the whole stream
        assert!(t.pct(50.0) >= TIMER_WINDOW as f64);
        assert!((t.max - (TIMER_WINDOW * 3 - 1) as f64).abs() < 1e-9);
    }

    #[test]
    fn snapshots_are_sorted_and_complete() {
        let m = Metrics::new();
        m.inc("b", 1);
        m.inc("a", 2);
        m.observe("t1", 0.5);
        let c = m.counters_snapshot();
        assert_eq!(c, vec![("a".into(), 2), ("b".into(), 1)]);
        let t = m.timers_snapshot();
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].0, "t1");
        assert_eq!(t[0].1.count, 1);
    }

    #[test]
    fn thread_safety() {
        let m = std::sync::Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        m.inc("n", 1);
                    }
                });
            }
        });
        assert_eq!(m.counter("n"), 400);
    }
}
