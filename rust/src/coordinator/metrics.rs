//! Lightweight metrics registry: counters and wall-time accumulators,
//! shared across the planner's worker threads.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    timers: Mutex<BTreeMap<String, (f64, u64)>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Time a closure and accumulate under `name`. Returns its result.
    pub fn time<R>(&self, name: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.observe(name, t0.elapsed().as_secs_f64());
        r
    }

    /// Record an externally measured duration (e.g. a stage time reported
    /// by a pipeline run on another thread).
    pub fn observe(&self, name: &str, seconds: f64) {
        let mut timers = self.timers.lock().unwrap();
        let e = timers.entry(name.to_string()).or_insert((0.0, 0));
        e.0 += seconds;
        e.1 += 1;
    }

    pub fn timer_total(&self, name: &str) -> f64 {
        self.timers.lock().unwrap().get(name).map(|e| e.0).unwrap_or(0.0)
    }

    pub fn timer_count(&self, name: &str) -> u64 {
        self.timers.lock().unwrap().get(name).map(|e| e.1).unwrap_or(0)
    }

    /// Human-readable dump, sorted by key.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k:<40} {v}\n"));
        }
        for (k, (total, count)) in self.timers.lock().unwrap().iter() {
            let avg_ms = if *count > 0 { total / *count as f64 * 1e3 } else { 0.0 };
            out.push_str(&format!(
                "timer   {k:<40} total {total:>9.3}s  n={count:<6} avg {avg_ms:.2}ms\n"
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_timers() {
        let m = Metrics::new();
        m.inc("solves", 2);
        m.inc("solves", 1);
        assert_eq!(m.counter("solves"), 3);
        let v = m.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(m.timer_count("work"), 1);
        assert!(m.timer_total("work") >= 0.0);
        let rep = m.report();
        assert!(rep.contains("solves") && rep.contains("work"));
    }

    #[test]
    fn thread_safety() {
        let m = std::sync::Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        m.inc("n", 1);
                    }
                });
            }
        });
        assert_eq!(m.counter("n"), 400);
    }
}
