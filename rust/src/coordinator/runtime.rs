//! The concurrent service runtime: an accept/worker split with
//! admission control, load shedding, per-request budgets and graceful
//! shutdown.
//!
//! The seed service handled every connection sequentially on the accept
//! thread; this module is what `service::serve` runs on instead. The
//! pieces:
//!
//!   * **Accept/worker split.** One accept thread hands each incoming
//!     connection to a long-lived bounded [`WorkerPool`]
//!     (`util::pool`). A connection occupies its worker for the
//!     connection's lifetime (clients pipeline many request lines), so
//!     `--workers N` bounds concurrent *connections being served* and
//!     `--queue K` bounds connections waiting for a worker.
//!   * **Admission control + load shedding.** When `active + queued`
//!     reaches `workers + queue`, new connections are not queued
//!     unboundedly: they get one typed line,
//!     `{"ok":false,"error":"overloaded","retry_after_ms":...}`, and
//!     are closed. `retry_after_ms` scales with the observed mean
//!     request latency times the backlog depth.
//!   * **Per-request budgets.** Request lines are read through a
//!     size-capped reader (`--max-request-bytes`; a client streaming
//!     one multi-GB line can no longer OOM the process — it gets a
//!     typed `"request too large"` error and the connection closes,
//!     since there is no way to resync mid-line). Requests that exceed
//!     `--request-timeout` answer `{"ok":false,"error":"timeout",...}`
//!     instead of their result. The budget bounds the *answer*, not the
//!     side effect: a session delta that finished late is still
//!     applied — query the session to resync.
//!   * **Graceful shutdown.** `RuntimeCtl::begin_shutdown` (or the
//!     `{"op":"shutdown"}` verb, gated by `--allow-shutdown`) stops the
//!     accept loop, lets every in-flight and queued connection finish
//!     the requests it already sent (connection handlers poll the
//!     shutdown flag on a 250ms read-timeout tick and serve any bytes
//!     already buffered before closing), then closes all open sessions.
//!   * **Observability.** Live/peak connection gauges, queue depth,
//!     shed/timeout/oversize counters and per-verb latency histograms
//!     (`request.<verb>`) all land in the shared `Metrics` registry and
//!     surface through `{"op":"stats"}`.
//!
//! The non-`Sync` PJRT artifact backend cannot be shared by concurrent
//! workers; `Planner::route_artifact_serial` moves it onto a dedicated
//! solver thread behind a channel (the CLI does this before serving),
//! and [`ServiceRuntime::bind`] refuses a multi-worker runtime whose
//! planner still holds a direct artifact handle.

use std::io::{self, BufRead, BufReader, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;
use crate::util::pool::WorkerPool;

use super::planner::Planner;
use super::service;

/// Default cap on one request line (bytes, newline excluded): roomy
/// enough for a ~100k-task inline instance, far below OOM territory.
pub const DEFAULT_MAX_REQUEST_BYTES: usize = 64 << 20;

/// Default per-request wall budget.
pub const DEFAULT_REQUEST_TIMEOUT: Duration = Duration::from_secs(120);

/// Read-timeout tick on connection sockets: how often an idle handler
/// polls the shutdown flag. Bounds shutdown latency, costs nothing while
/// requests flow.
const POLL_INTERVAL: Duration = Duration::from_millis(250);

/// How many post-shutdown poll ticks a handler waits for the rest of a
/// half-received line before giving up on it (~5s grace).
const SHUTDOWN_GRACE_POLLS: u32 = 20;

/// Consecutive transient accept() failures tolerated before the loop
/// treats the listener as wedged and exits.
const MAX_CONSECUTIVE_ACCEPT_ERRORS: u32 = 64;

/// Clamp range and no-data fallback for the shed response's
/// `retry_after_ms` hint.
const RETRY_AFTER_MIN_MS: f64 = 50.0;
const RETRY_AFTER_MAX_MS: f64 = 10_000.0;
const RETRY_AFTER_DEFAULT_MS: f64 = 200.0;

/// Runtime knobs (the `tlrs serve` flags).
#[derive(Clone, Debug)]
pub struct RuntimeConfig {
    /// Connection workers (`--workers`); each serves one connection at
    /// a time for that connection's lifetime.
    pub workers: usize,
    /// Connections admitted beyond the running ones (`--queue`); at
    /// `workers + queue` in flight, new connections are shed.
    pub queue: usize,
    /// Per-request wall budget (`--request-timeout`).
    pub request_timeout: Duration,
    /// Max bytes in one request line (`--max-request-bytes`).
    pub max_request_bytes: usize,
    /// Whether clients may stop the server via `{"op":"shutdown"}`
    /// (`--allow-shutdown`). Off by default: anyone who can reach the
    /// socket could otherwise take the service down.
    pub allow_shutdown: bool,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        RuntimeConfig {
            workers,
            queue: 2 * workers,
            request_timeout: DEFAULT_REQUEST_TIMEOUT,
            max_request_bytes: DEFAULT_MAX_REQUEST_BYTES,
            allow_shutdown: false,
        }
    }
}

impl RuntimeConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.workers >= 1, "--workers must be at least 1");
        anyhow::ensure!(self.workers <= 4096, "--workers {} is absurd (max 4096)", self.workers);
        anyhow::ensure!(
            self.request_timeout > Duration::ZERO,
            "--request-timeout must be positive"
        );
        anyhow::ensure!(
            self.max_request_bytes >= 1024,
            "--max-request-bytes must be at least 1024 (a bare request envelope \
             is tens of bytes)"
        );
        Ok(())
    }
}

/// Per-connection budgets, shared between the runtime path and the
/// legacy `serve_connection` entry point.
#[derive(Clone)]
pub struct ConnBudget {
    pub request_timeout: Duration,
    pub max_request_bytes: usize,
    /// Set when the runtime is draining; a standalone connection gets a
    /// private always-false flag.
    pub shutdown: Arc<AtomicBool>,
}

impl Default for ConnBudget {
    fn default() -> Self {
        ConnBudget {
            request_timeout: DEFAULT_REQUEST_TIMEOUT,
            max_request_bytes: DEFAULT_MAX_REQUEST_BYTES,
            shutdown: Arc::new(AtomicBool::new(false)),
        }
    }
}

/// Shutdown control surface, shared with connection handlers so the
/// `{"op":"shutdown"}` verb can reach the accept loop.
pub struct RuntimeCtl {
    allow_shutdown: bool,
    shutting_down: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl RuntimeCtl {
    pub fn shutting_down(&self) -> bool {
        self.shutting_down.load(Ordering::SeqCst)
    }

    /// The client-facing shutdown path (the `{"op":"shutdown"}` verb):
    /// refused unless the runtime was started with `allow_shutdown`.
    pub fn request_shutdown(&self) -> Result<()> {
        anyhow::ensure!(
            self.allow_shutdown,
            "shutdown is disabled on this server (start it with --allow-shutdown)"
        );
        self.begin_shutdown();
        Ok(())
    }

    /// The owner-side shutdown path (tests, signal handlers): always
    /// allowed. Sets the drain flag and pokes the accept loop awake with
    /// a throwaway self-connection. Idempotent.
    pub fn begin_shutdown(&self) {
        if !self.shutting_down.swap(true, Ordering::SeqCst) {
            let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        }
    }
}

/// Map a bound "any" address (0.0.0.0 / [::]) to loopback so the
/// shutdown poke can actually connect to it.
fn connectable(mut a: SocketAddr) -> SocketAddr {
    if a.ip().is_unspecified() {
        a.set_ip(if a.is_ipv4() {
            IpAddr::V4(Ipv4Addr::LOCALHOST)
        } else {
            IpAddr::V6(Ipv6Addr::LOCALHOST)
        });
    }
    a
}

/// The bound, not-yet-running service. `bind` then `run` (blocking) or
/// `spawn` (own thread, returns a [`RuntimeHandle`]).
pub struct ServiceRuntime {
    planner: Arc<Planner>,
    cfg: RuntimeConfig,
    listener: TcpListener,
    local_addr: SocketAddr,
    pool: WorkerPool,
    ctl: Arc<RuntimeCtl>,
}

impl ServiceRuntime {
    pub fn bind(planner: Arc<Planner>, addr: &str, cfg: RuntimeConfig) -> Result<ServiceRuntime> {
        cfg.validate()?;
        anyhow::ensure!(
            cfg.workers == 1 || !planner.artifact_needs_serial_routing(),
            "the PJRT artifact backend is single-client: call \
             Planner::route_artifact_serial() before serving with --workers > 1 \
             (tlrs serve does this automatically)"
        );
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local_addr = listener.local_addr().context("local_addr")?;
        let pool = WorkerPool::new("tlrs-conn", cfg.workers, cfg.queue);
        let ctl = Arc::new(RuntimeCtl {
            allow_shutdown: cfg.allow_shutdown,
            shutting_down: Arc::new(AtomicBool::new(false)),
            addr: connectable(local_addr),
        });
        Ok(ServiceRuntime { planner, cfg, listener, local_addr, pool, ctl })
    }

    /// The actually-bound address (resolves `--addr 127.0.0.1:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn config(&self) -> &RuntimeConfig {
        &self.cfg
    }

    pub fn ctl(&self) -> Arc<RuntimeCtl> {
        self.ctl.clone()
    }

    /// Accept until shutdown, then drain. The shed path and all request
    /// handling happen on the worker pool; this thread only accepts.
    pub fn run(mut self) -> Result<()> {
        let accept_result = self.accept_loop();
        let drain_result = self.drain();
        accept_result.and(drain_result)
    }

    /// `run` on a dedicated thread; the handle shuts the runtime down.
    pub fn spawn(self) -> RuntimeHandle {
        let addr = self.local_addr;
        let ctl = self.ctl.clone();
        // lint:allow(raw-spawn): the accept loop is a structural, named,
        // long-lived thread tied to the listener's lifetime, not a
        // data-parallel task the pool could own.
        let join = std::thread::Builder::new()
            .name("tlrs-accept".into())
            .spawn(move || self.run())
            .expect("spawn accept thread");
        RuntimeHandle { addr, ctl, join }
    }

    fn accept_loop(&self) -> Result<()> {
        let metrics = self.planner.metrics.clone();
        let mut consecutive_errors = 0u32;
        for stream in self.listener.incoming() {
            if self.ctl.shutting_down() {
                break;
            }
            let stream = match stream {
                Ok(s) => {
                    consecutive_errors = 0;
                    s
                }
                Err(e) => {
                    // a transient per-connection failure (peer reset
                    // mid-handshake, EINTR, ...) must not kill the whole
                    // server; a wedged listener must not spin forever
                    metrics.inc("accept_errors", 1);
                    if !accept_error_is_transient(&e) {
                        return Err(e).context("accept");
                    }
                    consecutive_errors += 1;
                    anyhow::ensure!(
                        consecutive_errors < MAX_CONSECUTIVE_ACCEPT_ERRORS,
                        "accept failing repeatedly ({consecutive_errors} consecutive \
                         transient errors, last: {e})"
                    );
                    eprintln!("accept error (transient, continuing): {e}");
                    continue;
                }
            };
            // a shutdown poke lands here: drop the poke connection and stop
            if self.ctl.shutting_down() {
                break;
            }
            self.dispatch(stream);
        }
        Ok(())
    }

    /// Admission control: shed with a typed response when the pool is
    /// full, otherwise hand the connection to a worker.
    fn dispatch(&self, stream: TcpStream) {
        let metrics = &self.planner.metrics;
        if !self.pool.has_space() {
            self.shed(stream);
            metrics.gauge_set("service_queue_depth", self.pool.queued() as i64);
            return;
        }
        let planner = self.planner.clone();
        let budget = ConnBudget {
            request_timeout: self.cfg.request_timeout,
            max_request_bytes: self.cfg.max_request_bytes,
            shutdown: self.ctl.shutting_down.clone(),
        };
        let ctl = self.ctl.clone();
        let peer = stream.peer_addr().ok();
        let job = Box::new(move || {
            planner.metrics.gauge_add("service_connections_live", 1);
            let res = handle_connection(&planner, stream, &budget, Some(&ctl));
            planner.metrics.gauge_add("service_connections_live", -1);
            if let Err(e) = res {
                let who = peer.map(|p| format!(" ({p})")).unwrap_or_default();
                eprintln!("connection error{who}: {e:#}");
            }
        });
        match self.pool.try_submit(job) {
            Ok(()) => metrics.inc("connections_accepted", 1),
            // unreachable while this accept loop is the only submitter;
            // shed silently rather than block the accept thread
            Err(_rejected) => metrics.inc("connections_shed", 1),
        }
        metrics.gauge_set("service_queue_depth", self.pool.queued() as i64);
    }

    fn shed(&self, mut stream: TcpStream) {
        let metrics = &self.planner.metrics;
        metrics.inc("connections_shed", 1);
        let line = Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str("overloaded".into())),
            ("retry_after_ms", Json::Num(self.retry_after_ms())),
        ])
        .to_string()
            + "\n";
        let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
        let _ = stream.write_all(line.as_bytes());
        // drop closes the connection
    }

    /// Back-off hint for shed clients: observed mean request latency ×
    /// the backlog ahead of them, clamped to a sane range.
    fn retry_after_ms(&self) -> f64 {
        let mean_s = self
            .planner
            .metrics
            .timer_stats("request")
            .map(|t| t.mean())
            .unwrap_or(0.0);
        let backlog = (self.pool.active() + self.pool.queued()) as f64;
        let est = if mean_s > 0.0 {
            mean_s * 1e3 * (backlog + 1.0)
        } else {
            RETRY_AFTER_DEFAULT_MS
        };
        est.clamp(RETRY_AFTER_MIN_MS, RETRY_AFTER_MAX_MS).round()
    }

    /// Stop-the-world tail of `run`: drain the pool (every queued and
    /// in-flight connection finishes the requests it already sent), then
    /// close all sessions.
    fn drain(&mut self) -> Result<()> {
        // the flag is already set on the programmatic path; set it here
        // too so a fatal accept error still drains handlers promptly
        self.ctl.shutting_down.store(true, Ordering::SeqCst);
        let metrics = self.planner.metrics.clone();
        eprintln!(
            "tlrs service: draining ({} active, {} queued connection(s))",
            self.pool.active(),
            self.pool.queued()
        );
        self.pool.shutdown();
        let closed = self.planner.sessions.drain_all();
        if closed > 0 {
            metrics.inc("sessions_closed_on_shutdown", closed as u64);
        }
        metrics.gauge_set("service_queue_depth", 0);
        eprintln!("tlrs service: drained; closed {closed} session(s)");
        Ok(())
    }
}

/// Handle to a runtime running on its own thread (tests, benches).
pub struct RuntimeHandle {
    pub addr: SocketAddr,
    ctl: Arc<RuntimeCtl>,
    join: std::thread::JoinHandle<Result<()>>,
}

impl RuntimeHandle {
    pub fn ctl(&self) -> Arc<RuntimeCtl> {
        self.ctl.clone()
    }

    /// Wait for the runtime to exit on its own (e.g. after a client
    /// issued `{"op":"shutdown"}`).
    pub fn join(self) -> Result<()> {
        self.join.join().map_err(|_| anyhow!("runtime thread panicked"))?
    }

    pub fn shutdown_and_join(self) -> Result<()> {
        self.ctl.begin_shutdown();
        self.join()
    }
}

fn accept_error_is_transient(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionAborted
            | io::ErrorKind::NotConnected
            | io::ErrorKind::Interrupted
            | io::ErrorKind::WouldBlock
            | io::ErrorKind::TimedOut
    )
}

// ----- per-connection request loop -----------------------------------------

/// What one capped line read produced.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum ReadOutcome {
    /// `buf` holds one complete request line (newline stripped, CRLF
    /// tolerated like the legacy `BufRead::lines` loop).
    Line,
    /// Clean end of stream with no pending bytes.
    Eof,
    /// The line exceeded the byte cap; the connection cannot resync.
    TooLong,
    /// The runtime is draining and no (complete) request is pending.
    ShuttingDown,
}

/// Read one `\n`-terminated line into `buf` (which the caller clears),
/// enforcing `max_bytes` (newline excluded; a line of exactly
/// `max_bytes` passes) and polling `shutdown` on every read-timeout
/// tick. Bytes already received are always served first — that is what
/// lets graceful shutdown drain requests that were in a socket buffer
/// when the flag flipped.
fn read_request_line<R: BufRead>(
    reader: &mut R,
    buf: &mut Vec<u8>,
    max_bytes: usize,
    shutdown: &AtomicBool,
) -> io::Result<ReadOutcome> {
    let mut grace_polls = 0u32;
    loop {
        let mut outcome = None;
        let used = {
            let available = match reader.fill_buf() {
                Ok(a) => a,
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
                    ) =>
                {
                    // poll tick (the 250ms socket read timeout)
                    if shutdown.load(Ordering::SeqCst) {
                        if buf.is_empty() {
                            return Ok(ReadOutcome::ShuttingDown);
                        }
                        // half a line received: give its tail a bounded
                        // grace window, then abandon it
                        grace_polls += 1;
                        if grace_polls >= SHUTDOWN_GRACE_POLLS {
                            return Ok(ReadOutcome::ShuttingDown);
                        }
                    }
                    continue;
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            };
            if available.is_empty() {
                // EOF; an unterminated trailing line is still a request
                // (matches the legacy `lines()` behavior)
                return Ok(if buf.is_empty() { ReadOutcome::Eof } else { ReadOutcome::Line });
            }
            match available.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if buf.len() + pos > max_bytes {
                        outcome = Some(ReadOutcome::TooLong);
                        0
                    } else {
                        buf.extend_from_slice(&available[..pos]);
                        outcome = Some(ReadOutcome::Line);
                        pos + 1
                    }
                }
                None => {
                    if buf.len() + available.len() > max_bytes {
                        outcome = Some(ReadOutcome::TooLong);
                        0
                    } else {
                        buf.extend_from_slice(available);
                        available.len()
                    }
                }
            }
        };
        reader.consume(used);
        match outcome {
            Some(ReadOutcome::Line) => {
                if buf.last() == Some(&b'\r') {
                    buf.pop();
                }
                return Ok(ReadOutcome::Line);
            }
            Some(o) => return Ok(o),
            None => grace_polls = 0, // data flowed: reset the grace window
        }
    }
}

/// What a received line needs before dispatch.
#[derive(Debug, PartialEq, Eq, Clone, Copy)]
enum LineClass {
    /// Only ASCII whitespace: skip it, like the legacy `trim()` check.
    Blank,
    /// Starts with a significant ASCII byte: hand the raw bytes to
    /// `service::handle_request_bytes` (no UTF-8 copy up front).
    Request,
    /// First significant byte is non-ASCII — could be Unicode whitespace
    /// (blank line) or invalid UTF-8 (connection error). Route through
    /// the legacy `from_utf8` + `trim()` path to keep those semantics.
    NeedsStr,
}

/// Classify with a pure byte scan. The ASCII whitespace set matches
/// `char::is_whitespace` restricted to ASCII (space, \t, \n, \v, \f,
/// \r); any non-ASCII lead byte defers to the `&str` path, which owns
/// the Unicode-whitespace and invalid-UTF-8 cases.
fn classify_line(buf: &[u8]) -> LineClass {
    for &b in buf {
        match b {
            b' ' | b'\t' | b'\n' | 0x0b | 0x0c | b'\r' => {}
            0x80.. => return LineClass::NeedsStr,
            _ => return LineClass::Request,
        }
    }
    LineClass::Blank
}

fn too_large_response(max_bytes: usize) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str("request too large".into())),
        ("max_request_bytes", Json::Num(max_bytes as f64)),
    ])
    .to_string()
}

fn timeout_response(elapsed: Duration, budget: Duration) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str("timeout".into())),
        ("budget_ms", Json::Num((budget.as_secs_f64() * 1e3).round())),
        ("elapsed_ms", Json::Num((elapsed.as_secs_f64() * 1e3).round())),
    ])
    .to_string()
}

/// Serve one connection's pipelined request lines under `budget`.
/// `ctl` is `Some` under the runtime (enables the shutdown verb and the
/// drain flag); the legacy `serve_connection` entry passes `None`.
pub(crate) fn handle_connection(
    planner: &Planner,
    stream: TcpStream,
    budget: &ConnBudget,
    ctl: Option<&RuntimeCtl>,
) -> Result<()> {
    // the read timeout is the shutdown poll tick, not a client deadline:
    // read_request_line treats WouldBlock/TimedOut as "check the flag"
    stream
        .set_read_timeout(Some(POLL_INTERVAL))
        .context("set_read_timeout")?;
    let mut writer = stream.try_clone().context("clone stream")?;
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        match read_request_line(
            &mut reader,
            &mut buf,
            budget.max_request_bytes,
            &budget.shutdown,
        )? {
            ReadOutcome::Eof | ReadOutcome::ShuttingDown => return Ok(()),
            ReadOutcome::TooLong => {
                planner.metrics.inc("requests_too_large", 1);
                write_line(&mut writer, &too_large_response(budget.max_request_bytes))?;
                return Ok(());
            }
            ReadOutcome::Line => {
                // requests that lead with a significant ASCII byte go to
                // the service as raw bytes — the streaming wire layer
                // pull-parses them with no UTF-8 validation copy. Only
                // non-ASCII lead bytes take the legacy `&str` detour
                // (Unicode blank lines, and the strict-UTF-8 contract:
                // a binary blob closes the connection instead of being
                // guessed at — handle_request_bytes errors identically).
                match classify_line(&buf) {
                    LineClass::Blank => continue,
                    LineClass::NeedsStr => {
                        let line = std::str::from_utf8(&buf)
                            .map_err(|e| anyhow!("request line is not valid UTF-8: {e}"))?;
                        if line.trim().is_empty() {
                            continue;
                        }
                    }
                    LineClass::Request => {}
                }
                let t0 = Instant::now();
                let (resp, verb) = service::handle_request_bytes(planner, &buf, ctl)?;
                let elapsed = t0.elapsed();
                let metrics = &planner.metrics;
                metrics.inc("requests_handled", 1);
                metrics.observe("request", elapsed.as_secs_f64());
                metrics.observe(&format!("request.{verb}"), elapsed.as_secs_f64());
                let resp = if elapsed > budget.request_timeout {
                    metrics.inc("requests_timed_out", 1);
                    timeout_response(elapsed, budget.request_timeout)
                } else {
                    resp
                };
                write_line(&mut writer, &resp)?;
            }
        }
    }
}

fn write_line(writer: &mut TcpStream, line: &str) -> Result<()> {
    writer.write_all(line.as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn read(
        input: &[u8],
        max: usize,
    ) -> (io::Result<ReadOutcome>, Vec<u8>, Cursor<Vec<u8>>) {
        let mut cur = Cursor::new(input.to_vec());
        let mut buf = Vec::new();
        let flag = AtomicBool::new(false);
        let r = read_request_line(&mut cur, &mut buf, max, &flag);
        (r, buf, cur)
    }

    #[test]
    fn reads_one_line_and_strips_crlf() {
        let (r, buf, _) = read(b"{\"op\":\"stats\"}\nrest", 1024);
        assert_eq!(r.unwrap(), ReadOutcome::Line);
        assert_eq!(buf, b"{\"op\":\"stats\"}");

        let (r, buf, _) = read(b"abc\r\n", 1024);
        assert_eq!(r.unwrap(), ReadOutcome::Line);
        assert_eq!(buf, b"abc");
    }

    #[test]
    fn sequential_lines_then_eof() {
        let mut cur = Cursor::new(b"a\nbb\nccc".to_vec());
        let flag = AtomicBool::new(false);
        let mut buf = Vec::new();
        for expect in [&b"a"[..], b"bb", b"ccc"] {
            buf.clear();
            let r = read_request_line(&mut cur, &mut buf, 1024, &flag).unwrap();
            assert_eq!(r, ReadOutcome::Line);
            assert_eq!(buf, expect, "unterminated trailing line still served");
        }
        buf.clear();
        let r = read_request_line(&mut cur, &mut buf, 1024, &flag).unwrap();
        assert_eq!(r, ReadOutcome::Eof);
    }

    #[test]
    fn empty_input_is_eof() {
        let (r, buf, _) = read(b"", 1024);
        assert_eq!(r.unwrap(), ReadOutcome::Eof);
        assert!(buf.is_empty());
    }

    #[test]
    fn cap_is_enforced_and_exact_fit_passes() {
        // 8 bytes + newline under a cap of 8: exactly at the cap passes
        let (r, buf, _) = read(b"12345678\n", 8);
        assert_eq!(r.unwrap(), ReadOutcome::Line);
        assert_eq!(buf, b"12345678");
        // 9 bytes over a cap of 8: rejected
        let (r, _, _) = read(b"123456789\n", 8);
        assert_eq!(r.unwrap(), ReadOutcome::TooLong);
        // a newline-free flood past the cap is rejected without waiting
        // for a newline that may never come
        let (r, _, _) = read(&[b'x'; 100], 8);
        assert_eq!(r.unwrap(), ReadOutcome::TooLong);
    }

    #[test]
    fn transient_accept_errors_classified() {
        for k in [
            io::ErrorKind::ConnectionReset,
            io::ErrorKind::ConnectionAborted,
            io::ErrorKind::Interrupted,
            io::ErrorKind::WouldBlock,
            io::ErrorKind::TimedOut,
        ] {
            assert!(accept_error_is_transient(&io::Error::from(k)), "{k:?}");
        }
        for k in [
            io::ErrorKind::NotFound,
            io::ErrorKind::PermissionDenied,
            io::ErrorKind::InvalidInput,
            io::ErrorKind::OutOfMemory,
        ] {
            assert!(!accept_error_is_transient(&io::Error::from(k)), "{k:?}");
        }
    }

    #[test]
    fn config_validation() {
        let ok = RuntimeConfig::default();
        assert!(ok.validate().is_ok());
        assert!(ok.workers >= 1 && ok.queue == 2 * ok.workers);
        assert!(RuntimeConfig { workers: 0, ..ok.clone() }.validate().is_err());
        assert!(RuntimeConfig { max_request_bytes: 10, ..ok.clone() }
            .validate()
            .is_err());
        assert!(RuntimeConfig { request_timeout: Duration::ZERO, ..ok.clone() }
            .validate()
            .is_err());
    }

    #[test]
    fn classify_line_matches_the_legacy_trim_semantics() {
        // blank: only ASCII whitespace (the exact `char::is_whitespace`
        // ASCII subset, incl. \v and \f)
        assert_eq!(classify_line(b""), LineClass::Blank);
        assert_eq!(classify_line(b" \t\r\x0b\x0c"), LineClass::Blank);
        // a significant ASCII byte, even after leading whitespace
        assert_eq!(classify_line(b"{\"op\":\"stats\"}"), LineClass::Request);
        assert_eq!(classify_line(b"  {}"), LineClass::Request);
        // 0x1c-0x1f are NOT whitespace: the legacy trim() kept them too
        assert_eq!(classify_line(b"\x1c"), LineClass::Request);
        // non-ASCII lead byte: Unicode whitespace (NBSP) or invalid
        // UTF-8 both defer to the &str path
        assert_eq!(classify_line("\u{a0}".as_bytes()), LineClass::NeedsStr);
        assert_eq!(classify_line(b" \xff{}"), LineClass::NeedsStr);
    }

    #[test]
    fn shed_hint_shapes() {
        assert!(RETRY_AFTER_MIN_MS < RETRY_AFTER_DEFAULT_MS);
        assert!(RETRY_AFTER_DEFAULT_MS < RETRY_AFTER_MAX_MS);
        let t = too_large_response(4096);
        assert!(t.contains("\"request too large\"") && t.contains("4096"), "{t}");
        let t = timeout_response(Duration::from_millis(1500), Duration::from_secs(1));
        assert!(t.contains("\"timeout\""), "{t}");
        assert!(t.contains("\"budget_ms\":1000"), "{t}");
        assert!(t.contains("\"elapsed_ms\":1500"), "{t}");
    }
}
