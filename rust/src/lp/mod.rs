//! LP substrates: the structured mapping LP, an exact simplex solver,
//! the native PDHG first-order solver, row equilibration and certified
//! dual bounds.

pub mod builder;
pub mod crossover;
pub mod dual;
pub mod pdhg;
pub mod problem;
pub mod scaling;
pub mod simplex;
pub mod solver;

pub use builder::MappingLp;
pub use pdhg::{PdhgOptions, PdhgResult};
