//! The mapping LP (paper section V-B), in the structured form every solver
//! backend consumes:
//!
//! ```text
//!     min  sum_B cost(B) * alpha_B
//!     s.t. sum_B x(u,B) = 1                          for every task u
//!          sum_{u~t} x(u,B) * r(u,B,d) <= alpha_B    for every (B,t,d)
//!          x, alpha >= 0
//! ```
//!
//! The constraint matrix is never materialized on the solve path (PDHG
//! applies it through interval prefix-sums / the Pallas kernel); the dense
//! export exists for the exact simplex cross-check on small instances.

use crate::model::Instance;

use super::problem::{DenseLp, Matrix};

/// Structured mapping LP extracted from a (timeline-trimmed) instance.
#[derive(Clone, Debug)]
pub struct MappingLp {
    pub n: usize,
    pub m: usize,
    pub dims: usize,
    pub t: usize,
    /// Per-task inclusive spans on the trimmed timeline.
    pub spans: Vec<(u32, u32)>,
    /// r[u,B,d] = dem(u,d)/cap(B,d), layout `u*m*dims + b*dims + d`.
    pub ratios: Vec<f64>,
    /// Node-type prices.
    pub costs: Vec<f64>,
    /// Row scaling rho[B,d] (uniform over t; see scaling.rs). The scaled
    /// inequality row is `rho * (K x - alpha) <= 0` — feasibility-equivalent.
    pub rho: Vec<f64>,
}

impl MappingLp {
    /// Build from an instance. The instance should already be
    /// timeline-trimmed (T <= n); an untrimmed one still works, just larger.
    pub fn from_instance(inst: &Instance) -> Self {
        let (n, m, dims) = (inst.n_tasks(), inst.n_types(), inst.dims());
        let mut ratios = vec![0.0; n * m * dims];
        for u in 0..n {
            for b in 0..m {
                for d in 0..dims {
                    ratios[(u * m + b) * dims + d] = inst.ratio(u, b, d);
                }
            }
        }
        MappingLp {
            n,
            m,
            dims,
            t: inst.horizon as usize,
            spans: inst.tasks.iter().map(|u| (u.start, u.end)).collect(),
            ratios: ratios,
            costs: inst.node_types.iter().map(|b| b.cost).collect(),
            rho: vec![1.0; m * dims],
        }
    }

    #[inline]
    pub fn ratio(&self, u: usize, b: usize, d: usize) -> f64 {
        self.ratios[(u * self.m + b) * self.dims + d]
    }

    #[inline]
    pub fn rho_at(&self, b: usize, d: usize) -> f64 {
        self.rho[b * self.dims + d]
    }

    /// Number of primal variables (x entries + alphas).
    pub fn n_vars(&self) -> usize {
        self.n * self.m + self.m
    }

    /// Objective of an (x, alpha) pair.
    pub fn objective(&self, alpha: &[f64]) -> f64 {
        self.costs.iter().zip(alpha).map(|(c, a)| c * a).sum()
    }

    /// Dense export for the exact simplex backend. Variable layout:
    /// `x(u,B) = u*m + B`, `alpha_B = n*m + B`. Only constraint rows for
    /// timeslots where some task is active are emitted (empty rows are
    /// trivially satisfied). Row scaling is intentionally *not* applied:
    /// the dense path is the unscaled ground truth.
    pub fn to_dense(&self) -> DenseLp {
        let (n, m, dims, t) = (self.n, self.m, self.dims, self.t);
        let nv = self.n_vars();
        let mut c = vec![0.0; nv];
        c[n * m..].copy_from_slice(&self.costs);

        let mut a_eq = Matrix::zeros(n, nv);
        for u in 0..n {
            for b in 0..m {
                a_eq.set(u, u * m + b, 1.0);
            }
        }

        // active task lists per timeslot
        let mut active: Vec<Vec<usize>> = vec![Vec::new(); t];
        for (u, &(s, e)) in self.spans.iter().enumerate() {
            for ts in s..=e {
                active[ts as usize].push(u);
            }
        }
        let live: Vec<usize> = (0..t).filter(|&ts| !active[ts].is_empty()).collect();
        let rows = live.len() * m * dims;
        let mut a_ub = Matrix::zeros(rows, nv);
        let mut row = 0;
        for b in 0..m {
            for &ts in &live {
                for d in 0..dims {
                    for &u in &active[ts] {
                        a_ub.set(row, u * m + b, self.ratio(u, b, d));
                    }
                    a_ub.set(row, n * m + b, -1.0);
                    row += 1;
                }
            }
        }
        DenseLp { c, a_ub, b_ub: vec![0.0; rows], a_eq, b_eq: vec![1.0; n] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};
    use crate::lp::simplex;
    use crate::model::trim;

    #[test]
    fn shapes_and_layout() {
        let inst = generate(&SynthParams { n: 12, m: 3, dims: 2, horizon: 6, ..Default::default() }, 1);
        let lp = MappingLp::from_instance(&inst);
        assert_eq!(lp.n, 12);
        assert_eq!(lp.m, 3);
        assert_eq!(lp.ratios.len(), 12 * 3 * 2);
        assert!((lp.ratio(3, 1, 0) - inst.ratio(3, 1, 0)).abs() < 1e-15);
    }

    #[test]
    fn dense_solves_tiny() {
        let inst = generate(&SynthParams { n: 6, m: 2, dims: 2, horizon: 4, ..Default::default() }, 2);
        let tr = trim(&inst);
        let lp = MappingLp::from_instance(&tr.instance);
        let dense = lp.to_dense();
        let r = simplex::solve(&dense);
        assert_eq!(r.status, simplex::SimplexStatus::Optimal);
        // optimum positive and below the trivial one-type bound
        assert!(r.objective > 0.0);
        // each task fully assigned
        for u in 0..lp.n {
            let s: f64 = (0..lp.m).map(|b| r.x[u * lp.m + b]).sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }
}
