//! The mapping LP (paper section V-B), in the structured form every solver
//! backend consumes:
//!
//! ```text
//!     min  sum_B cost(B) * alpha_B
//!     s.t. sum_B x(u,B) = 1                            for every task u
//!          sum_{u~t} x(u,B) * r(u,B,d,t) <= alpha_B    for every (B,t,d)
//!          x, alpha >= 0
//! ```
//!
//! With piecewise-constant demand profiles the congestion coefficient
//! `r(u,B,d,t) = dem(u,d,t)/cap(B,d)` varies over the task's span, but
//! only at *segment* boundaries — so the LP stores one ratio block per
//! demand segment and every operator keeps its interval sparsity: a task
//! contributes one difference-array update (or prefix-sum read) per
//! segment instead of one per task. Flat tasks have exactly one segment,
//! reproducing the seed LP coefficient-for-coefficient. The per-slot
//! aggregates mean the certified dual bound remains a true lower bound on
//! cost(opt) for shaped instances (the Lemma-1 argument is per-timeslot).
//!
//! The constraint matrix is never materialized on the solve path (PDHG
//! applies it through per-segment prefix-sums); the dense export exists
//! for the exact simplex cross-check on small instances.

use crate::model::Instance;

use super::problem::{DenseLp, Matrix};

/// Structured mapping LP extracted from a (timeline-trimmed) instance.
#[derive(Clone, Debug)]
pub struct MappingLp {
    pub n: usize,
    pub m: usize,
    pub dims: usize,
    pub t: usize,
    /// Per-task inclusive spans on the trimmed timeline.
    pub spans: Vec<(u32, u32)>,
    /// Segment offsets: task `u`'s demand segments are
    /// `seg_spans[seg_off[u]..seg_off[u+1]]` (length n+1; flat instances
    /// have exactly one segment per task).
    pub seg_off: Vec<usize>,
    /// Inclusive windows of every demand segment, task-major.
    pub seg_spans: Vec<(u32, u32)>,
    /// Per-segment demand/capacity ratios, layout `(s*m + b)*dims + d`.
    pub seg_ratios: Vec<f64>,
    /// Node-type prices.
    pub costs: Vec<f64>,
    /// Row scaling rho[B,d] (uniform over t; see scaling.rs). The scaled
    /// inequality row is `rho * (K x - alpha) <= 0` — feasibility-equivalent.
    pub rho: Vec<f64>,
}

impl MappingLp {
    /// Build from an instance. The instance should already be
    /// timeline-trimmed (T <= segment count); an untrimmed one still
    /// works, just larger.
    pub fn from_instance(inst: &Instance) -> Self {
        Self::from_instance_par(inst, 1)
    }

    /// Build with the O(S·m·D) ratio table filled by up to `threads`
    /// workers. The spans/offsets pass stays serial (it is O(S) and
    /// order-defining); each segment's ratio row is an exclusive
    /// contiguous range of `seg_ratios` and every entry is one pure
    /// division, so the table is bit-identical to the serial build for
    /// any thread count. Small tables fold to one inline thread.
    pub fn from_instance_par(inst: &Instance, threads: usize) -> Self {
        use super::pdhg::{n_chunks, DisjointSlice, PAR_MIN_NM, TASK_CHUNK};
        use crate::util::pool::Team;
        let (n, m, dims) = (inst.n_tasks(), inst.n_types(), inst.dims());
        let mut seg_off = Vec::with_capacity(n + 1);
        seg_off.push(0usize);
        let mut seg_spans: Vec<(u32, u32)> = Vec::with_capacity(n);
        let mut seg_demand: Vec<&[f64]> = Vec::with_capacity(n);
        for u in &inst.tasks {
            for seg in u.segments() {
                seg_spans.push((seg.start, seg.end));
                seg_demand.push(&seg.demand);
            }
            seg_off.push(seg_spans.len());
        }
        let s_total = seg_spans.len();
        let cells = s_total * m * dims;
        let threads = if cells < PAR_MIN_NM { 1 } else { threads.max(1) };
        let mut seg_ratios = vec![0.0; cells];
        {
            let team = Team::new(threads);
            let ds = DisjointSlice::new(&mut seg_ratios);
            let caps: Vec<&[f64]> =
                inst.node_types.iter().map(|b| b.capacity.as_slice()).collect();
            team.run_blocks(n_chunks(s_total), |c| {
                let lo = c * TASK_CHUNK;
                let hi = (lo + TASK_CHUNK).min(s_total);
                for s in lo..hi {
                    debug_assert!(s < s_total, "segment row within the table");
                    // SAFETY: segment s's ratio row is exclusive to the
                    // chunk owning s.
                    let row = unsafe { ds.slice_mut(s * m * dims, m * dims) };
                    let dem = seg_demand[s];
                    for b in 0..m {
                        for d in 0..dims {
                            row[b * dims + d] = dem[d] / caps[b][d];
                        }
                    }
                }
            });
        }
        MappingLp {
            n,
            m,
            dims,
            t: inst.horizon as usize,
            spans: inst.tasks.iter().map(|u| (u.start, u.end)).collect(),
            seg_off,
            seg_spans,
            seg_ratios,
            costs: inst.node_types.iter().map(|b| b.cost).collect(),
            rho: vec![1.0; m * dims],
        }
    }

    /// Ratio of segment `s` (an index into [`MappingLp::seg_spans`]) on
    /// node-type `b`, dimension `d`.
    #[inline]
    pub fn seg_ratio(&self, s: usize, b: usize, d: usize) -> f64 {
        self.seg_ratios[(s * self.m + b) * self.dims + d]
    }

    /// Segment index range of task `u`.
    #[inline]
    pub fn segs_of(&self, u: usize) -> std::ops::Range<usize> {
        self.seg_off[u]..self.seg_off[u + 1]
    }

    /// Total number of demand segments across all tasks.
    pub fn n_segments(&self) -> usize {
        self.seg_spans.len()
    }

    /// Every task has constant demand (one segment)? Fixed-shape
    /// backends (the AOT artifact) only support this case.
    pub fn is_flat(&self) -> bool {
        self.seg_spans.len() == self.n
    }

    #[inline]
    pub fn rho_at(&self, b: usize, d: usize) -> f64 {
        self.rho[b * self.dims + d]
    }

    /// Number of primal variables (x entries + alphas).
    pub fn n_vars(&self) -> usize {
        self.n * self.m + self.m
    }

    /// Objective of an (x, alpha) pair.
    pub fn objective(&self, alpha: &[f64]) -> f64 {
        self.costs.iter().zip(alpha).map(|(c, a)| c * a).sum()
    }

    /// Dense export for the exact simplex backend. Variable layout:
    /// `x(u,B) = u*m + B`, `alpha_B = n*m + B`. Only constraint rows for
    /// timeslots where some task is active are emitted (empty rows are
    /// trivially satisfied); the coefficient at (u, t) is the ratio of
    /// the segment covering t. Row scaling is intentionally *not*
    /// applied: the dense path is the unscaled ground truth.
    pub fn to_dense(&self) -> DenseLp {
        let (n, m, dims, t) = (self.n, self.m, self.dims, self.t);
        let nv = self.n_vars();
        let mut c = vec![0.0; nv];
        c[n * m..].copy_from_slice(&self.costs);

        let mut a_eq = Matrix::zeros(n, nv);
        for u in 0..n {
            for b in 0..m {
                a_eq.set(u, u * m + b, 1.0);
            }
        }

        // active (task, segment) lists per timeslot
        let mut active: Vec<Vec<(usize, usize)>> = vec![Vec::new(); t];
        for u in 0..n {
            for s in self.segs_of(u) {
                let (ss, se) = self.seg_spans[s];
                for ts in ss..=se {
                    active[ts as usize].push((u, s));
                }
            }
        }
        let live: Vec<usize> = (0..t).filter(|&ts| !active[ts].is_empty()).collect();
        let rows = live.len() * m * dims;
        let mut a_ub = Matrix::zeros(rows, nv);
        let mut row = 0;
        for b in 0..m {
            for &ts in &live {
                for d in 0..dims {
                    for &(u, s) in &active[ts] {
                        a_ub.set(row, u * m + b, self.seg_ratio(s, b, d));
                    }
                    a_ub.set(row, n * m + b, -1.0);
                    row += 1;
                }
            }
        }
        DenseLp { c, a_ub, b_ub: vec![0.0; rows], a_eq, b_eq: vec![1.0; n] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};
    use crate::lp::simplex;
    use crate::model::{trim, DemandSeg, NodeType, Task};

    #[test]
    fn shapes_and_layout() {
        let inst = generate(&SynthParams { n: 12, m: 3, dims: 2, horizon: 6, ..Default::default() }, 1);
        let lp = MappingLp::from_instance(&inst);
        assert_eq!(lp.n, 12);
        assert_eq!(lp.m, 3);
        // flat instance: one segment per task, seed ratios preserved
        assert!(lp.is_flat());
        assert_eq!(lp.n_segments(), 12);
        assert_eq!(lp.seg_ratios.len(), 12 * 3 * 2);
        assert!((lp.seg_ratio(3, 1, 0) - inst.ratio_avg(3, 1, 0)).abs() < 1e-15);
        assert_eq!(lp.segs_of(3), 3..4);
        assert_eq!(lp.seg_spans[3], lp.spans[3]);
    }

    #[test]
    fn piecewise_segments_materialize() {
        let inst = Instance::new(
            vec![
                Task::piecewise(
                    0,
                    vec![
                        DemandSeg { start: 0, end: 1, demand: vec![0.2] },
                        DemandSeg { start: 2, end: 3, demand: vec![0.8] },
                    ],
                ),
                Task::new(1, vec![0.5], 1, 2),
            ],
            vec![NodeType::new("a", vec![1.0], 1.0), NodeType::new("b", vec![0.8], 0.9)],
            4,
        );
        let lp = MappingLp::from_instance(&inst);
        assert!(!lp.is_flat());
        assert_eq!(lp.n_segments(), 3);
        assert_eq!(lp.segs_of(0), 0..2);
        assert_eq!(lp.segs_of(1), 2..3);
        assert!((lp.seg_ratio(0, 0, 0) - 0.2).abs() < 1e-15);
        assert!((lp.seg_ratio(1, 1, 0) - 1.0).abs() < 1e-15); // 0.8/0.8
        assert!((lp.seg_ratio(2, 0, 0) - 0.5).abs() < 1e-15);

        // dense export carries per-slot coefficients: on type 0, slot 0
        // uses 0.2 and slot 3 uses 0.8 for task 0
        let dense = lp.to_dense();
        // rows are (b-major, live-ts, d); all 4 slots live here
        assert!((dense.a_ub.at(0, 0) - 0.2).abs() < 1e-15, "slot 0");
        assert!((dense.a_ub.at(3, 0) - 0.8).abs() < 1e-15, "slot 3");
    }

    #[test]
    fn parallel_ratio_table_matches_serial_bitwise() {
        // big enough that from_instance_par really engages its team
        let inst = generate(
            &SynthParams { n: 1200, m: 3, dims: 2, horizon: 10, ..Default::default() },
            7,
        );
        let serial = MappingLp::from_instance(&inst);
        let par = MappingLp::from_instance_par(&inst, 4);
        assert_eq!(serial.seg_off, par.seg_off);
        assert_eq!(serial.seg_spans, par.seg_spans);
        assert_eq!(serial.seg_ratios.len(), par.seg_ratios.len());
        for (a, b) in serial.seg_ratios.iter().zip(&par.seg_ratios) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn dense_solves_tiny() {
        let inst = generate(&SynthParams { n: 6, m: 2, dims: 2, horizon: 4, ..Default::default() }, 2);
        let tr = trim(&inst);
        let lp = MappingLp::from_instance(&tr.instance);
        let dense = lp.to_dense();
        let r = simplex::solve(&dense);
        assert_eq!(r.status, simplex::SimplexStatus::Optimal);
        // optimum positive and below the trivial one-type bound
        assert!(r.objective > 0.0);
        // each task fully assigned
        for u in 0..lp.n {
            let s: f64 = (0..lp.m).map(|b| r.x[u * lp.m + b]).sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }
}
