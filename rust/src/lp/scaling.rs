//! Row equilibration for the mapping LP.
//!
//! PDHG convergence degrades when constraint rows have wildly different
//! norms. The inequality row (B,t,d) has entries `r(u,B,d)` for active
//! tasks; we scale each (B,d) row-group by `1/sqrt(max_u r(u,B,d))`
//! (a single Ruiz pass restricted to rows, uniform over t so the scaling
//! commutes with the interval prefix-sum operator and the AOT padding).
//! Scaled rows are `rho * (Kx - alpha) <= 0` — the feasible set, and hence
//! the optimum, is unchanged (verified in tests).

use super::builder::MappingLp;

/// Compute and install row scaling on the LP. Returns the scale factors.
pub fn equilibrate(lp: &mut MappingLp) -> Vec<f64> {
    let (m, dims) = (lp.m, lp.dims);
    let s_total = lp.n_segments();
    let mut rho = vec![1.0; m * dims];
    for b in 0..m {
        for d in 0..dims {
            let mut row_max: f64 = 0.0;
            for s in 0..s_total {
                row_max = row_max.max(lp.seg_ratio(s, b, d));
            }
            // Row also contains the -1 alpha entry: its norm is at least 1.
            let norm = row_max.max(1.0);
            rho[b * dims + d] = 1.0 / norm.sqrt();
        }
    }
    lp.rho = rho.clone();
    rho
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};
    use crate::lp::pdhg::{self, PdhgOptions};
    use crate::model::trim;

    #[test]
    fn scaling_bounded_and_positive() {
        let inst = generate(&SynthParams { n: 30, m: 4, ..Default::default() }, 3);
        let mut lp = MappingLp::from_instance(&trim(&inst).instance);
        let rho = equilibrate(&mut lp);
        assert_eq!(rho.len(), 4 * 5);
        assert!(rho.iter().all(|&v| v > 0.0 && v <= 1.0));
    }

    #[test]
    fn optimum_invariant_under_scaling() {
        let inst = generate(
            &SynthParams { n: 15, m: 3, dims: 2, horizon: 8, dem_range: (0.05, 0.3), ..Default::default() },
            7,
        );
        let lp_plain = MappingLp::from_instance(&trim(&inst).instance);
        let mut lp_scaled = lp_plain.clone();
        equilibrate(&mut lp_scaled);
        let r0 = pdhg::solve(&lp_plain, &PdhgOptions::default());
        let r1 = pdhg::solve(&lp_scaled, &PdhgOptions::default());
        let rel = (r0.objective - r1.objective).abs() / (1.0 + r0.objective);
        assert!(rel < 1e-3, "{} vs {}", r0.objective, r1.objective);
    }
}
