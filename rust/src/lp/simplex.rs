//! Exact LP solver: dense two-phase primal simplex with Bland's rule.
//!
//! Built from scratch as the verification substrate (the paper used CBC via
//! python-mip). Used on small instances in tests and as an optional exact
//! backend; the production path is the PDHG first-order solver (native or
//! the JAX/Pallas AOT artifact), cross-checked against this.
//!
//! Bland's anti-cycling rule guarantees termination; numerics use a fixed
//! pivot tolerance which is ample for the unit-scale mapping LPs here.

use super::problem::DenseLp;

const EPS: f64 = 1e-9;

#[derive(Clone, Debug, PartialEq)]
pub enum SimplexStatus {
    Optimal,
    Infeasible,
    Unbounded,
}

#[derive(Clone, Debug)]
pub struct SimplexResult {
    pub status: SimplexStatus,
    pub objective: f64,
    pub x: Vec<f64>,
}

/// Solve a dense LP exactly. Two phases: artificial variables drive an
/// initial basic feasible solution, then the true objective is optimized.
pub fn solve(lp: &DenseLp) -> SimplexResult {
    let n = lp.n_vars();
    let m_ub = lp.a_ub.rows;
    let m_eq = lp.a_eq.rows;
    let m = m_ub + m_eq;

    // Tableau variables: [x (n) | slack (m_ub) | artificial (m)]
    // We give every row an artificial to keep the construction uniform;
    // slack columns could serve as a basis for ub rows with b >= 0, but the
    // uniform version is simpler and phase 1 prices them out regardless.
    let n_slack = m_ub;
    let n_art = m;
    let cols = n + n_slack + n_art + 1; // + rhs
    let mut t = vec![0.0f64; m * cols];
    let rhs = cols - 1;
    let mut basis = vec![0usize; m];

    for r in 0..m {
        let (row_coeffs, b) = if r < m_ub {
            (lp.a_ub.row(r), lp.b_ub[r])
        } else {
            (lp.a_eq.row(r - m_ub), lp.b_eq[r - m_ub])
        };
        let sign = if b < 0.0 { -1.0 } else { 1.0 };
        for c in 0..n {
            t[r * cols + c] = sign * row_coeffs[c];
        }
        if r < m_ub {
            t[r * cols + n + r] = sign * 1.0; // slack
        }
        t[r * cols + n + n_slack + r] = 1.0; // artificial
        t[r * cols + rhs] = sign * b;
        basis[r] = n + n_slack + r;
    }

    // ---- phase 1: min sum(artificials) ----
    let mut cost1 = vec![0.0f64; cols - 1];
    for a in 0..n_art {
        cost1[n + n_slack + a] = 1.0;
    }
    if !optimize(&mut t, &mut basis, m, cols, &cost1) {
        // phase-1 objective is bounded below by 0; unbounded is impossible
        unreachable!("phase 1 cannot be unbounded");
    }
    let phase1_obj = objective_of(&t, &basis, m, cols, &cost1);
    if phase1_obj > 1e-7 {
        return SimplexResult { status: SimplexStatus::Infeasible, objective: f64::NAN, x: vec![] };
    }
    // Pivot out any artificial still in the basis (degenerate zero rows).
    for r in 0..m {
        if basis[r] >= n + n_slack {
            let mut pivoted = false;
            for c in 0..n + n_slack {
                if t[r * cols + c].abs() > 1e-7 {
                    pivot(&mut t, &mut basis, m, cols, r, c);
                    pivoted = true;
                    break;
                }
            }
            if !pivoted {
                // all-zero row: redundant constraint; leave artificial at 0
            }
        }
    }

    // ---- phase 2: original objective (artificials excluded) ----
    let mut cost2 = vec![0.0f64; cols - 1];
    cost2[..n].copy_from_slice(&lp.c);
    // forbid artificials from re-entering
    for a in 0..n_art {
        cost2[n + n_slack + a] = f64::INFINITY;
    }
    if !optimize(&mut t, &mut basis, m, cols, &cost2) {
        return SimplexResult { status: SimplexStatus::Unbounded, objective: f64::NEG_INFINITY, x: vec![] };
    }

    let mut x = vec![0.0f64; n];
    for r in 0..m {
        if basis[r] < n {
            x[basis[r]] = t[r * cols + rhs];
        }
    }
    let objective = lp.objective(&x);
    SimplexResult { status: SimplexStatus::Optimal, objective, x }
}

/// Reduced-cost driven simplex iterations with Bland's rule.
/// Returns false if unbounded.
fn optimize(t: &mut [f64], basis: &mut [usize], m: usize, cols: usize, cost: &[f64]) -> bool {
    let rhs = cols - 1;
    loop {
        // reduced costs: r_j = c_j - c_B^T B^{-1} A_j (computed via tableau)
        let mut entering = None;
        for j in 0..cols - 1 {
            if cost[j].is_infinite() {
                continue; // banned column
            }
            let mut rj = cost[j];
            for r in 0..m {
                let cb = cost[basis[r]];
                // lint:allow(float-ord): exact-zero skip — a structurally zero basis
                // cost contributes nothing; skipping it cannot change the sum.
                if cb != 0.0 && cb.is_finite() {
                    rj -= cb * t[r * cols + j];
                }
            }
            if rj < -EPS {
                entering = Some(j); // Bland: first improving index
                break;
            }
        }
        let Some(j) = entering else { return true };

        // ratio test, Bland tie-break on smallest basis index
        let mut leave: Option<usize> = None;
        let mut best = f64::INFINITY;
        for r in 0..m {
            let a = t[r * cols + j];
            if a > EPS {
                let ratio = t[r * cols + rhs] / a;
                if ratio < best - EPS
                    || (ratio < best + EPS
                        && leave.map(|l| basis[r] < basis[l]).unwrap_or(false))
                {
                    best = ratio;
                    leave = Some(r);
                }
            }
        }
        let Some(r) = leave else { return false };
        pivot(t, basis, m, cols, r, j);
    }
}

fn objective_of(t: &[f64], basis: &[usize], m: usize, cols: usize, cost: &[f64]) -> f64 {
    let rhs = cols - 1;
    (0..m)
        .filter(|&r| cost[basis[r]].is_finite())
        .map(|r| cost[basis[r]] * t[r * cols + rhs])
        .sum()
}

fn pivot(t: &mut [f64], basis: &mut [usize], m: usize, cols: usize, r: usize, j: usize) {
    let p = t[r * cols + j];
    debug_assert!(p.abs() > 1e-12, "zero pivot");
    for c in 0..cols {
        t[r * cols + c] /= p;
    }
    for rr in 0..m {
        if rr != r {
            let f = t[rr * cols + j];
            // lint:allow(float-ord): exact-zero pivot skip — eliminating a row
            // whose factor is exactly 0.0 is a no-op; the skip is bit-identical.
            if f != 0.0 {
                for c in 0..cols {
                    t[rr * cols + c] -= f * t[r * cols + c];
                }
            }
        }
    }
    basis[r] = j;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::problem::{DenseLp, Matrix};

    fn lp(c: &[f64], aub: &[&[f64]], bub: &[f64], aeq: &[&[f64]], beq: &[f64]) -> DenseLp {
        let n = c.len();
        let mut a_ub = Matrix::zeros(aub.len(), n);
        for (i, row) in aub.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                a_ub.set(i, j, v);
            }
        }
        let mut a_eq = Matrix::zeros(aeq.len(), n);
        for (i, row) in aeq.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                a_eq.set(i, j, v);
            }
        }
        DenseLp { c: c.to_vec(), a_ub, b_ub: bub.to_vec(), a_eq, b_eq: beq.to_vec() }
    }

    #[test]
    fn textbook_max_problem() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18  => min -3x-5y, opt=-36
        let p = lp(
            &[-3.0, -5.0],
            &[&[1.0, 0.0], &[0.0, 2.0], &[3.0, 2.0]],
            &[4.0, 12.0, 18.0],
            &[],
            &[],
        );
        let r = solve(&p);
        assert_eq!(r.status, SimplexStatus::Optimal);
        assert!((r.objective + 36.0).abs() < 1e-6);
        assert!((r.x[0] - 2.0).abs() < 1e-6 && (r.x[1] - 6.0).abs() < 1e-6);
    }

    #[test]
    fn equality_constraints() {
        // min x+2y s.t. x+y == 1 => x=1,y=0, obj 1
        let p = lp(&[1.0, 2.0], &[], &[], &[&[1.0, 1.0]], &[1.0]);
        let r = solve(&p);
        assert_eq!(r.status, SimplexStatus::Optimal);
        assert!((r.objective - 1.0).abs() < 1e-7);
    }

    #[test]
    fn infeasible_detected() {
        // x <= -1 with x >= 0
        let p = lp(&[1.0], &[&[1.0]], &[-1.0], &[], &[]);
        assert_eq!(solve(&p).status, SimplexStatus::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        // min -x, no constraints
        let p = lp(&[-1.0], &[], &[], &[], &[]);
        assert_eq!(solve(&p).status, SimplexStatus::Unbounded);
    }

    #[test]
    fn degenerate_terminates() {
        // redundant constraints forcing degeneracy
        let p = lp(
            &[-1.0, -1.0],
            &[&[1.0, 0.0], &[1.0, 0.0], &[1.0, 1.0]],
            &[1.0, 1.0, 1.0],
            &[],
            &[],
        );
        let r = solve(&p);
        assert_eq!(r.status, SimplexStatus::Optimal);
        assert!((r.objective + 1.0).abs() < 1e-7);
    }

    #[test]
    fn negative_rhs_rows() {
        // min x s.t. -x <= -2  (x >= 2)
        let p = lp(&[1.0], &[&[-1.0]], &[-2.0], &[], &[]);
        let r = solve(&p);
        assert_eq!(r.status, SimplexStatus::Optimal);
        assert!((r.objective - 2.0).abs() < 1e-7);
    }

    #[test]
    fn random_lps_feasible_and_kkt_sane() {
        // random feasible LPs: simplex solution must be feasible and not
        // worse than a known feasible point
        use crate::util::rng::Rng;
        let mut rng = Rng::new(17);
        for trial in 0..20 {
            let n = 3 + (trial % 4);
            let m = 2 + (trial % 3);
            // known feasible x0 in [0,1]^n; constraints a·x <= a·x0 + margin
            let x0: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let mut a_ub = Matrix::zeros(m, n);
            let mut b_ub = vec![0.0; m];
            for r in 0..m {
                let mut dot = 0.0;
                for c in 0..n {
                    let v = rng.uniform(-1.0, 1.0);
                    a_ub.set(r, c, v);
                    dot += v * x0[c];
                }
                b_ub[r] = dot + rng.f64() * 0.5;
            }
            let c: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let p = DenseLp { c, a_ub, b_ub, a_eq: Matrix::zeros(0, n), b_eq: vec![] };
            let r = solve(&p);
            if r.status == SimplexStatus::Optimal {
                assert!(p.max_violation(&r.x) < 1e-6, "trial {trial}");
                assert!(r.objective <= p.objective(&x0) + 1e-7, "trial {trial}");
            } else {
                assert_eq!(r.status, SimplexStatus::Unbounded, "trial {trial}");
            }
        }
    }
}
