//! Crossover: pull a first-order (PDHG) LP solution onto a near-vertex
//! point of the optimal face.
//!
//! Simplex returns *basic* solutions, which Lemma 4 shows are near-integral
//! (at most n + mTD fractional variables) — that is what the paper's
//! Figure 5 plots and what makes argmax rounding sharp. PDHG instead
//! converges to an interior point of the optimal face, smearing x across
//! node-types. This pass fixes that without changing the objective: with
//! alpha* held fixed, tasks are greedily re-assigned integrally (in
//! decreasing x_max order, preferring types by descending fractional mass)
//! subject to the congestion caps `K x <= alpha* (1 + tol)`; tasks that fit
//! nowhere integrally keep their fractional row. The result is feasible
//! for the same alpha*, so the LP objective — and the certified dual
//! bound — are untouched.

use super::builder::MappingLp;

/// Congestion tracker: load per (b, t, d) with interval updates.
struct Load {
    t: usize,
    dims: usize,
    data: Vec<f64>,
}

impl Load {
    fn new(lp: &MappingLp) -> Self {
        Load { t: lp.t, dims: lp.dims, data: vec![0.0; lp.m * lp.t * lp.dims] }
    }

    #[inline]
    fn idx(&self, b: usize, ts: usize, d: usize) -> usize {
        (b * self.t + ts) * self.dims + d
    }

    /// Add `frac` of task `u` on type `b` (per-segment coefficients).
    fn add(&mut self, lp: &MappingLp, u: usize, b: usize, frac: f64) {
        for s in lp.segs_of(u) {
            let (ss, se) = lp.seg_spans[s];
            for ts in ss as usize..=se as usize {
                for d in 0..self.dims {
                    let i = self.idx(b, ts, d);
                    self.data[i] += frac * lp.seg_ratio(s, b, d);
                }
            }
        }
    }

    /// Would adding `frac` of task `u` on `b` keep load within `cap[b,d]`?
    fn fits(&self, lp: &MappingLp, u: usize, b: usize, frac: f64, cap: &[f64]) -> bool {
        for s in lp.segs_of(u) {
            let (ss, se) = lp.seg_spans[s];
            for ts in ss as usize..=se as usize {
                for d in 0..self.dims {
                    if self.data[self.idx(b, ts, d)] + frac * lp.seg_ratio(s, b, d)
                        > cap[b * self.dims + d]
                    {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// Largest fraction of task `u` that fits on type `b` right now.
    fn max_fraction(&self, lp: &MappingLp, u: usize, b: usize, cap: &[f64]) -> f64 {
        let mut frac = f64::INFINITY;
        for s in lp.segs_of(u) {
            let (ss, se) = lp.seg_spans[s];
            for ts in ss as usize..=se as usize {
                for d in 0..self.dims {
                    let r = lp.seg_ratio(s, b, d);
                    if r > 0.0 {
                        let slack =
                            cap[b * self.dims + d] - self.data[self.idx(b, ts, d)];
                        frac = frac.min(slack / r);
                    }
                }
            }
        }
        frac.clamp(0.0, 1.0)
    }
}

/// Crossover `x` toward a vertex at fixed `alpha`. Returns the new x and
/// the number of tasks that remain fractional.
pub fn crossover(lp: &MappingLp, x: &[f64], alpha: &[f64], tol: f64) -> (Vec<f64>, usize) {
    let (n, m) = (lp.n, lp.m);
    // per-(b,d) congestion cap: alpha_b relaxed by tol (absolute + relative)
    let mut cap = vec![0.0; m * lp.dims];
    for b in 0..m {
        for d in 0..lp.dims {
            cap[b * lp.dims + d] = alpha[b] * (1.0 + tol) + tol;
        }
    }

    // Type-major pass: process node-types in descending total fractional
    // mass, and within a type take tasks in descending x[u,b]. On the
    // degenerate optimal faces of homogeneous cost models (every type has
    // identical capacity-per-cost) any congestion-feasible mapping is
    // LP-optimal — task-major rounding fragments tasks across all types
    // (one under-filled node per type after placement), while type-major
    // concentration keeps the mapping packable. On non-degenerate faces
    // the x mass is already concentrated and the two orders agree.
    let mut type_order: Vec<usize> = (0..m).collect();
    let mass: Vec<f64> =
        (0..m).map(|b| (0..n).map(|u| x[u * m + b]).sum()).collect();
    type_order.sort_by(|&a, &b| mass[b].total_cmp(&mass[a]).then(a.cmp(&b)));

    let mut load = Load::new(lp);
    let mut out = vec![0.0; n * m];
    let mut fractional = 0usize;
    let mut assigned = vec![false; n];

    for &b in &type_order {
        let mut tasks: Vec<usize> =
            (0..n).filter(|&u| !assigned[u] && x[u * m + b] > 1e-9).collect();
        tasks.sort_by(|&u, &v| {
            x[v * m + b].total_cmp(&x[u * m + b]).then(u.cmp(&v))
        });
        for u in tasks {
            if load.fits(lp, u, b, 1.0, &cap) {
                load.add(lp, u, b, 1.0);
                out[u * m + b] = 1.0;
                assigned[u] = true;
            }
        }
    }

    // leftover tasks: slack-split across their fractional support
    for u in 0..n {
        if assigned[u] {
            continue;
        }
        let mut types: Vec<usize> = (0..m).collect();
        types.sort_by(|&a, &b| {
            x[u * m + b].total_cmp(&x[u * m + a]).then(a.cmp(&b))
        });
        {
            // Split across types by remaining slack (descending x order,
            // then any type). The original fractional row is not re-usable
            // verbatim: other tasks' integral reassignments consumed
            // different slack than the LP solution did.
            fractional += 1;
            let mut remaining = 1.0f64;
            for &b in &types {
                if remaining <= 1e-12 {
                    break;
                }
                let f = load.max_fraction(lp, u, b, &cap).min(remaining);
                if f > 1e-12 {
                    load.add(lp, u, b, f);
                    out[u * m + b] += f;
                    remaining -= f;
                }
            }
            if remaining > 1e-9 {
                // No slack left anywhere: park the remainder on the type
                // with the most headroom. This slightly exceeds alpha; the
                // caller's tolerance accounts for it (tracked for tests).
                let b = types[0];
                load.add(lp, u, b, remaining);
                out[u * m + b] += remaining;
            }
        }
    }
    (out, fractional)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};
    use crate::lp::pdhg::{self, PdhgOptions};
    use crate::lp::scaling;
    use crate::model::trim;

    fn solved(seed: u64, n: usize) -> (MappingLp, pdhg::PdhgResult) {
        let inst = generate(&SynthParams { n, m: 5, ..Default::default() }, seed);
        let mut lp = MappingLp::from_instance(&trim(&inst).instance);
        scaling::equilibrate(&mut lp);
        let r = pdhg::solve(&lp, &PdhgOptions::default());
        (lp, r)
    }

    #[test]
    fn integralizes_most_tasks() {
        let (lp, r) = solved(3, 150);
        let before_integral = (0..lp.n)
            .filter(|&u| (0..lp.m).any(|b| r.x[u * lp.m + b] > 0.99))
            .count();
        let (x2, fractional) = crossover(&lp, &r.x, &r.alpha, 1e-4);
        let after_integral = (0..lp.n)
            .filter(|&u| (0..lp.m).any(|b| x2[u * lp.m + b] > 0.99))
            .count();
        assert!(after_integral >= before_integral);
        assert!(
            after_integral as f64 >= 0.8 * lp.n as f64,
            "only {after_integral}/{} integral ({fractional} fractional)",
            lp.n
        );
    }

    #[test]
    fn preserves_row_sums_and_objective() {
        let (lp, r) = solved(4, 100);
        let (x2, _) = crossover(&lp, &r.x, &r.alpha, 1e-4);
        for u in 0..lp.n {
            let s: f64 = (0..lp.m).map(|b| x2[u * lp.m + b]).sum();
            assert!((s - 1.0).abs() < 2e-3, "task {u} row sum {s}");
        }
        // The crossover x need not respect alpha exactly (that is the
        // integrality gap); what matters is that its implied congestion
        // cost stays close to the LP optimum — it feeds only the rounding.
        let mut op = pdhg::Operator::new(&lp);
        let mut buf = vec![0.0; lp.m * lp.t * lp.dims];
        op.forward(&x2, &vec![0.0; lp.m], &mut buf);
        let mut alpha2 = vec![0.0f64; lp.m];
        for b in 0..lp.m {
            for ts in 0..lp.t {
                for d in 0..lp.dims {
                    let rho = lp.rho_at(b, d);
                    if rho > 0.0 {
                        alpha2[b] =
                            alpha2[b].max(buf[(b * lp.t + ts) * lp.dims + d] / rho);
                    }
                }
            }
        }
        let obj2: f64 = lp.costs.iter().zip(&alpha2).map(|(c, a)| c * a).sum();
        assert!(
            obj2 <= r.objective * 1.10 + 1e-9,
            "crossover objective {obj2} vs LP {}",
            r.objective
        );
    }
}
