//! Native (f64) PDHG solver for the mapping LP — the same algorithm the
//! JAX/Pallas AOT artifact runs, with one structural difference: the
//! constraint operator exploits interval sparsity. Tasks are active over
//! contiguous spans, so `K x` is computed with difference arrays and
//! `K^T y` with prefix sums — O(m*D*(n+T)) per application instead of the
//! dense O(T*n*m*D) einsum. This is the production backend for trace-scale
//! instances whose trimmed T exceeds the largest artifact bucket; the two
//! backends are cross-checked in tests (and against the exact simplex).
//!
//! Enhancements over vanilla PDHG (both backends share the scheme, with
//! the restart/adaptation decisions taken between chunks):
//!   - iterate averaging (ergodic O(1/k) convergence on LPs),
//!   - adaptive restart to the better of {last, average} per chunk,
//!   - primal-weight (omega) rebalancing from the residual ratio.

use super::builder::MappingLp;

/// Solver options. Defaults suit the unit-scale mapping LPs.
#[derive(Clone, Debug)]
pub struct PdhgOptions {
    pub max_iters: usize,
    /// Iterations between residual checks / restarts (a "chunk" — matches
    /// the AOT artifact's compiled chunk length).
    pub chunk: usize,
    /// Feasibility tolerance (absolute; the LP is unit-scale).
    pub tol: f64,
    /// Relative duality-gap tolerance.
    pub gap_tol: f64,
    /// Initial primal weight.
    pub omega: f64,
    /// Adapt omega from the residual ratio between chunks. Off by
    /// default: on the mapping LP the restart scheme alone converges
    /// faster (see EXPERIMENTS.md section Perf, omega ablation).
    pub adapt_omega: bool,
}

impl Default for PdhgOptions {
    fn default() -> Self {
        PdhgOptions { max_iters: 120_000, chunk: 250, tol: 2e-4, gap_tol: 2e-4, omega: 1.0, adapt_omega: false }
    }
}

/// Solver outcome: primal/dual iterates, objective, residuals.
#[derive(Clone, Debug)]
pub struct PdhgResult {
    /// x[u*m + b]: fractional assignment of task u to node-type b.
    pub x: Vec<f64>,
    pub alpha: Vec<f64>,
    /// Inequality duals y[(b*t + ts)*dims + d] (for the *scaled* rows).
    pub y: Vec<f64>,
    /// Equality duals (one per task).
    pub w: Vec<f64>,
    pub objective: f64,
    pub iterations: usize,
    pub converged: bool,
    /// [eq_res, ineq_res, dual_res, rel_gap]
    pub residuals: [f64; 4],
}

/// The structured operator with scratch buffers.
///
/// Perf note (EXPERIMENTS.md section Perf): the public x/gx layout is
/// task-major `[u*m + b]` and ratios are `[(s*m + b)*dims + d]`, so the
/// per-(b,d) inner loops over tasks would stride by m / m*dims. The
/// operator therefore keeps a (b,d)-major copy of the per-*segment*
/// ratios and window endpoints, and transposes x/gx through scratch
/// buffers once per application — O(nm) copies against O(SmD) strided
/// reads saved. Piecewise demand keeps the interval sparsity: each task
/// contributes one diff-array update (forward) or prefix-sum read
/// (adjoint) per demand segment, so an application costs
/// O(m·D·(S + T)) where S is the total segment count (= n when flat).
pub struct Operator<'a> {
    lp: &'a MappingLp,
    /// prefix/diff scratch, length t+1
    scratch: Vec<f64>,
    /// per-segment ratios in (b,d)-major layout over the *permuted*
    /// segment order: ratios_bd[(b*dims + d)*S + j]
    ratios_bd: Vec<f64>,
    /// segment window endpoints as usize, permuted-task-major
    seg_starts: Vec<usize>,
    seg_ends: Vec<usize>,
    /// segment offsets per permuted task: permuted task i owns segments
    /// off[i]..off[i+1] of the arrays above (length n+1)
    off: Vec<usize>,
    /// x transposed to type-major: xt[b*n + u]
    xt: Vec<f64>,
    /// gx accumulator in type-major layout
    gxt: Vec<f64>,
    /// task permutation (sorted by start slot); internal arrays use
    /// permuted indices, transposes map back to the public order
    perm: Vec<usize>,
}

impl<'a> Operator<'a> {
    pub fn new(lp: &'a MappingLp) -> Self {
        let (n, m, dims) = (lp.n, lp.m, lp.dims);
        // Process tasks in start order: the diff-array scatter in forward()
        // then walks memory monotonically (second perf iteration, see
        // EXPERIMENTS.md section Perf).
        let mut perm: Vec<usize> = (0..n).collect();
        perm.sort_by_key(|&u| lp.spans[u].0);
        let s_total = lp.n_segments();
        let mut off = Vec::with_capacity(n + 1);
        off.push(0usize);
        let mut seg_starts = Vec::with_capacity(s_total);
        let mut seg_ends = Vec::with_capacity(s_total);
        // original segment index of each permuted segment slot
        let mut perm_segs = Vec::with_capacity(s_total);
        for &u in &perm {
            for s in lp.segs_of(u) {
                seg_starts.push(lp.seg_spans[s].0 as usize);
                seg_ends.push(lp.seg_spans[s].1 as usize);
                perm_segs.push(s);
            }
            off.push(seg_starts.len());
        }
        let mut ratios_bd = vec![0.0; m * dims * s_total];
        for (j, &s) in perm_segs.iter().enumerate() {
            for b in 0..m {
                for d in 0..dims {
                    ratios_bd[(b * dims + d) * s_total + j] = lp.seg_ratio(s, b, d);
                }
            }
        }
        Operator {
            lp,
            scratch: vec![0.0; lp.t + 1],
            ratios_bd,
            seg_starts,
            seg_ends,
            off,
            xt: vec![0.0; n * m],
            gxt: vec![0.0; n * m],
            perm,
        }
    }

    /// y_out = rho * (K x - alpha), shape (m, t, dims) flattened b-major.
    pub fn forward(&mut self, x: &[f64], alpha: &[f64], out: &mut [f64]) {
        let (n, m) = (self.lp.n, self.lp.m);
        // transpose x to type-major (permuted) once
        for (i, &u) in self.perm.iter().enumerate() {
            for b in 0..m {
                self.xt[b * n + i] = x[u * m + b];
            }
        }
        let xt = std::mem::take(&mut self.xt);
        self.forward_tm(&xt, alpha, out);
        self.xt = xt;
    }

    /// forward on a type-major permuted x (solver-internal hot path; the
    /// transpose-free variant saves 3 O(nm) passes per PDHG iteration).
    pub fn forward_tm(&mut self, xt: &[f64], alpha: &[f64], out: &mut [f64]) {
        let lp = self.lp;
        let (n, m, dims, t) = (lp.n, lp.m, lp.dims, lp.t);
        let s_total = lp.n_segments();
        debug_assert_eq!(out.len(), m * t * dims);
        for b in 0..m {
            let xb = &xt[b * n..(b + 1) * n];
            for d in 0..dims {
                let rho = lp.rho_at(b, d);
                let rat = &self.ratios_bd
                    [(b * dims + d) * s_total..(b * dims + d + 1) * s_total];
                let diff = &mut self.scratch;
                diff[..=t].fill(0.0);
                for u in 0..n {
                    let x = xb[u];
                    for j in self.off[u]..self.off[u + 1] {
                        let w = x * rat[j];
                        if w != 0.0 {
                            diff[self.seg_starts[j]] += w;
                            diff[self.seg_ends[j] + 1] -= w;
                        }
                    }
                }
                let mut acc = 0.0;
                let a = alpha[b];
                for ts in 0..t {
                    acc += diff[ts];
                    out[(b * t + ts) * dims + d] = rho * (acc - a);
                }
            }
        }
    }

    /// Adjoint pieces: gx[u*m+b] = sum_{t,d} rho*y * r over the task span;
    /// ga[b] = sum_{t,d} rho*y (the alpha-column contribution, negated by
    /// the caller).
    pub fn adjoint(&mut self, y: &[f64], gx: &mut [f64], ga: &mut [f64]) {
        let (n, m) = (self.lp.n, self.lp.m);
        let mut gxt = std::mem::take(&mut self.gxt);
        self.adjoint_tm(y, &mut gxt, ga);
        // transpose back to task-major public order
        for (i, &u) in self.perm.iter().enumerate() {
            for b in 0..m {
                gx[u * m + b] = gxt[b * n + i];
            }
        }
        self.gxt = gxt;
    }

    /// adjoint producing a type-major permuted gradient (solver-internal).
    pub fn adjoint_tm(&mut self, y: &[f64], gxt: &mut [f64], ga: &mut [f64]) {
        let lp = self.lp;
        let (n, m, dims, t) = (lp.n, lp.m, lp.dims, lp.t);
        let s_total = lp.n_segments();
        gxt.fill(0.0);
        ga.fill(0.0);
        for b in 0..m {
            let gxb = &mut gxt[b * n..(b + 1) * n];
            for d in 0..dims {
                let rho = lp.rho_at(b, d);
                let rat = &self.ratios_bd
                    [(b * dims + d) * s_total..(b * dims + d + 1) * s_total];
                // prefix[ts] = sum of rho*y[b,0..ts,d]
                let prefix = &mut self.scratch;
                prefix[0] = 0.0;
                for ts in 0..t {
                    prefix[ts + 1] = prefix[ts] + rho * y[(b * t + ts) * dims + d];
                }
                ga[b] += prefix[t];
                for u in 0..n {
                    for j in self.off[u]..self.off[u + 1] {
                        let seg = prefix[self.seg_ends[j] + 1] - prefix[self.seg_starts[j]];
                        gxb[u] += seg * rat[j];
                    }
                }
            }
        }
    }

    /// Transpose a type-major permuted vector into the public task-major
    /// order (chunk-boundary use).
    pub fn to_public(&self, vt: &[f64], v: &mut [f64]) {
        let (n, m) = (self.lp.n, self.lp.m);
        for (i, &u) in self.perm.iter().enumerate() {
            for b in 0..m {
                v[u * m + b] = vt[b * n + i];
            }
        }
    }

    /// Permute a public per-task vector into internal order.
    pub fn permute_tasks(&self, v: &[f64], vt: &mut [f64]) {
        for (i, &u) in self.perm.iter().enumerate() {
            vt[i] = v[u];
        }
    }

    /// Un-permute an internal per-task vector to public order.
    pub fn unpermute_tasks(&self, vt: &[f64], v: &mut [f64]) {
        for (i, &u) in self.perm.iter().enumerate() {
            v[u] = vt[i];
        }
    }

    /// Transpose public task-major x into type-major permuted layout.
    pub fn to_internal(&self, v: &[f64], vt: &mut [f64]) {
        let (n, m) = (self.lp.n, self.lp.m);
        for (i, &u) in self.perm.iter().enumerate() {
            for b in 0..m {
                vt[b * n + i] = v[u * m + b];
            }
        }
    }

    /// Power iteration estimate of the full operator's spectral norm
    /// (inequality rows + equality rows).
    pub fn norm_estimate(&mut self, iters: usize) -> f64 {
        let lp = self.lp;
        let (n, m) = (lp.n, lp.m);
        let mut x = vec![1.0 / ((n * m) as f64).sqrt(); n * m];
        let mut alpha = vec![1.0 / (m as f64).sqrt(); m];
        let mut y = vec![0.0; m * lp.t * lp.dims];
        let mut gx = vec![0.0; n * m];
        let mut ga = vec![0.0; m];
        let mut lam = 1.0;
        for _ in 0..iters {
            // A^T A (x, alpha)
            self.forward(&x, &alpha, &mut y);
            self.adjoint(&y, &mut gx, &mut ga);
            // equality rows: E x (per task), E^T e added to gx
            for u in 0..n {
                let e: f64 = (0..m).map(|b| x[u * m + b]).sum();
                for b in 0..m {
                    gx[u * m + b] += e;
                }
            }
            // alpha columns of A: -sum rho y
            for b in 0..m {
                ga[b] = -ga[b];
            }
            let nrm = (gx.iter().map(|v| v * v).sum::<f64>()
                + ga.iter().map(|v| v * v).sum::<f64>())
            .sqrt()
            .max(1e-30);
            lam = nrm;
            for (xi, gi) in x.iter_mut().zip(&gx) {
                *xi = gi / nrm;
            }
            for (ai, gi) in alpha.iter_mut().zip(&ga) {
                *ai = gi / nrm;
            }
        }
        lam.sqrt().max(1e-12)
    }
}

/// Residuals of an iterate: [eq, ineq, dual, rel_gap].
pub fn residuals(
    op: &mut Operator,
    x: &[f64],
    alpha: &[f64],
    y: &[f64],
    w: &[f64],
) -> [f64; 4] {
    let lp = op.lp;
    let (n, m) = (lp.n, lp.m);
    let mut eq: f64 = 0.0;
    for u in 0..n {
        let s: f64 = (0..m).map(|b| x[u * m + b]).sum();
        eq = eq.max((s - 1.0).abs());
    }
    let mut buf = vec![0.0; m * lp.t * lp.dims];
    op.forward(x, alpha, &mut buf);
    let ineq = buf.iter().copied().fold(0.0f64, |a, v| a.max(v));

    let mut gx = vec![0.0; n * m];
    let mut ga = vec![0.0; m];
    op.adjoint(y, &mut gx, &mut ga);
    let mut dual: f64 = 0.0;
    for u in 0..n {
        for b in 0..m {
            dual = dual.max(w[u] - gx[u * m + b]);
        }
    }
    for b in 0..m {
        dual = dual.max(ga[b] - lp.costs[b]);
    }
    let pobj = lp.objective(alpha);
    let dobj: f64 = w.iter().sum();
    let gap = (pobj - dobj).abs() / (1.0 + pobj.abs() + dobj.abs());
    [eq, ineq.max(0.0), dual.max(0.0), gap]
}

/// A full primal/dual PDHG state retained between solves — what a
/// [`crate::coordinator::session`] keeps alive so an incremental
/// re-solve after a workload delta resumes from the previous optimum
/// instead of iterating from zero. Layouts match [`PdhgResult`]:
/// `x[u*m + b]`, `alpha[b]`, `y[(b*t + ts)*dims + d]`, `w[u]`.
#[derive(Clone, Debug)]
pub struct WarmIterates {
    pub x: Vec<f64>,
    pub alpha: Vec<f64>,
    pub y: Vec<f64>,
    pub w: Vec<f64>,
}

impl WarmIterates {
    /// Do these iterates fit an LP of the given shape?
    pub fn fits_shape(&self, lp: &MappingLp) -> bool {
        self.x.len() == lp.n * lp.m
            && self.alpha.len() == lp.m
            && self.y.len() == lp.m * lp.t * lp.dims
            && self.w.len() == lp.n
    }
}

impl From<&PdhgResult> for WarmIterates {
    fn from(r: &PdhgResult) -> Self {
        WarmIterates { x: r.x.clone(), alpha: r.alpha.clone(), y: r.y.clone(), w: r.w.clone() }
    }
}

/// Resume from retained primal *and* dual iterates (see [`WarmIterates`]).
/// After a small instance perturbation (a handful of tasks admitted,
/// retired or reshaped) the previous optimum is a near-optimal start and
/// convergence takes a fraction of the cold iteration count.
pub fn solve_resume(lp: &MappingLp, opts: &PdhgOptions, warm: &WarmIterates) -> PdhgResult {
    assert!(warm.fits_shape(lp), "warm iterates do not fit the LP shape");
    solve_from(lp, opts, warm.x.clone(), warm.alpha.clone(), warm.y.clone(), warm.w.clone())
}

/// Solve with a warm primal start from an integral mapping: x is the
/// one-hot assignment, alpha its implied congestion peaks. Duals start at
/// zero. Cuts iterations substantially when the heuristic mapping is
/// already near-optimal (see EXPERIMENTS.md section Perf).
pub fn solve_warm(lp: &MappingLp, opts: &PdhgOptions, mapping: &[usize]) -> PdhgResult {
    assert_eq!(mapping.len(), lp.n);
    let mut x0 = vec![0.0; lp.n * lp.m];
    for (u, &b) in mapping.iter().enumerate() {
        x0[u * lp.m + b] = 1.0;
    }
    let mut op = Operator::new(lp);
    let mut kx = vec![0.0; lp.m * lp.t * lp.dims];
    op.forward(&x0, &vec![0.0; lp.m], &mut kx);
    let mut alpha0 = vec![0.0f64; lp.m];
    for b in 0..lp.m {
        for ts in 0..lp.t {
            for d in 0..lp.dims {
                let rho = lp.rho_at(b, d);
                if rho > 0.0 {
                    alpha0[b] = alpha0[b].max(kx[(b * lp.t + ts) * lp.dims + d] / rho);
                }
            }
        }
    }
    let ny = lp.m * lp.t * lp.dims;
    solve_from(lp, opts, x0, alpha0, vec![0.0; ny], vec![0.0; lp.n])
}

/// Solve the mapping LP with chunked, restarted, omega-adaptive PDHG.
pub fn solve(lp: &MappingLp, opts: &PdhgOptions) -> PdhgResult {
    let (n, m) = (lp.n, lp.m);
    let ny = m * lp.t * lp.dims;
    solve_from(lp, opts, vec![0.0; n * m], vec![0.0; m], vec![0.0; ny], vec![0.0; n])
}

fn solve_from(
    lp: &MappingLp,
    opts: &PdhgOptions,
    x0: Vec<f64>,
    alpha0: Vec<f64>,
    y0: Vec<f64>,
    w0: Vec<f64>,
) -> PdhgResult {
    let (n, m, dims, t) = (lp.n, lp.m, lp.dims, lp.t);
    let mut op = Operator::new(lp);
    let norm = op.norm_estimate(50);
    let base = 0.9 / norm;
    let mut omega = opts.omega;

    let nm = n * m;
    let ny = m * t * dims;
    assert_eq!(x0.len(), nm);
    assert_eq!(alpha0.len(), m);
    assert_eq!(y0.len(), ny);
    assert_eq!(w0.len(), n);
    // All per-iteration state lives in the operator-internal layout
    // (type-major, start-sorted): no transposes inside the hot loop.
    let mut xt = vec![0.0; nm];
    op.to_internal(&x0, &mut xt);
    let mut alpha = alpha0;
    let mut y = y0;
    let mut wt = vec![0.0; n];
    op.permute_tasks(&w0, &mut wt);

    // scratch (internal layout)
    let mut gxt = vec![0.0; nm];
    let mut ga = vec![0.0; m];
    let mut kx = vec![0.0; ny];
    let mut xbt = vec![0.0; nm];
    let mut ab = vec![0.0; m];
    let mut rows = vec![0.0; n];
    // chunk averages (internal layout)
    let (mut sxt, mut sa) = (vec![0.0; nm], vec![0.0; m]);
    let (mut sy, mut swt) = (vec![0.0; ny], vec![0.0; n]);
    // public-layout buffers for chunk-boundary residuals
    let mut xp = vec![0.0; nm];
    let mut wp = vec![0.0; n];

    let mut iter = 0usize;
    let mut res = [f64::INFINITY; 4];
    let mut converged = false;

    while iter < opts.max_iters {
        let tau = base * omega;
        let sigma = base / omega;
        sxt.fill(0.0);
        sa.fill(0.0);
        sy.fill(0.0);
        swt.fill(0.0);
        let chunk = opts.chunk.min(opts.max_iters - iter);
        for _ in 0..chunk {
            // primal step (fused: update + extrapolate + average + row sums)
            op.adjoint_tm(&y, &mut gxt, &mut ga);
            rows.fill(0.0);
            for b in 0..m {
                let base_i = b * n;
                for i in 0..n {
                    let j = base_i + i;
                    let v = xt[j] - tau * (gxt[j] - wt[i]);
                    let v = if v > 0.0 { v } else { 0.0 };
                    let xb = 2.0 * v - xt[j];
                    xbt[j] = xb;
                    rows[i] += xb;
                    xt[j] = v;
                    sxt[j] += v;
                }
            }
            for b in 0..m {
                let v = alpha[b] - tau * (lp.costs[b] - ga[b]);
                let v = if v > 0.0 { v } else { 0.0 };
                ab[b] = 2.0 * v - alpha[b];
                alpha[b] = v;
                sa[b] += v;
            }
            // dual step on extrapolated point (fused y update + average)
            op.forward_tm(&xbt, &ab, &mut kx);
            for i in 0..ny {
                let v = y[i] + sigma * kx[i];
                let v = if v > 0.0 { v } else { 0.0 };
                y[i] = v;
                sy[i] += v;
            }
            for i in 0..n {
                let v = wt[i] + sigma * (1.0 - rows[i]);
                wt[i] = v;
                swt[i] += v;
            }
            iter += 1;
        }
        // chunk boundary: evaluate last vs average, restart from the better
        let k = chunk as f64;
        let axt: Vec<f64> = sxt.iter().map(|v| v / k).collect();
        let aa: Vec<f64> = sa.iter().map(|v| v / k).collect();
        let ay: Vec<f64> = sy.iter().map(|v| v / k).collect();
        let awt: Vec<f64> = swt.iter().map(|v| v / k).collect();

        op.to_public(&xt, &mut xp);
        op.unpermute_tasks(&wt, &mut wp);
        let r_last = residuals(&mut op, &xp, &alpha, &y, &wp);
        op.to_public(&axt, &mut xp);
        op.unpermute_tasks(&awt, &mut wp);
        let r_avg = residuals(&mut op, &xp, &aa, &ay, &wp);
        let score = |r: &[f64; 4]| r[0].max(r[1]).max(r[2]).max(r[3]);
        if score(&r_avg) < score(&r_last) {
            xt.copy_from_slice(&axt);
            alpha.copy_from_slice(&aa);
            y.copy_from_slice(&ay);
            wt.copy_from_slice(&awt);
            res = r_avg;
        } else {
            res = r_last;
        }
        if res[0].max(res[1]) <= opts.tol && res[3] <= opts.gap_tol {
            converged = true;
            break;
        }
        // optional primal-weight adaptation (ablation shows the restart
        // scheme alone converges faster on the mapping LP; default off)
        if opts.adapt_omega {
            let pri = res[0].max(res[1]).max(1e-12);
            let dua = res[2].max(1e-12);
            let ratio = (pri / dua).sqrt().clamp(0.5, 2.0);
            omega = (omega * ratio).clamp(1e-3, 1e3);
        }
    }

    let mut x = vec![0.0; nm];
    let mut w = vec![0.0; n];
    op.to_public(&xt, &mut x);
    op.unpermute_tasks(&wt, &mut w);
    let objective = lp.objective(&alpha);
    PdhgResult { x, alpha, y, w, objective, iterations: iter, converged, residuals: res }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};
    use crate::lp::simplex;
    use crate::model::trim;

    fn small_lp(seed: u64, n: usize, m: usize, dims: usize, horizon: u32) -> MappingLp {
        let inst = generate(
            &SynthParams { n, m, dims, horizon, dem_range: (0.05, 0.3), ..Default::default() },
            seed,
        );
        let tr = trim(&inst);
        MappingLp::from_instance(&tr.instance)
    }

    #[test]
    fn operator_adjointness() {
        // <K x, y> == <x, K^T y> for random vectors
        use crate::util::rng::Rng;
        let lp = small_lp(1, 10, 3, 2, 8);
        let mut op = Operator::new(&lp);
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..lp.n * lp.m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let y: Vec<f64> = (0..lp.m * lp.t * lp.dims).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let alpha = vec![0.0; lp.m];
        let mut kx = vec![0.0; y.len()];
        op.forward(&x, &alpha, &mut kx);
        let lhs: f64 = kx.iter().zip(&y).map(|(a, b)| a * b).sum();
        let mut gx = vec![0.0; x.len()];
        let mut ga = vec![0.0; lp.m];
        op.adjoint(&y, &mut gx, &mut ga);
        let rhs: f64 = gx.iter().zip(&x).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-9 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
    }

    #[test]
    fn matches_simplex_on_small() {
        for seed in [0, 1, 2] {
            let lp = small_lp(seed, 8, 2, 2, 6);
            let exact = simplex::solve(&lp.to_dense());
            assert_eq!(exact.status, simplex::SimplexStatus::Optimal);
            let r = solve(&lp, &PdhgOptions { tol: 1e-7, gap_tol: 1e-7, ..Default::default() });
            assert!(r.converged, "seed {seed}: not converged {:?}", r.residuals);
            let rel = (r.objective - exact.objective).abs() / (1.0 + exact.objective.abs());
            assert!(rel < 1e-4, "seed {seed}: pdhg {} vs simplex {}", r.objective, exact.objective);
        }
    }

    #[test]
    fn shaped_operator_adjointness_and_optimum() {
        use crate::model::{DemandSeg, Instance, NodeType, Task};
        use crate::util::rng::Rng;
        // piecewise tasks: the operator applies per-segment coefficients
        let inst = Instance::new(
            vec![
                Task::piecewise(
                    0,
                    vec![
                        DemandSeg { start: 0, end: 2, demand: vec![0.1, 0.3] },
                        DemandSeg { start: 3, end: 5, demand: vec![0.4, 0.1] },
                    ],
                ),
                Task::new(1, vec![0.2, 0.2], 1, 4),
                Task::piecewise(
                    2,
                    vec![
                        DemandSeg { start: 2, end: 3, demand: vec![0.3, 0.05] },
                        DemandSeg { start: 4, end: 5, demand: vec![0.05, 0.3] },
                    ],
                ),
            ],
            vec![
                NodeType::new("a", vec![1.0, 1.0], 2.0),
                NodeType::new("b", vec![0.6, 0.6], 1.0),
            ],
            6,
        );
        let lp = MappingLp::from_instance(&trim(&inst).instance);
        assert!(!lp.is_flat());
        // <K x, y> == <x, K^T y>
        let mut op = Operator::new(&lp);
        let mut rng = Rng::new(6);
        let x: Vec<f64> = (0..lp.n * lp.m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let y: Vec<f64> =
            (0..lp.m * lp.t * lp.dims).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let alpha = vec![0.0; lp.m];
        let mut kx = vec![0.0; y.len()];
        op.forward(&x, &alpha, &mut kx);
        let lhs: f64 = kx.iter().zip(&y).map(|(a, b)| a * b).sum();
        let mut gx = vec![0.0; x.len()];
        let mut ga = vec![0.0; lp.m];
        op.adjoint(&y, &mut gx, &mut ga);
        let rhs: f64 = gx.iter().zip(&x).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-9 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
        // forward against a hand-built dense K x at a one-hot x
        let mut x1 = vec![0.0; lp.n * lp.m];
        for u in 0..lp.n {
            x1[u * lp.m] = 1.0; // everything on type 0
        }
        op.forward(&x1, &vec![0.0; lp.m], &mut kx);
        let dense = lp.to_dense();
        for ts in 0..lp.t {
            for d in 0..lp.dims {
                // recompute congestion at (type 0, ts, d) from segments
                let mut want = 0.0;
                for u in 0..lp.n {
                    for s in lp.segs_of(u) {
                        let (ss, se) = lp.seg_spans[s];
                        if ts as u32 >= ss && ts as u32 <= se {
                            want += lp.seg_ratio(s, 0, d);
                        }
                    }
                }
                let got = kx[(0 * lp.t + ts) * lp.dims + d];
                assert!((got - want).abs() < 1e-12, "ts {ts} d {d}: {got} vs {want}");
            }
        }
        // PDHG matches the exact simplex optimum on the shaped LP
        let exact = simplex::solve(&dense);
        assert_eq!(exact.status, simplex::SimplexStatus::Optimal);
        let r = solve(&lp, &PdhgOptions { tol: 1e-7, gap_tol: 1e-7, ..Default::default() });
        assert!(r.converged, "{:?}", r.residuals);
        let rel = (r.objective - exact.objective).abs() / (1.0 + exact.objective.abs());
        assert!(rel < 1e-4, "pdhg {} vs simplex {}", r.objective, exact.objective);
    }

    #[test]
    fn converges_on_medium() {
        let lp = small_lp(3, 60, 5, 3, 12);
        let r = solve(&lp, &PdhgOptions::default());
        assert!(r.converged, "residuals {:?}", r.residuals);
        assert!(r.objective > 0.0);
    }

    #[test]
    fn dual_never_exceeds_primal_at_tolerance() {
        let lp = small_lp(4, 20, 3, 2, 8);
        let r = solve(&lp, &PdhgOptions::default());
        let dobj: f64 = r.w.iter().sum();
        assert!(dobj <= r.objective + 1e-3 * (1.0 + r.objective));
    }

    #[test]
    fn resume_from_retained_iterates_converges_fast() {
        let lp = small_lp(8, 40, 4, 3, 10);
        let cold = solve(&lp, &PdhgOptions::default());
        assert!(cold.converged);
        // resuming at the optimum needs at most a chunk to re-certify
        let warm = WarmIterates::from(&cold);
        let r = solve_resume(&lp, &PdhgOptions::default(), &warm);
        assert!(r.converged, "{:?}", r.residuals);
        assert!(
            r.iterations <= cold.iterations,
            "resume {} iters vs cold {}",
            r.iterations,
            cold.iterations
        );
        let rel = (r.objective - cold.objective).abs() / (1.0 + cold.objective.abs());
        assert!(rel < 1e-3, "resume {} vs cold {}", r.objective, cold.objective);
        // shape mismatches are a programmer error, caught loudly
        let bad = WarmIterates { x: vec![0.0; 3], ..warm.clone() };
        assert!(!bad.fits_shape(&lp));
    }

    #[test]
    fn row_scaling_preserves_optimum() {
        let mut lp = small_lp(5, 15, 3, 2, 8);
        let r0 = solve(&lp, &PdhgOptions::default());
        for v in lp.rho.iter_mut() {
            *v = 0.37;
        }
        let r1 = solve(&lp, &PdhgOptions::default());
        let rel = (r0.objective - r1.objective).abs() / (1.0 + r0.objective);
        assert!(rel < 5e-4, "{} vs {}", r0.objective, r1.objective);
    }
}
