//! Native (f64) PDHG solver for the mapping LP — the same algorithm the
//! JAX/Pallas AOT artifact runs, with one structural difference: the
//! constraint operator exploits interval sparsity. Tasks are active over
//! contiguous spans, so `K x` is computed with difference arrays and
//! `K^T y` with prefix sums — O(m*D*(n+T)) per application instead of the
//! dense O(T*n*m*D) einsum. This is the production backend for trace-scale
//! instances whose trimmed T exceeds the largest artifact bucket; the two
//! backends are cross-checked in tests (and against the exact simplex).
//!
//! Enhancements over vanilla PDHG (both backends share the scheme, with
//! the restart/adaptation decisions taken between chunks):
//!   - iterate averaging (ergodic O(1/k) convergence on LPs),
//!   - adaptive restart to the better of {last, average} per chunk,
//!   - primal-weight (omega) rebalancing from the residual ratio.
//!
//! # Parallel engine
//!
//! Every hot kernel runs on a [`Team`] of persistent worker threads
//! ([`PdhgOptions::threads`], resolved by [`resolve_threads`]). The block
//! decomposition:
//!
//!   - `forward_tm` / `adjoint_tm` shard across **(b, d) blocks** — each
//!     (node-type, dimension) pair owns an exclusive diff/prefix lane of
//!     length t+1 in `Operator::lanes` plus a disjoint strided slice of
//!     the output, so blocks share nothing and run in any order.
//!   - the adjoint's alpha-column sums are combined **serially in fixed
//!     (b, d) order** from per-block partials (`ga_part`), and its task
//!     gradient runs a second phase over **(b, task-chunk) blocks** that
//!     reads the lanes of phase one.
//!   - dense vector kernels (proximal step, dual step, averaging,
//!     residual maxima) shard over **fixed-boundary index chunks** of
//!     [`TASK_CHUNK`] elements; per-chunk partials are folded serially in
//!     chunk order.
//!
//! # Deterministic-reduction contract
//!
//! Results are **bit-identical for every thread count** (the repo-wide
//! determinism guarantee, same style as the portfolio's
//! parallel==sequential-fold pin): every floating-point value is produced
//! by exactly the per-element operation sequence of the sequential
//! reference — blocks only interchange *independent* loop iterations,
//! all scalar f64 **sum** reductions (dual objective, norm estimate,
//! objective) stay sequential, and f64 **max** reductions parallelize
//! freely because `f64::max` is exactly associative (including its
//! NaN-dropping semantics). Instances below [`PAR_MIN_NM`] fold to one
//! inline thread; the outputs are unchanged by construction.

use super::builder::MappingLp;
use crate::util::pool::Team;

/// Trust-boundary cap on the LP thread knob (service requests are
/// untrusted input — same role as `MAX_PORTFOLIO_SPECS`).
pub const MAX_LP_THREADS: usize = 64;

/// Below this n*m the solver always runs inline on the caller thread:
/// dispatch overhead would dominate kernels this small, and unit-scale
/// LPs solve in microseconds anyway.
pub(crate) const PAR_MIN_NM: usize = 4096;

/// Fixed chunk length for dense-vector block decomposition. Fixed (not
/// derived from the thread count) so chunk boundaries — and therefore
/// every partial fold — are identical for every thread count.
pub(crate) const TASK_CHUNK: usize = 1024;

/// Resolve a requested thread count: 0 means auto (half the cores,
/// capped at 8, so the portfolio/decompose pools keep their share and
/// nested parallelism doesn't oversubscribe); explicit requests are
/// capped at [`MAX_LP_THREADS`].
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        (cores / 2).clamp(1, 8)
    } else {
        requested.min(MAX_LP_THREADS)
    }
}

/// Solver options. Defaults suit the unit-scale mapping LPs.
#[derive(Clone, Debug)]
pub struct PdhgOptions {
    pub max_iters: usize,
    /// Iterations between residual checks / restarts (a "chunk" — matches
    /// the AOT artifact's compiled chunk length).
    pub chunk: usize,
    /// Feasibility tolerance (absolute; the LP is unit-scale).
    pub tol: f64,
    /// Relative duality-gap tolerance.
    pub gap_tol: f64,
    /// Initial primal weight.
    pub omega: f64,
    /// Adapt omega from the residual ratio between chunks. Off by
    /// default: on the mapping LP the restart scheme alone converges
    /// faster (see EXPERIMENTS.md section Perf, omega ablation).
    pub adapt_omega: bool,
    /// Worker threads for the parallel kernels. 0 = auto (see
    /// [`resolve_threads`]); results are bit-identical for every value.
    pub threads: usize,
}

impl Default for PdhgOptions {
    fn default() -> Self {
        PdhgOptions {
            max_iters: 120_000,
            chunk: 250,
            tol: 2e-4,
            gap_tol: 2e-4,
            omega: 1.0,
            adapt_omega: false,
            threads: 0,
        }
    }
}

/// Solver outcome: primal/dual iterates, objective, residuals.
#[derive(Clone, Debug)]
pub struct PdhgResult {
    /// x[u*m + b]: fractional assignment of task u to node-type b.
    pub x: Vec<f64>,
    pub alpha: Vec<f64>,
    /// Inequality duals y[(b*t + ts)*dims + d] (for the *scaled* rows).
    pub y: Vec<f64>,
    /// Equality duals (one per task).
    pub w: Vec<f64>,
    pub objective: f64,
    pub iterations: usize,
    pub converged: bool,
    /// [eq_res, ineq_res, dual_res, rel_gap]
    pub residuals: [f64; 4],
}

/// One chunk of omega rebalancing, guarded against the failure mode a
/// converged dual chunk exposes: `pri`/`dua` that are zero (clamped to
/// 1e-12 before the ratio) or non-finite (omega passes through
/// unchanged — a NaN/inf ratio would otherwise poison every subsequent
/// iterate through tau/sigma).
pub(crate) fn adapt_omega(omega: f64, pri: f64, dua: f64) -> f64 {
    if !pri.is_finite() || !dua.is_finite() {
        return omega;
    }
    let ratio = (pri.max(1e-12) / dua.max(1e-12)).sqrt().clamp(0.5, 2.0);
    (omega * ratio).clamp(1e-3, 1e3)
}

/// A raw view over an `&mut [f64]` that parallel blocks index into.
///
/// SAFETY CONTRACT: every concurrent block must touch a disjoint set of
/// indices (the block decompositions above are designed so ownership is
/// provable from the block id alone); the view must not outlive the
/// kernel that created it. `Team::run_blocks` returning is the
/// happens-before edge that makes the writes visible to the caller.
#[derive(Clone, Copy)]
pub(crate) struct DisjointSlice {
    ptr: *mut f64,
    len: usize,
}

// SAFETY: the raw pointer is only written through the per-block
// disjoint-index contract above; sharing the view across the team's
// threads is the whole point and is sound under that contract.
unsafe impl Send for DisjointSlice {}
// SAFETY: same contract — concurrent blocks never alias an index.
unsafe impl Sync for DisjointSlice {}

impl DisjointSlice {
    pub(crate) fn new(s: &mut [f64]) -> Self {
        DisjointSlice { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// SAFETY: no concurrent block may touch index `i`.
    pub(crate) unsafe fn get(&self, i: usize) -> f64 {
        debug_assert!(i < self.len);
        // SAFETY: i is in bounds (debug-asserted) and exclusively owned
        // by the calling block per this fn's contract.
        unsafe { *self.ptr.add(i) }
    }

    /// SAFETY: no concurrent block may touch index `i`.
    pub(crate) unsafe fn set(&self, i: usize, v: f64) {
        debug_assert!(i < self.len);
        // SAFETY: i is in bounds (debug-asserted) and exclusively owned
        // by the calling block per this fn's contract.
        unsafe { *self.ptr.add(i) = v };
    }

    /// SAFETY: the range `start..start+len` must be exclusive to the
    /// calling block for the lifetime of the returned slice.
    pub(crate) unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [f64] {
        debug_assert!(start <= self.len && len <= self.len - start);
        // SAFETY: the range is in bounds (debug-asserted, overflow-proof
        // form) and exclusively owned by the caller per this fn's
        // contract, so no aliasing &mut can exist.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), len) }
    }
}

/// ceil(len / TASK_CHUNK) — the fixed-boundary chunk count.
pub(crate) fn n_chunks(len: usize) -> usize {
    (len + TASK_CHUNK - 1) / TASK_CHUNK
}

/// dst[i] = src[i] / k, sharded over fixed chunks (elementwise, so
/// bit-identical for any thread count).
fn div_into(team: &Team, src: &[f64], k: f64, dst: &mut [f64]) {
    debug_assert_eq!(src.len(), dst.len());
    let len = src.len();
    let ds = DisjointSlice::new(dst);
    team.run_blocks(n_chunks(len), |c| {
        let lo = c * TASK_CHUNK;
        let hi = (lo + TASK_CHUNK).min(len);
        for i in lo..hi {
            // SAFETY: chunk c owns indices lo..hi exclusively.
            unsafe { ds.set(i, src[i] / k) };
        }
    });
}

/// max over `eval(0..len)` with a 0.0 floor, computed as per-chunk
/// partial maxima folded serially in chunk order. `f64::max` is exactly
/// associative, so the result is bitwise equal to the sequential fold.
fn max_by_chunks<F: Fn(usize) -> f64 + Sync>(team: &Team, len: usize, eval: F) -> f64 {
    if len == 0 {
        return 0.0;
    }
    let chunks = n_chunks(len);
    let mut partials = vec![0.0f64; chunks];
    {
        let ds = DisjointSlice::new(&mut partials);
        team.run_blocks(chunks, |c| {
            let lo = c * TASK_CHUNK;
            let hi = (lo + TASK_CHUNK).min(len);
            let mut acc = 0.0f64;
            for i in lo..hi {
                acc = acc.max(eval(i));
            }
            // SAFETY: partial slot c is exclusive to chunk c.
            unsafe { ds.set(c, acc) };
        });
    }
    partials.iter().copied().fold(0.0f64, f64::max)
}

/// The structured operator with scratch buffers and its worker team.
///
/// Perf note (EXPERIMENTS.md section Perf): the public x/gx layout is
/// task-major `[u*m + b]` and ratios are `[(s*m + b)*dims + d]`, so the
/// per-(b,d) inner loops over tasks would stride by m / m*dims. The
/// operator therefore keeps a (b,d)-major copy of the per-*segment*
/// ratios and window endpoints, and transposes x/gx through scratch
/// buffers once per application — O(nm) copies against O(SmD) strided
/// reads saved. Piecewise demand keeps the interval sparsity: each task
/// contributes one diff-array update (forward) or prefix-sum read
/// (adjoint) per demand segment, so an application costs
/// O(m·D·(S + T)) where S is the total segment count (= n when flat).
pub struct Operator<'a> {
    lp: &'a MappingLp,
    /// per-(b,d) diff/prefix lanes, each of length t+1: lane
    /// k = b*dims + d occupies lanes[k*(t+1)..(k+1)*(t+1)] and is
    /// exclusive to block k during a kernel.
    lanes: Vec<f64>,
    /// per-(b,d) alpha-column partials from the adjoint's phase one,
    /// combined serially in fixed (b, d) order.
    ga_part: Vec<f64>,
    /// per-segment ratios in (b,d)-major layout over the *permuted*
    /// segment order: ratios_bd[(b*dims + d)*S + j]
    ratios_bd: Vec<f64>,
    /// segment window endpoints as usize, permuted-task-major
    seg_starts: Vec<usize>,
    seg_ends: Vec<usize>,
    /// segment offsets per permuted task: permuted task i owns segments
    /// off[i]..off[i+1] of the arrays above (length n+1)
    off: Vec<usize>,
    /// x transposed to type-major: xt[b*n + u]
    xt: Vec<f64>,
    /// gx accumulator in type-major layout
    gxt: Vec<f64>,
    /// task permutation (sorted by start slot); internal arrays use
    /// permuted indices, transposes map back to the public order
    perm: Vec<usize>,
    /// persistent worker team for the parallel kernels
    team: Team,
}

impl<'a> Operator<'a> {
    /// Single-threaded operator (kernels run inline on the caller).
    pub fn new(lp: &'a MappingLp) -> Self {
        Self::with_threads(lp, 1)
    }

    /// Operator with a worker team of up to `threads` threads. Instances
    /// below [`PAR_MIN_NM`] fold to one inline thread; outputs are
    /// bit-identical either way.
    pub fn with_threads(lp: &'a MappingLp, threads: usize) -> Self {
        let (n, m, dims) = (lp.n, lp.m, lp.dims);
        let threads = if n * m < PAR_MIN_NM { 1 } else { threads.max(1) };
        let team = Team::new(threads);
        // Process tasks in start order: the diff-array scatter in forward()
        // then walks memory monotonically (second perf iteration, see
        // EXPERIMENTS.md section Perf).
        let mut perm: Vec<usize> = (0..n).collect();
        perm.sort_by_key(|&u| lp.spans[u].0);
        let s_total = lp.n_segments();
        let mut off = Vec::with_capacity(n + 1);
        off.push(0usize);
        let mut seg_starts = Vec::with_capacity(s_total);
        let mut seg_ends = Vec::with_capacity(s_total);
        // original segment index of each permuted segment slot
        let mut perm_segs = Vec::with_capacity(s_total);
        for &u in &perm {
            for s in lp.segs_of(u) {
                seg_starts.push(lp.seg_spans[s].0 as usize);
                seg_ends.push(lp.seg_spans[s].1 as usize);
                perm_segs.push(s);
            }
            off.push(seg_starts.len());
        }
        // (b,d)-major ratio table, one exclusive row per (b,d) block
        // (each element is one pure division — order-free).
        let mut ratios_bd = vec![0.0; m * dims * s_total];
        {
            let ds = DisjointSlice::new(&mut ratios_bd);
            team.run_blocks(m * dims, |k| {
                let (b, d) = (k / dims, k % dims);
                // SAFETY: row k is exclusive to block k.
                let row = unsafe { ds.slice_mut(k * s_total, s_total) };
                for (j, &s) in perm_segs.iter().enumerate() {
                    row[j] = lp.seg_ratio(s, b, d);
                }
            });
        }
        Operator {
            lp,
            lanes: vec![0.0; m * dims * (lp.t + 1)],
            ga_part: vec![0.0; m * dims],
            ratios_bd,
            seg_starts,
            seg_ends,
            off,
            xt: vec![0.0; n * m],
            gxt: vec![0.0; n * m],
            perm,
            team,
        }
    }

    /// Worker threads backing this operator's kernels.
    pub fn threads(&self) -> usize {
        self.team.threads()
    }

    /// y_out = rho * (K x - alpha), shape (m, t, dims) flattened b-major.
    pub fn forward(&mut self, x: &[f64], alpha: &[f64], out: &mut [f64]) {
        let (n, m) = (self.lp.n, self.lp.m);
        // transpose x to type-major (permuted) once
        for (i, &u) in self.perm.iter().enumerate() {
            for b in 0..m {
                self.xt[b * n + i] = x[u * m + b];
            }
        }
        let xt = std::mem::take(&mut self.xt);
        self.forward_tm(&xt, alpha, out);
        self.xt = xt;
    }

    /// forward on a type-major permuted x (solver-internal hot path; the
    /// transpose-free variant saves 3 O(nm) passes per PDHG iteration).
    ///
    /// Parallel over (b,d) blocks: block k = b*dims + d owns diff lane k
    /// and the output indices `(b*t + ts)*dims + d` — fully disjoint, so
    /// any block order produces the sequential reference bit-for-bit.
    pub fn forward_tm(&mut self, xt: &[f64], alpha: &[f64], out: &mut [f64]) {
        let lp = self.lp;
        let (n, m, dims, t) = (lp.n, lp.m, lp.dims, lp.t);
        let s_total = lp.n_segments();
        debug_assert_eq!(out.len(), m * t * dims);
        let Operator { lanes, team, ratios_bd, seg_starts, seg_ends, off, .. } = self;
        let out_ds = DisjointSlice::new(out);
        let lanes_ds = DisjointSlice::new(lanes);
        team.run_blocks(m * dims, |k| {
            let (b, d) = (k / dims, k % dims);
            let rho = lp.rho_at(b, d);
            let xb = &xt[b * n..(b + 1) * n];
            let rat = &ratios_bd[k * s_total..(k + 1) * s_total];
            // SAFETY: lane k is exclusive to block k.
            let diff = unsafe { lanes_ds.slice_mut(k * (t + 1), t + 1) };
            diff.fill(0.0);
            for u in 0..n {
                let x = xb[u];
                for j in off[u]..off[u + 1] {
                    let w = x * rat[j];
                    // lint:allow(float-ord): exact-zero sparsity skip —
                    // adding/subtracting 0.0 is the identity, so skipping
                    // preserves bit-identical sums
                    if w != 0.0 {
                        diff[seg_starts[j]] += w;
                        diff[seg_ends[j] + 1] -= w;
                    }
                }
            }
            let mut acc = 0.0;
            let a = alpha[b];
            for ts in 0..t {
                acc += diff[ts];
                // SAFETY: stride-d index owned by block k = b*dims + d.
                unsafe { out_ds.set((b * t + ts) * dims + d, rho * (acc - a)) };
            }
        });
    }

    /// Adjoint pieces: gx[u*m+b] = sum_{t,d} rho*y * r over the task span;
    /// ga[b] = sum_{t,d} rho*y (the alpha-column contribution, negated by
    /// the caller).
    pub fn adjoint(&mut self, y: &[f64], gx: &mut [f64], ga: &mut [f64]) {
        let (n, m) = (self.lp.n, self.lp.m);
        let mut gxt = std::mem::take(&mut self.gxt);
        self.adjoint_tm(y, &mut gxt, ga);
        // transpose back to task-major public order
        for (i, &u) in self.perm.iter().enumerate() {
            for b in 0..m {
                gx[u * m + b] = gxt[b * n + i];
            }
        }
        self.gxt = gxt;
    }

    /// adjoint producing a type-major permuted gradient (solver-internal).
    ///
    /// Two parallel phases with a serial combine between them:
    ///   1. per-(b,d) prefix lanes (disjoint, like the forward) plus the
    ///      alpha-column partial `ga_part[k] = prefix[t]`;
    ///      then `ga[b] = Σ_d ga_part[b*dims + d]` serially in fixed d
    ///      order — the exact sum order of the sequential reference;
    ///   2. per-(b, task-chunk) blocks: each task u accumulates its
    ///      gradient in d-outer / segment-inner order into a local before
    ///      one disjoint write — again the sequential per-element order.
    pub fn adjoint_tm(&mut self, y: &[f64], gxt: &mut [f64], ga: &mut [f64]) {
        let lp = self.lp;
        let (n, m, dims, t) = (lp.n, lp.m, lp.dims, lp.t);
        let s_total = lp.n_segments();
        debug_assert_eq!(gxt.len(), n * m);
        let Operator { lanes, ga_part, team, ratios_bd, seg_starts, seg_ends, off, .. } = self;
        // phase 1: prefix lanes + alpha-column partials
        {
            let lanes_ds = DisjointSlice::new(lanes);
            let gp_ds = DisjointSlice::new(ga_part);
            team.run_blocks(m * dims, |k| {
                let (b, d) = (k / dims, k % dims);
                let rho = lp.rho_at(b, d);
                // SAFETY: lane k / partial slot k are exclusive to block k.
                let prefix = unsafe { lanes_ds.slice_mut(k * (t + 1), t + 1) };
                prefix[0] = 0.0;
                for ts in 0..t {
                    prefix[ts + 1] = prefix[ts] + rho * y[(b * t + ts) * dims + d];
                }
                // SAFETY: partial slot k is exclusive to block k.
                unsafe { gp_ds.set(k, prefix[t]) };
            });
        }
        // serial fixed-order combine (bit-identical to the sequential fold)
        for b in 0..m {
            let mut acc = 0.0;
            for d in 0..dims {
                acc += ga_part[b * dims + d];
            }
            ga[b] = acc;
        }
        // phase 2: task gradients off the (now read-only) prefix lanes
        let lanes_ref: &[f64] = lanes;
        let chunks = n_chunks(n);
        let gxt_ds = DisjointSlice::new(gxt);
        team.run_blocks(m * chunks, |q| {
            let (b, c) = (q / chunks, q % chunks);
            let lo = c * TASK_CHUNK;
            let hi = (lo + TASK_CHUNK).min(n);
            for u in lo..hi {
                let mut acc = 0.0;
                for d in 0..dims {
                    let k = b * dims + d;
                    let prefix = &lanes_ref[k * (t + 1)..(k + 1) * (t + 1)];
                    let rat = &ratios_bd[k * s_total..(k + 1) * s_total];
                    for j in off[u]..off[u + 1] {
                        acc += (prefix[seg_ends[j] + 1] - prefix[seg_starts[j]]) * rat[j];
                    }
                }
                // SAFETY: index b*n + u is owned by block (b, chunk of u).
                unsafe { gxt_ds.set(b * n + u, acc) };
            }
        });
    }

    /// Transpose a type-major permuted vector into the public task-major
    /// order (chunk-boundary use).
    pub fn to_public(&self, vt: &[f64], v: &mut [f64]) {
        let (n, m) = (self.lp.n, self.lp.m);
        for (i, &u) in self.perm.iter().enumerate() {
            for b in 0..m {
                v[u * m + b] = vt[b * n + i];
            }
        }
    }

    /// Permute a public per-task vector into internal order.
    pub fn permute_tasks(&self, v: &[f64], vt: &mut [f64]) {
        for (i, &u) in self.perm.iter().enumerate() {
            vt[i] = v[u];
        }
    }

    /// Un-permute an internal per-task vector to public order.
    pub fn unpermute_tasks(&self, vt: &[f64], v: &mut [f64]) {
        for (i, &u) in self.perm.iter().enumerate() {
            v[u] = vt[i];
        }
    }

    /// Transpose public task-major x into type-major permuted layout.
    pub fn to_internal(&self, v: &[f64], vt: &mut [f64]) {
        let (n, m) = (self.lp.n, self.lp.m);
        for (i, &u) in self.perm.iter().enumerate() {
            for b in 0..m {
                vt[b * n + i] = v[u * m + b];
            }
        }
    }

    /// Power iteration estimate of the full operator's spectral norm
    /// (inequality rows + equality rows). The norm accumulations are
    /// scalar sums and stay sequential (determinism contract).
    pub fn norm_estimate(&mut self, iters: usize) -> f64 {
        let lp = self.lp;
        let (n, m) = (lp.n, lp.m);
        let mut x = vec![1.0 / ((n * m) as f64).sqrt(); n * m];
        let mut alpha = vec![1.0 / (m as f64).sqrt(); m];
        let mut y = vec![0.0; m * lp.t * lp.dims];
        let mut gx = vec![0.0; n * m];
        let mut ga = vec![0.0; m];
        let mut lam = 1.0;
        for _ in 0..iters {
            // A^T A (x, alpha)
            self.forward(&x, &alpha, &mut y);
            self.adjoint(&y, &mut gx, &mut ga);
            // equality rows: E x (per task), E^T e added to gx
            for u in 0..n {
                let e: f64 = (0..m).map(|b| x[u * m + b]).sum();
                for b in 0..m {
                    gx[u * m + b] += e;
                }
            }
            // alpha columns of A: -sum rho y
            for b in 0..m {
                ga[b] = -ga[b];
            }
            let nrm = (gx.iter().map(|v| v * v).sum::<f64>()
                + ga.iter().map(|v| v * v).sum::<f64>())
            .sqrt()
            .max(1e-30);
            lam = nrm;
            for (xi, gi) in x.iter_mut().zip(&gx) {
                *xi = gi / nrm;
            }
            for (ai, gi) in alpha.iter_mut().zip(&ga) {
                *ai = gi / nrm;
            }
        }
        lam.sqrt().max(1e-12)
    }
}

/// Residuals of an iterate: [eq, ineq, dual, rel_gap].
///
/// The max reductions shard over fixed chunks (exactly associative); the
/// objectives are scalar sums and stay sequential (determinism contract).
pub fn residuals(
    op: &mut Operator,
    x: &[f64],
    alpha: &[f64],
    y: &[f64],
    w: &[f64],
) -> [f64; 4] {
    let lp = op.lp;
    let (n, m) = (lp.n, lp.m);
    let eq = max_by_chunks(&op.team, n, |u| {
        let s: f64 = (0..m).map(|b| x[u * m + b]).sum();
        (s - 1.0).abs()
    });
    let mut buf = vec![0.0; m * lp.t * lp.dims];
    op.forward(x, alpha, &mut buf);
    let ineq = max_by_chunks(&op.team, buf.len(), |i| buf[i]);

    let mut gx = vec![0.0; n * m];
    let mut ga = vec![0.0; m];
    op.adjoint(y, &mut gx, &mut ga);
    let mut dual = max_by_chunks(&op.team, n * m, |i| w[i / m] - gx[i]);
    for b in 0..m {
        dual = dual.max(ga[b] - lp.costs[b]);
    }
    let pobj = lp.objective(alpha);
    let dobj: f64 = w.iter().sum();
    let gap = (pobj - dobj).abs() / (1.0 + pobj.abs() + dobj.abs());
    [eq, ineq.max(0.0), dual.max(0.0), gap]
}

/// A full primal/dual PDHG state retained between solves — what a
/// [`crate::coordinator::session`] keeps alive so an incremental
/// re-solve after a workload delta resumes from the previous optimum
/// instead of iterating from zero. Layouts match [`PdhgResult`]:
/// `x[u*m + b]`, `alpha[b]`, `y[(b*t + ts)*dims + d]`, `w[u]`.
#[derive(Clone, Debug)]
pub struct WarmIterates {
    pub x: Vec<f64>,
    pub alpha: Vec<f64>,
    pub y: Vec<f64>,
    pub w: Vec<f64>,
}

impl WarmIterates {
    /// Do these iterates fit an LP of the given shape?
    pub fn fits_shape(&self, lp: &MappingLp) -> bool {
        self.x.len() == lp.n * lp.m
            && self.alpha.len() == lp.m
            && self.y.len() == lp.m * lp.t * lp.dims
            && self.w.len() == lp.n
    }
}

impl From<&PdhgResult> for WarmIterates {
    fn from(r: &PdhgResult) -> Self {
        WarmIterates { x: r.x.clone(), alpha: r.alpha.clone(), y: r.y.clone(), w: r.w.clone() }
    }
}

/// Resume from retained primal *and* dual iterates (see [`WarmIterates`]).
/// After a small instance perturbation (a handful of tasks admitted,
/// retired or reshaped) the previous optimum is a near-optimal start and
/// convergence takes a fraction of the cold iteration count.
pub fn solve_resume(lp: &MappingLp, opts: &PdhgOptions, warm: &WarmIterates) -> PdhgResult {
    assert!(warm.fits_shape(lp), "warm iterates do not fit the LP shape");
    solve_from(lp, opts, warm.x.clone(), warm.alpha.clone(), warm.y.clone(), warm.w.clone())
}

/// Solve with a warm primal start from an integral mapping: x is the
/// one-hot assignment, alpha its implied congestion peaks. Duals start at
/// zero. Cuts iterations substantially when the heuristic mapping is
/// already near-optimal (see EXPERIMENTS.md section Perf).
pub fn solve_warm(lp: &MappingLp, opts: &PdhgOptions, mapping: &[usize]) -> PdhgResult {
    assert_eq!(mapping.len(), lp.n);
    let mut x0 = vec![0.0; lp.n * lp.m];
    for (u, &b) in mapping.iter().enumerate() {
        x0[u * lp.m + b] = 1.0;
    }
    let mut op = Operator::new(lp);
    let mut kx = vec![0.0; lp.m * lp.t * lp.dims];
    op.forward(&x0, &vec![0.0; lp.m], &mut kx);
    let mut alpha0 = vec![0.0f64; lp.m];
    for b in 0..lp.m {
        for ts in 0..lp.t {
            for d in 0..lp.dims {
                let rho = lp.rho_at(b, d);
                if rho > 0.0 {
                    alpha0[b] = alpha0[b].max(kx[(b * lp.t + ts) * lp.dims + d] / rho);
                }
            }
        }
    }
    let ny = lp.m * lp.t * lp.dims;
    solve_from(lp, opts, x0, alpha0, vec![0.0; ny], vec![0.0; lp.n])
}

/// Solve the mapping LP with chunked, restarted, omega-adaptive PDHG.
pub fn solve(lp: &MappingLp, opts: &PdhgOptions) -> PdhgResult {
    let (n, m) = (lp.n, lp.m);
    let ny = m * lp.t * lp.dims;
    solve_from(lp, opts, vec![0.0; n * m], vec![0.0; m], vec![0.0; ny], vec![0.0; n])
}

/// Restart score: the worst residual, or +inf when any residual is
/// non-finite so a poisoned candidate never wins the restart comparison.
fn restart_score(r: &[f64; 4]) -> f64 {
    if r.iter().all(|v| v.is_finite()) {
        r[0].max(r[1]).max(r[2]).max(r[3])
    } else {
        f64::INFINITY
    }
}

fn solve_from(
    lp: &MappingLp,
    opts: &PdhgOptions,
    x0: Vec<f64>,
    alpha0: Vec<f64>,
    y0: Vec<f64>,
    w0: Vec<f64>,
) -> PdhgResult {
    let (n, m, dims, t) = (lp.n, lp.m, lp.dims, lp.t);
    let mut op = Operator::with_threads(lp, resolve_threads(opts.threads));
    let norm = op.norm_estimate(50);
    let base = 0.9 / norm;
    let mut omega = opts.omega;

    let nm = n * m;
    let ny = m * t * dims;
    assert_eq!(x0.len(), nm);
    assert_eq!(alpha0.len(), m);
    assert_eq!(y0.len(), ny);
    assert_eq!(w0.len(), n);
    // All per-iteration state lives in the operator-internal layout
    // (type-major, start-sorted): no transposes inside the hot loop.
    let mut xt = vec![0.0; nm];
    op.to_internal(&x0, &mut xt);
    let mut alpha = alpha0;
    let mut y = y0;
    let mut wt = vec![0.0; n];
    op.permute_tasks(&w0, &mut wt);

    // scratch (internal layout)
    let mut gxt = vec![0.0; nm];
    let mut ga = vec![0.0; m];
    let mut kx = vec![0.0; ny];
    let mut xbt = vec![0.0; nm];
    let mut ab = vec![0.0; m];
    let mut rows = vec![0.0; n];
    // chunk sums + averages (internal layout)
    let (mut sxt, mut sa) = (vec![0.0; nm], vec![0.0; m]);
    let (mut sy, mut swt) = (vec![0.0; ny], vec![0.0; n]);
    let (mut axt, mut aa) = (vec![0.0; nm], vec![0.0; m]);
    let (mut ay, mut awt) = (vec![0.0; ny], vec![0.0; n]);
    // public-layout buffers for chunk-boundary residuals
    let mut xp = vec![0.0; nm];
    let mut wp = vec![0.0; n];

    let task_chunks = n_chunks(n);
    let y_chunks = n_chunks(ny);

    let mut iter = 0usize;
    let mut res = [f64::INFINITY; 4];
    let mut converged = false;

    while iter < opts.max_iters {
        let tau = base * omega;
        let sigma = base / omega;
        sxt.fill(0.0);
        sa.fill(0.0);
        sy.fill(0.0);
        swt.fill(0.0);
        let chunk = opts.chunk.min(opts.max_iters - iter);
        for _ in 0..chunk {
            // primal step (fused: update + extrapolate + average + row
            // sums), sharded over task-index chunks: chunk c owns index
            // i across every type row (xt/xbt/sxt at b*n+i, rows[i]),
            // with the row sum accumulated b-ascending in a local — the
            // sequential reference's exact per-element order.
            op.adjoint_tm(&y, &mut gxt, &mut ga);
            {
                let xt_ds = DisjointSlice::new(&mut xt);
                let xbt_ds = DisjointSlice::new(&mut xbt);
                let sxt_ds = DisjointSlice::new(&mut sxt);
                let rows_ds = DisjointSlice::new(&mut rows);
                let gxt_ref: &[f64] = &gxt;
                let wt_ref: &[f64] = &wt;
                op.team.run_blocks(task_chunks, |c| {
                    let lo = c * TASK_CHUNK;
                    let hi = (lo + TASK_CHUNK).min(n);
                    for i in lo..hi {
                        let mut row = 0.0;
                        for b in 0..m {
                            let j = b * n + i;
                            // SAFETY: chunk c owns index i in every row.
                            unsafe {
                                let old = xt_ds.get(j);
                                let v = old - tau * (gxt_ref[j] - wt_ref[i]);
                                let v = if v > 0.0 { v } else { 0.0 };
                                let xb = 2.0 * v - old;
                                xbt_ds.set(j, xb);
                                row += xb;
                                xt_ds.set(j, v);
                                sxt_ds.set(j, sxt_ds.get(j) + v);
                            }
                        }
                        // SAFETY: row slot i is owned by chunk c.
                        unsafe { rows_ds.set(i, row) };
                    }
                });
            }
            for b in 0..m {
                let v = alpha[b] - tau * (lp.costs[b] - ga[b]);
                let v = if v > 0.0 { v } else { 0.0 };
                ab[b] = 2.0 * v - alpha[b];
                alpha[b] = v;
                sa[b] += v;
            }
            // dual step on extrapolated point (fused y update + average),
            // elementwise over fixed chunks
            op.forward_tm(&xbt, &ab, &mut kx);
            {
                let y_ds = DisjointSlice::new(&mut y);
                let sy_ds = DisjointSlice::new(&mut sy);
                let kx_ref: &[f64] = &kx;
                op.team.run_blocks(y_chunks, |c| {
                    let lo = c * TASK_CHUNK;
                    let hi = (lo + TASK_CHUNK).min(ny);
                    for i in lo..hi {
                        // SAFETY: chunk c owns indices lo..hi.
                        unsafe {
                            let v = y_ds.get(i) + sigma * kx_ref[i];
                            let v = if v > 0.0 { v } else { 0.0 };
                            y_ds.set(i, v);
                            sy_ds.set(i, sy_ds.get(i) + v);
                        }
                    }
                });
            }
            {
                let wt_ds = DisjointSlice::new(&mut wt);
                let swt_ds = DisjointSlice::new(&mut swt);
                let rows_ref: &[f64] = &rows;
                op.team.run_blocks(task_chunks, |c| {
                    let lo = c * TASK_CHUNK;
                    let hi = (lo + TASK_CHUNK).min(n);
                    for i in lo..hi {
                        // SAFETY: chunk c owns indices lo..hi.
                        unsafe {
                            let v = wt_ds.get(i) + sigma * (1.0 - rows_ref[i]);
                            wt_ds.set(i, v);
                            swt_ds.set(i, swt_ds.get(i) + v);
                        }
                    }
                });
            }
            iter += 1;
        }
        // chunk boundary: evaluate last vs average, restart from the better
        let k = chunk as f64;
        div_into(&op.team, &sxt, k, &mut axt);
        for b in 0..m {
            aa[b] = sa[b] / k;
        }
        div_into(&op.team, &sy, k, &mut ay);
        div_into(&op.team, &swt, k, &mut awt);

        op.to_public(&xt, &mut xp);
        op.unpermute_tasks(&wt, &mut wp);
        let r_last = residuals(&mut op, &xp, &alpha, &y, &wp);
        op.to_public(&axt, &mut xp);
        op.unpermute_tasks(&awt, &mut wp);
        let r_avg = residuals(&mut op, &xp, &aa, &ay, &wp);
        if restart_score(&r_avg) < restart_score(&r_last) {
            xt.copy_from_slice(&axt);
            alpha.copy_from_slice(&aa);
            y.copy_from_slice(&ay);
            wt.copy_from_slice(&awt);
            res = r_avg;
        } else {
            res = r_last;
        }
        if res.iter().all(|v| v.is_finite())
            && res[0].max(res[1]) <= opts.tol
            && res[3] <= opts.gap_tol
        {
            converged = true;
            break;
        }
        // optional primal-weight adaptation (ablation shows the restart
        // scheme alone converges faster on the mapping LP; default off)
        if opts.adapt_omega {
            omega = adapt_omega(omega, res[0].max(res[1]), res[2]);
        }
    }

    let mut x = vec![0.0; nm];
    let mut w = vec![0.0; n];
    op.to_public(&xt, &mut x);
    op.unpermute_tasks(&wt, &mut w);
    let objective = lp.objective(&alpha);
    PdhgResult { x, alpha, y, w, objective, iterations: iter, converged, residuals: res }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};
    use crate::lp::simplex;
    use crate::model::trim;

    fn small_lp(seed: u64, n: usize, m: usize, dims: usize, horizon: u32) -> MappingLp {
        let inst = generate(
            &SynthParams { n, m, dims, horizon, dem_range: (0.05, 0.3), ..Default::default() },
            seed,
        );
        let tr = trim(&inst);
        MappingLp::from_instance(&tr.instance)
    }

    #[test]
    fn operator_adjointness() {
        // <K x, y> == <x, K^T y> for random vectors
        use crate::util::rng::Rng;
        let lp = small_lp(1, 10, 3, 2, 8);
        let mut op = Operator::new(&lp);
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..lp.n * lp.m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let y: Vec<f64> = (0..lp.m * lp.t * lp.dims).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let alpha = vec![0.0; lp.m];
        let mut kx = vec![0.0; y.len()];
        op.forward(&x, &alpha, &mut kx);
        let lhs: f64 = kx.iter().zip(&y).map(|(a, b)| a * b).sum();
        let mut gx = vec![0.0; x.len()];
        let mut ga = vec![0.0; lp.m];
        op.adjoint(&y, &mut gx, &mut ga);
        let rhs: f64 = gx.iter().zip(&x).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-9 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
    }

    #[test]
    fn matches_simplex_on_small() {
        for seed in [0, 1, 2] {
            let lp = small_lp(seed, 8, 2, 2, 6);
            let exact = simplex::solve(&lp.to_dense());
            assert_eq!(exact.status, simplex::SimplexStatus::Optimal);
            let r = solve(&lp, &PdhgOptions { tol: 1e-7, gap_tol: 1e-7, ..Default::default() });
            assert!(r.converged, "seed {seed}: not converged {:?}", r.residuals);
            let rel = (r.objective - exact.objective).abs() / (1.0 + exact.objective.abs());
            assert!(rel < 1e-4, "seed {seed}: pdhg {} vs simplex {}", r.objective, exact.objective);
        }
    }

    #[test]
    fn shaped_operator_adjointness_and_optimum() {
        use crate::model::{DemandSeg, Instance, NodeType, Task};
        use crate::util::rng::Rng;
        // piecewise tasks: the operator applies per-segment coefficients
        let inst = Instance::new(
            vec![
                Task::piecewise(
                    0,
                    vec![
                        DemandSeg { start: 0, end: 2, demand: vec![0.1, 0.3] },
                        DemandSeg { start: 3, end: 5, demand: vec![0.4, 0.1] },
                    ],
                ),
                Task::new(1, vec![0.2, 0.2], 1, 4),
                Task::piecewise(
                    2,
                    vec![
                        DemandSeg { start: 2, end: 3, demand: vec![0.3, 0.05] },
                        DemandSeg { start: 4, end: 5, demand: vec![0.05, 0.3] },
                    ],
                ),
            ],
            vec![
                NodeType::new("a", vec![1.0, 1.0], 2.0),
                NodeType::new("b", vec![0.6, 0.6], 1.0),
            ],
            6,
        );
        let lp = MappingLp::from_instance(&trim(&inst).instance);
        assert!(!lp.is_flat());
        // <K x, y> == <x, K^T y>
        let mut op = Operator::new(&lp);
        let mut rng = Rng::new(6);
        let x: Vec<f64> = (0..lp.n * lp.m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let y: Vec<f64> =
            (0..lp.m * lp.t * lp.dims).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let alpha = vec![0.0; lp.m];
        let mut kx = vec![0.0; y.len()];
        op.forward(&x, &alpha, &mut kx);
        let lhs: f64 = kx.iter().zip(&y).map(|(a, b)| a * b).sum();
        let mut gx = vec![0.0; x.len()];
        let mut ga = vec![0.0; lp.m];
        op.adjoint(&y, &mut gx, &mut ga);
        let rhs: f64 = gx.iter().zip(&x).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-9 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
        // forward against a hand-built dense K x at a one-hot x
        let mut x1 = vec![0.0; lp.n * lp.m];
        for u in 0..lp.n {
            x1[u * lp.m] = 1.0; // everything on type 0
        }
        op.forward(&x1, &vec![0.0; lp.m], &mut kx);
        let dense = lp.to_dense();
        for ts in 0..lp.t {
            for d in 0..lp.dims {
                // recompute congestion at (type 0, ts, d) from segments
                let mut want = 0.0;
                for u in 0..lp.n {
                    for s in lp.segs_of(u) {
                        let (ss, se) = lp.seg_spans[s];
                        if ts as u32 >= ss && ts as u32 <= se {
                            want += lp.seg_ratio(s, 0, d);
                        }
                    }
                }
                let got = kx[(0 * lp.t + ts) * lp.dims + d];
                assert!((got - want).abs() < 1e-12, "ts {ts} d {d}: {got} vs {want}");
            }
        }
        // PDHG matches the exact simplex optimum on the shaped LP
        let exact = simplex::solve(&dense);
        assert_eq!(exact.status, simplex::SimplexStatus::Optimal);
        let r = solve(&lp, &PdhgOptions { tol: 1e-7, gap_tol: 1e-7, ..Default::default() });
        assert!(r.converged, "{:?}", r.residuals);
        let rel = (r.objective - exact.objective).abs() / (1.0 + exact.objective.abs());
        assert!(rel < 1e-4, "pdhg {} vs simplex {}", r.objective, exact.objective);
    }

    #[test]
    fn converges_on_medium() {
        let lp = small_lp(3, 60, 5, 3, 12);
        let r = solve(&lp, &PdhgOptions::default());
        assert!(r.converged, "residuals {:?}", r.residuals);
        assert!(r.objective > 0.0);
    }

    #[test]
    fn dual_never_exceeds_primal_at_tolerance() {
        let lp = small_lp(4, 20, 3, 2, 8);
        let r = solve(&lp, &PdhgOptions::default());
        let dobj: f64 = r.w.iter().sum();
        assert!(dobj <= r.objective + 1e-3 * (1.0 + r.objective));
    }

    #[test]
    fn resume_from_retained_iterates_converges_fast() {
        let lp = small_lp(8, 40, 4, 3, 10);
        let cold = solve(&lp, &PdhgOptions::default());
        assert!(cold.converged);
        // resuming at the optimum needs at most a chunk to re-certify
        let warm = WarmIterates::from(&cold);
        let r = solve_resume(&lp, &PdhgOptions::default(), &warm);
        assert!(r.converged, "{:?}", r.residuals);
        assert!(
            r.iterations <= cold.iterations,
            "resume {} iters vs cold {}",
            r.iterations,
            cold.iterations
        );
        let rel = (r.objective - cold.objective).abs() / (1.0 + cold.objective.abs());
        assert!(rel < 1e-3, "resume {} vs cold {}", r.objective, cold.objective);
        // shape mismatches are a programmer error, caught loudly
        let bad = WarmIterates { x: vec![0.0; 3], ..warm.clone() };
        assert!(!bad.fits_shape(&lp));
    }

    #[test]
    fn row_scaling_preserves_optimum() {
        let mut lp = small_lp(5, 15, 3, 2, 8);
        let r0 = solve(&lp, &PdhgOptions::default());
        for v in lp.rho.iter_mut() {
            *v = 0.37;
        }
        let r1 = solve(&lp, &PdhgOptions::default());
        let rel = (r0.objective - r1.objective).abs() / (1.0 + r0.objective);
        assert!(rel < 5e-4, "{} vs {}", r0.objective, r1.objective);
    }

    #[test]
    fn adapt_omega_guards_nonfinite_and_zero_ratios() {
        // a converged dual chunk: near-zero dual residual must not blow
        // omega up past its clamp (ratio saturates at 2.0)
        let w = adapt_omega(1.0, 1e-3, 0.0);
        assert!(w.is_finite());
        assert_eq!(w, 2.0);
        // both residuals at machine zero: ratio is exactly 1, omega holds
        assert_eq!(adapt_omega(1.0, 0.0, 0.0), 1.0);
        // non-finite residuals pass omega through untouched instead of
        // poisoning tau/sigma with NaN/inf
        assert_eq!(adapt_omega(0.7, f64::NAN, 1.0), 0.7);
        assert_eq!(adapt_omega(0.7, 1.0, f64::NAN), 0.7);
        assert_eq!(adapt_omega(0.7, f64::INFINITY, 1.0), 0.7);
        assert_eq!(adapt_omega(0.7, 1.0, f64::INFINITY), 0.7);
        // clamps still apply on the finite path
        assert_eq!(adapt_omega(1e3, 1.0, 1e-12), 1e3);
        assert_eq!(adapt_omega(1e-3, 1e-12, 1.0), 1e-3);
        // and a solve with adaptation on still converges
        let lp = small_lp(9, 30, 3, 2, 8);
        let r = solve(&lp, &PdhgOptions { adapt_omega: true, ..Default::default() });
        assert!(r.converged, "{:?}", r.residuals);
    }

    #[test]
    fn fits_shape_rejects_shrunk_and_reshaped_instances() {
        // a session keeps WarmIterates across deltas; a retire that
        // shrinks n or a reshape that changes the trimmed horizon must
        // fail fits_shape so callers fall back to a cold solve
        let lp = small_lp(11, 12, 3, 2, 8);
        let cold = solve(&lp, &PdhgOptions::default());
        let warm = WarmIterates::from(&cold);
        assert!(warm.fits_shape(&lp));
        // retire: fewer tasks
        let lp_small = small_lp(11, 9, 3, 2, 8);
        assert!(!warm.fits_shape(&lp_small));
        // reshape: same tasks, different trimmed horizon (t changes)
        let lp_long = small_lp(11, 12, 3, 2, 16);
        if lp_long.t != lp.t {
            assert!(!warm.fits_shape(&lp_long));
        }
        // the fallback path is a clean cold solve, no panic/misindex
        let r = if warm.fits_shape(&lp_small) {
            solve_resume(&lp_small, &PdhgOptions::default(), &warm)
        } else {
            solve(&lp_small, &PdhgOptions::default())
        };
        assert!(r.converged, "{:?}", r.residuals);
    }

    #[test]
    fn parallel_operator_matches_sequential_bitwise() {
        use crate::util::rng::Rng;
        // big enough to clear the PAR_MIN_NM gate so threads really engage
        let lp = small_lp(7, 2000, 3, 2, 10);
        assert!(lp.n * lp.m >= PAR_MIN_NM);
        let mut op1 = Operator::with_threads(&lp, 1);
        let mut op4 = Operator::with_threads(&lp, 4);
        assert_eq!(op1.threads(), 1);
        assert_eq!(op4.threads(), 4);
        assert_eq!(op1.ratios_bd, op4.ratios_bd);
        let mut rng = Rng::new(3);
        let x: Vec<f64> = (0..lp.n * lp.m).map(|_| rng.uniform(0.0, 1.0)).collect();
        let alpha: Vec<f64> = (0..lp.m).map(|_| rng.uniform(0.0, 2.0)).collect();
        let y: Vec<f64> =
            (0..lp.m * lp.t * lp.dims).map(|_| rng.uniform(0.0, 1.0)).collect();
        let mut kx1 = vec![0.0; y.len()];
        let mut kx4 = vec![0.0; y.len()];
        op1.forward(&x, &alpha, &mut kx1);
        op4.forward(&x, &alpha, &mut kx4);
        for (a, b) in kx1.iter().zip(&kx4) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let (mut gx1, mut ga1) = (vec![0.0; x.len()], vec![0.0; lp.m]);
        let (mut gx4, mut ga4) = (vec![0.0; x.len()], vec![0.0; lp.m]);
        op1.adjoint(&y, &mut gx1, &mut ga1);
        op4.adjoint(&y, &mut gx4, &mut ga4);
        for (a, b) in gx1.iter().zip(&gx4).chain(ga1.iter().zip(&ga4)) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // a short bounded solve is bit-identical end to end
        let opts1 = PdhgOptions { max_iters: 500, threads: 1, ..Default::default() };
        let opts4 = PdhgOptions { max_iters: 500, threads: 4, ..Default::default() };
        let r1 = solve(&lp, &opts1);
        let r4 = solve(&lp, &opts4);
        assert_eq!(r1.iterations, r4.iterations);
        assert_eq!(r1.converged, r4.converged);
        assert_eq!(r1.objective.to_bits(), r4.objective.to_bits());
        for (a, b) in r1.x.iter().zip(&r4.x) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in r1.y.iter().zip(&r4.y) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in r1.w.iter().zip(&r4.w) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for i in 0..4 {
            assert_eq!(r1.residuals[i].to_bits(), r4.residuals[i].to_bits());
        }
    }
}
