//! General dense LP representation (used by the exact simplex substrate
//! and for cross-checking the structured PDHG solvers on small instances).
//!
//! ```text
//!     min  c·x
//!     s.t. A_ub x <= b_ub
//!          A_eq x == b_eq
//!          x >= 0
//! ```

/// Dense row-major matrix.
#[derive(Clone, Debug, Default)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// A dense LP in inequality/equality form with non-negative variables.
#[derive(Clone, Debug, Default)]
pub struct DenseLp {
    pub c: Vec<f64>,
    pub a_ub: Matrix,
    pub b_ub: Vec<f64>,
    pub a_eq: Matrix,
    pub b_eq: Vec<f64>,
}

impl DenseLp {
    pub fn n_vars(&self) -> usize {
        self.c.len()
    }

    /// Objective value of a candidate point.
    pub fn objective(&self, x: &[f64]) -> f64 {
        self.c.iter().zip(x).map(|(a, b)| a * b).sum()
    }

    /// Max constraint violation of a candidate point (feasibility check).
    pub fn max_violation(&self, x: &[f64]) -> f64 {
        let mut v: f64 = 0.0;
        for r in 0..self.a_ub.rows {
            let lhs: f64 = self.a_ub.row(r).iter().zip(x).map(|(a, b)| a * b).sum();
            v = v.max(lhs - self.b_ub[r]);
        }
        for r in 0..self.a_eq.rows {
            let lhs: f64 = self.a_eq.row(r).iter().zip(x).map(|(a, b)| a * b).sum();
            v = v.max((lhs - self.b_eq[r]).abs());
        }
        for &xi in x {
            v = v.max(-xi);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_access() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn violation_and_objective() {
        // min x0 s.t. x0 + x1 <= 1, x0 == 0.25
        let mut lp = DenseLp {
            c: vec![1.0, 0.0],
            a_ub: Matrix::zeros(1, 2),
            b_ub: vec![1.0],
            a_eq: Matrix::zeros(1, 2),
            b_eq: vec![0.25],
        };
        lp.a_ub.set(0, 0, 1.0);
        lp.a_ub.set(0, 1, 1.0);
        lp.a_eq.set(0, 0, 1.0);
        assert_eq!(lp.objective(&[0.25, 0.5]), 0.25);
        assert!(lp.max_violation(&[0.25, 0.5]) < 1e-12);
        assert!(lp.max_violation(&[0.5, 0.9]) > 0.2);
    }
}
