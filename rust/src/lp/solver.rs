//! Backend abstraction for solving the mapping LP.
//!
//! Three interchangeable backends, cross-checked in tests:
//!   - `NativePdhgSolver`: f64 PDHG with the sparse interval operator,
//!   - `SimplexSolver`: exact dense simplex (small instances only),
//!   - `runtime::ArtifactSolver`: the AOT JAX/Pallas PDHG artifact run
//!     through PJRT (the paper-system production path).

use anyhow::Result;

use super::builder::MappingLp;
use super::pdhg::{self, PdhgOptions};
use super::simplex::{self, SimplexStatus};

/// Fractional mapping-LP solution returned by any backend.
#[derive(Clone, Debug)]
pub struct MappingSolution {
    /// x[u*m + b] fractional assignment.
    pub x: Vec<f64>,
    /// Inequality duals (scaled rows), layout (b*t + ts)*dims + d.
    /// May be empty for backends that do not expose duals.
    pub y: Vec<f64>,
    pub objective: f64,
    pub converged: bool,
    pub iterations: usize,
}

pub trait MappingSolver {
    fn solve_mapping(&self, lp: &MappingLp) -> Result<MappingSolution>;
    /// Short backend name for reports.
    fn name(&self) -> &'static str;
    /// Resolved worker-thread count this backend solves with. The LP
    /// build / certified-bound passes around a solve use the same count
    /// so one knob governs the whole mapping path. Results are
    /// bit-identical for every value (see `lp::pdhg`); backends without
    /// parallel kernels stay at 1.
    fn lp_threads(&self) -> usize {
        1
    }
}

/// Native f64 PDHG backend (default production path for large T).
pub struct NativePdhgSolver {
    pub opts: PdhgOptions,
}

impl Default for NativePdhgSolver {
    fn default() -> Self {
        NativePdhgSolver { opts: PdhgOptions::default() }
    }
}

impl MappingSolver for NativePdhgSolver {
    fn solve_mapping(&self, lp: &MappingLp) -> Result<MappingSolution> {
        let r = pdhg::solve(lp, &self.opts);
        Ok(MappingSolution {
            x: r.x,
            y: r.y,
            objective: r.objective,
            converged: r.converged,
            iterations: r.iterations,
        })
    }

    fn name(&self) -> &'static str {
        "pdhg-native"
    }

    fn lp_threads(&self) -> usize {
        pdhg::resolve_threads(self.opts.threads)
    }
}

impl NativePdhgSolver {
    /// Backend with an explicit thread knob (0 = auto).
    pub fn with_threads(threads: usize) -> Self {
        NativePdhgSolver { opts: PdhgOptions { threads, ..Default::default() } }
    }
}

/// Exact simplex backend. Cost is exponential-ish in practice on large
/// dense tableaus — use for tests and tiny instances only.
pub struct SimplexSolver;

impl MappingSolver for SimplexSolver {
    fn solve_mapping(&self, lp: &MappingLp) -> Result<MappingSolution> {
        let r = simplex::solve(&lp.to_dense());
        if r.status != SimplexStatus::Optimal {
            anyhow::bail!("simplex: {:?}", r.status);
        }
        let nm = lp.n * lp.m;
        Ok(MappingSolution {
            x: r.x[..nm].to_vec(),
            y: Vec::new(),
            objective: r.objective,
            converged: true,
            iterations: 0,
        })
    }

    fn name(&self) -> &'static str {
        "simplex"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};
    use crate::model::trim;

    #[test]
    fn backends_agree() {
        let inst = generate(
            &SynthParams { n: 10, m: 3, dims: 2, horizon: 6, dem_range: (0.05, 0.3), ..Default::default() },
            11,
        );
        let lp = MappingLp::from_instance(&trim(&inst).instance);
        let a = NativePdhgSolver::default().solve_mapping(&lp).unwrap();
        let b = SimplexSolver.solve_mapping(&lp).unwrap();
        let rel = (a.objective - b.objective).abs() / (1.0 + b.objective);
        assert!(rel < 1e-3, "pdhg {} vs simplex {}", a.objective, b.objective);
    }
}
