//! Certified lower bounds on the mapping-LP optimum (and hence, by the
//! paper's Lemma 1 argument generalized in section V-B, on cost(opt)).
//!
//! PDHG returns approximately-feasible duals. We repair them into an
//! exactly-feasible dual point in f64 and evaluate the dual objective:
//!
//! ```text
//!     max  sum_u w_u
//!     s.t. y >= 0
//!          sum_{t,d} rho*y[B,t,d] <= cost(B)          (alpha columns)
//!          w_u <= sum over span of rho*y . r          (x columns)
//! ```
//!
//! Repair: clip y at 0; scale each B's block down if its alpha-column
//! constraint is violated; then set w_u to its largest feasible value
//! (min over B of the x-column expression). Every reported
//! "normalized cost" in the harness divides by a bound certified here —
//! never by the raw approximate LP objective.

use super::builder::MappingLp;
use crate::model::Instance;

/// Repair `y` into dual-feasible and return the certified bound
/// `sum_u w_u` together with the repaired `w`.
pub fn certified_bound(lp: &MappingLp, y: &[f64]) -> (f64, Vec<f64>) {
    certified_bound_par(lp, y, 1)
}

/// [`certified_bound`] with the dominant O(S·m·D) per-task repair pass
/// sharded over up to `threads` workers. Deterministic-reduction
/// contract: the (b,d) prefix rows and each task's `w[u]` are exclusive
/// blocks computed in the serial reference's per-element order, and the
/// scale pass plus the final dual objective are scalar sums that stay
/// sequential — so the bound is bit-identical for every thread count.
pub fn certified_bound_par(lp: &MappingLp, y: &[f64], threads: usize) -> (f64, Vec<f64>) {
    use super::pdhg::{n_chunks, DisjointSlice, PAR_MIN_NM, TASK_CHUNK};
    use crate::util::pool::Team;
    let (n, m, dims, t) = (lp.n, lp.m, lp.dims, lp.t);
    debug_assert_eq!(y.len(), m * t * dims);
    let threads = if n * m < PAR_MIN_NM { 1 } else { threads.max(1) };
    let team = Team::new(threads);

    // per-B scale so that sum_{t,d} rho*y <= cost(B) — scalar sums,
    // sequential per the determinism contract
    let mut scale = vec![1.0f64; m];
    for b in 0..m {
        let mut s = 0.0;
        for ts in 0..t {
            for d in 0..dims {
                let v = y[(b * t + ts) * dims + d].max(0.0);
                s += lp.rho_at(b, d) * v;
            }
        }
        if s > lp.costs[b] {
            scale[b] = if s > 0.0 { lp.costs[b] / s } else { 0.0 };
        }
    }

    // prefix sums of the repaired rho*y per (b, d): each (b,d) row is an
    // exclusive block, sequential within the row
    let mut pref = vec![0.0f64; m * dims * (t + 1)];
    {
        let ds = DisjointSlice::new(&mut pref);
        let scale_ref: &[f64] = &scale;
        team.run_blocks(m * dims, |k| {
            let (b, d) = (k / dims, k % dims);
            debug_assert!(k < m * dims, "block id within the prefix table");
            // SAFETY: prefix row k is exclusive to block k.
            let row = unsafe { ds.slice_mut(k * (t + 1), t + 1) };
            for ts in 0..t {
                let v = y[(b * t + ts) * dims + d].max(0.0) * scale_ref[b];
                row[ts + 1] = row[ts] + lp.rho_at(b, d) * v;
            }
        });
    }

    let mut w = vec![0.0f64; n];
    {
        let ds = DisjointSlice::new(&mut w);
        let pref_ref: &[f64] = &pref;
        team.run_blocks(n_chunks(n), |c| {
            let lo = c * TASK_CHUNK;
            let hi = (lo + TASK_CHUNK).min(n);
            for u in lo..hi {
                let mut best = f64::INFINITY;
                for b in 0..m {
                    let mut acc = 0.0;
                    for d in 0..dims {
                        let base = (b * dims + d) * (t + 1);
                        // per-slot coefficients: the x-column of task u
                        // sums rho*y weighted by the demand segment
                        // covering each slot
                        for s in lp.segs_of(u) {
                            let (ss, se) = lp.seg_spans[s];
                            acc += (pref_ref[base + se as usize + 1]
                                - pref_ref[base + ss as usize])
                                * lp.seg_ratio(s, b, d);
                        }
                    }
                    best = best.min(acc);
                }
                // w may be any real; only positive contributions help the
                // bound, but we keep the exact min to report a true dual
                // point.
                debug_assert!(u < n, "task index within the dual vector");
                // SAFETY: w[u] is owned by the chunk owning u.
                unsafe { ds.set(u, best) };
            }
        });
    }
    // dual objective: scalar sum, sequential in ascending u — the serial
    // reference's exact accumulation order
    let total: f64 = w.iter().sum();
    (total, w)
}

/// Combinatorial congestion lower bound (paper Lemma 1): the maximum over
/// timeslots of the aggregate minimum penalty of active tasks,
/// `max_t sum_{u~t} p*_avg(u, t)`. With shaped tasks the per-slot penalty
/// uses the demand of the segment covering the slot (Lemma 1's argument
/// is per-timeslot, so the bound stays exact). Cheap (no LP solve) and
/// used as a sanity floor alongside the certified dual bound.
pub fn congestion_bound(lp: &MappingLp) -> f64 {
    let (n, m, dims, t) = (lp.n, lp.m, lp.dims, lp.t);
    let mut diff = vec![0.0f64; t + 1];
    for u in 0..n {
        for s in lp.segs_of(u) {
            let mut pstar = f64::INFINITY;
            for b in 0..m {
                let h: f64 =
                    (0..dims).map(|d| lp.seg_ratio(s, b, d)).sum::<f64>() / dims as f64;
                pstar = pstar.min(lp.costs[b] * h);
            }
            let (ss, se) = lp.seg_spans[s];
            diff[ss as usize] += pstar;
            diff[se as usize + 1] -= pstar;
        }
    }
    let mut acc = 0.0;
    let mut best: f64 = 0.0;
    for ts in 0..t {
        acc += diff[ts];
        best = best.max(acc);
    }
    best
}

/// [`congestion_bound`] computed straight from the instance, without
/// materializing a [`MappingLp`]. The LP stores every per-(segment,
/// node-type, dimension) ratio up front — n·S·m·D doubles, hundreds of
/// megabytes at n = 10^6 — but Lemma 1 only ever *sums* those ratios
/// once, so decomposed solves derive them on the fly. Iteration order
/// and arithmetic mirror [`congestion_bound`] operation-for-operation
/// (the stored ratio is the same single division), so the two agree
/// bit-for-bit; equilibration doesn't enter (it only rescales `rho`,
/// which Lemma 1 never reads).
pub fn congestion_bound_instance(inst: &Instance) -> f64 {
    let m = inst.n_types();
    let dims = inst.dims();
    let t = inst.horizon as usize;
    let mut diff = vec![0.0f64; t + 1];
    for task in &inst.tasks {
        for seg in task.segments() {
            let mut pstar = f64::INFINITY;
            for b in 0..m {
                let nt = &inst.node_types[b];
                let h: f64 = (0..dims)
                    .map(|d| seg.demand[d] / nt.capacity[d])
                    .sum::<f64>()
                    / dims as f64;
                pstar = pstar.min(nt.cost * h);
            }
            diff[seg.start as usize] += pstar;
            diff[seg.end as usize + 1] -= pstar;
        }
    }
    let mut acc = 0.0;
    let mut best: f64 = 0.0;
    for ts in 0..t {
        acc += diff[ts];
        best = best.max(acc);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};
    use crate::lp::pdhg::{self, PdhgOptions};
    use crate::lp::{scaling, simplex};
    use crate::model::trim;

    fn lp_for(seed: u64, n: usize) -> MappingLp {
        let inst = generate(
            &SynthParams { n, m: 3, dims: 2, horizon: 8, dem_range: (0.05, 0.3), ..Default::default() },
            seed,
        );
        MappingLp::from_instance(&trim(&inst).instance)
    }

    #[test]
    fn certified_bound_is_valid() {
        for seed in [0, 1, 2] {
            let mut lp = lp_for(seed, 10);
            scaling::equilibrate(&mut lp);
            let exact = simplex::solve(&lp.to_dense());
            let r = pdhg::solve(&lp, &PdhgOptions::default());
            let (lb, _w) = certified_bound(&lp, &r.y);
            assert!(
                lb <= exact.objective + 1e-7 * (1.0 + exact.objective),
                "seed {seed}: lb {lb} > opt {}",
                exact.objective
            );
            // and it should be tight at convergence
            assert!(
                lb >= exact.objective * 0.98 - 1e-6,
                "seed {seed}: lb {lb} too loose vs {}",
                exact.objective
            );
        }
    }

    #[test]
    fn congestion_bound_below_lp() {
        for seed in [3, 4] {
            let lp = lp_for(seed, 12);
            let exact = simplex::solve(&lp.to_dense());
            let cong = congestion_bound(&lp);
            assert!(cong <= exact.objective + 1e-7, "cong {cong} vs lp {}", exact.objective);
            assert!(cong > 0.0);
        }
    }

    #[test]
    fn shaped_bounds_stay_valid() {
        use crate::model::{DemandSeg, Instance, NodeType, Task};
        // piecewise tasks: the certified bound and the congestion bound
        // must still lower-bound the per-slot LP optimum
        let inst = Instance::new(
            vec![
                Task::piecewise(
                    0,
                    vec![
                        DemandSeg { start: 0, end: 2, demand: vec![0.1, 0.25] },
                        DemandSeg { start: 3, end: 5, demand: vec![0.3, 0.05] },
                    ],
                ),
                Task::new(1, vec![0.2, 0.2], 1, 4),
                Task::piecewise(
                    2,
                    vec![
                        DemandSeg { start: 2, end: 3, demand: vec![0.25, 0.1] },
                        DemandSeg { start: 4, end: 5, demand: vec![0.05, 0.3] },
                    ],
                ),
            ],
            vec![
                NodeType::new("a", vec![1.0, 1.0], 2.0),
                NodeType::new("b", vec![0.5, 0.5], 1.0),
            ],
            6,
        );
        let mut lp = MappingLp::from_instance(&trim(&inst).instance);
        scaling::equilibrate(&mut lp);
        let exact = simplex::solve(&lp.to_dense());
        assert_eq!(exact.status, simplex::SimplexStatus::Optimal);
        let r = pdhg::solve(&lp, &PdhgOptions::default());
        let (lb, _) = certified_bound(&lp, &r.y);
        assert!(
            lb <= exact.objective + 1e-7 * (1.0 + exact.objective),
            "lb {lb} > shaped optimum {}",
            exact.objective
        );
        assert!(lb > 0.0);
        let cong = congestion_bound(&lp);
        assert!(cong <= exact.objective + 1e-7, "cong {cong} vs {}", exact.objective);
        assert!(cong > 0.0);
    }

    #[test]
    fn instance_congestion_matches_lp_congestion_bitwise() {
        use crate::model::{DemandSeg, Instance, NodeType, Task};
        for seed in [6, 7, 8] {
            let inst = generate(
                &SynthParams { n: 60, m: 4, dims: 3, ..Default::default() },
                seed,
            );
            let tr = trim(&inst).instance;
            let mut lp = MappingLp::from_instance(&tr);
            let want = congestion_bound(&lp);
            assert_eq!(
                want.to_bits(),
                congestion_bound_instance(&tr).to_bits(),
                "seed {seed}"
            );
            // equilibration must not move the congestion bound
            scaling::equilibrate(&mut lp);
            assert_eq!(want.to_bits(), congestion_bound(&lp).to_bits());
        }
        // shaped tasks: per-segment penalties, same agreement
        let inst = Instance::new(
            vec![
                Task::piecewise(
                    0,
                    vec![
                        DemandSeg { start: 0, end: 2, demand: vec![0.1, 0.25] },
                        DemandSeg { start: 3, end: 5, demand: vec![0.3, 0.05] },
                    ],
                ),
                Task::new(1, vec![0.2, 0.2], 1, 4),
            ],
            vec![
                NodeType::new("a", vec![1.0, 1.0], 2.0),
                NodeType::new("b", vec![0.5, 0.5], 1.0),
            ],
            6,
        );
        let tr = trim(&inst).instance;
        let lp = MappingLp::from_instance(&tr);
        assert_eq!(
            congestion_bound(&lp).to_bits(),
            congestion_bound_instance(&tr).to_bits()
        );
    }

    #[test]
    fn parallel_certified_bound_matches_serial_bitwise() {
        use crate::util::rng::Rng;
        // n*m clears the parallel gate so the team really engages
        let lp = lp_for(9, 2000);
        let mut rng = Rng::new(11);
        let y: Vec<f64> =
            (0..lp.m * lp.t * lp.dims).map(|_| rng.uniform(-0.5, 1.5)).collect();
        let (t1, w1) = certified_bound(&lp, &y);
        for threads in [2, 4, 8] {
            let (tp, wp) = certified_bound_par(&lp, &y, threads);
            assert_eq!(t1.to_bits(), tp.to_bits(), "threads {threads}");
            assert_eq!(w1.len(), wp.len());
            for (a, b) in w1.iter().zip(&wp) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads {threads}");
            }
        }
    }

    #[test]
    fn garbage_duals_still_give_valid_bound() {
        use crate::util::rng::Rng;
        let lp = lp_for(5, 10);
        let exact = simplex::solve(&lp.to_dense());
        let mut rng = Rng::new(9);
        let y: Vec<f64> = (0..lp.m * lp.t * lp.dims).map(|_| rng.uniform(-1.0, 2.0)).collect();
        let (lb, _) = certified_bound(&lp, &y);
        assert!(lb <= exact.objective + 1e-7 * (1.0 + exact.objective));
    }
}
