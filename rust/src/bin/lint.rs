//! `tlrs-lint` CLI: scan a Rust source tree for determinism & safety
//! invariant violations (see `util::lint` and docs/INVARIANTS.md).
//!
//! Exit status: 0 clean, 1 violations found, 2 usage error. Output is
//! line-oriented (`file:line: [rule] message`) and byte-identical to
//! the Python mirror (`python/tools/lint.py`) on the same tree.
//!
//! ```text
//! tlrs-lint [--root DIR] [--unsafe-out FILE] [--quiet]
//! ```

use std::path::Path;
use std::process::ExitCode;

use tlrs::util::lint::{scan_tree, unsafe_json};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().collect();
    let mut root = String::from("rust/src");
    let mut unsafe_out: Option<String> = None;
    let mut quiet = false;
    let mut i = 1usize;
    while i < args.len() {
        if args[i] == "--root" && i + 1 < args.len() {
            root = args[i + 1].clone();
            i += 2;
        } else if args[i] == "--unsafe-out" && i + 1 < args.len() {
            unsafe_out = Some(args[i + 1].clone());
            i += 2;
        } else if args[i] == "--quiet" {
            quiet = true;
            i += 1;
        } else {
            eprintln!("usage: tlrs-lint [--root DIR] [--unsafe-out FILE] [--quiet]");
            return ExitCode::from(2);
        }
    }
    let report = match scan_tree(Path::new(&root)) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("tlrs-lint: cannot scan {root}: {e}");
            return ExitCode::from(2);
        }
    };
    for (f, ln, rule, msg) in &report.findings {
        println!("{root}/{f}:{ln}: [{rule}] {msg}");
    }
    if !quiet {
        for (f, ln, rule, reason) in &report.allows {
            println!("note: {root}/{f}:{ln}: lint:allow({rule}): {reason}");
        }
    }
    if let Some(path) = unsafe_out {
        if let Err(e) = std::fs::write(&path, unsafe_json(&report.blocks)) {
            eprintln!("tlrs-lint: cannot write {path}: {e}");
            return ExitCode::from(2);
        }
    }
    println!(
        "tlrs-lint: scanned {} files: {} violation(s), {} allow(s) honored, \
         {} unsafe block(s) inventoried",
        report.n_files,
        report.findings.len(),
        report.allows.len(),
        report.blocks.len()
    );
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
