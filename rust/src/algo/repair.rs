//! Incremental repair engine: a live pool of purchased nodes whose load
//! profiles survive across admissions, retirements and reshapes.
//!
//! The one-shot solvers rebuild every node profile per solve; a plan
//! *session* (and the admission simulator, and the online baseline)
//! instead keeps [`NodeState`]s alive and repairs only the nodes a delta
//! touches: an admit is one first-fit scan (O(|nodes|·D) fast-accepts +
//! one O(D·log T) insert), a retirement one profile subtraction, a
//! reshape an eviction followed by a re-admit. This is the code path the
//! planning service's `delta` verb, `sim::autoscale` and
//! `algo::online::solve_online` all share — the sim exercises exactly
//! what the service serves.
//!
//! Admission failures are `Result` errors (or honest `None`s), never
//! asserts: these entry points run inside a long-lived service process
//! fed by untrusted deltas, where aborting on bad input is unacceptable.

use anyhow::{ensure, Result};

use crate::model::{Instance, PlacedNode, Solution};

use super::placement::{select_node, FitPolicy, NodeState};

/// A live pool of purchased nodes over one instance's timeline. Node
/// order is purchase order (what first-fit scans), and `purchase_order`
/// labels survive node drops so reports stay stable.
#[derive(Clone, Default)]
pub struct Pool {
    pub nodes: Vec<NodeState>,
    /// Next purchase sequence number.
    seq: usize,
}

impl Pool {
    pub fn new() -> Self {
        Pool { nodes: Vec::new(), seq: 0 }
    }

    /// Rebuild the live pool of an existing solution (profiles restored
    /// from the task lists). Node order and purchase numbers are kept.
    pub fn from_solution(inst: &Instance, sol: &Solution) -> Self {
        let nodes: Vec<NodeState> = sol
            .nodes
            .iter()
            .map(|n| NodeState::from_placed(inst, n, n.purchase_order))
            .collect();
        let seq = nodes.iter().map(|n| n.purchase_order + 1).max().unwrap_or(0);
        Pool { nodes, seq }
    }

    /// The purchased-but-empty cluster of a plan: same node multiset, no
    /// load — the admission simulator's starting state. `inst` here is
    /// the instance whose tasks will be streamed in (it only needs to
    /// share the plan's node-type catalog and horizon).
    pub fn empty_from_plan(inst: &Instance, plan: &Solution) -> Self {
        let nodes: Vec<NodeState> = plan
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| NodeState::new(inst, n.type_idx, i))
            .collect();
        let seq = nodes.len();
        Pool { nodes, seq }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Total purchase cost of the pool.
    pub fn cost(&self, inst: &Instance) -> f64 {
        self.nodes.iter().map(|b| inst.node_types[b.type_idx].cost).sum()
    }

    /// Admit task `u` into an already-purchased node: the `hint` node is
    /// tried first (a scheduler executing its own plan admits planned
    /// load by construction), then the policy's scan. Returns the node
    /// index, or `None` when nothing fits — never buys.
    pub fn try_admit(
        &mut self,
        inst: &Instance,
        u: usize,
        policy: FitPolicy,
        hint: Option<usize>,
    ) -> Option<usize> {
        if let Some(h) = hint {
            if h < self.nodes.len() && self.nodes[h].fits(inst, u) {
                self.nodes[h].add(inst, u);
                return Some(h);
            }
        }
        let i = select_node(inst, &self.nodes, u, policy)?;
        self.nodes[i].add(inst, u);
        Some(i)
    }

    /// Purchase a fresh node of type `b` and place task `u` in it. Errors
    /// (instead of asserting) when the task cannot fit even an empty node
    /// of that type — the service-path contract.
    pub fn buy_and_place(&mut self, inst: &Instance, u: usize, b: usize) -> Result<usize> {
        ensure!(b < inst.n_types(), "node-type {b} does not exist");
        let mut node = NodeState::new(inst, b, self.seq);
        ensure!(
            node.fits(inst, u),
            "task {} (id {}) does not fit an empty '{}' node",
            u,
            inst.tasks[u].id,
            inst.node_types[b].name
        );
        self.seq += 1;
        node.add(inst, u);
        self.nodes.push(node);
        Ok(self.nodes.len() - 1)
    }

    /// [`Pool::try_admit`] falling back to a purchase of type `b`.
    pub fn admit_or_buy(
        &mut self,
        inst: &Instance,
        u: usize,
        b: usize,
        policy: FitPolicy,
    ) -> Result<usize> {
        match self.try_admit(inst, u, policy, None) {
            Some(i) => Ok(i),
            None => self.buy_and_place(inst, u, b),
        }
    }

    /// Evict task `u` from node `node_idx` (profile subtraction).
    pub fn evict(&mut self, inst: &Instance, u: usize, node_idx: usize) {
        self.nodes[node_idx].remove(inst, u);
    }

    /// Drop nodes that hold no tasks (a retirement may empty a node; the
    /// session sheds the spend immediately). Returns how many were
    /// dropped. Node indices compact; purchase numbers are preserved.
    pub fn drop_empty(&mut self) -> usize {
        let before = self.nodes.len();
        self.nodes.retain(|n| !n.tasks.is_empty());
        before - self.nodes.len()
    }

    /// Remap the task indices stored in every node (after the session
    /// compacts its task vector over a retirement). `new_idx[u]` is the
    /// task's new index, `usize::MAX` for removed tasks — callers must
    /// have evicted those first.
    pub fn remap_tasks(&mut self, new_idx: &[usize]) {
        for node in self.nodes.iter_mut() {
            for u in node.tasks.iter_mut() {
                debug_assert!(new_idx[*u] != usize::MAX, "remapping an evicted task");
                *u = new_idx[*u];
            }
        }
    }

    /// Per-task node assignment derived from the node task lists.
    pub fn assignment(&self, n_tasks: usize) -> Vec<Option<usize>> {
        let mut a = vec![None; n_tasks];
        for (i, node) in self.nodes.iter().enumerate() {
            for &u in &node.tasks {
                a[u] = Some(i);
            }
        }
        a
    }

    /// Snapshot the pool as a [`Solution`] (what `verify`, costing and
    /// the wire responses consume).
    pub fn to_solution(&self, inst: &Instance) -> Solution {
        let mut sol = Solution::new(inst.n_tasks());
        for node in &self.nodes {
            let idx = sol.nodes.len();
            for &u in &node.tasks {
                sol.assignment[u] = Some(idx);
            }
            sol.nodes.push(PlacedNode {
                type_idx: node.type_idx,
                purchase_order: node.purchase_order,
                tasks: node.tasks.clone(),
            });
        }
        sol
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NodeType, Task};

    fn inst() -> Instance {
        Instance::new(
            vec![
                Task::new(0, vec![0.6], 0, 2),
                Task::new(1, vec![0.6], 1, 3),
                Task::new(2, vec![0.6], 4, 5),
                Task::new(3, vec![0.3], 0, 5),
            ],
            vec![NodeType::new("a", vec![1.0], 2.0)],
            6,
        )
    }

    #[test]
    fn admit_buy_evict_roundtrip() {
        let inst = inst();
        let mut pool = Pool::new();
        assert_eq!(pool.try_admit(&inst, 0, FitPolicy::FirstFit, None), None);
        pool.buy_and_place(&inst, 0, 0).unwrap();
        // task 1 overlaps task 0 at 1.2 > 1.0 -> needs a second node
        assert_eq!(pool.admit_or_buy(&inst, 1, 0, FitPolicy::FirstFit).unwrap(), 1);
        // task 2 reuses node 0 after task 0's span
        assert_eq!(pool.try_admit(&inst, 2, FitPolicy::FirstFit, None), Some(0));
        assert_eq!(pool.len(), 2);
        assert!((pool.cost(&inst) - 4.0).abs() < 1e-12);
        let sol = pool.to_solution(&inst);
        assert_eq!(sol.assignment[..3], [Some(0), Some(1), Some(0)]);

        // evicting task 1 empties node 1; drop_empty sheds it
        pool.evict(&inst, 1, 1);
        assert_eq!(pool.drop_empty(), 1);
        assert_eq!(pool.len(), 1);
        assert!((pool.cost(&inst) - 2.0).abs() < 1e-12);
        // the freed overlap now fits node 0? no — task 0 still loads it
        assert_eq!(pool.try_admit(&inst, 1, FitPolicy::FirstFit, None), None);
    }

    #[test]
    fn hint_is_tried_first() {
        let inst = inst();
        let mut pool = Pool::new();
        pool.buy_and_place(&inst, 0, 0).unwrap(); // node 0: task 0
        pool.buy_and_place(&inst, 3, 0).unwrap(); // node 1: task 3 (0.3)
        // task 2 fits both; the hint overrides first-fit's node 0
        assert_eq!(pool.try_admit(&inst, 2, FitPolicy::FirstFit, Some(1)), Some(1));
        // stale hints (out of range / full) fall back to the scan
        pool.evict(&inst, 2, 1);
        assert_eq!(pool.try_admit(&inst, 2, FitPolicy::FirstFit, Some(9)), Some(0));
    }

    #[test]
    fn buy_of_unfitting_task_is_an_error_not_a_panic() {
        let inst = Instance::new(
            vec![Task::new(0, vec![1.5], 0, 0)],
            vec![NodeType::new("a", vec![1.0], 1.0)],
            1,
        );
        let mut pool = Pool::new();
        let err = pool.buy_and_place(&inst, 0, 0).unwrap_err().to_string();
        assert!(err.contains("does not fit an empty"), "{err}");
        assert!(pool.is_empty());
    }

    #[test]
    fn from_solution_restores_profiles() {
        let inst = inst();
        let mut pool = Pool::new();
        for u in 0..4 {
            pool.admit_or_buy(&inst, u, 0, FitPolicy::FirstFit).unwrap();
        }
        let sol = pool.to_solution(&inst);
        assert!(sol.verify(&inst).is_ok());
        let rebuilt = Pool::from_solution(&inst, &sol);
        assert_eq!(rebuilt.len(), pool.len());
        // the rebuilt profiles refuse exactly what the live ones refuse
        for (a, b) in rebuilt.nodes.iter().zip(&pool.nodes) {
            assert_eq!(a.tasks, b.tasks);
            assert_eq!(a.purchase_order, b.purchase_order);
            assert!((a.peak_utilization() - b.peak_utilization()).abs() < 1e-12);
        }
        assert_eq!(rebuilt.assignment(4), sol.assignment);
    }

    #[test]
    fn remap_compacts_after_retirement() {
        let inst = inst();
        let mut pool = Pool::new();
        for u in 0..4 {
            pool.admit_or_buy(&inst, u, 0, FitPolicy::FirstFit).unwrap();
        }
        let assignment = pool.assignment(4);
        // retire task 1 (its own node): evict, compact indices 2->1, 3->2
        pool.evict(&inst, 1, assignment[1].unwrap());
        pool.drop_empty();
        let new_idx = [0, usize::MAX, 1, 2];
        pool.remap_tasks(&new_idx);
        let a = pool.assignment(3);
        assert!(a.iter().all(|x| x.is_some()));
        let tasks: Vec<usize> = pool.nodes.iter().flat_map(|n| n.tasks.clone()).collect();
        let mut sorted = tasks.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2]);
    }
}
