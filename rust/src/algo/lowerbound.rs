//! Lower bounds on the optimal cluster cost.
//!
//! The paper normalizes every reported cost by the LP lower bound
//! (section VI-A). We report the best of:
//!   - the certified dual bound of the mapping LP (section V-B: the LP
//!     optimum lower-bounds cost(opt); our bound is a feasible dual point,
//!     so it lower-bounds the LP optimum and hence cost(opt)),
//!   - the congestion bound of Lemma 1 (cheap, no LP solve).

use anyhow::Result;

use crate::lp::solver::MappingSolver;
use crate::lp::{dual, scaling, MappingLp};
use crate::model::Instance;

#[derive(Clone, Debug)]
pub struct LowerBoundReport {
    /// Certified LP dual bound.
    pub lp_bound: f64,
    /// Lemma-1 congestion bound.
    pub congestion_bound: f64,
    /// Approximate LP objective (diagnostic; not a certified bound).
    pub lp_objective: f64,
}

impl LowerBoundReport {
    /// The normalizer used in every figure.
    pub fn best(&self) -> f64 {
        self.lp_bound.max(self.congestion_bound)
    }
}

/// Compute lower bounds for a (timeline-trimmed) instance.
pub fn lower_bound(inst: &Instance, solver: &dyn MappingSolver) -> Result<LowerBoundReport> {
    let mut lp = MappingLp::from_instance(inst);
    scaling::equilibrate(&mut lp);
    let sol = solver.solve_mapping(&lp)?;
    let lp_bound = if sol.y.is_empty() {
        sol.objective
    } else {
        dual::certified_bound(&lp, &sol.y).0
    };
    Ok(LowerBoundReport {
        lp_bound,
        congestion_bound: dual::congestion_bound(&lp),
        lp_objective: sol.objective,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::penalty_map::{map_tasks, MappingPolicy};
    use crate::algo::placement::FitPolicy;
    use crate::algo::twophase::solve_with_mapping;
    use crate::io::synth::{generate, SynthParams};
    use crate::lp::solver::NativePdhgSolver;
    use crate::model::trim;

    #[test]
    fn bounds_below_any_algorithm() {
        for seed in 0..4 {
            let inst = generate(&SynthParams { n: 100, m: 5, ..Default::default() }, seed);
            let tr = trim(&inst).instance;
            let lb = lower_bound(&tr, &NativePdhgSolver::default()).unwrap();
            let mapping = map_tasks(&tr, MappingPolicy::HAvg);
            let sol = solve_with_mapping(&tr, &mapping, FitPolicy::FirstFit, false);
            assert!(
                lb.best() <= sol.cost(&tr) + 1e-6,
                "seed {seed}: lb {} vs cost {}",
                lb.best(),
                sol.cost(&tr)
            );
            assert!(lb.best() > 0.0);
            assert!(lb.congestion_bound <= lb.lp_objective + 1e-6);
        }
    }
}
