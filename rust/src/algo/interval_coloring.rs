//! Interval coloring with bandwidths — the D=1, m=1 special case the paper
//! builds on (section I, Prior Work). Kept as a standalone baseline: the
//! classical first-fit-by-start-time heuristic with O(1) approximation on
//! unit-capacity instances, and a clique-load lower bound.

use crate::model::{Instance, Solution};

use super::placement::{place_group, to_solution, FitPolicy, NodeState};

/// Solve a single-node-type instance by first-fit in start order.
/// (With m=1 the mapping phase is trivial; this is exactly the paper's
/// placement phase.) Works for any D; the classic setting is D=1.
pub fn color(inst: &Instance) -> Solution {
    assert_eq!(inst.n_types(), 1, "interval coloring needs a single node-type");
    let tasks: Vec<usize> = (0..inst.n_tasks()).collect();
    let mut seq = 0;
    let nodes: Vec<NodeState> = place_group(inst, 0, &tasks, FitPolicy::FirstFit, &mut seq);
    to_solution(inst, vec![nodes])
}

/// Clique-load lower bound: at any timeslot, total demand / capacity
/// (rounded up) nodes are needed. Shaped tasks contribute their exact
/// per-slot demand (the segment covering `t`), so the bound stays exact.
pub fn clique_bound(inst: &Instance) -> usize {
    assert_eq!(inst.n_types(), 1);
    let dims = inst.dims();
    let cap = &inst.node_types[0].capacity;
    let mut best = 0usize;
    for t in 0..inst.horizon {
        for d in 0..dims {
            let load: f64 = inst
                .tasks
                .iter()
                .filter_map(|u| u.demand_at(t))
                .map(|dem| dem[d])
                .sum();
            best = best.max((load / cap[d] - 1e-9).ceil() as usize);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NodeType, Task};
    use crate::util::rng::Rng;

    fn unit_instance(tasks: Vec<Task>, horizon: u32) -> Instance {
        Instance::new(tasks, vec![NodeType::new("c", vec![1.0], 1.0)], horizon)
    }

    #[test]
    fn disjoint_intervals_share_one_node() {
        let inst = unit_instance(
            vec![
                Task::new(0, vec![0.9], 0, 1),
                Task::new(1, vec![0.9], 2, 3),
                Task::new(2, vec![0.9], 4, 5),
            ],
            6,
        );
        let sol = color(&inst);
        assert!(sol.verify(&inst).is_ok());
        assert_eq!(sol.nodes.len(), 1);
    }

    #[test]
    fn overlap_forces_split() {
        let inst = unit_instance(
            vec![Task::new(0, vec![0.6], 0, 2), Task::new(1, vec![0.6], 1, 3)],
            4,
        );
        let sol = color(&inst);
        assert_eq!(sol.nodes.len(), 2);
        assert!(clique_bound(&inst) >= 2);
    }

    #[test]
    fn random_instances_near_bound() {
        // first-fit with bandwidths stays within a small constant of the
        // clique bound on random small-bandwidth instances
        let mut rng = Rng::new(31);
        for trial in 0..10 {
            let tasks: Vec<Task> = (0..120)
                .map(|i| {
                    let s = rng.below(20) as u32;
                    let e = (s + rng.below(6) as u32).min(19);
                    Task::new(i, vec![rng.uniform(0.05, 0.25)], s, e)
                })
                .collect();
            let inst = unit_instance(tasks, 20);
            let sol = color(&inst);
            assert!(sol.verify(&inst).is_ok(), "trial {trial}");
            let lb = clique_bound(&inst).max(1);
            assert!(
                sol.nodes.len() <= 4 * lb,
                "trial {trial}: {} nodes vs bound {lb}",
                sol.nodes.len()
            );
        }
    }
}
