//! Post-placement local search — the paper's first "fruitful research"
//! avenue (section VII): bridging the residual gap between the heuristic
//! solutions and the lower bound on hard instances.
//!
//! Two moves, applied to a fixed point:
//!   1. *Drain*: try to empty the least-valuable nodes (highest cost per
//!      peak utilization) by relocating each of their tasks into any other
//!      node with room; an emptied node is returned (cost saved).
//!   2. *Downgrade*: replace a node with a strictly cheaper node-type that
//!      still fits its load profile.
//!
//! Both moves only ever reduce cost, so the loop terminates; every
//! intermediate state is capacity-feasible.

use crate::model::{Instance, PlacedNode, Solution};

/// Load profile of one node, supporting add/remove/fit queries.
struct NodeLoad {
    type_idx: usize,
    usage: Vec<f64>,
    tasks: Vec<usize>,
}

impl NodeLoad {
    fn new(inst: &Instance, node: &PlacedNode) -> Self {
        let dims = inst.dims();
        let mut usage = vec![0.0; inst.horizon as usize * dims];
        for &u in &node.tasks {
            let t = &inst.tasks[u];
            for ts in t.start..=t.end {
                for d in 0..dims {
                    usage[ts as usize * dims + d] += t.demand[d];
                }
            }
        }
        NodeLoad { type_idx: node.type_idx, usage, tasks: node.tasks.clone() }
    }

    fn fits(&self, inst: &Instance, u: usize) -> bool {
        let task = &inst.tasks[u];
        let dims = inst.dims();
        let cap = &inst.node_types[self.type_idx].capacity;
        for ts in task.start..=task.end {
            for d in 0..dims {
                if self.usage[ts as usize * dims + d] + task.demand[d] > cap[d] + 1e-9 {
                    return false;
                }
            }
        }
        true
    }

    fn add(&mut self, inst: &Instance, u: usize) {
        let task = &inst.tasks[u];
        let dims = inst.dims();
        for ts in task.start..=task.end {
            for d in 0..dims {
                self.usage[ts as usize * dims + d] += task.demand[d];
            }
        }
        self.tasks.push(u);
    }

    fn remove(&mut self, inst: &Instance, u: usize) {
        let task = &inst.tasks[u];
        let dims = inst.dims();
        for ts in task.start..=task.end {
            for d in 0..dims {
                self.usage[ts as usize * dims + d] -= task.demand[d];
            }
        }
        self.tasks.retain(|&t| t != u);
    }

    /// Peak usage per dimension over the timeline.
    fn peaks(&self, dims: usize) -> Vec<f64> {
        let mut peaks = vec![0.0f64; dims];
        for chunk in self.usage.chunks(dims) {
            for d in 0..dims {
                peaks[d] = peaks[d].max(chunk[d]);
            }
        }
        peaks
    }
}

/// Statistics from one `improve` run.
#[derive(Clone, Debug, Default)]
pub struct LocalSearchStats {
    pub nodes_drained: usize,
    pub nodes_downgraded: usize,
    pub tasks_moved: usize,
    pub cost_before: f64,
    pub cost_after: f64,
}

/// Improve a feasible solution in place. Returns statistics.
pub fn improve(inst: &Instance, sol: &mut Solution, max_rounds: usize) -> LocalSearchStats {
    let dims = inst.dims();
    let mut stats = LocalSearchStats {
        cost_before: sol.cost(inst),
        ..Default::default()
    };
    let mut nodes: Vec<NodeLoad> = sol.nodes.iter().map(|n| NodeLoad::new(inst, n)).collect();

    for _round in 0..max_rounds {
        let mut changed = false;

        // ---- downgrade pass: cheapest admitting type per node ----
        for node in nodes.iter_mut() {
            if node.tasks.is_empty() {
                continue;
            }
            let peaks = node.peaks(dims);
            let current_cost = inst.node_types[node.type_idx].cost;
            let mut best: Option<(usize, f64)> = None;
            for (b, ty) in inst.node_types.iter().enumerate() {
                if ty.cost < current_cost - 1e-12
                    && peaks.iter().zip(&ty.capacity).all(|(&p, &c)| p <= c + 1e-9)
                {
                    if best.map(|(_, c)| ty.cost < c).unwrap_or(true) {
                        best = Some((b, ty.cost));
                    }
                }
            }
            if let Some((b, _)) = best {
                node.type_idx = b;
                stats.nodes_downgraded += 1;
                changed = true;
            }
        }

        // ---- drain pass: empty expensive low-utilization nodes ----
        // candidate order: descending cost / peak-utilization
        let mut order: Vec<usize> = (0..nodes.len()).collect();
        let value = |nl: &NodeLoad| {
            let cap = &inst.node_types[nl.type_idx].capacity;
            let util = nl
                .peaks(dims)
                .iter()
                .zip(cap)
                .map(|(&p, &c)| p / c)
                .fold(0.0f64, f64::max);
            inst.node_types[nl.type_idx].cost * (1.0 - util)
        };
        order.sort_by(|&a, &b| value(&nodes[b]).partial_cmp(&value(&nodes[a])).unwrap());

        for &i in &order {
            if nodes[i].tasks.is_empty() {
                continue;
            }
            // tentatively relocate every task of node i elsewhere
            let tasks: Vec<usize> = nodes[i].tasks.clone();
            let mut moves: Vec<(usize, usize)> = Vec::with_capacity(tasks.len());
            let mut ok = true;
            for &u in &tasks {
                nodes[i].remove(inst, u);
                let mut placed = false;
                for j in 0..nodes.len() {
                    if j != i && !nodes[j].tasks.is_empty() && nodes[j].fits(inst, u) {
                        nodes[j].add(inst, u);
                        moves.push((u, j));
                        placed = true;
                        break;
                    }
                }
                if !placed {
                    ok = false;
                    break;
                }
            }
            if ok {
                stats.nodes_drained += 1;
                stats.tasks_moved += moves.len();
                changed = true;
            } else {
                // roll back
                for &(u, j) in moves.iter().rev() {
                    nodes[j].remove(inst, u);
                    nodes[i].add(inst, u);
                }
                // re-add the task that failed placement
                for &u in &tasks {
                    if !nodes[i].tasks.contains(&u)
                        && !nodes.iter().any(|n| n.tasks.contains(&u))
                    {
                        nodes[i].add(inst, u);
                    }
                }
            }
        }

        if !changed {
            break;
        }
    }

    // rebuild the solution from surviving nodes
    let mut out = Solution::new(inst.n_tasks());
    for node in nodes.into_iter().filter(|n| !n.tasks.is_empty()) {
        let idx = out.nodes.len();
        for &u in &node.tasks {
            out.assignment[u] = Some(idx);
        }
        out.nodes.push(PlacedNode {
            type_idx: node.type_idx,
            purchase_order: idx,
            tasks: node.tasks,
        });
    }
    stats.cost_after = out.cost(inst);
    *sol = out;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::penalty_map::{map_tasks, MappingPolicy};
    use crate::algo::placement::FitPolicy;
    use crate::algo::twophase::solve_with_mapping;
    use crate::io::synth::{generate, SynthParams};
    use crate::model::{trim, NodeType, Task};

    #[test]
    fn drains_obviously_wasteful_node() {
        // two nodes each holding one tiny task -> local search merges them
        let inst = Instance::new(
            vec![Task::new(0, vec![0.2], 0, 1), Task::new(1, vec![0.2], 2, 3)],
            vec![NodeType::new("a", vec![1.0], 5.0)],
            4,
        );
        let mut sol = Solution::new(2);
        sol.nodes.push(PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0] });
        sol.nodes.push(PlacedNode { type_idx: 0, purchase_order: 1, tasks: vec![1] });
        sol.assignment = vec![Some(0), Some(1)];
        let stats = improve(&inst, &mut sol, 5);
        assert!(sol.verify(&inst).is_ok());
        assert_eq!(sol.nodes.len(), 1);
        assert_eq!(stats.nodes_drained, 1);
        assert!(stats.cost_after < stats.cost_before);
    }

    #[test]
    fn downgrades_oversized_node() {
        let inst = Instance::new(
            vec![Task::new(0, vec![0.3], 0, 0)],
            vec![
                NodeType::new("big", vec![1.0], 10.0),
                NodeType::new("small", vec![0.4], 2.0),
            ],
            1,
        );
        let mut sol = Solution::new(1);
        sol.nodes.push(PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0] });
        sol.assignment = vec![Some(0)];
        let stats = improve(&inst, &mut sol, 5);
        assert!(sol.verify(&inst).is_ok());
        assert_eq!(stats.nodes_downgraded, 1);
        assert_eq!(sol.nodes[0].type_idx, 1);
        assert_eq!(sol.cost(&inst), 2.0);
    }

    #[test]
    fn never_increases_cost_and_stays_feasible() {
        for seed in 0..6u64 {
            let inst = generate(&SynthParams { n: 120, m: 5, ..Default::default() }, seed);
            let tr = trim(&inst).instance;
            let mapping = map_tasks(&tr, MappingPolicy::HAvg);
            let mut sol = solve_with_mapping(&tr, &mapping, FitPolicy::FirstFit, false);
            let before = sol.cost(&tr);
            let stats = improve(&tr, &mut sol, 10);
            assert!(sol.verify(&tr).is_ok(), "seed {seed}");
            assert!(sol.cost(&tr) <= before + 1e-9, "seed {seed}");
            assert!((stats.cost_after - sol.cost(&tr)).abs() < 1e-9, "seed {seed}");
            assert!(stats.cost_before >= stats.cost_after, "seed {seed}");
        }
    }
}
