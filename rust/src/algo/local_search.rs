//! Post-placement local search — the paper's first "fruitful research"
//! avenue (section VII): bridging the residual gap between the heuristic
//! solutions and the lower bound on hard instances.
//!
//! Two moves, applied to a fixed point:
//!   1. *Drain*: try to empty the least-valuable nodes (highest cost per
//!      peak utilization) by relocating each of their tasks into any other
//!      node with room; an emptied node is returned (cost saved).
//!   2. *Downgrade*: replace a node with a strictly cheaper node-type that
//!      still fits its load profile.
//!
//! Both moves only ever reduce cost, so the loop terminates; every
//! intermediate state is capacity-feasible.

use crate::model::{Instance, PlacedNode, Profile, Solution, EPS};

use super::placement::NodeState;

/// Statistics from one `improve` run.
#[derive(Clone, Debug, Default)]
pub struct LocalSearchStats {
    pub nodes_drained: usize,
    pub nodes_downgraded: usize,
    pub tasks_moved: usize,
    pub cost_before: f64,
    pub cost_after: f64,
}

/// Improve a feasible solution in place. Returns statistics.
pub fn improve(inst: &Instance, sol: &mut Solution, max_rounds: usize) -> LocalSearchStats {
    let mut stats = LocalSearchStats {
        cost_before: sol.cost(inst),
        ..Default::default()
    };
    // relocation probes and peaks ride the shared indexed NodeState —
    // the same O(D·log T) profile the placement phase uses
    let mut nodes: Vec<NodeState> = sol
        .nodes
        .iter()
        .enumerate()
        .map(|(i, n)| NodeState::from_placed(inst, n, i))
        .collect();

    for _round in 0..max_rounds {
        let mut changed = false;

        // ---- downgrade pass: cheapest admitting type per node ----
        for node in nodes.iter_mut() {
            if node.tasks.is_empty() {
                continue;
            }
            let peaks = node.profile().peaks();
            let current_cost = inst.node_types[node.type_idx].cost;
            let mut best: Option<(usize, f64)> = None;
            for (b, ty) in inst.node_types.iter().enumerate() {
                if ty.cost < current_cost - 1e-12
                    && peaks.iter().zip(&ty.capacity).all(|(&p, &c)| p <= c + EPS)
                {
                    if best.map(|(_, c)| ty.cost < c).unwrap_or(true) {
                        best = Some((b, ty.cost));
                    }
                }
            }
            if let Some((b, _)) = best {
                node.set_type(inst, b);
                stats.nodes_downgraded += 1;
                changed = true;
            }
        }

        // ---- drain pass: empty expensive low-utilization nodes ----
        // candidate order: descending cost / peak-utilization (NaN-safe
        // total ordering with a deterministic index tie-break)
        let mut order: Vec<usize> = (0..nodes.len()).collect();
        let value = |nl: &NodeState| {
            let util = nl.peak_utilization();
            inst.node_types[nl.type_idx].cost * (1.0 - util)
        };
        order.sort_by(|&a, &b| {
            value(&nodes[b]).total_cmp(&value(&nodes[a])).then(a.cmp(&b))
        });

        for &i in &order {
            if nodes[i].tasks.is_empty() {
                continue;
            }
            // tentatively relocate every task of node i elsewhere
            let tasks: Vec<usize> = nodes[i].tasks.clone();
            let mut moves: Vec<(usize, usize)> = Vec::with_capacity(tasks.len());
            let mut ok = true;
            for &u in &tasks {
                nodes[i].remove(inst, u);
                let mut placed = false;
                for j in 0..nodes.len() {
                    if j != i && !nodes[j].tasks.is_empty() && nodes[j].fits(inst, u) {
                        nodes[j].add(inst, u);
                        moves.push((u, j));
                        placed = true;
                        break;
                    }
                }
                if !placed {
                    ok = false;
                    break;
                }
            }
            if ok {
                stats.nodes_drained += 1;
                stats.tasks_moved += moves.len();
                changed = true;
            } else {
                // roll back
                for &(u, j) in moves.iter().rev() {
                    nodes[j].remove(inst, u);
                    nodes[i].add(inst, u);
                }
                // re-add the task that failed placement
                for &u in &tasks {
                    if !nodes[i].tasks.contains(&u)
                        && !nodes.iter().any(|n| n.tasks.contains(&u))
                    {
                        nodes[i].add(inst, u);
                    }
                }
            }
        }

        if !changed {
            break;
        }
    }

    // rebuild the solution from surviving nodes
    let mut out = Solution::new(inst.n_tasks());
    for node in nodes.into_iter().filter(|n| !n.tasks.is_empty()) {
        let idx = out.nodes.len();
        for &u in &node.tasks {
            out.assignment[u] = Some(idx);
        }
        out.nodes.push(PlacedNode {
            type_idx: node.type_idx,
            purchase_order: idx,
            tasks: node.tasks,
        });
    }
    stats.cost_after = out.cost(inst);
    *sol = out;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::penalty_map::{map_tasks, MappingPolicy};
    use crate::algo::placement::FitPolicy;
    use crate::algo::twophase::solve_with_mapping;
    use crate::io::synth::{generate, SynthParams};
    use crate::model::{trim, NodeType, Task};

    #[test]
    fn drains_obviously_wasteful_node() {
        // two nodes each holding one tiny task -> local search merges them
        let inst = Instance::new(
            vec![Task::new(0, vec![0.2], 0, 1), Task::new(1, vec![0.2], 2, 3)],
            vec![NodeType::new("a", vec![1.0], 5.0)],
            4,
        );
        let mut sol = Solution::new(2);
        sol.nodes.push(PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0] });
        sol.nodes.push(PlacedNode { type_idx: 0, purchase_order: 1, tasks: vec![1] });
        sol.assignment = vec![Some(0), Some(1)];
        let stats = improve(&inst, &mut sol, 5);
        assert!(sol.verify(&inst).is_ok());
        assert_eq!(sol.nodes.len(), 1);
        assert_eq!(stats.nodes_drained, 1);
        assert!(stats.cost_after < stats.cost_before);
    }

    #[test]
    fn downgrades_oversized_node() {
        let inst = Instance::new(
            vec![Task::new(0, vec![0.3], 0, 0)],
            vec![
                NodeType::new("big", vec![1.0], 10.0),
                NodeType::new("small", vec![0.4], 2.0),
            ],
            1,
        );
        let mut sol = Solution::new(1);
        sol.nodes.push(PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0] });
        sol.assignment = vec![Some(0)];
        let stats = improve(&inst, &mut sol, 5);
        assert!(sol.verify(&inst).is_ok());
        assert_eq!(stats.nodes_downgraded, 1);
        assert_eq!(sol.nodes[0].type_idx, 1);
        assert_eq!(sol.cost(&inst), 2.0);
    }

    #[test]
    fn never_increases_cost_and_stays_feasible() {
        for seed in 0..6u64 {
            let inst = generate(&SynthParams { n: 120, m: 5, ..Default::default() }, seed);
            let tr = trim(&inst).instance;
            let mapping = map_tasks(&tr, MappingPolicy::HAvg);
            let mut sol = solve_with_mapping(&tr, &mapping, FitPolicy::FirstFit, false);
            let before = sol.cost(&tr);
            let stats = improve(&tr, &mut sol, 10);
            assert!(sol.verify(&tr).is_ok(), "seed {seed}");
            assert!(sol.cost(&tr) <= before + 1e-9, "seed {seed}");
            assert!((stats.cost_after - sol.cost(&tr)).abs() < 1e-9, "seed {seed}");
            assert!(stats.cost_before >= stats.cost_after, "seed {seed}");
        }
    }
}
