//! LP-map: the paper's improved mapping strategy (section V).
//!
//! Solve the mapping LP once, round each task to its argmax node-type
//! (near-integrality, Lemma 4 / Figure 5 — `x_max` is exported so the
//! harness can regenerate the figure), then run the shared placement
//! phase, optionally with cross-node-type filling (LP-map-F). The LP
//! solve is decoupled from placement so one solve can feed all
//! fit-policy/filling variants.

use anyhow::Result;

use crate::lp::dual;
use crate::lp::scaling;
use crate::lp::solver::MappingSolver;
use crate::lp::MappingLp;
use crate::model::{Instance, Solution};

use super::placement::FitPolicy;
use super::twophase::solve_with_mapping;

/// Result of the LP mapping phase (placement-independent).
#[derive(Clone, Debug)]
pub struct LpOutcome {
    /// Primary rounded mapping (argmax of the crossover solution).
    pub mapping: Vec<usize>,
    /// Alternative LP-derived roundings (top-k-mass restrictions etc.);
    /// the placement phase picks the cheapest. On the degenerate optimal
    /// faces of homogeneous cost models the LP cannot distinguish
    /// packable from fragmented mappings, so rounding variants matter.
    pub alternates: Vec<Vec<usize>>,
    /// Per-task `x_max(u) = max_B x*(u,B)` — Figure 5's series.
    pub x_max: Vec<f64>,
    /// LP objective (approximate for first-order backends).
    pub lp_objective: f64,
    /// Certified dual lower bound on the LP optimum (valid normalizer).
    pub certified_lb: f64,
    pub solver_iterations: usize,
    pub solver_converged: bool,
}

/// Full LP-map result: outcome + a placed solution.
#[derive(Clone, Debug)]
pub struct LpMapReport {
    pub solution: Solution,
    pub mapping: Vec<usize>,
    pub lp_objective: f64,
    pub certified_lb: f64,
    pub x_max: Vec<f64>,
    pub solver_iterations: usize,
    pub solver_converged: bool,
}

/// Per-type congestion peaks implied by a fractional assignment — the
/// tightest alpha for which x is feasible (used as the crossover budget).
fn implied_alpha(lp: &crate::lp::MappingLp, x: &[f64], threads: usize) -> Vec<f64> {
    let mut op = crate::lp::pdhg::Operator::with_threads(lp, threads);
    let mut buf = vec![0.0; lp.m * lp.t * lp.dims];
    op.forward(x, &vec![0.0; lp.m], &mut buf);
    let mut alpha = vec![0.0f64; lp.m];
    for b in 0..lp.m {
        for ts in 0..lp.t {
            for d in 0..lp.dims {
                let rho = lp.rho_at(b, d);
                if rho > 0.0 {
                    let v = buf[(b * lp.t + ts) * lp.dims + d] / rho;
                    alpha[b] = alpha[b].max(v);
                }
            }
        }
    }
    alpha
}

/// Concentrating roundings: restrict each task to its argmax among the
/// k node-types carrying the most total fractional mass (k = 1..3),
/// falling back to the global admissible argmax when none of the top-k
/// admit the task. Counters placement fragmentation when the LP optimum
/// is degenerate across many equally cost-effective types.
fn top_k_mass_mappings(inst: &Instance, x: &[f64]) -> Vec<Vec<usize>> {
    let (n, m) = (inst.n_tasks(), inst.n_types());
    let mut mass: Vec<(usize, f64)> = (0..m)
        .map(|b| (b, (0..n).map(|u| x[u * m + b]).sum()))
        .collect();
    mass.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    let mut out = Vec::new();
    for k in 1..=3usize.min(m) {
        let allowed: Vec<usize> = mass[..k].iter().map(|&(b, _)| b).collect();
        let mapping: Vec<usize> = (0..n)
            .map(|u| {
                // NaN-safe; ties keep max_by's last-wins over the
                // mass-ordered candidate list (the seed's tie behavior —
                // an index tie-break here would pick a different type
                // whenever x-values tie, e.g. at 0.0)
                let pick = allowed
                    .iter()
                    .copied()
                    .filter(|&b| inst.node_types[b].admits(inst.tasks[u].peak()))
                    .max_by(|&a, &b| x[u * m + a].total_cmp(&x[u * m + b]));
                match pick {
                    Some(b) => b,
                    None => {
                        // fall back to the global admissible argmax
                        (0..m)
                            .filter(|&b| {
                                inst.node_types[b].admits(inst.tasks[u].peak())
                            })
                            .max_by(|&a, &b| {
                                x[u * m + a].total_cmp(&x[u * m + b]).then(a.cmp(&b))
                            })
                            .expect("task fits some type")
                    }
                }
            })
            .collect();
        out.push(mapping);
    }
    out.dedup();
    out
}

/// Round a fractional assignment to the argmax admissible node-type.
/// Inadmissible types are skipped; ties break toward lower index.
pub fn round_mapping(inst: &Instance, x: &[f64]) -> (Vec<usize>, Vec<f64>) {
    let (n, m) = (inst.n_tasks(), inst.n_types());
    let mut mapping = Vec::with_capacity(n);
    let mut x_max = Vec::with_capacity(n);
    for u in 0..n {
        let mut arg = usize::MAX;
        let mut best = f64::NEG_INFINITY;
        for b in 0..m {
            if !inst.node_types[b].admits(inst.tasks[u].peak()) {
                continue;
            }
            let v = x[u * m + b];
            if v > best {
                best = v;
                arg = b;
            }
        }
        assert!(arg != usize::MAX, "task {u} fits no node-type");
        mapping.push(arg);
        // report the raw max over all types (figure 5 semantics)
        let raw = (0..m).map(|b| x[u * m + b]).fold(f64::NEG_INFINITY, f64::max);
        x_max.push(raw);
    }
    (mapping, x_max)
}

/// Phase 1 only: solve + round. The instance should be timeline-trimmed.
pub fn solve_lp_mapping(inst: &Instance, solver: &dyn MappingSolver) -> Result<LpOutcome> {
    // One thread knob governs the whole mapping path: the ratio-table
    // build, the solve itself, the crossover's operator applications and
    // the certified-bound repair (all bit-identical for any count).
    let threads = solver.lp_threads();
    let mut lp = MappingLp::from_instance_par(inst, threads);
    scaling::equilibrate(&mut lp);
    let sol = solver.solve_mapping(&lp)?;
    // First-order backends return interior-face points; crossover pulls
    // them to a near-vertex solution (Lemma 4 near-integrality) without
    // changing the objective. Exact backends are already basic.
    let x = if sol.y.is_empty() {
        sol.x.clone()
    } else {
        // alpha is implied by x at the optimum: recompute per-type peaks
        let alpha = implied_alpha(&lp, &sol.x, threads);
        crate::lp::crossover::crossover(&lp, &sol.x, &alpha, 1e-4).0
    };
    let (mapping, x_max) = round_mapping(inst, &x);
    let mut alternates = top_k_mass_mappings(inst, &sol.x);
    // argmax of the raw (pre-crossover) solution is a further candidate
    alternates.push(round_mapping(inst, &sol.x).0);
    alternates.retain(|alt| alt != &mapping);
    alternates.dedup();
    let certified_lb = if sol.y.is_empty() {
        // exact backend: the objective itself is the bound
        sol.objective
    } else {
        dual::certified_bound_par(&lp, &sol.y, threads).0
    };
    Ok(LpOutcome {
        mapping,
        alternates,
        x_max,
        lp_objective: sol.objective,
        certified_lb,
        solver_iterations: sol.iterations,
        solver_converged: sol.converged,
    })
}

/// Phase 2: place a previously-computed LP mapping — the primary rounding
/// plus every alternate, keeping the cheapest feasible placement.
pub fn place_lp_outcome(
    inst: &Instance,
    outcome: &LpOutcome,
    policy: FitPolicy,
    cross_fill: bool,
) -> Solution {
    let mut best = solve_with_mapping(inst, &outcome.mapping, policy, cross_fill);
    for alt in &outcome.alternates {
        let sol = solve_with_mapping(inst, alt, policy, cross_fill);
        if sol.cost(inst) < best.cost(inst) {
            best = sol;
        }
    }
    best
}

/// Convenience: run both phases.
pub fn lp_map(
    inst: &Instance,
    solver: &dyn MappingSolver,
    policy: FitPolicy,
    cross_fill: bool,
) -> Result<LpMapReport> {
    let outcome = solve_lp_mapping(inst, solver)?;
    let solution = place_lp_outcome(inst, &outcome, policy, cross_fill);
    Ok(LpMapReport {
        solution,
        mapping: outcome.mapping,
        lp_objective: outcome.lp_objective,
        certified_lb: outcome.certified_lb,
        x_max: outcome.x_max,
        solver_iterations: outcome.solver_iterations,
        solver_converged: outcome.solver_converged,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};
    use crate::lp::solver::{NativePdhgSolver, SimplexSolver};
    use crate::model::trim;

    #[test]
    fn produces_feasible_and_bounded() {
        let inst = generate(&SynthParams { n: 80, m: 4, ..Default::default() }, 21);
        let tr = trim(&inst).instance;
        let rep = lp_map(&tr, &NativePdhgSolver::default(), FitPolicy::FirstFit, false).unwrap();
        assert!(rep.solution.verify(&tr).is_ok());
        assert!(rep.certified_lb <= rep.solution.cost(&tr) + 1e-6);
        assert!(rep.certified_lb > 0.0);
        assert_eq!(rep.x_max.len(), 80);
    }

    #[test]
    fn near_integrality_manifest() {
        // paper Figure 5: most tasks are (nearly) integrally assigned
        let inst = generate(&SynthParams { n: 120, m: 5, ..Default::default() }, 22);
        let tr = trim(&inst).instance;
        let rep = lp_map(&tr, &NativePdhgSolver::default(), FitPolicy::FirstFit, false).unwrap();
        let frac_near_integral =
            rep.x_max.iter().filter(|&&v| v > 0.9).count() as f64 / 120.0;
        assert!(frac_near_integral > 0.5, "only {frac_near_integral} near-integral");
    }

    #[test]
    fn rounding_respects_admissibility() {
        use crate::model::{NodeType, Task};
        let inst = Instance::new(
            vec![Task::new(0, vec![0.8], 0, 0)],
            vec![
                NodeType::new("small", vec![0.5], 0.1),
                NodeType::new("big", vec![1.0], 1.0),
            ],
            1,
        );
        // fractional solution prefers the small type, but it can't fit
        let (mapping, _) = round_mapping(&inst, &[0.9, 0.1]);
        assert_eq!(mapping, vec![1]);
    }

    #[test]
    fn one_solve_feeds_all_variants() {
        let inst = generate(&SynthParams { n: 60, m: 4, ..Default::default() }, 24);
        let tr = trim(&inst).instance;
        let outcome = solve_lp_mapping(&tr, &NativePdhgSolver::default()).unwrap();
        for policy in [FitPolicy::FirstFit, FitPolicy::SimilarityFit] {
            for fill in [false, true] {
                let sol = place_lp_outcome(&tr, &outcome, policy, fill);
                assert!(sol.verify(&tr).is_ok());
                assert!(outcome.certified_lb <= sol.cost(&tr) + 1e-6);
            }
        }
    }

    #[test]
    fn simplex_backend_end_to_end() {
        let inst = generate(
            &SynthParams { n: 12, m: 3, dims: 2, horizon: 6, dem_range: (0.05, 0.3), ..Default::default() },
            23,
        );
        let tr = trim(&inst).instance;
        let rep = lp_map(&tr, &SimplexSolver, FitPolicy::SimilarityFit, true).unwrap();
        assert!(rep.solution.verify(&tr).is_ok());
        assert!(rep.lp_objective <= rep.solution.cost(&tr) + 1e-6);
    }
}
