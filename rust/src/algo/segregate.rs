//! Small/large task segregation (paper section III, after Theorem 3).
//!
//! The O(D·min(m,T)) analysis assumes *small* tasks (every demand at most
//! half of every capacity). The general-case recipe solves the small and
//! large classes separately and unions the solutions; the paper notes that
//! in practice segregation is rarely worth it — our ablation bench
//! (`cargo bench`/harness) quantifies exactly that.

use crate::model::{Instance, Solution};

/// Split task indices into (small, large) per the paper's definition:
/// small iff for all node-types B and dims d, dem(u,d) <= cap(B,d)/2.
pub fn split_small_large(inst: &Instance) -> (Vec<usize>, Vec<usize>) {
    let mut small = Vec::new();
    let mut large = Vec::new();
    for (u, task) in inst.tasks.iter().enumerate() {
        let is_small = inst
            .node_types
            .iter()
            .all(|b| task.is_small_for(&b.capacity));
        if is_small {
            small.push(u);
        } else {
            large.push(u);
        }
    }
    (small, large)
}

/// Restrict an instance to a subset of tasks; returns the sub-instance and
/// the original indices (position i in the sub-instance = `keep[i]`).
pub fn sub_instance(inst: &Instance, keep: &[usize]) -> Instance {
    let tasks = keep
        .iter()
        .enumerate()
        .map(|(new_id, &u)| inst.tasks[u].with_id(new_id as u64))
        .collect();
    Instance::new(tasks, inst.node_types.clone(), inst.horizon)
}

/// Union two sub-solutions back into a solution over the full instance.
pub fn merge_solutions(
    inst: &Instance,
    parts: &[(&[usize], &Solution)],
) -> Solution {
    let mut out = Solution::new(inst.n_tasks());
    for (keep, sol) in parts {
        let base = out.nodes.len();
        for node in &sol.nodes {
            let mut mapped = node.clone();
            mapped.purchase_order = base + mapped.purchase_order;
            mapped.tasks = node.tasks.iter().map(|&u| keep[u]).collect();
            for &orig in &mapped.tasks {
                out.assignment[orig] = Some(out.nodes.len());
            }
            out.nodes.push(mapped);
        }
    }
    out
}

/// Solve with segregation: apply `solve` to the small and large classes
/// independently and union the results.
pub fn solve_segregated(
    inst: &Instance,
    mut solve: impl FnMut(&Instance) -> Solution,
) -> Solution {
    let (small, large) = split_small_large(inst);
    if small.is_empty() || large.is_empty() {
        return solve(inst);
    }
    let si = sub_instance(inst, &small);
    let li = sub_instance(inst, &large);
    let ss = solve(&si);
    let ls = solve(&li);
    merge_solutions(inst, &[(&small, &ss), (&large, &ls)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::penalty_map::{map_tasks, MappingPolicy};
    use crate::algo::placement::FitPolicy;
    use crate::algo::twophase::solve_with_mapping;
    use crate::io::synth::{generate, SynthParams};
    use crate::model::trim;

    #[test]
    fn split_definition() {
        let inst = generate(
            &SynthParams { n: 50, m: 3, dem_range: (0.01, 0.6), ..Default::default() },
            5,
        );
        let (small, large) = split_small_large(&inst);
        assert_eq!(small.len() + large.len(), 50);
        for &u in &small {
            for b in &inst.node_types {
                assert!(inst.tasks[u].is_small_for(&b.capacity));
            }
        }
        for &u in &large {
            assert!(inst
                .node_types
                .iter()
                .any(|b| !inst.tasks[u].is_small_for(&b.capacity)));
        }
    }

    #[test]
    fn segregated_solution_feasible() {
        let inst = generate(
            &SynthParams { n: 120, m: 5, dem_range: (0.01, 0.5), ..Default::default() },
            6,
        );
        let tr = trim(&inst).instance;
        let sol = solve_segregated(&tr, |i| {
            let mapping = map_tasks(i, MappingPolicy::HAvg);
            solve_with_mapping(i, &mapping, FitPolicy::FirstFit, false)
        });
        assert!(sol.verify(&tr).is_ok());
    }

    #[test]
    fn all_small_shortcut() {
        let inst = generate(&SynthParams { n: 40, m: 3, ..Default::default() }, 7);
        let tr = trim(&inst).instance;
        let (small, large) = split_small_large(&tr);
        assert_eq!(small.len(), 40);
        assert!(large.is_empty());
        let sol = solve_segregated(&tr, |i| {
            let mapping = map_tasks(i, MappingPolicy::HAvg);
            solve_with_mapping(i, &mapping, FitPolicy::FirstFit, false)
        });
        assert!(sol.verify(&tr).is_ok());
    }
}
