//! The two-phase solve framework shared by every mapping strategy
//! (paper Figures 3 and 6): partition tasks by their mapped node-type,
//! place each group greedily, and optionally run cross-node-type filling.
//!
//! Without filling the node-type groups are fully independent, so they
//! are placed concurrently through `util::pool::run_indexed` (all
//! threading routes through the pool — the `raw-spawn` lint invariant)
//! and the per-node purchase numbers are renumbered afterwards to match
//! the sequential counter exactly: the parallel solve is bit-identical
//! to the sequential one.

use crate::model::{DenseProfile, Instance, LoadProfile, Profile, Solution};

use super::fill;
use super::placement::{
    place_group, place_group_scan, to_solution, FitPolicy, NodeState, NodeStateImpl,
};

/// Below this many tasks a solve is microseconds; thread spawn overhead
/// would dominate, so place sequentially.
const PARALLEL_MIN_TASKS: usize = 512;

/// Partition task indices by their mapped node-type.
fn group_by_type(inst: &Instance, mapping: &[usize]) -> Vec<Vec<usize>> {
    assert_eq!(mapping.len(), inst.n_tasks());
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); inst.n_types()];
    for (u, &b) in mapping.iter().enumerate() {
        groups[b].push(u);
    }
    groups
}

/// Sequential per-type placement over any profile backend.
fn solve_sequential<P: Profile>(
    inst: &Instance,
    mapping: &[usize],
    policy: FitPolicy,
) -> Solution {
    let groups = group_by_type(inst, mapping);
    let mut seq = 0usize;
    let placed: Vec<Vec<NodeStateImpl<P>>> = (0..inst.n_types())
        .map(|b| place_group(inst, b, &groups[b], policy, &mut seq))
        .collect();
    to_solution(inst, placed)
}

/// Solve with a given task -> node-type mapping.
///
/// Without filling, node-types are independent and processed in index
/// order (paper Figure 3). With filling, they are processed in decreasing
/// capacity-per-cost order and leftover capacity is offered to the tasks
/// of later node-types (paper Figure 6).
pub fn solve_with_mapping(
    inst: &Instance,
    mapping: &[usize],
    policy: FitPolicy,
    cross_fill: bool,
) -> Solution {
    if cross_fill {
        assert_eq!(mapping.len(), inst.n_tasks());
        return fill::solve_with_filling(inst, mapping, policy);
    }
    let m = inst.n_types();
    if m <= 1 || inst.n_tasks() < PARALLEL_MIN_TASKS {
        return solve_sequential::<LoadProfile>(inst, mapping, policy);
    }

    let groups = group_by_type(inst, mapping);
    // one pooled worker per node-type; each places with a local purchase
    // counter starting at zero (results come back in type order)
    let mut placed: Vec<Vec<NodeState>> =
        crate::util::pool::run_indexed(groups.len(), groups.len(), |b| {
            let mut local_seq = 0usize;
            place_group::<LoadProfile>(inst, b, &groups[b], policy, &mut local_seq)
        });

    // Renumber purchase orders to the global sequential counter: groups in
    // type order, nodes within a group already in purchase order. This
    // reproduces the sequential numbering exactly.
    let mut seq = 0usize;
    for nodes in placed.iter_mut() {
        for node in nodes.iter_mut() {
            node.purchase_order = seq;
            seq += 1;
        }
    }
    to_solution(inst, placed)
}

/// Sequential *indexed* solve — same segment-tree profiles, no threads.
/// Benchmarks use this to isolate the indexing win from the scoped-thread
/// parallelism (which `solve_with_mapping` adds on top).
pub fn solve_with_mapping_sequential(
    inst: &Instance,
    mapping: &[usize],
    policy: FitPolicy,
) -> Solution {
    solve_sequential::<LoadProfile>(inst, mapping, policy)
}

/// Sequential indexed solve with the *linear-scan* first-fit loop
/// (no bucketed-headroom index) — the A/B baseline isolating the
/// candidate-index win at the solve level; identical placements, only
/// the per-task node search differs.
pub fn solve_with_mapping_scan(
    inst: &Instance,
    mapping: &[usize],
    policy: FitPolicy,
) -> Solution {
    let groups = group_by_type(inst, mapping);
    let mut seq = 0usize;
    let placed: Vec<Vec<NodeState>> = (0..inst.n_types())
        .map(|b| place_group_scan(inst, b, &groups[b], policy, &mut seq))
        .collect();
    to_solution(inst, placed)
}

/// Sequential dense-profile reference solve — the seed's exact code path,
/// kept for property tests (cost equality with the indexed path) and as
/// the baseline `benches/placement.rs` measures speedups against.
pub fn solve_with_mapping_ref(
    inst: &Instance,
    mapping: &[usize],
    policy: FitPolicy,
) -> Solution {
    solve_sequential::<DenseProfile>(inst, mapping, policy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::penalty_map::{map_tasks, MappingPolicy};
    use crate::io::synth::{generate, SynthParams};
    use crate::model::trim;

    #[test]
    fn produces_feasible_solutions() {
        for seed in 0..5 {
            let inst = generate(&SynthParams { n: 120, m: 5, ..Default::default() }, seed);
            let tr = trim(&inst).instance;
            let mapping = map_tasks(&tr, MappingPolicy::HAvg);
            for policy in [FitPolicy::FirstFit, FitPolicy::SimilarityFit] {
                for fill in [false, true] {
                    let sol = solve_with_mapping(&tr, &mapping, policy, fill);
                    assert!(sol.verify(&tr).is_ok(), "seed {seed} {policy:?} fill={fill}");
                }
            }
        }
    }

    #[test]
    fn filling_never_costs_more() {
        for seed in 0..5 {
            let inst = generate(&SynthParams { n: 150, m: 6, ..Default::default() }, seed + 50);
            let tr = trim(&inst).instance;
            let mapping = map_tasks(&tr, MappingPolicy::HAvg);
            let plain = solve_with_mapping(&tr, &mapping, FitPolicy::FirstFit, false);
            let filled = solve_with_mapping(&tr, &mapping, FitPolicy::FirstFit, true);
            assert!(
                filled.cost(&tr) <= plain.cost(&tr) + 1e-9,
                "seed {seed}: fill {} > plain {}",
                filled.cost(&tr),
                plain.cost(&tr)
            );
        }
    }

    #[test]
    fn parallel_solve_matches_sequential_numbering() {
        // n >= PARALLEL_MIN_TASKS exercises the scoped-thread branch; the
        // dense sequential reference must agree node-for-node
        let inst = generate(&SynthParams { n: 600, m: 6, ..Default::default() }, 9);
        let tr = trim(&inst).instance;
        let mapping = map_tasks(&tr, MappingPolicy::HAvg);
        let par = solve_with_mapping(&tr, &mapping, FitPolicy::FirstFit, false);
        let seq = solve_with_mapping_ref(&tr, &mapping, FitPolicy::FirstFit);
        assert_eq!(par.nodes.len(), seq.nodes.len());
        for (a, b) in par.nodes.iter().zip(&seq.nodes) {
            assert_eq!(a.type_idx, b.type_idx);
            assert_eq!(a.purchase_order, b.purchase_order);
            assert_eq!(a.tasks, b.tasks);
        }
        assert_eq!(par.assignment, seq.assignment);
    }
}
