//! The two-phase solve framework shared by every mapping strategy
//! (paper Figures 3 and 6): partition tasks by their mapped node-type,
//! place each group greedily, and optionally run cross-node-type filling.

use crate::model::{Instance, Solution};

use super::fill;
use super::placement::{place_group, to_solution, FitPolicy};

/// Solve with a given task -> node-type mapping.
///
/// Without filling, node-types are independent and processed in index
/// order (paper Figure 3). With filling, they are processed in decreasing
/// capacity-per-cost order and leftover capacity is offered to the tasks
/// of later node-types (paper Figure 6).
pub fn solve_with_mapping(
    inst: &Instance,
    mapping: &[usize],
    policy: FitPolicy,
    cross_fill: bool,
) -> Solution {
    assert_eq!(mapping.len(), inst.n_tasks());
    if cross_fill {
        return fill::solve_with_filling(inst, mapping, policy);
    }
    let m = inst.n_types();
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); m];
    for (u, &b) in mapping.iter().enumerate() {
        groups[b].push(u);
    }
    let mut seq = 0usize;
    let placed: Vec<_> = (0..m)
        .map(|b| place_group(inst, b, &groups[b], policy, &mut seq))
        .collect();
    to_solution(inst, placed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::penalty_map::{map_tasks, MappingPolicy};
    use crate::io::synth::{generate, SynthParams};
    use crate::model::trim;

    #[test]
    fn produces_feasible_solutions() {
        for seed in 0..5 {
            let inst = generate(&SynthParams { n: 120, m: 5, ..Default::default() }, seed);
            let tr = trim(&inst).instance;
            let mapping = map_tasks(&tr, MappingPolicy::HAvg);
            for policy in [FitPolicy::FirstFit, FitPolicy::SimilarityFit] {
                for fill in [false, true] {
                    let sol = solve_with_mapping(&tr, &mapping, policy, fill);
                    assert!(sol.verify(&tr).is_ok(), "seed {seed} {policy:?} fill={fill}");
                }
            }
        }
    }

    #[test]
    fn filling_never_costs_more() {
        for seed in 0..5 {
            let inst = generate(&SynthParams { n: 150, m: 6, ..Default::default() }, seed + 50);
            let tr = trim(&inst).instance;
            let mapping = map_tasks(&tr, MappingPolicy::HAvg);
            let plain = solve_with_mapping(&tr, &mapping, FitPolicy::FirstFit, false);
            let filled = solve_with_mapping(&tr, &mapping, FitPolicy::FirstFit, true);
            assert!(
                filled.cost(&tr) <= plain.cost(&tr) + 1e-9,
                "seed {seed}: fill {} > plain {}",
                filled.cost(&tr),
                plain.cost(&tr)
            );
        }
    }
}
