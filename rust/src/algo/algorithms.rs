//! The four evaluated algorithms, with the paper's best-of-policy
//! reporting convention (section VI-A): PenaltyMap and PenaltyMap-F take
//! the minimum over {h_avg, h_max} x {first-fit, similarity-fit};
//! LP-map and LP-map-F over the two fitting policies.
//!
//! [`Algorithm`] is a thin shim over the named pipeline presets in
//! [`super::pipeline`]; the free functions below are the original direct
//! code paths, kept as the reference implementations the preset
//! property tests (`tests/prop_pipeline.rs`) pin bit-identity against.

use anyhow::Result;

use crate::lp::solver::MappingSolver;
use crate::model::{Instance, Solution};

use super::lpmap::LpMapReport;
use super::penalty_map::{map_tasks, MappingPolicy};
use super::placement::FitPolicy;
use super::twophase::solve_with_mapping;

/// Which algorithm (figure legend names).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Algorithm {
    PenaltyMap,
    PenaltyMapF,
    LpMap,
    LpMapF,
}

impl Algorithm {
    pub fn label(&self) -> &'static str {
        match self {
            Algorithm::PenaltyMap => "PenaltyMap",
            Algorithm::PenaltyMapF => "PenaltyMap-F",
            Algorithm::LpMap => "LP-map",
            Algorithm::LpMapF => "LP-map-F",
        }
    }

    pub fn uses_lp(&self) -> bool {
        matches!(self, Algorithm::LpMap | Algorithm::LpMapF)
    }

    pub fn all() -> [Algorithm; 4] {
        [Algorithm::PenaltyMap, Algorithm::PenaltyMapF, Algorithm::LpMap, Algorithm::LpMapF]
    }

    /// Name of the pipeline preset this algorithm is a shim over.
    pub fn preset_name(&self) -> &'static str {
        match self {
            Algorithm::PenaltyMap => "penalty-map",
            Algorithm::PenaltyMapF => "penalty-map-f",
            Algorithm::LpMap => "lp-map",
            Algorithm::LpMapF => "lp-map-f",
        }
    }

    /// The equivalent composable pipeline (see [`super::pipeline`]).
    pub fn pipeline(&self) -> super::pipeline::Pipeline {
        super::pipeline::preset(self.preset_name()).expect("preset exists")
    }
}

const FITS: [FitPolicy; 2] = [FitPolicy::FirstFit, FitPolicy::SimilarityFit];
const MAPS: [MappingPolicy; 2] = [MappingPolicy::HAvg, MappingPolicy::HMax];

/// First-wins minimum: the earliest candidate with the (NaN-safe) least
/// cost — the same shared selection rule the pipeline layer uses.
fn best_solution(inst: &Instance, mut candidates: Vec<Solution>) -> Solution {
    let i = crate::util::stats::argmin_f64(candidates.iter().map(|s| s.cost(inst)))
        .expect("at least one candidate");
    candidates.swap_remove(i)
}

/// PenaltyMap / PenaltyMap-F: min over four policy combinations.
pub fn penalty_map_best(inst: &Instance, cross_fill: bool) -> Solution {
    let mut candidates = Vec::with_capacity(4);
    for mp in MAPS {
        let mapping = map_tasks(inst, mp);
        for fit in FITS {
            candidates.push(solve_with_mapping(inst, &mapping, fit, cross_fill));
        }
    }
    best_solution(inst, candidates)
}

/// LP-map / LP-map-F from a precomputed LP outcome: min over the two
/// fitting policies (no additional LP solves).
pub fn lp_place_best(
    inst: &Instance,
    outcome: &super::lpmap::LpOutcome,
    cross_fill: bool,
) -> Solution {
    let candidates = FITS
        .iter()
        .map(|&fit| super::lpmap::place_lp_outcome(inst, outcome, fit, cross_fill))
        .collect();
    best_solution(inst, candidates)
}

/// LP-map / LP-map-F: one LP solve, then min over the two fitting
/// policies. Returns the best report (solution + LP diagnostics).
pub fn lp_map_best(
    inst: &Instance,
    solver: &dyn MappingSolver,
    cross_fill: bool,
) -> Result<LpMapReport> {
    let outcome = super::lpmap::solve_lp_mapping(inst, solver)?;
    let solution = lp_place_best(inst, &outcome, cross_fill);
    Ok(LpMapReport {
        solution,
        mapping: outcome.mapping,
        lp_objective: outcome.lp_objective,
        certified_lb: outcome.certified_lb,
        x_max: outcome.x_max,
        solver_iterations: outcome.solver_iterations,
        solver_converged: outcome.solver_converged,
    })
}

/// Dispatch by algorithm enum; returns (solution, optional LP report).
/// A thin shim over the pipeline presets: the enum names a pipeline,
/// the pipeline does the work.
pub fn run(
    inst: &Instance,
    algo: Algorithm,
    solver: &dyn MappingSolver,
) -> Result<(Solution, Option<LpMapReport>)> {
    let rep = algo.pipeline().run(inst, solver)?;
    let (solution, certified_lb, lp) = (rep.solution, rep.certified_lb, rep.lp);
    let lp_report = lp.map(|stats| LpMapReport {
        solution: solution.clone(),
        mapping: stats.mapping,
        lp_objective: stats.objective,
        certified_lb: certified_lb.expect("LP pipelines certify a bound"),
        x_max: stats.x_max,
        solver_iterations: stats.iterations,
        solver_converged: stats.converged,
    });
    Ok((solution, lp_report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};
    use crate::lp::solver::NativePdhgSolver;
    use crate::model::trim;

    #[test]
    fn all_algorithms_feasible_and_ordered() {
        let inst = generate(&SynthParams { n: 150, m: 6, ..Default::default() }, 33);
        let tr = trim(&inst).instance;
        let solver = NativePdhgSolver::default();
        let mut costs = std::collections::BTreeMap::new();
        for algo in Algorithm::all() {
            let (sol, rep) = run(&tr, algo, &solver).unwrap();
            assert!(sol.verify(&tr).is_ok(), "{algo:?}");
            costs.insert(algo, sol.cost(&tr));
            if let Some(rep) = rep {
                assert!(rep.certified_lb <= sol.cost(&tr) + 1e-6, "{algo:?}");
            }
        }
        // filling variants never lose to their plain versions here
        assert!(costs[&Algorithm::PenaltyMapF] <= costs[&Algorithm::PenaltyMap] + 1e-9);
        assert!(costs[&Algorithm::LpMapF] <= costs[&Algorithm::LpMap] + 1e-9);
    }
}
