//! Partition-decomposed solves for million-task instances.
//!
//! One monolithic solve means one mapping LP over *all* tasks and greedy
//! placement whose candidate scans grow with the whole node pool. At
//! n = 10^6 that is the scaling wall. A decomposed solve splits the task
//! set with a pluggable [`Partitioner`], solves each partition
//! *concurrently* on the worker pool through the unchanged
//! [`Portfolio`] API (each partition gets its own trimmed sub-instance,
//! its own shared-LP race, its own certified bound), concatenates the
//! per-partition solutions, and runs the stitching cross-fill pass
//! (`fill::stitch_fill`) over the merged node pool to reclaim the
//! leftover capacity the partition boundaries fragmented.
//!
//! ## The combined certificate
//!
//! Two different sums are worth telling apart, because only one of them
//! is a global lower bound:
//!
//! * **`sum_lb` = Σ_P lb(P)** is the *decomposition certificate*: a
//!   valid lower bound on any plan in which partitions do not share
//!   nodes — in particular on the merged, pre-stitch solution
//!   (`pre_stitch_cost >= sum_lb` always). It is **not** a bound on the
//!   global optimum: nodes persist the whole horizon, so an optimal
//!   plan may reuse one node across time-disjoint partitions and beat
//!   the sum.
//! * **`certified_lb` = max(max_P lb(P), congestion(whole))** is the
//!   *globally valid* certificate this report exposes as such.
//!   Restricting any global solution to one partition's tasks yields a
//!   feasible (and no costlier) solution of that partition, so every
//!   per-partition bound individually lower-bounds the global optimum;
//!   Lemma 1's congestion bound over the whole instance is valid by
//!   construction and computed instance-direct
//!   (`lp::dual::congestion_bound_instance`) to avoid materializing the
//!   n·S·m·D ratio table of a full mapping LP.
//!
//! Reported costs always satisfy `certified_lb <= cost <= pre_stitch
//! cost`, and stitching can push `cost` below `sum_lb` — that is the
//! node-sharing the per-partition certificate cannot see, working as
//! intended.

use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::lp::dual::congestion_bound_instance;
use crate::lp::solver::MappingSolver;
use crate::model::{trim, Instance, Solution};
use crate::util::pool::run_indexed;

use super::fill::stitch_fill;
use super::pipeline::{Portfolio, StageTime};
use super::placement::FitPolicy;
use super::segregate::{merge_solutions, split_small_large, sub_instance};

/// Untrusted-spec cap on the partition count (mirrors the grammar caps
/// from the workload/portfolio parsers): service clients must not be
/// able to request an absurd fan-out.
pub const MAX_PARTITIONS: usize = 64;

/// Grammar accepted by [`parse_decompose`] (printed by its errors and
/// the CLI usage text).
pub const DECOMPOSE_GRAMMAR: &str = "\
decompose spec grammar:
  window[:k]   k near-equal chunks in task start order (default k=8)
  dims[:k]     group by dominant demand dimension; k keeps the k-1
               largest groups and merges the rest (default: one group
               per dimension)
  size[:k]     segregate large tasks, window-chunk the small ones into
               k-1 groups (default k=2)
constraints: 1 <= k <= 64, and k must not exceed the task count";

/// Which partitioning family to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionKind {
    /// Near-equal chunks in (start, index) order — the DVBP-style
    /// time-window axis; best when load is spread over the horizon.
    Window,
    /// Group by dominant demand dimension, so each sub-solve packs
    /// tasks that contend on the same resource.
    Dims,
    /// Segregate-style: large tasks (which dominate node purchases)
    /// solved apart from the smalls.
    Size,
}

/// A parsed `--decompose` / service `decompose` value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecomposeSpec {
    pub kind: PartitionKind,
    /// Requested partition count; `None` means the family default.
    pub k: Option<usize>,
}

impl DecomposeSpec {
    /// The partition count this spec asks for (family default applied).
    /// `Dims` without `k` is data-dependent (one group per dimension),
    /// reported as `None`.
    pub fn requested_k(&self) -> Option<usize> {
        match (self.kind, self.k) {
            (_, Some(k)) => Some(k),
            (PartitionKind::Window, None) => Some(8),
            (PartitionKind::Size, None) => Some(2),
            (PartitionKind::Dims, None) => None,
        }
    }

    /// The partitioner implementing this spec.
    pub fn partitioner(&self) -> Box<dyn Partitioner> {
        match self.kind {
            PartitionKind::Window => {
                Box::new(WindowPartitioner { k: self.requested_k().unwrap() })
            }
            PartitionKind::Dims => Box::new(DimsPartitioner { k: self.k }),
            PartitionKind::Size => {
                Box::new(SizePartitioner { k: self.requested_k().unwrap() })
            }
        }
    }
}

impl std::fmt::Display for DecomposeSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = match self.kind {
            PartitionKind::Window => "window",
            PartitionKind::Dims => "dims",
            PartitionKind::Size => "size",
        };
        match self.k {
            Some(k) => write!(f, "{kind}:{k}"),
            None => write!(f, "{kind}"),
        }
    }
}

/// Parse `window|dims|size[:k]`. Degenerate counts (k = 0, k beyond
/// [`MAX_PARTITIONS`]) are rejected here — errors, not clamped solves;
/// the task-count check needs the instance and lives in
/// [`partition_tasks`].
pub fn parse_decompose(spec: &str) -> Result<DecomposeSpec> {
    let spec = spec.trim();
    let (head, k) = match spec.split_once(':') {
        None => (spec, None),
        Some((head, ks)) => {
            let k: usize = ks.trim().parse().map_err(|_| {
                anyhow::anyhow!(
                    "decompose spec '{spec}': '{ks}' is not a partition count\n{DECOMPOSE_GRAMMAR}"
                )
            })?;
            (head.trim(), Some(k))
        }
    };
    let kind = match head {
        "window" => PartitionKind::Window,
        "dims" => PartitionKind::Dims,
        "size" => PartitionKind::Size,
        other => bail!("decompose spec '{spec}': unknown partitioner '{other}'\n{DECOMPOSE_GRAMMAR}"),
    };
    if let Some(k) = k {
        ensure!(k >= 1, "decompose spec '{spec}': k must be >= 1\n{DECOMPOSE_GRAMMAR}");
        ensure!(
            k <= MAX_PARTITIONS,
            "decompose spec '{spec}': k = {k} exceeds the cap of {MAX_PARTITIONS}\n{DECOMPOSE_GRAMMAR}"
        );
    }
    Ok(DecomposeSpec { kind, k })
}

/// A task-set partitioning strategy. Implementations must emit
/// non-empty, disjoint, covering parts — [`solve_decomposed`] re-checks
/// all three and errors (rather than solving a degenerate instance) on
/// violation, so a buggy custom partitioner cannot silently lose or
/// duplicate tasks.
pub trait Partitioner {
    /// Display name for telemetry ("window", "dims", "size", ...).
    fn name(&self) -> &'static str;

    /// Label for partition `i` of the emitted list (telemetry rows).
    fn part_label(&self, i: usize) -> String {
        format!("{}:{i}", self.name())
    }

    /// Split `0..inst.n_tasks()` into non-empty, disjoint, covering
    /// parts.
    fn partition(&self, inst: &Instance) -> Result<Vec<Vec<usize>>>;
}

/// Chunk `order` into `k` near-equal contiguous runs (first `len % k`
/// runs get the extra task). `k` must not exceed `order.len()`.
fn chunk(order: &[usize], k: usize) -> Vec<Vec<usize>> {
    let n = order.len();
    let (base, extra) = (n / k, n % k);
    let mut parts = Vec::with_capacity(k);
    let mut at = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        parts.push(order[at..at + len].to_vec());
        at += len;
    }
    parts
}

/// Guard shared by the built-in partitioners: a requested count must
/// not exceed the task count (an empty chunk is an error, not a
/// degenerate solve).
fn ensure_k_fits(name: &str, k: usize, n: usize) -> Result<()> {
    ensure!(n > 0, "decompose {name}: instance has no tasks");
    ensure!(
        k <= n,
        "decompose {name}:{k}: partition count exceeds the {n} task(s); \
         lower k or solve without --decompose"
    );
    Ok(())
}

/// Near-equal chunks in (start, index) order.
pub struct WindowPartitioner {
    pub k: usize,
}

impl Partitioner for WindowPartitioner {
    fn name(&self) -> &'static str {
        "window"
    }

    fn partition(&self, inst: &Instance) -> Result<Vec<Vec<usize>>> {
        ensure_k_fits(self.name(), self.k, inst.n_tasks())?;
        let mut order: Vec<usize> = (0..inst.n_tasks()).collect();
        order.sort_by_key(|&u| (inst.tasks[u].start, u));
        Ok(chunk(&order, self.k))
    }
}

/// Group tasks by dominant demand dimension: `argmax_d peak(u, d) /
/// cap_ref(d)` with the mean per-dimension capacity over node-types as
/// the reference scale (first dimension wins ties). With `k`, the k-1
/// largest groups are kept and the rest merge into one.
pub struct DimsPartitioner {
    pub k: Option<usize>,
}

impl Partitioner for DimsPartitioner {
    fn name(&self) -> &'static str {
        "dims"
    }

    fn partition(&self, inst: &Instance) -> Result<Vec<Vec<usize>>> {
        let n = inst.n_tasks();
        ensure_k_fits(self.name(), self.k.unwrap_or(1), n)?;
        let dims = inst.dims();
        let m = inst.n_types() as f64;
        let cap_ref: Vec<f64> = (0..dims)
            .map(|d| inst.node_types.iter().map(|nt| nt.capacity[d]).sum::<f64>() / m)
            .collect();
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); dims];
        for (u, task) in inst.tasks.iter().enumerate() {
            let peak = task.peak();
            let mut sig = 0usize;
            let mut best = f64::NEG_INFINITY;
            for d in 0..dims {
                let v = peak[d] / cap_ref[d];
                if v > best {
                    best = v;
                    sig = d;
                }
            }
            groups[sig].push(u);
        }
        let mut parts: Vec<Vec<usize>> = groups.into_iter().filter(|g| !g.is_empty()).collect();
        if let Some(k) = self.k {
            if parts.len() > k {
                // keep the k-1 largest groups (stable order on ties),
                // merge the tail into one
                let mut by_size: Vec<usize> = (0..parts.len()).collect();
                by_size.sort_by_key(|&i| (std::cmp::Reverse(parts[i].len()), i));
                let keep: std::collections::BTreeSet<usize> =
                    by_size[..k - 1].iter().copied().collect();
                let mut kept = Vec::with_capacity(k);
                let mut rest = Vec::new();
                for (i, g) in parts.into_iter().enumerate() {
                    if keep.contains(&i) {
                        kept.push(g);
                    } else {
                        rest.extend(g);
                    }
                }
                rest.sort_unstable();
                kept.push(rest);
                parts = kept;
            }
        }
        Ok(parts)
    }
}

/// Segregate-style: the large tasks (too big to be "small" for every
/// node-type) in one partition, the smalls window-chunked into `k - 1`.
pub struct SizePartitioner {
    pub k: usize,
}

impl Partitioner for SizePartitioner {
    fn name(&self) -> &'static str {
        "size"
    }

    fn part_label(&self, i: usize) -> String {
        if i == 0 {
            "size:large".into()
        } else {
            format!("size:small:{}", i - 1)
        }
    }

    fn partition(&self, inst: &Instance) -> Result<Vec<Vec<usize>>> {
        ensure_k_fits(self.name(), self.k, inst.n_tasks())?;
        if self.k == 1 {
            // one requested partition is the whole task set: the solve
            // takes the exact non-decomposed sequential path
            return Ok(vec![(0..inst.n_tasks()).collect()]);
        }
        let (mut small, large) = split_small_large(inst);
        // when one side is empty the family degrades to fewer parts —
        // never to an empty part
        if small.is_empty() {
            return Ok(vec![large]);
        }
        let small_parts = (self.k - 1).clamp(1, small.len());
        small.sort_by_key(|&u| (inst.tasks[u].start, u));
        let mut parts = Vec::with_capacity(small_parts + 1);
        if !large.is_empty() {
            parts.push(large);
        }
        parts.extend(chunk(&small, small_parts));
        Ok(parts)
    }
}

/// Validate that `parts` is a true partition of `0..n`: non-empty
/// parts, disjoint, covering. Errors name the first violation.
pub fn validate_partition(n_tasks: usize, parts: &[Vec<usize>]) -> Result<()> {
    ensure!(!parts.is_empty(), "partitioner returned no partitions");
    ensure!(
        parts.len() <= n_tasks.max(1),
        "{} partitions exceed the {n_tasks} task(s)",
        parts.len()
    );
    let mut owner = vec![false; n_tasks];
    let mut covered = 0usize;
    for (i, part) in parts.iter().enumerate() {
        ensure!(!part.is_empty(), "partition {i} is empty");
        for &u in part {
            ensure!(u < n_tasks, "partition {i} references task {u} out of {n_tasks}");
            ensure!(!owner[u], "task {u} appears in more than one partition");
            owner[u] = true;
            covered += 1;
        }
    }
    ensure!(
        covered == n_tasks,
        "partitions cover {covered} of {n_tasks} tasks"
    );
    Ok(())
}

/// Factory producing a per-worker LP solver: each concurrent partition
/// solve gets its own instance, so the factory (not the solver) must be
/// shareable across threads.
pub type SolverFactory<'a> = &'a (dyn Fn() -> Box<dyn MappingSolver> + Sync);

/// Telemetry for one solved partition.
#[derive(Clone, Debug)]
pub struct PartitionReport {
    pub label: String,
    pub n_tasks: usize,
    /// Cost of the partition's winning solution (also its contribution
    /// to the merged pre-stitch cost).
    pub cost: f64,
    /// Certified lower bound for the partition as a standalone
    /// instance: best of the portfolio's LP certificate and the
    /// partition's congestion bound. Individually valid for the *whole*
    /// instance too (see the module docs).
    pub lb: f64,
    pub seconds: f64,
    /// Label of the partition's winning pipeline.
    pub winner: String,
}

/// Result of a decomposed solve.
#[derive(Clone, Debug)]
pub struct DecomposeReport {
    /// The stitched, verified-shape final solution over the input
    /// instance's task indices.
    pub solution: Solution,
    /// Cost of `solution`.
    pub cost: f64,
    /// Globally valid certified bound:
    /// `max(max_P lb(P), congestion(whole instance))`.
    pub certified_lb: f64,
    /// Σ per-partition bounds — the node-disjoint decomposition
    /// certificate (`pre_stitch_cost >= sum_lb`); NOT a global bound.
    pub sum_lb: f64,
    /// Whole-instance Lemma-1 congestion bound (instance-direct).
    pub congestion_lb: f64,
    /// Merged cost before stitching reclaimed cross-partition leftovers.
    pub pre_stitch_cost: f64,
    /// Wall time of the concurrent partition fan-out.
    pub partition_seconds: f64,
    /// Wall time of the stitching refine pass.
    pub stitch_seconds: f64,
    /// Per-partition telemetry, in partition order.
    pub partitions: Vec<PartitionReport>,
    /// Stage timings (partition / solve / merge / stitch), same shape as
    /// `SolveReport::stages`.
    pub stages: Vec<StageTime>,
}

/// The stitch pass runs first-fit relocation: deterministic, cheapest
/// per probe, and the similarity objective adds nothing when the only
/// question is "does the victim drain completely".
const STITCH_POLICY: FitPolicy = FitPolicy::FirstFit;

/// Solve `inst` decomposed: partition, solve partitions concurrently
/// through the unchanged portfolio API, merge, stitch.
///
/// A single-partition spec routes the outer instance directly through
/// `portfolio.run_sequential` — bit-identical to a non-decomposed
/// sequential solve (no sub-instance relabeling, no stitch pass).
pub fn solve_decomposed(
    inst: &Instance,
    portfolio: &Portfolio,
    make_solver: SolverFactory,
    spec: &DecomposeSpec,
) -> Result<DecomposeReport> {
    let partitioner = spec.partitioner();
    // lint:allow(wallclock): stage telemetry only — never feeds a decision
    let t_part = Instant::now();
    let parts = partitioner.partition(inst)?;
    validate_partition(inst.n_tasks(), &parts)?;
    let partition_prep = t_part.elapsed().as_secs_f64();

    if parts.len() == 1 {
        // lint:allow(wallclock): stage telemetry only — never feeds a decision
        let t0 = Instant::now();
        let rep = portfolio.run_sequential(inst, make_solver().as_ref())?;
        let secs = t0.elapsed().as_secs_f64();
        let best = rep.best();
        let congestion_lb = congestion_bound_instance(inst);
        let lb = rep.certified_lb().unwrap_or(0.0).max(congestion_lb);
        return Ok(DecomposeReport {
            solution: best.solution.clone(),
            cost: best.cost,
            certified_lb: lb,
            sum_lb: lb,
            congestion_lb,
            pre_stitch_cost: best.cost,
            partition_seconds: secs,
            stitch_seconds: 0.0,
            partitions: vec![PartitionReport {
                label: partitioner.part_label(0),
                n_tasks: inst.n_tasks(),
                cost: best.cost,
                lb,
                seconds: secs,
                winner: best.label.clone(),
            }],
            stages: vec![
                StageTime { stage: "partition".into(), seconds: partition_prep },
                StageTime { stage: "solve".into(), seconds: secs },
            ],
        });
    }

    // concurrent per-partition solves: each worker trims its
    // sub-instance and races the full portfolio sequentially (the
    // parallelism budget is spent across partitions, not within one)
    // lint:allow(wallclock): stage telemetry only — never feeds a decision
    let t_solve = Instant::now();
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let outcomes: Vec<Result<(Solution, f64, f64, f64, String)>> =
        run_indexed(parts.len(), workers.min(parts.len()), |i| {
            // lint:allow(wallclock): stage telemetry only — never feeds a decision
            let t0 = Instant::now();
            let sub = sub_instance(inst, &parts[i]);
            let sub = trim(&sub).instance;
            let rep = portfolio.run_sequential(&sub, make_solver().as_ref())?;
            let lb = rep
                .certified_lb()
                .unwrap_or(0.0)
                .max(congestion_bound_instance(&sub));
            let best = rep.best();
            Ok((
                best.solution.clone(),
                best.cost,
                lb,
                t0.elapsed().as_secs_f64(),
                best.label.clone(),
            ))
        });
    let mut solved = Vec::with_capacity(parts.len());
    for (i, outcome) in outcomes.into_iter().enumerate() {
        match outcome {
            Ok(v) => solved.push(v),
            Err(e) => bail!("partition {} ({}): {e}", i, partitioner.part_label(i)),
        }
    }
    let partition_seconds = t_solve.elapsed().as_secs_f64();

    // merge: concatenate per-partition node pools, remapping task ids
    // lint:allow(wallclock): stage telemetry only — never feeds a decision
    let t_merge = Instant::now();
    let merge_parts: Vec<(&[usize], &Solution)> = parts
        .iter()
        .zip(&solved)
        .map(|(keep, (sol, ..))| (keep.as_slice(), sol))
        .collect();
    let merged = merge_solutions(inst, &merge_parts);
    let pre_stitch_cost = merged.cost(inst);
    let merge_seconds = t_merge.elapsed().as_secs_f64();

    // stitch: parallel per-type compaction + cross-type piggyback over
    // the merged pool — the refine pass that lets partitions share nodes
    // lint:allow(wallclock): stage telemetry only — never feeds a decision
    let t_stitch = Instant::now();
    let stitched = stitch_fill(inst, &merged, STITCH_POLICY);
    let cost = stitched.cost(inst);
    let stitch_seconds = t_stitch.elapsed().as_secs_f64();

    let congestion_lb = congestion_bound_instance(inst);
    let mut sum_lb = 0.0;
    let mut max_lb: f64 = 0.0;
    let partitions: Vec<PartitionReport> = solved
        .iter()
        .enumerate()
        .map(|(i, (_, pcost, plb, psecs, winner))| {
            sum_lb += plb;
            max_lb = max_lb.max(*plb);
            PartitionReport {
                label: partitioner.part_label(i),
                n_tasks: parts[i].len(),
                cost: *pcost,
                lb: *plb,
                seconds: *psecs,
                winner: winner.clone(),
            }
        })
        .collect();
    let certified_lb = max_lb.max(congestion_lb);
    debug_assert!(
        pre_stitch_cost >= sum_lb - 1e-6 * (1.0 + sum_lb.abs()),
        "node-disjoint certificate violated: merged {pre_stitch_cost} < sum of bounds {sum_lb}"
    );

    Ok(DecomposeReport {
        solution: stitched,
        cost,
        certified_lb,
        sum_lb,
        congestion_lb,
        pre_stitch_cost,
        partition_seconds,
        stitch_seconds,
        partitions,
        stages: vec![
            StageTime { stage: "partition".into(), seconds: partition_prep },
            StageTime { stage: "solve".into(), seconds: partition_seconds },
            StageTime { stage: "merge".into(), seconds: merge_seconds },
            StageTime { stage: "stitch".into(), seconds: stitch_seconds },
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::pipeline::parse_portfolio;
    use crate::io::synth::{generate, SynthParams};
    use crate::lp::solver::NativePdhgSolver;

    fn factory() -> Box<dyn MappingSolver> {
        Box::new(NativePdhgSolver::default())
    }

    fn test_instance(seed: u64, n: usize) -> Instance {
        let inst = generate(&SynthParams { n, m: 4, ..Default::default() }, seed);
        trim(&inst).instance
    }

    #[test]
    fn parse_accepts_grammar() {
        assert_eq!(
            parse_decompose("window").unwrap(),
            DecomposeSpec { kind: PartitionKind::Window, k: None }
        );
        assert_eq!(
            parse_decompose("size:3").unwrap(),
            DecomposeSpec { kind: PartitionKind::Size, k: Some(3) }
        );
        assert_eq!(parse_decompose("dims:5").unwrap().requested_k(), Some(5));
        assert_eq!(parse_decompose(" window : 4 ").unwrap().k, Some(4));
        assert_eq!(parse_decompose("window").unwrap().to_string(), "window");
        assert_eq!(parse_decompose("dims:2").unwrap().to_string(), "dims:2");
    }

    #[test]
    fn parse_rejects_degenerate_counts() {
        assert!(parse_decompose("window:0").is_err());
        assert!(parse_decompose("window:65").is_err());
        assert!(parse_decompose("window:x").is_err());
        assert!(parse_decompose("shard:4").is_err());
        assert!(parse_decompose("").is_err());
        let msg = format!("{:#}", parse_decompose("window:0").unwrap_err());
        assert!(msg.contains("grammar"), "error teaches the grammar: {msg}");
    }

    #[test]
    fn partitions_are_disjoint_and_covering() {
        let inst = test_instance(11, 90);
        for spec in ["window:5", "dims", "dims:2", "size:3", "size"] {
            let spec = parse_decompose(spec).unwrap();
            let parts = spec.partitioner().partition(&inst).unwrap();
            validate_partition(inst.n_tasks(), &parts).unwrap();
            for part in &parts {
                assert!(!part.is_empty());
            }
        }
    }

    #[test]
    fn partition_count_exceeding_tasks_is_error() {
        let inst = test_instance(3, 5);
        let spec = parse_decompose("window:8").unwrap();
        let err = spec.partitioner().partition(&inst).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
        let spec = parse_decompose("size:8").unwrap();
        assert!(spec.partitioner().partition(&inst).is_err());
    }

    #[test]
    fn validate_rejects_malformed_partitions() {
        assert!(validate_partition(3, &[vec![0, 1, 2], vec![]]).is_err());
        assert!(validate_partition(3, &[vec![0, 1]]).is_err());
        assert!(validate_partition(3, &[vec![0, 1], vec![1, 2]]).is_err());
        assert!(validate_partition(3, &[vec![0, 1], vec![2, 7]]).is_err());
        assert!(validate_partition(3, &[]).is_err());
        assert!(validate_partition(3, &[vec![0], vec![1], vec![2]]).is_ok());
    }

    #[test]
    fn decomposed_solves_verify_and_bound_holds() {
        let inst = test_instance(17, 120);
        let portfolio = parse_portfolio("penalty-map,penalty-map-f").unwrap();
        for spec in ["window:4", "dims", "size:2"] {
            let spec = parse_decompose(spec).unwrap();
            let rep = solve_decomposed(&inst, &portfolio, &factory, &spec).unwrap();
            assert!(rep.solution.verify(&inst).is_ok(), "{spec:?}");
            assert!(
                rep.certified_lb <= rep.cost + 1e-6,
                "{spec:?}: lb {} > cost {}",
                rep.certified_lb,
                rep.cost
            );
            assert!(rep.cost <= rep.pre_stitch_cost + 1e-9);
            assert!(
                rep.pre_stitch_cost >= rep.sum_lb - 1e-6,
                "{spec:?}: node-disjoint certificate"
            );
            assert_eq!(
                rep.partitions.iter().map(|p| p.n_tasks).sum::<usize>(),
                inst.n_tasks()
            );
            assert!(rep.stages.iter().any(|s| s.stage == "stitch"));
        }
    }

    #[test]
    fn single_partition_matches_sequential_portfolio() {
        let inst = test_instance(23, 80);
        let portfolio = parse_portfolio("penalty-map,lp-map").unwrap();
        let spec = parse_decompose("window:1").unwrap();
        let rep = solve_decomposed(&inst, &portfolio, &factory, &spec).unwrap();
        let direct = portfolio.run_sequential(&inst, &NativePdhgSolver::default()).unwrap();
        let best = direct.best();
        assert_eq!(rep.solution.assignment, best.solution.assignment);
        assert_eq!(rep.solution.nodes.len(), best.solution.nodes.len());
        assert_eq!(rep.cost.to_bits(), best.cost.to_bits());
        assert_eq!(rep.partitions.len(), 1);
        assert!((rep.stitch_seconds - 0.0).abs() < 1e-12);
    }
}
