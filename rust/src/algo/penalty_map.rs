//! PenaltyMap: the paper's baseline two-phase algorithm (section III).
//!
//! Mapping phase: each task goes to the node-type minimizing the penalty
//! `p(u|B) = cost(B) * h(u|B)` where the relative demand `h` is either the
//! dimension-average (`h_avg`) or the dimension-max (`h_max`). With
//! piecewise demand profiles the two aggregates generalize naturally:
//! `h_avg` uses the *time-averaged* demand (a task's expected congestion
//! contribution) and `h_max` the *peak* demand (its worst-case
//! footprint); both reduce to the seed's constant-demand formulas on flat
//! tasks. Admissibility is always a peak property.
//! Placement phase: per node-type greedy placement (placement.rs).

use crate::model::Instance;

/// Which relative-demand aggregate drives the penalty.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MappingPolicy {
    HAvg,
    HMax,
}

/// Dense n×m matrix of `h_avg(u|B)` values, computed once per cross-fill
/// solve as the cached piggy-back sort key (the seed re-derived the O(D)
/// aggregate inside the sort comparator). `penalty_matrix` below already
/// touches each (u, B) pair exactly once and needs no shared cache.
pub fn h_avg_matrix(inst: &Instance) -> Vec<f64> {
    let (n, m) = (inst.n_tasks(), inst.n_types());
    let mut h = vec![0.0f64; n * m];
    for u in 0..n {
        for b in 0..m {
            h[u * m + b] = inst.h_avg(u, b);
        }
    }
    h
}

/// One penalty cell `p(u|B) = cost(B) * h(u|B)` — the single source of
/// the mapping rule shared by the matrix (preset paths) and the per-task
/// argmin (online/session admit paths). `+inf` when node-type `b` cannot
/// admit the task alone (a peak-demand property).
#[inline]
pub fn penalty(inst: &Instance, u: usize, b: usize, policy: MappingPolicy) -> f64 {
    if !inst.node_types[b].admits(inst.tasks[u].peak()) {
        return f64::INFINITY;
    }
    let h = match policy {
        MappingPolicy::HAvg => inst.h_avg(u, b),
        MappingPolicy::HMax => inst.h_max(u, b),
    };
    inst.node_types[b].cost * h
}

/// Penalty matrix p[u*m + b] for the chosen policy. Inadmissible pairs
/// (demand exceeding capacity in some dimension) get +inf so the argmin
/// never maps a task onto a node-type it cannot fit alone.
pub fn penalty_matrix(inst: &Instance, policy: MappingPolicy) -> Vec<f64> {
    let (n, m) = (inst.n_tasks(), inst.n_types());
    let mut p = vec![f64::INFINITY; n * m];
    for u in 0..n {
        for b in 0..m {
            p[u * m + b] = penalty(inst, u, b, policy);
        }
    }
    p
}

/// Minimum penalty per task, `p*(u)` — the congestion-bound ingredient
/// (paper Lemma 1).
pub fn min_penalties(inst: &Instance, policy: MappingPolicy) -> Vec<f64> {
    let m = inst.n_types();
    penalty_matrix(inst, policy)
        .chunks(m)
        .map(|row| row.iter().copied().fold(f64::INFINITY, f64::min))
        .collect()
}

/// Penalty-argmin node-type for a single task — the per-arrival variant
/// of [`map_tasks`] (identical strict-less / first-wins rule), used by
/// the incremental admit path where recomputing the full n×m matrix per
/// delta would be wasteful. `None` when no node-type admits the task.
pub fn best_type(inst: &Instance, u: usize, policy: MappingPolicy) -> Option<usize> {
    let mut best = f64::INFINITY;
    let mut arg = None;
    for b in 0..inst.n_types() {
        let p = penalty(inst, u, b, policy);
        if p < best {
            best = p;
            arg = Some(b);
        }
    }
    arg
}

/// The penalty-based mapping: task -> argmin_B p(u|B).
pub fn map_tasks(inst: &Instance, policy: MappingPolicy) -> Vec<usize> {
    let m = inst.n_types();
    let p = penalty_matrix(inst, policy);
    (0..inst.n_tasks())
        .map(|u| {
            let row = &p[u * m..(u + 1) * m];
            let (mut best, mut arg) = (f64::INFINITY, usize::MAX);
            for (b, &v) in row.iter().enumerate() {
                if v < best {
                    best = v;
                    arg = b;
                }
            }
            assert!(arg != usize::MAX, "task {u} fits no node-type");
            arg
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NodeType, Task};

    fn inst() -> Instance {
        Instance::new(
            vec![
                Task::new(0, vec![0.4, 0.1], 0, 0), // cpu-heavy
                Task::new(1, vec![0.1, 0.4], 0, 0), // mem-heavy
            ],
            vec![
                NodeType::new("cpu", vec![1.0, 0.25], 1.0),
                NodeType::new("mem", vec![0.25, 1.0], 1.0),
            ],
            1,
        )
    }

    #[test]
    fn maps_to_matching_shape() {
        let inst = inst();
        let map = map_tasks(&inst, MappingPolicy::HAvg);
        assert_eq!(map, vec![0, 1]);
        let map = map_tasks(&inst, MappingPolicy::HMax);
        assert_eq!(map, vec![0, 1]);
    }

    #[test]
    fn penalty_values() {
        let inst = inst();
        let p = penalty_matrix(&inst, MappingPolicy::HAvg);
        // task 0 on cpu-type: (0.4/1.0 + 0.1/0.25)/2 = 0.4
        assert!((p[0] - 0.4).abs() < 1e-12);
        // task 0 on mem-type: inadmissible (0.4 > cap 0.25) -> +inf
        assert!(p[1].is_infinite());
        // task 1 on mem-type: (0.1/0.25 + 0.4/1.0)/2 = 0.4
        assert!((p[3] - 0.4).abs() < 1e-12);
    }

    #[test]
    fn inadmissible_pair_excluded() {
        let inst = Instance::new(
            vec![Task::new(0, vec![0.5, 0.5], 0, 0)],
            vec![
                NodeType::new("small", vec![0.4, 1.0], 0.1),
                NodeType::new("big", vec![1.0, 1.0], 5.0),
            ],
            1,
        );
        // cheap type can't hold the task; must map to the big one
        assert_eq!(map_tasks(&inst, MappingPolicy::HAvg), vec![1]);
        let p = penalty_matrix(&inst, MappingPolicy::HAvg);
        assert!(p[0].is_infinite());
    }

    #[test]
    fn cost_breaks_ties() {
        let inst = Instance::new(
            vec![Task::new(0, vec![0.1], 0, 0)],
            vec![
                NodeType::new("expensive", vec![1.0], 10.0),
                NodeType::new("cheap", vec![1.0], 1.0),
            ],
            1,
        );
        assert_eq!(map_tasks(&inst, MappingPolicy::HAvg), vec![1]);
    }

    #[test]
    fn best_type_matches_map_tasks() {
        let inst = inst();
        for policy in [MappingPolicy::HAvg, MappingPolicy::HMax] {
            let full = map_tasks(&inst, policy);
            for u in 0..inst.n_tasks() {
                assert_eq!(best_type(&inst, u, policy), Some(full[u]), "{policy:?} task {u}");
            }
        }
        // a task nothing admits maps to None instead of panicking
        let tight = Instance::new(
            vec![Task::new(0, vec![2.0], 0, 0)],
            vec![NodeType::new("a", vec![1.0], 1.0)],
            1,
        );
        assert_eq!(best_type(&tight, 0, MappingPolicy::HAvg), None);
    }

    #[test]
    fn min_penalties_are_row_minima() {
        let inst = inst();
        let mp = min_penalties(&inst, MappingPolicy::HAvg);
        assert!((mp[0] - 0.4).abs() < 1e-12);
        assert!((mp[1] - 0.4).abs() < 1e-12);
    }
}
