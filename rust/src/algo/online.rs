//! Online TL-Rightsizing baseline: tasks arrive in start-time order and
//! must be placed immediately (online interval coloring with bandwidths,
//! the paper's second prior-work stream, generalized to multiple
//! dimensions and node-types). No remapping, no lookahead: each arrival
//! is mapped by the penalty rule and first-fit into the purchased pool,
//! buying a new node of its penalty-best type when nothing fits.
//!
//! Serves as an ablation anchor: how much of the offline algorithms' win
//! comes from seeing the whole workload up front.

use anyhow::{Context, Result};

use crate::model::{Instance, Solution};

use super::penalty_map::{best_type, MappingPolicy};
use super::placement::FitPolicy;
use super::repair::Pool;

/// Place tasks online (start order, ties by index). Cross-type reuse is
/// allowed on arrival — the online player may use any open node.
///
/// Runs on the shared [`Pool`] repair engine, so an arrival no node-type
/// admits is an `Err` instead of a process-aborting assert — this path
/// serves inside the planning service, where bad input must never take
/// the process down.
pub fn solve_online(inst: &Instance, policy: FitPolicy) -> Result<Solution> {
    let mut order: Vec<usize> = (0..inst.n_tasks()).collect();
    order.sort_by_key(|&u| (inst.tasks[u].start, u));

    let mut pool = Pool::new();
    for u in order {
        let b = best_type(inst, u, MappingPolicy::HAvg)
            .with_context(|| format!("task {} (id {}) fits no node-type", u, inst.tasks[u].id))?;
        pool.admit_or_buy(inst, u, b, policy)
            .with_context(|| format!("online admission of task {u}"))?;
    }
    Ok(pool.to_solution(inst))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::algorithms::penalty_map_best;
    use crate::io::synth::{generate, SynthParams};
    use crate::model::trim;

    #[test]
    fn online_is_feasible() {
        for seed in 0..5u64 {
            let inst = generate(&SynthParams { n: 100, m: 5, ..Default::default() }, seed);
            let tr = trim(&inst).instance;
            for policy in [FitPolicy::FirstFit, FitPolicy::SimilarityFit] {
                let sol = solve_online(&tr, policy).unwrap();
                assert!(sol.verify(&tr).is_ok(), "seed {seed} {policy:?}");
            }
        }
    }

    #[test]
    fn inadmissible_arrival_is_an_error_not_an_abort() {
        use crate::model::{NodeType, Task};
        // the second arrival exceeds every capacity: a service must get
        // an Err back, not a process abort
        let inst = Instance::new(
            vec![Task::new(0, vec![0.5], 0, 0), Task::new(1, vec![1.5], 0, 0)],
            vec![NodeType::new("a", vec![1.0], 1.0)],
            1,
        );
        let err = solve_online(&inst, FitPolicy::FirstFit).unwrap_err().to_string();
        assert!(err.contains("fits no node-type"), "{err}");
    }

    #[test]
    fn offline_usually_wins() {
        // aggregate over seeds: the offline best-of-policies should not
        // lose to the online player
        let mut online_total = 0.0;
        let mut offline_total = 0.0;
        for seed in 0..5u64 {
            let inst = generate(&SynthParams { n: 150, m: 6, ..Default::default() }, seed + 10);
            let tr = trim(&inst).instance;
            online_total += solve_online(&tr, FitPolicy::FirstFit).unwrap().cost(&tr);
            offline_total += penalty_map_best(&tr, true).cost(&tr);
        }
        assert!(
            offline_total <= online_total + 1e-9,
            "offline {offline_total} vs online {online_total}"
        );
    }
}
