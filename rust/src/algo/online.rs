//! Online TL-Rightsizing baseline: tasks arrive in start-time order and
//! must be placed immediately (online interval coloring with bandwidths,
//! the paper's second prior-work stream, generalized to multiple
//! dimensions and node-types). No remapping, no lookahead: each arrival
//! is mapped by the penalty rule and first-fit into the purchased pool,
//! buying a new node of its penalty-best type when nothing fits.
//!
//! Serves as an ablation anchor: how much of the offline algorithms' win
//! comes from seeing the whole workload up front.

use crate::model::{Instance, Solution};

use super::penalty_map::{map_tasks, MappingPolicy};
use super::placement::{select_node, to_solution, FitPolicy, NodeState};

/// Place tasks online (start order, ties by index). Cross-type reuse is
/// allowed on arrival — the online player may use any open node.
pub fn solve_online(inst: &Instance, policy: FitPolicy) -> Solution {
    let mapping = map_tasks(inst, MappingPolicy::HAvg);
    let mut order: Vec<usize> = (0..inst.n_tasks()).collect();
    order.sort_by_key(|&u| (inst.tasks[u].start, u));

    let mut nodes: Vec<NodeState> = Vec::new();
    let mut seq = 0usize;
    for u in order {
        match select_node(inst, &nodes, u, policy) {
            Some(i) => nodes[i].add(inst, u),
            None => {
                let b = mapping[u];
                let mut node = NodeState::new(inst, b, seq);
                seq += 1;
                assert!(node.fits(inst, u), "mapping must admit task {u}");
                node.add(inst, u);
                nodes.push(node);
            }
        }
    }
    to_solution(inst, vec![nodes])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::algorithms::penalty_map_best;
    use crate::io::synth::{generate, SynthParams};
    use crate::model::trim;

    #[test]
    fn online_is_feasible() {
        for seed in 0..5u64 {
            let inst = generate(&SynthParams { n: 100, m: 5, ..Default::default() }, seed);
            let tr = trim(&inst).instance;
            for policy in [FitPolicy::FirstFit, FitPolicy::SimilarityFit] {
                let sol = solve_online(&tr, policy);
                assert!(sol.verify(&tr).is_ok(), "seed {seed} {policy:?}");
            }
        }
    }

    #[test]
    fn offline_usually_wins() {
        // aggregate over seeds: the offline best-of-policies should not
        // lose to the online player
        let mut online_total = 0.0;
        let mut offline_total = 0.0;
        for seed in 0..5u64 {
            let inst = generate(&SynthParams { n: 150, m: 6, ..Default::default() }, seed + 10);
            let tr = trim(&inst).instance;
            online_total += solve_online(&tr, FitPolicy::FirstFit).cost(&tr);
            offline_total += penalty_map_best(&tr, true).cost(&tr);
        }
        assert!(
            offline_total <= online_total + 1e-9,
            "offline {offline_total} vs online {online_total}"
        );
    }
}
