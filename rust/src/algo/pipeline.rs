//! Composable solver pipelines: the contribution layer as an *open*
//! strategy space instead of a closed four-variant enum.
//!
//! Every paper algorithm is an instance of one shape — map tasks to
//! node-types, place per type, refine — so the pieces are first-class:
//!
//!   * [`MappingStrategy`] produces candidate task → node-type mappings
//!     (penalty argmin over `h_avg`/`h_max`, the LP rounding with its
//!     alternates, or an [`Oracle`] escape hatch for custom mappings),
//!   * [`FitPolicy`] picks the node within a type (shared with
//!     `placement.rs`),
//!   * [`RefinePass`] post-processes a placed candidate ([`CrossFill`]
//!     re-places with cross-node-type filling, [`LocalSearch`] runs the
//!     drain/downgrade loop no preset could previously reach),
//!   * [`Pipeline`] chains them (`Pipeline::new().map(..).fit(..)
//!     .refine(..)`) and evaluates every (mapping × fit) candidate,
//!     keeping the cheapest with a deterministic first-wins tie-break,
//!   * [`Portfolio`] races pipelines on scoped threads, sharing one LP
//!     outcome across every LP-based pipeline (one solve, N placements —
//!     the same contract `lp_place_best` had) and picking the min-cost
//!     winner with an index tie-break, so the result is independent of
//!     thread scheduling.
//!
//! The four paper algorithms are named [`preset`]s; [`parse`] accepts
//! both preset names and a spec grammar (`lp+fill+ls`, `penalty:ff`,
//! ...), which is what the CLI `--algo` flag and the planning service
//! speak. Preset outputs are bit-identical to the pre-pipeline
//! `Algorithm::run` paths — `tests/prop_pipeline.rs` pins that down —
//! because candidate enumeration preserves each seed path's loop order
//! and every selection uses the same strict-less / first-wins rule.

use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::lp::solver::MappingSolver;
use crate::model::{Instance, Solution};

use super::local_search;
use super::lpmap::{solve_lp_mapping, LpOutcome};
use super::penalty_map::{map_tasks, MappingPolicy};
use super::placement::FitPolicy;
use super::twophase::solve_with_mapping;

/// Order in which (mapping × fit) candidates are enumerated. Selection
/// keeps the *first* cheapest candidate, so the order decides cost ties;
/// each strategy declares the order its pre-pipeline code path used.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CandidateOrder {
    /// `for mapping { for fit }` — the penalty-map convention.
    MappingMajor,
    /// `for fit { for mapping }` — the LP-map convention (one placement
    /// pass per fit policy over the primary mapping and its alternates).
    FitMajor,
}

/// Phase 1 of the two-phase shape: produce candidate mappings.
pub trait MappingStrategy: Send + Sync {
    /// Short stage name used in spec strings and reports.
    fn label(&self) -> String;

    /// Whether this strategy consumes a mapping-LP outcome. Pipelines
    /// solve the LP once per run; portfolios share one outcome across
    /// all LP-based member pipelines.
    fn needs_lp(&self) -> bool {
        false
    }

    fn candidate_order(&self) -> CandidateOrder {
        CandidateOrder::MappingMajor
    }

    /// Candidate mappings (each `n_tasks` long). `lp` is `Some` exactly
    /// when [`MappingStrategy::needs_lp`] returned true.
    fn mappings(&self, inst: &Instance, lp: Option<&LpOutcome>) -> Result<Vec<Vec<usize>>>;
}

/// Penalty mapping (paper section III): one candidate mapping per
/// configured policy, enumerated mapping-major like `penalty_map_best`.
pub struct Penalty {
    pub policies: Vec<MappingPolicy>,
}

impl Penalty {
    /// Both `h_avg` and `h_max` — the paper's best-of reporting set.
    pub fn both() -> Self {
        Penalty { policies: vec![MappingPolicy::HAvg, MappingPolicy::HMax] }
    }

    pub fn single(policy: MappingPolicy) -> Self {
        Penalty { policies: vec![policy] }
    }
}

impl MappingStrategy for Penalty {
    fn label(&self) -> String {
        match self.policies.as_slice() {
            [MappingPolicy::HAvg] => "penalty-havg".into(),
            [MappingPolicy::HMax] => "penalty-hmax".into(),
            _ => "penalty".into(),
        }
    }

    fn mappings(&self, inst: &Instance, _lp: Option<&LpOutcome>) -> Result<Vec<Vec<usize>>> {
        ensure!(!self.policies.is_empty(), "penalty strategy has no policies");
        Ok(self.policies.iter().map(|&p| map_tasks(inst, p)).collect())
    }
}

/// LP mapping (paper section V): the crossover-rounded primary mapping
/// plus the top-k-mass alternates, enumerated fit-major like
/// `lp_place_best` (one LP solve feeds every placement).
pub struct Lp;

impl MappingStrategy for Lp {
    fn label(&self) -> String {
        "lp".into()
    }

    fn needs_lp(&self) -> bool {
        true
    }

    fn candidate_order(&self) -> CandidateOrder {
        CandidateOrder::FitMajor
    }

    fn mappings(&self, _inst: &Instance, lp: Option<&LpOutcome>) -> Result<Vec<Vec<usize>>> {
        let outcome = lp.context("LP strategy requires a mapping-LP outcome")?;
        let mut out = Vec::with_capacity(1 + outcome.alternates.len());
        out.push(outcome.mapping.clone());
        out.extend(outcome.alternates.iter().cloned());
        Ok(out)
    }
}

/// Escape hatch: a caller-supplied mapping (externally computed, replayed
/// from a previous run, or hand-crafted). Validated against admissibility
/// so an impossible mapping fails with an error instead of a placement
/// panic.
pub struct Oracle {
    pub name: String,
    pub mapping: Vec<usize>,
}

impl Oracle {
    pub fn new(name: impl Into<String>, mapping: Vec<usize>) -> Self {
        Oracle { name: name.into(), mapping }
    }
}

impl MappingStrategy for Oracle {
    fn label(&self) -> String {
        self.name.clone()
    }

    fn mappings(&self, inst: &Instance, _lp: Option<&LpOutcome>) -> Result<Vec<Vec<usize>>> {
        ensure!(
            self.mapping.len() == inst.n_tasks(),
            "oracle mapping '{}' has {} entries for {} tasks",
            self.name,
            self.mapping.len(),
            inst.n_tasks()
        );
        for (u, &b) in self.mapping.iter().enumerate() {
            ensure!(
                b < inst.n_types(),
                "oracle mapping '{}': task {u} mapped to nonexistent type {b}",
                self.name
            );
            ensure!(
                inst.node_types[b].admits(inst.tasks[u].peak()),
                "oracle mapping '{}': task {u} does not fit node-type {b} alone",
                self.name
            );
        }
        Ok(vec![self.mapping.clone()])
    }
}

/// Phase 3: refine one placed candidate. Passes run per candidate,
/// *before* the cheapest candidate is selected (the paper's best-of
/// convention applies to the refined costs).
pub trait RefinePass: Send + Sync {
    /// Short stage name used in spec strings and telemetry.
    fn name(&self) -> &'static str;

    /// True when the pass rebuilds the placement from the mapping itself;
    /// the plain placement is skipped when such a pass runs first.
    fn replaces_placement(&self) -> bool {
        false
    }

    fn refine(&self, inst: &Instance, mapping: &[usize], fit: FitPolicy, sol: &mut Solution);
}

/// Cross-node-type filling (paper section V-D): re-places the candidate's
/// mapping with leftover-capacity piggy-backing. Replaces the placement,
/// exactly like the `cross_fill: true` solves did.
pub struct CrossFill;

impl RefinePass for CrossFill {
    fn name(&self) -> &'static str {
        "fill"
    }

    fn replaces_placement(&self) -> bool {
        true
    }

    fn refine(&self, inst: &Instance, mapping: &[usize], fit: FitPolicy, sol: &mut Solution) {
        *sol = solve_with_mapping(inst, mapping, fit, true);
    }
}

/// Drain/downgrade local search (paper section VII) as a pipeline stage —
/// previously dead weight no preset could reach.
pub struct LocalSearch {
    pub max_rounds: usize,
}

impl Default for LocalSearch {
    fn default() -> Self {
        LocalSearch { max_rounds: 8 }
    }
}

impl RefinePass for LocalSearch {
    fn name(&self) -> &'static str {
        "ls"
    }

    fn refine(&self, inst: &Instance, _mapping: &[usize], _fit: FitPolicy, sol: &mut Solution) {
        local_search::improve(inst, sol, self.max_rounds);
    }
}

/// Wall time of one pipeline stage, aggregated over candidates.
#[derive(Clone, Debug)]
pub struct StageTime {
    pub stage: String,
    pub seconds: f64,
}

/// Diagnostics carried over from the mapping-LP solve.
#[derive(Clone, Debug)]
pub struct LpStats {
    /// Primary rounded mapping (the crossover argmax).
    pub mapping: Vec<usize>,
    pub objective: f64,
    /// Figure-5 series `x_max(u)`.
    pub x_max: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
}

/// Result of one pipeline run: the winning solution plus per-stage
/// telemetry (replacing the positional `[f64; 4]`/`[f64; 5]` arrays the
/// planner used to hardcode).
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// Display label (preset name, spec string, or custom label).
    pub label: String,
    pub solution: Solution,
    pub cost: f64,
    /// Certified dual lower bound, when the pipeline consumed an LP.
    pub certified_lb: Option<f64>,
    pub lp: Option<LpStats>,
    /// Per-stage wall time in execution order. A shared LP solve done by
    /// a [`Portfolio`] is *not* included here (see
    /// [`PortfolioReport::lp_seconds`]); a pipeline-local solve is, as
    /// the leading `lp-solve` stage.
    pub stages: Vec<StageTime>,
    /// Number of (mapping × fit) candidates evaluated.
    pub candidates: usize,
}

impl SolveReport {
    /// Total wall seconds across recorded stages.
    pub fn total_seconds(&self) -> f64 {
        self.stages.iter().map(|s| s.seconds).sum()
    }

    pub fn stage_seconds(&self, stage: &str) -> f64 {
        self.stages
            .iter()
            .filter(|s| s.stage == stage)
            .map(|s| s.seconds)
            .sum()
    }

    /// `"lp-solve 0.52s, place 0.11s, fill 0.07s"` — for report lines.
    pub fn stage_summary(&self) -> String {
        self.stages
            .iter()
            .map(|s| format!("{} {:.3}s", s.stage, s.seconds))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// A composable solve pipeline. Build with
/// `Pipeline::new().map(..).fit(..).refine(..)`; omitting `.fit(..)`
/// races both fitting policies (the paper's best-of convention).
pub struct Pipeline {
    strategy: Option<Box<dyn MappingStrategy>>,
    fits: Vec<FitPolicy>,
    refines: Vec<Box<dyn RefinePass>>,
    label: Option<String>,
}

impl Default for Pipeline {
    fn default() -> Self {
        Pipeline::new()
    }
}

impl Pipeline {
    pub fn new() -> Self {
        Pipeline { strategy: None, fits: Vec::new(), refines: Vec::new(), label: None }
    }

    /// Set the mapping strategy (phase 1). Required.
    pub fn map(mut self, strategy: impl MappingStrategy + 'static) -> Self {
        self.strategy = Some(Box::new(strategy));
        self
    }

    /// Add a fitting policy candidate (phase 2). May be called multiple
    /// times; with no call, both policies are raced.
    pub fn fit(mut self, fit: FitPolicy) -> Self {
        self.fits.push(fit);
        self
    }

    /// Append a refinement pass (phase 3); passes run per candidate in
    /// the order added.
    pub fn refine(mut self, pass: impl RefinePass + 'static) -> Self {
        self.refines.push(Box::new(pass));
        self
    }

    /// Override the display label (defaults to [`Pipeline::spec`]).
    pub fn label(mut self, label: impl Into<String>) -> Self {
        self.label = Some(label.into());
        self
    }

    pub fn needs_lp(&self) -> bool {
        self.strategy.as_ref().map(|s| s.needs_lp()).unwrap_or(false)
    }

    /// Canonical spec string, e.g. `lp:ff+fill+ls`.
    pub fn spec(&self) -> String {
        let mut out = self
            .strategy
            .as_ref()
            .map(|s| s.label())
            .unwrap_or_else(|| "<unmapped>".into());
        match self.fits.as_slice() {
            [] => {}
            [FitPolicy::FirstFit] => out.push_str(":ff"),
            [FitPolicy::SimilarityFit] => out.push_str(":sim"),
            _ => {}
        }
        for pass in &self.refines {
            out.push('+');
            out.push_str(pass.name());
        }
        out
    }

    pub fn display_label(&self) -> String {
        self.label.clone().unwrap_or_else(|| self.spec())
    }

    /// Structural validation: a placement-replacing pass (cross-fill)
    /// anywhere but first would silently discard the passes before it.
    fn validate(&self) -> Result<()> {
        if let Some(pos) = self.refines.iter().skip(1).position(|p| p.replaces_placement()) {
            anyhow::bail!(
                "refine stage '{}' rebuilds the placement from the mapping and must be \
                 the first refine stage — the work of every pass before it would be \
                 silently discarded",
                self.refines[pos + 1].name()
            );
        }
        Ok(())
    }

    /// Run the pipeline, solving the mapping LP first when the strategy
    /// needs one. To share one LP outcome across several pipelines, use
    /// [`Pipeline::run_shared`] (or a [`Portfolio`]).
    pub fn run(&self, inst: &Instance, solver: &dyn MappingSolver) -> Result<SolveReport> {
        if !self.needs_lp() {
            return self.run_shared(inst, None);
        }
        // lint:allow(wallclock): stage telemetry only — never feeds a decision
        let t0 = Instant::now();
        let outcome = solve_lp_mapping(inst, solver)?;
        let lp_seconds = t0.elapsed().as_secs_f64();
        let mut rep = self.run_shared(inst, Some(&outcome))?;
        rep.stages.insert(0, StageTime { stage: "lp-solve".into(), seconds: lp_seconds });
        Ok(rep)
    }

    /// Run against a pre-solved LP outcome (`None` for LP-free
    /// strategies). The shared-LP contract of the old `lp_place_best`:
    /// one solve, any number of placements.
    pub fn run_shared(&self, inst: &Instance, lp: Option<&LpOutcome>) -> Result<SolveReport> {
        self.validate()?;
        let strategy = self
            .strategy
            .as_ref()
            .context("pipeline has no mapping strategy (call .map(..))")?;
        ensure!(
            !strategy.needs_lp() || lp.is_some(),
            "strategy '{}' needs an LP outcome but none was provided",
            strategy.label()
        );

        // lint:allow(wallclock): stage telemetry only — never feeds a decision
        let t0 = Instant::now();
        let mappings = strategy.mappings(inst, lp)?;
        ensure!(!mappings.is_empty(), "strategy '{}' produced no mappings", strategy.label());
        for m in &mappings {
            ensure!(
                m.len() == inst.n_tasks(),
                "strategy '{}' produced a mapping of length {} for {} tasks",
                strategy.label(),
                m.len(),
                inst.n_tasks()
            );
        }
        let map_seconds = t0.elapsed().as_secs_f64();

        let fits: Vec<FitPolicy> = if self.fits.is_empty() {
            vec![FitPolicy::FirstFit, FitPolicy::SimilarityFit]
        } else {
            self.fits.clone()
        };

        // Enumeration preserves each strategy's pre-pipeline loop order so
        // that first-wins cost ties reproduce the seed paths exactly.
        let candidates: Vec<(&Vec<usize>, FitPolicy)> = match strategy.candidate_order() {
            CandidateOrder::MappingMajor => mappings
                .iter()
                .flat_map(|m| fits.iter().map(move |&f| (m, f)))
                .collect(),
            CandidateOrder::FitMajor => fits
                .iter()
                .flat_map(|&f| mappings.iter().map(move |m| (m, f)))
                .collect(),
        };

        // When the first refine pass rebuilds the placement (cross-fill),
        // the plain placement would be thrown away — skip it.
        let skip_place =
            self.refines.first().map(|p| p.replaces_placement()).unwrap_or(false);

        let mut place_seconds = 0.0f64;
        let mut refine_seconds = vec![0.0f64; self.refines.len()];
        let mut solved: Vec<(Solution, f64)> = Vec::with_capacity(candidates.len());
        for &(mapping, fit) in &candidates {
            let mut sol;
            let first_pass = if skip_place {
                // lint:allow(wallclock): stage telemetry only — never feeds a decision
                let t = Instant::now();
                sol = Solution::new(inst.n_tasks());
                self.refines[0].refine(inst, mapping, fit, &mut sol);
                refine_seconds[0] += t.elapsed().as_secs_f64();
                1
            } else {
                // lint:allow(wallclock): stage telemetry only — never feeds a decision
                let t = Instant::now();
                sol = solve_with_mapping(inst, mapping, fit, false);
                place_seconds += t.elapsed().as_secs_f64();
                0
            };
            for (i, pass) in self.refines.iter().enumerate().skip(first_pass) {
                // lint:allow(wallclock): stage telemetry only — never feeds a decision
                let t = Instant::now();
                pass.refine(inst, mapping, fit, &mut sol);
                refine_seconds[i] += t.elapsed().as_secs_f64();
            }
            let cost = sol.cost(inst);
            solved.push((sol, cost));
        }
        // shared first-wins selection rule (see util::stats::argmin_f64)
        let wi = crate::util::stats::argmin_f64(solved.iter().map(|(_, c)| *c))
            .expect("at least one candidate");
        let (solution, cost) = solved.swap_remove(wi);

        let mut stages = vec![StageTime { stage: "map".into(), seconds: map_seconds }];
        if !skip_place {
            stages.push(StageTime { stage: "place".into(), seconds: place_seconds });
        }
        for (pass, &secs) in self.refines.iter().zip(&refine_seconds) {
            stages.push(StageTime { stage: pass.name().into(), seconds: secs });
        }

        let lp_used = strategy.needs_lp();
        Ok(SolveReport {
            label: self.display_label(),
            solution,
            cost,
            certified_lb: if lp_used { lp.map(|o| o.certified_lb) } else { None },
            lp: if lp_used {
                lp.map(|o| LpStats {
                    mapping: o.mapping.clone(),
                    objective: o.lp_objective,
                    x_max: o.x_max.clone(),
                    iterations: o.solver_iterations,
                    converged: o.solver_converged,
                })
            } else {
                None
            },
            stages,
            candidates: candidates.len(),
        })
    }
}

/// The four paper algorithms as named pipelines (figure legend labels).
pub const PRESET_NAMES: [&str; 4] = ["penalty-map", "penalty-map-f", "lp-map", "lp-map-f"];

pub fn preset(name: &str) -> Option<Pipeline> {
    match name {
        "penalty-map" => Some(Pipeline::new().map(Penalty::both()).label("PenaltyMap")),
        "penalty-map-f" => {
            Some(Pipeline::new().map(Penalty::both()).refine(CrossFill).label("PenaltyMap-F"))
        }
        "lp-map" => Some(Pipeline::new().map(Lp).label("LP-map")),
        "lp-map-f" => Some(Pipeline::new().map(Lp).refine(CrossFill).label("LP-map-F")),
        _ => None,
    }
}

/// The `--algo` / service spec grammar (also printed by parse errors).
pub const SPEC_GRAMMAR: &str = "\
  algo    := <spec>[,<spec>]...      (multiple specs race in parallel as
                                      a portfolio on one shared LP solve)
  spec    := portfolio | <head>[:<fit>][+<refine>]...
             ('portfolio' expands to the four presets)
  head    := <preset> | <map>        (a preset keeps its refine chain)
  preset  := penalty-map | penalty-map-f | lp-map | lp-map-f
  map     := penalty | penalty-havg | penalty-hmax | lp
  fit     := ff | sim | best            (default: best = race both)
  refine  := fill | ls[:<max_rounds>]   (fill must be the first refine;
             e.g. lp+fill+ls, lp-map-f+ls, penalty:ff+ls:16)";

fn spec_error(spec: &str, why: String) -> anyhow::Error {
    anyhow::anyhow!(
        "unknown algorithm or pipeline spec '{spec}': {why}\nvalid specs:\n{SPEC_GRAMMAR}"
    )
}

/// Parse a preset name or pipeline spec (see [`SPEC_GRAMMAR`]). Presets
/// compose with extra stages (`lp-map-f+ls` = the preset plus a local
/// search pass). Errors list the valid presets and the grammar.
pub fn parse(spec: &str) -> Result<Pipeline> {
    if let Some(p) = preset(spec) {
        // echo the client's token as the label so race winners can be
        // matched back against the submitted spec strings
        return Ok(p.label(spec));
    }
    if spec == "portfolio" {
        return Err(spec_error(
            spec,
            "'portfolio' expands to four pipelines, not one — it is valid inside an \
             --algo/algorithm value (see parse_portfolio), not as a single pipeline"
                .into(),
        ));
    }
    let mut parts = spec.split('+');
    let head = parts.next().unwrap_or_default();
    let (map_name, fit_name) = match head.split_once(':') {
        Some((m, f)) => (m, Some(f)),
        None => (head, None),
    };
    // a preset head keeps its refine chain and composes with the rest
    let mut p = if let Some(base) = preset(map_name) {
        base
    } else {
        match map_name {
            "penalty" => Pipeline::new().map(Penalty::both()),
            "penalty-havg" => Pipeline::new().map(Penalty::single(MappingPolicy::HAvg)),
            "penalty-hmax" => Pipeline::new().map(Penalty::single(MappingPolicy::HMax)),
            "lp" => Pipeline::new().map(Lp),
            other => {
                return Err(spec_error(
                    spec,
                    format!("'{other}' is not a preset or mapping stage"),
                ))
            }
        }
    };
    match fit_name {
        None | Some("best") => {}
        Some("ff") => p = p.fit(FitPolicy::FirstFit),
        Some("sim") => p = p.fit(FitPolicy::SimilarityFit),
        Some(other) => {
            return Err(spec_error(spec, format!("'{other}' is not a fit policy")))
        }
    }
    for stage in parts {
        let (name, arg) = match stage.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (stage, None),
        };
        match (name, arg) {
            ("fill", None) => p = p.refine(CrossFill),
            ("ls", None) => p = p.refine(LocalSearch::default()),
            ("ls", Some(rounds)) => {
                let max_rounds: usize = rounds.parse().map_err(|_| {
                    spec_error(spec, format!("'{rounds}' is not a round count"))
                })?;
                p = p.refine(LocalSearch { max_rounds });
            }
            _ => {
                return Err(spec_error(
                    spec,
                    format!("'{stage}' is not a refine stage"),
                ))
            }
        }
    }
    p.validate().map_err(|e| spec_error(spec, e.to_string()))?;
    Ok(p.label(spec))
}

/// Most pipelines one parsed `--algo` / `algorithm` value may race.
/// Each member gets a scoped thread, and the spec string reaches the
/// planning service from untrusted clients — the cap keeps a hostile
/// `portfolio,portfolio,...` list from exhausting process threads.
pub const MAX_PORTFOLIO_SPECS: usize = 16;

/// Parse a full `--algo` / service `algorithm` value: a comma-separated
/// list of specs raced as one portfolio. The token `portfolio` expands
/// to the four presets; a single spec yields a one-member portfolio.
/// The CLI and the planning service both call this, so they accept the
/// exact same language.
pub fn parse_portfolio(specs: &str) -> Result<Portfolio> {
    let mut members: Vec<Pipeline> = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for tok in specs.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let expanded: Vec<Pipeline> = if tok == "portfolio" {
            PRESET_NAMES
                .iter()
                // label with the spec token (not the figure-legend name)
                // so race winners are resubmittable spec strings
                .map(|&name| preset(name).expect("preset exists").label(name))
                .collect()
        } else {
            vec![parse(tok)?]
        };
        for p in expanded {
            // duplicates (e.g. "lp-map-f,portfolio") would race the same
            // work twice and make the label-keyed winner ambiguous
            if !seen.insert(p.display_label()) {
                continue;
            }
            members.push(p);
            if members.len() > MAX_PORTFOLIO_SPECS {
                return Err(spec_error(
                    specs,
                    format!("expands to more than {MAX_PORTFOLIO_SPECS} distinct pipelines"),
                ));
            }
        }
    }
    if members.is_empty() {
        return Err(spec_error(specs, "no pipeline specs given".into()));
    }
    // the CLI/service race path: skip members the certified shared-LP
    // bound proves cannot beat a finished incumbent (figure sweeps build
    // their portfolios directly and keep every member's cost)
    Ok(Portfolio { pipelines: members, early_abort: true })
}

/// Result of racing a portfolio of pipelines on one instance.
#[derive(Clone, Debug)]
pub struct PortfolioReport {
    /// One report per *completed* member pipeline, in portfolio order.
    /// Without early abort every member completes; with it, members the
    /// shared-LP bound proved non-winners may be skipped (see `skipped`).
    pub reports: Vec<SolveReport>,
    /// Display labels of members skipped by LB early abort: a finished
    /// lower-index member already matched the certified bound, so they
    /// could not have produced a strictly cheaper solution.
    pub skipped: Vec<String>,
    /// Index into `reports` of the winning member (ties break toward the
    /// lower index, so the winner is independent of thread scheduling).
    pub winner: usize,
    /// The shared mapping-LP outcome, when any member needed one.
    pub lp: Option<LpOutcome>,
    /// Wall seconds of the shared LP solve (0 when no member needed it).
    pub lp_seconds: f64,
}

impl PortfolioReport {
    pub fn best(&self) -> &SolveReport {
        &self.reports[self.winner]
    }

    /// Report for a member pipeline by display label.
    pub fn get(&self, label: &str) -> Option<&SolveReport> {
        self.reports.iter().find(|r| r.label == label)
    }

    /// Certified lower bound for the instance: the winner's own bound
    /// when it consumed the LP, else the shared LP solve's bound (which
    /// is valid regardless of which member won the race).
    pub fn certified_lb(&self) -> Option<f64> {
        self.best()
            .certified_lb
            .or_else(|| self.lp.as_ref().map(|o| o.certified_lb))
    }
}

/// A set of candidate pipelines raced on scoped threads. The mapping LP
/// is solved once up front and shared by reference with every LP-based
/// member — one LP solve, N placements.
pub struct Portfolio {
    pub pipelines: Vec<Pipeline>,
    /// Lower-bound early abort (ROADMAP Architecture lever): when a
    /// member finishes with cost within FP tolerance of the certified
    /// shared-LP bound, members that have not started yet are skipped —
    /// no feasible solution can cost less than the bound, so they cannot
    /// *beat* the incumbent. Off by default (figure sweeps need every
    /// member's cost); the CLI/service `--algo` path enables it.
    pub early_abort: bool,
}

impl Default for Portfolio {
    fn default() -> Self {
        Portfolio::new()
    }
}

/// The provable-optimality threshold for `cost` against a certified
/// lower bound `lb`: `cost <= lb·(1+eps) + eps`. Any feasible cost is
/// `>= lb` exactly, so a member at the threshold is optimal up to FP
/// noise and later members can tie it at best.
fn abort_bound(lb: f64) -> f64 {
    lb + 1e-9 * lb.abs() + 1e-9
}

impl Portfolio {
    pub fn new() -> Self {
        Portfolio { pipelines: Vec::new(), early_abort: false }
    }

    pub fn add(mut self, pipeline: Pipeline) -> Self {
        self.pipelines.push(pipeline);
        self
    }

    /// Enable or disable lower-bound early abort (default off).
    pub fn with_early_abort(mut self, on: bool) -> Self {
        self.early_abort = on;
        self
    }

    /// All four paper presets, in figure-legend order.
    pub fn presets() -> Self {
        Portfolio {
            pipelines: PRESET_NAMES
                .iter()
                .map(|n| preset(n).expect("preset exists"))
                .collect(),
            early_abort: false,
        }
    }

    /// The one LP solve every racer shares, run on the *caller* thread
    /// before the race starts. This ordering is what lets the LP engine
    /// use its own worker team (`PdhgOptions::threads` via
    /// `solver.lp_threads()`) without oversubscribing: LP threads are
    /// done and parked before the racer pool spawns, so the two pools
    /// never hold cores at the same time.
    fn shared_lp(
        &self,
        inst: &Instance,
        solver: &dyn MappingSolver,
    ) -> Result<(Option<LpOutcome>, f64)> {
        if !self.pipelines.iter().any(|p| p.needs_lp()) {
            return Ok((None, 0.0));
        }
        // lint:allow(wallclock): stage telemetry only — never feeds a decision
        let t0 = Instant::now();
        let outcome = solve_lp_mapping(inst, solver)?;
        Ok((Some(outcome), t0.elapsed().as_secs_f64()))
    }

    /// Race the member pipelines on scoped worker threads (at most one
    /// per hardware thread — each pipeline may itself spawn per-type
    /// placement threads, so an unbounded fan-out would oversubscribe).
    /// The result is deterministic and thread-count independent: each
    /// pipeline is deterministic, results are stored by member index,
    /// and the winner uses an index tie-break (`run_sequential` must and
    /// does agree).
    ///
    /// With `early_abort` on, a member is skipped iff some *lower-index*
    /// member already finished with cost within [`abort_bound`] of the
    /// certified shared-LP bound. The winner — cost and label — is still
    /// timing-independent: the lowest-index member that would reach the
    /// bound is claimed before any member it could suppress (the pool
    /// claims indices in order), so it always completes, and the winner
    /// rule picks the first bound-matching report. Which *other* members
    /// got skipped may vary with scheduling; only `skipped` reflects
    /// that, never the winner.
    pub fn run(&self, inst: &Instance, solver: &dyn MappingSolver) -> Result<PortfolioReport> {
        ensure!(!self.pipelines.is_empty(), "empty portfolio");
        let (lp, lp_seconds) = self.shared_lp(inst, solver)?;
        let lp_ref = lp.as_ref();
        let bound = if self.early_abort {
            lp.as_ref().map(|o| abort_bound(o.certified_lb))
        } else {
            None
        };
        let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        let first_optimal = std::sync::atomic::AtomicUsize::new(usize::MAX);
        let results = crate::util::pool::run_indexed(self.pipelines.len(), workers, |i| {
            use std::sync::atomic::Ordering::SeqCst;
            if bound.is_some() && first_optimal.load(SeqCst) < i {
                return None; // a finished lower-index member is provably unbeatable
            }
            let r = self.pipelines[i].run_shared(inst, lp_ref);
            if let (Some(b), Ok(rep)) = (bound, &r) {
                if rep.cost <= b {
                    first_optimal.fetch_min(i, SeqCst);
                }
            }
            Some(r)
        });
        self.assemble(results, lp, lp_seconds, bound)
    }

    /// Sequential fold over the same members — the reference the property
    /// tests compare the parallel race against, and the baseline
    /// `benches/end_to_end.rs` measures the racing speedup from. With
    /// `early_abort` on it skips maximally (everything after the first
    /// bound-matching member), the deterministic upper envelope of what
    /// the parallel race may skip.
    pub fn run_sequential(
        &self,
        inst: &Instance,
        solver: &dyn MappingSolver,
    ) -> Result<PortfolioReport> {
        ensure!(!self.pipelines.is_empty(), "empty portfolio");
        let (lp, lp_seconds) = self.shared_lp(inst, solver)?;
        let bound = if self.early_abort {
            lp.as_ref().map(|o| abort_bound(o.certified_lb))
        } else {
            None
        };
        let mut first_optimal = usize::MAX;
        let results: Vec<Option<Result<SolveReport>>> = self
            .pipelines
            .iter()
            .enumerate()
            .map(|(i, p)| {
                if bound.is_some() && first_optimal < i {
                    return None;
                }
                let r = p.run_shared(inst, lp.as_ref());
                if let (Some(b), Ok(rep)) = (bound, &r) {
                    if rep.cost <= b {
                        first_optimal = first_optimal.min(i);
                    }
                }
                Some(r)
            })
            .collect();
        self.assemble(results, lp, lp_seconds, bound)
    }

    fn assemble(
        &self,
        results: Vec<Option<Result<SolveReport>>>,
        lp: Option<LpOutcome>,
        lp_seconds: f64,
        bound: Option<f64>,
    ) -> Result<PortfolioReport> {
        let mut reports = Vec::with_capacity(results.len());
        let mut skipped = Vec::new();
        for (i, r) in results.into_iter().enumerate() {
            match r {
                Some(r) => reports.push(r?),
                None => skipped.push(self.pipelines[i].display_label()),
            }
        }
        // member 0 is never skipped, so completed reports exist
        let winner = bound
            .and_then(|b| reports.iter().position(|r| r.cost <= b))
            .unwrap_or_else(|| {
                crate::util::stats::argmin_f64(reports.iter().map(|r| r.cost))
                    .expect("non-empty portfolio")
            });
        Ok(PortfolioReport { reports, skipped, winner, lp, lp_seconds })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::synth::{generate, SynthParams};
    use crate::lp::solver::NativePdhgSolver;
    use crate::model::trim;

    fn tiny() -> Instance {
        let inst = generate(&SynthParams { n: 60, m: 4, ..Default::default() }, 17);
        trim(&inst).instance
    }

    #[test]
    fn builder_runs_and_verifies() {
        let tr = tiny();
        let solver = NativePdhgSolver::default();
        let rep = Pipeline::new()
            .map(Penalty::both())
            .fit(FitPolicy::FirstFit)
            .refine(CrossFill)
            .refine(LocalSearch::default())
            .run(&tr, &solver)
            .unwrap();
        assert!(rep.solution.verify(&tr).is_ok());
        assert!((rep.cost - rep.solution.cost(&tr)).abs() < 1e-12);
        assert_eq!(rep.candidates, 2); // two mappings x one fit
        assert!(rep.certified_lb.is_none());
        // stages: map, fill (place skipped: fill replaces it), ls
        let names: Vec<&str> = rep.stages.iter().map(|s| s.stage.as_str()).collect();
        assert_eq!(names, vec!["map", "fill", "ls"]);
    }

    #[test]
    fn missing_strategy_is_an_error() {
        let tr = tiny();
        let err = Pipeline::new()
            .run(&tr, &NativePdhgSolver::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("mapping strategy"), "{err}");
    }

    #[test]
    fn presets_exist_and_label_like_the_enum() {
        for name in PRESET_NAMES {
            assert!(preset(name).is_some(), "{name}");
        }
        assert_eq!(preset("lp-map-f").unwrap().display_label(), "LP-map-F");
        assert!(preset("nope").is_none());
    }

    #[test]
    fn parse_accepts_presets_specs_and_rejects_garbage() {
        assert!(parse("penalty-map-f").is_ok());
        assert!(parse("lp+fill+ls").is_ok());
        assert!(parse("penalty:ff+ls:16").is_ok());
        assert!(parse("penalty-hmax:sim").is_ok());
        for bad in ["magic", "lp:xx", "lp+frob", "lp+ls:many", ""] {
            let err = parse(bad).unwrap_err().to_string();
            assert!(err.contains("unknown algorithm"), "{bad}: {err}");
            // the error teaches the valid names and grammar
            assert!(err.contains("penalty-map"), "{bad}: {err}");
            assert!(err.contains("fill | ls"), "{bad}: {err}");
        }
    }

    #[test]
    fn spec_roundtrip_labels() {
        let p = parse("lp:ff+fill+ls").unwrap();
        assert_eq!(p.spec(), "lp:ff+fill+ls");
        assert_eq!(p.display_label(), "lp:ff+fill+ls");
        assert!(p.needs_lp());
    }

    #[test]
    fn parse_portfolio_expands_lists_and_the_portfolio_token() {
        assert_eq!(parse_portfolio("lp-map-f").unwrap().pipelines.len(), 1);
        assert_eq!(parse_portfolio("portfolio").unwrap().pipelines.len(), 4);
        let mixed = parse_portfolio("lp+fill+ls, portfolio").unwrap();
        assert_eq!(mixed.pipelines.len(), 5);
        // every member label is a resubmittable spec token
        assert_eq!(mixed.pipelines[0].display_label(), "lp+fill+ls");
        assert_eq!(mixed.pipelines[1].display_label(), "penalty-map");
        assert_eq!(parse("lp-map-f").unwrap().display_label(), "lp-map-f");
        for bad in ["", " , ", "portfolio,magic"] {
            let err = parse_portfolio(bad).unwrap_err().to_string();
            assert!(err.contains("unknown algorithm"), "{bad}: {err}");
        }
        // duplicates dedup instead of racing the same work twice with
        // ambiguous labels
        let dup = parse_portfolio("lp-map-f,portfolio,portfolio").unwrap();
        assert_eq!(dup.pipelines.len(), 4);
        // client-controlled spec lists cannot spawn unbounded threads:
        // distinct pipelines beyond the cap are rejected
        let bomb = (1..=17).map(|i| format!("lp+ls:{i}")).collect::<Vec<_>>().join(",");
        let err = parse_portfolio(&bomb).unwrap_err().to_string();
        assert!(err.contains("more than"), "{err}");
        // 'portfolio' is a list-level token, not a single pipeline
        let err = parse("portfolio").unwrap_err().to_string();
        assert!(err.contains("expands to four pipelines"), "{err}");
    }

    #[test]
    fn presets_compose_with_extra_stages() {
        // a preset head keeps its refine chain: lp-map-f+ls = lp+fill+ls
        let p = parse("lp-map-f+ls").unwrap();
        assert!(p.needs_lp());
        assert_eq!(p.spec(), "lp+fill+ls");
        assert_eq!(p.display_label(), "lp-map-f+ls");
    }

    #[test]
    fn fill_must_be_the_first_refine_stage() {
        // spec level: local-search work before a fill would be discarded
        let err = parse("lp+ls+fill").unwrap_err().to_string();
        assert!(err.contains("must be the first refine stage"), "{err}");
        // builder level: same rule, caught at run time
        let tr = tiny();
        let err = Pipeline::new()
            .map(Penalty::both())
            .refine(LocalSearch::default())
            .refine(CrossFill)
            .run(&tr, &NativePdhgSolver::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("must be the first refine stage"), "{err}");
    }

    #[test]
    fn oracle_mapping_validated() {
        let tr = tiny();
        let solver = NativePdhgSolver::default();
        // wrong length
        let err = Pipeline::new()
            .map(Oracle::new("bad", vec![0; 3]))
            .run(&tr, &solver)
            .unwrap_err()
            .to_string();
        assert!(err.contains("3 entries"), "{err}");
        // a valid custom mapping runs end to end
        let mapping = map_tasks(&tr, MappingPolicy::HAvg);
        let rep = Pipeline::new()
            .map(Oracle::new("havg-oracle", mapping.clone()))
            .run(&tr, &solver)
            .unwrap();
        assert!(rep.solution.verify(&tr).is_ok());
        // equals the best-of-fits fold over the same mapping
        let ff = solve_with_mapping(&tr, &mapping, FitPolicy::FirstFit, false);
        let sim = solve_with_mapping(&tr, &mapping, FitPolicy::SimilarityFit, false);
        let want = ff.cost(&tr).min(sim.cost(&tr));
        assert!((rep.cost - want).abs() < 1e-12);
    }

    #[test]
    fn early_abort_skips_provably_beaten_members() {
        use crate::lp::solver::SimplexSolver;
        use crate::model::{NodeType, Task};
        // four half-capacity tasks on one slot: the LP bound (2 nodes) is
        // tight, and the exact simplex backend certifies it exactly, so
        // the lp member finishes at the bound and later members skip
        let inst = Instance::new(
            (0..4).map(|i| Task::new(i, vec![0.5], 0, 1)).collect(),
            vec![NodeType::new("a", vec![1.0], 1.0)],
            2,
        );
        let tr = crate::model::trim(&inst).instance;
        let portfolio = parse_portfolio("lp:ff,penalty:ff,penalty:ff+ls").unwrap();
        assert!(portfolio.early_abort, "parse_portfolio enables early abort");
        let seq = portfolio.run_sequential(&tr, &SimplexSolver).unwrap();
        // member 0 matched the certified bound; the rest were skipped
        assert_eq!(seq.reports.len(), 1, "skipped: {:?}", seq.skipped);
        assert_eq!(seq.skipped, vec!["penalty:ff", "penalty:ff+ls"]);
        assert_eq!(seq.best().label, "lp:ff");
        assert!((seq.best().cost - 2.0).abs() < 1e-9);
        // the parallel race picks the same winner at the same cost, no
        // matter which members its scheduling let through
        let par = portfolio.run(&tr, &SimplexSolver).unwrap();
        assert_eq!(par.best().label, "lp:ff");
        assert!((par.best().cost - seq.best().cost).abs() < 1e-12);
        assert!(par.best().solution.verify(&tr).is_ok());
        // with early abort off, every member runs and the winner agrees
        let full = parse_portfolio("lp:ff,penalty:ff,penalty:ff+ls")
            .unwrap()
            .with_early_abort(false)
            .run_sequential(&tr, &SimplexSolver)
            .unwrap();
        assert_eq!(full.reports.len(), 3);
        assert!(full.skipped.is_empty());
        assert!((full.best().cost - seq.best().cost).abs() < 1e-12);
    }

    #[test]
    fn early_abort_never_fires_without_a_bound_match() {
        // LP-free portfolio: no certified bound, nothing can be skipped
        let tr = tiny();
        let race = parse_portfolio("penalty-map,penalty-map-f")
            .unwrap()
            .run_sequential(&tr, &NativePdhgSolver::default())
            .unwrap();
        assert_eq!(race.reports.len(), 2);
        assert!(race.skipped.is_empty());
    }

    #[test]
    fn portfolio_race_matches_sequential() {
        let tr = tiny();
        let solver = NativePdhgSolver::default();
        let par = Portfolio::presets().run(&tr, &solver).unwrap();
        let seq = Portfolio::presets().run_sequential(&tr, &solver).unwrap();
        assert_eq!(par.winner, seq.winner);
        assert_eq!(par.reports.len(), 4);
        for (a, b) in par.reports.iter().zip(&seq.reports) {
            assert_eq!(a.label, b.label);
            assert!((a.cost - b.cost).abs() < 1e-12, "{}", a.label);
            assert_eq!(a.solution.assignment, b.solution.assignment, "{}", a.label);
        }
        assert!(par.best().solution.verify(&tr).is_ok());
        assert!(par.lp.is_some());
        // winner is the min-cost member with the lowest index
        let min = par.reports.iter().map(|r| r.cost).fold(f64::INFINITY, f64::min);
        assert!((par.best().cost - min).abs() < 1e-12);
        assert!(par.reports[..par.winner].iter().all(|r| r.cost > min));
    }
}
