//! TL-Rightsizing algorithms: the paper's contribution layer.

pub mod algorithms;
pub mod decompose;
pub mod exact;
pub mod fill;
pub mod interval_coloring;
pub mod local_search;
pub mod lowerbound;
pub mod lpmap;
pub mod online;
pub mod penalty_map;
pub mod pipeline;
pub mod placement;
pub mod repair;
pub mod segregate;
pub mod twophase;

pub use algorithms::Algorithm;
pub use pipeline::{Pipeline, Portfolio, SolveReport};
pub use placement::FitPolicy;
