//! Exhaustive exact solver for tiny instances (branch and bound over
//! task -> node assignments). Used to (a) reproduce "optimal" reference
//! points like Figure 1's $16 no-timeline packing, and (b) measure true
//! approximation ratios of the heuristics in tests. Exponential — guarded
//! to small n.

use crate::model::{Instance, LoadProfile, PlacedNode, Profile, Solution};

const MAX_TASKS: usize = 12;

/// Compute the optimal solution by branch and bound. Panics if the
/// instance is larger than MAX_TASKS tasks (use the heuristics instead).
pub fn optimal(inst: &Instance) -> Solution {
    assert!(
        inst.n_tasks() <= MAX_TASKS,
        "exact solver is exponential; n={} > {MAX_TASKS}",
        inst.n_tasks()
    );
    let t_len = inst.horizon as usize;

    // State: open nodes (type, indexed load profile); branch each task
    // into every open node it fits plus one new node per type. Feasibility
    // probes ride the shared [`LoadProfile`] segment trees (O(D·log T)),
    // the same code path the heuristics and the verifier use.
    struct Node {
        type_idx: usize,
        profile: LoadProfile,
        tasks: Vec<usize>,
    }
    struct Search<'a> {
        inst: &'a Instance,
        t_len: usize,
        best_cost: f64,
        best: Option<Vec<(usize, Vec<usize>)>>,
    }
    impl<'a> Search<'a> {
        fn go(&mut self, u: usize, nodes: &mut Vec<Node>, cost: f64) {
            if cost >= self.best_cost - 1e-12 {
                return; // bound
            }
            if u == self.inst.n_tasks() {
                self.best_cost = cost;
                self.best = Some(
                    nodes
                        .iter()
                        .map(|n| (n.type_idx, n.tasks.clone()))
                        .collect(),
                );
                return;
            }
            let task = &self.inst.tasks[u];
            // existing nodes
            for i in 0..nodes.len() {
                if nodes[i].profile.fits(task) {
                    add(&mut nodes[i], self.inst, u);
                    self.go(u + 1, nodes, cost);
                    remove(&mut nodes[i], self.inst, u);
                }
            }
            // new node of each admitting type; skip symmetric duplicates
            // (only open a new node of type b if no empty node of b exists)
            for b in 0..self.inst.n_types() {
                if !self.inst.node_types[b].admits(task.peak()) {
                    continue;
                }
                let mut node = Node {
                    type_idx: b,
                    profile: LoadProfile::new(
                        self.t_len,
                        self.inst.node_types[b].capacity.clone(),
                    ),
                    tasks: Vec::new(),
                };
                add(&mut node, self.inst, u);
                nodes.push(node);
                self.go(u + 1, nodes, cost + self.inst.node_types[b].cost);
                nodes.pop();
            }
        }
    }
    fn add(node: &mut Node, inst: &Instance, u: usize) {
        node.profile.add_task(&inst.tasks[u]);
        node.tasks.push(u);
    }
    fn remove(node: &mut Node, inst: &Instance, u: usize) {
        node.profile.remove_task(&inst.tasks[u]);
        node.tasks.pop();
    }

    let mut search = Search { inst, t_len, best_cost: f64::INFINITY, best: None };
    search.go(0, &mut Vec::new(), 0.0);
    let layout = search.best.expect("feasible instance");

    let mut sol = Solution::new(inst.n_tasks());
    for (i, (type_idx, tasks)) in layout.into_iter().enumerate() {
        for &u in &tasks {
            sol.assignment[u] = Some(i);
        }
        sol.nodes.push(PlacedNode { type_idx, purchase_order: i, tasks });
    }
    sol
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::algorithms::penalty_map_best;
    use crate::harness::scenarios::figure1_instance;
    use crate::io::synth::{generate, SynthParams};
    use crate::model::trim;

    #[test]
    fn figure1_reference_points() {
        let inst = figure1_instance();
        // timeline-aware optimum is the single $10 node
        let sol = optimal(&inst);
        assert!(sol.verify(&inst).is_ok());
        assert!((sol.cost(&inst) - 10.0).abs() < 1e-9);
        // no-timeline optimum is $16 (one node of each type)
        let collapsed = inst.collapse_timeline();
        let sol = optimal(&collapsed);
        assert!(sol.verify(&collapsed).is_ok());
        assert!((sol.cost(&collapsed) - 16.0).abs() < 1e-9, "got {}", sol.cost(&collapsed));
    }

    #[test]
    fn heuristics_never_beat_optimal() {
        for seed in 0..6 {
            let inst = generate(
                &SynthParams {
                    n: 7,
                    m: 3,
                    dims: 2,
                    horizon: 6,
                    dem_range: (0.1, 0.5),
                    ..Default::default()
                },
                seed,
            );
            let tr = trim(&inst).instance;
            let opt = optimal(&tr);
            assert!(opt.verify(&tr).is_ok());
            let heur = penalty_map_best(&tr, true);
            assert!(
                heur.cost(&tr) >= opt.cost(&tr) - 1e-9,
                "seed {seed}: heuristic {} < optimal {}",
                heur.cost(&tr),
                opt.cost(&tr)
            );
            // and the approximation is reasonable on tiny instances
            assert!(heur.cost(&tr) <= 3.0 * opt.cost(&tr) + 1e-9, "seed {seed}");
        }
    }
}
