//! Greedy placement engine (the paper's Placement Phase, section III).
//!
//! Tasks mapped to one node-type are processed in increasing start-time
//! order; each is placed into an already-purchased node when it fits
//! (first-fit: earliest purchased; similarity-fit: highest cosine
//! similarity between the task's normalized demand and the node's
//! remaining capacity over the task span), else a new node is purchased.

use crate::model::{Instance, PlacedNode, Solution};

/// Node-selection policy among feasible already-purchased nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FitPolicy {
    /// Paper's first-fit: the node purchased the earliest.
    FirstFit,
    /// Paper's similarity-fit: maximum cosine similarity between the
    /// capacity-normalized demand and remaining-capacity vectors, summed
    /// over the task's active timeslots.
    SimilarityFit,
}

/// Mutable state of one purchased node: its load profile over (t, d).
#[derive(Clone, Debug)]
pub struct NodeState {
    pub type_idx: usize,
    pub purchase_order: usize,
    pub tasks: Vec<usize>,
    /// usage[t*dims + d]: aggregate demand of active tasks.
    usage: Vec<f64>,
    /// Cached capacity vector of the node-type.
    cap: Vec<f64>,
    dims: usize,
}

const EPS: f64 = 1e-9;

impl NodeState {
    pub fn new(inst: &Instance, type_idx: usize, purchase_order: usize) -> Self {
        let dims = inst.dims();
        NodeState {
            type_idx,
            purchase_order,
            tasks: Vec::new(),
            usage: vec![0.0; inst.horizon as usize * dims],
            cap: inst.node_types[type_idx].capacity.clone(),
            dims,
        }
    }

    /// Does task `u` fit without violating capacity anywhere in its span?
    pub fn fits(&self, inst: &Instance, u: usize) -> bool {
        let task = &inst.tasks[u];
        let dims = self.dims;
        for t in task.start..=task.end {
            let base = t as usize * dims;
            for d in 0..dims {
                if self.usage[base + d] + task.demand[d] > self.cap[d] + EPS {
                    return false;
                }
            }
        }
        true
    }

    /// Cosine similarity between capacity-normalized demand and remaining
    /// capacity, aggregated over the task span (paper section III,
    /// "Alternative Mapping and Fitting Policies").
    pub fn similarity(&self, inst: &Instance, u: usize) -> f64 {
        let task = &inst.tasks[u];
        let dims = self.dims;
        let mut dot = 0.0;
        let mut nrm_d = 0.0;
        let mut nrm_r = 0.0;
        for t in task.start..=task.end {
            let base = t as usize * dims;
            for d in 0..dims {
                let dem = task.demand[d] / self.cap[d];
                let rem = (self.cap[d] - self.usage[base + d]).max(0.0) / self.cap[d];
                dot += dem * rem;
                nrm_d += dem * dem;
                nrm_r += rem * rem;
            }
        }
        if nrm_d <= 0.0 || nrm_r <= 0.0 {
            return 0.0;
        }
        dot / (nrm_d.sqrt() * nrm_r.sqrt())
    }

    /// Add task `u` (caller must have checked `fits`).
    pub fn add(&mut self, inst: &Instance, u: usize) {
        let task = &inst.tasks[u];
        let dims = self.dims;
        for t in task.start..=task.end {
            let base = t as usize * dims;
            for d in 0..dims {
                self.usage[base + d] += task.demand[d];
            }
        }
        self.tasks.push(u);
    }

    /// Peak load fraction over the node's busiest (t, d).
    pub fn peak_utilization(&self) -> f64 {
        let dims = self.dims;
        let mut best: f64 = 0.0;
        for chunk in self.usage.chunks(dims) {
            for d in 0..dims {
                best = best.max(chunk[d] / self.cap[d]);
            }
        }
        best
    }
}

/// Pick a feasible node per policy; `None` if nothing fits.
pub fn select_node(
    inst: &Instance,
    nodes: &[NodeState],
    u: usize,
    policy: FitPolicy,
) -> Option<usize> {
    match policy {
        FitPolicy::FirstFit => nodes.iter().position(|b| b.fits(inst, u)),
        FitPolicy::SimilarityFit => {
            let mut best: Option<(usize, f64)> = None;
            for (i, b) in nodes.iter().enumerate() {
                if b.fits(inst, u) {
                    let s = b.similarity(inst, u);
                    if best.map(|(_, bs)| s > bs).unwrap_or(true) {
                        best = Some((i, s));
                    }
                }
            }
            best.map(|(i, _)| i)
        }
    }
}

/// Place the given tasks (already filtered to one node-type) in increasing
/// start order, purchasing nodes of `type_idx` as needed. `purchase_seq`
/// is the global purchase counter shared across node-types.
pub fn place_group(
    inst: &Instance,
    type_idx: usize,
    tasks: &[usize],
    policy: FitPolicy,
    purchase_seq: &mut usize,
) -> Vec<NodeState> {
    let mut order: Vec<usize> = tasks.to_vec();
    order.sort_by_key(|&u| (inst.tasks[u].start, u));
    let mut nodes: Vec<NodeState> = Vec::new();
    for u in order {
        match select_node(inst, &nodes, u, policy) {
            Some(i) => nodes[i].add(inst, u),
            None => {
                let mut b = NodeState::new(inst, type_idx, *purchase_seq);
                *purchase_seq += 1;
                assert!(
                    b.fits(inst, u),
                    "task {u} cannot fit an empty node of type {type_idx}: \
                     mapping must respect admissibility"
                );
                b.add(inst, u);
                nodes.push(b);
            }
        }
    }
    nodes
}

/// Assemble a [`Solution`] from per-type node lists.
pub fn to_solution(inst: &Instance, groups: Vec<Vec<NodeState>>) -> Solution {
    let mut sol = Solution::new(inst.n_tasks());
    for nodes in groups {
        for b in nodes {
            let idx = sol.nodes.len();
            for &u in &b.tasks {
                sol.assignment[u] = Some(idx);
            }
            sol.nodes.push(PlacedNode {
                type_idx: b.type_idx,
                purchase_order: b.purchase_order,
                tasks: b.tasks,
            });
        }
    }
    sol
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NodeType, Task};

    fn inst() -> Instance {
        Instance::new(
            vec![
                Task::new(0, vec![0.6], 0, 2),
                Task::new(1, vec![0.6], 1, 3),
                Task::new(2, vec![0.6], 4, 5),
                Task::new(3, vec![0.3], 0, 5),
            ],
            vec![NodeType::new("a", vec![1.0], 2.0)],
            6,
        )
    }

    #[test]
    fn first_fit_reuses_after_expiry() {
        let inst = inst();
        let mut seq = 0;
        let nodes = place_group(&inst, 0, &[0, 1, 2], FitPolicy::FirstFit, &mut seq);
        // tasks 0,1 overlap (1.2 > 1.0) -> 2 nodes; task 2 fits node 0 later
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[0].tasks, vec![0, 2]);
        assert_eq!(nodes[1].tasks, vec![1]);
    }

    #[test]
    fn capacity_respected() {
        let inst = inst();
        let mut seq = 0;
        let nodes = place_group(&inst, 0, &[0, 1, 2, 3], FitPolicy::FirstFit, &mut seq);
        let sol = to_solution(&inst, vec![nodes]);
        assert!(sol.verify(&inst).is_ok());
    }

    #[test]
    fn similarity_prefers_complementary_node() {
        // node 0 holds a balanced task (remaining capacity (0.7,0.7));
        // node 1 holds a cpu-heavy task (remaining (0.2,0.9)).
        // A memory-heavy task fits both; cosine similarity picks node 1
        // (complementary shape), while first-fit would pick node 0.
        let inst = Instance::new(
            vec![
                Task::new(0, vec![0.3, 0.3], 0, 0),
                Task::new(1, vec![0.8, 0.1], 0, 0),
                Task::new(2, vec![0.1, 0.6], 0, 0),
            ],
            vec![NodeType::new("a", vec![1.0, 1.0], 1.0)],
            1,
        );
        let mut seq = 0;
        let sim = place_group(&inst, 0, &[0, 1, 2], FitPolicy::SimilarityFit, &mut seq);
        assert_eq!(sim.len(), 2);
        let node_of_2 = sim.iter().position(|b| b.tasks.contains(&2)).unwrap();
        assert!(sim[node_of_2].tasks.contains(&1), "similarity: {sim:?}");

        let mut seq = 0;
        let ff = place_group(&inst, 0, &[0, 1, 2], FitPolicy::FirstFit, &mut seq);
        let node_of_2 = ff.iter().position(|b| b.tasks.contains(&2)).unwrap();
        assert!(ff[node_of_2].tasks.contains(&0), "first-fit: {ff:?}");
    }

    #[test]
    fn select_none_when_full() {
        let inst = Instance::new(
            vec![Task::new(0, vec![0.9], 0, 0), Task::new(1, vec![0.9], 0, 0)],
            vec![NodeType::new("a", vec![1.0], 1.0)],
            1,
        );
        let mut seq = 0;
        let mut nodes = vec![NodeState::new(&inst, 0, seq)];
        seq += 1;
        nodes[0].add(&inst, 0);
        assert_eq!(select_node(&inst, &nodes, 1, FitPolicy::FirstFit), None);
        let _ = seq;
    }

    #[test]
    fn peak_utilization_tracks_load() {
        let inst = inst();
        let mut b = NodeState::new(&inst, 0, 0);
        b.add(&inst, 3);
        assert!((b.peak_utilization() - 0.3).abs() < 1e-12);
        b.add(&inst, 0);
        assert!((b.peak_utilization() - 0.9).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn inadmissible_task_panics() {
        let inst = Instance::new(
            vec![Task::new(0, vec![1.5], 0, 0)],
            vec![NodeType::new("a", vec![1.0], 1.0)],
            1,
        );
        let mut seq = 0;
        place_group(&inst, 0, &[0], FitPolicy::FirstFit, &mut seq);
    }
}
