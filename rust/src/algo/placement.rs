//! Greedy placement engine (the paper's Placement Phase, section III).
//!
//! Tasks mapped to one node-type are processed in increasing start-time
//! order; each is placed into an already-purchased node when it fits
//! (first-fit: earliest purchased; similarity-fit: highest cosine
//! similarity between the task's normalized demand and the node's
//! remaining capacity over the task span), else a new node is purchased.
//!
//! The hot path is indexed: node load profiles live in [`LoadProfile`]
//! lazy segment trees ((max, sum, sumsq) aggregates under range-add), and
//! `select_node` prunes candidates with an O(D) peak-headroom fast-accept
//! before paying for an exact windowed check. Per-operation complexity
//! (T = timeslots, D = dimensions, |S| = purchased nodes of the type,
//! span = task span length):
//!
//! | operation          | dense (seed)      | indexed (current)                     |
//! |--------------------|-------------------|---------------------------------------|
//! | `fits`             | O(span · D)       | O(D) fast-accept, O(D · log T) exact  |
//! | `add` / `remove`   | O(span · D)       | O(D · log T)                          |
//! | `similarity`       | O(span · D)       | O(D · log T)                          |
//! | `peak_utilization` | O(T · D)          | O(D)                                  |
//! | `select_node`      | O(|S| · span · D) | O(|S| · D) + exact checks on demand   |
//!
//! The seed's dense scan survives as [`DenseNodeState`] /
//! [`place_group_dense`] — the property-test reference and the benchmark
//! baseline that `benches/placement.rs` measures the indexed path
//! against in the same run.

use std::cmp::Ordering;

use crate::model::{DenseProfile, Instance, LoadProfile, PlacedNode, Profile, Solution};

/// Node-selection policy among feasible already-purchased nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FitPolicy {
    /// Paper's first-fit: the node purchased the earliest.
    FirstFit,
    /// Paper's similarity-fit: maximum cosine similarity between the
    /// capacity-normalized demand and remaining-capacity vectors, summed
    /// over the task's active timeslots.
    SimilarityFit,
}

/// Mutable state of one purchased node, generic over the load-profile
/// backend (indexed in production, dense in reference paths).
#[derive(Clone, Debug)]
pub struct NodeStateImpl<P: Profile> {
    pub type_idx: usize,
    pub purchase_order: usize,
    pub tasks: Vec<usize>,
    profile: P,
}

/// Production node state: indexed segment-tree profile.
pub type NodeState = NodeStateImpl<LoadProfile>;

/// Reference node state over the seed's dense per-timeslot array.
pub type DenseNodeState = NodeStateImpl<DenseProfile>;

impl<P: Profile> NodeStateImpl<P> {
    pub fn new(inst: &Instance, type_idx: usize, purchase_order: usize) -> Self {
        NodeStateImpl {
            type_idx,
            purchase_order,
            tasks: Vec::new(),
            profile: P::new(
                inst.horizon as usize,
                inst.node_types[type_idx].capacity.clone(),
            ),
        }
    }

    /// Does task `u` fit without violating capacity anywhere in its span?
    pub fn fits(&self, inst: &Instance, u: usize) -> bool {
        self.profile.fits(&inst.tasks[u])
    }

    /// Cosine similarity between capacity-normalized demand and remaining
    /// capacity, aggregated over the task span (paper section III,
    /// "Alternative Mapping and Fitting Policies").
    pub fn similarity(&self, inst: &Instance, u: usize) -> f64 {
        self.profile.similarity(&inst.tasks[u])
    }

    /// Add task `u` (caller must have checked `fits`).
    pub fn add(&mut self, inst: &Instance, u: usize) {
        self.profile.add_task(&inst.tasks[u]);
        self.tasks.push(u);
    }

    /// Remove a previously added task `u`.
    pub fn remove(&mut self, inst: &Instance, u: usize) {
        self.profile.remove_task(&inst.tasks[u]);
        self.tasks.retain(|&t| t != u);
    }

    /// Peak load fraction over the node's busiest (t, d).
    pub fn peak_utilization(&self) -> f64 {
        self.profile.peak_utilization()
    }

    /// Read access to the underlying load profile.
    pub fn profile(&self) -> &P {
        &self.profile
    }

    /// Rebuild the mutable state of an already-placed node (how local
    /// search re-enters placement state from a finished [`Solution`]).
    pub fn from_placed(inst: &Instance, node: &PlacedNode, purchase_order: usize) -> Self {
        let mut b = Self::new(inst, node.type_idx, purchase_order);
        for &u in &node.tasks {
            b.add(inst, u);
        }
        b
    }

    /// Retype the node: the capacity changes, the load profile stays
    /// (local search downgrade move).
    pub fn set_type(&mut self, inst: &Instance, type_idx: usize) {
        self.type_idx = type_idx;
        self.profile
            .set_cap(inst.node_types[type_idx].capacity.clone());
    }
}

/// Pick a feasible node per policy; `None` if nothing fits.
///
/// First-fit returns the earliest purchased feasible node; similarity-fit
/// the feasible node with maximum similarity, ties broken toward the
/// earliest index with a NaN-safe total ordering. Both scans lean on the
/// profile's O(D) peak-headroom fast-accept (candidate pruning) and only
/// fall back to the exact O(D·log T) windowed check when the whole
/// timeline is too loaded to decide.
pub fn select_node<P: Profile>(
    inst: &Instance,
    nodes: &[NodeStateImpl<P>],
    u: usize,
    policy: FitPolicy,
) -> Option<usize> {
    let task = &inst.tasks[u];
    match policy {
        FitPolicy::FirstFit => nodes.iter().position(|b| b.profile.fits(task)),
        FitPolicy::SimilarityFit => {
            let mut best: Option<(usize, f64)> = None;
            for (i, b) in nodes.iter().enumerate() {
                if b.profile.fits(task) {
                    let s = b.profile.similarity(task);
                    let better = match &best {
                        None => true,
                        Some((_, bs)) => s.total_cmp(bs) == Ordering::Greater,
                    };
                    if better {
                        best = Some((i, s));
                    }
                }
            }
            best.map(|(i, _)| i)
        }
    }
}

/// Place the given tasks (already filtered to one node-type) in increasing
/// start order, purchasing nodes of `type_idx` as needed. `purchase_seq`
/// is the global purchase counter shared across node-types.
pub fn place_group<P: Profile>(
    inst: &Instance,
    type_idx: usize,
    tasks: &[usize],
    policy: FitPolicy,
    purchase_seq: &mut usize,
) -> Vec<NodeStateImpl<P>> {
    let mut order: Vec<usize> = tasks.to_vec();
    order.sort_by_key(|&u| (inst.tasks[u].start, u));
    let mut nodes: Vec<NodeStateImpl<P>> = Vec::new();
    for u in order {
        match select_node(inst, &nodes, u, policy) {
            Some(i) => nodes[i].add(inst, u),
            None => {
                let mut b = NodeStateImpl::<P>::new(inst, type_idx, *purchase_seq);
                *purchase_seq += 1;
                assert!(
                    b.fits(inst, u),
                    "task {u} cannot fit an empty node of type {type_idx}: \
                     mapping must respect admissibility"
                );
                b.add(inst, u);
                nodes.push(b);
            }
        }
    }
    nodes
}

/// The seed's dense placement path — kept as the reference for property
/// tests and as the baseline `benches/placement.rs` measures against.
pub fn place_group_dense(
    inst: &Instance,
    type_idx: usize,
    tasks: &[usize],
    policy: FitPolicy,
    purchase_seq: &mut usize,
) -> Vec<DenseNodeState> {
    place_group::<DenseProfile>(inst, type_idx, tasks, policy, purchase_seq)
}

/// Assemble a [`Solution`] from per-type node lists.
pub fn to_solution<P: Profile>(
    inst: &Instance,
    groups: Vec<Vec<NodeStateImpl<P>>>,
) -> Solution {
    let mut sol = Solution::new(inst.n_tasks());
    for nodes in groups {
        for b in nodes {
            let idx = sol.nodes.len();
            for &u in &b.tasks {
                sol.assignment[u] = Some(idx);
            }
            sol.nodes.push(PlacedNode {
                type_idx: b.type_idx,
                purchase_order: b.purchase_order,
                tasks: b.tasks,
            });
        }
    }
    sol
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NodeType, Task};

    fn inst() -> Instance {
        Instance::new(
            vec![
                Task::new(0, vec![0.6], 0, 2),
                Task::new(1, vec![0.6], 1, 3),
                Task::new(2, vec![0.6], 4, 5),
                Task::new(3, vec![0.3], 0, 5),
            ],
            vec![NodeType::new("a", vec![1.0], 2.0)],
            6,
        )
    }

    #[test]
    fn first_fit_reuses_after_expiry() {
        let inst = inst();
        let mut seq = 0;
        let nodes: Vec<NodeState> =
            place_group(&inst, 0, &[0, 1, 2], FitPolicy::FirstFit, &mut seq);
        // tasks 0,1 overlap (1.2 > 1.0) -> 2 nodes; task 2 fits node 0 later
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[0].tasks, vec![0, 2]);
        assert_eq!(nodes[1].tasks, vec![1]);
    }

    #[test]
    fn capacity_respected() {
        let inst = inst();
        let mut seq = 0;
        let nodes: Vec<NodeState> =
            place_group(&inst, 0, &[0, 1, 2, 3], FitPolicy::FirstFit, &mut seq);
        let sol = to_solution(&inst, vec![nodes]);
        assert!(sol.verify(&inst).is_ok());
    }

    #[test]
    fn similarity_prefers_complementary_node() {
        // node 0 holds a balanced task (remaining capacity (0.7,0.7));
        // node 1 holds a cpu-heavy task (remaining (0.2,0.9)).
        // A memory-heavy task fits both; cosine similarity picks node 1
        // (complementary shape), while first-fit would pick node 0.
        let inst = Instance::new(
            vec![
                Task::new(0, vec![0.3, 0.3], 0, 0),
                Task::new(1, vec![0.8, 0.1], 0, 0),
                Task::new(2, vec![0.1, 0.6], 0, 0),
            ],
            vec![NodeType::new("a", vec![1.0, 1.0], 1.0)],
            1,
        );
        let mut seq = 0;
        let sim: Vec<NodeState> =
            place_group(&inst, 0, &[0, 1, 2], FitPolicy::SimilarityFit, &mut seq);
        assert_eq!(sim.len(), 2);
        let node_of_2 = sim.iter().position(|b| b.tasks.contains(&2)).unwrap();
        assert!(sim[node_of_2].tasks.contains(&1), "similarity: {sim:?}");

        let mut seq = 0;
        let ff: Vec<NodeState> =
            place_group(&inst, 0, &[0, 1, 2], FitPolicy::FirstFit, &mut seq);
        let node_of_2 = ff.iter().position(|b| b.tasks.contains(&2)).unwrap();
        assert!(ff[node_of_2].tasks.contains(&0), "first-fit: {ff:?}");
    }

    #[test]
    fn select_none_when_full() {
        let inst = Instance::new(
            vec![Task::new(0, vec![0.9], 0, 0), Task::new(1, vec![0.9], 0, 0)],
            vec![NodeType::new("a", vec![1.0], 1.0)],
            1,
        );
        let mut seq = 0;
        let mut nodes = vec![NodeState::new(&inst, 0, seq)];
        seq += 1;
        nodes[0].add(&inst, 0);
        assert_eq!(select_node(&inst, &nodes, 1, FitPolicy::FirstFit), None);
        let _ = seq;
    }

    #[test]
    fn peak_utilization_tracks_load() {
        let inst = inst();
        let mut b = NodeState::new(&inst, 0, 0);
        b.add(&inst, 3);
        assert!((b.peak_utilization() - 0.3).abs() < 1e-12);
        b.add(&inst, 0);
        assert!((b.peak_utilization() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn remove_undoes_add() {
        let inst = inst();
        let mut b = NodeState::new(&inst, 0, 0);
        b.add(&inst, 0);
        b.add(&inst, 3);
        b.remove(&inst, 0);
        assert_eq!(b.tasks, vec![3]);
        assert!((b.peak_utilization() - 0.3).abs() < 1e-9);
        // after removal the heavy overlapper fits again
        assert!(b.fits(&inst, 1));
    }

    #[test]
    fn dense_reference_places_identically() {
        let inst = inst();
        let mut seq_a = 0;
        let indexed: Vec<NodeState> =
            place_group(&inst, 0, &[0, 1, 2, 3], FitPolicy::FirstFit, &mut seq_a);
        let mut seq_b = 0;
        let dense = place_group_dense(&inst, 0, &[0, 1, 2, 3], FitPolicy::FirstFit, &mut seq_b);
        assert_eq!(indexed.len(), dense.len());
        for (a, b) in indexed.iter().zip(&dense) {
            assert_eq!(a.tasks, b.tasks);
            assert_eq!(a.purchase_order, b.purchase_order);
        }
    }

    #[test]
    #[should_panic]
    fn inadmissible_task_panics() {
        let inst = Instance::new(
            vec![Task::new(0, vec![1.5], 0, 0)],
            vec![NodeType::new("a", vec![1.0], 1.0)],
            1,
        );
        let mut seq = 0;
        let _: Vec<NodeState> = place_group(&inst, 0, &[0], FitPolicy::FirstFit, &mut seq);
    }
}
