//! Greedy placement engine (the paper's Placement Phase, section III).
//!
//! Tasks mapped to one node-type are processed in increasing start-time
//! order; each is placed into an already-purchased node when it fits
//! (first-fit: earliest purchased; similarity-fit: highest cosine
//! similarity between the task's normalized demand and the node's
//! remaining capacity over the task span), else a new node is purchased.
//!
//! The hot path is indexed: node load profiles live in [`LoadProfile`]
//! lazy segment trees ((max, sum, sumsq) aggregates under range-add), and
//! `select_node` prunes candidates with an O(D) peak-headroom fast-accept
//! before paying for an exact windowed check. Per-operation complexity
//! (T = timeslots, D = dimensions, |S| = purchased nodes of the type,
//! span = task span length):
//!
//! | operation          | dense (seed)      | indexed (current)                     |
//! |--------------------|-------------------|---------------------------------------|
//! | `fits`             | O(span · D)       | O(D) fast-accept, O(D · log T) exact  |
//! | `add` / `remove`   | O(span · D)       | O(D · log T)                          |
//! | `similarity`       | O(span · D)       | O(D · log T)                          |
//! | `peak_utilization` | O(T · D)          | O(D)                                  |
//! | `select_node`      | O(|S| · span · D) | O(|S| · D) + exact checks on demand   |
//!
//! On top of the per-node fast paths, first-fit placement maintains a
//! [`HeadroomIndex`]: purchased nodes bucketed by whole-timeline
//! headroom fraction (power-of-two thresholds, a `BTreeSet` of node ids
//! per bucket). A query computes the task's demand fraction `q` in O(D),
//! takes the minimum id over buckets whose guaranteed headroom exceeds
//! `q` — a node that *surely* fits — and only runs exact `fits` checks
//! on the prefix of earlier (more loaded) nodes. The returned node is
//! **bit-identical** to the linear scan's: the scan's prefix up to the
//! jump target is checked exactly, and the jump target itself satisfies
//! the O(D) sure-accept, so the minimum feasible index is unchanged.
//! What changes is the cost of skipping the long full-node prefix a
//! million-task first-fit otherwise rescans per task: amortized
//! O(D + log |S| + prefix of genuinely ambiguous nodes) instead of
//! O(|S| · D). The pre-index linear scan survives as
//! [`place_group_scan`] — the A/B baseline `benches/placement.rs`
//! reports as `bucketed_index_speedup`.
//!
//! The seed's dense scan survives as [`DenseNodeState`] /
//! [`place_group_dense`] — the property-test reference and the benchmark
//! baseline that `benches/placement.rs` measures the indexed path
//! against in the same run.

use std::cmp::Ordering;
use std::collections::BTreeSet;

use crate::model::{DenseProfile, Instance, LoadProfile, PlacedNode, Profile, Solution, Task};

/// Node-selection policy among feasible already-purchased nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FitPolicy {
    /// Paper's first-fit: the node purchased the earliest.
    FirstFit,
    /// Paper's similarity-fit: maximum cosine similarity between the
    /// capacity-normalized demand and remaining-capacity vectors, summed
    /// over the task's active timeslots.
    SimilarityFit,
}

/// Mutable state of one purchased node, generic over the load-profile
/// backend (indexed in production, dense in reference paths).
#[derive(Clone, Debug)]
pub struct NodeStateImpl<P: Profile> {
    pub type_idx: usize,
    pub purchase_order: usize,
    pub tasks: Vec<usize>,
    profile: P,
}

/// Production node state: indexed segment-tree profile.
pub type NodeState = NodeStateImpl<LoadProfile>;

/// Reference node state over the seed's dense per-timeslot array.
pub type DenseNodeState = NodeStateImpl<DenseProfile>;

impl<P: Profile> NodeStateImpl<P> {
    pub fn new(inst: &Instance, type_idx: usize, purchase_order: usize) -> Self {
        NodeStateImpl {
            type_idx,
            purchase_order,
            tasks: Vec::new(),
            profile: P::new(
                inst.horizon as usize,
                inst.node_types[type_idx].capacity.clone(),
            ),
        }
    }

    /// Does task `u` fit without violating capacity anywhere in its span?
    pub fn fits(&self, inst: &Instance, u: usize) -> bool {
        self.profile.fits(&inst.tasks[u])
    }

    /// Cosine similarity between capacity-normalized demand and remaining
    /// capacity, aggregated over the task span (paper section III,
    /// "Alternative Mapping and Fitting Policies").
    pub fn similarity(&self, inst: &Instance, u: usize) -> f64 {
        self.profile.similarity(&inst.tasks[u])
    }

    /// Add task `u` (caller must have checked `fits`).
    pub fn add(&mut self, inst: &Instance, u: usize) {
        self.profile.add_task(&inst.tasks[u]);
        self.tasks.push(u);
    }

    /// Remove a previously added task `u`.
    pub fn remove(&mut self, inst: &Instance, u: usize) {
        self.profile.remove_task(&inst.tasks[u]);
        self.tasks.retain(|&t| t != u);
    }

    /// Peak load fraction over the node's busiest (t, d).
    pub fn peak_utilization(&self) -> f64 {
        self.profile.peak_utilization()
    }

    /// Read access to the underlying load profile.
    pub fn profile(&self) -> &P {
        &self.profile
    }

    /// Rebuild the mutable state of an already-placed node (how local
    /// search re-enters placement state from a finished [`Solution`]).
    pub fn from_placed(inst: &Instance, node: &PlacedNode, purchase_order: usize) -> Self {
        let mut b = Self::new(inst, node.type_idx, purchase_order);
        for &u in &node.tasks {
            b.add(inst, u);
        }
        b
    }

    /// Retype the node: the capacity changes, the load profile stays
    /// (local search downgrade move).
    pub fn set_type(&mut self, inst: &Instance, type_idx: usize) {
        self.type_idx = type_idx;
        self.profile
            .set_cap(inst.node_types[type_idx].capacity.clone());
    }
}

/// Pick a feasible node per policy; `None` if nothing fits.
///
/// First-fit returns the earliest purchased feasible node; similarity-fit
/// the feasible node with maximum similarity, ties broken toward the
/// earliest index with a NaN-safe total ordering. Both scans lean on the
/// profile's O(D) peak-headroom fast-accept (candidate pruning) and only
/// fall back to the exact O(D·log T) windowed check when the whole
/// timeline is too loaded to decide.
pub fn select_node<P: Profile>(
    inst: &Instance,
    nodes: &[NodeStateImpl<P>],
    u: usize,
    policy: FitPolicy,
) -> Option<usize> {
    let task = &inst.tasks[u];
    match policy {
        FitPolicy::FirstFit => nodes.iter().position(|b| b.profile.fits(task)),
        FitPolicy::SimilarityFit => {
            let mut best: Option<(usize, f64)> = None;
            for (i, b) in nodes.iter().enumerate() {
                if b.profile.fits(task) {
                    let s = b.profile.similarity(task);
                    let better = match &best {
                        None => true,
                        Some((_, bs)) => s.total_cmp(bs) == Ordering::Greater,
                    };
                    if better {
                        best = Some((i, s));
                    }
                }
            }
            best.map(|(i, _)| i)
        }
    }
}

/// Number of headroom buckets: thresholds halve from 1 down to 2^-10,
/// with a final catch-all for (near-)full nodes that can never be a
/// sure fit.
const HR_BUCKETS: usize = 11;

/// `THRESH[k] = 2^-k`. Bucket `k < HR_BUCKETS-1` holds nodes with
/// headroom in `(THRESH[k+1], THRESH[k]]`; the last bucket holds the
/// rest (headroom <= 2^-10, including negative on EPS-overfull nodes).
const THRESH: [f64; HR_BUCKETS] = [
    1.0,
    0.5,
    0.25,
    0.125,
    0.0625,
    0.03125,
    0.015625,
    0.0078125,
    0.00390625,
    0.001953125,
    0.0009765625,
];

/// Minimum per-dimension headroom fraction of a node profile over the
/// whole timeline: `min_d (cap_d - peak_d) / cap_d`. O(D) when the
/// backend has [`Profile::CHEAP_PEAKS`].
pub fn headroom<P: Profile>(profile: &P) -> f64 {
    profile
        .cap()
        .iter()
        .enumerate()
        .map(|(d, &c)| (c - profile.peak(d)) / c)
        .fold(f64::INFINITY, f64::min)
}

/// Bucketed-headroom candidate index over one first-fit node group.
///
/// First-fit wants the *minimum* feasible node index, and as nodes fill
/// up the feasible prefix starts ever later — yet the plain scan re-pays
/// an exact check per full node, per task. The index keeps each node in
/// a bucket keyed by its current headroom fraction; for a task demanding
/// fraction `q` it finds the earliest node in any bucket guaranteeing
/// headroom > `q` (a *sure* fit by the O(D) peak argument) and exact-
/// checks only the nodes before it. Returns exactly what the linear scan
/// returns — see the module docs for the argument — so the indexed and
/// scan paths are interchangeable, and `place_group` keeps determinism
/// while skipping the full-node prefix.
#[derive(Clone, Debug)]
pub struct HeadroomIndex {
    buckets: Vec<BTreeSet<usize>>,
    /// `slot[i]` = bucket currently holding node `i`.
    slot: Vec<usize>,
}

impl Default for HeadroomIndex {
    fn default() -> Self {
        Self::new()
    }
}

impl HeadroomIndex {
    pub fn new() -> Self {
        HeadroomIndex { buckets: vec![BTreeSet::new(); HR_BUCKETS], slot: Vec::new() }
    }

    fn bucket_of(hr: f64) -> usize {
        for k in 0..HR_BUCKETS - 1 {
            if hr > THRESH[k + 1] {
                return k;
            }
        }
        HR_BUCKETS - 1
    }

    /// Register the next node (ids must arrive densely: 0, 1, 2, ...).
    pub fn insert(&mut self, hr: f64) {
        let k = Self::bucket_of(hr);
        let i = self.slot.len();
        self.buckets[k].insert(i);
        self.slot.push(k);
    }

    /// Re-bucket node `i` after its load (and so headroom) changed.
    pub fn update(&mut self, i: usize, hr: f64) {
        let k = Self::bucket_of(hr);
        let old = self.slot[i];
        if k != old {
            self.buckets[old].remove(&i);
            self.buckets[k].insert(i);
            self.slot[i] = k;
        }
    }

    /// First-fit select: bit-identical to
    /// `nodes.iter().position(|b| b.profile.fits(task))`, paying exact
    /// checks only for nodes before the earliest sure fit.
    pub fn select<P: Profile>(
        &self,
        nodes: &[NodeStateImpl<P>],
        task: &Task,
        cap: &[f64],
    ) -> Option<usize> {
        let peak_dem = task.peak();
        let mut q = 0.0f64;
        for (d, &c) in cap.iter().enumerate() {
            q = q.max(peak_dem[d] / c);
        }
        // earliest node whose bucket guarantees headroom > q (strictly:
        // bucket k holds hr > THRESH[k+1] >= q). Buckets are ordered by
        // decreasing threshold, so qualifying buckets form a prefix.
        let mut jump: Option<usize> = None;
        for k in 0..HR_BUCKETS - 1 {
            if THRESH[k + 1] < q {
                break;
            }
            if let Some(&i) = self.buckets[k].first() {
                jump = Some(jump.map_or(i, |j| j.min(i)));
            }
        }
        let limit = jump.map_or(nodes.len(), |j| j.min(nodes.len()));
        for (i, b) in nodes.iter().enumerate().take(limit) {
            if b.profile.fits(task) {
                return Some(i);
            }
        }
        jump.filter(|&j| j < nodes.len())
    }
}

/// Place the given tasks (already filtered to one node-type) in increasing
/// start order, purchasing nodes of `type_idx` as needed. `purchase_seq`
/// is the global purchase counter shared across node-types.
///
/// First-fit on a cheap-peaks backend runs through the
/// [`HeadroomIndex`]; every other (policy, backend) combination takes
/// the plain scan. Both produce identical placements.
pub fn place_group<P: Profile>(
    inst: &Instance,
    type_idx: usize,
    tasks: &[usize],
    policy: FitPolicy,
    purchase_seq: &mut usize,
) -> Vec<NodeStateImpl<P>> {
    if !(P::CHEAP_PEAKS && policy == FitPolicy::FirstFit) {
        return place_group_scan(inst, type_idx, tasks, policy, purchase_seq);
    }
    let cap = &inst.node_types[type_idx].capacity;
    let mut order: Vec<usize> = tasks.to_vec();
    order.sort_by_key(|&u| (inst.tasks[u].start, u));
    let mut nodes: Vec<NodeStateImpl<P>> = Vec::new();
    let mut index = HeadroomIndex::new();
    for u in order {
        match index.select(&nodes, &inst.tasks[u], cap) {
            Some(i) => {
                nodes[i].add(inst, u);
                index.update(i, headroom(nodes[i].profile()));
            }
            None => {
                let mut b = NodeStateImpl::<P>::new(inst, type_idx, *purchase_seq);
                *purchase_seq += 1;
                assert!(
                    b.fits(inst, u),
                    "task {u} cannot fit an empty node of type {type_idx}: \
                     mapping must respect admissibility"
                );
                b.add(inst, u);
                index.insert(headroom(b.profile()));
                nodes.push(b);
            }
        }
    }
    nodes
}

/// The pre-index placement loop: linear `select_node` scan per task.
/// Kept callable as the A/B baseline for the bucketed-headroom index
/// (`benches/placement.rs` reports indexed-vs-scan as
/// `bucketed_index_speedup`); produces the same placement as
/// [`place_group`].
pub fn place_group_scan<P: Profile>(
    inst: &Instance,
    type_idx: usize,
    tasks: &[usize],
    policy: FitPolicy,
    purchase_seq: &mut usize,
) -> Vec<NodeStateImpl<P>> {
    let mut order: Vec<usize> = tasks.to_vec();
    order.sort_by_key(|&u| (inst.tasks[u].start, u));
    let mut nodes: Vec<NodeStateImpl<P>> = Vec::new();
    for u in order {
        match select_node(inst, &nodes, u, policy) {
            Some(i) => nodes[i].add(inst, u),
            None => {
                let mut b = NodeStateImpl::<P>::new(inst, type_idx, *purchase_seq);
                *purchase_seq += 1;
                assert!(
                    b.fits(inst, u),
                    "task {u} cannot fit an empty node of type {type_idx}: \
                     mapping must respect admissibility"
                );
                b.add(inst, u);
                nodes.push(b);
            }
        }
    }
    nodes
}

/// The seed's dense placement path — kept as the reference for property
/// tests and as the baseline `benches/placement.rs` measures against.
pub fn place_group_dense(
    inst: &Instance,
    type_idx: usize,
    tasks: &[usize],
    policy: FitPolicy,
    purchase_seq: &mut usize,
) -> Vec<DenseNodeState> {
    place_group::<DenseProfile>(inst, type_idx, tasks, policy, purchase_seq)
}

/// Assemble a [`Solution`] from per-type node lists.
pub fn to_solution<P: Profile>(
    inst: &Instance,
    groups: Vec<Vec<NodeStateImpl<P>>>,
) -> Solution {
    let mut sol = Solution::new(inst.n_tasks());
    for nodes in groups {
        for b in nodes {
            let idx = sol.nodes.len();
            for &u in &b.tasks {
                sol.assignment[u] = Some(idx);
            }
            sol.nodes.push(PlacedNode {
                type_idx: b.type_idx,
                purchase_order: b.purchase_order,
                tasks: b.tasks,
            });
        }
    }
    sol
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NodeType, Task};

    fn inst() -> Instance {
        Instance::new(
            vec![
                Task::new(0, vec![0.6], 0, 2),
                Task::new(1, vec![0.6], 1, 3),
                Task::new(2, vec![0.6], 4, 5),
                Task::new(3, vec![0.3], 0, 5),
            ],
            vec![NodeType::new("a", vec![1.0], 2.0)],
            6,
        )
    }

    #[test]
    fn first_fit_reuses_after_expiry() {
        let inst = inst();
        let mut seq = 0;
        let nodes: Vec<NodeState> =
            place_group(&inst, 0, &[0, 1, 2], FitPolicy::FirstFit, &mut seq);
        // tasks 0,1 overlap (1.2 > 1.0) -> 2 nodes; task 2 fits node 0 later
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[0].tasks, vec![0, 2]);
        assert_eq!(nodes[1].tasks, vec![1]);
    }

    #[test]
    fn capacity_respected() {
        let inst = inst();
        let mut seq = 0;
        let nodes: Vec<NodeState> =
            place_group(&inst, 0, &[0, 1, 2, 3], FitPolicy::FirstFit, &mut seq);
        let sol = to_solution(&inst, vec![nodes]);
        assert!(sol.verify(&inst).is_ok());
    }

    #[test]
    fn similarity_prefers_complementary_node() {
        // node 0 holds a balanced task (remaining capacity (0.7,0.7));
        // node 1 holds a cpu-heavy task (remaining (0.2,0.9)).
        // A memory-heavy task fits both; cosine similarity picks node 1
        // (complementary shape), while first-fit would pick node 0.
        let inst = Instance::new(
            vec![
                Task::new(0, vec![0.3, 0.3], 0, 0),
                Task::new(1, vec![0.8, 0.1], 0, 0),
                Task::new(2, vec![0.1, 0.6], 0, 0),
            ],
            vec![NodeType::new("a", vec![1.0, 1.0], 1.0)],
            1,
        );
        let mut seq = 0;
        let sim: Vec<NodeState> =
            place_group(&inst, 0, &[0, 1, 2], FitPolicy::SimilarityFit, &mut seq);
        assert_eq!(sim.len(), 2);
        let node_of_2 = sim.iter().position(|b| b.tasks.contains(&2)).unwrap();
        assert!(sim[node_of_2].tasks.contains(&1), "similarity: {sim:?}");

        let mut seq = 0;
        let ff: Vec<NodeState> =
            place_group(&inst, 0, &[0, 1, 2], FitPolicy::FirstFit, &mut seq);
        let node_of_2 = ff.iter().position(|b| b.tasks.contains(&2)).unwrap();
        assert!(ff[node_of_2].tasks.contains(&0), "first-fit: {ff:?}");
    }

    #[test]
    fn select_none_when_full() {
        let inst = Instance::new(
            vec![Task::new(0, vec![0.9], 0, 0), Task::new(1, vec![0.9], 0, 0)],
            vec![NodeType::new("a", vec![1.0], 1.0)],
            1,
        );
        let mut seq = 0;
        let mut nodes = vec![NodeState::new(&inst, 0, seq)];
        seq += 1;
        nodes[0].add(&inst, 0);
        assert_eq!(select_node(&inst, &nodes, 1, FitPolicy::FirstFit), None);
        let _ = seq;
    }

    #[test]
    fn peak_utilization_tracks_load() {
        let inst = inst();
        let mut b = NodeState::new(&inst, 0, 0);
        b.add(&inst, 3);
        assert!((b.peak_utilization() - 0.3).abs() < 1e-12);
        b.add(&inst, 0);
        assert!((b.peak_utilization() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn remove_undoes_add() {
        let inst = inst();
        let mut b = NodeState::new(&inst, 0, 0);
        b.add(&inst, 0);
        b.add(&inst, 3);
        b.remove(&inst, 0);
        assert_eq!(b.tasks, vec![3]);
        assert!((b.peak_utilization() - 0.3).abs() < 1e-9);
        // after removal the heavy overlapper fits again
        assert!(b.fits(&inst, 1));
    }

    #[test]
    fn dense_reference_places_identically() {
        let inst = inst();
        let mut seq_a = 0;
        let indexed: Vec<NodeState> =
            place_group(&inst, 0, &[0, 1, 2, 3], FitPolicy::FirstFit, &mut seq_a);
        let mut seq_b = 0;
        let dense = place_group_dense(&inst, 0, &[0, 1, 2, 3], FitPolicy::FirstFit, &mut seq_b);
        assert_eq!(indexed.len(), dense.len());
        for (a, b) in indexed.iter().zip(&dense) {
            assert_eq!(a.tasks, b.tasks);
            assert_eq!(a.purchase_order, b.purchase_order);
        }
    }

    #[test]
    fn bucket_of_thresholds() {
        assert_eq!(HeadroomIndex::bucket_of(1.0), 0);
        assert_eq!(HeadroomIndex::bucket_of(0.6), 0);
        assert_eq!(HeadroomIndex::bucket_of(0.5), 1);
        assert_eq!(HeadroomIndex::bucket_of(0.3), 1);
        assert_eq!(HeadroomIndex::bucket_of(0.25), 2);
        assert_eq!(HeadroomIndex::bucket_of(0.001), HR_BUCKETS - 1);
        assert_eq!(HeadroomIndex::bucket_of(0.0), HR_BUCKETS - 1);
        assert_eq!(HeadroomIndex::bucket_of(-0.1), HR_BUCKETS - 1);
    }

    #[test]
    fn indexed_first_fit_matches_scan() {
        // pseudo-random workload (LCG, fixed seed): mixed spans and
        // demand fractions spanning several headroom buckets; the
        // indexed placement must be node-for-node identical to the scan
        // and to the dense reference
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut rng = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f64) / ((1u64 << 31) as f64)
        };
        let horizon = 48u32;
        let mut tasks = Vec::new();
        for id in 0..160u64 {
            let start = (rng() * 40.0) as u32;
            let end = (start + 1 + (rng() * 8.0) as u32).min(horizon - 1);
            let d0 = 0.02 + rng() * 0.55;
            let d1 = 0.02 + rng() * 0.55;
            tasks.push(Task::new(id, vec![d0, d1], start, end));
        }
        let inst = Instance::new(
            tasks,
            vec![NodeType::new("a", vec![1.0, 1.0], 1.0)],
            horizon,
        );
        let all: Vec<usize> = (0..inst.n_tasks()).collect();
        let mut seq_a = 0;
        let indexed: Vec<NodeState> =
            place_group(&inst, 0, &all, FitPolicy::FirstFit, &mut seq_a);
        let mut seq_b = 0;
        let scan: Vec<NodeState> =
            place_group_scan(&inst, 0, &all, FitPolicy::FirstFit, &mut seq_b);
        let mut seq_c = 0;
        let dense = place_group_dense(&inst, 0, &all, FitPolicy::FirstFit, &mut seq_c);
        assert_eq!(indexed.len(), scan.len());
        assert_eq!(indexed.len(), dense.len());
        for ((a, b), c) in indexed.iter().zip(&scan).zip(&dense) {
            assert_eq!(a.tasks, b.tasks);
            assert_eq!(a.tasks, c.tasks);
            assert_eq!(a.purchase_order, b.purchase_order);
        }
        assert!(indexed.len() > 3, "workload too easy to exercise the index");
        let sol = to_solution(&inst, vec![indexed]);
        assert!(sol.verify(&inst).is_ok());
    }

    #[test]
    fn headroom_index_select_agrees_with_position_scan() {
        // drive the index through adds that cross bucket boundaries and
        // compare select() against the naive position() at every step
        let inst = Instance::new(
            (0..40u64)
                .map(|id| {
                    let frac = 0.05 + 0.9 * ((id * 7 % 13) as f64) / 13.0;
                    let start = (id % 5) as u32;
                    Task::new(id, vec![frac.min(0.95)], start, (start + 3).min(9))
                })
                .collect(),
            vec![NodeType::new("a", vec![1.0], 1.0)],
            10,
        );
        let mut nodes: Vec<NodeState> = Vec::new();
        let mut index = HeadroomIndex::new();
        let cap = vec![1.0];
        let mut seq = 0;
        for u in 0..inst.n_tasks() {
            let task = &inst.tasks[u];
            let want = nodes.iter().position(|b| b.profile().fits(task));
            let got = index.select(&nodes, task, &cap);
            assert_eq!(got, want, "task {u}");
            match got {
                Some(i) => {
                    nodes[i].add(&inst, u);
                    index.update(i, headroom(nodes[i].profile()));
                }
                None => {
                    let mut b = NodeState::new(&inst, 0, seq);
                    seq += 1;
                    b.add(&inst, u);
                    index.insert(headroom(b.profile()));
                    nodes.push(b);
                }
            }
        }
        assert!(nodes.len() > 2);
    }

    #[test]
    #[should_panic]
    fn inadmissible_task_panics() {
        let inst = Instance::new(
            vec![Task::new(0, vec![1.5], 0, 0)],
            vec![NodeType::new("a", vec![1.0], 1.0)],
            1,
        );
        let mut seq = 0;
        let _: Vec<NodeState> = place_group(&inst, 0, &[0], FitPolicy::FirstFit, &mut seq);
    }
}
