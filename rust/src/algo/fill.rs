//! Cross-node-type filling (paper section V-D, Figure 6).
//!
//! Node-types are processed in decreasing capacity-per-cost order
//! (`sum_d cap(B,d) / cost(B)`). For each node-type B: first its own
//! remaining mapped tasks are placed greedily (purchasing nodes), then
//! every still-unplaced task — regardless of mapping — gets a chance to
//! piggy-back into the leftover capacity of B's nodes, in increasing
//! `h_avg(u|B)` order, never purchasing. Tasks mapped to less
//! cost-effective node-types thus ride along on cheaper capacity.

use crate::model::{Instance, PlacedNode, Solution};
use crate::util::pool::run_indexed;

use super::penalty_map::h_avg_matrix;
use super::placement::{place_group, select_node, to_solution, FitPolicy, NodeState};

/// Node-type processing order: decreasing capacity per cost. NaN-safe
/// total ordering with a deterministic index tie-break.
pub fn type_order(inst: &Instance) -> Vec<usize> {
    let mut order: Vec<usize> = (0..inst.n_types()).collect();
    order.sort_by(|&a, &b| {
        inst.node_types[b]
            .capacity_per_cost()
            .total_cmp(&inst.node_types[a].capacity_per_cost())
            .then(a.cmp(&b))
    });
    order
}

/// Two-phase solve with cross-node-type filling.
pub fn solve_with_filling(
    inst: &Instance,
    mapping: &[usize],
    policy: FitPolicy,
) -> Solution {
    let m = inst.n_types();
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); m];
    for (u, &b) in mapping.iter().enumerate() {
        groups[b].push(u);
    }
    let mut remaining = vec![true; inst.n_tasks()];
    let mut placed_groups: Vec<Vec<NodeState>> = Vec::with_capacity(m);
    let mut seq = 0usize;
    // h_avg(u|B) for every pair, computed once per solve: the seed
    // re-derived the O(D) aggregate inside the sort comparator below,
    // costing O(n·D·log n) per node-type.
    let h_avg = h_avg_matrix(inst);

    for &b in &type_order(inst) {
        // 1. place this node-type's own still-remaining tasks
        let own: Vec<usize> =
            groups[b].iter().copied().filter(|&u| remaining[u]).collect();
        let mut nodes: Vec<NodeState> = place_group(inst, b, &own, policy, &mut seq);
        for u in &own {
            remaining[*u] = false;
        }

        // 2. piggy-back: all remaining tasks, cheapest-footprint first
        // (cached h_avg key, NaN-safe, deterministic index tie-break)
        let mut rest: Vec<usize> =
            (0..inst.n_tasks()).filter(|&u| remaining[u]).collect();
        rest.sort_by(|&u, &v| {
            h_avg[u * m + b].total_cmp(&h_avg[v * m + b]).then(u.cmp(&v))
        });
        for u in rest {
            if let Some(i) = select_node(inst, &nodes, u, policy) {
                nodes[i].add(inst, u);
                remaining[u] = false;
            }
        }
        placed_groups.push(nodes);
    }
    debug_assert!(remaining.iter().all(|&r| !r), "all tasks placed");
    to_solution(inst, placed_groups)
}

/// Victims with peak utilization below this fraction are offered for
/// cross-type relocation in the stitch pass. Half-empty is the natural
/// threshold: a victim above it rarely fits into leftovers anyway, and
/// scanning every nearly-full node against every target is the cost the
/// pass exists to avoid.
const STITCH_VICTIM_UTIL: f64 = 0.5;

/// Pick a destination among `cand` (indices into `nodes`) for task `u`,
/// honoring the fit policy; never purchases. The candidate list is
/// already in the deterministic order the policy scans (ascending
/// purchase order for first-fit).
fn masked_select(
    inst: &Instance,
    nodes: &[NodeState],
    cand: &[usize],
    u: usize,
    policy: FitPolicy,
) -> Option<usize> {
    match policy {
        FitPolicy::FirstFit => cand.iter().copied().find(|&i| nodes[i].fits(inst, u)),
        FitPolicy::SimilarityFit => {
            let mut best: Option<(usize, f64)> = None;
            for &i in cand {
                if nodes[i].fits(inst, u) {
                    let s = nodes[i].similarity(inst, u);
                    let better = match &best {
                        None => true,
                        Some((_, bs)) => s.total_cmp(bs) == std::cmp::Ordering::Greater,
                    };
                    if better {
                        best = Some((i, s));
                    }
                }
            }
            best.map(|(i, _)| i)
        }
    }
}

/// Try to relocate every task of `nodes[victim]` into the candidate
/// nodes, all-or-nothing: either the victim empties completely (true)
/// or every tentative move is rolled back (false). Candidates must not
/// include the victim.
fn drain_node(
    inst: &Instance,
    nodes: &mut [NodeState],
    victim: usize,
    cand: &[usize],
    policy: FitPolicy,
) -> bool {
    let tasks = nodes[victim].tasks.clone();
    let mut moves: Vec<(usize, usize)> = Vec::with_capacity(tasks.len());
    for &u in &tasks {
        // the victim still holds u while probing destinations: fine, the
        // candidate profiles are independent of the victim's
        match masked_select(inst, nodes, cand, u, policy) {
            Some(i) => {
                nodes[i].add(inst, u);
                moves.push((u, i));
            }
            None => {
                for &(mu, mi) in moves.iter().rev() {
                    nodes[mi].remove(inst, mu);
                }
                return false;
            }
        }
    }
    for &u in &tasks {
        nodes[victim].remove(inst, u);
    }
    true
}

/// The stitching refine pass over a merged node pool — cross-fill
/// re-imagined for decomposed solves, and the parallel half of the
/// "parallel cross-fill" lever.
///
/// A decomposed solve (`algo/decompose.rs`) concatenates per-partition
/// solutions, so nodes purchased by different partitions never share
/// tasks even when one partition's leftovers could absorb another's —
/// exactly the waste cross-fill hunts. Stitching runs in two phases:
///
/// 1. **Per-type compaction, in parallel.** Node-type groups are
///    independent, so each runs on the worker pool: walk the type's
///    nodes in ascending purchase order and try to relocate each node's
///    tasks — all-or-nothing, with rollback — into earlier kept nodes
///    of the same type. Emptied nodes are dropped. Purchase order makes
///    the walk deterministic regardless of scheduling.
/// 2. **Cross-type piggyback, sequential.** In decreasing
///    capacity-per-cost order (the same `type_order` as filling), offer
///    every under-utilized node of *other* types (peak utilization
///    below [`STITCH_VICTIM_UTIL`]) for all-or-nothing relocation into
///    the target type's kept nodes. Nothing is ever purchased, so any
///    completed relocation saves the victim's whole node cost.
///
/// Kept nodes are renumbered by original purchase order, so the result
/// is deterministic and `cost(stitched) <= cost(input)` always — the
/// pass only ever drops nodes.
pub fn stitch_fill(inst: &Instance, sol: &Solution, policy: FitPolicy) -> Solution {
    let m = inst.n_types();
    // canonical node order: ascending purchase order
    let mut order: Vec<usize> = (0..sol.nodes.len()).collect();
    order.sort_by_key(|&i| sol.nodes[i].purchase_order);
    let mut by_type: Vec<Vec<&PlacedNode>> = vec![Vec::new(); m];
    for &i in &order {
        by_type[sol.nodes[i].type_idx].push(&sol.nodes[i]);
    }

    // phase 1: per-type compaction on the worker pool
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let compacted: Vec<Vec<NodeState>> = run_indexed(m, workers.min(m.max(1)), |b| {
        let mut states: Vec<NodeState> = by_type[b]
            .iter()
            .map(|node| NodeState::from_placed(inst, node, node.purchase_order))
            .collect();
        let mut kept = vec![true; states.len()];
        for j in 1..states.len() {
            let cand: Vec<usize> = (0..j).filter(|&i| kept[i]).collect();
            if cand.is_empty() {
                continue;
            }
            if drain_node(inst, &mut states, j, &cand, policy) {
                kept[j] = false;
            }
        }
        states
            .into_iter()
            .zip(kept)
            .filter_map(|(s, k)| k.then_some(s))
            .collect()
    });

    // phase 2: sequential cross-type piggyback into value-ordered types
    let mut all: Vec<NodeState> = compacted.into_iter().flatten().collect();
    all.sort_by_key(|s| s.purchase_order);
    let mut kept = vec![true; all.len()];
    for &b in &type_order(inst) {
        let targets: Vec<usize> = (0..all.len())
            .filter(|&i| kept[i] && all[i].type_idx == b)
            .collect();
        if targets.is_empty() {
            continue;
        }
        let victims: Vec<usize> = (0..all.len())
            .filter(|&i| {
                kept[i]
                    && all[i].type_idx != b
                    && all[i].peak_utilization() < STITCH_VICTIM_UTIL
            })
            .collect();
        for v in victims {
            if drain_node(inst, &mut all, v, &targets, policy) {
                kept[v] = false;
            }
        }
    }

    // assemble: kept nodes, renumbered along original purchase order
    let mut out = Solution::new(inst.n_tasks());
    for (state, keep) in all.into_iter().zip(kept) {
        if !keep {
            continue;
        }
        let idx = out.nodes.len();
        for &u in &state.tasks {
            out.assignment[u] = Some(idx);
        }
        out.nodes.push(PlacedNode {
            type_idx: state.type_idx,
            purchase_order: idx,
            tasks: state.tasks,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NodeType, Task};

    #[test]
    fn type_order_by_value() {
        let inst = Instance::new(
            vec![Task::new(0, vec![0.1], 0, 0)],
            vec![
                NodeType::new("pricey", vec![1.0], 4.0),  // 0.25 cap/cost
                NodeType::new("value", vec![1.0], 1.0),   // 1.0
                NodeType::new("mid", vec![0.5], 1.0),     // 0.5
            ],
            1,
        );
        assert_eq!(type_order(&inst), vec![1, 2, 0]);
    }

    #[test]
    fn piggyback_avoids_new_node() {
        // Task 1 is mapped to the expensive type but fits in the leftover
        // capacity of the node purchased for task 0 -> only one node bought.
        let inst = Instance::new(
            vec![
                Task::new(0, vec![0.5], 0, 1),
                Task::new(1, vec![0.4], 0, 1),
            ],
            vec![
                NodeType::new("value", vec![1.0], 1.0),
                NodeType::new("pricey", vec![1.0], 3.0),
            ],
            2,
        );
        let mapping = vec![0, 1];
        let sol = solve_with_filling(&inst, &mapping, FitPolicy::FirstFit);
        assert!(sol.verify(&inst).is_ok());
        assert_eq!(sol.nodes.len(), 1);
        assert_eq!(sol.nodes[0].type_idx, 0);
        assert!((sol.cost(&inst) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn no_piggyback_when_no_room() {
        let inst = Instance::new(
            vec![
                Task::new(0, vec![0.9], 0, 1),
                Task::new(1, vec![0.4], 0, 1),
            ],
            vec![
                NodeType::new("value", vec![1.0], 1.0),
                NodeType::new("pricey", vec![1.0], 3.0),
            ],
            2,
        );
        let sol = solve_with_filling(&inst, &[0, 1], FitPolicy::FirstFit);
        assert!(sol.verify(&inst).is_ok());
        assert_eq!(sol.nodes.len(), 2);
        assert!((sol.cost(&inst) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn fill_order_prefers_small_tasks() {
        // leftover space 0.5; two candidates mapped elsewhere: a 0.3 and a
        // 0.4; filling in increasing h_avg places the 0.3 first, then the
        // 0.4 cannot fit — deterministic by the paper's ordering.
        let inst = Instance::new(
            vec![
                Task::new(0, vec![0.5], 0, 0),
                Task::new(1, vec![0.4], 0, 0),
                Task::new(2, vec![0.3], 0, 0),
            ],
            vec![
                NodeType::new("value", vec![1.0], 1.0),
                NodeType::new("pricey", vec![1.0], 2.0),
            ],
            1,
        );
        let sol = solve_with_filling(&inst, &[0, 1, 1], FitPolicy::FirstFit);
        assert!(sol.verify(&inst).is_ok());
        // node 0 holds tasks 0 and 2; task 1 forced onto pricey type
        let n0 = &sol.nodes[0];
        assert!(n0.tasks.contains(&0) && n0.tasks.contains(&2));
        assert_eq!(sol.nodes.len(), 2);
    }

    #[test]
    fn stitch_merges_underfull_same_type_nodes() {
        // a merged two-partition solution: each partition bought its own
        // half-empty node; stitching folds them into one
        let inst = Instance::new(
            vec![Task::new(0, vec![0.3], 0, 3), Task::new(1, vec![0.3], 0, 3)],
            vec![NodeType::new("a", vec![1.0], 2.0)],
            4,
        );
        let merged = Solution {
            nodes: vec![
                PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0] },
                PlacedNode { type_idx: 0, purchase_order: 1, tasks: vec![1] },
            ],
            assignment: vec![Some(0), Some(1)],
        };
        assert!(merged.verify(&inst).is_ok());
        let stitched = stitch_fill(&inst, &merged, FitPolicy::FirstFit);
        assert!(stitched.verify(&inst).is_ok());
        assert_eq!(stitched.nodes.len(), 1);
        assert_eq!(stitched.nodes[0].tasks, vec![0, 1]);
        assert!(stitched.cost(&inst) <= merged.cost(&inst));
    }

    #[test]
    fn stitch_relocates_across_types_only_when_whole_node_drains() {
        // a lonely task on a pricey node fits the value node's leftover:
        // the pricey node must be dropped entirely
        let inst = Instance::new(
            vec![Task::new(0, vec![0.5], 0, 1), Task::new(1, vec![0.3], 0, 1)],
            vec![
                NodeType::new("value", vec![1.0], 1.0),
                NodeType::new("pricey", vec![1.0], 3.0),
            ],
            2,
        );
        let merged = Solution {
            nodes: vec![
                PlacedNode { type_idx: 0, purchase_order: 0, tasks: vec![0] },
                PlacedNode { type_idx: 1, purchase_order: 1, tasks: vec![1] },
            ],
            assignment: vec![Some(0), Some(1)],
        };
        let stitched = stitch_fill(&inst, &merged, FitPolicy::FirstFit);
        assert!(stitched.verify(&inst).is_ok());
        assert_eq!(stitched.nodes.len(), 1);
        assert_eq!(stitched.nodes[0].type_idx, 0);
        assert!((stitched.cost(&inst) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stitch_never_raises_cost_and_keeps_feasibility() {
        use crate::algo::penalty_map::{map_tasks, MappingPolicy};
        use crate::io::synth::{generate, SynthParams};
        use crate::model::trim;
        for seed in 0..6 {
            let inst =
                generate(&SynthParams { n: 140, m: 5, ..Default::default() }, seed + 21);
            let tr = trim(&inst).instance;
            let mapping = map_tasks(&tr, MappingPolicy::HAvg);
            for policy in [FitPolicy::FirstFit, FitPolicy::SimilarityFit] {
                let base = crate::algo::twophase::solve_with_mapping(
                    &tr, &mapping, policy, false,
                );
                let stitched = stitch_fill(&tr, &base, policy);
                assert!(
                    stitched.verify(&tr).is_ok(),
                    "seed {seed} {policy:?}: {:?}",
                    stitched.verify(&tr)
                );
                assert!(
                    stitched.cost(&tr) <= base.cost(&tr) + 1e-9,
                    "seed {seed} {policy:?}: stitched {} > base {}",
                    stitched.cost(&tr),
                    base.cost(&tr)
                );
            }
        }
    }
}
